"""L2 layer correctness: custom_vjp fwd + all 5 gradients vs dense autodiff.

This is the gold differential test: both implementations (MoEBlaze with
Algorithm-1 checkpointing; conventional baseline) must reproduce the
gradients jax.grad derives from the dense O(L·E·d·h) reference — proving
the paper's memory optimizations are *lossless* ("without comprising
accuracy", §1).
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import moe_layer as ml
from compile.kernels import ref


def _setup(seed, L, d, h, E, k):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = lambda key, *s, sc=0.2: jax.random.normal(key, s, jnp.float32) * sc
    return (r(ks[0], L, d), r(ks[1], E, d, sc=0.5), r(ks[2], E, d, h),
            r(ks[3], E, d, h), r(ks[4], E, h, d), r(ks[5], L, d))


VARIANTS = [
    ("swiglu", "moeblaze", True), ("swiglu", "moeblaze", False),
    ("swiglu", "baseline", False),
    ("silu", "moeblaze", True), ("silu", "moeblaze", False),
    ("silu", "baseline", False),
    ("relu", "moeblaze", True), ("relu", "baseline", False),
    ("gelu", "moeblaze", False),
]


@pytest.mark.parametrize("act,impl,pallas", VARIANTS)
def test_layer_forward_and_grads_vs_dense(act, impl, pallas):
    L, d, h, E, k, blk = 64, 16, 32, 4, 2, 8
    x, wg, w1, w2, w3, cot = _setup(0, L, d, h, E, k)
    spec = ml.MoeSpec(E, k, d, h, act, blk, impl, use_pallas=pallas)
    layer = ml.make_moe_layer(spec)

    y = layer(x, wg, w1, w2, w3)
    y_ref, _, _ = ref.moe_ref(x, wg, w1, w2, w3, k, act)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=3e-4, atol=1e-5)

    g = jax.grad(lambda *a: jnp.sum(layer(*a) * cot), argnums=(0, 1, 2, 3, 4))(
        x, wg, w1, w2, w3)
    g_ref = jax.grad(
        lambda *a: jnp.sum(ref.moe_ref(*a, k, act)[0] * cot),
        argnums=(0, 1, 2, 3, 4))(x, wg, w1, w2, w3)
    names = ["x", "wg", "w1", "w2", "w3"]
    for i, nm in enumerate(names):
        if act != "swiglu" and nm == "w2":
            continue  # w2 unused in plain activations
        np.testing.assert_allclose(np.asarray(g[i]), np.asarray(g_ref[i]),
                                   rtol=2e-3, atol=2e-4, err_msg=nm)


def test_moeblaze_equals_baseline_outputs():
    """Both impls compute the same function (bitwise-close)."""
    L, d, h, E, k, blk = 64, 16, 32, 8, 2, 8
    x, wg, w1, w2, w3, _ = _setup(1, L, d, h, E, k)
    args = (x, wg, w1, w2, w3)
    y_m = ml.make_moe_layer(ml.MoeSpec(E, k, d, h, "swiglu", blk, "moeblaze"))(*args)
    y_b = ml.make_moe_layer(ml.MoeSpec(E, k, d, h, "swiglu", blk, "baseline"))(*args)
    np.testing.assert_allclose(np.asarray(y_m), np.asarray(y_b),
                               rtol=1e-4, atol=1e-6)


def test_jit_and_grad_compose():
    """Layer must jit cleanly (the AOT requirement)."""
    L, d, h, E, k, blk = 32, 8, 16, 4, 2, 8
    x, wg, w1, w2, w3, cot = _setup(2, L, d, h, E, k)
    layer = ml.make_moe_layer(ml.MoeSpec(E, k, d, h, "swiglu", blk, "moeblaze"))
    f = jax.jit(jax.grad(lambda *a: jnp.sum(layer(*a) * cot), argnums=0))
    g1 = f(x, wg, w1, w2, w3)
    g2 = jax.grad(lambda *a: jnp.sum(layer(*a) * cot), argnums=0)(x, wg, w1, w2, w3)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    L=st.sampled_from([16, 32, 64]),
    E=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_layer_hypothesis_sweep(L, E, k, seed):
    k = min(k, E)
    d, h, blk = 8, 16, 8
    x, wg, w1, w2, w3, cot = _setup(seed, L, d, h, E, k)
    spec = ml.MoeSpec(E, k, d, h, "swiglu", blk, "moeblaze", use_pallas=True)
    layer = ml.make_moe_layer(spec)
    y = layer(x, wg, w1, w2, w3)
    y_ref, _, _ = ref.moe_ref(x, wg, w1, w2, w3, k, "swiglu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=5e-4, atol=5e-5)
    gx = jax.grad(lambda *a: jnp.sum(layer(*a) * cot))(x, wg, w1, w2, w3)
    gx_ref = jax.grad(lambda *a: jnp.sum(ref.moe_ref(*a, k, "swiglu")[0] * cot))(
        x, wg, w1, w2, w3)
    np.testing.assert_allclose(np.asarray(gx), np.asarray(gx_ref),
                               rtol=3e-3, atol=3e-4)


def test_residual_sets_match_design():
    """The saved-activation *names* match DESIGN.md §6 exactly."""
    L, d, h, E, k, blk = 32, 8, 16, 4, 2, 8
    x, wg, w1, w2, w3, _ = _setup(3, L, d, h, E, k)
    spec = ml.MoeSpec(E, k, d, h, "swiglu", blk, "moeblaze")
    _, res = ml.forward_with_residuals(spec, x, wg, w1, w2, w3)
    assert set(res) == {"gates", "ids", "pad_expert_token_indices",
                        "pad_token_index_map", "block_expert",
                        "pad_expert_token_offsets", "A", "B"}
    # save_yswi ablation re-adds the Algorithm-1-literal Yswi residual
    _, res_y = ml.forward_with_residuals(spec._replace(save_yswi=True),
                                         x, wg, w1, w2, w3)
    assert set(res_y) == set(res) | {"Yswi"}
    spec_b = spec._replace(impl="baseline", use_pallas=False)
    _, res_b = ml.forward_with_residuals(spec_b, x, wg, w1, w2, w3)
    assert set(res_b) == {"gates", "ids", "expert_token_indices",
                          "token_index_map", "expert_token_offsets",
                          "xs_routed", "A", "B", "sigma", "act", "Yswi"}
    # The headline: MoEBlaze never saves a routed (n, d) token buffer.
    assert not any(v.shape[-1:] == (d,) and v.ndim == 2 and v.shape[0] > L
                   for v in res.values())

"""Activation-memory accounting: analytic model == actual residual bytes.

Validates DESIGN.md §6: the formulas behind Figures 3/5 (and the Rust
memory model) agree byte-for-byte with what the custom_vjp layers really
save. Also checks the paper's §2.1/§2.2 worked examples (~94 GB routing
buffer, ~98 GB FFN intermediates for the DeepSeek-like config).
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import memory_model as mm
from compile import moe_layer as ml
from compile import configs as cfgs


def _setup(seed, L, d, h, E, k):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    r = lambda key, *s, sc=0.2: jax.random.normal(key, s, jnp.float32) * sc
    return (r(ks[0], L, d), r(ks[1], E, d, sc=0.5), r(ks[2], E, d, h),
            r(ks[3], E, d, h), r(ks[4], E, h, d))


CASES = [
    (64, 16, 32, 4, 2, 8, "swiglu"), (64, 16, 32, 4, 2, 8, "silu"),
    (32, 8, 64, 8, 3, 8, "swiglu"), (128, 16, 32, 16, 4, 16, "relu"),
]


@pytest.mark.parametrize("L,d,h,E,k,blk,act", CASES)
@pytest.mark.parametrize("impl", ["moeblaze", "baseline"])
def test_analytic_model_matches_actual_residuals(L, d, h, E, k, blk, act, impl):
    x, wg, w1, w2, w3 = _setup(0, L, d, h, E, k)
    spec = ml.MoeSpec(E, k, d, h, act, blk, impl,
                      use_pallas=(impl == "moeblaze"))
    _, res = ml.forward_with_residuals(spec, x, wg, w1, w2, w3)
    actual = ml.residual_bytes(res)
    model = mm.layer_bytes(impl, L, d, h, E, k, act, dtype_bytes=4, block=blk)
    assert model.total == actual, (
        f"{impl}/{act}: model {model.total} != actual {actual}")


def test_moeblaze_always_smaller():
    for c in cfgs.PAPER_CONFIGS:
        for act in ("silu", "swiglu"):
            m = mm.moeblaze_bytes(c.tokens, c.input_d, c.hidden,
                                  c.num_experts, c.top_k, act)
            b = mm.baseline_bytes(c.tokens, c.input_d, c.hidden,
                                  c.num_experts, c.top_k, act)
            assert m.total < b.total, (c.name, act)


def test_swiglu_ratio_exceeds_silu_ratio():
    """Fig 5 vs Fig 3: gated activations widen MoEBlaze's advantage."""
    for c in cfgs.PAPER_CONFIGS:
        r = {}
        for act in ("silu", "swiglu"):
            m = mm.moeblaze_bytes(c.tokens, c.input_d, c.hidden,
                                  c.num_experts, c.top_k, act).total
            b = mm.baseline_bytes(c.tokens, c.input_d, c.hidden,
                                  c.num_experts, c.top_k, act,
                                  mode="paper_baseline").total
            r[act] = b / m
        assert r["swiglu"] > 1.5
        assert r["swiglu"] > r["silu"] * 0.9  # swiglu ratio at least comparable


def test_paper_baseline_mode_reaches_reported_ratios():
    """conf3 swiglu: the paper reports ≈4× (40 GB → 10 GB)."""
    c = cfgs.by_name("conf3", scaled=False)
    m = mm.moeblaze_bytes(c.tokens, c.input_d, c.hidden, c.num_experts,
                          c.top_k, "swiglu").total
    b = mm.baseline_bytes(c.tokens, c.input_d, c.hidden, c.num_experts,
                          c.top_k, "swiglu", mode="paper_baseline").total
    assert 1.8 < b / m < 6.0


def test_deepseek_worked_examples():
    """§2.1: Mem_routing ≈ 94 GB; §2.2: Mem_act ≈ 98 GB (decimal GB; the
    paper rounds loosely — see memory_model docstrings)."""
    ds = cfgs.DEEPSEEK_EXAMPLE
    routing = mm.routing_buffer_bytes(ds["tokens"], ds["d"], ds["top_k"])
    act = mm.ffn_intermediate_bytes(ds["tokens"], ds["hidden"])
    assert abs(routing / 1e9 - 94) < 9, routing / 1e9
    assert abs(act / 1e9 - 98) < 9, act / 1e9


def test_memory_scales_linearly_in_tokens():
    """At paper scale the block-padding constant E·(block−1) is negligible
    and the footprint is linear in L (paper §2.2)."""
    a = mm.moeblaze_bytes(65536, 512, 2048, 8, 2, "swiglu").total
    b = mm.moeblaze_bytes(131072, 512, 2048, 8, 2, "swiglu").total
    assert 1.95 < b / a < 2.05


def test_index_bytes_negligible():
    """Paper §3: 'the token-expert index list … is extremely lightweight'."""
    c = cfgs.by_name("conf4", scaled=False)
    m = mm.moeblaze_bytes(c.tokens, c.input_d, c.hidden, c.num_experts,
                          c.top_k, "swiglu")
    assert m.index_bytes < 0.02 * m.total

"""MoE transformer LM + train step: shapes, loss decrease, AOT manifest."""

import sys, os, json
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import transformer as tf
from compile import train_step as ts

TINY = tf.LmConfig(vocab=64, d_model=32, n_layers=1, n_heads=2,
                   num_experts=4, top_k=2, seq_len=16, block=8)


def _batch(seed, cfg, B=2):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (B, cfg.seq_len + 1), 0, cfg.vocab)
    return toks[:, :-1].astype(jnp.int32), toks[:, 1:].astype(jnp.int32)


def test_forward_shapes():
    params = tf.init_params(jax.random.PRNGKey(0), TINY)
    tokens, _ = _batch(1, TINY)
    logits, aux = tf.forward(params, tokens, TINY)
    assert logits.shape == (2, TINY.seq_len, TINY.vocab)
    assert np.isfinite(np.asarray(aux))


def test_param_spec_consistent():
    params = tf.init_params(jax.random.PRNGKey(0), TINY)
    spec = tf.param_spec(TINY)
    assert len(params) == len(spec)
    for p, (name, shape, _) in zip(params, spec):
        assert p.shape == tuple(shape), name


def test_initial_loss_near_uniform():
    params = tf.init_params(jax.random.PRNGKey(0), TINY)
    tokens, targets = _batch(2, TINY)
    loss = tf.loss_fn(params, tokens, targets, TINY)
    assert abs(float(loss) - np.log(TINY.vocab)) < 1.0


def test_train_step_decreases_loss():
    """A few Adam steps on one repeated batch must overfit it."""
    cfg = TINY
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    tokens, targets = _batch(3, cfg)
    step = jax.jit(ts.make_train_step(cfg))
    first = None
    loss = None
    for i in range(8):
        params, m, v, loss = step(params, m, v, jnp.float32(i + 1),
                                  jnp.float32(3e-3), tokens, targets)
        if first is None:
            first = float(loss)
    assert float(loss) < first - 0.1, (first, float(loss))


def test_baseline_impl_same_loss():
    """MoEBlaze and baseline LMs compute identical losses."""
    cfg_m = TINY._replace(impl="moeblaze")
    cfg_b = TINY._replace(impl="baseline", use_pallas=False)
    params = tf.init_params(jax.random.PRNGKey(0), cfg_m)
    tokens, targets = _batch(4, TINY)
    lm = tf.loss_fn(params, tokens, targets, cfg_m)
    lb = tf.loss_fn(params, tokens, targets, cfg_b)
    np.testing.assert_allclose(float(lm), float(lb), rtol=1e-4)


ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_manifest_io_shapes_match_lowering():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    arts = {a["name"]: a for a in man["artifacts"]}
    assert len(arts) >= 33
    # every referenced HLO file exists and is non-trivial
    for a in man["artifacts"]:
        p = os.path.join(ART, a["file"])
        assert os.path.exists(p), a["file"]
        assert os.path.getsize(p) > 1000
    # lm manifest params match transformer.param_spec
    lm = man["lm"]
    cfg = tf.LmConfig(**{k: v for k, v in lm["config"].items()})
    spec = tf.param_spec(cfg)
    assert len(spec) == len(lm["params"])
    for (name, shape, _), entry in zip(spec, lm["params"]):
        assert entry["name"] == name
        assert tuple(entry["shape"]) == tuple(shape)
    # layer_step artifacts: one per conf × act × impl
    for c in ("conf1", "conf2", "conf3", "conf4", "conf5", "conf6", "conf7"):
        for act in ("silu", "swiglu"):
            for impl in ("moeblaze", "baseline"):
                assert f"layer_step_{c}_{act}_{impl}" in arts

"""L1 kernel correctness: Pallas vs pure-jnp oracles (+ hypothesis sweeps)."""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, fused_swiglu as fs, gather_mlp as gm

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape, scale=0.3):
    return jax.random.normal(key, shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# fused_swiglu forward
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,d,h", [(16, 8, 16), (64, 32, 64), (128, 16, 32)])
def test_fused_swiglu_fwd_matches_ref(m, d, h):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x, w1, w2 = _rand(ks[0], m, d), _rand(ks[1], d, h), _rand(ks[2], d, h)
    a, b, y = fs.fused_swiglu_fwd(x, w1, w2)
    np.testing.assert_allclose(a, x @ w1, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b, x @ w2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(y, ref.swiglu(x, w1, w2), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("activation", ["silu", "relu", "gelu"])
def test_fused_plain_activation_fwd(activation):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x, w1, w2 = _rand(ks[0], 32, 8), _rand(ks[1], 8, 16), _rand(ks[2], 8, 16)
    a, b, y = fs.fused_swiglu_fwd(x, w1, w2, activation=activation)
    assert b is None
    np.testing.assert_allclose(
        y, ref.apply_activation(x @ w1, None, activation), rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(4, 96),
    d=st.integers(2, 24),
    h=st.integers(2, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_swiglu_fwd_hypothesis(m, d, h, seed):
    """Shape sweep: arbitrary (m, d, h), incl. non-divisible block shapes."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x, w1, w2 = _rand(ks[0], m, d), _rand(ks[1], d, h), _rand(ks[2], d, h)
    _, _, y = fs.fused_swiglu_fwd(x, w1, w2)
    np.testing.assert_allclose(y, ref.swiglu(x, w1, w2), rtol=2e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# fused backward epilogue (SiLU recomputation — Algorithm 1 line 24)
# ---------------------------------------------------------------------------


def test_bwd_epilogue_matches_autodiff():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    a, b, g = _rand(ks[0], 32, 16), _rand(ks[1], 32, 16), _rand(ks[2], 32, 16)
    da, db = fs.fused_swiglu_bwd_epilogue(a, b, g)
    ref_da, ref_db = jax.vjp(lambda a_, b_: ref.silu(a_) * b_, a, b)[1](g)
    np.testing.assert_allclose(da, ref_da, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(db, ref_db, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("activation", ["silu", "relu"])
def test_plain_bwd_epilogue(activation):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    a, g = _rand(ks[0], 16, 8), _rand(ks[1], 16, 8)
    da = fs.fused_act_bwd_epilogue(a, g, activation=activation)
    (ref_da,) = jax.vjp(lambda a_: ref.apply_activation(a_, None, activation), a)[1](g)
    np.testing.assert_allclose(da, ref_da, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(m=st.integers(2, 64), h=st.integers(2, 64), seed=st.integers(0, 2**31 - 1))
def test_bwd_epilogue_hypothesis(m, h, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a, b, g = _rand(ks[0], m, h, scale=2.0), _rand(ks[1], m, h), _rand(ks[2], m, h)
    da, db = fs.fused_swiglu_bwd_epilogue(a, b, g)
    ref_da, ref_db = jax.vjp(lambda a_, b_: ref.silu(a_) * b_, a, b)[1](g)
    np.testing.assert_allclose(da, ref_da, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(db, ref_db, rtol=2e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# gather/grouped/combine/scatter kernels
# ---------------------------------------------------------------------------


def _setup_moe(seed, L=64, d=16, h=32, E=4, k=2, blk=8):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = _rand(ks[0], L, d)
    w1, w2 = _rand(ks[1], E, d, h), _rand(ks[2], E, d, h)
    w3, wg = _rand(ks[3], E, h, d), _rand(ks[4], E, d, scale=0.5)
    gates, ids = ref.gating(x, wg, k)
    pd = ref.padded_dispatch_ref(ids, E, blk)
    return x, w1, w2, w3, wg, gates, ids, pd, blk


def test_gather_dual_gemm_matches_grouped_ref():
    x, w1, w2, w3, wg, gates, ids, pd, blk = _setup_moe(4)
    a, b, y = gm.gather_dual_gemm(x, w1, w2, pd["pad_expert_token_indices"],
                                  pd["block_expert"], block_slots=blk)
    # reference: masked gather + ragged grouped mlp
    idx = pd["pad_expert_token_indices"]
    xs = x[jnp.maximum(idx, 0)] * (idx >= 0).astype(x.dtype)[:, None]
    gsz = pd["pad_expert_token_offsets"][1:] - pd["pad_expert_token_offsets"][:-1]
    a_r, b_r, hid_r, _ = ref.grouped_mlp_ref(xs, w1, w2, w3, gsz)
    np.testing.assert_allclose(a, a_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(b, b_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(y, hid_r, rtol=1e-5, atol=1e-6)


def test_full_moe_forward_path_matches_dense_ref():
    x, w1, w2, w3, wg, gates, ids, pd, blk = _setup_moe(5)
    a, b, yswi = gm.gather_dual_gemm(x, w1, w2, pd["pad_expert_token_indices"],
                                     pd["block_expert"], block_slots=blk)
    y2 = gm.grouped_gemm(yswi, w3, pd["block_expert"], block_slots=blk)
    y = gm.combine(y2, pd["pad_token_index_map"], gates)
    y_ref, _, _ = ref.moe_ref(x, wg, w1, w2, w3, 2, "swiglu")
    np.testing.assert_allclose(y, y_ref, rtol=3e-4, atol=1e-5)


def test_scatter_rows_is_combine_adjoint():
    """⟨combine(y2), dy⟩ == ⟨y2, scatter(dy)⟩ — adjointness property."""
    x, w1, w2, w3, wg, gates, ids, pd, blk = _setup_moe(6)
    n_pad, L, d = pd["n_pad"], x.shape[0], x.shape[1]
    ks = jax.random.split(jax.random.PRNGKey(7), 2)
    y2 = _rand(ks[0], n_pad, d)
    dy = _rand(ks[1], L, d)
    gos = jnp.zeros((n_pad,), jnp.float32).at[
        pd["pad_token_index_map"].reshape(-1)].set(gates.reshape(-1))
    lhs = jnp.sum(gm.combine(y2, pd["pad_token_index_map"], gates) * dy)
    rhs = jnp.sum(y2 * gm.scatter_rows(dy, pd["pad_expert_token_indices"],
                                       gos, block_slots=blk))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    L=st.sampled_from([16, 32, 64]),
    E=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_moe_forward_path_hypothesis(L, E, k, seed):
    d, h, blk = 8, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = _rand(ks[0], L, d)
    w1, w2 = _rand(ks[1], E, d, h), _rand(ks[2], E, d, h)
    w3, wg = _rand(ks[3], E, h, d), _rand(ks[4], E, d, scale=0.5)
    gates, ids = ref.gating(x, wg, k)
    pd = ref.padded_dispatch_ref(ids, E, blk)
    a, b, yswi = gm.gather_dual_gemm(x, w1, w2, pd["pad_expert_token_indices"],
                                     pd["block_expert"], block_slots=blk)
    y2 = gm.grouped_gemm(yswi, w3, pd["block_expert"], block_slots=blk)
    y = gm.combine(y2, pd["pad_token_index_map"], gates)
    y_ref, _, _ = ref.moe_ref(x, wg, w1, w2, w3, k, "swiglu")
    np.testing.assert_allclose(y, y_ref, rtol=5e-4, atol=5e-5)

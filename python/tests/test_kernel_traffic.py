"""L1 performance-structure tests (DESIGN.md §8).

Interpret-mode timings are not a TPU proxy, so kernel performance is
validated *structurally*: modelled HBM traffic, VMEM tile footprints, and
MXU alignment of the chosen block shapes. These encode the paper's §5.2
bandwidth argument ("epilogue fusion eliminates global writes of a, b and
subsequent re-reads … halves the input reads of x").
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import pytest

from compile import configs as cfgs

BYTES = 2  # bf16 on real hardware


def unfused_swiglu_traffic(L, d, h):
    """Conventional pipeline: separate kernels for each stage.

    GEMM-a: read x (L·d) + W1, write a (L·h)
    GEMM-b: read x again + W2, write b
    sigmoid: read a, write σ(a)
    silu mul: read a, σ(a), write SiLU(a)
    gate mul: read SiLU(a), b, write Yswi
    (weights excluded from both sides — identical contribution)
    """
    read = 2 * L * d + L * h * (1 + 2 + 2)
    write = L * h * (1 + 1 + 1 + 1 + 1)
    return (read + write) * BYTES


def fused_swiglu_traffic(L, d, h, training=True):
    """MoEBlaze fused kernel: read x once, write only (A, B, Yswi) in
    training mode (Algorithm 1), only Yswi in inference."""
    read = L * d
    write = L * h * (3 if training else 1)
    return (read + write) * BYTES


@pytest.mark.parametrize("conf", cfgs.PAPER_CONFIGS, ids=lambda c: c.name)
def test_fused_epilogue_saves_traffic_on_all_configs(conf):
    L, d, h = conf.tokens, conf.input_d, conf.hidden
    ratio = unfused_swiglu_traffic(L, d, h) / fused_swiglu_traffic(L, d, h)
    # paper §5.2: eliminates a/b round-trips and halves x reads. With the
    # training-mode stores kept (A, B, Yswi) the modelled saving is ~2.3x.
    assert ratio > 2.0, (conf.name, ratio)


def test_inference_mode_fusion_is_stronger():
    c = cfgs.by_name("conf4", scaled=False)
    t = fused_swiglu_traffic(c.tokens, c.input_d, c.hidden, training=True)
    i = fused_swiglu_traffic(c.tokens, c.input_d, c.hidden, training=False)
    assert t / i > 2.5  # dropping A/B stores pays off further


def test_bwd_epilogue_recompute_beats_loading():
    """Recomputing SiLU in bwd (Alg. 1 line 24) vs loading saved σ/SiLU:
    the recompute variant reads A, B, dY and writes dA, dB (5 L·h tensors);
    the conventional variant additionally reads σ(A) and SiLU(A)
    (7 L·h tensors). Point-wise FLOPs are free at these intensities."""
    Lh = 1
    recompute = 5 * Lh
    conventional = 7 * Lh
    assert recompute < conventional


# ---------------------------------------------------------------------------
# VMEM footprint + MXU alignment of the shipped block shapes
# ---------------------------------------------------------------------------

VMEM_LIMIT = 16 * 1024 * 1024  # ~16 MiB/core on modern TPUs


def fused_kernel_vmem(L, d, h, bl, bh, dtype=4):
    """Resident tiles of the fused dual-GEMM kernel at paper block sizes:
    x tile (bl, d) + W1/W2 column tiles (d, bh) + out tiles a/b/y (bl, bh)."""
    return dtype * (bl * d + 2 * d * bh + 3 * bl * bh)


@pytest.mark.parametrize("conf", cfgs.PAPER_CONFIGS, ids=lambda c: c.name)
def test_paper_scale_tiles_fit_vmem(conf):
    bl = bh = 128  # the paper-scale tile (DESIGN.md §8)
    v = fused_kernel_vmem(conf.tokens, conf.input_d, conf.hidden, bl, bh, dtype=2)
    assert v < VMEM_LIMIT, (conf.name, v)


def test_mxu_alignment_at_paper_scale():
    """The MXU systolic array wants multiples of 128 on both GEMM dims."""
    for c in cfgs.PAPER_CONFIGS:
        assert c.input_d % 128 == 0
        assert c.hidden % 128 == 0
        assert cfgs.PAPER_BLOCK % 128 == 0


def test_dispatch_metadata_vs_routed_buffer():
    """§3: index lists are 'extremely lightweight' — < 1% of the routed
    activation buffer they replace at paper scale."""
    for c in cfgs.PAPER_CONFIGS:
        n = c.tokens * c.top_k
        metadata = 4 * (4 * n)            # four ~n-length i32 structures
        routed = n * c.input_d * BYTES
        assert metadata < 0.02 * routed, c.name

"""Dispatch-structure construction: Pallas 3-step build vs sort-based oracle.

Covers the paper's §4.1 worked example (Figure 2) verbatim, full
structural invariants, and hypothesis sweeps over (L, E, k).
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, dispatch as dk


def _random_ids(seed, L, E, k):
    """Distinct top-k expert ids per token (as top_k guarantees)."""
    key = jax.random.PRNGKey(seed)
    scores = jax.random.uniform(key, (L, E))
    _, ids = jax.lax.top_k(scores, k)
    return ids.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Paper Figure 2 worked example
# ---------------------------------------------------------------------------

FIG2_IDS = jnp.array([[2, 3], [0, 1], [0, 3], [1, 2], [0, 3]], jnp.int32)


def test_paper_figure2_example():
    d = ref.dispatch_ref(FIG2_IDS, 4)
    np.testing.assert_array_equal(
        d["token_expert_indices"], [2, 3, 0, 1, 0, 3, 1, 2, 0, 3])
    np.testing.assert_array_equal(
        d["expert_token_indices"], [1, 2, 4, 1, 3, 0, 3, 0, 2, 4])
    np.testing.assert_array_equal(d["expert_token_offsets"], [0, 3, 5, 7, 10])
    # "token_index_map[0] = {5, 7}" (paper §4.1)
    np.testing.assert_array_equal(d["token_index_map"][0], [5, 7])


def test_paper_figure2_pallas_build_matches():
    pd = ref.padded_dispatch_ref(FIG2_IDS, 4, block=4)
    bd = dk.build_dispatch(FIG2_IDS, 4, block=4, block_l=5)
    for key in ("expert_lengths", "pad_expert_token_offsets",
                "pad_expert_token_indices", "pad_token_index_map",
                "block_expert"):
        np.testing.assert_array_equal(np.asarray(bd[key]), np.asarray(pd[key]),
                                      err_msg=key)


# ---------------------------------------------------------------------------
# Structural invariants (mirror of the Rust testkit properties)
# ---------------------------------------------------------------------------


def check_invariants(ids, E, d):
    L, k = ids.shape
    n = L * k
    offs = np.asarray(d["expert_token_offsets"])
    lens = np.asarray(d["expert_lengths"])
    eti = np.asarray(d["expert_token_indices"])
    tim = np.asarray(d["token_index_map"])

    assert offs[0] == 0 and offs[-1] == n
    assert np.all(np.diff(offs) >= 0)
    np.testing.assert_array_equal(np.diff(offs), lens)
    # expert_token_indices is a permutation of each token id repeated k times
    np.testing.assert_array_equal(np.sort(eti), np.repeat(np.arange(L), k))
    # token_index_map inverts expert_token_indices
    np.testing.assert_array_equal(eti[tim.reshape(-1)],
                                  np.repeat(np.arange(L), k))
    # every slot of expert e holds a token that chose e
    ids_np = np.asarray(ids)
    for e in range(E):
        for s in range(offs[e], offs[e + 1]):
            assert e in ids_np[eti[s]]


@settings(max_examples=25, deadline=None)
@given(
    L=st.integers(2, 64),
    E=st.sampled_from([2, 4, 8, 16]),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_dispatch_invariants_hypothesis(L, E, k, seed):
    k = min(k, E)
    ids = _random_ids(seed, L, E, k)
    d = ref.dispatch_ref(ids, E)
    check_invariants(ids, E, d)


@settings(max_examples=15, deadline=None)
@given(
    L=st.sampled_from([8, 16, 32, 64]),
    E=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 3),
    block=st.sampled_from([4, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_build_equals_sort_build_hypothesis(L, E, k, block, seed):
    """The paper's central §4.2 claim: 3-step build ≡ sort build."""
    k = min(k, E)
    ids = _random_ids(seed, L, E, k)
    pd = ref.padded_dispatch_ref(ids, E, block)
    bd = dk.build_dispatch(ids, E, block)
    for key in ("expert_lengths", "pad_expert_token_offsets",
                "pad_expert_token_indices", "pad_token_index_map",
                "block_expert"):
        np.testing.assert_array_equal(np.asarray(bd[key]), np.asarray(pd[key]),
                                      err_msg=key)


# ---------------------------------------------------------------------------
# Individual kernel steps
# ---------------------------------------------------------------------------


def test_dense_map_counts():
    ids = _random_ids(11, 32, 8, 2)
    dense = dk.build_dense_map(ids, 8)
    assert dense.shape == (32, 8)
    np.testing.assert_array_equal(np.asarray(dense).sum(axis=1), np.full(32, 2))


def test_column_scan_lengths_and_ranks():
    ids = _random_ids(12, 32, 4, 2)
    dense = dk.build_dense_map(ids, 4)
    lengths, colrank = dk.column_scan(dense)
    dn = np.asarray(dense)
    np.testing.assert_array_equal(lengths, dn.sum(axis=0))
    np.testing.assert_array_equal(np.asarray(colrank),
                                  np.cumsum(dn, axis=0) - dn)


def test_pad_markers_are_minus_one():
    ids = _random_ids(13, 16, 4, 2)
    bd = dk.build_dispatch(ids, 4, block=8)
    eti = np.asarray(bd["pad_expert_token_indices"])
    lens = np.asarray(bd["expert_lengths"])
    pad_offs = np.asarray(bd["pad_expert_token_offsets"])
    for e in range(4):
        seg = eti[pad_offs[e]:pad_offs[e + 1]]
        assert np.all(seg[:lens[e]] >= 0)
        assert np.all(seg[lens[e]:] == -1)


def test_degenerate_all_tokens_one_expert():
    """Worst-case imbalance: every token routes to expert 0 (k=1)."""
    L, E = 16, 4
    ids = jnp.zeros((L, 1), jnp.int32)
    bd = dk.build_dispatch(ids, E, block=8)
    lens = np.asarray(bd["expert_lengths"])
    np.testing.assert_array_equal(lens, [L, 0, 0, 0])
    pd = ref.padded_dispatch_ref(ids, E, 8)
    np.testing.assert_array_equal(np.asarray(bd["pad_expert_token_indices"]),
                                  np.asarray(pd["pad_expert_token_indices"]))

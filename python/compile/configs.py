"""Paper Table-1 MoE configurations, at paper scale and CPU-bench scale.

The paper's seven configs (Table 1) use ffn_hidden = 4 * input_d. The
"scaled" variants keep every *ratio* (k/E, d/h, the relative ordering of
L·k·d across configs) while dividing the absolute sizes so a single-core
CPU PJRT client can run fwd+bwd in tractable time (DESIGN.md §3):
d ÷ 8, batch → 4 (2 where the paper used 16), seq ÷ 16.

Memory figures (Fig 3/5) are *analytic* and therefore always computed at
full paper scale; only the timed figures (Fig 4/6) use the scaled sizes.
"""

from __future__ import annotations

from typing import NamedTuple


class PaperConfig(NamedTuple):
    name: str
    input_d: int
    num_experts: int
    top_k: int
    batch: int
    seq_len: int

    @property
    def hidden(self) -> int:
        return 4 * self.input_d

    @property
    def tokens(self) -> int:
        return self.batch * self.seq_len


# Paper Table 1 (full scale) ------------------------------------------------
PAPER_CONFIGS = [
    PaperConfig("conf1", 512, 4, 1, 32, 2048),
    PaperConfig("conf2", 1024, 8, 2, 32, 2048),
    PaperConfig("conf3", 1024, 16, 4, 32, 2048),
    PaperConfig("conf4", 2048, 16, 4, 32, 1024),
    PaperConfig("conf5", 512, 16, 4, 32, 1024),
    PaperConfig("conf6", 1024, 16, 4, 16, 1024),
    PaperConfig("conf7", 2048, 8, 4, 16, 512),
]

# CPU-bench scale (ratios preserved; see module docstring) -------------------
SCALED_CONFIGS = [
    PaperConfig("conf1", 64, 4, 1, 4, 128),
    PaperConfig("conf2", 128, 8, 2, 4, 128),
    PaperConfig("conf3", 128, 16, 4, 4, 128),
    PaperConfig("conf4", 256, 16, 4, 4, 64),
    PaperConfig("conf5", 64, 16, 4, 4, 64),
    PaperConfig("conf6", 128, 16, 4, 2, 64),
    PaperConfig("conf7", 256, 8, 4, 2, 32),
]

# Slot-block size for the block-aligned index layout. The paper's kernels
# tile at 128 on H100; at the scaled sizes a 32-wide block keeps padding
# overhead proportionally similar.
SCALED_BLOCK = 32
PAPER_BLOCK = 128

# DeepSeek-like config for the §2.1/§2.2 worked examples (94 GB / 98 GB).
DEEPSEEK_EXAMPLE = dict(tokens=2_000_000, d=6144, hidden=24576, top_k=4)


def by_name(name: str, scaled: bool = True) -> PaperConfig:
    src = SCALED_CONFIGS if scaled else PAPER_CONFIGS
    for c in src:
        if c.name == name:
            return c
    raise KeyError(name)

"""L1 Pallas kernels: 3-step dispatch-structure construction (paper §4.2).

The paper replaces the multi-pass radix-sort dispatch pipeline with three
atomic-free, data-parallel steps:

  1. **Build dense token-expert map** — one CTA-tile of token rows per grid
     step writes the one-hot routing map. Here: grid over L-tiles, each
     tile computes its (bl, E) one-hot block in VMEM.
  2. **Compute expert lengths** — one CTA per expert column counts its
     non-zeros (warp reduction → per-block `jnp.sum`) and performs the
     CTA-local exclusive scan (prefix sum → `jnp.cumsum`) that becomes the
     location map column.
  3. **Route indices to gates** — with the location map (= CTA-local scan +
     global expert offset), every non-zero knows its final position in
     ``expert_token_indices``; a simple parallel pass writes token ids (and
     the inverse ``token_index_map``) with no atomics: each destination is
     written exactly once.

The exclusive prefix over the E per-expert lengths (E is tiny) happens at
the jnp level between kernels, exactly like the paper's "prefix-sum outside
the initial counting kernel".

All kernels run under ``interpret=True``; the grid iterates sequentially,
which matches the determinism assumptions (TPU grids are sequential per
core as well).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_L = 256


def _pick_block(dim: int, preferred: int) -> int:
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return max(b, 1)


# ---------------------------------------------------------------------------
# Step 1: dense token-expert map
# ---------------------------------------------------------------------------


def _dense_map_kernel(ids_ref, dense_ref, *, num_experts: int):
    ids = ids_ref[...]  # (bl, k)
    onehot = jax.nn.one_hot(ids, num_experts, dtype=jnp.int32)  # (bl, k, E)
    dense_ref[...] = jnp.sum(onehot, axis=1)


def build_dense_map(topk_ids, num_experts: int, *, block_l: int = DEFAULT_BLOCK_L,
                    interpret: bool = True):
    """dense[i, e] = 1 iff token i routed to expert e. (L, E) i32."""
    L, k = topk_ids.shape
    bl = _pick_block(L, block_l)
    (dense,) = pl.pallas_call(
        functools.partial(_dense_map_kernel, num_experts=num_experts),
        grid=(L // bl,),
        in_specs=[pl.BlockSpec((bl, k), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((bl, num_experts), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((L, num_experts), jnp.int32)],
        interpret=interpret,
    )(topk_ids)
    return dense


# ---------------------------------------------------------------------------
# Step 2: expert lengths + per-column exclusive scan (location-map column)
# ---------------------------------------------------------------------------


def _column_scan_kernel(dense_ref, len_ref, rank_ref):
    col = dense_ref[...]  # (L, be) one expert-column tile
    len_ref[...] = jnp.sum(col, axis=0)
    # CTA-local exclusive scan along the token axis: rank of each non-zero
    # inside its expert column (paper §4.2, "tile-level scan").
    rank_ref[...] = jnp.cumsum(col, axis=0) - col


def column_scan(dense, *, interpret: bool = True):
    """Returns (expert_lengths (E,), colrank (L, E))."""
    L, E = dense.shape
    lengths, colrank = pl.pallas_call(
        _column_scan_kernel,
        grid=(E,),
        in_specs=[pl.BlockSpec((L, 1), lambda e: (0, e))],
        out_specs=[
            pl.BlockSpec((1,), lambda e: (e,)),
            pl.BlockSpec((L, 1), lambda e: (0, e)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E,), jnp.int32),
            jax.ShapeDtypeStruct((L, E), jnp.int32),
        ],
        interpret=interpret,
    )(dense)
    return lengths, colrank


# ---------------------------------------------------------------------------
# Step 3: route indices to gates (location map -> final scatter)
# ---------------------------------------------------------------------------


def _route_kernel(ids_ref, rank_ref, offs_ref, eti_ref, tim_ref, *,
                  block_l: int, num_experts: int):
    i = pl.program_id(0)

    # Initialize the full expert_token_indices output to the pad marker on
    # the first sequential grid step (interpret/TPU grids are sequential).
    @pl.when(i == 0)
    def _init():
        eti_ref[...] = jnp.full_like(eti_ref, -1)

    ids = ids_ref[...]                       # (bl, k) expert ids per token
    rank = rank_ref[...]                     # (bl, E) column ranks
    offs = offs_ref[...]                     # (E+1,) padded expert offsets
    bl, k = ids.shape
    token0 = i * block_l
    tokens = token0 + jax.lax.broadcasted_iota(jnp.int32, (bl, k), 0)
    # location map: final position of routed copy (i, j) (paper §4.2 (ii)):
    # CTA-local rank + global expert offset.
    rank_sel = jnp.take_along_axis(rank, ids, axis=1)  # (bl, k)
    pos = offs[ids] + rank_sel                          # (bl, k)
    # Contention-free scatter: every pos is unique by construction.
    eti_ref[pos.reshape(-1)] = tokens.reshape(-1)
    tim_ref[...] = pos


def route_indices(topk_ids, colrank, pad_offsets, n_pad: int, *,
                  block_l: int = DEFAULT_BLOCK_L, interpret: bool = True):
    """Returns (pad_expert_token_indices (n_pad,), pad_token_index_map (L,k))."""
    L, k = topk_ids.shape
    E = colrank.shape[1]
    bl = _pick_block(L, block_l)
    eti, tim = pl.pallas_call(
        functools.partial(_route_kernel, block_l=bl, num_experts=E),
        grid=(L // bl,),
        in_specs=[
            pl.BlockSpec((bl, k), lambda i: (i, 0)),
            pl.BlockSpec((bl, E), lambda i: (i, 0)),
            pl.BlockSpec((E + 1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((n_pad,), lambda i: (0,)),  # full output, disjoint writes
            pl.BlockSpec((bl, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((L, k), jnp.int32),
        ],
        interpret=interpret,
    )(topk_ids, colrank, pad_offsets)
    return eti, tim


# ---------------------------------------------------------------------------
# End-to-end dispatch build (the MoEBlaze replacement for sort_build)
# ---------------------------------------------------------------------------


def build_dispatch(topk_ids, num_experts: int, block: int, *,
                   block_l: int = DEFAULT_BLOCK_L, interpret: bool = True):
    """Construct the block-aligned §4.1 index structures without sorting.

    Returns a dict with:
      expert_lengths           (E,)
      expert_token_offsets     (E+1,)   compact offsets
      pad_expert_token_offsets (E+1,)   block-aligned offsets
      pad_expert_token_indices (n_pad,) token id per padded slot (-1 pad)
      pad_token_index_map      (L, k)   padded slot of each routed copy
      block_expert             (n_pad/block,) expert id per slot block
      n_pad                    python int (static)
    """
    L, k = topk_ids.shape
    n_pad = ref.padded_len(L, k, num_experts, block)

    dense = build_dense_map(topk_ids, num_experts, block_l=block_l,
                            interpret=interpret)
    lengths, colrank = column_scan(dense, interpret=interpret)

    # Tiny E-length exclusive prefix between kernels (paper: "outside the
    # initial counting kernel").
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(lengths).astype(jnp.int32)]
    )
    padded_lengths = ((lengths + block - 1) // block) * block
    pad_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded_lengths).astype(jnp.int32)]
    )

    eti, tim = route_indices(topk_ids, colrank, pad_offsets, n_pad,
                             block_l=block_l, interpret=interpret)

    nblocks = n_pad // block
    blk = jnp.arange(nblocks, dtype=jnp.int32) * block
    block_expert = jnp.clip(
        jnp.searchsorted(pad_offsets[1:], blk, side="right").astype(jnp.int32),
        0, num_experts - 1,
    )

    return {
        "expert_lengths": lengths,
        "expert_token_offsets": offsets,
        "pad_expert_token_offsets": pad_offsets,
        "pad_expert_token_indices": eti,
        "pad_token_index_map": tim,
        "block_expert": block_expert,
        "n_pad": n_pad,
        "block": block,
    }


# ---------------------------------------------------------------------------
# Vectorized jnp twin of the 3-step build (no pallas, no sorting)
# ---------------------------------------------------------------------------


def build_dispatch_jnp(topk_ids, num_experts: int, block: int):
    """The same 3-step, sort-free construction as `build_dispatch`, written
    as whole-array jnp ops (dense one-hot map -> column counts/scans ->
    location-map scatter). This is the XLA-fused variant used by the
    benchmark artifacts: identical outputs, no interpret-mode overhead.
    """
    L, k = topk_ids.shape
    n_pad = ref.padded_len(L, k, num_experts, block)

    # Step 1: dense token-expert map (one-hot, summed over the k slots).
    dense = jnp.sum(jax.nn.one_hot(topk_ids, num_experts, dtype=jnp.int32), axis=1)

    # Step 2: expert lengths + column-local exclusive scan (location map).
    lengths = jnp.sum(dense, axis=0).astype(jnp.int32)
    colrank = (jnp.cumsum(dense, axis=0) - dense).astype(jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(lengths).astype(jnp.int32)])
    padded_lengths = ((lengths + block - 1) // block) * block
    pad_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded_lengths).astype(jnp.int32)])

    # Step 3: location map = CTA-local rank + global offset; scatter once.
    rank_sel = jnp.take_along_axis(colrank, topk_ids, axis=1)      # (L, k)
    pos = pad_offsets[topk_ids] + rank_sel                          # (L, k)
    tokens = jnp.broadcast_to(
        jnp.arange(L, dtype=jnp.int32)[:, None], (L, k))
    eti = jnp.full((n_pad,), -1, jnp.int32).at[pos.reshape(-1)].set(
        tokens.reshape(-1))

    nblocks = n_pad // block
    blk = jnp.arange(nblocks, dtype=jnp.int32) * block
    block_expert = jnp.clip(
        jnp.searchsorted(pad_offsets[1:], blk, side="right").astype(jnp.int32),
        0, num_experts - 1)

    return {
        "expert_lengths": lengths,
        "expert_token_offsets": offsets,
        "pad_expert_token_offsets": pad_offsets,
        "pad_expert_token_indices": eti,
        "pad_token_index_map": pos,
        "block_expert": block_expert,
        "n_pad": n_pad,
        "block": block,
    }


def build_dispatch_compact_jnp(topk_ids, num_experts: int):
    """Compact (unpadded) 3-step build for the XLA-fused path.

    `jax.lax.ragged_dot` consumes true group sizes, so the fused lowering
    needs no block alignment at all — zero padded slots, zero wasted
    GEMM rows (the blocked Pallas kernels still use the padded variant).
    Same sort-free construction: one-hot map -> column scan -> location
    map = column rank + global offset.
    """
    L, k = topk_ids.shape

    dense = jnp.sum(jax.nn.one_hot(topk_ids, num_experts, dtype=jnp.int32), axis=1)
    lengths = jnp.sum(dense, axis=0).astype(jnp.int32)
    colrank = (jnp.cumsum(dense, axis=0) - dense).astype(jnp.int32)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(lengths).astype(jnp.int32)])

    rank_sel = jnp.take_along_axis(colrank, topk_ids, axis=1)  # (L, k)
    pos = offsets[topk_ids] + rank_sel                          # (L, k) compact
    tokens = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[:, None], (L, k))
    eti = jnp.zeros((L * k,), jnp.int32).at[pos.reshape(-1)].set(tokens.reshape(-1))

    return {
        "expert_lengths": lengths,
        "expert_token_offsets": offsets,
        "expert_token_indices": eti,   # (n,) compact, expert-major
        "token_index_map": pos,        # (L, k) compact positions
    }

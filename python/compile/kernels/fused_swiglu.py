"""L1 Pallas kernels: fused SwiGLU epilogue (paper §5).

Two kernels live here:

* ``fused_swiglu_fwd``   — the "epilogue fusion" forward: one pass over a
  token tile computes ``a = x@W1`` and ``b = x@W2`` on the MXU, applies the
  SiLU epilogue in-register (VMEM tile), and stores only ``(A, B, Yswi)``.
  ``sigmoid(a)`` and ``SiLU(a)`` are **transient** — never written to HBM
  (paper Algorithm 1, lines 5–11).

* ``fused_swiglu_bwd_epilogue`` — the backward epilogue: recomputes
  ``SiLU(A)`` from the checkpointed ``A`` (paper Algorithm 1, line 24) and
  produces ``(dA, dB)`` in a single fused pass, eliminating the σ(a)/SiLU(a)
  activation buffers a conventional implementation saves.

Hardware adaptation (DESIGN.md §2): the paper fuses in CUDA registers/smem
on H100; here the same dataflow is expressed as a Pallas VMEM tile with the
HBM↔VMEM schedule in ``BlockSpec``. Kernels run under ``interpret=True``
so they lower to plain HLO executable by the CPU PJRT client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

DEFAULT_BLOCK_L = 128
DEFAULT_BLOCK_H = 128


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of `dim` that is <= preferred (TPU tiles want 128)."""
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return max(b, 1)


# ---------------------------------------------------------------------------
# Forward: fused dual-GEMM + SiLU epilogue (single expert / dense tile)
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, w1_ref, w2_ref, a_ref, b_ref, y_ref, *, gated: bool,
                activation: str):
    """One (block_l, block_h) tile: load x once, both GEMMs, epilogue."""
    xb = x_ref[...]
    a = jnp.dot(xb, w1_ref[...], preferred_element_type=jnp.float32)
    a_ref[...] = a.astype(a_ref.dtype)
    if gated:
        b = jnp.dot(xb, w2_ref[...], preferred_element_type=jnp.float32)
        b_ref[...] = b.astype(b_ref.dtype)
        # SiLU(a) lives only in the VMEM tile — the fusion the paper sells.
        y_ref[...] = (ref.silu(a) * b).astype(y_ref.dtype)
    else:
        y_ref[...] = ref.apply_activation(a, None, activation).astype(y_ref.dtype)


def fused_swiglu_fwd(x, w1, w2, *, activation: str = "swiglu",
                     block_l: int = DEFAULT_BLOCK_L,
                     block_h: int = DEFAULT_BLOCK_H,
                     interpret: bool = True):
    """Fused first-layer MoE projection for a single expert.

    x: (m, d); w1, w2: (d, h). Returns (a, b, y):
      gated (swiglu): y = SiLU(a) * b, all (m, h); b is x@W2.
      non-gated:      y = act(a); b is a zero-size placeholder (None).
    """
    m, d = x.shape
    h = w1.shape[1]
    gated = activation == "swiglu"
    bl = _pick_block(m, block_l)
    bh = _pick_block(h, block_h)
    grid = (m // bl, h // bh)

    kernel = functools.partial(_fwd_kernel, gated=gated, activation=activation)
    out_shape = [
        jax.ShapeDtypeStruct((m, h), x.dtype),  # a
        jax.ShapeDtypeStruct((m, h), x.dtype),  # b
        jax.ShapeDtypeStruct((m, h), x.dtype),  # y
    ]
    a, b, y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bl, d), lambda i, j: (i, 0)),   # x tile: loaded once per row-tile
            pl.BlockSpec((d, bh), lambda i, j: (0, j)),   # W1 column tile
            pl.BlockSpec((d, bh), lambda i, j: (0, j)),   # W2 column tile
        ],
        out_specs=[
            pl.BlockSpec((bl, bh), lambda i, j: (i, j)),
            pl.BlockSpec((bl, bh), lambda i, j: (i, j)),
            pl.BlockSpec((bl, bh), lambda i, j: (i, j)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(x, w1, w2)
    if not gated:
        b = None
    return a, b, y


# ---------------------------------------------------------------------------
# Backward: fused epilogue with SiLU recomputation
# ---------------------------------------------------------------------------


def _bwd_kernel(a_ref, b_ref, g_ref, da_ref, db_ref):
    """dA, dB from checkpointed (A, B) and upstream dYswi in one pass.

    Recomputes sigmoid/SiLU — paper Algorithm 1 line 24 ("Recomputes
    SiLU(A) to save memory"). All intermediates stay in the VMEM tile.
    """
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    s = jax.nn.sigmoid(a)
    silu_a = a * s                      # S_recomp
    dsilu = s * (1.0 + a * (1.0 - s))   # ∇SiLU(A)
    da_ref[...] = (g * b * dsilu).astype(da_ref.dtype)   # Alg.1 line 26
    db_ref[...] = (g * silu_a).astype(db_ref.dtype)      # Alg.1 line 28


def fused_swiglu_bwd_epilogue(a, b, dy, *, block_l: int = DEFAULT_BLOCK_L,
                              block_h: int = DEFAULT_BLOCK_H,
                              interpret: bool = True):
    """(dA, dB) = fused backward epilogue. a, b, dy: (m, h)."""
    m, h = a.shape
    bl = _pick_block(m, block_l)
    bh = _pick_block(h, block_h)
    grid = (m // bl, h // bh)
    spec = pl.BlockSpec((bl, bh), lambda i, j: (i, j))
    da, db = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((m, h), a.dtype)] * 2,
        interpret=interpret,
    )(a, b, dy)
    return da, db


def _bwd_plain_kernel(a_ref, g_ref, da_ref, *, activation: str):
    """Non-gated backward epilogue: dA = g * act'(A), recomputing act'."""
    a = a_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    da_ref[...] = (g * ref.dactivation(a, activation)).astype(da_ref.dtype)


def fused_act_bwd_epilogue(a, dy, *, activation: str,
                           block_l: int = DEFAULT_BLOCK_L,
                           block_h: int = DEFAULT_BLOCK_H,
                           interpret: bool = True):
    """dA for the plain (relu/silu/gelu) activations; recompute, don't load."""
    m, h = a.shape
    bl = _pick_block(m, block_l)
    bh = _pick_block(h, block_h)
    grid = (m // bl, h // bh)
    spec = pl.BlockSpec((bl, bh), lambda i, j: (i, j))
    (da,) = pl.pallas_call(
        functools.partial(_bwd_plain_kernel, activation=activation),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec],
        out_shape=[jax.ShapeDtypeStruct((m, h), a.dtype)],
        interpret=interpret,
    )(a, dy)
    return da

"""L1 Pallas kernels: expert MLP with on-the-fly gather (paper §3).

The MoEBlaze contribution: expert compute consumes the **original,
unpermuted** ``(L, d)`` activation tensor. No ``(L·k, d)`` routed-token
buffer is ever materialized as a saved activation; each kernel gathers the
rows it needs through the lightweight index structures of paper §4.1.

Kernels:

* ``gather_dual_gemm`` — grid over block-aligned routed *slots*; each block
  belongs to exactly one expert (block_expert, scalar-prefetched so the
  BlockSpec index_map can stream that expert's weight tile); gathers its
  token rows from x in-kernel and runs the fused dual-GEMM + SiLU epilogue
  of :mod:`fused_swiglu`.
* ``grouped_gemm`` — second MLP (W3) over the expert-major hidden tiles.
* ``combine`` — paper §3.1 "Output Aggregation": per token-tile, gather the
  k expert outputs via token_index_map and reduce with the gate weights,
  writing straight into the (L, d) output.
* ``scatter_rows`` — paper §3.2 step 1 "Expert Summation Backward": map the
  (L, d) output gradient to the (n_pad, d) routed-slot gradient via the
  same metadata (gathered formulation: each slot reads its token's row).

Padding note: slots are block-aligned per expert (indices-only, -1 marks a
pad slot); padded slots compute garbage rows of x[0] that are masked to 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 128


def _pick_block(dim: int, preferred: int) -> int:
    b = min(preferred, dim)
    while dim % b != 0:
        b -= 1
    return max(b, 1)


# ---------------------------------------------------------------------------
# Fused gather + dual GEMM + epilogue (forward hot loop)
# ---------------------------------------------------------------------------


def _gather_kernel(be_ref, idx_ref, x_ref, w1_ref, w2_ref,
                   a_ref, b_ref, y_ref, *, gated: bool, activation: str):
    del be_ref  # consumed by the BlockSpec index_maps
    from . import ref as _ref

    idx = idx_ref[...]
    safe = jnp.maximum(idx, 0)
    mask = (idx >= 0).astype(jnp.float32)[:, None]
    # On-the-fly gather from the *unpermuted* activation tensor (paper §3.1).
    xb = x_ref[safe, :] * mask.astype(x_ref.dtype)
    a = jnp.dot(xb, w1_ref[0], preferred_element_type=jnp.float32)
    a_ref[...] = a.astype(a_ref.dtype)
    if gated:
        b = jnp.dot(xb, w2_ref[0], preferred_element_type=jnp.float32)
        b_ref[...] = b.astype(b_ref.dtype)
        y_ref[...] = (_ref.silu(a) * b).astype(y_ref.dtype)
    else:
        b_ref[...] = jnp.zeros_like(b_ref)
        y_ref[...] = _ref.apply_activation(a, None, activation).astype(y_ref.dtype)


def gather_dual_gemm(x, w1, w2, pad_indices, block_expert, *,
                     activation: str = "swiglu", block_slots: int = DEFAULT_BLOCK,
                     block_h: int = DEFAULT_BLOCK, interpret: bool = True):
    """Fused gather + first-layer dual GEMM + activation epilogue.

    x:            (L, d) unpermuted activations
    w1, w2:       (E, d, h) stacked expert weights
    pad_indices:  (n_pad,) token id per padded slot (-1 = pad)
    block_expert: (n_pad / block_slots,) expert id per slot block
    Returns (a, b, y) of shape (n_pad, h); b is zeros for non-gated.
    """
    L, d = x.shape
    E, _, h = w1.shape
    n_pad = pad_indices.shape[0]
    bs = block_slots
    assert n_pad % bs == 0, (n_pad, bs)
    assert block_expert.shape[0] == n_pad // bs
    bh = _pick_block(h, block_h)
    gated = activation == "swiglu"

    grid = (n_pad // bs, h // bh)
    kernel = functools.partial(_gather_kernel, gated=gated, activation=activation)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs,), lambda i, j, be: (i,)),          # slot indices
            pl.BlockSpec((L, d), lambda i, j, be: (0, 0)),       # full x resident
            pl.BlockSpec((1, d, bh), lambda i, j, be: (be[i], 0, j)),  # W1[e]
            pl.BlockSpec((1, d, bh), lambda i, j, be: (be[i], 0, j)),  # W2[e]
        ],
        out_specs=[
            pl.BlockSpec((bs, bh), lambda i, j, be: (i, j)),
            pl.BlockSpec((bs, bh), lambda i, j, be: (i, j)),
            pl.BlockSpec((bs, bh), lambda i, j, be: (i, j)),
        ],
    )
    a, b, y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_pad, h), x.dtype)] * 3,
        interpret=interpret,
    )(block_expert, pad_indices, x, w1, w2)
    return a, b, y


# ---------------------------------------------------------------------------
# Grouped GEMM for the second MLP (W3) over block-aligned slots
# ---------------------------------------------------------------------------


def _grouped_kernel(be_ref, hid_ref, w_ref, o_ref):
    del be_ref
    o_ref[...] = jnp.dot(
        hid_ref[...], w_ref[0], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def grouped_gemm(hidden, w, block_expert, *, block_slots: int = DEFAULT_BLOCK,
                 block_out: int = DEFAULT_BLOCK, interpret: bool = True):
    """out[s] = hidden[s] @ w[expert_of_block(s)].

    hidden: (n_pad, h); w: (E, h, d). Returns (n_pad, d).
    """
    n_pad, h = hidden.shape
    E, _, d = w.shape
    bs = block_slots
    bo = _pick_block(d, block_out)
    grid = (n_pad // bs, d // bo)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bs, h), lambda i, j, be: (i, 0)),
            pl.BlockSpec((1, h, bo), lambda i, j, be: (be[i], 0, j)),
        ],
        out_specs=[pl.BlockSpec((bs, bo), lambda i, j, be: (i, j))],
    )
    (out,) = pl.pallas_call(
        _grouped_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_pad, d), hidden.dtype)],
        interpret=interpret,
    )(block_expert, hidden, w)
    return out


# ---------------------------------------------------------------------------
# Output aggregation (combine) and its backward scatter
# ---------------------------------------------------------------------------


def _combine_kernel(y2_ref, tim_ref, gates_ref, o_ref):
    tim = tim_ref[...]          # (bl, k) padded slot ids
    gates = gates_ref[...]      # (bl, k)
    y2 = y2_ref[...]            # (n_pad, bd) resident tile
    # On-the-fly reduction via token_index_map (paper §3.1, aggregation).
    acc = jnp.einsum("lkd,lk->ld", y2[tim, :], gates.astype(jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


def combine(y2, token_index_map, gates, *, block_l: int = DEFAULT_BLOCK,
            block_d: int = DEFAULT_BLOCK, interpret: bool = True):
    """y[i] = Σ_j gates[i, j] · y2[token_index_map[i, j]].

    y2: (n_pad, d); token_index_map, gates: (L, k). Returns (L, d).
    """
    n_pad, d = y2.shape
    L, k = token_index_map.shape
    bl = _pick_block(L, block_l)
    bd = _pick_block(d, block_d)
    grid = (L // bl, d // bd)
    (y,) = pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_pad, bd), lambda i, j: (0, j)),
            pl.BlockSpec((bl, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bl, k), lambda i, j: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((bl, bd), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((L, d), y2.dtype)],
        interpret=interpret,
    )(y2, token_index_map, gates)
    return y


def _scatter_kernel(dy_ref, idx_ref, gate_ref, o_ref):
    idx = idx_ref[...]
    safe = jnp.maximum(idx, 0)
    mask = (idx >= 0).astype(jnp.float32)
    g = gate_ref[...].astype(jnp.float32) * mask
    o_ref[...] = (dy_ref[safe, :] * g[:, None]).astype(o_ref.dtype)


def scatter_rows(dy, pad_indices, gate_of_slot, *, block_slots: int = DEFAULT_BLOCK,
                 block_d: int = DEFAULT_BLOCK, interpret: bool = True):
    """dY2[s] = gate_of_slot[s] · dy[token_of_slot[s]]  (paper §3.2 step 1).

    Expressed as a gather per slot-block — contention-free by construction
    (each output row written exactly once), the same trick the paper's
    location-map uses to avoid atomics.
    """
    L, d = dy.shape
    n_pad = pad_indices.shape[0]
    bs = block_slots
    bd = _pick_block(d, block_d)
    grid = (n_pad // bs, d // bd)
    (dy2,) = pl.pallas_call(
        _scatter_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((L, bd), lambda i, j: (0, j)),
            pl.BlockSpec((bs,), lambda i, j: (i,)),
            pl.BlockSpec((bs,), lambda i, j: (i,)),
        ],
        out_specs=[pl.BlockSpec((bs, bd), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((n_pad, d), dy.dtype)],
        interpret=interpret,
    )(dy, pad_indices, gate_of_slot)
    return dy2

"""Pure-jnp correctness oracles for the MoEBlaze kernels.

Everything in this module is deliberately simple and allocation-heavy:
these are the *reference semantics* the Pallas kernels (and the Rust
dispatch twin) are validated against, not an efficient implementation.

Notation follows the paper (S2):
  L  number of routed token instances (batch * seq)
  d  model dim
  h  FFN hidden dim (= 4d in the paper's Table 1)
  E  number of experts
  k  experts selected per token
  n  = L * k routed slots
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def sigmoid(x):
    return jax.nn.sigmoid(x)


def silu(x):
    """SiLU(u) = u * sigmoid(u)  (paper S5.1)."""
    return x * jax.nn.sigmoid(x)


def dsilu(x):
    """d/dx SiLU(x) = sigmoid(x) * (1 + x * (1 - sigmoid(x)))."""
    s = jax.nn.sigmoid(x)
    return s * (1.0 + x * (1.0 - s))


def swiglu(x, w1, w2):
    """SwiGLU(x; W1, W2) = SiLU(x W1) * (x W2)  (paper S5.1)."""
    return silu(x @ w1) * (x @ w2)


def apply_activation(a, b, activation: str):
    """Apply the paper's activation family to first-MLP outputs.

    For the gated ("swiglu") family both projections participate; for the
    plain family ("relu"/"silu") only `a` is used and `b` is ignored.
    """
    if activation == "swiglu":
        return silu(a) * b
    if activation == "silu":
        return silu(a)
    if activation == "relu":
        return jnp.maximum(a, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(a)
    raise ValueError(f"unknown activation: {activation}")


def dactivation(a, activation: str):
    """Pointwise derivative of the non-gated activations."""
    if activation == "silu":
        return dsilu(a)
    if activation == "relu":
        return (a > 0.0).astype(a.dtype)
    if activation == "gelu":
        return jax.vmap(jax.vmap(jax.grad(jax.nn.gelu)))(a)
    raise ValueError(f"no pointwise derivative for activation: {activation}")


# ---------------------------------------------------------------------------
# Gating (paper S2.1)
# ---------------------------------------------------------------------------


def top_k(values, k: int):
    """Sort-based top-k (descending, ties broken by lower index).

    Semantically identical to ``jax.lax.top_k`` but lowers to the ``sort``
    HLO instead of the ``topk`` op: the AOT consumer is xla_extension
    0.5.1 whose HLO text parser predates ``topk`` (DESIGN.md S9).
    """
    # stop_gradient: the permutation itself has no useful tangent and this
    # jax build's sort-JVP emits gathers the backend rejects.
    order = jnp.argsort(jax.lax.stop_gradient(-values), axis=-1,
                        stable=True)[..., :k]
    # Differentiable value selection via one-hot contraction: this jax
    # build's take_along_axis VJP is broken (GatherDimensionNumbers /
    # operand_batching_dims TypeError), and the E axis is tiny anyway.
    onehot = jax.nn.one_hot(order, values.shape[-1], dtype=values.dtype)
    vals = jnp.einsum("...e,...ke->...k", values, onehot)
    return vals, order


def gating(x, wg, k: int):
    """softmax -> top-k.

    Returns (gates (L,k), ids (L,k) i32). Gate scores are the softmax
    probabilities of the selected experts (paper S2.1), renormalized over
    the selected k as in most production routers.
    """
    logits = x @ wg.T  # (L, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = top_k(probs, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates.astype(x.dtype), ids.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Dispatch structures (paper S4.1) -- argsort-based oracle (the criticized
# sort-build baseline, S4.2).
# ---------------------------------------------------------------------------


def dispatch_ref(topk_ids, num_experts: int):
    """Sort-based construction of the four S4.1 index structures.

    topk_ids: (L, k) int32 -- token i's selected experts (distinct per row).

    Returns a dict:
      token_expert_indices: (L*k,) expert id per slot in token-major order
      expert_token_indices: (L*k,) token id per slot in expert-major order
      expert_token_offsets: (E+1,) exclusive prefix of per-expert counts
      token_index_map:      (L, k) position of each (token, j) routed copy
                            inside expert_token_indices
      expert_lengths:       (E,) tokens routed to each expert
    """
    L, k = topk_ids.shape
    flat_expert = topk_ids.reshape(-1)  # (n,) expert per token-major slot
    token_of_slot = jnp.repeat(jnp.arange(L, dtype=jnp.int32), k)

    # Stable sort by expert id groups tokens per expert while preserving
    # token order inside a group (paper S4.2 "sorting-based approach").
    order = jnp.argsort(flat_expert, stable=True).astype(jnp.int32)
    expert_token_indices = token_of_slot[order]

    expert_lengths = jnp.bincount(flat_expert, length=num_experts).astype(jnp.int32)
    expert_token_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(expert_lengths).astype(jnp.int32)]
    )

    # token_index_map = inverse permutation of `order`, token-major.
    n = L * k
    inv = jnp.zeros((n,), jnp.int32).at[order].set(jnp.arange(n, dtype=jnp.int32))
    token_index_map = inv.reshape(L, k)

    return {
        "token_expert_indices": flat_expert.astype(jnp.int32),
        "expert_token_indices": expert_token_indices,
        "expert_token_offsets": expert_token_offsets,
        "token_index_map": token_index_map,
        "expert_lengths": expert_lengths,
    }


def padded_len(L: int, k: int, num_experts: int, block: int) -> int:
    """Static worst-case padded slot count (python int, AOT-stable)."""
    n = L * k
    worst = n + num_experts * (block - 1)
    return ((worst + block - 1) // block) * block


def padded_dispatch_ref(topk_ids, num_experts: int, block: int):
    """Block-aligned variant used by the grouped-GEMM kernels.

    Each expert's slot segment is padded up to a multiple of `block` so a
    slot-block never spans two experts (MegaBlocks-style block alignment,
    but *indices only*: no routed activations are materialized). The total
    padded length is the static worst case roundup(L*k + E*(block-1)) so
    AOT shapes are fixed.

    Returns dispatch_ref() fields plus:
      pad_expert_token_indices: (n_pad,) token id per padded slot, -1 = pad
      pad_slot_of_slot:         (n,)    padded position of each compact slot
                                         (expert-major compact order)
      pad_token_index_map:      (L, k)  padded position of each routed copy
      pad_expert_token_offsets: (E+1,)  offsets in the padded layout
      block_expert:             (n_pad/block,) expert id per slot-block
      n_pad, block:             python ints (static)
    """
    L, k = topk_ids.shape
    n = L * k
    n_pad = padded_len(L, k, num_experts, block)
    base = dispatch_ref(topk_ids, num_experts)

    lengths = base["expert_lengths"]
    padded_lengths = ((lengths + block - 1) // block) * block
    pad_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded_lengths).astype(jnp.int32)]
    )

    # position of compact slot s (expert-major) inside the padded layout
    offsets = base["expert_token_offsets"]
    expert_of_compact = jnp.searchsorted(
        offsets[1:], jnp.arange(n, dtype=jnp.int32), side="right"
    ).astype(jnp.int32)
    local = jnp.arange(n, dtype=jnp.int32) - offsets[expert_of_compact]
    pad_slot_of_slot = (pad_offsets[expert_of_compact] + local).astype(jnp.int32)

    pad_expert_token_indices = jnp.full((n_pad,), -1, jnp.int32)
    pad_expert_token_indices = pad_expert_token_indices.at[pad_slot_of_slot].set(
        base["expert_token_indices"]
    )

    tim = base["token_index_map"].reshape(-1)
    pad_token_index_map = pad_slot_of_slot[tim].reshape(L, k)

    nblocks = n_pad // block
    blk = jnp.arange(nblocks, dtype=jnp.int32) * block
    block_expert = jnp.clip(
        jnp.searchsorted(pad_offsets[1:], blk, side="right").astype(jnp.int32),
        0,
        num_experts - 1,
    )

    out = dict(base)
    out.update(
        pad_expert_token_indices=pad_expert_token_indices,
        pad_slot_of_slot=pad_slot_of_slot,
        pad_token_index_map=pad_token_index_map,
        pad_expert_token_offsets=pad_offsets,
        pad_expert_lengths=padded_lengths,
        block_expert=block_expert,
        n_pad=n_pad,
        block=block,
    )
    return out


# ---------------------------------------------------------------------------
# Dense MoE reference (paper S2 end-to-end semantics)
# ---------------------------------------------------------------------------


def moe_ref(x, wg, w1, w2, w3, k: int, activation: str = "swiglu"):
    """Dense O(L*E*d*h) MoE layer: every expert on every token, masked sum.

    x:  (L, d)
    wg: (E, d)    gating
    w1: (E, d, h) first projection ("a" path)
    w2: (E, d, h) gate projection ("b" path; unused for relu/silu)
    w3: (E, h, d) output projection
    Returns (y (L, d), gates (L,k), ids (L,k)).
    """
    gates, ids = gating(x, wg, k)
    E = wg.shape[0]
    dense_gates = jnp.zeros((x.shape[0], E), x.dtype)
    dense_gates = jax.vmap(lambda dg, i, g: dg.at[i].set(g))(dense_gates, ids, gates)

    a = jnp.einsum("ld,edh->leh", x, w1)
    if activation == "swiglu":
        b = jnp.einsum("ld,edh->leh", x, w2)
        hidden = silu(a) * b
    else:
        hidden = apply_activation(a, None, activation)
    y_all = jnp.einsum("leh,ehd->led", hidden, w3)
    y = jnp.einsum("led,le->ld", y_all, dense_gates)
    return y, gates, ids


def grouped_mlp_ref(xs, w1, w2, w3, group_sizes, activation: str = "swiglu"):
    """Grouped (per-expert) MLP over expert-major compacted tokens.

    xs: (n, d) tokens gathered in expert-major order
    group_sizes: (E,) tokens per expert, sum == n
    Returns (a, b, hidden, y2): all intermediates, for residual checks.
    """
    a = jax.lax.ragged_dot(xs, w1, group_sizes)
    if activation == "swiglu":
        b = jax.lax.ragged_dot(xs, w2, group_sizes)
        hidden = silu(a) * b
    else:
        b = None
        hidden = apply_activation(a, None, activation)
    y2 = jax.lax.ragged_dot(hidden, w3, group_sizes)
    return a, b, hidden, y2

"""Analytic activation-memory model (the Fig 3 / Fig 5 metric).

Mirrors — tensor for tensor — the residual sets of
:func:`moe_layer.forward_with_residuals`; the pytest
``test_memory_accounting.py`` asserts byte-exact agreement with the real
residual pytrees. The Rust twin (`rust/src/memory/model.rs`) implements
the same formulas and is cross-checked against this module through the
shared manifest (same numbers must appear in both reports).

Two accounting modes:

* ``mode="ours"`` — exactly what *our* two implementations save. Exact,
  deterministic, reproducible.
* ``mode="paper_baseline"`` — adds the extra tensors a PyTorch-eager
  conventional stack (the paper's Megablocks baseline measured via
  saved-tensor hooks) retains on top of the ideal conventional set:
  fp32 router probabilities (L·E), the pre-combine expert outputs y2
  (n·d), and the expanded combine-backward buffer (n·d). This mode
  reproduces the paper's reported ~4× swiglu ratios; "ours" yields
  ~1.8–2.8× (EXPERIMENTS.md discusses the gap).
"""

from __future__ import annotations

from typing import NamedTuple

from .kernels import ref


class MemoryBreakdown(NamedTuple):
    data_bytes: int       # bf16/f32 activation payloads
    index_bytes: int      # i32 routing metadata
    extra_bytes: int      # paper_baseline-mode additions

    @property
    def total(self) -> int:
        return self.data_bytes + self.index_bytes + self.extra_bytes


def moeblaze_bytes(L: int, d: int, h: int, E: int, k: int, activation: str,
                   *, dtype_bytes: int = 2, block: int = 128,
                   save_yswi: bool = False) -> MemoryBreakdown:
    """Residuals of the MoEBlaze layer (Algorithm-1 checkpoint policy)."""
    n = L * k
    n_pad = ref.padded_len(L, k, E, block)
    gated = activation == "swiglu"

    data = n * dtype_bytes                     # gates (L, k)
    data += n_pad * h * dtype_bytes            # A
    if gated:
        data += n_pad * h * dtype_bytes        # B (Yswi recomputed, §5.2)
        if save_yswi:
            data += n_pad * h * dtype_bytes    # ablation: Yswi saved
    idx = 4 * (
        n                                      # ids (L, k)
        + n_pad                                # pad_expert_token_indices
        + n                                    # pad_token_index_map
        + n_pad // block                       # block_expert
        + (E + 1)                              # pad_expert_token_offsets
    )
    return MemoryBreakdown(data, idx, 0)


def baseline_bytes(L: int, d: int, h: int, E: int, k: int, activation: str,
                   *, dtype_bytes: int = 2, block: int = 128,
                   mode: str = "ours") -> MemoryBreakdown:
    """Residuals of the conventional (MegaBlocks-style) layer (§2, §5.2)."""
    n = L * k
    gated = activation == "swiglu"

    data = n * dtype_bytes                     # gates
    data += n * d * dtype_bytes                # xs — materialized routed buffer
    data += n * h * dtype_bytes                # A
    if gated:
        data += 4 * n * h * dtype_bytes        # B, σ(A), SiLU(A), Yswi
    else:
        data += n * h * dtype_bytes            # act(A)
    idx = 4 * (
        n                                      # ids
        + n                                    # expert_token_indices
        + n                                    # token_index_map
        + (E + 1)                              # offsets
    )
    extra = 0
    if mode == "paper_baseline":
        extra += L * E * 4                     # fp32 router probabilities
        extra += n * d * dtype_bytes           # y2 kept for combine backward
        extra += n * d * dtype_bytes           # expanded routed-gradient buffer
    elif mode != "ours":
        raise ValueError(mode)
    return MemoryBreakdown(data, idx, extra)


def layer_bytes(impl: str, L, d, h, E, k, activation, **kw) -> MemoryBreakdown:
    if impl == "moeblaze":
        kw.pop("mode", None)
        return moeblaze_bytes(L, d, h, E, k, activation, **kw)
    if impl == "baseline":
        return baseline_bytes(L, d, h, E, k, activation, **kw)
    raise ValueError(impl)


def routing_buffer_bytes(L: int, d: int, k: int, dtype_bytes: int = 2) -> int:
    """Paper §2.1 worked example: Mem_routing = L·d·k·dtype (≈94 GB for the
    DeepSeek-like config; with L = 2e6 exactly this is 98.3e9 B — the paper
    rounds loosely)."""
    return L * d * k * dtype_bytes


def ffn_intermediate_bytes(L: int, h: int, dtype_bytes: int = 2) -> int:
    """Paper §2.2 worked example.

    The paper prints "Mem_act = 2L × h ≈ 98 GB", but 2·(2e6)·24576·2 B is
    ≈197e9 — double their own number. Their 98 GB corresponds to a single
    (L, h) bf16 intermediate (L·h·2 B = 98.3e9), so that is the formula we
    implement; the '2' in their display is evidently the dtype bytes.
    """
    return L * h * dtype_bytes

"""L2: MoE transformer language model (build path).

A compact but complete decoder-only LM whose FFN is the MoE layer of
:mod:`moe_layer` — the composition target the paper's intro motivates
(DeepSeek/Mixtral-style MoE LLM training). Used by the end-to-end
training example (`examples/train_tiny_lm.rs`) through the AOT path.

Components: token embedding, RoPE causal self-attention, RMSNorm,
MoE FFN (MoEBlaze or baseline), tied unembedding, and the standard
auxiliary load-balancing loss (Shazeer et al. 2017; paper §7 "Routing
policies").

Parameters are a flat ordered list of arrays so the Rust coordinator can
feed/receive them positionally (manifest carries names/shapes/dtypes).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import moe_layer as ml
from .kernels import ref


class LmConfig(NamedTuple):
    vocab: int = 256           # byte-level tokenizer
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    num_experts: int = 8
    top_k: int = 2
    seq_len: int = 128
    activation: str = "swiglu"
    block: int = 32
    impl: str = "moeblaze"
    use_pallas: bool = True
    aux_loss_coef: float = 0.01

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_hidden(self) -> int:
        return 4 * self.d_model

    def moe_spec(self) -> ml.MoeSpec:
        return ml.MoeSpec(self.num_experts, self.top_k, self.d_model,
                          self.d_hidden, self.activation, self.block,
                          self.impl, self.use_pallas)


# ---------------------------------------------------------------------------
# Parameters — flat ordered list
# ---------------------------------------------------------------------------


def param_spec(cfg: LmConfig):
    """[(name, shape, init_scale)] in the canonical flat order."""
    d, dh, E = cfg.d_model, cfg.d_hidden, cfg.num_experts
    spec = [("embed", (cfg.vocab, d), 0.02)]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        spec += [
            (p + "ln1", (d,), 1.0),
            (p + "wq", (d, d), d ** -0.5),
            (p + "wk", (d, d), d ** -0.5),
            (p + "wv", (d, d), d ** -0.5),
            (p + "wo", (d, d), d ** -0.5),
            (p + "ln2", (d,), 1.0),
            (p + "wg", (E, d), 0.02),
            (p + "w1", (E, d, dh), d ** -0.5),
            (p + "w2", (E, d, dh), d ** -0.5),
            (p + "w3", (E, dh, d), dh ** -0.5),
        ]
    spec.append(("ln_f", (d,), 1.0))
    return spec


def init_params(key, cfg: LmConfig):
    params = []
    for name, shape, scale in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(jax.random.normal(sub, shape, jnp.float32) * scale)
    return params


def num_params(cfg: LmConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s, _ in param_spec(cfg))


# ---------------------------------------------------------------------------
# Model pieces
# ---------------------------------------------------------------------------


def rmsnorm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def rope(q, seq_len, d_head):
    """Rotary position embedding over the last axis."""
    half = d_head // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    ang = jnp.einsum("s,f->sf", t, freqs)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    q1, q2 = q[..., :half], q[..., half:]
    return jnp.concatenate(
        [q1 * cos - q2 * sin, q2 * cos + q1 * sin], axis=-1)


def attention(x, wq, wk, wv, wo, cfg: LmConfig):
    """Causal multi-head attention with RoPE. x: (B, S, d)."""
    B, S, d = x.shape
    H, dh = cfg.n_heads, cfg.d_head

    def split(w):
        return (x @ w).reshape(B, S, H, dh).transpose(0, 2, 1, 3)

    q, k_, v = split(wq), split(wk), split(wv)
    q = rope(q, S, dh)
    k_ = rope(k_, S, dh)
    att = jnp.einsum("bhsd,bhtd->bhst", q, k_) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((S, S), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, d)
    return out @ wo


def aux_load_balance_loss(x2d, wg, cfg: LmConfig):
    """Switch-style load-balancing loss: E · Σ_e f_e · p_e.

    f_e = fraction of tokens whose top-1 is e; p_e = mean router prob.
    """
    probs = jax.nn.softmax(x2d @ wg.T, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, cfg.num_experts), axis=0)
    p = jnp.mean(probs, axis=0)
    return cfg.num_experts * jnp.sum(f * jax.lax.stop_gradient(f) * 0 + f * p)


def forward(params, tokens, cfg: LmConfig):
    """tokens: (B, S) i32 → (logits (B, S, V), aux_loss scalar)."""
    layer_fn = ml.make_moe_layer(cfg.moe_spec())
    it = iter(params)
    embed = next(it)
    x = embed[tokens]  # (B, S, d)
    B, S, d = x.shape
    aux = 0.0
    for _ in range(cfg.n_layers):
        ln1, wq, wk, wv, wo, ln2, wg, w1, w2, w3 = (next(it) for _ in range(10))
        x = x + attention(rmsnorm(x, ln1), wq, wk, wv, wo, cfg)
        h = rmsnorm(x, ln2)
        h2d = h.reshape(B * S, d)
        aux = aux + aux_load_balance_loss(h2d, wg, cfg)
        moe_out = layer_fn(h2d, wg, w1, w2, w3).reshape(B, S, d)
        x = x + moe_out
    ln_f = next(it)
    x = rmsnorm(x, ln_f)
    logits = x @ embed.T  # tied unembedding
    return logits, aux / cfg.n_layers


def loss_fn(params, tokens, targets, cfg: LmConfig):
    """Mean next-token cross-entropy + aux loss. tokens/targets: (B, S)."""
    logits, aux = forward(params, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll) + cfg.aux_loss_coef * aux

"""L2: the MoEBlaze MoE layer (paper §3 + §5, Algorithm 1) as a custom_vjp.

Two implementations share one interface:

* ``impl="moeblaze"`` — index-driven dispatch (paper §4), on-the-fly gathers
  from the unpermuted ``(L, d)`` tensor, fused first-layer dual-GEMM +
  activation epilogue, and the Algorithm-1 activation-checkpoint policy:

      residuals (swiglu) = {gates, ids, dispatch indices, A, B}
      residuals (plain)  = {gates, ids, dispatch indices, A}

  ``SiLU(A)``/``σ(A)``/``Yswi``, the routed token buffer, the routed
  gradient buffer, and the per-slot expert outputs are *never* saved —
  they are recomputed or streamed (paper §3.2, §5.2, Algorithm 1 line
  24; ``save_yswi=True`` re-enables the Algorithm-1-literal variant as
  an ablation).

* ``impl="baseline"`` — the conventional dropless pipeline the paper
  benchmarks against (MegaBlocks-style): argsort-based dispatch, a
  **materialized** routed-token buffer ``xs (n, d)``, unfused point-wise
  stages, and the conventional residual set:

      residuals (swiglu) = {gates, ids, sort metadata, xs, A, B, σ(A),
                            SiLU(A), Yswi}                  (paper §5.2)
      residuals (plain)  = {gates, ids, sort metadata, xs, A, act(A)}

Because both are ``custom_vjp``, the saved-activation set is *exact and
deterministic* — the quantity Figures 3/5 report. `forward_with_residuals`
exposes it for the accounting tests and the Rust memory model cross-check.

The layer is a pure function of ``(x, wg, w1, w2, w3)`` so it AOT-lowers
cleanly; all routing metadata is built in-graph.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import dispatch as dk
from .kernels import fused_swiglu as fs
from .kernels import gather_mlp as gm
from .kernels import ref


class MoeSpec(NamedTuple):
    """Static configuration of one MoE layer."""

    num_experts: int
    top_k: int
    d_model: int
    d_hidden: int
    activation: str = "swiglu"  # swiglu | silu | relu | gelu
    block: int = 128            # slot-block size (expert-aligned padding)
    impl: str = "moeblaze"      # moeblaze | baseline
    use_pallas: bool = True     # pallas kernels vs pure-jnp equivalents
    interpret: bool = True      # pallas interpret mode (CPU PJRT)
    save_yswi: bool = False     # ablation: save Yswi instead of recomputing
                                # it from (A, B) in bwd (paper §5.2 skips it)

    @property
    def gated(self) -> bool:
        return self.activation == "swiglu"


# ---------------------------------------------------------------------------
# Shared helpers
# ---------------------------------------------------------------------------


def _gating_bwd(x, wg, gates, ids, dgates):
    """Backprop through softmax → top-k → renormalize (recomputes probs).

    Returns (dx_gating, dwg). Recomputing the (L, E) probs is one small
    GEMM — cheaper than saving them (same checkpointing philosophy).
    """
    logits = x @ wg.T
    p = jax.nn.softmax(logits, axis=-1)           # (L, E)
    s = jnp.take_along_axis(p, ids, axis=1)       # (L, k) selected probs
    t = jnp.sum(s, axis=-1, keepdims=True)
    # gates = s / t  =>  ds_j = dg_j / t - (sum_m dg_m s_m) / t^2
    dot = jnp.sum(dgates * s, axis=-1, keepdims=True)
    ds = dgates / t - dot / (t * t)
    dp = jnp.zeros_like(p)
    dp = jax.vmap(lambda row, i, v: row.at[i].add(v))(dp, ids, ds)
    # softmax vjp: dlogits = p * (dp - sum(dp * p))
    dlogits = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dx = dlogits @ wg
    dwg = dlogits.T @ x
    return dx, dwg


def _block_weight_grads(rows, grads, block_expert, num_experts, block):
    """Per-expert weight gradient via block outer-products + segment sum.

    rows: (n_pad, p) input rows (expert-block aligned); grads: (n_pad, q).
    Returns (E, p, q) = Σ_{s in expert e} rows[s]ᵀ grads[s].

    This is the aggregation-in-place/tiled-reduction structure of paper
    §5.2 ("aggregates gradients … via tiled reductions — completely
    eliminating temporary global buffers"): each block contributes one
    (p, q) tile, summed by expert; no (E, p, q)·nblocks buffer exists.
    """
    n_pad, p = rows.shape
    q = grads.shape[1]
    nblocks = n_pad // block
    rb = rows.reshape(nblocks, block, p)
    gb = grads.reshape(nblocks, block, q)
    per_block = jnp.einsum("bip,biq->bpq", rb, gb)
    return jax.ops.segment_sum(per_block, block_expert, num_segments=num_experts)


def _pad_group_sizes(dispatch):
    return dispatch["pad_expert_token_offsets"][1:] - dispatch["pad_expert_token_offsets"][:-1]


def _gather_rows(x, pad_indices):
    """Masked gather of token rows into the padded slot layout (transient)."""
    safe = jnp.maximum(pad_indices, 0)
    mask = (pad_indices >= 0).astype(x.dtype)[:, None]
    return x[safe] * mask


def _gate_of_slot(gates, pad_token_index_map, n_pad):
    g = jnp.zeros((n_pad,), gates.dtype)
    return g.at[pad_token_index_map.reshape(-1)].set(gates.reshape(-1))


def _token_of_slot_combine(y2, pad_tim, gates):
    """Pure-jnp combine: y[i] = Σ_j gates[i,j] · y2[pad_tim[i,j]]."""
    return jnp.einsum("lkd,lk->ld", y2[pad_tim], gates)


# ---------------------------------------------------------------------------
# MoEBlaze forward/backward
# ---------------------------------------------------------------------------


def _moeblaze_fwd(spec: MoeSpec, x, wg, w1, w2, w3):
    gates, ids = ref.gating(x, wg, spec.top_k)

    if spec.use_pallas:
        disp = dk.build_dispatch(ids, spec.num_experts, spec.block,
                                 interpret=spec.interpret)
        eti = disp["pad_expert_token_indices"]
        tim = disp["pad_token_index_map"]
        be = disp["block_expert"]
        pad_offsets = disp["pad_expert_token_offsets"]
        a, b, hidden = gm.gather_dual_gemm(
            x, w1, w2, eti, be, activation=spec.activation,
            block_slots=spec.block, interpret=spec.interpret)
        y2 = gm.grouped_gemm(hidden, w3, be, block_slots=spec.block,
                             interpret=spec.interpret)
        y = gm.combine(y2, tim, gates, interpret=spec.interpret)
    else:
        # Compact layout: ragged_dot takes true group sizes, so the fused
        # lowering runs zero padded GEMM rows (the padded layout exists
        # only for the blocked Pallas kernels).
        disp = dk.build_dispatch_compact_jnp(ids, spec.num_experts)
        eti = disp["expert_token_indices"]
        tim = disp["token_index_map"]
        be = jnp.zeros((0,), jnp.int32)  # unused in the compact path
        pad_offsets = disp["expert_token_offsets"]
        xs = x[eti]  # transient — not a residual
        gs = disp["expert_lengths"]
        a = jax.lax.ragged_dot(xs, w1, gs)
        if spec.gated:
            b = jax.lax.ragged_dot(xs, w2, gs)
            hidden = ref.silu(a) * b
        else:
            b = jnp.zeros_like(a)
            hidden = ref.apply_activation(a, None, spec.activation)
        y2 = jax.lax.ragged_dot(hidden, w3, gs)
        y = _token_of_slot_combine(y2, tim, gates)

    # Algorithm-1 residual policy: indices + gates + {A, B} (gated; Yswi is
    # recomputed pointwise in bwd unless the save_yswi ablation is on — the
    # paper §5.2 "skip saving the SwiGLU intermediate result") or {A} (plain).
    saved_hidden = hidden if (spec.gated and spec.save_yswi) else jnp.zeros((0,), x.dtype)
    saved_b = b if spec.gated else jnp.zeros((0,), x.dtype)
    res = (x, wg, w1, w2, w3, gates, ids, eti, tim, be,
           pad_offsets, a, saved_b, saved_hidden)
    return y, res


def _moeblaze_bwd(spec: MoeSpec, res, dy):
    (x, wg, w1, w2, w3, gates, ids, eti, tim, be, pad_offsets,
     a, b, saved_hidden) = res
    n_pad = eti.shape[0]  # compact n in the jnp path
    E = spec.num_experts
    gs = pad_offsets[1:] - pad_offsets[:-1]

    if spec.gated:
        # Recompute Yswi = SiLU(A)·B pointwise unless the ablation saved it
        # (paper §5.2: activation computation is bandwidth-bound; recompute
        # beats the HBM round-trip).
        hidden = saved_hidden if spec.save_yswi else ref.silu(a) * b
    else:
        hidden = ref.apply_activation(a, None, spec.activation)  # recompute

    # --- recompute per-slot expert outputs for the gate gradient ----------
    if spec.use_pallas:
        y2 = gm.grouped_gemm(hidden, w3, be, block_slots=spec.block,
                             interpret=spec.interpret)
    else:
        y2 = jax.lax.ragged_dot(hidden, w3, gs)
    dgates = jnp.einsum("ld,lkd->lk", dy, y2[tim])

    # --- paper §3.2 step 1: expert-summation backward (scatter) -----------
    gos = _gate_of_slot(gates, tim, n_pad)
    if spec.use_pallas:
        dy2 = gm.scatter_rows(dy, eti, gos, block_slots=spec.block,
                              interpret=spec.interpret)
    else:
        dy2 = _gather_rows(dy, eti) * gos[:, None]

    # --- second MLP backward ----------------------------------------------
    if spec.use_pallas:
        dw3 = _block_weight_grads(hidden, dy2, be, E, spec.block)
    else:
        pad = _compact_pad_map(eti, pad_offsets, spec)
        dw3 = _block_weight_grads(_pad_rows(hidden, pad), _pad_rows(dy2, pad),
                                  pad["block_expert"], E, spec.block)
    w3t = jnp.swapaxes(w3, 1, 2)
    if spec.use_pallas:
        dhidden = gm.grouped_gemm(dy2, w3t, be, block_slots=spec.block,
                                  interpret=spec.interpret)
    else:
        dhidden = jax.lax.ragged_dot(dy2, w3t, gs)

    # --- fused backward epilogue (recompute SiLU — Alg. 1 line 24) --------
    if spec.gated:
        if spec.use_pallas:
            da, db = fs.fused_swiglu_bwd_epilogue(a, b, dhidden,
                                                  interpret=spec.interpret)
        else:
            s = jax.nn.sigmoid(a)
            da = dhidden * b * (s * (1.0 + a * (1.0 - s)))
            db = dhidden * (a * s)
    else:
        if spec.use_pallas:
            da = fs.fused_act_bwd_epilogue(a, dhidden, activation=spec.activation,
                                           interpret=spec.interpret)
        else:
            da = dhidden * ref.dactivation(a, spec.activation)
        db = None

    # --- first MLP backward: weight grads need xs — regather, never saved -
    xs = _gather_rows(x, eti)
    if spec.use_pallas:
        dw1 = _block_weight_grads(xs, da, be, E, spec.block)
    else:
        dw1 = _block_weight_grads(_pad_rows(xs, pad), _pad_rows(da, pad),
                                  pad["block_expert"], E, spec.block)
    w1t = jnp.swapaxes(w1, 1, 2)
    if spec.use_pallas:
        dxs = gm.grouped_gemm(da, w1t, be, block_slots=spec.block,
                              interpret=spec.interpret)
    else:
        dxs = jax.lax.ragged_dot(da, w1t, gs)
    if spec.gated:
        if spec.use_pallas:
            dw2 = _block_weight_grads(xs, db, be, E, spec.block)
        else:
            dw2 = _block_weight_grads(_pad_rows(xs, pad), _pad_rows(db, pad),
                                      pad["block_expert"], E, spec.block)
        w2t = jnp.swapaxes(w2, 1, 2)
        if spec.use_pallas:
            dxs = dxs + gm.grouped_gemm(db, w2t, be, block_slots=spec.block,
                                        interpret=spec.interpret)
        else:
            dxs = dxs + jax.lax.ragged_dot(db, w2t, gs)
    else:
        dw2 = jnp.zeros_like(w2)

    # --- paper §3.2 step 3: token-gradient accumulation (on-the-fly) ------
    if spec.use_pallas:
        ones = jnp.ones_like(gates)
        dx = gm.combine(dxs, tim, ones, interpret=spec.interpret)
    else:
        dx = jnp.sum(dxs[tim], axis=1)

    # --- gating backward ----------------------------------------------------
    dx_g, dwg = _gating_bwd(x, wg, gates, ids, dgates)
    dx = dx + dx_g
    return dx, dwg, dw1, dw2, dw3


# ---------------------------------------------------------------------------
# Baseline (conventional / MegaBlocks-style) forward/backward
# ---------------------------------------------------------------------------


def _kernel_boundary(*ts):
    """Model a conventional multi-kernel pipeline: each stage of the
    baseline is a separate kernel launch whose outputs round-trip through
    global memory, so XLA must not fuse across stages. MoEBlaze's whole
    point is eliminating these boundaries; the fused path has none.
    """
    out = jax.lax.optimization_barrier(ts)
    return out[0] if len(ts) == 1 else out


def _baseline_fwd(spec: MoeSpec, x, wg, w1, w2, w3):
    gates, ids = ref.gating(x, wg, spec.top_k)
    disp = ref.dispatch_ref(ids, spec.num_experts)  # argsort pipeline (§4.2)
    eti = disp["expert_token_indices"]       # (n,) compact
    tim = disp["token_index_map"]            # (L, k)
    lengths = disp["expert_lengths"]
    eti, tim = _kernel_boundary(eti, tim)    # dispatch kernel | permute kernel

    xs = _kernel_boundary(x[eti])            # MATERIALIZED routed buffer
    a = _kernel_boundary(jax.lax.ragged_dot(xs, w1, lengths))
    if spec.gated:
        b = _kernel_boundary(jax.lax.ragged_dot(xs, w2, lengths))
        sig = _kernel_boundary(jax.nn.sigmoid(a))  # saved (conventional, §5.2)
        act = _kernel_boundary(a * sig)            # SiLU(a), saved
        hidden = _kernel_boundary(act * b)         # Yswi, saved
    else:
        b = jnp.zeros((0,), x.dtype)
        sig = jnp.zeros((0,), x.dtype)
        act = _kernel_boundary(ref.apply_activation(a, None, spec.activation))
        hidden = act
    y2 = _kernel_boundary(jax.lax.ragged_dot(hidden, w3, lengths))
    y = jnp.einsum("lkd,lk->ld", y2[tim], gates)

    res = (x, wg, w1, w2, w3, gates, ids, eti, tim,
           disp["expert_token_offsets"], xs, a, b, sig, act, hidden)
    return y, res


def _baseline_bwd(spec: MoeSpec, res, dy):
    (x, wg, w1, w2, w3, gates, ids, eti, tim, offsets,
     xs, a, b, sig, act, hidden) = res
    E = spec.num_experts
    n = eti.shape[0]
    lengths = offsets[1:] - offsets[:-1]

    y2 = _kernel_boundary(jax.lax.ragged_dot(hidden, w3, lengths))  # kept
    dgates = jnp.einsum("ld,lkd->lk", dy, y2[tim])

    # expand (L, d) grads to the (n, d) routed-gradient buffer (materialized)
    gos = jnp.zeros((n,), gates.dtype).at[tim.reshape(-1)].set(gates.reshape(-1))
    dy2 = _kernel_boundary(dy[eti] * gos[:, None])

    w3t = jnp.swapaxes(w3, 1, 2)
    dhidden = _kernel_boundary(jax.lax.ragged_dot(dy2, w3t, lengths))

    if spec.gated:
        # uses the SAVED sig/act — no recompute (conventional kernels);
        # separate pointwise kernels as in the eager pipeline
        da = _kernel_boundary(dhidden * b * (sig * (1.0 + a * (1.0 - sig))))
        db = _kernel_boundary(dhidden * act)
    else:
        da = _kernel_boundary(dhidden * ref.dactivation(a, spec.activation))
        db = None

    # weight grads via block-aligned regrouping of the *saved* buffers
    # (compute detail only; residuals are the saved set above)
    pad = _baseline_pad_map(eti, offsets, spec)
    xs_p = _pad_rows(xs, pad)
    da_p = _pad_rows(da, pad)
    hid_p = _pad_rows(hidden, pad)
    dy2_p = _pad_rows(dy2, pad)
    be = pad["block_expert"]
    dw1 = _block_weight_grads(xs_p, da_p, be, E, spec.block)
    dw3 = _block_weight_grads(hid_p, dy2_p, be, E, spec.block)
    if spec.gated:
        db_p = _pad_rows(db, pad)
        dw2 = _block_weight_grads(xs_p, db_p, be, E, spec.block)
    else:
        dw2 = jnp.zeros_like(w2)

    w1t = jnp.swapaxes(w1, 1, 2)
    dxs = jax.lax.ragged_dot(da, w1t, lengths)
    if spec.gated:
        w2t = jnp.swapaxes(w2, 1, 2)
        dxs = dxs + jax.lax.ragged_dot(db, w2t, lengths)
    dx = jnp.zeros_like(x).at[eti].add(dxs)

    dx_g, dwg = _gating_bwd(x, wg, gates, ids, dgates)
    return dx + dx_g, dwg, dw1, dw2, dw3


def _compact_pad_map(eti, offsets, spec: MoeSpec):
    """Compact→padded mapping for the bwd weight-grad block reduction
    (transient metadata; same machinery the baseline bwd uses)."""
    return _baseline_pad_map(eti, offsets, spec)


def _baseline_pad_map(eti, offsets, spec: MoeSpec):
    """Compact→padded slot mapping recomputed in bwd (metadata only)."""
    n = eti.shape[0]
    E = spec.num_experts
    block = spec.block
    L = n // spec.top_k
    n_pad = ref.padded_len(L, spec.top_k, E, block)
    lengths = offsets[1:] - offsets[:-1]
    padded_lengths = ((lengths + block - 1) // block) * block
    pad_offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(padded_lengths).astype(jnp.int32)])
    sl = jnp.arange(n, dtype=jnp.int32)
    e_of = jnp.searchsorted(offsets[1:], sl, side="right").astype(jnp.int32)
    local = sl - offsets[e_of]
    pad_slot = pad_offsets[e_of] + local
    compact_of_pad = jnp.full((n_pad,), -1, jnp.int32).at[pad_slot].set(sl)
    nblocks = n_pad // block
    blk = jnp.arange(nblocks, dtype=jnp.int32) * block
    block_expert = jnp.clip(
        jnp.searchsorted(pad_offsets[1:], blk, side="right").astype(jnp.int32),
        0, E - 1)
    return {"compact_of_pad": compact_of_pad, "block_expert": block_expert,
            "n_pad": n_pad}


def _pad_rows(rows, pad):
    idx = pad["compact_of_pad"]
    safe = jnp.maximum(idx, 0)
    mask = (idx >= 0).astype(rows.dtype)[:, None]
    return rows[safe] * mask


# ---------------------------------------------------------------------------
# Public constructors
# ---------------------------------------------------------------------------


def make_moe_layer(spec: MoeSpec):
    """Returns a differentiable fn(x, wg, w1, w2, w3) -> y for `spec`."""
    fwd = _moeblaze_fwd if spec.impl == "moeblaze" else _baseline_fwd
    bwd = _moeblaze_bwd if spec.impl == "moeblaze" else _baseline_bwd

    @jax.custom_vjp
    def layer(x, wg, w1, w2, w3):
        y, _ = fwd(spec, x, wg, w1, w2, w3)
        return y

    def layer_fwd(x, wg, w1, w2, w3):
        return fwd(spec, x, wg, w1, w2, w3)

    def layer_bwd(res, dy):
        return bwd(spec, res, dy)

    layer.defvjp(layer_fwd, layer_bwd)
    return layer


def forward_with_residuals(spec: MoeSpec, x, wg, w1, w2, w3):
    """(y, residuals) — for the activation-memory accounting tests.

    Residual classification (DESIGN.md §6): parameters and the layer input
    x are excluded from "activation memory"; everything else the layer
    saves between fwd and bwd is counted.
    """
    fwd = _moeblaze_fwd if spec.impl == "moeblaze" else _baseline_fwd
    y, res = fwd(spec, x, wg, w1, w2, w3)
    if spec.impl == "moeblaze":
        (x_, wg_, w1_, w2_, w3_, gates, ids, eti, tim, be, pad_offsets,
         a, b, hidden) = res
        named = {"gates": gates, "ids": ids, "pad_expert_token_indices": eti,
                 "pad_token_index_map": tim, "block_expert": be,
                 "pad_expert_token_offsets": pad_offsets, "A": a}
        if spec.gated:
            named.update(B=b)
            if spec.save_yswi:
                named.update(Yswi=hidden)
    else:
        (x_, wg_, w1_, w2_, w3_, gates, ids, eti, tim, offsets,
         xs, a, b, sig, act, hidden) = res
        named = {"gates": gates, "ids": ids, "expert_token_indices": eti,
                 "token_index_map": tim, "expert_token_offsets": offsets,
                 "xs_routed": xs, "A": a, "act": act}
        if spec.gated:
            named.update(B=b, sigma=sig, Yswi=hidden)
    return y, named


def residual_bytes(named: dict) -> int:
    """Total bytes of the saved-activation set (the Fig 3/5 metric)."""
    return int(sum(v.size * v.dtype.itemsize for v in named.values()))

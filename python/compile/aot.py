"""AOT export: lower every jitted computation to HLO *text* + manifest.

HLO text (not ``lowered.compiler_ir("hlo").serialize()``) is the
interchange format: jax ≥ 0.5 emits HloModuleProtos with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced (all under ``artifacts/``):

* ``layer_step_<conf>_<act>_<impl>.hlo.txt`` — single-MoE-layer fwd+bwd
  (Fig 4 / Fig 6 speed benches): 7 configs × {silu, swiglu} ×
  {moeblaze, baseline}.
* ``layer_fwd_<conf>_swiglu_moeblaze.hlo.txt`` — forward-only layers for
  the quickstart example.
* ``dispatch_build_conf3.hlo.txt`` — standalone Pallas 3-step dispatch
  build (structure-parity demo vs the Rust twin).
* ``lm_train_step.hlo.txt`` / ``lm_eval_step.hlo.txt`` — full MoE-LM
  training/eval step for the end-to-end example.
* ``manifest.json`` — machine-readable description of every artifact
  (inputs/outputs with shapes+dtypes, config metadata, LM param spec)
  consumed by the Rust runtime.

Python runs ONCE, at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs as cfgs
from . import moe_layer as ml
from . import train_step as ts
from . import transformer as tf
from .kernels import dispatch as dk

ACTIVATIONS = ("silu", "swiglu")
IMPLS = ("moeblaze", "baseline")

LM_CONFIG = tf.LmConfig(
    vocab=256, d_model=128, n_layers=2, n_heads=4, num_experts=8, top_k=2,
    seq_len=128, activation="swiglu", block=32, impl="moeblaze",
    use_pallas=True)
LM_BATCH = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _dtype_tag(dt) -> str:
    return {"float32": "f32", "int32": "s32", "uint32": "u32",
            "bfloat16": "bf16"}[jnp.dtype(dt).name]


def _io_entry(name, aval):
    return {"name": name, "shape": [int(s) for s in aval.shape],
            "dtype": _dtype_tag(aval.dtype)}


def _flatten_io(names, avals):
    out = []
    for name, aval in zip(names, avals):
        leaves = jax.tree_util.tree_leaves(aval)
        if len(leaves) == 1 and not isinstance(aval, (list, tuple, dict)):
            out.append(_io_entry(name, leaves[0]))
        else:
            for i, leaf in enumerate(leaves):
                out.append(_io_entry(f"{name}.{i}", leaf))
    return out


class Exporter:
    def __init__(self, out_dir: str, force: bool = False):
        self.out_dir = out_dir
        self.force = force
        self.manifest = {"artifacts": [], "generated_by": "compile.aot"}
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name: str, kind: str, fn, arg_specs, arg_names,
               out_names, meta=None):
        """Lower fn(*args) at the given ShapeDtypeStructs and write HLO."""
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        t0 = time.time()
        if self.force or not os.path.exists(path):
            lowered = jax.jit(fn).lower(*arg_specs)
            text = to_hlo_text(lowered)
            with open(path, "w") as f:
                f.write(text)
            status = f"lowered in {time.time() - t0:5.1f}s, {len(text)//1024} KiB"
        else:
            status = "cached"
        outs = jax.eval_shape(fn, *arg_specs)
        if not isinstance(outs, (list, tuple)):
            outs = (outs,)
        entry = {
            "name": name, "file": fname, "kind": kind,
            "inputs": _flatten_io(arg_names, arg_specs),
            "outputs": _flatten_io(out_names, outs),
        }
        if meta:
            entry["meta"] = meta
        self.manifest["artifacts"].append(entry)
        print(f"  [{kind:>10s}] {name}: {status}")

    def write_manifest(self, extra=None):
        if extra:
            self.manifest.update(extra)
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"manifest: {path} ({len(self.manifest['artifacts'])} artifacts)")


def layer_arg_specs(c: cfgs.PaperConfig, with_cot: bool, gated: bool = True):
    L, d, h, E = c.tokens, c.input_d, c.hidden, c.num_experts
    f32 = jnp.float32
    specs = [
        jax.ShapeDtypeStruct((L, d), f32),       # x
        jax.ShapeDtypeStruct((E, d), f32),       # wg
        jax.ShapeDtypeStruct((E, d, h), f32),    # w1
    ]
    names = ["x", "wg", "w1"]
    if gated:
        specs.append(jax.ShapeDtypeStruct((E, d, h), f32))  # w2
        names.append("w2")
    specs.append(jax.ShapeDtypeStruct((E, h, d), f32))       # w3
    names.append("w3")
    if with_cot:
        specs.append(jax.ShapeDtypeStruct((L, d), f32))
        names.append("cot")
    return specs, names


def conf_meta(c: cfgs.PaperConfig, act: str, impl: str, block: int):
    return {"config": c.name, "d": c.input_d, "h": c.hidden,
            "experts": c.num_experts, "top_k": c.top_k, "batch": c.batch,
            "seq_len": c.seq_len, "tokens": c.tokens, "activation": act,
            "impl": impl, "block": block}


def export_layer_steps(ex: Exporter, only=None):
    blk = cfgs.SCALED_BLOCK
    for c in cfgs.SCALED_CONFIGS:
        if only and c.name not in only:
            continue
        for act in ACTIVATIONS:
            for impl in IMPLS:
                # Timed artifacts use the XLA-fused lowering for BOTH impls
                # (use_pallas=False): on this CPU substrate interpret-mode
                # Pallas adds loop overhead that is a lowering artifact, not
                # the paper's algorithm (EXPERIMENTS.md discusses; the
                # *_pallas ablation below quantifies it).
                spec = ml.MoeSpec(c.num_experts, c.top_k, c.input_d, c.hidden,
                                  act, blk, impl, use_pallas=False)
                fn = ts.make_layer_step(spec, c.tokens)
                gated = act == "swiglu"
                args, names = layer_arg_specs(c, with_cot=True, gated=gated)
                outs = (["loss", "dx", "dwg", "dw1", "dw2", "dw3"] if gated
                        else ["loss", "dx", "dwg", "dw1", "dw3"])
                ex.export(f"layer_step_{c.name}_{act}_{impl}", "layer_step",
                          fn, args, names, outs,
                          meta=conf_meta(c, act, impl, blk))
    # Pallas-lowering ablation (interpret-mode overhead measurement)
    for cname in ("conf2",):
        if only and cname not in only:
            continue
        c = cfgs.by_name(cname)
        spec = ml.MoeSpec(c.num_experts, c.top_k, c.input_d, c.hidden,
                          "swiglu", blk, "moeblaze", use_pallas=True)
        fn = ts.make_layer_step(spec, c.tokens)
        args, names = layer_arg_specs(c, with_cot=True)
        ex.export(f"layer_step_{cname}_swiglu_moeblaze_pallas", "layer_step_ablation",
                  fn, args, names,
                  ["loss", "dx", "dwg", "dw1", "dw2", "dw3"],
                  meta=conf_meta(c, "swiglu", "moeblaze_pallas", blk))


def export_layer_fwds(ex: Exporter):
    blk = cfgs.SCALED_BLOCK
    for name in ("conf1", "conf2"):
        c = cfgs.by_name(name)
        spec = ml.MoeSpec(c.num_experts, c.top_k, c.input_d, c.hidden,
                          "swiglu", blk, "moeblaze", use_pallas=True)
        fn = ts.make_layer_fwd(spec)
        args, names = layer_arg_specs(c, with_cot=False)
        ex.export(f"layer_fwd_{c.name}_swiglu_moeblaze", "layer_fwd",
                  fn, args, names, ["y"],
                  meta=conf_meta(c, "swiglu", "moeblaze", blk))


def export_dispatch(ex: Exporter):
    c = cfgs.by_name("conf3")
    blk = cfgs.SCALED_BLOCK

    def fn(ids):
        out = dk.build_dispatch(ids, c.num_experts, blk)
        return (out["expert_lengths"], out["pad_expert_token_offsets"],
                out["pad_expert_token_indices"], out["pad_token_index_map"],
                out["block_expert"])

    args = [jax.ShapeDtypeStruct((c.tokens, c.top_k), jnp.int32)]
    ex.export("dispatch_build_conf3", "dispatch", fn, args, ["topk_ids"],
              ["expert_lengths", "pad_expert_token_offsets",
               "pad_expert_token_indices", "pad_token_index_map",
               "block_expert"],
              meta=conf_meta(c, "-", "moeblaze", blk))


def lm_param_entries(cfg: tf.LmConfig):
    return [{"name": n, "shape": list(s), "init_scale": float(sc)}
            for n, s, sc in tf.param_spec(cfg)]


def export_lm(ex: Exporter):
    cfg = LM_CONFIG
    pspecs = [jax.ShapeDtypeStruct(tuple(s), jnp.float32)
              for _, s, _ in tf.param_spec(cfg)]
    tok = jax.ShapeDtypeStruct((LM_BATCH, cfg.seq_len), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    step = ts.make_train_step(cfg)

    def flat_step(*flat):
        P = len(pspecs)
        params = list(flat[:P])
        m = list(flat[P:2 * P])
        v = list(flat[2 * P:3 * P])
        stepi, lr, tokens, targets = flat[3 * P:]
        np_, nm, nv, loss = step(params, m, v, stepi, lr, tokens, targets)
        return tuple(np_) + tuple(nm) + tuple(nv) + (loss,)

    P = len(pspecs)
    args = pspecs * 3 + [scalar, scalar, tok, tok]
    in_names = ([f"param.{i}" for i in range(P)] +
                [f"m.{i}" for i in range(P)] +
                [f"v.{i}" for i in range(P)] +
                ["step", "lr", "tokens", "targets"])
    out_names = ([f"param.{i}" for i in range(P)] +
                 [f"m.{i}" for i in range(P)] +
                 [f"v.{i}" for i in range(P)] + ["loss"])
    meta = {"batch": LM_BATCH, **{k: getattr(cfg, k) for k in
            ("vocab", "d_model", "n_layers", "n_heads", "num_experts",
             "top_k", "seq_len", "activation", "block", "impl")}}
    ex.export("lm_train_step", "lm_train", flat_step, args, in_names,
              out_names, meta=meta)

    ev = ts.make_eval_step(cfg)

    def flat_eval(*flat):
        params = list(flat[:P])
        tokens, targets = flat[P:]
        return ev(params, tokens, targets)

    ex.export("lm_eval_step", "lm_eval", flat_eval, pspecs + [tok, tok],
              [f"param.{i}" for i in range(P)] + ["tokens", "targets"],
              ["loss"], meta=meta)


def memory_fixture():
    """Cross-language parity fixture: the Python memory model's numbers at
    paper scale, consumed by rust/tests/memory_parity.rs."""
    from . import memory_model as mm
    rows = []
    for c in cfgs.PAPER_CONFIGS:
        for act in ("silu", "swiglu"):
            for impl in ("moeblaze", "baseline"):
                kw = dict(dtype_bytes=2, block=cfgs.PAPER_BLOCK)
                if impl == "baseline":
                    kw["mode"] = "paper_baseline"
                b = mm.layer_bytes(impl, c.tokens, c.input_d, c.hidden,
                                   c.num_experts, c.top_k, act, **kw)
                rows.append({"config": c.name, "activation": act,
                             "impl": impl, "total_bytes": b.total,
                             "data_bytes": b.data_bytes,
                             "index_bytes": b.index_bytes,
                             "extra_bytes": b.extra_bytes})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", nargs="*", help="restrict layer steps to confs")
    ap.add_argument("--skip-lm", action="store_true")
    args = ap.parse_args()

    ex = Exporter(args.out, force=args.force)
    t0 = time.time()
    export_layer_steps(ex, only=args.only)
    export_layer_fwds(ex)
    export_dispatch(ex)
    if not args.skip_lm:
        export_lm(ex)
    ex.write_manifest(extra={
        "lm": {"batch": LM_BATCH, "params": lm_param_entries(LM_CONFIG),
               "config": {k: getattr(LM_CONFIG, k) for k in LM_CONFIG._fields}},
        "scaled_block": cfgs.SCALED_BLOCK,
        "configs_scaled": [c._asdict() for c in cfgs.SCALED_CONFIGS],
        "configs_paper": [c._asdict() for c in cfgs.PAPER_CONFIGS],
        "memory_fixture": memory_fixture(),
    })
    print(f"total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()

"""L2: training step (loss + grads + Adam) for the MoE LM.

The full update is one jitted function so the whole fwd/bwd/optimizer
pipeline AOT-lowers into a single HLO module the Rust coordinator executes
per step. Parameters and optimizer moments are flat lists (positional
interface, see transformer.param_spec); buffers are donated at lowering
time so XLA updates in place.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer as tf


ADAM_B1 = 0.9
ADAM_B2 = 0.95
ADAM_EPS = 1e-8


def adam_update(params, grads, m, v, step, lr, *, weight_decay=0.0):
    """Standard AdamW; `step` is 1-based (f32 scalar)."""
    b1c = 1.0 - ADAM_B1 ** step
    b2c = 1.0 - ADAM_B2 ** step
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * (g * g)
        upd = (mi / b1c) / (jnp.sqrt(vi / b2c) + ADAM_EPS)
        p = p - lr * (upd + weight_decay * p)
        new_p.append(p)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def make_train_step(cfg: tf.LmConfig):
    """(params, m, v, step, lr, tokens, targets) → (params', m', v', loss)."""

    def step_fn(params, m, v, step, lr, tokens, targets):
        loss, grads = jax.value_and_grad(tf.loss_fn)(params, tokens, targets, cfg)
        params, m, v = adam_update(params, grads, m, v, step, lr)
        return params, m, v, loss

    return step_fn


def make_eval_step(cfg: tf.LmConfig):
    """(params, tokens, targets) → loss (no update)."""

    def eval_fn(params, tokens, targets):
        return tf.loss_fn(params, tokens, targets, cfg)

    return eval_fn


def make_layer_step(spec, L: int):
    """Single-MoE-layer fwd+bwd step used by the Fig 4/6 speed benches.

    (x, wg, w1, w2, w3, cot) → (loss, dx, dwg, dw1, dw2, dw3)
    loss = Σ y ⊙ cot exercises the full backward exactly once, matching the
    paper's "end-to-end single training pass … excluding optimizer".
    """
    from . import moe_layer as ml

    layer = ml.make_moe_layer(spec)

    if spec.gated:
        def step_fn(x, wg, w1, w2, w3, cot):
            def scalar(x_, wg_, w1_, w2_, w3_):
                return jnp.sum(layer(x_, wg_, w1_, w2_, w3_) * cot)

            loss, grads = jax.value_and_grad(scalar, argnums=(0, 1, 2, 3, 4))(
                x, wg, w1, w2, w3)
            return (loss,) + grads
    else:
        # Non-gated activations never touch W2 — export a W2-free signature
        # so XLA's parameter pruning and the manifest agree.
        def step_fn(x, wg, w1, w3, cot):
            w2 = jnp.zeros_like(w1)

            def scalar(x_, wg_, w1_, w3_):
                return jnp.sum(layer(x_, wg_, w1_, w2, w3_) * cot)

            loss, grads = jax.value_and_grad(scalar, argnums=(0, 1, 2, 3))(
                x, wg, w1, w3)
            return (loss,) + grads

    return step_fn


def make_layer_fwd(spec):
    """(x, wg, w1, w2, w3) → y — inference-style single layer."""
    from . import moe_layer as ml

    layer = ml.make_moe_layer(spec)

    def fwd(x, wg, w1, w2, w3):
        return layer(x, wg, w1, w2, w3)

    return fwd

"""Snapshot the PR-5 perf baseline: run `ep-bench --json-out` on the
Figure-2-derived fixture and write BENCH_PR5.json at the repo root, so
the bench trajectory (tokens/s + peak comm bytes, old packed path vs new
index-driven path) is a reproducible artifact instead of a console line.

Usage:
    python tools/bench_snapshot.py [--out BENCH_PR5.json]

Requires a Rust toolchain (cargo) — the build container used for the
Python mirrors has none, so CI runs this from the non-blocking
`bench-smoke` job on a toolchain-equipped runner.
"""
import argparse
import json
import pathlib
import shutil
import subprocess
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

# The fixture: the default ep-bench workload scaled to bench size — the
# same L/E/k shape family as the paper's Figure 2 worked example, large
# enough that the kernel path (not fixed overheads) dominates.
FIXTURE = [
    "--ranks", "4",
    "--tokens", "2048",
    "--experts", "16",
    "--top-k", "2",
    "--d-model", "32",
    "--d-hidden", "64",
    "--skew", "0.7",
    "--seed", "7",
]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="BENCH_PR5.json",
                    help="output path, relative to the repo root")
    ap.add_argument("--steps", default="2",
                    help="bench steps passed through to ep-bench")
    args = ap.parse_args()

    if shutil.which("cargo") is None:
        print("bench_snapshot: no cargo toolchain on this host — "
              "run from a toolchain-equipped checkout", file=sys.stderr)
        return 1

    out = ROOT / args.out
    cmd = ["cargo", "run", "--release", "--", "ep-bench",
           "--steps", args.steps, "--json-out", str(out)] + FIXTURE
    print("bench_snapshot:", " ".join(cmd))
    proc = subprocess.run(cmd, cwd=ROOT)
    if proc.returncode != 0:
        print(f"bench_snapshot: ep-bench exited {proc.returncode}",
              file=sys.stderr)
        return proc.returncode

    snap = json.loads(out.read_text())
    speedup = snap.get("speedup", 0.0)
    old = snap.get("baseline", {})
    new = snap.get("indexed", {})
    print(f"bench_snapshot: wrote {out}")
    print(f"  old packed path : {old.get('tokens_per_sec', 0):.0f} tokens/s, "
          f"peak rank comm {old.get('peak_rank_comm_bytes', 0):.0f} B")
    print(f"  new indexed path: {new.get('tokens_per_sec', 0):.0f} tokens/s, "
          f"peak rank comm {new.get('peak_rank_comm_bytes', 0):.0f} B")
    print(f"  speedup         : {speedup:.2f}x")
    if speedup < 1.5:
        print("bench_snapshot: WARNING — speedup below the 1.5x acceptance "
              "bar on this host", file=sys.stderr)
    if new.get("peak_rank_comm_bytes", 0) >= old.get("peak_rank_comm_bytes", 1):
        print("bench_snapshot: WARNING — staging bytes did not drop below "
              "the packed buffers", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

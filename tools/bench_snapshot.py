"""Snapshot the ep-bench perf baseline: run `ep-bench --json-out` over
the snapshot matrix (activation x tile policy) on the Figure-2-derived
fixture and merge the per-run JSON objects into one artifact at --out,
so the bench trajectory (tokens/s + peak comm bytes, old packed path vs
new index-driven path, SiLU vs SwiGLU, static vs autotuned tiles) is a
reproducible artifact instead of a console line.

Usage:
    python tools/bench_snapshot.py --out BENCH_PR6.json

Requires a Rust toolchain (cargo) — the build container used for the
Python mirrors has none, so CI runs this from the non-blocking
`bench-smoke` job on a toolchain-equipped runner.
"""
import argparse
import json
import pathlib
import shutil
import subprocess
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parent.parent

# The fixture: the default ep-bench workload scaled to bench size — the
# same L/E/k shape family as the paper's Figure 2 worked example, large
# enough that the kernel path (not fixed overheads) dominates.
FIXTURE = [
    "--ranks", "4",
    "--tokens", "2048",
    "--experts", "16",
    "--top-k", "2",
    "--d-model", "32",
    "--d-hidden", "64",
    "--skew", "0.7",
    "--seed", "7",
]

# The snapshot matrix: (row name, extra ep-bench flags). `--tile-rows 0`
# is the autotune path — the probed tile lands in the row's `tile_rows`.
MATRIX = [
    ("silu", ["--activation", "silu"]),
    ("swiglu", ["--activation", "swiglu"]),
    ("silu_tile_auto", ["--activation", "silu", "--tile-rows", "0"]),
    ("swiglu_tile_auto", ["--activation", "swiglu", "--tile-rows", "0"]),
]


def run_one(name, extra, steps, tmpdir, subcommand="ep-bench"):
    row_out = pathlib.Path(tmpdir) / f"{name}.json"
    cmd = ["cargo", "run", "--release", "--", subcommand,
           "--steps", steps, "--json-out", str(row_out)] + FIXTURE + extra
    print(f"bench_snapshot [{name}]:", " ".join(cmd))
    proc = subprocess.run(cmd, cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"ep-bench [{name}] exited {proc.returncode}")
    return json.loads(row_out.read_text())


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", required=True,
                    help="output path (e.g. BENCH_PR6.json), relative to "
                         "the repo root")
    ap.add_argument("--steps", default="2",
                    help="bench steps passed through to ep-bench")
    args = ap.parse_args()

    if shutil.which("cargo") is None:
        print("bench_snapshot: no cargo toolchain on this host — "
              "run from a toolchain-equipped checkout", file=sys.stderr)
        return 1

    rows = {}
    warnings = 0
    with tempfile.TemporaryDirectory() as tmpdir:
        for name, extra in MATRIX:
            snap = run_one(name, extra, args.steps, tmpdir)
            rows[name] = snap
            speedup = snap.get("speedup", 0.0)
            old = snap.get("baseline", {})
            new = snap.get("indexed", {})
            print(f"  [{name}] act={snap.get('activation', '?')} "
                  f"tile_rows={snap.get('tile_rows', '?')}"
                  f"{' (autotuned)' if snap.get('tile_autotuned') else ''}")
            print(f"    old packed path : "
                  f"{old.get('tokens_per_sec', 0):.0f} tokens/s, peak rank "
                  f"comm {old.get('peak_rank_comm_bytes', 0):.0f} B")
            print(f"    new indexed path: "
                  f"{new.get('tokens_per_sec', 0):.0f} tokens/s, peak rank "
                  f"comm {new.get('peak_rank_comm_bytes', 0):.0f} B")
            print(f"    speedup         : {speedup:.2f}x")
            if speedup < 1.5:
                print(f"bench_snapshot: WARNING — [{name}] speedup below the "
                      "1.5x acceptance bar on this host", file=sys.stderr)
                warnings += 1
            if new.get("peak_rank_comm_bytes", 0) \
                    >= old.get("peak_rank_comm_bytes", 1):
                print(f"bench_snapshot: WARNING — [{name}] staging bytes did "
                      "not drop below the packed buffers", file=sys.stderr)
                warnings += 1

        # forward-only serving smoke cell: ep-serve on the same fixture
        # (--steps aliases the tick count), pinned to the matrix so the
        # bench gate tracks serving throughput + peak bytes too
        serve = run_one("serve_smoke", ["--activation", "swiglu"],
                        args.steps, tmpdir, subcommand="ep-serve")
        rows["serve_smoke"] = serve
        print(f"  [serve_smoke] engine={serve.get('engine', '?')} "
              f"ticks={serve.get('ticks', '?')}")
        print(f"    requests: {serve.get('generated', 0):.0f} generated, "
              f"{serve.get('completed', 0):.0f} completed, "
              f"{serve.get('rejected_queue_full', 0):.0f}+"
              f"{serve.get('rejected_capacity', 0):.0f} rejected, "
              f"{serve.get('queued_at_end', 0):.0f} queued at end")
        print(f"    {serve.get('tokens_per_sec', 0):.0f} tokens/s, "
              f"p99 {serve.get('latency_p99_ms', 0):.3f} ms, peak rank "
              f"{serve.get('peak_rank_data_bytes', 0):.0f} B")
        accounted = (serve.get("completed", 0)
                     + serve.get("rejected_queue_full", 0)
                     + serve.get("rejected_capacity", 0)
                     + serve.get("queued_at_end", 0))
        if serve.get("generated", -1) != accounted:
            print("bench_snapshot: WARNING — [serve_smoke] request counters "
                  "do not conserve", file=sys.stderr)
            warnings += 1

        # training smoke cell: ep-train on the same fixture so the gate
        # tracks training throughput + peak bytes + drift flags too
        train = run_one("train_smoke",
                        ["--activation", "swiglu", "--pipeline-chunks", "2"],
                        args.steps, tmpdir, subcommand="ep-train")
        rows["train_smoke"] = train
        print(f"  [train_smoke] {train.get('tokens_per_sec', 0):.0f} tokens/s, "
              f"loss {train.get('first_loss', 0):.4f} -> "
              f"{train.get('final_loss', 0):.4f}, peak rank "
              f"{train.get('peak_rank_data_bytes', 0):.0f} B, "
              f"drift flags {train.get('drift_flags', 0):.0f}")
        if not train.get("final_loss", 1e9) < train.get("first_loss", 0):
            print("bench_snapshot: WARNING — [train_smoke] loss did not drop",
                  file=sys.stderr)
            warnings += 1

        for name, snap in rows.items():
            if snap.get("snapshot_version") != 1:
                print(f"bench_snapshot: WARNING — [{name}] snapshot is "
                      "unversioned (the gate will reject it)", file=sys.stderr)
                warnings += 1

    out = ROOT / args.out
    out.write_text(json.dumps({"bench": "ep_bench_matrix",
                               "snapshot_version": 1, "runs": rows},
                              indent=2, sort_keys=True) + "\n")
    print(f"bench_snapshot: wrote {len(rows)} runs to {out}"
          + (f" ({warnings} warnings)" if warnings else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())

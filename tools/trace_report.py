"""Validate and summarize moeblaze Chrome traces (--trace-out files).

The Rust tracer (rust/src/trace/) exports Chrome trace-event JSON with a
`moeblaze` metadata object: `schema_version`, the rank count, and one
per-step summary carrying the engine's own `measured_step_s()` and
per-rank `memory_per_rank()` data bytes. That makes every trace
self-validating, and this tool is the validator CI runs after the
`ep-bench --trace-out` smoke:

  * schema: `schema_version` matches, every event is a well-formed
    "X" (duration), "C" (counter), or "M" (metadata) record, span names
    are known phases, durations are non-negative;
  * time consistency: per step, the summed wall-clock of the *section*
    spans of the measured phases (gather / expert_gemm / combine on the
    coordinator pid — detail spans excluded) equals the engine's
    `measured_step_s` up to float addition order;
  * memory consistency: per step and rank, the max `resident_bytes`
    counter sample equals the summary's `peak_rank_bytes[rank]` exactly
    (both are the same u64 `memory_per_rank()` reading);
  * load tracks (only when the trace carries them — `--skew-alarm` /
    `--metrics-expose` runs): the per-rank `load_rows` counter tracks
    are cumulative routed-row totals, so each rank's samples must be
    monotone non-decreasing in ts order, and the tracks must be
    rank-complete — every rank `0..ranks` has one.

Usage:
    python tools/trace_report.py --validate trace.json   # CI gate
    python tools/trace_report.py trace.json              # breakdown table
    python tools/trace_report.py --self-test
"""
import argparse
import json
import pathlib
import sys

# Mirrors TRACE_SCHEMA_VERSION in rust/src/trace/mod.rs.
SCHEMA_VERSION = 1

# TracePhase::name() values, split by TracePhase::is_measured().
MEASURED_PHASES = ("gather", "expert_gemm", "combine")
HOST_PHASES = ("optimizer_update", "batcher_tick")
KNOWN_PHASES = MEASURED_PHASES + HOST_PHASES

# The coordinator pid section spans land on (COORD_PID in trace/mod.rs);
# per-rank detail spans and counters use pid = rank + 2.
COORD_PID = 1

# Section spans carry the exact f64 values fed to the timeline's
# record_measured, so only addition order separates the span sum from
# measured_step_s — micro-tolerance, not a physics fudge factor.
REL_TOL = 1e-6


def rank_of_pid(pid):
    return int(pid) - 2


def iter_events(trace, phase_kind):
    for e in trace.get("traceEvents", []):
        if isinstance(e, dict) and e.get("ph") == phase_kind:
            yield e


def check_event_shapes(trace):
    """Structural failures over every event in the trace."""
    fails = []
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        return ["traceEvents missing or empty"]
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            fails.append(f"event {i} is not an object")
            continue
        ph = e.get("ph")
        if ph == "M":
            continue
        if ph not in ("X", "C"):
            fails.append(f"event {i}: unknown ph {ph!r}")
            continue
        for key in ("name", "ts", "pid", "tid", "args"):
            if key not in e:
                fails.append(f"event {i} ({ph}): missing {key}")
        if ph == "X":
            if e.get("name") not in KNOWN_PHASES:
                fails.append(f"event {i}: unknown span name {e.get('name')!r}")
            if not isinstance(e.get("dur"), (int, float)) or e.get("dur", -1) < 0:
                fails.append(f"event {i}: bad dur {e.get('dur')!r}")
            if "step" not in e.get("args", {}):
                fails.append(f"event {i}: span args missing step")
        if ph == "C":
            args = e.get("args", {})
            if e.get("name") not in args:
                fails.append(f"event {i}: counter args missing its own "
                             f"{e.get('name')!r} value")
            if "step" not in args:
                fails.append(f"event {i}: counter args missing step")
    return fails


def section_span_sums(trace):
    """Per-step summed seconds of the measured-phase section spans."""
    sums = {}
    for e in iter_events(trace, "X"):
        if e.get("pid") != COORD_PID or e.get("name") not in MEASURED_PHASES:
            continue
        step = int(e.get("args", {}).get("step", -1))
        sums[step] = sums.get(step, 0.0) + float(e.get("dur", 0.0)) / 1e6
    return sums


def counter_maxima(trace, name="resident_bytes"):
    """(step, rank) -> max sampled value of the named counter track."""
    maxima = {}
    for e in iter_events(trace, "C"):
        if e.get("name") != name:
            continue
        args = e.get("args", {})
        key = (int(args.get("step", -1)), rank_of_pid(e.get("pid", 0)))
        value = float(args.get(name, 0.0))
        maxima[key] = max(maxima.get(key, 0.0), value)
    return maxima


def check_load_tracks(trace, ranks):
    """Per-rank `load_rows` counter tracks: monotone and rank-complete.

    The tracker records *cumulative* routed rows per rank, so within a
    rank's track the sampled value can never decrease. Load tracks are
    optional (they exist only when the run had a load tracker attached);
    an empty set of tracks is valid.
    """
    fails = []
    tracks = {}
    for e in iter_events(trace, "C"):
        if e.get("name") != "load_rows":
            continue
        rank = rank_of_pid(e.get("pid", 0))
        tracks.setdefault(rank, []).append(
            (float(e.get("ts", 0.0)),
             float(e.get("args", {}).get("load_rows", 0.0))))
    if not tracks:
        return fails
    missing = sorted(set(range(ranks)) - set(tracks))
    if missing:
        fails.append(f"load_rows tracks exist but ranks {missing} have "
                     f"none ({ranks} ranks in metadata)")
    for rank in sorted(tracks):
        samples = sorted(tracks[rank])
        for (_, prev), (ts, cur) in zip(samples, samples[1:]):
            if cur < prev:
                fails.append(
                    f"rank {rank}: load_rows track decreases "
                    f"{prev:.0f} -> {cur:.0f} at ts {ts:.0f} "
                    f"(cumulative counter must be monotone)")
                break
    return fails


def validate(trace):
    """Return a list of failure strings (empty = trace is valid)."""
    meta = trace.get("moeblaze")
    if not isinstance(meta, dict):
        return ["missing `moeblaze` metadata object"]
    if meta.get("schema_version") != SCHEMA_VERSION:
        return [f"schema_version {meta.get('schema_version')!r} is not the "
                f"supported {SCHEMA_VERSION}"]
    fails = check_event_shapes(trace)
    if fails:
        return fails

    steps = meta.get("steps", [])
    if not isinstance(steps, list):
        return ["moeblaze.steps is not a list"]
    ranks = int(meta.get("ranks", 0))
    sums = section_span_sums(trace)
    maxima = counter_maxima(trace)
    fails.extend(check_load_tracks(trace, ranks))

    for entry in steps:
        step = int(entry.get("step", -1))
        measured = float(entry.get("measured_step_s", 0.0))
        span_sum = sums.get(step, 0.0)
        tol = max(REL_TOL * max(abs(span_sum), abs(measured)), 1e-12)
        if abs(span_sum - measured) > tol:
            fails.append(
                f"step {step}: section-span sum {span_sum:.9f}s != "
                f"measured_step_s {measured:.9f}s (tol {tol:.2e})")
        peaks = entry.get("peak_rank_bytes", [])
        if len(peaks) > ranks:
            fails.append(f"step {step}: {len(peaks)} peak_rank_bytes entries "
                         f"but metadata says {ranks} ranks")
        for r, expected in enumerate(peaks):
            got = maxima.get((step, r))
            if got is None:
                continue  # no gauge sample for this rank/step (empty tick)
            if got != float(expected):
                fails.append(
                    f"step {step} rank {r}: resident_bytes counter max "
                    f"{got:.0f} != summary peak_rank_bytes {expected:.0f}")
    return fails


def report(trace):
    """Human summary: per-phase totals and the per-step roll-up."""
    meta = trace.get("moeblaze", {})
    totals = {}
    for e in iter_events(trace, "X"):
        if e.get("cat") == "detail":
            continue
        name = e.get("name", "?")
        spans, secs, bytes_ = totals.get(name, (0, 0.0, 0))
        totals[name] = (spans + 1,
                        secs + float(e.get("dur", 0.0)) / 1e6,
                        bytes_ + int(e.get("args", {}).get("bytes", 0)))
    print(f"trace: schema v{meta.get('schema_version')}, "
          f"{meta.get('ranks', 0)} ranks, {len(meta.get('steps', []))} steps")
    print(f"{'phase':<18} {'spans':>6} {'total ms':>10} {'bytes':>12}")
    for name in KNOWN_PHASES:
        if name not in totals:
            continue
        spans, secs, bytes_ = totals[name]
        print(f"{name:<18} {spans:>6} {secs * 1e3:>10.3f} {bytes_:>12}")
    sums = section_span_sums(trace)
    for entry in meta.get("steps", []):
        step = int(entry.get("step", -1))
        peaks = entry.get("peak_rank_bytes", [])
        print(f"step {step}: measured {entry.get('measured_step_s', 0.0) * 1e3:.3f} ms "
              f"(spans {sums.get(step, 0.0) * 1e3:.3f} ms), peak rank bytes "
              f"{max(peaks) if peaks else 0:.0f}")


def synthetic_trace():
    """A minimal valid trace: 2 steps, 2 ranks, exact summaries."""
    events = [{"name": "process_name", "ph": "M", "pid": COORD_PID, "tid": 0,
               "args": {"name": "coordinator"}}]
    steps = []
    for step in range(2):
        t0 = step * 10_000.0
        durs = {"gather": 120.5, "expert_gemm": 800.25, "combine": 60.125}
        for i, (name, dur) in enumerate(durs.items()):
            events.append({"name": name, "cat": "comm", "ph": "X",
                           "ts": t0 + 1000.0 * i, "dur": dur,
                           "pid": COORD_PID, "tid": 1,
                           "args": {"step": step, "bytes": 1024}})
        # a detail span and a host span, both excluded from the sum
        events.append({"name": "gather", "cat": "detail", "ph": "X",
                       "ts": t0, "dur": 55.0, "pid": 2, "tid": 1,
                       "args": {"step": step}})
        events.append({"name": "optimizer_update", "cat": "host", "ph": "X",
                       "ts": t0 + 5000.0, "dur": 42.0, "pid": COORD_PID,
                       "tid": 3, "args": {"step": step}})
        peaks = [4096.0 + step, 2048.0]
        for r, v in enumerate(peaks):
            events.append({"name": "resident_bytes", "cat": "gauge",
                           "ph": "C", "ts": t0, "pid": r + 2, "tid": 0,
                           "args": {"resident_bytes": v, "step": step,
                                    "phase": "expert_gemm"}})
        steps.append({"step": step,
                      "measured_step_s": sum(durs.values()) / 1e6,
                      "peak_rank_bytes": peaks})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "moeblaze": {"schema_version": SCHEMA_VERSION, "ranks": 2,
                         "steps": steps}}


def self_test() -> int:
    good = synthetic_trace()
    checks = [("valid trace passes", validate(good) == [])]

    wrong_ver = json.loads(json.dumps(good))
    wrong_ver["moeblaze"]["schema_version"] = 99
    checks.append(("wrong schema_version fails", validate(wrong_ver) != []))

    no_meta = {"traceEvents": good["traceEvents"]}
    checks.append(("missing metadata fails", validate(no_meta) != []))

    drifted = json.loads(json.dumps(good))
    drifted["moeblaze"]["steps"][0]["measured_step_s"] *= 1.5
    checks.append(("span/measured mismatch fails", validate(drifted) != []))

    fat = json.loads(json.dumps(good))
    fat["moeblaze"]["steps"][1]["peak_rank_bytes"][0] += 1
    checks.append(("counter/peak mismatch fails", validate(fat) != []))

    alien = json.loads(json.dumps(good))
    alien["traceEvents"].append({"name": "warp_drive", "ph": "X", "ts": 0,
                                 "dur": 1, "pid": 1, "tid": 1,
                                 "args": {"step": 0}})
    checks.append(("unknown span name fails", validate(alien) != []))

    negative = json.loads(json.dumps(good))
    negative["traceEvents"][1]["dur"] = -5.0
    checks.append(("negative duration fails", validate(negative) != []))

    # detail spans must stay excluded: inflating one changes nothing
    detail = json.loads(json.dumps(good))
    for e in detail["traceEvents"]:
        if e.get("cat") == "detail":
            e["dur"] = 1e9
    checks.append(("detail spans excluded from sums", validate(detail) == []))

    # an empty tick (summary step with no spans/counters) still passes
    # when its measured_step_s is zero
    sparse = json.loads(json.dumps(good))
    sparse["moeblaze"]["steps"].append(
        {"step": 7, "measured_step_s": 0.0, "peak_rank_bytes": []})
    checks.append(("span-free zero step passes", validate(sparse) == []))

    # load_rows tracks are optional — the base trace has none and
    # validates; with well-formed tracks it still validates
    def with_load_tracks(rows_by_rank_step):
        t = json.loads(json.dumps(good))
        for (rank, step), rows in sorted(rows_by_rank_step.items()):
            t["traceEvents"].append(
                {"name": "load_rows", "cat": "gauge", "ph": "C",
                 "ts": step * 10_000.0 + 9_000.0, "pid": rank + 2,
                 "tid": 0, "args": {"load_rows": rows, "step": step,
                                    "phase": "gather"}})
        return t

    tracked = with_load_tracks({(0, 0): 96.0, (0, 1): 192.0,
                                (1, 0): 32.0, (1, 1): 64.0})
    checks.append(("monotone rank-complete load tracks pass",
                   validate(tracked) == []))

    shrinking = with_load_tracks({(0, 0): 96.0, (0, 1): 40.0,
                                  (1, 0): 32.0, (1, 1): 64.0})
    checks.append(("decreasing load_rows track fails",
                   any("monotone" in f for f in validate(shrinking))))

    lopsided = with_load_tracks({(0, 0): 96.0, (0, 1): 192.0})
    checks.append(("rank-incomplete load tracks fail",
                   any("ranks [1]" in f for f in validate(lopsided))))

    failed = [name for name, passed in checks if not passed]
    for name, passed in checks:
        print(f"trace_report self-test: {name}: {'ok' if passed else 'FAIL'}")
    if failed:
        print(f"trace_report self-test: {len(failed)} check(s) failed",
              file=sys.stderr)
        return 1
    print(f"trace_report self-test: all {len(checks)} checks passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", nargs="?", help="Chrome trace JSON to read")
    ap.add_argument("--validate", metavar="TRACE",
                    help="validate the trace and exit nonzero on failure")
    ap.add_argument("--self-test", action="store_true",
                    help="run the built-in behavior checks and exit")
    args = ap.parse_args()

    if args.self_test:
        return self_test()
    path = args.validate or args.trace
    if not path:
        ap.error("a trace path, --validate TRACE, or --self-test is required")
    p = pathlib.Path(path)
    if not p.exists():
        print(f"trace_report: {p} does not exist", file=sys.stderr)
        return 1
    trace = json.loads(p.read_text())

    if args.validate:
        fails = validate(trace)
        if fails:
            for f in fails:
                print(f"trace_report: FAIL {f}", file=sys.stderr)
            return 1
        meta = trace.get("moeblaze", {})
        spans = sum(1 for _ in iter_events(trace, "X"))
        counters = sum(1 for _ in iter_events(trace, "C"))
        print(f"trace_report: {p.name} valid \N{CHECK MARK} "
              f"({len(meta.get('steps', []))} steps, {spans} spans, "
              f"{counters} counter samples, {meta.get('ranks', 0)} ranks)")
        return 0

    report(trace)
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Render expert-load telemetry: EWMA heat tables and alarm timelines.

The Rust side exports load telemetry through two channels, and this tool
reads both:

  * the Prometheus text exposition written to `[ep] metrics_expose_path`
    (`--metrics-expose`): `moeblaze_expert_load_ewma{expert,layer}`
    gauges plus the per-layer `moeblaze_load_imbalance` /
    `moeblaze_load_cov` / `moeblaze_router_entropy` /
    `moeblaze_skew_alarm_active` gauges, the
    `moeblaze_skew_alarms_total` counter, and the per-rank
    `moeblaze_rank_load_rows_total` counter;
  * the metrics JSONL written to `[ep] metrics_path` (`--metrics`):
    one `skew_alarm` event per raising edge (step/tick, layer,
    imbalance, threshold) and one end-of-run `load_summary`.

The exposition gives the *final* load shape (heat table per layer, rank
row totals); the JSONL gives the *history* (when each alarm fired).
Either input alone renders what it can.

Usage:
    python tools/load_report.py metrics.prom
    python tools/load_report.py --jsonl metrics.jsonl
    python tools/load_report.py metrics.prom --jsonl metrics.jsonl
    python tools/load_report.py --self-test
"""
import argparse
import json
import math
import pathlib
import re
import sys

# Metric family names published by ExpertLoadTracker::publish_registry
# (rust/src/trace/load.rs) — parsing keys, keep in sync.
EWMA = "moeblaze_expert_load_ewma"
IMBALANCE = "moeblaze_load_imbalance"
COV = "moeblaze_load_cov"
ENTROPY = "moeblaze_router_entropy"
ALARM_ACTIVE = "moeblaze_skew_alarm_active"
ALARMS_TOTAL = "moeblaze_skew_alarms_total"
RANK_ROWS = "moeblaze_rank_load_rows_total"

# Unicode eighth-blocks for the per-layer heat strip.
HEAT = "▁▂▃▄▅▆▇█"

_SAMPLE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+(\S+)\s*$')
_LABEL = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:\\.|[^"\\])*)"')


def _unescape(v):
    """Invert the exposition label escaping (\\\\, \\", \\n)."""
    out, i = [], 0
    while i < len(v):
        if v[i] == "\\" and i + 1 < len(v):
            out.append({"n": "\n", '"': '"', "\\": "\\"}.get(v[i + 1],
                                                            v[i + 1]))
            i += 2
        else:
            out.append(v[i])
            i += 1
    return "".join(out)


def parse_exposition(text):
    """Prometheus text -> {family: [(labels dict, float value)]}.

    Comment/HELP/TYPE lines and malformed lines are skipped; NaN and
    +/-Inf values parse to their float counterparts.
    """
    families = {}
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE.match(line)
        if not m:
            continue
        name, raw_labels, raw_value = m.groups()
        try:
            value = float(raw_value)
        except ValueError:
            continue
        labels = {k: _unescape(v)
                  for k, v in _LABEL.findall(raw_labels or "")}
        families.setdefault(name, []).append((labels, value))
    return families


def parse_jsonl(text):
    """Metrics JSONL -> list of event dicts (malformed lines skipped)."""
    events = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            e = json.loads(line)
        except ValueError:
            continue
        if isinstance(e, dict):
            events.append(e)
    return events


def _by_layer(samples):
    out = {}
    for labels, value in samples:
        try:
            out[int(labels.get("layer", "0"))] = value
        except ValueError:
            continue
    return out


def heat_table(families):
    """Per-layer expert EWMA heat tables from exposition samples."""
    lines = []
    grid = {}
    for labels, value in families.get(EWMA, []):
        try:
            layer = int(labels.get("layer", "0"))
            expert = int(labels.get("expert", "0"))
        except ValueError:
            continue
        grid.setdefault(layer, {})[expert] = value
    if not grid:
        lines.append("load_report: no expert EWMA gauges in exposition")
        return lines

    imb = _by_layer(families.get(IMBALANCE, []))
    cov = _by_layer(families.get(COV, []))
    ent = _by_layer(families.get(ENTROPY, []))
    active = _by_layer(families.get(ALARM_ACTIVE, []))
    totals = _by_layer(families.get(ALARMS_TOTAL, []))

    for layer in sorted(grid):
        experts = grid[layer]
        vals = [experts.get(e, 0.0) for e in range(max(experts) + 1)]
        finite = [v for v in vals if math.isfinite(v)]
        peak = max(finite) if finite else 0.0
        strip = "".join(
            HEAT[min(len(HEAT) - 1, int(v / peak * (len(HEAT) - 1)))]
            if peak > 0 and math.isfinite(v) else HEAT[0]
            for v in vals)
        flag = " ALARM" if active.get(layer, 0.0) > 0 else ""
        lines.append(
            f"layer {layer}  {strip}  imbalance {imb.get(layer, 0.0):.3f}  "
            f"cov {cov.get(layer, 0.0):.3f}  entropy {ent.get(layer, 0.0):.3f}  "
            f"alarms {totals.get(layer, 0.0):.0f}{flag}")
        lines.append("  " + "  ".join(
            f"e{e}:{v:.1f}" for e, v in enumerate(vals)))

    rank_rows = {}
    for labels, value in families.get(RANK_ROWS, []):
        try:
            rank_rows[int(labels.get("rank", "0"))] = value
        except ValueError:
            continue
    if rank_rows:
        lines.append("rank rows  " + "  ".join(
            f"r{r}:{rank_rows[r]:.0f}" for r in sorted(rank_rows)))
    return lines


def alarm_timeline(events):
    """Per-layer `.`/`!` timeline of skew_alarm events over steps/ticks."""
    lines = []
    alarms = [e for e in events if e.get("kind") == "skew_alarm"]
    summary = next((e for e in events if e.get("kind") == "load_summary"),
                   None)
    if not alarms:
        lines.append("load_report: no skew_alarm events"
                     + (" (summary: 0 alarms)" if summary else ""))
    else:
        def when(e):
            return int(e.get("step", e.get("tick", 0)))

        last = max(when(e) for e in alarms)
        by_layer = {}
        for e in alarms:
            by_layer.setdefault(int(e.get("layer", 0)), []).append(e)
        for layer in sorted(by_layer):
            marks = {when(e) for e in by_layer[layer]}
            strip = "".join("!" if s in marks else "."
                            for s in range(last + 1))
            lines.append(f"layer {layer}  [{strip}]  "
                         f"{len(by_layer[layer])} alarm(s)")
            for e in sorted(by_layer[layer], key=when):
                lines.append(
                    f"  step {when(e)}: imbalance "
                    f"{e.get('imbalance', 0.0):.3f} over threshold "
                    f"{e.get('threshold', 0.0):g} "
                    f"({int(e.get('ranks', 0))} ranks)")
    if summary:
        lines.append(
            f"summary: {int(summary.get('skew_alarms', 0))} alarm(s), "
            f"max imbalance {summary.get('max_imbalance', 0.0):.3f} over "
            f"{int(summary.get('layers', 0))} layer(s)")
    return lines


def _synthetic_exposition():
    return "\n".join([
        "# HELP moeblaze_expert_load_ewma EWMA of routed rows",
        "# TYPE moeblaze_expert_load_ewma gauge",
        'moeblaze_expert_load_ewma{expert="0",layer="0"} 12',
        'moeblaze_expert_load_ewma{expert="1",layer="0"} 2',
        'moeblaze_expert_load_ewma{expert="2",layer="0"} 1.5',
        'moeblaze_expert_load_ewma{expert="3",layer="0"} 1',
        'moeblaze_expert_load_ewma{expert="0",layer="1"} 4',
        'moeblaze_expert_load_ewma{expert="1",layer="1"} 4',
        "# TYPE moeblaze_load_imbalance gauge",
        'moeblaze_load_imbalance{layer="0"} 1.75',
        'moeblaze_load_imbalance{layer="1"} 1',
        "# TYPE moeblaze_load_cov gauge",
        'moeblaze_load_cov{layer="0"} 0.75',
        "# TYPE moeblaze_router_entropy gauge",
        'moeblaze_router_entropy{layer="0"} 1.213',
        "# TYPE moeblaze_skew_alarm_active gauge",
        'moeblaze_skew_alarm_active{layer="0"} 1',
        'moeblaze_skew_alarm_active{layer="1"} 0',
        "# TYPE moeblaze_skew_alarms_total counter",
        'moeblaze_skew_alarms_total{layer="0"} 1',
        "# TYPE moeblaze_rank_load_rows_total counter",
        'moeblaze_rank_load_rows_total{rank="0"} 140',
        'moeblaze_rank_load_rows_total{rank="1"} 25',
        'weird{tag="a\\"b\\\\c\\nd"} NaN',
        "this line is not a sample",
    ]) + "\n"


def _synthetic_jsonl():
    return "\n".join([
        json.dumps({"kind": "skew_alarm", "t": 0.1, "step": 3, "layer": 0,
                    "imbalance": 1.75, "threshold": 1.5, "ranks": 2}),
        json.dumps({"kind": "skew_alarm", "t": 0.2, "step": 7, "layer": 0,
                    "imbalance": 1.9, "threshold": 1.5, "ranks": 2}),
        json.dumps({"kind": "train", "t": 0.3, "loss": 1.0}),
        "not json at all",
        json.dumps({"kind": "load_summary", "t": 0.4, "skew_alarms": 2,
                    "max_imbalance": 1.9, "layers": 1, "records": 10}),
    ]) + "\n"


def self_test() -> int:
    checks = []

    fams = parse_exposition(_synthetic_exposition())
    checks.append(("EWMA samples parse",
                   len(fams.get(EWMA, [])) == 6))
    checks.append(("comment and junk lines skipped",
                   "this" not in fams))
    ewma00 = next((v for l, v in fams[EWMA]
                   if l == {"expert": "0", "layer": "0"}), None)
    checks.append(("labelled value round-trips", ewma00 == 12.0))
    weird = fams.get("weird", [])
    checks.append(("escaped label value unescapes",
                   weird and weird[0][0] == {"tag": 'a"b\\c\nd'}))
    checks.append(("NaN value parses", weird
                   and math.isnan(weird[0][1])))

    heat = "\n".join(heat_table(fams))
    checks.append(("heat table covers both layers",
                   "layer 0" in heat and "layer 1" in heat))
    checks.append(("hot expert renders full block",
                   HEAT[-1] in heat))
    checks.append(("imbalance gauge surfaces", "1.750" in heat))
    checks.append(("active alarm flagged", "ALARM" in heat))
    checks.append(("rank totals surface",
                   "r0:140" in heat and "r1:25" in heat))

    events = parse_jsonl(_synthetic_jsonl())
    checks.append(("jsonl skips malformed lines", len(events) == 4))
    timeline = "\n".join(alarm_timeline(events))
    checks.append(("alarm steps marked",
                   "[...!...!]" in timeline))
    checks.append(("alarm details listed",
                   "step 3" in timeline and "step 7" in timeline))
    checks.append(("summary rendered",
                   "2 alarm(s), max imbalance 1.900" in timeline))

    quiet = "\n".join(alarm_timeline(
        [{"kind": "load_summary", "skew_alarms": 0, "max_imbalance": 1.05,
          "layers": 1, "records": 4}]))
    checks.append(("silent run renders summary only",
                   "no skew_alarm events" in quiet and "0 alarm(s)" in quiet))
    checks.append(("empty exposition degrades gracefully",
                   "no expert EWMA gauges"
                   in "\n".join(heat_table({}))))

    failed = [name for name, passed in checks if not passed]
    for name, passed in checks:
        print(f"load_report self-test: {name}: "
              f"{'ok' if passed else 'FAIL'}")
    if failed:
        print(f"load_report self-test: {len(failed)} check(s) failed",
              file=sys.stderr)
        return 1
    print(f"load_report self-test: all {len(checks)} checks passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("exposition", nargs="?",
                    help="Prometheus exposition file (--metrics-expose)")
    ap.add_argument("--jsonl", metavar="PATH",
                    help="metrics JSONL file (--metrics) for the "
                         "alarm timeline")
    ap.add_argument("--self-test", action="store_true",
                    help="run the built-in behavior checks and exit")
    args = ap.parse_args()

    if args.self_test:
        return self_test()
    if not args.exposition and not args.jsonl:
        ap.error("an exposition path, --jsonl PATH, or --self-test "
                 "is required")

    if args.exposition:
        p = pathlib.Path(args.exposition)
        if not p.exists():
            print(f"load_report: {p} does not exist", file=sys.stderr)
            return 1
        for line in heat_table(parse_exposition(p.read_text())):
            print(line)
    if args.jsonl:
        p = pathlib.Path(args.jsonl)
        if not p.exists():
            print(f"load_report: {p} does not exist", file=sys.stderr)
            return 1
        for line in alarm_timeline(parse_jsonl(p.read_text())):
            print(line)
    return 0


if __name__ == "__main__":
    sys.exit(main())

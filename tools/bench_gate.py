"""Regression gate over bench snapshots: compare the current
BENCH_PR<N>.json against the previous snapshot and fail on a >10%
tokens/s regression or ANY peak-bytes growth on a shared row. Memory
rows are deterministic (measured data/comm bytes of a fixed seeded
workload), so byte growth is a real regression, not noise; throughput
rows get the --max-regress tolerance for host jitter.

Understands every snapshot shape this repo emits:
  * ep_bench_matrix   — {"bench": "ep_bench_matrix", "runs": {name: run}}
  * ep_bench_pr5-style single runs with "baseline"/"indexed" sub-objects
  * ep_train          — the ep-train --json-out training snapshot
  * ep_serve          — the ep-serve --json-out serving snapshot

Every shape carries the shared `snapshot_version` stamp (the Rust CLI
writes it on every --json-out); the gate rejects snapshots without it
rather than guessing at pre-versioned key layouts.

A missing baseline file is a notice, not a failure — the gate becomes
blocking once the first snapshot is committed.

Usage:
    python tools/bench_gate.py --current BENCH_PR8.json --baseline BENCH_PR7.json
    python tools/bench_gate.py --self-test
"""
import argparse
import json
import pathlib
import sys

# The shared --json-out stamp (SNAPSHOT_VERSION in rust/src/main.rs).
SNAPSHOT_VERSION = 1


def check_version(snap, label):
    """Failure strings for a snapshot missing/mismatching the version."""
    v = snap.get("snapshot_version")
    if v is None:
        return [f"[{label}] snapshot has no snapshot_version — pre-versioned "
                "shape; regenerate it with the current CLI"]
    if int(v) != SNAPSHOT_VERSION:
        return [f"[{label}] snapshot_version {v} is not the supported "
                f"{SNAPSHOT_VERSION}"]
    return []


def extract_rows(snap):
    """Flatten any snapshot shape into (label, tokens_per_sec, peak_bytes)."""
    kind = snap.get("bench", "")
    if kind == "ep_bench_matrix":
        for name, run in sorted(snap.get("runs", {}).items()):
            for label, tps, peak in extract_rows(run):
                yield f"{name}/{label}", tps, peak
    elif kind == "ep_serve":
        yield ("serve", float(snap.get("tokens_per_sec", 0.0)),
               float(snap.get("peak_rank_data_bytes", 0.0)))
    elif kind == "ep_train":
        yield ("train", float(snap.get("tokens_per_sec", 0.0)),
               float(snap.get("peak_rank_data_bytes", 0.0)))
    else:
        # single ep-bench run: gate the shipping (indexed) path only —
        # the packed baseline row exists to be beaten, not preserved
        new = snap.get("indexed")
        if isinstance(new, dict):
            yield ("indexed", float(new.get("tokens_per_sec", 0.0)),
                   float(new.get("peak_rank_comm_bytes", 0.0)))


def compare(current, baseline, max_regress):
    """Return a list of failure strings (empty = gate passes)."""
    failures = (check_version(current, "current")
                + check_version(baseline, "baseline"))
    if failures:
        return failures
    cur = {label: (tps, peak) for label, tps, peak in extract_rows(current)}
    base = {label: (tps, peak) for label, tps, peak in extract_rows(baseline)}
    for label in sorted(set(cur) | set(base)):
        if label not in cur:
            failures.append(f"[{label}] present in baseline but missing from "
                            "the current snapshot (row dropped?)")
            continue
        if label not in base:
            print(f"bench_gate: [{label}] is new (no baseline row) — skipped")
            continue
        tps_c, peak_c = cur[label]
        tps_b, peak_b = base[label]
        if tps_b > 0 and tps_c < tps_b * (1.0 - max_regress):
            failures.append(
                f"[{label}] tokens/s regressed {tps_b:.0f} -> {tps_c:.0f} "
                f"({100.0 * (1.0 - tps_c / tps_b):.1f}% > "
                f"{100.0 * max_regress:.0f}% allowed)")
        elif tps_b > 0:
            print(f"bench_gate: [{label}] tokens/s {tps_b:.0f} -> {tps_c:.0f} ok")
        if peak_c > peak_b:
            failures.append(
                f"[{label}] peak bytes grew {peak_b:.0f} -> {peak_c:.0f} "
                "(any growth fails: measured bytes are deterministic)")
        else:
            print(f"bench_gate: [{label}] peak bytes {peak_b:.0f} -> "
                  f"{peak_c:.0f} ok")
    return failures


def self_test() -> int:
    base = {
        "bench": "ep_bench_matrix",
        "snapshot_version": 1,
        "runs": {
            "silu": {"bench": "ep_bench_pr5",
                     "snapshot_version": 1,
                     "indexed": {"tokens_per_sec": 1000.0,
                                 "peak_rank_comm_bytes": 4096}},
        },
    }
    serve_base = {"bench": "ep_serve", "snapshot_version": 1,
                  "tokens_per_sec": 500.0,
                  "peak_rank_data_bytes": 2048}
    train_base = {"bench": "ep_train", "snapshot_version": 1,
                  "tokens_per_sec": 900.0,
                  "peak_rank_data_bytes": 1024}

    checks = []
    # identical snapshots pass
    checks.append(("identical passes", compare(base, base, 0.10) == []))
    checks.append(("serve identical passes",
                   compare(serve_base, serve_base, 0.10) == []))
    # a 5% dip is inside the tolerance
    ok = json.loads(json.dumps(base))
    ok["runs"]["silu"]["indexed"]["tokens_per_sec"] = 950.0
    checks.append(("5% dip passes", compare(ok, base, 0.10) == []))
    # a 20% dip fails
    slow = json.loads(json.dumps(base))
    slow["runs"]["silu"]["indexed"]["tokens_per_sec"] = 800.0
    checks.append(("20% dip fails", compare(slow, base, 0.10) != []))
    # any byte growth fails, even 1 byte
    fat = json.loads(json.dumps(base))
    fat["runs"]["silu"]["indexed"]["peak_rank_comm_bytes"] = 4097
    checks.append(("byte growth fails", compare(fat, base, 0.10) != []))
    # serve regressions caught through the ep_serve shape
    slow_serve = dict(serve_base, tokens_per_sec=100.0)
    checks.append(("serve dip fails", compare(slow_serve, serve_base, 0.10) != []))
    fat_serve = dict(serve_base, peak_rank_data_bytes=4096)
    checks.append(("serve byte growth fails",
                   compare(fat_serve, serve_base, 0.10) != []))
    # new rows are a notice, dropped rows a failure
    grown = json.loads(json.dumps(base))
    grown["runs"]["swiglu"] = grown["runs"]["silu"]
    checks.append(("new row passes", compare(grown, base, 0.10) == []))
    checks.append(("dropped row fails", compare(base, grown, 0.10) != []))
    # training snapshots gate through the shared common keys
    checks.append(("train identical passes",
                   compare(train_base, train_base, 0.10) == []))
    slow_train = dict(train_base, tokens_per_sec=100.0)
    checks.append(("train dip fails",
                   compare(slow_train, train_base, 0.10) != []))
    # unversioned snapshots are rejected outright, on either side
    unversioned = {k: v for k, v in serve_base.items()
                   if k != "snapshot_version"}
    checks.append(("unversioned current fails",
                   compare(unversioned, serve_base, 0.10) != []))
    checks.append(("unversioned baseline fails",
                   compare(serve_base, unversioned, 0.10) != []))
    future = dict(serve_base, snapshot_version=99)
    checks.append(("unknown version fails",
                   compare(future, serve_base, 0.10) != []))

    failed = [name for name, passed in checks if not passed]
    for name, passed in checks:
        print(f"bench_gate self-test: {name}: {'ok' if passed else 'FAIL'}")
    if failed:
        print(f"bench_gate self-test: {len(failed)} check(s) failed",
              file=sys.stderr)
        return 1
    print(f"bench_gate self-test: all {len(checks)} checks passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--current", help="snapshot produced by this change")
    ap.add_argument("--baseline", help="previous committed snapshot")
    ap.add_argument("--max-regress", type=float, default=0.10,
                    help="allowed fractional tokens/s regression "
                         "(default 0.10)")
    ap.add_argument("--self-test", action="store_true",
                    help="run the built-in behavior checks and exit")
    args = ap.parse_args()

    if args.self_test:
        return self_test()
    if not args.current or not args.baseline:
        ap.error("--current and --baseline are required (or --self-test)")

    current_path = pathlib.Path(args.current)
    baseline_path = pathlib.Path(args.baseline)
    if not current_path.exists():
        print(f"bench_gate: current snapshot {current_path} missing",
              file=sys.stderr)
        return 1
    if not baseline_path.exists():
        print(f"bench_gate: no baseline at {baseline_path} — nothing to "
              "gate against yet (the gate blocks once a baseline is "
              "committed)")
        return 0

    current = json.loads(current_path.read_text())
    baseline = json.loads(baseline_path.read_text())
    failures = compare(current, baseline, args.max_regress)
    if failures:
        for f in failures:
            print(f"bench_gate: FAIL {f}", file=sys.stderr)
        return 1
    print("bench_gate: no regressions against "
          f"{baseline_path.name} \N{CHECK MARK}")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Python mirror of rust/src/dispatch/shard.rs + coordinator/engine.rs
to validate the algorithm (indexing, routes, packing, byte accounting),
plus — since ISSUE 2 — the step-session training semantics: grad-accum
microbatching with a shared accumulator, checkpoint-policy equivalence
(save-all / save-inputs / recompute-all), and SGD/Adam optimizer steps
decoupled from the backward pass. Run by CI as the cross-validation
gate; no Rust toolchain exists in the build container."""
import random
import numpy as np

def build(ids, l, e, k):
    # expert-major stable order (token-major scan per expert) — matches
    # both Rust builders
    per = [[] for _ in range(e)]
    for t in range(l):
        for j in range(k):
            per[ids[t*k+j]].append((t, t*k+j))
    offsets = [0]
    eti, origin_of_pos = [], []
    for ex in range(e):
        for (t, o) in per[ex]:
            eti.append(t); origin_of_pos.append(o)
        offsets.append(len(eti))
    tim = [0]*(l*k)
    for pos, o in enumerate(origin_of_pos):
        tim[o] = pos
    return dict(l=l, e=e, k=k, ids=ids, eti=eti, off=offsets, tim=tim)

def validate(d):
    l, e, k = d['l'], d['e'], d['k']
    n = l*k
    assert d['off'][0] == 0 and d['off'][e] == n
    assert sorted(d['tim']) == list(range(n))
    for i in range(l):
        for j in range(k):
            pos = d['tim'][i*k+j]
            assert d['eti'][pos] == i
            ex = d['ids'][i*k+j]
            assert d['off'][ex] <= pos < d['off'][ex+1]

def rank_of_expert(ex, E, R, strided):
    return ex % R if strided else ex // (E // R)

def rank_of_token(t, l, R):
    return min(t*R//l, R-1)

def shard(d, R, strided):
    l, e, k = d['l'], d['e'], d['k']
    inv = [0]*(l*k)
    for slot, pos in enumerate(d['tim']):
        inv[pos] = slot
    shards = []
    for r in range(R):
        experts = [x for x in range(e) if rank_of_expert(x, e, R, strided) == r]
        off = [0]; toks = []; orig = []
        for ex in experts:
            lo, hi = d['off'][ex], d['off'][ex+1]
            toks += d['eti'][lo:hi]
            orig += inv[lo:hi]
            off.append(len(toks))
        shards.append(dict(rank=r, experts=experts, off=off, toks=toks, orig=orig))
    return shards

def merge(shards, l, e, k):
    lengths = [None]*e
    for s in shards:
        for i, ex in enumerate(s['experts']):
            assert lengths[ex] is None
            lengths[ex] = s['off'][i+1]-s['off'][i]
    assert all(v is not None for v in lengths)
    off = [0]
    for x in lengths: off.append(off[-1]+x)
    n = l*k
    eti = [0]*n; ids = [0]*n; tim = [0]*n; seen = [False]*n
    for s in shards:
        for i, ex in enumerate(s['experts']):
            base = off[ex]
            for j in range(s['off'][i+1]-s['off'][i]):
                local = s['off'][i]+j
                pos = base+j
                o = s['orig'][local]
                assert not seen[o]; seen[o] = True
                eti[pos] = s['toks'][local]
                ids[o] = ex
                tim[o] = pos
    return dict(l=l, e=e, k=k, ids=ids, eti=eti, off=off, tim=tim)

def expert_fwd(W, x):
    # stand-in per-row expert fn: W[e] @ x (float32) — order-free per row
    return (W @ x).astype(np.float32)

def single_forward(d, W, x, gates, dm):
    l, e, k = d['l'], d['e'], d['k']
    n = l*k
    ys = np.zeros((n, dm), np.float32)
    for ex in range(e):
        for pos in range(d['off'][ex], d['off'][ex+1]):
            ys[pos] = expert_fwd(W[ex], x[d['eti'][pos]])
    out = np.zeros((l, dm), np.float32)
    for i in range(l):
        for j in range(k):
            pos = d['tim'][i*k+j]
            out[i] = out[i] + np.float32(gates[i*k+j]) * ys[pos]
    return out

def sharded_forward(d, W, x, gates, dm, R, strided):
    l, e, k = d['l'], d['e'], d['k']
    shards = shard(d, R, strided)
    routes = [[[] for _ in range(R)] for _ in range(R)]  # [dst][src]
    ret_lookup = [None]*(l*k)
    for dst, s in enumerate(shards):
        for ls, (tok, o) in enumerate(zip(s['toks'], s['orig'])):
            src = rank_of_token(tok, l, R)
            ret_lookup[o] = (dst, len(routes[dst][src]))
            routes[dst][src].append((ls, tok, o))
    # phase A: pack
    send = [[np.stack([x[t] for (_, t, _) in routes[dst][src]]) if routes[dst][src]
             else np.zeros((0, dm), np.float32)
             for dst in range(R)] for src in range(R)]
    dispatch_bytes = sum(send[s][t].size*4 for s in range(R) for t in range(R) if s != t)
    cross_rows = sum(len(routes[t][s]) for s in range(R) for t in range(R) if s != t)
    # phase B: unpack + compute + pack return
    rets = []
    for dst in range(R):
        s = shards[dst]
        nl = len(s['toks'])
        xs = np.zeros((nl, dm), np.float32)
        for src in range(R):
            for i, (ls, tok, o) in enumerate(routes[dst][src]):
                xs[ls] = send[src][dst][i]
        ys = np.zeros((nl, dm), np.float32)
        for i, ex in enumerate(s['experts']):
            for ls in range(s['off'][i], s['off'][i+1]):
                ys[ls] = expert_fwd(W[ex], xs[ls])
        rets.append([np.stack([ys[ls] for (ls, _, _) in routes[dst][src]]) if routes[dst][src]
                     else np.zeros((0, dm), np.float32) for src in range(R)])
    # phase C: combine on home ranks
    out = np.zeros((l, dm), np.float32)
    for home in range(R):
        for t in range(l):
            if rank_of_token(t, l, R) != home:
                continue
            for j in range(k):
                slot = t*k+j
                dst, idx = ret_lookup[slot]
                out[t] = out[t] + np.float32(gates[slot]) * rets[dst][home][idx]
    return out, dispatch_bytes, cross_rows

def plan_bytes(d, R, strided, dm):
    cross = 0
    for ex in range(d['e']):
        dst = rank_of_expert(ex, d['e'], R, strided)
        for pos in range(d['off'][ex], d['off'][ex+1]):
            if rank_of_token(d['eti'][pos], d['l'], R) != dst:
                cross += 1
    return cross*dm*4, cross

random.seed(0)
for case in range(300):
    R = random.choice([1, 2, 4, 8])
    e = R*random.randint(1, 4)
    l = random.randint(1, 80)
    k = random.randint(1, min(e, 3))
    strided = random.random() < 0.5
    if random.random() < 0.1:
        ids = [0]*(l*k)  # all-to-one (k must be 1 for distinctness)
        k = 1
        ids = [0]*l
    else:
        ids = []
        for _ in range(l):
            ids += random.sample(range(e), k)
    d = build(ids, l, e, k)
    validate(d)
    # shard/merge round trip
    m = merge(shard(d, R, strided), l, e, k)
    assert m == d, f"round-trip failed case {case}"
    # engine equivalence + measured bytes
    dm = 4
    rng = np.random.default_rng(case)
    W = rng.standard_normal((e, dm, dm)).astype(np.float32)
    x = rng.standard_normal((l, dm)).astype(np.float32)
    gates = rng.random(l*k).astype(np.float32)
    a = single_forward(d, W, x, gates, dm)
    b, measured, cross_rows = sharded_forward(d, W, x, gates, dm, R, strided)
    assert a.tobytes() == b.tobytes(), f"bit mismatch case {case} R={R}"
    pb, pc = plan_bytes(d, R, strided, dm)
    assert measured == pb and cross_rows == pc, \
        f"bytes case {case}: measured {measured} vs plan {pb}"
print("300 fuzz cases OK: round-trip exact, outputs bit-identical, measured == planned bytes")

# ===========================================================================
# Step-session training parity (mirror of coordinator/engine.rs +
# trainer.rs + optim.rs after the ISSUE-2 redesign).
#
# Mirrored invariants, each asserted bitwise on the loss curve:
#   * grad_accum ∈ {1, 2, 4}: contiguous token-range microbatches,
#     gradients accumulated into ONE shared accumulator in expert-segment
#     order, loss accumulated into ONE running f64 — identical float-op
#     sequence to the unsplit batch;
#   * checkpoint policy ∈ {save-all, save-inputs, recompute-all}: saved
#     vs recomputed hidden activations / re-gathered inputs;
#   * optimizer ∈ {sgd, adam}: update computed from accumulated grads as
#     an additive delta, applied once per global step.
# ===========================================================================

f32 = np.float32

def silu32(a):
    return (a / (1 + np.exp(-a))).astype(f32)

def ffn_fwd(p, x, want_hidden):
    pre = (p['w1'] @ x + p['b1']).astype(f32)
    act = silu32(pre)
    y = (p['w2'] @ act + p['b2']).astype(f32)
    return (y, pre, act) if want_hidden else (y, None, None)

def ffn_bwd_row(p, g, x, dy, pre, act):
    # mirrors expert_backward_row in engine.rs
    g['b2'] += dy
    g['w2'] += np.outer(dy, act).astype(f32)
    dz = (p['w2'].T @ dy).astype(f32)
    sig = (1 / (1 + np.exp(-pre))).astype(f32)
    da = (dz * sig * (1 + pre * (1 - sig))).astype(f32)
    g['b1'] += da
    g['w1'] += np.outer(da, x).astype(f32)

def zeros_like_params(d, h):
    return dict(w1=np.zeros((h, d), f32), b1=np.zeros(h, f32),
                w2=np.zeros((d, h), f32), b2=np.zeros(d, f32))

def init_experts(E, d, h, rng):
    return [dict(w1=rng.standard_normal((h, d)).astype(f32) * f32(d ** -0.5),
                 b1=np.zeros(h, f32),
                 w2=rng.standard_normal((d, h)).astype(f32) * f32(h ** -0.5),
                 b2=np.zeros(d, f32)) for _ in range(E)]

def session_fwd_bwd(d_sub, params, x_sub, gates_sub, target, t0, scale,
                    grads, policy, loss):
    """One microbatch step session: forward, running-loss continuation,
    backward into the SHARED `grads` accumulator. Mirrors the single-rank
    engine row-for-row (the sharded engine is bit-identical to it by the
    fuzz suite above + segment-order accumulation)."""
    l, e, k, dm = d_sub['l'], d_sub['e'], d_sub['k'], x_sub.shape[1]
    n = l * k
    ys = np.zeros((n, dm), f32)
    save_hidden = policy == 'save-all'
    save_inputs = policy != 'recompute-all'
    xs = np.zeros((n, dm), f32) if save_inputs else None
    pre_s = np.zeros((n, params[0]['b1'].size), f32) if save_hidden else None
    act_s = np.zeros((n, params[0]['b1'].size), f32) if save_hidden else None
    for ex in range(e):
        for pos in range(d_sub['off'][ex], d_sub['off'][ex + 1]):
            xin = x_sub[d_sub['eti'][pos]]
            if save_inputs:
                xs[pos] = xin
            y, pre, act = ffn_fwd(params[ex], xin, save_hidden)
            if save_hidden:
                pre_s[pos], act_s[pos] = pre, act
            ys[pos] = y
    out = np.zeros((l, dm), f32)
    for i in range(l):
        for j in range(k):
            pos = d_sub['tim'][i * k + j]
            out[i] = out[i] + np.float32(gates_sub[i * k + j]) * ys[pos]
    # loss + d_out, continuing the running f64 accumulator in token order
    d_out = np.zeros((l, dm), f32)
    for i in range(l):
        for c in range(dm):
            diff = f32(out[i, c] - target[t0 + i, c])
            loss += float(diff) * float(diff)
            d_out[i, c] = scale * diff
    # backward, expert-major segment order, into the shared accumulator
    origin = [0] * n
    for slot, pos in enumerate(d_sub['tim']):
        origin[pos] = slot
    for ex in range(e):
        for pos in range(d_sub['off'][ex], d_sub['off'][ex + 1]):
            tok = d_sub['eti'][pos]
            dy = (np.float32(gates_sub[origin[pos]]) * d_out[tok]).astype(f32)
            xin = xs[pos] if save_inputs else x_sub[tok]
            if save_hidden:
                pre, act = pre_s[pos], act_s[pos]
            else:
                pre = (params[ex]['w1'] @ xin + params[ex]['b1']).astype(f32)
                act = silu32(pre)
            ffn_bwd_row(params[ex], grads[ex], xin, dy, pre, act)
    return loss

def sgd_delta(grads, lr):
    return [{k: (-(f32(lr) * g[k])).astype(f32) for k in g} for g in grads]

def adam_step(state, grads, lr):
    b1, b2, eps = f32(0.9), f32(0.999), f32(1e-8)
    state['t'] += 1
    bc1 = f32(1) - b1 ** f32(state['t'])
    bc2 = f32(1) - b2 ** f32(state['t'])
    delta = []
    for ex, g in enumerate(grads):
        de = {}
        for k in g:
            m = (b1 * state['m'][ex][k] + (f32(1) - b1) * g[k]).astype(f32)
            v = (b2 * state['v'][ex][k] + (f32(1) - b2) * g[k] * g[k]).astype(f32)
            state['m'][ex][k], state['v'][ex][k] = m, v
            mhat = (m / bc1).astype(f32)
            vhat = (v / bc2).astype(f32)
            de[k] = (-(f32(lr) * mhat / (np.sqrt(vhat) + eps))).astype(f32)
        delta.append(de)
    return delta

def train(L, E, K, DM, H, steps, accum, policy, opt, lr, seed):
    rng = np.random.default_rng(seed)
    params = init_experts(E, DM, H, rng)
    ids = np.concatenate([rng.choice(E, K, replace=False)
                          for _ in range(L)]).astype(int)
    gates = rng.random(L * K).astype(f32)
    x = rng.standard_normal((L, DM)).astype(f32)
    target = rng.standard_normal((L, DM)).astype(f32)
    # microbatches built once, before the loop (contiguous token ranges)
    bounds = [L * i // accum for i in range(accum + 1)]
    micros = []
    for m in range(accum):
        t0, t1 = bounds[m], bounds[m + 1]
        sub_ids = list(ids[t0 * K:t1 * K])
        d_sub = build(sub_ids, t1 - t0, E, K)
        micros.append((t0, d_sub, x[t0:t1], gates[t0 * K:t1 * K]))
    adam_state = dict(t=0, m=[zeros_like_params(DM, H) for _ in range(E)],
                      v=[zeros_like_params(DM, H) for _ in range(E)])
    scale = f32(2.0 / (L * DM))
    losses = []
    for _ in range(steps):
        grads = [zeros_like_params(DM, H) for _ in range(E)]
        loss = 0.0
        for (t0, d_sub, x_sub, gates_sub) in micros:
            loss = session_fwd_bwd(d_sub, params, x_sub, gates_sub, target,
                                   t0, scale, grads, policy, loss)
        losses.append(loss / (L * DM))
        delta = adam_step(adam_state, grads, lr) if opt == 'adam' \
            else sgd_delta(grads, lr)
        for ex in range(E):
            for k in params[ex]:
                params[ex][k] = (params[ex][k] + delta[ex][k]).astype(f32)
    return losses

L, E, K, DM, H, STEPS = 24, 4, 2, 6, 10, 3
for opt, lr in [('sgd', 0.05), ('adam', 0.01)]:
    ref = train(L, E, K, DM, H, STEPS, 1, 'save-inputs', opt, lr, 123)
    assert ref[-1] < ref[0], f"{opt}: no learning: {ref}"
    for accum in [1, 2, 4]:
        for policy in ['save-all', 'save-inputs', 'recompute-all']:
            got = train(L, E, K, DM, H, STEPS, accum, policy, opt, lr, 123)
            assert got == ref, \
                f"{opt} accum={accum} {policy}: loss curve diverged\n{got}\n{ref}"
print("step-session parity OK: loss curves bit-identical across "
      "grad_accum x checkpoint policy, for sgd and adam")

# ===========================================================================
# Chunked-pipeline parity (mirror of coordinator/pipeline, ISSUE 3).
#
# The pipelined engine splits a batch into K token-contiguous chunks and
# streams them through the exchange. Two load-bearing contracts mirrored
# here, both asserted BITWISE and fuzzed over K x R x policy:
#   * token residency stays in GLOBAL coordinates
#     (rank_of_token(t0 + local_t, L)), so the summed per-chunk cross
#     bytes equal the whole-batch analytic plan exactly;
#   * chunks accumulate gradients in ascending token order, which is the
#     unchunked float-op sequence — outputs AND grads bit-identical to
#     the single-rank reference for every checkpoint policy.
# ===========================================================================

def single_fwd_bwd_ffn(d, params, x, gates, dm, policy, d_out, grads):
    """Unchunked single-rank reference (full FFN experts): forward
    combine output + backward accumulation into `grads`."""
    l, e, k = d['l'], d['e'], d['k']
    n = l * k
    save_hidden = policy == 'save-all'
    save_inputs = policy != 'recompute-all'
    hdim = params[0]['b1'].size
    ys = np.zeros((n, dm), f32)
    xs = np.zeros((n, dm), f32) if save_inputs else None
    pre_s = np.zeros((n, hdim), f32) if save_hidden else None
    act_s = np.zeros((n, hdim), f32) if save_hidden else None
    for ex in range(e):
        for pos in range(d['off'][ex], d['off'][ex + 1]):
            xin = x[d['eti'][pos]]
            if save_inputs:
                xs[pos] = xin
            y, pre, act = ffn_fwd(params[ex], xin, save_hidden)
            if save_hidden:
                pre_s[pos], act_s[pos] = pre, act
            ys[pos] = y
    out = np.zeros((l, dm), f32)
    for i in range(l):
        for j in range(k):
            pos = d['tim'][i * k + j]
            out[i] = out[i] + np.float32(gates[i * k + j]) * ys[pos]
    origin = [0] * n
    for slot, pos in enumerate(d['tim']):
        origin[pos] = slot
    for ex in range(e):
        for pos in range(d['off'][ex], d['off'][ex + 1]):
            tok = d['eti'][pos]
            dy = (np.float32(gates[origin[pos]]) * d_out[tok]).astype(f32)
            xin = xs[pos] if save_inputs else x[tok]
            if save_hidden:
                pre, act = pre_s[pos], act_s[pos]
            else:
                pre = (params[ex]['w1'] @ xin + params[ex]['b1']).astype(f32)
                act = silu32(pre)
            ffn_bwd_row(params[ex], grads[ex], xin, dy, pre, act)
    return out

def pipelined_fwd_bwd(ids, L, E, K_top, params, x, gates, dm, R, strided,
                      chunks, policy, d_out, grads):
    """Chunk-pipelined sharded mirror: global token residency, per-chunk
    exchange/compute/combine, backward accumulated in ascending chunk
    order. Returns (out, summed cross-rank dispatch bytes)."""
    kc = min(chunks, L)
    bounds = [L * i // kc for i in range(kc + 1)]
    out = np.zeros((L, dm), f32)
    dispatch_bytes = 0
    chunk_state = []
    for m in range(kc):
        t0, t1 = bounds[m], bounds[m + 1]
        lm = t1 - t0
        dsub = build(list(ids[t0 * K_top:t1 * K_top]), lm, E, K_top)
        shards = shard(dsub, R, strided)
        routes = [[[] for _ in range(R)] for _ in range(R)]
        ret_lookup = [None] * (lm * K_top)
        for dst, s in enumerate(shards):
            for ls, (tok, o) in enumerate(zip(s['toks'], s['orig'])):
                src = rank_of_token(t0 + tok, L, R)  # global residency
                ret_lookup[o] = (dst, len(routes[dst][src]))
                routes[dst][src].append((ls, tok, o))
        dispatch_bytes += sum(len(routes[dst][src]) * dm * 4
                              for dst in range(R) for src in range(R)
                              if src != dst)
        # per-rank expert compute (saved state mirrors the policy);
        # activations/gates always come from the PARENT arrays with the
        # chunk's token offset — the engine caches no payload copies
        saved = []
        ys_of = []
        for dst in range(R):
            s = shards[dst]
            nl = len(s['toks'])
            xs = np.zeros((nl, dm), f32)
            for src in range(R):
                for i, (ls, tok, o) in enumerate(routes[dst][src]):
                    xs[ls] = x[t0 + tok]
            hdim = params[0]['b1'].size
            ys = np.zeros((nl, dm), f32)
            pre_s = np.zeros((nl, hdim), f32) if policy == 'save-all' else None
            act_s = np.zeros((nl, hdim), f32) if policy == 'save-all' else None
            for i, ex in enumerate(s['experts']):
                for ls in range(s['off'][i], s['off'][i + 1]):
                    y, pre, act = ffn_fwd(params[ex], xs[ls],
                                          policy == 'save-all')
                    if policy == 'save-all':
                        pre_s[ls], act_s[ls] = pre, act
                    ys[ls] = y
            ys_of.append(ys)
            if policy == 'recompute-all':
                saved.append((None, None))
            elif policy == 'save-all':
                saved.append((xs, (pre_s, act_s)))
            else:
                saved.append((xs, None))
        # combine on home ranks (global residency), ascending j order
        for t in range(lm):
            home = rank_of_token(t0 + t, L, R)
            for j in range(K_top):
                slot = t * K_top + j
                dst, idx = ret_lookup[slot]
                ls, tok, o = routes[dst][home][idx]
                g = np.float32(gates[(t0 + t) * K_top + j])
                out[t0 + t] = out[t0 + t] + g * ys_of[dst][ls]
        chunk_state.append((t0, shards, routes, saved))
    # backward: chunks in ascending order, each rank's experts in
    # segment order — the unchunked op sequence
    for (t0, shards, routes, saved) in chunk_state:
        gate_base = t0 * K_top
        for dst in range(R):
            s = shards[dst]
            nl = len(s['toks'])
            dys = np.zeros((nl, dm), f32)
            for src in range(R):
                for i, (ls, tok, o) in enumerate(routes[dst][src]):
                    dys[ls] = (np.float32(gates[gate_base + o])
                               * d_out[t0 + tok]).astype(f32)
            xs_rank, hidden_rank = saved[dst]
            for i, ex in enumerate(s['experts']):
                for ls in range(s['off'][i], s['off'][i + 1]):
                    # recompute-all: re-gather the routed input (the
                    # backward re-run of the dispatch exchange)
                    xin = xs_rank[ls] if xs_rank is not None \
                        else x[t0 + s['toks'][ls]]
                    if hidden_rank is not None:
                        pre, act = hidden_rank[0][ls], hidden_rank[1][ls]
                    else:
                        pre = (params[ex]['w1'] @ xin
                               + params[ex]['b1']).astype(f32)
                        act = silu32(pre)
                    ffn_bwd_row(params[ex], grads[ex], xin, dys[ls], pre, act)
    return out, dispatch_bytes

def grads_bytes(grads):
    return b''.join(g[kk].tobytes() for g in grads for kk in ('w1', 'b1', 'w2', 'b2'))

# ===========================================================================
# Index-driven (zero-materialization) dispatch parity — mirror of the
# ISSUE-5 redesign in dispatch/structures.rs (RowIndexPlan) +
# coordinator/kernels.rs + the rewritten engines.
#
# Mirrored contracts, asserted BITWISE and fuzzed over R x tile x policy:
#   * expert compute gathers routed rows DIRECTLY from the caller's x via
#     the per-(rank, expert) token-index lists — no send buffer, no
#     per-rank unpack buffer, no return buffer — processing each expert
#     segment in tiles of T rows whose row order equals the packed walk;
#   * the combine scatter reads each expert-output row in place through
#     an (origin slot -> (rank, local slot)) lookup;
#   * backward gathers gated gradient rows (gate * d_out[token]) per tile
#     and, under recompute-all, re-gathers routed inputs by INDEX;
#   * dispatch bytes are DERIVED from the plan's src->dst row counts and
#     must equal both the analytic whole-batch plan and a simulated
#     packing of the old buffers.
# Outputs and grads must match the row-by-row reference bit-for-bit for
# every tile size (tile boundaries never cross a row's op order).
# ===========================================================================

def row_index_plan(d, R, strided):
    """Per-rank (experts, offsets, tokens, gate_slots, src_rank) + the
    src->dst row-count matrix — the RowIndexPlan mirror."""
    l, e, k = d['l'], d['e'], d['k']
    origin = [0] * (l * k)
    for slot, pos in enumerate(d['tim']):
        origin[pos] = slot
    per_rank = []
    rows_between = [[0] * R for _ in range(R)]
    for r in range(R):
        experts = [x for x in range(e) if rank_of_expert(x, e, R, strided) == r]
        off = [0]
        toks, gslots, srcs = [], [], []
        for ex in experts:
            for pos in range(d['off'][ex], d['off'][ex + 1]):
                tok = d['eti'][pos]
                toks.append(tok)
                gslots.append(origin[pos])
                src = rank_of_token(tok, l, R)
                srcs.append(src)
                rows_between[src][r] += 1
            off.append(len(toks))
        per_rank.append(dict(experts=experts, off=off, toks=toks,
                             gslots=gslots, srcs=srcs))
    return per_rank, rows_between

def indexed_blocked_fwd_bwd(d, params, x, gates, dm, R, strided, tile,
                            policy, d_out, grads):
    """Zero-materialization sharded step: gather-by-index in tiles of
    `tile` rows, combine in place, backward without a gradient exchange
    buffer. Returns (out, derived dispatch bytes)."""
    l, k = d['l'], d['k']
    per_rank, rows_between = row_index_plan(d, R, strided)
    dispatch_bytes = sum(rows_between[s][t] * dm * 4
                         for s in range(R) for t in range(R) if s != t)
    # forward: per rank, per expert segment, tiles of `tile` rows
    ys_of, saved = [], []
    ret_lookup = [None] * (l * k)
    for r in range(R):
        rr = per_rank[r]
        nl = len(rr['toks'])
        for ls, o in enumerate(rr['gslots']):
            ret_lookup[o] = (r, ls)
        ys = np.zeros((nl, dm), f32)
        xs = np.zeros((nl, dm), f32) if policy != 'recompute-all' else None
        hdim = params[0]['b1'].size
        pre_s = np.zeros((nl, hdim), f32) if policy == 'save-all' else None
        act_s = np.zeros((nl, hdim), f32) if policy == 'save-all' else None
        for i, ex in enumerate(rr['experts']):
            lo, hi = rr['off'][i], rr['off'][i + 1]
            t0 = lo
            while t0 < hi:
                rows = min(tile, hi - t0)
                for rrow in range(rows):
                    ls = t0 + rrow
                    xin = x[rr['toks'][ls]]  # gathered straight from x
                    if xs is not None:
                        xs[ls] = xin
                    y, pre, act = ffn_fwd(params[ex], xin,
                                          policy == 'save-all')
                    if policy == 'save-all':
                        pre_s[ls], act_s[ls] = pre, act
                    ys[ls] = y
                t0 += rows
        ys_of.append(ys)
        saved.append((xs, (pre_s, act_s) if policy == 'save-all' else None))
    # combine: read expert outputs in place via the return lookup
    out = np.zeros((l, dm), f32)
    for home in range(R):
        for t in range(l):
            if rank_of_token(t, l, R) != home:
                continue
            for j in range(k):
                r, ls = ret_lookup[t * k + j]
                out[t] = out[t] + np.float32(gates[t * k + j]) * ys_of[r][ls]
    # backward: gated dy rows gathered per tile, inputs from the saved
    # rows or (recompute-all) re-gathered by index
    for r in range(R):
        rr = per_rank[r]
        xs, hidden = saved[r]
        for i, ex in enumerate(rr['experts']):
            lo, hi = rr['off'][i], rr['off'][i + 1]
            t0 = lo
            while t0 < hi:
                rows = min(tile, hi - t0)
                for rrow in range(rows):
                    ls = t0 + rrow
                    tok = rr['toks'][ls]
                    dy = (np.float32(gates[rr['gslots'][ls]])
                          * d_out[tok]).astype(f32)
                    xin = xs[ls] if xs is not None else x[tok]
                    if hidden is not None:
                        pre, act = hidden[0][ls], hidden[1][ls]
                    else:
                        pre = (params[ex]['w1'] @ xin
                               + params[ex]['b1']).astype(f32)
                        act = silu32(pre)
                    ffn_bwd_row(params[ex], grads[ex], xin, dy, pre, act)
                t0 += rows
    return out, dispatch_bytes

random.seed(5)
idx_cases = 0
for case in range(40):
    R = random.choice([1, 2, 4])
    E = R * random.randint(1, 3)
    L = random.randint(4, 48)
    K_top = random.randint(1, min(E, 3))
    DM, H2 = 5, 7
    tile = random.choice([1, 2, 3, 8, 64])
    strided = random.random() < 0.5
    policy = random.choice(['save-all', 'save-inputs', 'recompute-all'])
    rng = np.random.default_rng(6000 + case)
    ids = np.concatenate([rng.choice(E, K_top, replace=False)
                          for _ in range(L)]).astype(int)
    params = init_experts(E, DM, H2, rng)
    x = rng.standard_normal((L, DM)).astype(f32)
    gates = rng.random(L * K_top).astype(f32)
    d_out = rng.standard_normal((L, DM)).astype(f32)
    d_full = build(list(ids), L, E, K_top)
    ref_grads = [zeros_like_params(DM, H2) for _ in range(E)]
    ref_out = single_fwd_bwd_ffn(d_full, params, x, gates, DM, policy,
                                 d_out, ref_grads)
    got_grads = [zeros_like_params(DM, H2) for _ in range(E)]
    got_out, derived = indexed_blocked_fwd_bwd(d_full, params, x, gates, DM,
                                               R, strided, tile, policy,
                                               d_out, got_grads)
    assert ref_out.tobytes() == got_out.tobytes(), \
        f"indexed case {case}: outputs diverged (R={R} tile={tile} {policy})"
    assert grads_bytes(ref_grads) == grads_bytes(got_grads), \
        f"indexed case {case}: grads diverged (R={R} tile={tile} {policy})"
    pb, _ = plan_bytes(d_full, R, strided, DM)
    assert derived == pb, \
        f"indexed case {case}: derived bytes {derived} != plan {pb}"
    # the derived bytes also round-trip a simulated packing of the old
    # send buffers, buffer by buffer
    per_rank, rows_between = row_index_plan(d_full, R, strided)
    packed = [[0] * R for _ in range(R)]
    for dst in range(R):
        for src in per_rank[dst]['srcs']:
            packed[src][dst] += 1
    assert packed == rows_between, f"indexed case {case}: packing mismatch"
    idx_cases += 1
print(f"index-driven parity OK: {idx_cases} fuzz cases, gather-by-index + "
      "tiled segments bit-identical to the packed reference across "
      "R x tile x policy, derived bytes == plan == simulated packing")

random.seed(3)
cases = 0
for case in range(48):
    R = random.choice([1, 2, 4])
    E = R * random.randint(1, 3)
    L = random.randint(4, 40)
    K_top = random.randint(1, min(E, 3))
    DM, H2 = 5, 7
    chunks = random.choice([1, 2, 3, 4])
    strided = random.random() < 0.5
    policy = random.choice(['save-all', 'save-inputs', 'recompute-all'])
    rng = np.random.default_rng(4000 + case)
    ids = np.concatenate([rng.choice(E, K_top, replace=False)
                          for _ in range(L)]).astype(int)
    params = init_experts(E, DM, H2, rng)
    x = rng.standard_normal((L, DM)).astype(f32)
    gates = rng.random(L * K_top).astype(f32)
    d_out = rng.standard_normal((L, DM)).astype(f32)

    d_full = build(list(ids), L, E, K_top)
    ref_grads = [zeros_like_params(DM, H2) for _ in range(E)]
    ref_out = single_fwd_bwd_ffn(d_full, params, x, gates, DM, policy,
                                 d_out, ref_grads)
    pipe_grads = [zeros_like_params(DM, H2) for _ in range(E)]
    pipe_out, measured = pipelined_fwd_bwd(ids, L, E, K_top, params, x, gates,
                                           DM, R, strided, chunks, policy,
                                           d_out, pipe_grads)
    assert ref_out.tobytes() == pipe_out.tobytes(), \
        f"pipeline case {case}: outputs diverged (R={R} K={chunks} {policy})"
    assert grads_bytes(ref_grads) == grads_bytes(pipe_grads), \
        f"pipeline case {case}: grads diverged (R={R} K={chunks} {policy})"
    pb, _ = plan_bytes(d_full, R, strided, DM)
    assert measured == pb, \
        f"pipeline case {case}: chunked bytes {measured} != whole-batch plan {pb}"
    cases += 1
print(f"chunked-pipeline parity OK: {cases} fuzz cases, outputs + grads "
      "bit-identical across K x R x policy, chunk bytes == whole-batch plan")

# ===========================================================================
# Multi-layer stack parity (mirror of coordinator/stack + the backward
# d_x chaining that makes it possible, ISSUE 4).
#
# Mirrored contracts, asserted BITWISE and fuzzed over
# L_layers x R x K x per-layer policy vectors:
#   * every engine folds per-slot dx rows into d_x in global
#     expert-major position order (per chunk, chunks ascending), so
#     d_x — and therefore the whole stacked loss curve — is identical
#     between the single-rank reference chain and the chunk-pipelined
#     sharded chain;
#   * an L-layer stack is exactly L sequential single-layer sessions:
#     forward chains outputs into the next layer's routing, backward
#     walks layers in reverse handing d_x down;
#   * parameter grads are bit-identical whether or not d_x is requested
#     (the dx ops touch separate memory).
# ===========================================================================

def ffn_bwd_row_dx(p, g, x, dy, pre, act):
    # ffn_bwd_row plus the input gradient dx = W1^T @ da
    g['b2'] += dy
    g['w2'] += np.outer(dy, act).astype(f32)
    dz = (p['w2'].T @ dy).astype(f32)
    sig = (1 / (1 + np.exp(-pre))).astype(f32)
    da = (dz * sig * (1 + pre * (1 - sig))).astype(f32)
    g['b1'] += da
    g['w1'] += np.outer(da, x).astype(f32)
    return (p['w1'].T @ da).astype(f32)

def single_fwd_bwd_dx(d, params, x, gates, dm, policy, d_out, grads):
    """Single-rank reference with input gradients: returns (out, d_x).
    d_x rows are folded home in global expert-major position order —
    the one order every engine shares."""
    l, e, k = d['l'], d['e'], d['k']
    n = l * k
    hdim = params[0]['b1'].size
    save_hidden = policy == 'save-all'
    ys = np.zeros((n, dm), f32)
    pre_s = np.zeros((n, hdim), f32)
    act_s = np.zeros((n, hdim), f32)
    for ex in range(e):
        for pos in range(d['off'][ex], d['off'][ex + 1]):
            y, pre, act = ffn_fwd(params[ex], x[d['eti'][pos]], True)
            pre_s[pos], act_s[pos] = pre, act
            ys[pos] = y
    out = np.zeros((l, dm), f32)
    for i in range(l):
        for j in range(k):
            pos = d['tim'][i * k + j]
            out[i] = out[i] + np.float32(gates[i * k + j]) * ys[pos]
    origin = [0] * n
    for slot, pos in enumerate(d['tim']):
        origin[pos] = slot
    dxs = np.zeros((n, dm), f32)
    for ex in range(e):
        for pos in range(d['off'][ex], d['off'][ex + 1]):
            tok = d['eti'][pos]
            dy = (np.float32(gates[origin[pos]]) * d_out[tok]).astype(f32)
            xin = x[tok]
            if save_hidden:
                pre, act = pre_s[pos], act_s[pos]
            else:
                pre = (params[ex]['w1'] @ xin + params[ex]['b1']).astype(f32)
                act = silu32(pre)
            dxs[pos] = ffn_bwd_row_dx(params[ex], grads[ex], xin, dy, pre, act)
    d_x = np.zeros((l, dm), f32)
    for pos in range(n):
        d_x[d['eti'][pos]] = d_x[d['eti'][pos]] + dxs[pos]
    return out, d_x

def pipelined_fwd_bwd_dx(ids, L, E, K_top, params, x, gates, dm, R, strided,
                         chunks, policy, d_out, grads):
    """Chunk-pipelined sharded mirror with input gradients: per chunk,
    per-rank dx rows are mapped back to the chunk's expert-major global
    positions and folded in ascending position order, chunks ascending —
    the exact Rust fold_dx order."""
    kc = min(chunks, L)
    bounds = [L * i // kc for i in range(kc + 1)]
    out = np.zeros((L, dm), f32)
    d_x = np.zeros((L, dm), f32)
    chunk_state = []
    for m in range(kc):
        t0, t1 = bounds[m], bounds[m + 1]
        lm = t1 - t0
        dsub = build(list(ids[t0 * K_top:t1 * K_top]), lm, E, K_top)
        shards = shard(dsub, R, strided)
        routes = [[[] for _ in range(R)] for _ in range(R)]
        ret_lookup = [None] * (lm * K_top)
        for dst, s in enumerate(shards):
            for ls, (tok, o) in enumerate(zip(s['toks'], s['orig'])):
                src = rank_of_token(t0 + tok, L, R)
                ret_lookup[o] = (dst, len(routes[dst][src]))
                routes[dst][src].append((ls, tok, o))
        ys_of = []
        for dst in range(R):
            s = shards[dst]
            nl = len(s['toks'])
            xs = np.zeros((nl, dm), f32)
            for src in range(R):
                for i, (ls, tok, o) in enumerate(routes[dst][src]):
                    xs[ls] = x[t0 + tok]
            ys = np.zeros((nl, dm), f32)
            for i, ex in enumerate(s['experts']):
                for ls in range(s['off'][i], s['off'][i + 1]):
                    y, _, _ = ffn_fwd(params[ex], xs[ls], False)
                    ys[ls] = y
            ys_of.append(ys)
        for t in range(lm):
            home = rank_of_token(t0 + t, L, R)
            for j in range(K_top):
                slot = t * K_top + j
                dst, idx = ret_lookup[slot]
                ls, tok, o = routes[dst][home][idx]
                g = np.float32(gates[(t0 + t) * K_top + j])
                out[t0 + t] = out[t0 + t] + g * ys_of[dst][ls]
        chunk_state.append((t0, dsub, shards, routes))
    for (t0, dsub, shards, routes) in chunk_state:
        gate_base = t0 * dsub['k']
        n = len(dsub['eti'])
        dxs = np.zeros((n, dm), f32)
        for dst in range(R):
            s = shards[dst]
            for i, ex in enumerate(s['experts']):
                base = dsub['off'][ex]
                for jj in range(s['off'][i + 1] - s['off'][i]):
                    ls = s['off'][i] + jj
                    tok = s['toks'][ls]
                    o = s['orig'][ls]
                    dy = (np.float32(gates[gate_base + o])
                          * d_out[t0 + tok]).astype(f32)
                    xin = x[t0 + tok]
                    pre = (params[ex]['w1'] @ xin
                           + params[ex]['b1']).astype(f32)
                    act = silu32(pre)
                    dxs[base + jj] = ffn_bwd_row_dx(params[ex], grads[ex], xin,
                                                    dy, pre, act)
        for pos in range(n):
            t = t0 + dsub['eti'][pos]
            d_x[t] = d_x[t] + dxs[pos]
    return out, d_x

def train_stack(layer_ids, Ltok, E, K_top, DM, H, steps, policies, lr, seed,
                R=1, strided=False, chunks=0):
    """Stacked training loop: forward chains layer outputs, backward
    chains d_x top-down, SGD per layer. chunks == 0 runs the single-rank
    reference chain; chunks > 0 the chunk-pipelined sharded chain.
    Returns the loss curve."""
    n_layers = len(layer_ids)
    rng = np.random.default_rng(seed)
    params = [init_experts(E, DM, H, rng) for _ in range(n_layers)]
    layer_gates = [rng.random(Ltok * K_top).astype(f32) for _ in range(n_layers)]
    x0 = rng.standard_normal((Ltok, DM)).astype(f32)
    target = rng.standard_normal((Ltok, DM)).astype(f32)
    dsubs = [build(list(layer_ids[l]), Ltok, E, K_top) for l in range(n_layers)]
    scale = f32(2.0 / (Ltok * DM))
    losses = []
    for _ in range(steps):
        grads = [[zeros_like_params(DM, H) for _ in range(E)]
                 for _ in range(n_layers)]
        # forward chain (outputs recomputed inside the bwd helpers —
        # bit-identical, pure functions)
        xs = [x0]
        for l in range(n_layers):
            if chunks == 0:
                probe = [zeros_like_params(DM, H) for _ in range(E)]
                o, _ = single_fwd_bwd_dx(dsubs[l], params[l], xs[l],
                                         layer_gates[l], DM, policies[l],
                                         np.zeros((Ltok, DM), f32), probe)
            else:
                probe = [zeros_like_params(DM, H) for _ in range(E)]
                o, _ = pipelined_fwd_bwd_dx(layer_ids[l], Ltok, E, K_top,
                                            params[l], xs[l], layer_gates[l],
                                            DM, R, strided, chunks,
                                            policies[l],
                                            np.zeros((Ltok, DM), f32), probe)
            xs.append(o)
        loss = 0.0
        d_out = np.zeros((Ltok, DM), f32)
        final = xs[-1]
        for i in range(Ltok):
            for c in range(DM):
                diff = f32(final[i, c] - target[i, c])
                loss += float(diff) * float(diff)
                d_out[i, c] = scale * diff
        losses.append(loss / (Ltok * DM))
        # backward chain, top layer first
        d_cur = d_out
        for l in reversed(range(n_layers)):
            if chunks == 0:
                _, d_cur = single_fwd_bwd_dx(dsubs[l], params[l], xs[l],
                                             layer_gates[l], DM, policies[l],
                                             d_cur, grads[l])
            else:
                _, d_cur = pipelined_fwd_bwd_dx(layer_ids[l], Ltok, E, K_top,
                                                params[l], xs[l],
                                                layer_gates[l], DM, R, strided,
                                                chunks, policies[l], d_cur,
                                                grads[l])
        for l in range(n_layers):
            delta = sgd_delta(grads[l], lr)
            for ex in range(E):
                for kk in params[l][ex]:
                    params[l][ex][kk] = (params[l][ex][kk]
                                         + delta[ex][kk]).astype(f32)
    return losses

random.seed(7)
stack_cases = 0
for case in range(24):
    R = random.choice([1, 2, 4])
    E = R * random.randint(1, 2)
    Ltok = random.randint(8, 28)
    K_top = random.randint(1, min(E, 2))
    DM, H3 = 4, 6
    n_layers = random.randint(1, 3)
    chunks = random.choice([1, 2, 3])
    strided = random.random() < 0.5
    policies = [random.choice(['save-all', 'save-inputs', 'recompute-all'])
                for _ in range(n_layers)]
    rng = np.random.default_rng(9000 + case)
    layer_ids = [np.concatenate([rng.choice(E, K_top, replace=False)
                                 for _ in range(Ltok)]).astype(int)
                 for _ in range(n_layers)]
    ref = train_stack(layer_ids, Ltok, E, K_top, DM, H3, 3, policies, 0.05,
                      777 + case)
    got = train_stack(layer_ids, Ltok, E, K_top, DM, H3, 3, policies, 0.05,
                      777 + case, R=R, strided=strided, chunks=chunks)
    assert got == ref, (f"stack case {case}: L={n_layers} R={R} K={chunks} "
                        f"{policies}: stacked loss curve diverged\n{got}\n{ref}")
    stack_cases += 1
print(f"stack parity OK: {stack_cases} fuzz cases, L-layer chained loss "
      "curves bit-identical between the single-rank reference and the "
      "chunk-pipelined sharded chain (d_x chaining exact)")

# ===========================================================================
# Smart-checkpoint planner mirror: the greedy downgrade sequence on the
# same analytic model as memory/planner.rs, asserted for (a) budget
# feasibility whenever the all-recompute floor fits, (b) projected-peak
# monotonicity as the budget tightens, and — for small L — agreement
# with exhaustive enumeration on feasibility.
# ===========================================================================

SAVED_PER_SLOT = {  # f32: save-all 4(d+2h), save-inputs 4d, recompute 0
    0: lambda d, h: 4 * (d + 2 * h),
    1: lambda d, h: 4 * d,
    2: lambda d, h: 0,
}

def planner_layer(rng):
    ranks = rng.choice([1, 2, 4])
    d, h = int(rng.integers(4, 16)), int(rng.integers(6, 20))
    slots = rng.integers(0, 40, size=ranks)
    resident = rng.integers(1, 20, size=ranks)
    regather = rng.integers(0, 2000, size=ranks)
    def bytes_for(pol):
        per = [4 * d * (int(s) + 2 * int(r)) + int(s) * SAVED_PER_SLOT[pol](d, h)
               for s, r in zip(slots, resident)]
        return max(per)
    extra_flops = 4 * d * h  # bwd recompute-hidden delta per row
    comp = max(int(s) for s in slots) * extra_flops / 200e9
    comm = max(int(g) for g in regather) / 50e9
    times = [0.0, comp, comp + comm]
    return [bytes_for(p) for p in range(3)], times

def greedy_plan(layers_cand, budget):
    choice = [0] * len(layers_cand)
    peak = sum(c[0][0] for c in layers_cand)
    while peak > budget:
        best = None
        for i, (by, tm) in enumerate(layers_cand):
            if choice[i] >= 2:
                continue
            saved = by[choice[i]] - by[choice[i] + 1]
            if saved <= 0:
                continue  # zero-slot max rank: no step on this layer saves
            dt = tm[choice[i] + 1] - tm[choice[i]]
            ratio = (saved / dt) if dt > 0 else float('inf')
            if best is None or ratio > best[2]:
                best = (i, saved, ratio)
        if best is None:
            break
        choice[best[0]] += 1
        peak -= best[1]
    return choice, peak

rng = np.random.default_rng(0xBEE)
for case in range(60):
    nl = int(rng.integers(1, 9))
    layers_cand = [planner_layer(rng) for _ in range(nl)]
    ceiling = sum(c[0][0] for c in layers_cand)
    floor = sum(c[0][2] for c in layers_cand)
    last_peak = float('inf')
    for step in range(6):
        budget = max(1, int(ceiling * 1.05) * (6 - step) // 6)
        choice, peak = greedy_plan(layers_cand, budget)
        # (b) monotone as the budget tightens
        assert peak <= last_peak, f"planner case {case}: peak rose"
        last_peak = peak
        # (a) feasibility whenever the floor fits
        if budget >= floor:
            assert peak <= budget, \
                f"planner case {case}: {peak} over feasible budget {budget}"
        # exhaustive cross-check for small L: some assignment fits iff
        # the floor fits (bytes are monotone per layer)
        if nl <= 5:
            fits = any(
                sum(layers_cand[i][0][(mask // 3 ** i) % 3]
                    for i in range(nl)) <= budget
                for mask in range(3 ** nl))
            assert fits == (floor <= budget), f"planner case {case}"
print("planner mirror OK: greedy plans fit every feasible budget, projected "
      "peak monotone in the budget, exhaustive feasibility agrees")

# ===========================================================================
# Gated-expert (SwiGLU) mirror — PR 6, coordinator/kernels.rs +
# engine.rs `expert_backward_row_swiglu`.
#
# The gated expert computes, per routed row:
#   pre = W1 @ x + b1        (the SiLU pre-activation chain)
#   gate = W3 @ x            (no gate bias)
#   z   = silu(pre) * gate
#   y   = W2 @ z + b2
# and the backward folds the gate product through both branches:
#   dz = W2^T @ dy
#   da = (dz * gate) * sig * (1 + pre * (1 - sig))    [SiLU' chain]
#   dg = dz * silu(pre)
#   dW1 += da x^T,  dW3 += dg x^T,  dx = W1^T da + W3^T dg
#
# Verified here two ways:
#   * float64 numeric gradients (central differences, eps = 1e-6,
#     loss = dy . y) against the analytic formulas, for every parameter
#     AND the input — the oracle the Rust row kernel encodes;
#   * tiled blocked-vs-row parity fuzz (float32, bitwise) across
#     R x tile x checkpoint policy, mirroring the zero-materialization
#     hot path with the extra gate chain in the same staging tiles.
# ===========================================================================

def swiglu_fwd(p, x, want_hidden):
    pre = (p['w1'] @ x + p['b1']).astype(f32)
    gate = (p['w3'] @ x).astype(f32)
    act = (silu32(pre) * gate).astype(f32)
    y = (p['w2'] @ act + p['b2']).astype(f32)
    return (y, pre, gate) if want_hidden else (y, None, None)

def swiglu_bwd_row(p, g, x, dy, pre, gate):
    # mirrors expert_backward_row_swiglu in engine.rs
    act = (silu32(pre) * gate).astype(f32)
    g['b2'] += dy
    g['w2'] += np.outer(dy, act).astype(f32)
    dz = (p['w2'].T @ dy).astype(f32)
    sig = (1 / (1 + np.exp(-pre))).astype(f32)
    da = ((dz * gate) * sig * (1 + pre * (1 - sig))).astype(f32)
    dg = (dz * silu32(pre)).astype(f32)
    g['b1'] += da
    g['w1'] += np.outer(da, x).astype(f32)
    g['w3'] += np.outer(dg, x).astype(f32)

def zeros_like_params_gated(d, h):
    z = zeros_like_params(d, h)
    z['w3'] = np.zeros((h, d), f32)
    return z

def init_experts_gated(E, d, h, rng):
    # draw order mirrors ExpertParams::init_gated: w1, w2, then w3
    # (scale sqrt(1/d), like w1)
    out = []
    for _ in range(E):
        p = dict(w1=rng.standard_normal((h, d)).astype(f32) * f32(d ** -0.5),
                 b1=np.zeros(h, f32),
                 w2=rng.standard_normal((d, h)).astype(f32) * f32(h ** -0.5),
                 b2=np.zeros(d, f32))
        p['w3'] = rng.standard_normal((h, d)).astype(f32) * f32(d ** -0.5)
        out.append(p)
    return out

# -- float64 numeric-gradient oracle ----------------------------------------

def swiglu_fwd64(p, x):
    pre = p['w1'] @ x + p['b1']
    gate = p['w3'] @ x
    return p['w2'] @ (pre / (1 + np.exp(-pre)) * gate) + p['b2']

rng = np.random.default_rng(2026)
for trial in range(5):
    d_n, h_n = 5, 7
    p64 = dict(w1=rng.standard_normal((h_n, d_n)),
               b1=rng.standard_normal(h_n),
               w2=rng.standard_normal((d_n, h_n)),
               b2=rng.standard_normal(d_n),
               w3=rng.standard_normal((h_n, d_n)))
    x64 = rng.standard_normal(d_n)
    dy64 = rng.standard_normal(d_n)
    pre = p64['w1'] @ x64 + p64['b1']
    gate = p64['w3'] @ x64
    sig = 1 / (1 + np.exp(-pre))
    sil = pre * sig
    analytic = dict(b2=dy64.copy(), w2=np.outer(dy64, sil * gate))
    dz = p64['w2'].T @ dy64
    da = (dz * gate) * sig * (1 + pre * (1 - sig))
    dg = dz * sil
    analytic['b1'] = da
    analytic['w1'] = np.outer(da, x64)
    analytic['w3'] = np.outer(dg, x64)
    dx = p64['w1'].T @ da + p64['w3'].T @ dg
    eps = 1e-6
    loss = lambda: float(dy64 @ swiglu_fwd64(p64, x64))
    for key in ('w1', 'b1', 'w2', 'b2', 'w3'):
        arr = p64[key]
        num = np.zeros_like(arr)
        it = np.nditer(arr, flags=['multi_index'])
        for _ in it:
            idx = it.multi_index
            orig = arr[idx]
            arr[idx] = orig + eps
            lp = loss()
            arr[idx] = orig - eps
            lm = loss()
            arr[idx] = orig
            num[idx] = (lp - lm) / (2 * eps)
        rel = np.abs(num - analytic[key]).max() / max(np.abs(analytic[key]).max(), 1.0)
        assert rel < 1e-6, f"swiglu trial {trial}: d{key} rel err {rel:.2e}"
    num_dx = np.zeros_like(x64)
    for i in range(d_n):
        orig = x64[i]
        x64[i] = orig + eps
        lp = loss()
        x64[i] = orig - eps
        lm = loss()
        x64[i] = orig
        num_dx[i] = (lp - lm) / (2 * eps)
    rel = np.abs(num_dx - dx).max() / max(np.abs(dx).max(), 1.0)
    assert rel < 1e-6, f"swiglu trial {trial}: dx rel err {rel:.2e}"
print("swiglu numeric gradients OK: 5 trials, every parameter + dx within "
      "1e-6 of float64 central differences")

# -- tiled blocked-vs-row gated parity fuzz ---------------------------------

def single_fwd_bwd_swiglu(d, params, x, gates, dm, policy, d_out, grads):
    """Row-by-row gated reference: forward combine + backward into
    `grads`, saved state per checkpoint policy ((pre, gate) is the gated
    hidden pair — silu(pre)*gate is recomputed from it in backward)."""
    l, e, k = d['l'], d['e'], d['k']
    n = l * k
    hdim = params[0]['b1'].size
    save_hidden = policy == 'save-all'
    save_inputs = policy != 'recompute-all'
    ys = np.zeros((n, dm), f32)
    xs = np.zeros((n, dm), f32) if save_inputs else None
    pre_s = np.zeros((n, hdim), f32) if save_hidden else None
    gate_s = np.zeros((n, hdim), f32) if save_hidden else None
    for ex in range(e):
        for pos in range(d['off'][ex], d['off'][ex + 1]):
            xin = x[d['eti'][pos]]
            if save_inputs:
                xs[pos] = xin
            y, pre, gate = swiglu_fwd(params[ex], xin, save_hidden)
            if save_hidden:
                pre_s[pos], gate_s[pos] = pre, gate
            ys[pos] = y
    out = np.zeros((l, dm), f32)
    for i in range(l):
        for j in range(k):
            pos = d['tim'][i * k + j]
            out[i] = out[i] + np.float32(gates[i * k + j]) * ys[pos]
    origin = [0] * n
    for slot, pos in enumerate(d['tim']):
        origin[pos] = slot
    for ex in range(e):
        for pos in range(d['off'][ex], d['off'][ex + 1]):
            tok = d['eti'][pos]
            dy = (np.float32(gates[origin[pos]]) * d_out[tok]).astype(f32)
            xin = xs[pos] if save_inputs else x[tok]
            if save_hidden:
                pre, gate = pre_s[pos], gate_s[pos]
            else:
                pre = (params[ex]['w1'] @ xin + params[ex]['b1']).astype(f32)
                gate = (params[ex]['w3'] @ xin).astype(f32)
            swiglu_bwd_row(params[ex], grads[ex], xin, dy, pre, gate)
    return out

def indexed_blocked_fwd_bwd_swiglu(d, params, x, gates, dm, R, strided, tile,
                                   policy, d_out, grads):
    """Zero-materialization gated step: gather-by-index in tiles, the
    gate chain staged alongside the pre chain in the same tile pass."""
    l, k = d['l'], d['k']
    per_rank, rows_between = row_index_plan(d, R, strided)
    dispatch_bytes = sum(rows_between[s][t] * dm * 4
                         for s in range(R) for t in range(R) if s != t)
    ys_of, saved = [], []
    ret_lookup = [None] * (l * k)
    for r in range(R):
        rr = per_rank[r]
        nl = len(rr['toks'])
        for ls, o in enumerate(rr['gslots']):
            ret_lookup[o] = (r, ls)
        ys = np.zeros((nl, dm), f32)
        xs = np.zeros((nl, dm), f32) if policy != 'recompute-all' else None
        hdim = params[0]['b1'].size
        pre_s = np.zeros((nl, hdim), f32) if policy == 'save-all' else None
        gate_s = np.zeros((nl, hdim), f32) if policy == 'save-all' else None
        for i, ex in enumerate(rr['experts']):
            lo, hi = rr['off'][i], rr['off'][i + 1]
            t0 = lo
            while t0 < hi:
                rows = min(tile, hi - t0)
                for rrow in range(rows):
                    ls = t0 + rrow
                    xin = x[rr['toks'][ls]]
                    if xs is not None:
                        xs[ls] = xin
                    y, pre, gate = swiglu_fwd(params[ex], xin,
                                              policy == 'save-all')
                    if policy == 'save-all':
                        pre_s[ls], gate_s[ls] = pre, gate
                    ys[ls] = y
                t0 += rows
        ys_of.append(ys)
        saved.append((xs, (pre_s, gate_s) if policy == 'save-all' else None))
    out = np.zeros((l, dm), f32)
    for home in range(R):
        for t in range(l):
            if rank_of_token(t, l, R) != home:
                continue
            for j in range(k):
                r, ls = ret_lookup[t * k + j]
                out[t] = out[t] + np.float32(gates[t * k + j]) * ys_of[r][ls]
    for r in range(R):
        rr = per_rank[r]
        xs, hidden = saved[r]
        for i, ex in enumerate(rr['experts']):
            lo, hi = rr['off'][i], rr['off'][i + 1]
            t0 = lo
            while t0 < hi:
                rows = min(tile, hi - t0)
                for rrow in range(rows):
                    ls = t0 + rrow
                    tok = rr['toks'][ls]
                    dy = (np.float32(gates[rr['gslots'][ls]])
                          * d_out[tok]).astype(f32)
                    xin = xs[ls] if xs is not None else x[tok]
                    if hidden is not None:
                        pre, gate = hidden[0][ls], hidden[1][ls]
                    else:
                        pre = (params[ex]['w1'] @ xin
                               + params[ex]['b1']).astype(f32)
                        gate = (params[ex]['w3'] @ xin).astype(f32)
                    swiglu_bwd_row(params[ex], grads[ex], xin, dy, pre, gate)
                t0 += rows
    return out, dispatch_bytes

def grads_bytes_gated(grads):
    return b''.join(g[kk].tobytes() for g in grads
                    for kk in ('w1', 'b1', 'w2', 'b2', 'w3'))

random.seed(11)
gated_cases = 0
for case in range(30):
    R = random.choice([1, 2, 4])
    E = R * random.randint(1, 3)
    L = random.randint(4, 40)
    K_top = random.randint(1, min(E, 3))
    DM, H2 = 5, 7
    tile = random.choice([1, 2, 3, 8, 64])
    strided = random.random() < 0.5
    policy = random.choice(['save-all', 'save-inputs', 'recompute-all'])
    rng = np.random.default_rng(7000 + case)
    ids = np.concatenate([rng.choice(E, K_top, replace=False)
                          for _ in range(L)]).astype(int)
    params = init_experts_gated(E, DM, H2, rng)
    x = rng.standard_normal((L, DM)).astype(f32)
    gates = rng.random(L * K_top).astype(f32)
    d_out = rng.standard_normal((L, DM)).astype(f32)
    d_full = build(list(ids), L, E, K_top)
    ref_grads = [zeros_like_params_gated(DM, H2) for _ in range(E)]
    ref_out = single_fwd_bwd_swiglu(d_full, params, x, gates, DM, policy,
                                    d_out, ref_grads)
    got_grads = [zeros_like_params_gated(DM, H2) for _ in range(E)]
    got_out, derived = indexed_blocked_fwd_bwd_swiglu(
        d_full, params, x, gates, DM, R, strided, tile, policy, d_out,
        got_grads)
    assert ref_out.tobytes() == got_out.tobytes(), \
        f"swiglu case {case}: outputs diverged (R={R} tile={tile} {policy})"
    assert grads_bytes_gated(ref_grads) == grads_bytes_gated(got_grads), \
        f"swiglu case {case}: grads diverged (R={R} tile={tile} {policy})"
    pb, _ = plan_bytes(d_full, R, strided, DM)
    assert derived == pb, \
        f"swiglu case {case}: derived bytes {derived} != plan {pb}"
    gated_cases += 1
print(f"swiglu parity OK: {gated_cases} fuzz cases, gated blocked path "
      "bit-identical to the row reference across R x tile x policy, "
      "derived bytes == plan")

# ===========================================================================
# Forward-only serving mirror (ISSUE 7): continuous batching +
# capacity-aware admission, mirroring rust/src/serving/.
#
# Mirrored contracts:
#   * batching is INVISIBLE — each request's span of the aggregated
#     forward output is bit-identical (float32) to serving the request
#     alone, because the expert kernels are per-row: the batch only
#     concatenates rows, and aggregation is fuzzed over the sharded
#     engine too so the exchange cannot leak between requests;
#   * the admission projection prices exactly what the engine measures:
#     per-rank forward data bytes are 4*d*(slots_r + 2*tok_r), where
#     slots_r counts routed top-k slots on the rank owning each expert
#     and tok_r is the contiguous token partition — the ceil closed
#     form asserted here against rank_of_token, token by token;
#   * a budget-driven admission loop (FIFO drain, queue vs reject
#     policy) never admits a batch whose projected peak exceeds the
#     budget, and conserves every generated request exactly once.
# ===========================================================================

def tokens_per_rank_ceil(l, R):
    # rank r holds [ceil(r*l/R), ceil((r+1)*l/R)) — the closed form of
    # rank_of_token's contiguous partition
    return [-(-((r + 1) * l) // R) - (-(-(r * l) // R)) for r in range(R)]

for R in [1, 2, 4, 8]:
    for l in range(1, 40):
        counted = [0] * R
        for t in range(l):
            counted[rank_of_token(t, l, R)] += 1
        assert tokens_per_rank_ceil(l, R) == counted, \
            f"token partition closed form diverged at l={l} R={R}"

def admission_peak_bytes(req_ids_list, total_tokens, E, R, k, dm):
    # the AdmissionController projection: one slot per top-k assignment
    # on the rank owning that expert, 4*d*(slots + 2*tokens) per rank
    slots = [0] * R
    for ids in req_ids_list:
        for ex in ids:
            slots[rank_of_expert(ex, E, R, False)] += 1
    toks = tokens_per_rank_ceil(total_tokens, R)
    return max(4 * dm * (s + 2 * t) for s, t in zip(slots, toks))

random.seed(21)
serve_cases = 0
for case in range(40):
    R = random.choice([1, 2, 4])
    E = R * random.randint(1, 4)
    k = random.randint(1, min(E, 3))
    dm = 5
    rng = np.random.default_rng(8000 + case)
    n_req = random.randint(2, 6)
    reqs = []
    for _ in range(n_req):
        lt = random.randint(1, 7)
        ids = np.concatenate([rng.choice(E, k, replace=False)
                              for _ in range(lt)]).astype(int)
        reqs.append(dict(tokens=lt, ids=list(ids),
                         x=rng.standard_normal((lt, dm)).astype(f32),
                         gates=rng.random(lt * k).astype(f32)))
    W = rng.standard_normal((E, dm, dm)).astype(f32)
    # aggregate: concatenate rows in arrival order (the batcher mirror)
    agg_ids = sum((r['ids'] for r in reqs), [])
    agg_x = np.concatenate([r['x'] for r in reqs])
    agg_gates = np.concatenate([r['gates'] for r in reqs])
    L = agg_x.shape[0]
    d_agg = build(agg_ids, L, E, k)
    out_single = single_forward(d_agg, W, agg_x, agg_gates, dm)
    out_shard, _, _ = sharded_forward(d_agg, W, agg_x, agg_gates, dm, R, False)
    assert out_single.tobytes() == out_shard.tobytes(), \
        f"serve case {case}: aggregated sharded forward diverged"
    # scatter: each request's span == the request served alone, bitwise
    off = 0
    for r in reqs:
        d_solo = build(r['ids'], r['tokens'], E, k)
        solo = single_forward(d_solo, W, r['x'], r['gates'], dm)
        span = out_shard[off:off + r['tokens']]
        assert solo.tobytes() == span.tobytes(), \
            f"serve case {case}: span diverged from solo inference"
        off += r['tokens']
    # projection == measured: the engine's forward data bytes for the
    # aggregated batch are 4*d*(slots_r + 2*tok_r) on every rank
    measured = []
    shards = shard(d_agg, R, False)
    for r in range(R):
        slots_r = len(shards[r]['toks'])
        tok_r = tokens_per_rank_ceil(L, R)[r]
        measured.append(4 * dm * (slots_r + 2 * tok_r))
    projected = admission_peak_bytes([r['ids'] for r in reqs], L, E, R, k, dm)
    assert projected == max(measured), \
        f"serve case {case}: projection {projected} != measured {max(measured)}"
    serve_cases += 1
print(f"serving parity OK: {serve_cases} fuzz cases, per-request spans "
      "bit-identical to solo inference through the sharded aggregate, "
      "admission projection == per-rank forward bytes")

# -- budget-driven admission loop: peak never exceeds the budget ------------

def admission_sim(ticks, tick_tokens, max_queue, budget, policy, E, R, k, dm,
                  seed):
    rng = random.Random(seed)
    queue = []
    completed = rejected_cap = rejected_full = generated = 0
    batch_peaks = []
    for _ in range(ticks):
        for _ in range(rng.randint(0, 3)):  # arrivals
            lt = rng.randint(1, 6)
            ids = [rng.randrange(E) for _ in range(lt * k)]
            generated += 1
            req = dict(tokens=lt, ids=ids)
            alone = admission_peak_bytes([ids], lt, E, R, k, dm)
            if budget > 0 and alone > budget:
                rejected_cap += 1
            elif len(queue) >= max_queue:
                rejected_full += 1
            else:
                queue.append(req)
        picked, picked_tokens = [], 0
        while queue:
            req = queue[0]
            if picked and picked_tokens + req['tokens'] > tick_tokens:
                break
            trial = [p['ids'] for p in picked] + [req['ids']]
            peak = admission_peak_bytes(trial, picked_tokens + req['tokens'],
                                        E, R, k, dm)
            if budget > 0 and peak > budget:
                if policy == 'queue':
                    break  # head-of-line waits for a lighter tick
                queue.pop(0)
                rejected_cap += 1
                continue
            picked.append(queue.pop(0))
            picked_tokens += req['tokens']
        if picked:
            batch_peaks.append(admission_peak_bytes(
                [p['ids'] for p in picked], picked_tokens, E, R, k, dm))
            completed += len(picked)
    return dict(generated=generated, completed=completed,
                rejected_cap=rejected_cap, rejected_full=rejected_full,
                queued=len(queue), peaks=batch_peaks)

for policy in ['queue', 'reject']:
    for budget in [0, 600, 2000]:
        r = admission_sim(24, 16, 6, budget, policy, 8, 4, 2, 5, seed=13)
        assert r['generated'] == (r['completed'] + r['rejected_cap']
                                  + r['rejected_full'] + r['queued']), \
            f"admission {policy}/{budget}: counters do not conserve"
        assert r['completed'] > 0, f"admission {policy}/{budget}: starved"
        if budget > 0:
            assert all(p <= budget for p in r['peaks']), \
                f"admission {policy}/{budget}: admitted batch over budget"
        if budget == 0:
            assert r['rejected_cap'] == 0, \
                "no budget must mean no capacity rejects"
print("admission mirror OK: FIFO drain under queue + reject policies, "
      "every admitted batch's projected peak within budget, request "
      "counters conserve")

# -- drift-band mirror: EWMA predicted-vs-measured flagging -----------------
# Bit-for-bit port of rust/src/trace/drift.rs::DriftTracker. The update
# order is the cross-language contract: deviation and flag are judged
# against the PRE-update mean/mad, then both EWMAs fold the observation
# in. Constants mirror DRIFT_ALPHA / DRIFT_K / DRIFT_EPS / DRIFT_WARMUP.

DRIFT_ALPHA, DRIFT_K, DRIFT_EPS, DRIFT_WARMUP = 0.2, 4.0, 0.25, 3
MASK64 = (1 << 64) - 1
LCG_MUL, LCG_ADD = 6364136223846793005, 1442695040888963407


def drift_flags(ratios, alpha=DRIFT_ALPHA, k=DRIFT_K, eps=DRIFT_EPS,
                warmup=DRIFT_WARMUP):
    mean = mad = 0.0
    n = 0
    flags = []
    for i, r in enumerate(ratios):
        if n == 0:
            mean, mad, n = r, 0.0, 1
            continue
        dev = abs(r - mean)
        width = max(k * mad, eps)
        if n >= warmup and dev > width:
            flags.append(i)
        mean += alpha * (r - mean)
        mad += alpha * (dev - mad)
        n += 1
    return flags


def drift_sequence(seq):
    # same LCG as the Rust test: ratios in [0.8, 1.2) with a rare 2.5x
    # spike when the top nibble of the second draw is zero
    state = (0x5EED0 + seq) & MASK64
    out = []
    for _ in range(40):
        state = (state * LCG_MUL + LCG_ADD) & MASK64
        u = (state >> 11) / float(1 << 53)
        r = 0.8 + 0.4 * u
        state = (state * LCG_MUL + LCG_ADD) & MASK64
        if state >> 60 == 0:
            r *= 2.5
        out.append(r)
    return out


# the pinned table — rust/src/trace/drift.rs holds the identical one
DRIFT_EXPECTED = [
    [11, 23, 33], [13], [36], [3, 5, 14, 37], [10, 15], [17, 28], [6],
    [3, 22], [19, 20], [21], [3, 7, 14], [], [37], [18, 30], [25],
    [6, 38], [], [9, 10], [4, 8], [7],
]

for s, expected in enumerate(DRIFT_EXPECTED):
    got = drift_flags(drift_sequence(s))
    assert got == expected, \
        f"drift sequence {s}: flagged {got}, Rust table says {expected}"
assert sum(len(f) for f in DRIFT_EXPECTED) == 33

# behavior pins matching the Rust unit tests: a quiet history never
# flags; a 2x spike after warmup flags once and the widened band then
# absorbs the return to baseline
assert drift_flags([1.0] * 20) == []
assert drift_flags([1.0] * 5 + [2.0, 1.0]) == [5]
print("drift mirror OK: 20 LCG sequences x 40 steps flag exactly the "
      "33 pinned (sequence, step) pairs; quiet histories stay silent, "
      "post-warmup spikes flag once")

# -- expert-load mirror: routed-row EWMAs, rank skew, alarm hysteresis ------
# Bit-for-bit port of rust/src/trace/load.rs::ExpertLoadTracker. The
# fold order is the cross-language contract: seed-or-fold the per-expert
# EWMAs (expert-id ascending), aggregate rank loads through the expert->
# rank map, take max/mean in rank order, then walk the warmup +
# hysteresis state machine. A flag marks the raising edge only.
# Constants mirror LOAD_ALPHA / LOAD_WARMUP / LOAD_HYSTERESIS /
# LOAD_RELEASE.

LOAD_ALPHA, LOAD_WARMUP, LOAD_HYSTERESIS, LOAD_RELEASE = 0.2, 3, 2, 0.9


def skew_flags(steps, rank_of, thr, alpha=LOAD_ALPHA, warmup=LOAD_WARMUP,
               hysteresis=LOAD_HYSTERESIS, release=LOAD_RELEASE):
    ewma = [0.0] * len(rank_of)
    n = over = under = 0
    active = False
    flags = []
    for s, rows in enumerate(steps):
        if n == 0:
            for e, r in enumerate(rows):
                ewma[e] = float(r)
        else:
            for e, r in enumerate(rows):
                ewma[e] += alpha * (float(r) - ewma[e])
        n += 1
        ranks = max(rank_of) + 1
        loads = [0.0] * ranks
        for e, w in enumerate(ewma):
            loads[rank_of[e]] += w
        total = 0.0
        mx = 0.0
        for v in loads:
            total += v
            if v > mx:
                mx = v
        mean = total / ranks
        imbalance = mx / mean if mean > 0.0 else 0.0
        if not active:
            if n >= warmup and thr > 0.0 and imbalance > thr:
                over += 1
            else:
                over = 0
            if over >= hysteresis:
                active, over = True, 0
                flags.append(s)
        else:
            if imbalance <= thr * release:
                under += 1
            else:
                under = 0
            if under >= hysteresis:
                active, under = False, 0
    return flags


def load_sequence(seq):
    # same LCG as the Rust test: 40 steps of 8-expert routed-row counts
    # in [16, 32), with two LCG-placed hot windows adding 160 rows
    state = (0x10AD5EED + seq) & MASK64

    def draw():
        nonlocal state
        state = (state * LCG_MUL + LCG_ADD) & MASK64
        return state

    hot = []
    for w in range(2):
        e = (draw() >> 33) % 8
        if w == 0:
            start = 8 + (draw() >> 33) % 8
            length = 6 + (draw() >> 33) % 10
        else:
            start = 26 + (draw() >> 33) % 6
            length = 4 + (draw() >> 33) % 6
        hot.append((e, start, start + length))
    steps = []
    for s in range(40):
        rows = []
        for _ in range(8):
            u = (draw() >> 11) / float(1 << 53)
            rows.append(16 + int(u * 16.0))
        for e, start, end in hot:
            if start <= s < end:
                rows[e] += 160
        steps.append(rows)
    return steps


# the pinned table — rust/src/trace/load.rs holds the identical one
LOAD_EXPECTED = [
    [13], [14], [15], [16], [17], [10, 29], [11, 31], [12, 32],
    [13, 32], [14, 33], [15, 31], [16, 33],
]

LOAD_RANK_OF = [e // 2 for e in range(8)]
for s, expected in enumerate(LOAD_EXPECTED):
    got = skew_flags(load_sequence(s), LOAD_RANK_OF, 1.5)
    assert got == expected, \
        f"load sequence {s}: flagged {got}, Rust table says {expected}"
assert sum(len(f) for f in LOAD_EXPECTED) == 19

# behavior pins matching the Rust unit tests: balanced loads and the
# Figure-2 fixture never alarm; the skewed fixture (loads [14, 2],
# imbalance 1.75) raises exactly once at step 3 (warmup 3 + hysteresis
# 2); a zero threshold tracks but never raises
assert skew_flags([[20] * 8 for _ in range(40)], LOAD_RANK_OF, 1.5) == []
assert skew_flags([[3, 2, 2, 3]] * 10, [0, 0, 1, 1], 1.5) == []
assert skew_flags([[12, 2, 1, 1]] * 10, [0, 0, 1, 1], 1.5) == [3]
assert skew_flags([[100, 1, 1, 1]] * 10, [0, 0, 1, 1], 0.0) == []
print("load mirror OK: 12 LCG sequences x 40 steps raise exactly the "
      "19 pinned (sequence, step) alarms; Figure-2 and balanced "
      "fixtures stay silent, the skewed fixture raises once at step 3")

# ===========================================================================
# Resilience mirror (ISSUE 10): crash-consistent resume + the seeded
# fault plan.
#
# Two cross-language contracts, both pinned BITWISE against
# rust/src/resilience/:
#   * kill-and-resume is bit-identical — capturing params + Adam moments
#     at any optimizer-step boundary (exactly what TrainState
#     serializes: exact f32 bits, exact Adam t/m/v, the step cursor) and
#     rerunning the remaining schedule reproduces the never-interrupted
#     loss curve as float64 equality, across optimizer x policy x
#     grad_accum and at every kill point;
#   * the splitmix64 fault arithmetic — mix64 / fault_hash / fault_unit
#     and the per-family decision sites match rust's fault.rs exactly,
#     pinned by the same 8-seed x 20-step x 2-micro decision tables the
#     Rust unit suite holds (FAULT_STALLS / FAULT_EXCH / FAULT_CORRUPT).
# ===========================================================================

def copy_params(params):
    return [{k: v.copy() for k, v in p.items()} for p in params]

def snapshot_state(params, adam_state):
    """What TrainState carries: exact parameter bits + optimizer state."""
    return dict(params=copy_params(params),
                adam=dict(t=adam_state['t'],
                          m=copy_params(adam_state['m']),
                          v=copy_params(adam_state['v'])))

def train_segment(L, E, K, DM, H, steps, accum, policy, opt, lr, seed,
                  start=0, stop=None, state=None):
    """Steps [start, stop) of train()'s schedule. `state` restores a
    snapshot taken at `start` (a resumed run); returns (losses, state at
    stop) so the caller can chain segments like kill + resume do."""
    stop = steps if stop is None else stop
    rng = np.random.default_rng(seed)
    params = init_experts(E, DM, H, rng)
    ids = np.concatenate([rng.choice(E, K, replace=False)
                          for _ in range(L)]).astype(int)
    gates = rng.random(L * K).astype(f32)
    x = rng.standard_normal((L, DM)).astype(f32)
    target = rng.standard_normal((L, DM)).astype(f32)
    bounds = [L * i // accum for i in range(accum + 1)]
    micros = []
    for m in range(accum):
        t0, t1 = bounds[m], bounds[m + 1]
        sub_ids = list(ids[t0 * K:t1 * K])
        d_sub = build(sub_ids, t1 - t0, E, K)
        micros.append((t0, d_sub, x[t0:t1], gates[t0 * K:t1 * K]))
    adam_state = dict(t=0, m=[zeros_like_params(DM, H) for _ in range(E)],
                      v=[zeros_like_params(DM, H) for _ in range(E)])
    if state is not None:
        params = copy_params(state['params'])
        adam_state = dict(t=state['adam']['t'],
                          m=copy_params(state['adam']['m']),
                          v=copy_params(state['adam']['v']))
    scale = f32(2.0 / (L * DM))
    losses = []
    for _ in range(start, stop):
        grads = [zeros_like_params(DM, H) for _ in range(E)]
        loss = 0.0
        for (t0, d_sub, x_sub, gates_sub) in micros:
            loss = session_fwd_bwd(d_sub, params, x_sub, gates_sub, target,
                                   t0, scale, grads, policy, loss)
        losses.append(loss / (L * DM))
        delta = adam_step(adam_state, grads, lr) if opt == 'adam' \
            else sgd_delta(grads, lr)
        for ex in range(E):
            for k in params[ex]:
                params[ex][k] = (params[ex][k] + delta[ex][k]).astype(f32)
    return losses, snapshot_state(params, adam_state)

RES_STEPS = 4
for opt, lr in [('sgd', 0.05), ('adam', 0.01)]:
    for accum, policy in [(1, 'save-inputs'), (2, 'recompute-all')]:
        full, _ = train_segment(L, E, K, DM, H, RES_STEPS, accum, policy,
                                opt, lr, 123)
        for kill in range(1, RES_STEPS):
            part, st = train_segment(L, E, K, DM, H, RES_STEPS, accum,
                                     policy, opt, lr, 123, stop=kill)
            rest, _ = train_segment(L, E, K, DM, H, RES_STEPS, accum,
                                    policy, opt, lr, 123, start=kill,
                                    state=st)
            assert part + rest == full, \
                f"{opt} accum={accum} {policy} kill={kill}: resumed curve " \
                f"diverged\n{part + rest}\n{full}"
print("resume mirror OK: kill-at-any-step + snapshot-state resume is "
      "bit-identical to the uninterrupted curve, across optimizer x "
      "policy x grad_accum")

# --- the fault plan's decision arithmetic (rust/src/resilience/fault.rs)

SALT_STALL = 0x57A11
SALT_EXCHANGE = 0xE8C7A9
SALT_SNAPSHOT = 0x5A4B

def mix64(z):
    z = (z + 0x9E3779B97F4A7C15) & MASK64
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    return z ^ (z >> 31)

def fault_hash(seed, salt, a, b, c):
    h = mix64((seed ^ salt) & MASK64)
    h = mix64(h ^ a)
    h = mix64(h ^ b)
    return mix64(h ^ c)

def fault_unit(seed, salt, a, b, c):
    # top 53 bits: exactly representable in float64, so Rust and Python
    # compare the same number against the same threshold
    return (fault_hash(seed, salt, a, b, c) >> 11) / float(1 << 53)

FAULT_STALL_P, FAULT_EXCH_P, FAULT_CORRUPT_P, FAULT_BUDGET = \
    0.15, 0.25, 0.2, 3

def fault_stalls(seed, step):
    return fault_unit(seed, SALT_STALL, step, 0, 0) < FAULT_STALL_P

def fault_stall_rank(seed, step, ranks):
    return fault_hash(seed, SALT_STALL, step, 1, 0) % max(ranks, 1)

def fault_exchange_retries(seed, step, micro):
    """Mirror of FaultInjector::exchange_gate: retries taken, or None
    when the budget is exhausted (the loud unrecovered path)."""
    attempt = 0
    while fault_unit(seed, SALT_EXCHANGE, step, micro, attempt) \
            < FAULT_EXCH_P:
        if attempt >= FAULT_BUDGET:
            return None
        attempt += 1
    return attempt

def fault_corrupts(seed, step):
    return fault_unit(seed, SALT_SNAPSHOT, step, 0, 0) < FAULT_CORRUPT_P

def fault_corruption(seed, step, length):
    h = fault_hash(seed, SALT_SNAPSHOT, step, 1, 0)
    offset = h % max(length, 1)
    xor = 0 if (h >> 62) == 0 else 1 + (h >> 32) % 255
    return offset, xor

# the pinned tables — rust/src/resilience/fault.rs holds the identical
# ones (STALLS / EXCH / CORRUPT), 8 seeds x 20 steps x 2 microbatches
FAULT_STALLS = [
    [4],
    [1, 10, 13, 14, 16, 18],
    [],
    [19],
    [6, 14],
    [9, 14],
    [8, 12, 15],
    [13, 17],
]
FAULT_EXCH = [
    [(0, 1, 1), (5, 1, 1), (6, 1, 1), (7, 0, 1), (8, 0, 1), (9, 0, 1),
     (9, 1, 1), (10, 0, 1), (13, 0, 2), (15, 0, 2), (18, 0, 1),
     (18, 1, 1)],
    [(2, 0, 2), (2, 1, 1), (7, 0, 1), (9, 0, 1), (11, 1, 2), (12, 0, 1),
     (14, 1, 3), (18, 1, 2)],
    [(0, 0, 1), (0, 1, 1), (5, 1, 1), (6, 1, 1), (7, 0, 1), (7, 1, 1),
     (8, 0, 2), (15, 1, 2), (17, 1, 1), (18, 1, 1)],
    [(0, 0, 1), (1, 0, 1), (1, 1, 2), (3, 0, 1), (5, 0, 1), (9, 1, 1),
     (11, 0, 1), (12, 1, 1), (17, 0, 1)],
    [(0, 1, 1), (2, 1, 1), (5, 0, 1), (5, 1, 1), (6, 1, 1), (7, 1, 1),
     (11, 0, 1), (12, 0, 1), (14, 0, 1), (17, 0, 1), (17, 1, 1),
     (18, 0, 1)],
    [(3, 0, 1), (5, 0, 1), (5, 1, 1), (10, 0, 1), (10, 1, 1),
     (11, 0, 3), (11, 1, 1), (13, 0, 1), (14, 0, 1), (16, 1, 2),
     (17, 0, 3), (19, 0, 1)],
    [(0, 0, 1), (0, 1, 1), (2, 0, 1), (3, 0, 1), (8, 0, 1), (9, 0, 1),
     (10, 0, 1), (10, 1, 3), (11, 1, 1), (13, 0, 1), (16, 0, 1),
     (18, 0, 1), (18, 1, 1), (19, 0, 1)],
    [(0, 0, 1), (0, 1, 1), (2, 0, 2), (2, 1, 1), (4, 1, 1), (7, 0, 1),
     (7, 1, 2), (8, 1, 1), (9, 0, 3), (10, 1, 1), (12, 0, 1),
     (12, 1, 1), (16, 0, 1), (16, 1, 1), (18, 1, 1)],
]
FAULT_CORRUPT = [
    [1, 5, 12, 15, 18],
    [0, 9, 14, 15],
    [4, 13, 17],
    [1, 4, 6, 19],
    [15, 17, 18],
    [12],
    [0, 5, 13, 15, 16],
    [1, 2, 7, 10, 14, 17, 18],
]

for seed in range(8):
    stalls = [s for s in range(20) if fault_stalls(seed, s)]
    assert stalls == FAULT_STALLS[seed], \
        f"stalls, seed {seed}: {stalls} != {FAULT_STALLS[seed]}"
    exch = []
    for s in range(20):
        for m in range(2):
            r = fault_exchange_retries(seed, s, m)
            assert r is not None, \
                f"seed {seed} ({s},{m}): budget exhausted, Rust recovers"
            if r > 0:
                exch.append((s, m, r))
    assert exch == FAULT_EXCH[seed], \
        f"exchange, seed {seed}: {exch} != {FAULT_EXCH[seed]}"
    corrupt = [s for s in range(20) if fault_corrupts(seed, s)]
    assert corrupt == FAULT_CORRUPT[seed], \
        f"corrupt, seed {seed}: {corrupt} != {FAULT_CORRUPT[seed]}"
    # stall ranks stay in range; corruption sites stay in bounds and
    # are never a no-op flip (xor 0 means truncate)
    for s in stalls:
        assert fault_stall_rank(seed, s, 4) < 4
    for s in corrupt:
        for length in [1, 8, 100, 4096]:
            off, xor = fault_corruption(seed, s, length)
            assert off < length and 0 <= xor <= 255

# replay stability + seed sensitivity, like the Rust unit suite
assert [fault_stalls(3, s) for s in range(50)] == \
    [fault_stalls(3, s) for s in range(50)]
assert [fault_stalls(1, s) for s in range(64)] != \
    [fault_stalls(2, s) for s in range(64)]
print("fault mirror OK: splitmix64 decision tables (8 seeds x 20 steps "
      "x 2 micros) match rust/src/resilience/fault.rs exactly — stalls, "
      "exchange retry counts, and snapshot corruption sites")

"""Python mirror of rust/src/dispatch/shard.rs + coordinator/engine.rs
to validate the algorithm (indexing, routes, packing, byte accounting)
since no Rust toolchain exists in this container."""
import random
import numpy as np

def build(ids, l, e, k):
    # expert-major stable order (token-major scan per expert) — matches
    # both Rust builders
    per = [[] for _ in range(e)]
    for t in range(l):
        for j in range(k):
            per[ids[t*k+j]].append((t, t*k+j))
    offsets = [0]
    eti, origin_of_pos = [], []
    for ex in range(e):
        for (t, o) in per[ex]:
            eti.append(t); origin_of_pos.append(o)
        offsets.append(len(eti))
    tim = [0]*(l*k)
    for pos, o in enumerate(origin_of_pos):
        tim[o] = pos
    return dict(l=l, e=e, k=k, ids=ids, eti=eti, off=offsets, tim=tim)

def validate(d):
    l, e, k = d['l'], d['e'], d['k']
    n = l*k
    assert d['off'][0] == 0 and d['off'][e] == n
    assert sorted(d['tim']) == list(range(n))
    for i in range(l):
        for j in range(k):
            pos = d['tim'][i*k+j]
            assert d['eti'][pos] == i
            ex = d['ids'][i*k+j]
            assert d['off'][ex] <= pos < d['off'][ex+1]

def rank_of_expert(ex, E, R, strided):
    return ex % R if strided else ex // (E // R)

def rank_of_token(t, l, R):
    return min(t*R//l, R-1)

def shard(d, R, strided):
    l, e, k = d['l'], d['e'], d['k']
    inv = [0]*(l*k)
    for slot, pos in enumerate(d['tim']):
        inv[pos] = slot
    shards = []
    for r in range(R):
        experts = [x for x in range(e) if rank_of_expert(x, e, R, strided) == r]
        off = [0]; toks = []; orig = []
        for ex in experts:
            lo, hi = d['off'][ex], d['off'][ex+1]
            toks += d['eti'][lo:hi]
            orig += inv[lo:hi]
            off.append(len(toks))
        shards.append(dict(rank=r, experts=experts, off=off, toks=toks, orig=orig))
    return shards

def merge(shards, l, e, k):
    lengths = [None]*e
    for s in shards:
        for i, ex in enumerate(s['experts']):
            assert lengths[ex] is None
            lengths[ex] = s['off'][i+1]-s['off'][i]
    assert all(v is not None for v in lengths)
    off = [0]
    for x in lengths: off.append(off[-1]+x)
    n = l*k
    eti = [0]*n; ids = [0]*n; tim = [0]*n; seen = [False]*n
    for s in shards:
        for i, ex in enumerate(s['experts']):
            base = off[ex]
            for j in range(s['off'][i+1]-s['off'][i]):
                local = s['off'][i]+j
                pos = base+j
                o = s['orig'][local]
                assert not seen[o]; seen[o] = True
                eti[pos] = s['toks'][local]
                ids[o] = ex
                tim[o] = pos
    return dict(l=l, e=e, k=k, ids=ids, eti=eti, off=off, tim=tim)

def expert_fwd(W, x):
    # stand-in per-row expert fn: W[e] @ x (float32) — order-free per row
    return (W @ x).astype(np.float32)

def single_forward(d, W, x, gates, dm):
    l, e, k = d['l'], d['e'], d['k']
    n = l*k
    ys = np.zeros((n, dm), np.float32)
    for ex in range(e):
        for pos in range(d['off'][ex], d['off'][ex+1]):
            ys[pos] = expert_fwd(W[ex], x[d['eti'][pos]])
    out = np.zeros((l, dm), np.float32)
    for i in range(l):
        for j in range(k):
            pos = d['tim'][i*k+j]
            out[i] = out[i] + np.float32(gates[i*k+j]) * ys[pos]
    return out

def sharded_forward(d, W, x, gates, dm, R, strided):
    l, e, k = d['l'], d['e'], d['k']
    shards = shard(d, R, strided)
    routes = [[[] for _ in range(R)] for _ in range(R)]  # [dst][src]
    ret_lookup = [None]*(l*k)
    for dst, s in enumerate(shards):
        for ls, (tok, o) in enumerate(zip(s['toks'], s['orig'])):
            src = rank_of_token(tok, l, R)
            ret_lookup[o] = (dst, len(routes[dst][src]))
            routes[dst][src].append((ls, tok, o))
    # phase A: pack
    send = [[np.stack([x[t] for (_, t, _) in routes[dst][src]]) if routes[dst][src]
             else np.zeros((0, dm), np.float32)
             for dst in range(R)] for src in range(R)]
    dispatch_bytes = sum(send[s][t].size*4 for s in range(R) for t in range(R) if s != t)
    cross_rows = sum(len(routes[t][s]) for s in range(R) for t in range(R) if s != t)
    # phase B: unpack + compute + pack return
    rets = []
    for dst in range(R):
        s = shards[dst]
        nl = len(s['toks'])
        xs = np.zeros((nl, dm), np.float32)
        for src in range(R):
            for i, (ls, tok, o) in enumerate(routes[dst][src]):
                xs[ls] = send[src][dst][i]
        ys = np.zeros((nl, dm), np.float32)
        for i, ex in enumerate(s['experts']):
            for ls in range(s['off'][i], s['off'][i+1]):
                ys[ls] = expert_fwd(W[ex], xs[ls])
        rets.append([np.stack([ys[ls] for (ls, _, _) in routes[dst][src]]) if routes[dst][src]
                     else np.zeros((0, dm), np.float32) for src in range(R)])
    # phase C: combine on home ranks
    out = np.zeros((l, dm), np.float32)
    for home in range(R):
        for t in range(l):
            if rank_of_token(t, l, R) != home:
                continue
            for j in range(k):
                slot = t*k+j
                dst, idx = ret_lookup[slot]
                out[t] = out[t] + np.float32(gates[slot]) * rets[dst][home][idx]
    return out, dispatch_bytes, cross_rows

def plan_bytes(d, R, strided, dm):
    cross = 0
    for ex in range(d['e']):
        dst = rank_of_expert(ex, d['e'], R, strided)
        for pos in range(d['off'][ex], d['off'][ex+1]):
            if rank_of_token(d['eti'][pos], d['l'], R) != dst:
                cross += 1
    return cross*dm*4, cross

random.seed(0)
for case in range(300):
    R = random.choice([1, 2, 4, 8])
    e = R*random.randint(1, 4)
    l = random.randint(1, 80)
    k = random.randint(1, min(e, 3))
    strided = random.random() < 0.5
    if random.random() < 0.1:
        ids = [0]*(l*k)  # all-to-one (k must be 1 for distinctness)
        k = 1
        ids = [0]*l
    else:
        ids = []
        for _ in range(l):
            ids += random.sample(range(e), k)
    d = build(ids, l, e, k)
    validate(d)
    # shard/merge round trip
    m = merge(shard(d, R, strided), l, e, k)
    assert m == d, f"round-trip failed case {case}"
    # engine equivalence + measured bytes
    dm = 4
    rng = np.random.default_rng(case)
    W = rng.standard_normal((e, dm, dm)).astype(np.float32)
    x = rng.standard_normal((l, dm)).astype(np.float32)
    gates = rng.random(l*k).astype(np.float32)
    a = single_forward(d, W, x, gates, dm)
    b, measured, cross_rows = sharded_forward(d, W, x, gates, dm, R, strided)
    assert a.tobytes() == b.tobytes(), f"bit mismatch case {case} R={R}"
    pb, pc = plan_bytes(d, R, strided, dm)
    assert measured == pb and cross_rows == pc, \
        f"bytes case {case}: measured {measured} vs plan {pb}"
print("300 fuzz cases OK: round-trip exact, outputs bit-identical, measured == planned bytes")

"""Backward-pass mirror of engine.rs: validates expert_backward math
(silu grad, W1/W2/b1/b2 accumulation) against numeric gradients, and
training parity single vs sharded (accumulation-order argument)."""
import numpy as np

def silu(a): return a/(1+np.exp(-a))

def fwd(p, x):
    a = p['w1'] @ x + p['b1']
    z = silu(a)
    return p['w2'] @ z + p['b2']

def bwd_row(p, g, x, dy):
    # mirrors expert_backward in engine.rs exactly
    a = p['w1'] @ x + p['b1']
    z = silu(a)
    g['b2'] += dy
    g['w2'] += np.outer(dy, z)
    dz = p['w2'].T @ dy
    sig = 1/(1+np.exp(-a))
    da = dz * sig * (1 + a*(1-sig))
    g['b1'] += da
    g['w1'] += np.outer(da, x)

def zeros(d, h):
    return dict(w1=np.zeros((h, d)), b1=np.zeros(h),
                w2=np.zeros((d, h)), b2=np.zeros(d))

rng = np.random.default_rng(0)
d, h = 5, 7
p = dict(w1=rng.standard_normal((h, d)), b1=rng.standard_normal(h),
         w2=rng.standard_normal((d, h)), b2=rng.standard_normal(d))
x = rng.standard_normal(d)
dy = rng.standard_normal(d)
g = zeros(d, h)
bwd_row(p, g, x, dy)

# numeric check of every parameter gradient (loss = dy . y)
eps = 1e-6
for name in ['w1', 'b1', 'w2', 'b2']:
    num = np.zeros_like(p[name])
    it = np.nditer(p[name], flags=['multi_index'])
    for _ in it:
        idx = it.multi_index
        orig = p[name][idx]
        p[name][idx] = orig + eps; lp = dy @ fwd(p, x)
        p[name][idx] = orig - eps; lm = dy @ fwd(p, x)
        p[name][idx] = orig
        num[idx] = (lp - lm) / (2*eps)
    err = np.max(np.abs(num - g[name])) / (np.max(np.abs(num)) + 1e-12)
    assert err < 1e-6, f"{name} grad mismatch: rel err {err}"
print("expert_backward matches numeric gradients for w1/b1/w2/b2")

# accumulation-order parity: per-expert grads summed in segment order on
# one rank vs the same segment order within a shard — identical sequences
# of float ops, so parity is structural; sanity-check float32 here
rows = [rng.standard_normal(d).astype(np.float32) for _ in range(6)]
dys = [rng.standard_normal(d).astype(np.float32) for _ in range(6)]
p32 = {k: v.astype(np.float32) for k, v in p.items()}
ga, gb = zeros(d, h), zeros(d, h)
ga = {k: v.astype(np.float32) for k, v in ga.items()}
gb = {k: v.astype(np.float32) for k, v in gb.items()}
for i in range(6):
    bwd_row(p32, ga, rows[i], dys[i])      # "single rank": all 6 rows
for i in range(6):
    bwd_row(p32, gb, rows[i], dys[i])      # "sharded": same segment order
for k in ga:
    assert ga[k].tobytes() == gb[k].tobytes()
print("segment-order gradient accumulation is bit-stable")

//! Quickstart: load an AOT-compiled MoEBlaze layer and run a forward pass.
//!
//! ```text
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Demonstrates the full three-layer composition on one MoE layer
//! (conf1, SwiGLU, MoEBlaze implementation with the Pallas kernels
//! lowered into the HLO): the Rust coordinator loads the artifact,
//! compiles it on the PJRT CPU client, feeds random tokens and expert
//! weights, and reads the (L, d) output back — no Python anywhere.

use anyhow::Result;
use moeblaze::bench_harness::inputs_from_specs;
use moeblaze::runtime::client::Runtime;
use moeblaze::runtime::host::HostTensor;

fn main() -> Result<()> {
    let runtime = Runtime::new(&moeblaze::artifacts_dir())?;
    println!("platform: {}", runtime.platform());

    let exe = runtime.load("layer_fwd_conf1_swiglu_moeblaze")?;
    println!(
        "loaded `{}` ({} inputs, compiled in {:.0} ms)",
        exe.name,
        exe.inputs.len(),
        exe.compile_ms
    );
    for spec in &exe.inputs {
        println!("  input  {:12} {:?}", spec.name, spec.shape);
    }

    // Random x and expert weights, shaped by the manifest.
    let inputs = inputs_from_specs(&exe.inputs, 42);
    let outputs = exe.run(&inputs)?;

    let y = &outputs[0];
    let data = y.as_f32()?;
    let l2: f32 = data.iter().map(|v| v * v).sum::<f32>().sqrt();
    println!("\noutput y: shape {:?}", y.shape());
    println!("  first row: {:?}", &data[..8.min(data.len())]);
    println!("  ||y||_2 = {l2:.4}");
    assert!(data.iter().all(|v| v.is_finite()), "non-finite output");

    // The same layer, driven twice, must be deterministic.
    let outputs2 = exe.run(&inputs)?;
    assert_eq!(outputs2[0].as_f32()?, data, "non-deterministic execution");
    println!("\ndeterminism check passed — quickstart OK");
    Ok(())
}

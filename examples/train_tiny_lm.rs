//! End-to-end training driver (EXPERIMENTS.md §E2E).
//!
//! Trains the manifest's MoE transformer LM (2 layers, 8 experts, top-2,
//! SwiGLU, MoEBlaze layer with the Pallas kernels lowered into the step
//! HLO) for a few hundred steps on a synthetic structured corpus, from
//! the Rust coordinator through the AOT train-step executable. Proves all
//! three layers compose: L1 Pallas kernels inside the L2 jax train step,
//! driven by the L3 orchestrator (data pipeline, LR schedule, metrics,
//! checkpointing) with Python nowhere at runtime.
//!
//! ```text
//! make artifacts && cargo run --release --example train_tiny_lm -- \
//!     [--steps 300] [--lr 1e-3] [--metrics runs/tiny.jsonl]
//! ```
//!
//! Success criterion: final EMA loss well below the corpus' unigram
//! entropy (~2.3 nats for the structured digit corpus) and strictly
//! below the initial loss (~ln 256 ≈ 5.55).

use anyhow::Result;
use moeblaze::config::train::TrainConfig;
use moeblaze::coordinator::params::ParamStore;
use moeblaze::coordinator::trainer::Trainer;
use moeblaze::data::batcher::Batcher;
use moeblaze::data::corpus::structured_corpus;
use moeblaze::data::tokenizer::ByteTokenizer;
use moeblaze::runtime::client::Runtime;
use moeblaze::util::cli::Args;
use moeblaze::util::prng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    let mut cfg = TrainConfig {
        steps: 300,
        lr: 1.5e-3,
        warmup_steps: 20,
        eval_every: 50,
        log_every: 10,
        checkpoint_every: 100,
        checkpoint_dir: "runs/tiny_lm_ckpt".into(),
        metrics_path: "runs/tiny_lm.jsonl".into(),
        ..TrainConfig::default()
    };
    cfg.steps = args.usize_or("steps", cfg.steps).map_err(anyhow::Error::msg)?;
    cfg.lr = args.f64_or("lr", cfg.lr).map_err(anyhow::Error::msg)?;
    if let Some(p) = args.get("metrics") {
        cfg.metrics_path = p.into();
    }

    let runtime = Runtime::new(&moeblaze::artifacts_dir())?;
    println!("platform: {}", runtime.platform());
    let lm = runtime.manifest.lm.clone().expect("manifest lm section");
    println!(
        "model: {} params / {} tensors | batch {} | seq {} | experts {} top-{} ({})",
        lm.num_params(),
        lm.params.len(),
        lm.batch,
        lm.seq_len(),
        lm.config.get("num_experts").and_then(|j| j.as_i64()).unwrap_or(0),
        lm.config.get("top_k").and_then(|j| j.as_i64()).unwrap_or(0),
        lm.config.get("activation").and_then(|j| j.as_str()).unwrap_or("?"),
    );

    // synthetic but *learnable* corpus (see data::corpus docs)
    let tok = ByteTokenizer;
    let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
    let corpus = structured_corpus(&mut rng, 1 << 20);
    let ids = tok.encode(&corpus);
    let split = ids.len() * 9 / 10;
    let mut train_b = Batcher::new(ids[..split].to_vec(), lm.batch, lm.seq_len(), cfg.seed)
        .map_err(anyhow::Error::msg)?;
    let mut eval_b = Batcher::new(ids[split..].to_vec(), lm.batch, lm.seq_len(), cfg.seed + 1)
        .map_err(anyhow::Error::msg)?;

    let store = ParamStore::init(&lm, cfg.seed);
    let mut trainer = Trainer::new(&runtime, store, cfg)?;
    let report = trainer.run(&mut train_b, &mut eval_b)?;

    println!("\n=== loss curve (every 10th step) ===");
    for (s, l) in report.losses.iter().step_by(10) {
        let bar = "#".repeat((l * 12.0).min(70.0) as usize);
        println!("{s:>5} {l:7.4} {bar}");
    }
    println!("\nsteps {} | loss {:.4} -> {:.4} | {:.0} tok/s | {:.1} ms/step",
             report.steps, report.first_loss, report.final_loss_ema,
             report.tokens_per_sec, report.step_ms_mean);

    anyhow::ensure!(report.final_loss_ema < report.first_loss - 0.5,
                    "loss did not decrease enough");
    println!("train_tiny_lm OK (loss decreased by {:.2} nats)",
             report.first_loss - report.final_loss_ema);
    Ok(())
}

//! Dispatch playground: the paper's §4 data structures, three ways.
//!
//! 1. Reproduces Figure 2's worked example (L=6 tokens in the figure's
//!    prose — 5 with listed assignments — E=4, k=2) with the Rust 3-step
//!    builder and checks it against the paper's printed arrays.
//! 2. Cross-checks the Rust builder against the Pallas dispatch kernel
//!    through the `dispatch_build_conf3` AOT artifact (same topk ids in,
//!    same structures out) — proving the L1 kernel and the L3 twin agree.
//! 3. Runs the expert-parallel planner on the result.
//!
//! ```text
//! make artifacts && cargo run --release --example dispatch_playground
//! ```

use anyhow::Result;
use moeblaze::coordinator::expert_parallel::EpTopology;
use moeblaze::dispatch::gating::synthetic_gating;
use moeblaze::dispatch::parallel_build::parallel_build_with_stats;
use moeblaze::dispatch::sort_build::sort_build;
use moeblaze::runtime::client::Runtime;
use moeblaze::runtime::host::HostTensor;
use moeblaze::util::prng::Rng;
use moeblaze::util::table::human_bytes;

fn main() -> Result<()> {
    // --- 1. paper Figure 2 ---------------------------------------------
    println!("== paper Figure 2 worked example ==");
    let ids = vec![2u32, 3, 0, 1, 0, 3, 1, 2, 0, 3]; // tokens 0..4, k=2
    let (d, stats) = parallel_build_with_stats(&ids, 5, 4, 2, 1);
    d.validate().map_err(anyhow::Error::msg)?;
    println!("token_expert_indices = {:?}", d.token_expert_indices);
    println!("expert_token_indices = {:?}", d.expert_token_indices);
    println!("expert_token_offsets = {:?}", d.expert_token_offsets);
    println!("token_index_map[0]   = {:?}  (paper: {{5, 7}})", &d.token_index_map[0..2]);
    assert_eq!(d.expert_token_indices, vec![1, 2, 4, 1, 3, 0, 3, 0, 2, 4]);
    assert_eq!(d.expert_token_offsets, vec![0, 3, 5, 7, 10]);
    assert_eq!(&d.token_index_map[0..2], &[5, 7]);
    assert_eq!(sort_build(&ids, 5, 4, 2), d, "3-step build must equal sort build");
    println!("matches the paper ✓ ({} passes, {} metadata)\n",
             stats.data_passes, human_bytes(d.metadata_bytes() as u64));

    // --- 2. Rust twin vs Pallas kernel (through the AOT artifact) -------
    println!("== Rust 3-step builder vs Pallas dispatch kernel (conf3) ==");
    let runtime = Runtime::new(&moeblaze::artifacts_dir())?;
    let exe = runtime.load("dispatch_build_conf3")?;
    let spec = &exe.inputs[0];
    let (l, k) = (spec.shape[0], spec.shape[1]);
    let e = runtime.manifest.get("dispatch_build_conf3")?
        .meta_usize("experts").unwrap();
    let block = runtime.manifest.get("dispatch_build_conf3")?
        .meta_usize("block").unwrap();

    let mut rng = Rng::new(1234);
    let gating = synthetic_gating(&mut rng, l, e, k, 0.7);
    let ids_i32: Vec<i32> = gating.topk_ids.iter().map(|&x| x as i32).collect();
    let out = exe.run(&[HostTensor::i32(vec![l, k], ids_i32)?])?;

    // Rust twin on the same ids
    let rust = moeblaze::dispatch::parallel_build::parallel_build(
        &gating.topk_ids, l, e, k);
    rust.validate().map_err(anyhow::Error::msg)?;

    // compare expert lengths + compact offsets
    let kernel_lengths = out[0].as_i32()?;
    for (ei, &len) in kernel_lengths.iter().enumerate() {
        assert_eq!(len as usize, rust.expert_len(ei), "expert {ei} length");
    }
    // padded expert_token_indices from the kernel must contain exactly the
    // Rust twin's per-expert token lists (pads are -1)
    let pad_offsets = out[1].as_i32()?;
    let pad_eti = out[2].as_i32()?;
    for ei in 0..e {
        let lo = pad_offsets[ei] as usize;
        let tokens: Vec<u32> = (lo..lo + rust.expert_len(ei))
            .map(|s| pad_eti[s] as u32)
            .collect();
        assert_eq!(tokens.as_slice(), rust.expert_tokens(ei), "expert {ei} tokens");
    }
    println!("Pallas kernel ≡ Rust twin on L={l} E={e} k={k} block={block} ✓\n");

    // --- 3. expert-parallel plan -----------------------------------------
    println!("== expert-parallel all-to-all plan (4 ranks) ==");
    let topo = EpTopology::new(4, e).map_err(anyhow::Error::msg)?;
    let plan = topo.plan(&rust, 128, 2);
    println!("cross-rank traffic {} | imbalance {:.3} | dropless: 0 dropped",
             human_bytes(plan.cross_rank_bytes()), plan.imbalance());
    for gamma in [1.0, 1.25] {
        println!("  capacity-router at γ={gamma}: {} tokens dropped",
                 plan.dropped_under_capacity(gamma));
    }
    println!("\ndispatch_playground OK");
    Ok(())
}

//! Regenerate every figure of the paper's evaluation (§6) in one run.
//!
//! ```text
//! make artifacts && cargo build --release
//! cargo run --release --example paper_figures             # all figures
//! cargo run --release --example paper_figures -- --skip-speed   # memory only
//! ```
//!
//! * Figure 3 — activation memory, SiLU  (analytic, full paper scale)
//! * Figure 4 — training speedup, SiLU   (measured, scaled configs)
//! * Figure 5 — activation memory, SwiGLU
//! * Figure 6 — training speedup, SwiGLU
//! * Table 1 is printed by `moeblaze configs`.
//!
//! Results are appended as JSON lines to `runs/figures.jsonl` for
//! EXPERIMENTS.md bookkeeping.

use anyhow::Result;
use moeblaze::bench_harness as bh;
use moeblaze::config::model::Activation;
use moeblaze::memory::model::AccountingMode;
use moeblaze::memory::report::{memory_figure, render_memory_figure};
use moeblaze::runtime::client::Runtime;
use moeblaze::util::cli::Args;
use moeblaze::util::stats::Bench;

fn main() -> Result<()> {
    let args = Args::from_env().map_err(anyhow::Error::msg)?;
    std::fs::create_dir_all("runs").ok();
    let mut log = String::new();

    // ---- memory figures (3, 5) -----------------------------------------
    for (fig, act) in [("Figure 3", Activation::Silu), ("Figure 5", Activation::Swiglu)] {
        for (mode, label) in [
            (AccountingMode::Ours, "exact residual accounting"),
            (AccountingMode::PaperBaseline, "paper-baseline accounting"),
        ] {
            let rows = memory_figure(act, mode, true);
            println!("{}", render_memory_figure(
                &format!("{fig} — activation memory, {} ({label}, paper scale)",
                         act.name()),
                &rows));
            for r in &rows {
                log.push_str(&format!(
                    "{{\"figure\":\"{fig}\",\"mode\":\"{label}\",\"config\":\"{}\",\"baseline\":{},\"moeblaze\":{},\"ratio\":{:.3}}}\n",
                    r.config, r.baseline, r.moeblaze, r.ratio()));
            }
        }
    }

    // ---- speed figures (4, 6) -------------------------------------------
    if !args.has("skip-speed") {
        let runtime = Runtime::new(&moeblaze::artifacts_dir())?;
        println!("platform: {}\n", runtime.platform());
        let bench = if args.has("full") { Bench::default() } else { Bench::quick() };
        for (fig, act) in [("Figure 4", Activation::Silu), ("Figure 6", Activation::Swiglu)] {
            let cells = bh::speed_figure(&runtime, act, &bench, None)?;
            println!("{}", bh::render_speed_figure(
                &format!("{fig} — fwd+bwd step time, {} (scaled configs)", act.name()),
                &cells));
            log.push_str(&bh::speed_figure_json(act, &cells));
            log.push('\n');
        }
    }

    std::fs::write("runs/figures.jsonl", &log)?;
    println!("wrote runs/figures.jsonl");
    Ok(())
}

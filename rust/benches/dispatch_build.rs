//! Bench: dispatch-structure construction — sort-build vs the paper's
//! 3-step build (§4.2), over an L·k sweep and an expert-count sweep.
//!
//! The paper's argument is about *data movement*: radix sort makes
//! multiple O(n) global passes while the 3-step build makes a constant
//! number. On this single-core host wall-time gaps are secondary to the
//! reported pass/byte counts, both are printed.
//!
//! Run: `cargo bench --bench dispatch_build`

use moeblaze::dispatch::gating::synthetic_gating;
use moeblaze::dispatch::parallel_build::parallel_build_with_stats;
use moeblaze::dispatch::sort_build::sort_build;
use moeblaze::util::prng::Rng;
use moeblaze::util::stats::Bench;
use moeblaze::util::table::Table;

fn main() {
    let bench = Bench { warmup: 1, min_samples: 5, max_samples: 15,
                        max_total: std::time::Duration::from_secs(6) };

    println!("== L sweep (E=16, k=4, mildly skewed routing) ==");
    let mut t = Table::new(["L", "n=L*k", "sort-build", "3-step build", "speedup",
                            "passes", "MiB moved"]);
    for l in [1 << 10, 1 << 12, 1 << 14, 1 << 16, 1 << 18] {
        let (e, k) = (16usize, 4usize);
        let mut rng = Rng::new(l as u64);
        let ids = synthetic_gating(&mut rng, l, e, k, 0.7).topk_ids;
        let s_sort = bench.run(|| {
            std::hint::black_box(sort_build(&ids, l, e, k));
        });
        let s_par = bench.run(|| {
            std::hint::black_box(parallel_build_with_stats(&ids, l, e, k, 1));
        });
        let (_, stats) = parallel_build_with_stats(&ids, l, e, k, 1);
        t.row([
            l.to_string(),
            (l * k).to_string(),
            format!("{:.3} ms", s_sort.mean_ms()),
            format!("{:.3} ms", s_par.mean_ms()),
            format!("{:.2}x", s_sort.mean_ns / s_par.mean_ns),
            stats.data_passes.to_string(),
            format!("{:.1}", stats.bytes_moved as f64 / (1024.0 * 1024.0)),
        ]);
    }
    println!("{}", t.render());

    println!("== E sweep (L=65536, k=4) ==");
    let mut t = Table::new(["E", "sort-build", "3-step build", "speedup"]);
    for e in [8usize, 16, 32, 64] {
        let (l, k) = (1usize << 16, 4usize);
        let mut rng = Rng::new(e as u64);
        let ids = synthetic_gating(&mut rng, l, e, k, 0.7).topk_ids;
        let s_sort = bench.run(|| {
            std::hint::black_box(sort_build(&ids, l, e, k));
        });
        let s_par = bench.run(|| {
            std::hint::black_box(parallel_build_with_stats(&ids, l, e, k, 1));
        });
        t.row([
            e.to_string(),
            format!("{:.3} ms", s_sort.mean_ms()),
            format!("{:.3} ms", s_par.mean_ms()),
            format!("{:.2}x", s_sort.mean_ns / s_par.mean_ns),
        ]);
    }
    println!("{}", t.render());

    // equality sanity on the largest case
    let (l, e, k) = (1usize << 16, 16usize, 4usize);
    let mut rng = Rng::new(99);
    let ids = synthetic_gating(&mut rng, l, e, k, 0.7).topk_ids;
    assert_eq!(sort_build(&ids, l, e, k),
               parallel_build_with_stats(&ids, l, e, k, 1).0);
    println!("equality check (L=65536): OK");
}

//! Bench: the expert-parallel all-to-all, executed (not estimated).
//!
//! Part 1 sweeps rank counts × router skew × placement policy, runs the
//! sharded engine's dispatch→compute→combine forward with real buffer
//! packing, and reports *measured* exchanged bytes (asserted equal to
//! the analytic plan on every combination), load imbalance, and
//! exchange bandwidth.
//!
//! Part 2 sweeps the step-session axes: checkpoint policy × grad_accum,
//! running full forward+backward sessions and reporting the *peak*
//! data-class bytes any microbatch session held across the fwd→bwd
//! boundary (the engine's per-session accounting, sampled while the
//! saved tensors are resident — the paper's saved-tensor metric, so
//! transient backward re-materialization under `recompute-all` shows up
//! in the `recompute bytes` column, not in peak data).
//!
//! Run: `cargo bench --bench ep_alltoall`

use moeblaze::config::ep::Placement;
use moeblaze::coordinator::engine::{ExecutionEngine, ShardedEngine, StepBatch};
use moeblaze::coordinator::expert_parallel::EpTopology;
use moeblaze::coordinator::params::ExpertStore;
use moeblaze::coordinator::pipeline::timeline::CostModel;
use moeblaze::coordinator::pipeline::PipelinedEngine;
use moeblaze::dispatch::gating::synthetic_gating;
use moeblaze::dispatch::parallel_build::parallel_build;
use moeblaze::memory::model::CheckpointPolicy;
use moeblaze::metrics::{Peak, Throughput};
use moeblaze::util::json::Json;
use moeblaze::util::prng::Rng;
use moeblaze::util::stats::Bench;
use moeblaze::util::table::{human_bytes, Table};

fn main() {
    let (l, e, k, d, h) = (2048usize, 16usize, 2usize, 32usize, 64usize);
    let bench = Bench::quick();
    let store = ExpertStore::init(e, d, h, 7);

    for (skew_label, skew) in [("balanced", 0.0), ("skewed", 1.5)] {
        let mut rng = Rng::new(42);
        let gating = synthetic_gating(&mut rng, l, e, k, skew);
        let disp = parallel_build(&gating.topk_ids, l, e, k);
        let x = rng.normal_vec(l * d, 1.0);
        let batch = StepBatch::new(disp, x, gating.gates).expect("batch");

        println!("== L={l} E={e} k={k} d={d} — {skew_label} routing (skew {skew}) ==");
        // "step bw": comm bytes over the whole fwd step (incl. expert
        // compute) — an effective rate, not isolated link bandwidth
        let mut t = Table::new(["ranks", "placement", "cross bytes", "local rows",
                                "imbalance", "fwd", "step bw"]);
        for placement in [Placement::Contiguous, Placement::Strided] {
            for ranks in [1usize, 2, 4, 8] {
                let topo = EpTopology::with_placement(ranks, e, placement)
                    .expect("topology");
                let plan = topo.plan(batch.disp(), d, 4);
                let mut engine = ShardedEngine::new(topo, &store, ranks)
                    .expect("engine");
                let s = bench.run(|| {
                    std::hint::black_box(engine.forward(&batch).expect("fwd"));
                });
                let traffic = engine.traffic();
                assert_eq!(traffic.dispatch_bytes, plan.cross_rank_bytes(),
                           "measured bytes diverged from the plan at R={ranks}");
                let mut tp = Throughput::new();
                tp.record(traffic.dispatch_bytes + traffic.combine_bytes, s.mean_ns / 1e9);
                t.row([
                    ranks.to_string(),
                    placement.name().to_string(),
                    human_bytes(traffic.dispatch_bytes),
                    traffic.local_rows.to_string(),
                    format!("{:.3}", plan.imbalance()),
                    format!("{:.3} ms", s.mean_ms()),
                    tp.format_brief(),
                ]);
            }
        }
        println!("{}", t.render());
        assert_eq!(batch.copy_count(), 0, "sweep deep-copied the workload");
    }
    println!("measured == planned cross-rank bytes on every combination ✓");

    policy_accum_matrix(&store, l, e, k, d, h);
    packed_vs_indexed_matrix(&store, l, e, k, d);
    pipeline_overlap_matrix(&store, l, e, k, d);
    stack_planner_matrix(l, e, k, d, h);
}

/// Old-vs-new hot path (PR 5): the packed row-dot baseline against the
/// index-driven blocked engines, fwd+bwd, same worker count, outputs and
/// gradients asserted bit-identical before any timing. One JSON line per
/// cell (the machine-readable trajectory `tools/bench_snapshot.py`
/// complements from `ep-bench --json-out`).
fn packed_vs_indexed_matrix(store: &ExpertStore, l: usize, e: usize, k: usize,
                            d: usize) {
    use moeblaze::coordinator::engine::PackedReference;
    use moeblaze::dispatch::RowIndexPlan;

    let mut rng = Rng::new(23);
    let gating = synthetic_gating(&mut rng, l, e, k, 0.7);
    let disp = parallel_build(&gating.topk_ids, l, e, k);
    let x = rng.normal_vec(l * d, 1.0);
    let batch = StepBatch::new(disp, x, gating.gates).expect("batch");
    let d_out = rng.normal_vec(l * d, 1.0);
    let bench = Bench::quick();
    let policy = CheckpointPolicy::default();

    println!("== zero-materialization dispatch vs packed baseline \
              (fwd+bwd, {policy}) ==");
    let mut t = Table::new(["ranks", "old fwd+bwd", "new fwd+bwd", "speedup",
                            "old peak comm", "new peak comm"]);
    for ranks in [1usize, 2, 4, 8] {
        let topo = EpTopology::new(ranks, e).expect("topology");
        // plan built once outside the timed loop — the fair baseline
        // (the retired engines cached plans per batch id)
        let packed = PackedReference::new(&topo, &batch).expect("packed plan");
        let (old_out, old_grads) = packed
            .step(store, &batch, &d_out, policy, ranks)
            .expect("packed baseline");
        let mut eng = ShardedEngine::with_policy(topo.clone(), store, ranks,
                                                 policy)
            .expect("engine");
        let handle = eng.forward(&batch).expect("fwd");
        assert!(handle
                    .output()
                    .iter()
                    .zip(&old_out)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "R={ranks}: indexed output diverged from the packed baseline");
        let new_grads = handle.backward(&mut eng, &d_out).expect("bwd");
        assert_eq!(new_grads, old_grads,
                   "R={ranks}: indexed grads diverged from the packed baseline");

        let s_old = bench.run(|| {
            std::hint::black_box(
                packed
                    .step(store, &batch, &d_out, policy, ranks)
                    .expect("packed baseline"),
            );
        });
        let s_new = bench.run(|| {
            let handle = eng.forward(&batch).expect("fwd");
            let mut g = eng.zero_grads();
            handle.backward_into(&mut eng, &d_out, &mut g).expect("bwd");
            std::hint::black_box(&g);
        });
        let speedup = s_old.mean_ns / s_new.mean_ns;

        let token_rank: Vec<u32> =
            (0..l).map(|tk| topo.rank_of_token(tk, l) as u32).collect();
        let rplan = RowIndexPlan::build(batch.disp(), ranks,
                                        &topo.assignment().rank_of,
                                        &token_rank)
            .expect("row plan");
        let old_extra: u64 = (0..ranks)
            .map(|rank| rplan.packed_buffer_bytes(rank, d, 4))
            .max()
            .unwrap_or(0);
        let new_extra: u64 = eng
            .memory_per_rank()
            .iter()
            .map(|m| m.extra_bytes)
            .max()
            .unwrap_or(0);
        if ranks > 1 {
            assert!(new_extra < old_extra,
                    "R={ranks}: staging {new_extra} not below packed \
                     {old_extra}");
        }
        t.row([
            ranks.to_string(),
            format!("{:.3} ms", s_old.mean_ms()),
            format!("{:.3} ms", s_new.mean_ms()),
            format!("{speedup:.2}x"),
            human_bytes(old_extra),
            human_bytes(new_extra),
        ]);
        let tokens_s_old = l as f64 / (s_old.mean_ns / 1e9);
        let tokens_s_new = l as f64 / (s_new.mean_ns / 1e9);
        let cell = Json::obj(vec![
            ("bench", Json::str("ep_packed_vs_indexed")),
            ("ranks", Json::num(ranks as f64)),
            ("speedup", Json::num(speedup)),
            ("old_tokens_per_sec", Json::num(tokens_s_old)),
            ("new_tokens_per_sec", Json::num(tokens_s_new)),
            ("old_peak_comm_bytes", Json::num(old_extra as f64)),
            ("new_peak_comm_bytes", Json::num(new_extra as f64)),
        ]);
        println!("{cell}");
    }
    println!("{}", t.render());
    println!("indexed path bit-identical to the packed baseline on every \
              rank count ✓");
}

/// Checkpoint-policy × grad_accum matrix: full fwd+bwd sessions, peak
/// resident data bytes per policy (high-water mark across microbatches).
fn policy_accum_matrix(store: &ExpertStore, l: usize, e: usize, k: usize, d: usize, h: usize) {
    let ranks = 4usize;
    let mut rng = Rng::new(11);
    let gating = synthetic_gating(&mut rng, l, e, k, 0.7);
    let disp = parallel_build(&gating.topk_ids, l, e, k);
    let x = rng.normal_vec(l * d, 1.0);
    let batch = StepBatch::new(disp, x, gating.gates).expect("batch");
    let d_out_full = rng.normal_vec(l * d, 1.0);
    let bench = Bench::quick();

    println!("== step-session matrix: policy × grad_accum (R={ranks}, L={l}) ==");
    let mut t = Table::new(["policy", "accum", "peak data", "peak/slot",
                            "recompute bytes", "fwd+bwd"]);
    let mut peak_by_policy = Vec::new();
    for policy in CheckpointPolicy::ALL {
        let mut policy_peak = 0u64;
        for accum in [1usize, 2, 4] {
            let topo = EpTopology::new(ranks, e).expect("topology");
            let mut engine = ShardedEngine::with_policy(topo, store, ranks, policy)
                .expect("engine");
            let micros = batch.split(accum).expect("split");
            let mut peak = Peak::new();
            let mut recompute = 0u64;
            let s = bench.run(|| {
                let mut grads = engine.zero_grads();
                for (off, mb) in &micros {
                    let handle = engine.forward(mb).expect("fwd");
                    let data: u64 = engine
                        .memory_per_rank()
                        .iter()
                        .map(|m| m.data_bytes)
                        .sum();
                    peak.observe(data);
                    let lm = mb.num_tokens();
                    let d_out = &d_out_full[*off * d..(*off + lm) * d];
                    handle
                        .backward_into(&mut engine, d_out, &mut grads)
                        .expect("bwd");
                }
                recompute = engine.traffic().recompute_bytes;
                std::hint::black_box(&grads);
            });
            policy_peak = policy_peak.max(peak.get());
            t.row([
                policy.name().to_string(),
                accum.to_string(),
                human_bytes(peak.get()),
                human_bytes(peak.get() / (l as u64 * k as u64 / accum as u64)),
                human_bytes(recompute),
                format!("{:.3} ms", s.mean_ms()),
            ]);
            for (_, mb) in &micros {
                assert_eq!(mb.copy_count(), 0, "matrix deep-copied a microbatch");
            }
        }
        peak_by_policy.push(policy_peak);
    }
    println!("{}", t.render());
    assert!(peak_by_policy[0] > peak_by_policy[1]
                && peak_by_policy[1] > peak_by_policy[2],
            "peak data bytes not strictly decreasing across policies: \
             {peak_by_policy:?}");
    println!("peak data bytes strictly decrease save-all → save-inputs → \
              recompute-all ✓ (h={h})");
}

/// Chunks × policy overlap matrix: full fwd+bwd through the pipelined
/// engine, outputs re-verified against the barrier engine, one JSON line
/// per cell (the machine-readable artifact the CI tooling consumes).
fn pipeline_overlap_matrix(store: &ExpertStore, l: usize, e: usize, k: usize,
                           d: usize) {
    let ranks = 4usize;
    let mut rng = Rng::new(19);
    let gating = synthetic_gating(&mut rng, l, e, k, 0.7);
    let disp = parallel_build(&gating.topk_ids, l, e, k);
    let x = rng.normal_vec(l * d, 1.0);
    let batch = StepBatch::new(disp, x, gating.gates).expect("batch");
    let d_out = rng.normal_vec(l * d, 1.0);
    let cost = CostModel::default();

    let topo = EpTopology::new(ranks, e).expect("topology");
    let mut barrier = ShardedEngine::new(topo.clone(), store, ranks)
        .expect("barrier engine");
    let reference = barrier.forward(&batch).expect("fwd").into_output();

    println!("== chunk-pipeline overlap: chunks × policy (R={ranks}, L={l}, \
              link {} GB/s, compute {} GFLOP/s) ==",
             cost.link_gbps, cost.compute_gflops);
    let mut t = Table::new(["policy", "chunks", "critical", "serial",
                            "exposed comm", "overlap eff", "peak comm buf"]);
    for policy in CheckpointPolicy::ALL {
        for chunks in [1usize, 2, 4, 8] {
            let mut engine = PipelinedEngine::with_policy(
                topo.clone(), store, ranks, policy, chunks, cost)
                .expect("pipelined engine");
            let handle = engine.forward(&batch).expect("fwd");
            assert!(handle
                        .output()
                        .iter()
                        .zip(&reference)
                        .all(|(a, b)| a.to_bits() == b.to_bits()),
                    "{policy} K={chunks}: pipelined output diverged");
            let mut grads = engine.zero_grads();
            handle
                .backward_into(&mut engine, &d_out, &mut grads)
                .expect("bwd");
            let rep = engine.overlap_report().expect("report");
            let peak_extra: u64 = engine
                .memory_per_rank()
                .iter()
                .map(|m| m.extra_bytes)
                .sum();
            t.row([
                policy.name().to_string(),
                chunks.to_string(),
                format!("{:.3} ms", rep.critical_path_s * 1e3),
                format!("{:.3} ms", rep.serial_path_s() * 1e3),
                format!("{:.1}%", 100.0 * rep.exposed_comm_fraction()),
                format!("{:.1}%", 100.0 * rep.overlap_efficiency()),
                human_bytes(peak_extra),
            ]);
            let cell = Json::obj(vec![
                ("bench", Json::str("ep_pipeline_overlap")),
                ("policy", Json::str(policy.name())),
                ("peak_comm_buffer_bytes", Json::num(peak_extra as f64)),
                ("report", rep.to_json()),
            ]);
            println!("{cell}");
        }
    }
    println!("{}", t.render());
    assert_eq!(batch.copy_count(), 0, "overlap matrix deep-copied the workload");
    println!("pipelined outputs bit-identical to the barrier engine on every \
              cell ✓");
}

/// Stack depth × budget matrix: the planner's per-layer policy vector
/// under shrinking budgets, and the *measured* per-rank peak of a real
/// stacked forward checked against each plan's projection. One JSON
/// line per cell.
fn stack_planner_matrix(l: usize, e: usize, k: usize, d: usize, h: usize) {
    use moeblaze::config::ep::EpConfig;
    use moeblaze::coordinator::engine::step_batch_from_config;
    use moeblaze::coordinator::stack::{plan_from_config, stack_with_plan};

    println!("== multi-layer stack: depth × budget (planner-driven) ==");
    let mut t = Table::new(["layers", "budget", "plan", "projected peak",
                            "measured peak", "extra bwd"]);
    for layers in [1usize, 2, 4] {
        let base = EpConfig {
            num_layers: layers,
            checkpoint_auto: true,
            ranks: 4,
            tokens: l.min(256),
            num_experts: e,
            top_k: k,
            d_model: d,
            d_hidden: h,
            ..EpConfig::default()
        };
        let ceiling = plan_from_config(&base)
            .expect("plan")
            .expect("auto plans")
            .save_all_peak_bytes;
        for frac in [100u64, 75, 55] {
            let budget = ceiling * frac / 100;
            let cfg = EpConfig { mem_budget_bytes: budget, ..base.clone() };
            let plan = plan_from_config(&cfg).expect("plan").expect("auto plans");
            let mut stack = stack_with_plan(&cfg, Some(&plan)).expect("stack");
            let (batch, _) = step_batch_from_config(&cfg).expect("batch");
            let _session = stack.forward(&batch).expect("fwd");
            let measured = stack
                .memory_per_rank()
                .iter()
                .map(|m| m.data_bytes)
                .max()
                .unwrap_or(0);
            assert!(measured <= plan.projected_peak_bytes,
                    "L={layers} budget {budget}: measured {measured} above \
                     the projection {}", plan.projected_peak_bytes);
            assert!(!plan.feasible || plan.projected_peak_bytes <= budget,
                    "L={layers}: feasible plan over budget");
            let summary: Vec<&str> =
                plan.choices.iter().map(|c| c.policy.name()).collect();
            t.row([
                layers.to_string(),
                format!("{frac}% ({})", human_bytes(budget)),
                summary.join(","),
                human_bytes(plan.projected_peak_bytes),
                human_bytes(measured),
                format!("{:.3} ms", plan.extra_time_s * 1e3),
            ]);
            let cell = Json::obj(vec![
                ("bench", Json::str("ep_stack_planner")),
                ("layers", Json::num(layers as f64)),
                ("budget_bytes", Json::num(budget as f64)),
                ("measured_peak_bytes", Json::num(measured as f64)),
                ("plan", plan.to_json()),
            ]);
            println!("{cell}");
        }
    }
    println!("{}", t.render());
    println!("stacked measured per-rank peak never exceeded the planner's \
              projection ✓");
}

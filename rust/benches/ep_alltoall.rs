//! Bench: the expert-parallel all-to-all, executed (not estimated).
//!
//! Sweeps rank counts × router skew × placement policy, runs the sharded
//! engine's dispatch→compute→combine forward with real buffer packing,
//! and reports *measured* exchanged bytes (asserted equal to the analytic
//! plan on every combination), load imbalance, and exchange bandwidth.
//!
//! Run: `cargo bench --bench ep_alltoall`

use moeblaze::config::ep::Placement;
use moeblaze::coordinator::engine::{ExecutionEngine, ShardedEngine};
use moeblaze::coordinator::expert_parallel::EpTopology;
use moeblaze::coordinator::params::ExpertStore;
use moeblaze::dispatch::gating::synthetic_gating;
use moeblaze::dispatch::parallel_build::parallel_build;
use moeblaze::metrics::Throughput;
use moeblaze::util::prng::Rng;
use moeblaze::util::stats::Bench;
use moeblaze::util::table::{human_bytes, Table};

fn main() {
    let (l, e, k, d, h) = (2048usize, 16usize, 2usize, 32usize, 64usize);
    let bench = Bench::quick();
    let store = ExpertStore::init(e, d, h, 7);

    for (skew_label, skew) in [("balanced", 0.0), ("skewed", 1.5)] {
        let mut rng = Rng::new(42);
        let gating = synthetic_gating(&mut rng, l, e, k, skew);
        let disp = parallel_build(&gating.topk_ids, l, e, k);
        let x = rng.normal_vec(l * d, 1.0);

        println!("== L={l} E={e} k={k} d={d} — {skew_label} routing (skew {skew}) ==");
        // "step bw": comm bytes over the whole fwd step (incl. expert
        // compute) — an effective rate, not isolated link bandwidth
        let mut t = Table::new(["ranks", "placement", "cross bytes", "local rows",
                                "imbalance", "fwd", "step bw"]);
        for placement in [Placement::Contiguous, Placement::Strided] {
            for ranks in [1usize, 2, 4, 8] {
                let topo = EpTopology::with_placement(ranks, e, placement)
                    .expect("topology");
                let plan = topo.plan(&disp, d, 4);
                let mut engine = ShardedEngine::new(topo, &store, ranks)
                    .expect("engine");
                let s = bench.run(|| {
                    std::hint::black_box(
                        engine.forward(&disp, &x, &gating.gates).expect("fwd"),
                    );
                });
                let traffic = engine.traffic();
                assert_eq!(traffic.dispatch_bytes, plan.cross_rank_bytes(),
                           "measured bytes diverged from the plan at R={ranks}");
                let mut tp = Throughput::new();
                tp.record(traffic.dispatch_bytes + traffic.combine_bytes,
                          s.mean_ns / 1e9);
                t.row([
                    ranks.to_string(),
                    placement.name().to_string(),
                    human_bytes(traffic.dispatch_bytes),
                    traffic.local_rows.to_string(),
                    format!("{:.3}", plan.imbalance()),
                    format!("{:.3} ms", s.mean_ms()),
                    tp.format_brief(),
                ]);
            }
        }
        println!("{}", t.render());
    }
    println!("measured == planned cross-rank bytes on every combination ✓");
}

//! Figure 5: activation-memory footprint, SwiGLU activation (paper §6.5:
//! "peak activation memory often less than half of the baseline's usage",
//! ≈4x at conf3 under the paper's saved-tensor-hook accounting).
//!
//! Run: `cargo bench --bench fig5_memory_swiglu`

use moeblaze::config::model::Activation;
use moeblaze::memory::model::AccountingMode;
use moeblaze::memory::report::{memory_figure, render_memory_figure};

fn main() {
    for (mode, label) in [
        (AccountingMode::Ours, "exact residual accounting (both impls as built here)"),
        (AccountingMode::PaperBaseline, "paper-baseline accounting (torch-eager extras)"),
    ] {
        let rows = memory_figure(Activation::Swiglu, mode, true);
        println!("{}", render_memory_figure(
            &format!("Figure 5 — activation memory, SwiGLU, paper scale\n[{label}]"),
            &rows));
        assert!(rows.iter().all(|r| r.ratio() > 1.0));
    }
    // paper §6.5 headline: conf3 baseline > 2x moeblaze under paper accounting
    let rows = memory_figure(Activation::Swiglu, AccountingMode::PaperBaseline, true);
    let c3 = rows.iter().find(|r| r.config == "conf3").unwrap();
    assert!(c3.ratio() > 2.0, "conf3 swiglu ratio {:.2}", c3.ratio());
    println!("conf3 swiglu reduction: {:.2}x (paper reports ~4x)", c3.ratio());
}

//! Figure 3: activation-memory footprint, SiLU activation, MoEBlaze vs
//! MegaBlocks-style baseline across the Table-1 configs (paper scale —
//! the accounting is analytic and exact, validated against the real
//! residual pytrees by pytest `test_memory_accounting.py`).
//!
//! Run: `cargo bench --bench fig3_memory_silu`

use moeblaze::config::model::Activation;
use moeblaze::memory::model::AccountingMode;
use moeblaze::memory::report::{memory_figure, render_memory_figure};

fn main() {
    for (mode, label) in [
        (AccountingMode::Ours, "exact residual accounting (both impls as built here)"),
        (AccountingMode::PaperBaseline, "paper-baseline accounting (torch-eager extras)"),
    ] {
        let rows = memory_figure(Activation::Silu, mode, true);
        println!("{}", render_memory_figure(
            &format!("Figure 3 — activation memory, SiLU, paper scale\n[{label}]"),
            &rows));
        // paper shape: moeblaze wins on every config. (Under exact
        // accounting the ratio is nearly flat across configs — k and d/h
        // are constant in Table 1; the paper's per-config variation comes
        // from framework overheads we don't model.)
        assert!(rows.iter().all(|r| r.ratio() > 1.0));
        let c1 = rows.iter().find(|r| r.config == "conf1").unwrap().ratio();
        let c4 = rows.iter().find(|r| r.config == "conf4").unwrap().ratio();
        assert!(c4 > 0.95 * c1, "conf4 ({c4:.2}) far below conf1 ({c1:.2})");
    }
}

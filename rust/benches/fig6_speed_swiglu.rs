//! Figure 6: end-to-end single-layer training-step speedup, SwiGLU,
//! MoEBlaze vs MegaBlocks-style baseline (scaled Table-1 configs; both
//! implementations AOT-compiled to XLA and executed via PJRT).
//!
//! Run: `cargo bench --bench fig6_speed_swiglu`
//! Env: MOEBLAZE_BENCH_CONFIGS=conf1,conf2 to restrict;
//!      MOEBLAZE_BENCH_FULL=1 for more samples.

use moeblaze::bench_harness as bh;
use moeblaze::config::model::Activation;
use moeblaze::runtime::client::Runtime;
use moeblaze::util::stats::Bench;

fn main() {
    let runtime = Runtime::new(&moeblaze::artifacts_dir())
        .expect("run `make artifacts` first");
    eprintln!("platform: {}", runtime.platform());
    let bench = if std::env::var("MOEBLAZE_BENCH_FULL").is_ok() {
        Bench::default()
    } else {
        Bench::quick()
    };
    let only: Option<Vec<String>> = std::env::var("MOEBLAZE_BENCH_CONFIGS")
        .ok()
        .map(|v| v.split(',').map(str::to_string).collect());
    let cells = bh::speed_figure(&runtime, Activation::Swiglu, &bench,
                                 only.as_deref()).expect("bench failed");
    println!("{}", bh::render_speed_figure(
        "Figure 6 — fwd+bwd step time, SwiGLU (scaled Table-1 configs)", &cells));
    println!("{}", bh::speed_figure_json(Activation::Swiglu, &cells));
    // Paper shape: moeblaze should not lose. On this substrate the two
    // impls run identical XLA GEMMs, so wall-clock sits near parity with
    // scheduler noise (EXPERIMENTS.md discusses); flag real regressions
    // only.
    for c in &cells {
        if c.speedup() < 0.7 {
            eprintln!("WARNING {}: speedup {:.2} below noise floor", c.config,
                      c.speedup());
        }
        assert!(c.speedup() > 0.5, "{}: speedup {:.2}", c.config, c.speedup());
    }
}

//! Bench: end-to-end LM training step (the E2E workload of
//! `examples/train_tiny_lm`) + the Pallas-lowering ablation.
//!
//! Reports:
//!   * lm_train_step latency + tokens/s (full 2-layer MoE transformer,
//!     MoEBlaze layers with Pallas kernels, fwd+bwd+Adam in one HLO)
//!   * coordinator overhead: time spent outside the executable
//!   * conf2 swiglu: XLA-fused moeblaze vs interpret-mode Pallas variant
//!
//! Run: `cargo bench --bench e2e_train_step`

use moeblaze::bench_harness::inputs_from_specs;
use moeblaze::runtime::client::Runtime;
use moeblaze::util::stats::Bench;

fn main() {
    let runtime = Runtime::new(&moeblaze::artifacts_dir())
        .expect("run `make artifacts` first");
    eprintln!("platform: {}", runtime.platform());
    let bench = Bench { warmup: 1, min_samples: 3, max_samples: 8,
                        max_total: std::time::Duration::from_secs(30) };

    // --- LM train step ----------------------------------------------------
    let exe = runtime.load("lm_train_step").expect("load lm_train_step");
    let lm = runtime.manifest.lm.as_ref().unwrap();
    let tokens = (lm.batch * lm.seq_len()) as f64;
    let mut inputs = inputs_from_specs(&exe.inputs, 7);
    // step/lr scalars must be sane (they are the 3P and 3P+1 inputs)
    let p = lm.params.len();
    inputs[3 * p] =
        moeblaze::runtime::host::HostTensor::F32 { shape: vec![], data: vec![1.0] };
    inputs[3 * p + 1] =
        moeblaze::runtime::host::HostTensor::F32 { shape: vec![], data: vec![1e-3] };
    let s = bench.run(|| {
        exe.run(&inputs).expect("lm step");
    });
    println!("lm_train_step: {}  ({:.0} tokens/s)", s.format_brief(),
             tokens / (s.mean_ns / 1e9));

    // --- Pallas ablation ----------------------------------------------------
    let fused = runtime.load("layer_step_conf2_swiglu_moeblaze").unwrap();
    let pallas = runtime.load("layer_step_conf2_swiglu_moeblaze_pallas").unwrap();
    let fi = inputs_from_specs(&fused.inputs, 11);
    let pi = inputs_from_specs(&pallas.inputs, 11);
    let sf = bench.run(|| { fused.run(&fi).unwrap(); });
    let sp = bench.run(|| { pallas.run(&pi).unwrap(); });
    println!("conf2 swiglu moeblaze, XLA-fused lowering:      {}", sf.format_brief());
    println!("conf2 swiglu moeblaze, interpret-mode Pallas:   {}", sp.format_brief());
    println!("interpret-mode overhead: {:.2}x (lowering artifact — see EXPERIMENTS.md)",
             sp.mean_ns / sf.mean_ns);
}

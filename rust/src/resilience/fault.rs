//! Deterministic fault injection: seeded plans, typed events, bounded
//! recovery.
//!
//! A [`FaultPlan`] turns the `[fault]` config into a pure decision
//! oracle: whether step `s` stalls a rank, whether attempt `a` of
//! microbatch `m`'s exchange transiently fails, whether the snapshot
//! written at step `s` gets its bytes corrupted — each a splitmix64 mix
//! of `(seed, site-salt, step, lane)`, so the full fault sequence of a
//! run is fixed before it starts and identical across replays. The
//! arithmetic is mirrored bit-for-bit in `tools/ep_sim.py` (pinned
//! decision tables in both suites, the PR-8/PR-9 cross-language
//! contract).
//!
//! The [`FaultInjector`] wraps a plan with the recovery discipline the
//! resilience tests enforce: every injected fault is either *recovered*
//! (bounded retry with exponential backoff for transient faults,
//! last-good-generation fallback for corrupt snapshots) or *surfaced*
//! as a typed [`FaultEvent`] — the trainer and serve loop drain the
//! event queue into `MetricsSink` each step, so silent degradation is
//! structurally impossible. Injection sits in the drivers (trainer /
//! serve loop) around the engine calls, not inside the engine hot
//! paths: all three engine families and the stack are covered through
//! the shared trait, and an unarmed plan costs nothing.

use std::fmt;

use crate::config::fault::FaultConfig;

use super::snapshot::SnapshotStore;

/// Decision-site salts — each fault family draws from its own stream.
const SALT_STALL: u64 = 0x57A11;
const SALT_EXCHANGE: u64 = 0xE8C7A9;
const SALT_SNAPSHOT: u64 = 0x5A4B;

/// splitmix64 finalizer — the one mixing function every decision uses.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Chained mix of one decision site.
fn fault_hash(seed: u64, salt: u64, a: u64, b: u64, c: u64) -> u64 {
    let mut h = mix64(seed ^ salt);
    h = mix64(h ^ a);
    h = mix64(h ^ b);
    mix64(h ^ c)
}

/// Uniform in [0, 1): the top 53 bits of the hash, exactly
/// representable in f64 — Rust and the Python mirror compare the same
/// number against the same threshold.
fn fault_unit(seed: u64, salt: u64, a: u64, b: u64, c: u64) -> f64 {
    (fault_hash(seed, salt, a, b, c) >> 11) as f64 / (1u64 << 53) as f64
}

/// The fault family an event belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// One rank stalled for the configured duration (numerics-neutral).
    RankStall,
    /// A transient exchange failure hit a step/tick's forward path.
    ExchangeTransient,
    /// A just-written snapshot generation had its bytes corrupted.
    SnapshotCorrupt,
}

impl FaultKind {
    pub fn name(self) -> &'static str {
        match self {
            FaultKind::RankStall => "rank_stall",
            FaultKind::ExchangeTransient => "exchange_transient",
            FaultKind::SnapshotCorrupt => "snapshot_corrupt",
        }
    }
}

impl fmt::Display for FaultKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One injected fault, typed and accounted — the unit the metrics
/// stream carries (`fault` events) and the zero-silent-degradation
/// tests count.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    /// optimizer step (training) or tick (serving) the fault hit
    pub step: u64,
    /// stalled rank for `RankStall`; 0 otherwise
    pub rank: usize,
    /// retries the recovery took (`ExchangeTransient`)
    pub retries: u64,
    /// whether the fault was absorbed (retry succeeded / an older good
    /// snapshot generation remains loadable); `false` events make the
    /// run fail loudly
    pub recovered: bool,
}

/// The seeded decision oracle (see the module docs). Pure functions
/// only — the injector layers state (events, sleeps) on top.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub cfg: FaultConfig,
}

impl FaultPlan {
    pub fn new(cfg: FaultConfig) -> FaultPlan {
        FaultPlan { cfg }
    }

    pub fn disabled() -> FaultPlan {
        FaultPlan::new(FaultConfig { seed: 0, ..FaultConfig::default() })
    }

    pub fn enabled(&self) -> bool {
        self.cfg.enabled()
    }

    /// Does step/tick `step` stall a rank?
    pub fn stalls(&self, step: u64) -> bool {
        self.cfg.stall_prob > 0.0
            && fault_unit(self.cfg.seed, SALT_STALL, step, 0, 0)
                < self.cfg.stall_prob
    }

    /// Which of `ranks` ranks the step's stall hits.
    pub fn stall_rank(&self, step: u64, ranks: usize) -> usize {
        (fault_hash(self.cfg.seed, SALT_STALL, step, 1, 0) % ranks.max(1) as u64)
            as usize
    }

    /// Does attempt `attempt` of microbatch `micro`'s exchange at
    /// `step` transiently fail?
    pub fn exchange_fails(&self, step: u64, micro: u64, attempt: u64) -> bool {
        self.cfg.exchange_fail_prob > 0.0
            && fault_unit(self.cfg.seed, SALT_EXCHANGE, step, micro, attempt)
                < self.cfg.exchange_fail_prob
    }

    /// Does the snapshot generation written at `step` get corrupted?
    pub fn corrupts_snapshot(&self, step: u64) -> bool {
        self.cfg.snapshot_corrupt_prob > 0.0
            && fault_unit(self.cfg.seed, SALT_SNAPSHOT, step, 0, 0)
                < self.cfg.snapshot_corrupt_prob
    }

    /// How step `step`'s snapshot corruption lands on a `len`-byte
    /// artifact: `(offset, xor)` — `xor == 0` truncates the file at
    /// `offset`, otherwise the byte at `offset` is flipped with it.
    pub fn corruption(&self, step: u64, len: usize) -> (usize, u8) {
        let h = fault_hash(self.cfg.seed, SALT_SNAPSHOT, step, 1, 0);
        let offset = (h % len.max(1) as u64) as usize;
        // truncate every 4th corruption, flip otherwise (never xor 0 —
        // that would be a no-op "corruption")
        let xor = if h >> 62 == 0 { 0 } else { (1 + (h >> 32) % 255) as u8 };
        (offset, xor)
    }
}

/// Stateful wrapper: runs the recovery discipline and accumulates the
/// typed event stream. Drivers drain events into their `MetricsSink`
/// each step; running totals survive the drain for end-of-run reports.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    events: Vec<FaultEvent>,
    /// events raised so far (drained or not)
    pub total: u64,
    /// events whose fault could NOT be absorbed — any nonzero count is
    /// a loud failure at run end
    pub unrecovered: u64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector { plan, events: Vec::new(), total: 0, unrecovered: 0 }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    pub fn enabled(&self) -> bool {
        self.plan.enabled()
    }

    /// Move the undrained events out (running totals persist).
    pub fn drain(&mut self) -> Vec<FaultEvent> {
        std::mem::take(&mut self.events)
    }

    fn record(&mut self, ev: FaultEvent) {
        self.total += 1;
        if !ev.recovered {
            self.unrecovered += 1;
        }
        self.events.push(ev);
    }

    /// Rank-stall injection for step/tick `step`: sleeps the configured
    /// duration and records a recovered event. Numerics-neutral —
    /// returns the stalled rank so serving can flip into shed mode.
    pub fn maybe_stall(&mut self, step: u64, ranks: usize) -> Option<usize> {
        if !self.plan.stalls(step) {
            return None;
        }
        let rank = self.plan.stall_rank(step, ranks);
        if self.plan.cfg.stall_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(
                self.plan.cfg.stall_ms,
            ));
        }
        self.record(FaultEvent {
            kind: FaultKind::RankStall,
            step,
            rank,
            retries: 0,
            recovered: true,
        });
        Some(rank)
    }

    /// Transient-exchange gate for `(step, micro)`: simulates attempt
    /// failures per the plan, sleeping the exponential backoff between
    /// attempts, until an attempt goes through or the retry budget is
    /// spent. Returns the retries taken; an exhausted budget records an
    /// unrecovered event AND errors, so the caller cannot proceed
    /// silently.
    pub fn exchange_gate(&mut self, step: u64, micro: u64) -> Result<u64, String> {
        if self.plan.cfg.exchange_fail_prob <= 0.0 {
            return Ok(0);
        }
        let budget = self.plan.cfg.max_retries as u64;
        let mut attempt = 0u64;
        while self.plan.exchange_fails(step, micro, attempt) {
            if attempt >= budget {
                self.record(FaultEvent {
                    kind: FaultKind::ExchangeTransient,
                    step,
                    rank: 0,
                    retries: attempt,
                    recovered: false,
                });
                return Err(format!(
                    "exchange failed at step {step} micro {micro}: retry \
                     budget {budget} exhausted"
                ));
            }
            if self.plan.cfg.backoff_ms > 0 {
                let shift = attempt.min(6) as u32;
                std::thread::sleep(std::time::Duration::from_millis(
                    self.plan.cfg.backoff_ms << shift,
                ));
            }
            attempt += 1;
        }
        if attempt > 0 {
            self.record(FaultEvent {
                kind: FaultKind::ExchangeTransient,
                step,
                rank: 0,
                retries: attempt,
                recovered: true,
            });
        }
        Ok(attempt)
    }

    /// Snapshot-corruption injection for the generation just written at
    /// `step`: flips or truncates its bytes per the plan, then *proves*
    /// recovery by asking the store whether a loadable generation
    /// remains (the last-good fallback). Recorded recovered/unrecovered
    /// accordingly — corrupting the only generation is surfaced, not
    /// hidden.
    pub fn maybe_corrupt_snapshot(&mut self, step: u64,
                                  store: &SnapshotStore) -> Result<(), String> {
        if !self.plan.corrupts_snapshot(step) {
            return Ok(());
        }
        let gens = store.generations();
        let Some((_, path)) = gens.last() else {
            return Ok(());
        };
        let mut bytes = std::fs::read(path).map_err(|e| format!("{path}: {e}"))?;
        let (offset, xor) = self.plan.corruption(step, bytes.len());
        if xor == 0 {
            bytes.truncate(offset);
        } else {
            bytes[offset] ^= xor;
        }
        std::fs::write(path, &bytes).map_err(|e| format!("{path}: {e}"))?;
        let recovered = store.load_latest().is_some();
        self.record(FaultEvent {
            kind: FaultKind::SnapshotCorrupt,
            step,
            rank: 0,
            retries: 0,
            recovered,
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The fixture plan of the cross-language table: probabilities and
    /// budget match `tools/ep_sim.py`'s fault mirror exactly.
    fn table_plan(seed: u64) -> FaultPlan {
        FaultPlan::new(FaultConfig {
            seed,
            stall_prob: 0.15,
            stall_ms: 0,
            exchange_fail_prob: 0.25,
            snapshot_corrupt_prob: 0.2,
            max_retries: 3,
            backoff_ms: 0,
        })
    }

    /// Pinned decision tables, 8 seeds x 20 steps x 2 microbatches —
    /// `tools/ep_sim.py` holds the identical ones (FAULT_STALLS /
    /// FAULT_EXCH / FAULT_CORRUPT). A divergence means the mixing
    /// arithmetic drifted between the suites.
    const STALLS: [&[u64]; 8] = [
        &[4],
        &[1, 10, 13, 14, 16, 18],
        &[],
        &[19],
        &[6, 14],
        &[9, 14],
        &[8, 12, 15],
        &[13, 17],
    ];
    const EXCH: [&[(u64, u64, u64)]; 8] = [
        &[(0, 1, 1), (5, 1, 1), (6, 1, 1), (7, 0, 1), (8, 0, 1), (9, 0, 1),
          (9, 1, 1), (10, 0, 1), (13, 0, 2), (15, 0, 2), (18, 0, 1),
          (18, 1, 1)],
        &[(2, 0, 2), (2, 1, 1), (7, 0, 1), (9, 0, 1), (11, 1, 2), (12, 0, 1),
          (14, 1, 3), (18, 1, 2)],
        &[(0, 0, 1), (0, 1, 1), (5, 1, 1), (6, 1, 1), (7, 0, 1), (7, 1, 1),
          (8, 0, 2), (15, 1, 2), (17, 1, 1), (18, 1, 1)],
        &[(0, 0, 1), (1, 0, 1), (1, 1, 2), (3, 0, 1), (5, 0, 1), (9, 1, 1),
          (11, 0, 1), (12, 1, 1), (17, 0, 1)],
        &[(0, 1, 1), (2, 1, 1), (5, 0, 1), (5, 1, 1), (6, 1, 1), (7, 1, 1),
          (11, 0, 1), (12, 0, 1), (14, 0, 1), (17, 0, 1), (17, 1, 1),
          (18, 0, 1)],
        &[(3, 0, 1), (5, 0, 1), (5, 1, 1), (10, 0, 1), (10, 1, 1),
          (11, 0, 3), (11, 1, 1), (13, 0, 1), (14, 0, 1), (16, 1, 2),
          (17, 0, 3), (19, 0, 1)],
        &[(0, 0, 1), (0, 1, 1), (2, 0, 1), (3, 0, 1), (8, 0, 1), (9, 0, 1),
          (10, 0, 1), (10, 1, 3), (11, 1, 1), (13, 0, 1), (16, 0, 1),
          (18, 0, 1), (18, 1, 1), (19, 0, 1)],
        &[(0, 0, 1), (0, 1, 1), (2, 0, 2), (2, 1, 1), (4, 1, 1), (7, 0, 1),
          (7, 1, 2), (8, 1, 1), (9, 0, 3), (10, 1, 1), (12, 0, 1),
          (12, 1, 1), (16, 0, 1), (16, 1, 1), (18, 1, 1)],
    ];
    const CORRUPT: [&[u64]; 8] = [
        &[1, 5, 12, 15, 18],
        &[0, 9, 14, 15],
        &[4, 13, 17],
        &[1, 4, 6, 19],
        &[15, 17, 18],
        &[12],
        &[0, 5, 13, 15, 16],
        &[1, 2, 7, 10, 14, 17, 18],
    ];

    #[test]
    fn pinned_decision_tables_match_the_python_mirror() {
        for seed in 0..8u64 {
            let plan = table_plan(seed);
            let stalls: Vec<u64> = (0..20).filter(|&s| plan.stalls(s)).collect();
            assert_eq!(stalls, STALLS[seed as usize], "stalls, seed {seed}");
            let mut exch = Vec::new();
            for s in 0..20u64 {
                for m in 0..2u64 {
                    let mut inj = FaultInjector::new(plan.clone());
                    let retries = inj.exchange_gate(s, m).unwrap();
                    if retries > 0 {
                        exch.push((s, m, retries));
                    }
                }
            }
            assert_eq!(exch, EXCH[seed as usize], "exchange, seed {seed}");
            let corrupt: Vec<u64> =
                (0..20).filter(|&s| plan.corrupts_snapshot(s)).collect();
            assert_eq!(corrupt, CORRUPT[seed as usize], "corrupt, seed {seed}");
        }
    }

    #[test]
    fn decisions_are_replay_stable_and_seed_sensitive() {
        let plan = table_plan(3);
        for s in 0..50u64 {
            assert_eq!(plan.stalls(s), plan.stalls(s));
            assert_eq!(plan.exchange_fails(s, 1, 0), plan.exchange_fails(s, 1, 0));
        }
        // different seeds draw different sequences
        let a: Vec<bool> = (0..64).map(|s| table_plan(1).stalls(s)).collect();
        let b: Vec<bool> = (0..64).map(|s| table_plan(2).stalls(s)).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn disabled_plan_never_fires() {
        let plan = FaultPlan::disabled();
        assert!(!plan.enabled());
        for s in 0..100u64 {
            assert!(!plan.stalls(s));
            assert!(!plan.exchange_fails(s, 0, 0));
            assert!(!plan.corrupts_snapshot(s));
        }
        let mut inj = FaultInjector::new(plan);
        for s in 0..100 {
            assert_eq!(inj.maybe_stall(s, 4), None);
            assert_eq!(inj.exchange_gate(s, 0).unwrap(), 0);
        }
        assert_eq!(inj.total, 0);
        assert!(inj.drain().is_empty());
    }

    #[test]
    fn exhausted_retry_budget_is_loud_not_silent() {
        // certain failure + zero budget: the gate must error AND record
        // an unrecovered event — never both-absent
        let plan = FaultPlan::new(FaultConfig {
            seed: 1,
            exchange_fail_prob: 1.0,
            max_retries: 0,
            backoff_ms: 0,
            ..FaultConfig::default()
        });
        let mut inj = FaultInjector::new(plan);
        assert!(inj.exchange_gate(0, 0).is_err());
        assert_eq!(inj.unrecovered, 1);
        let evs = inj.drain();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].kind, FaultKind::ExchangeTransient);
        assert!(!evs[0].recovered);
    }

    #[test]
    fn stall_events_are_recovered_and_ranked() {
        let plan = table_plan(1); // stalls at steps 1, 10, 13, 14, 16, 18
        let mut inj = FaultInjector::new(plan);
        let r = inj.maybe_stall(1, 4).expect("seed 1 stalls at step 1");
        assert!(r < 4);
        assert_eq!(inj.maybe_stall(2, 4), None);
        let evs = inj.drain();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].kind, FaultKind::RankStall);
        assert!(evs[0].recovered);
        assert_eq!(inj.total, 1);
        assert_eq!(inj.unrecovered, 0);
        // drain is move-out: totals persist, queue empties
        assert!(inj.drain().is_empty());
        assert_eq!(inj.total, 1);
    }

    #[test]
    fn corruption_site_is_in_bounds_and_never_a_noop() {
        let plan = table_plan(5);
        for s in 0..100u64 {
            for len in [1usize, 8, 100, 4096] {
                let (offset, _xor) = plan.corruption(s, len);
                assert!(offset < len, "offset {offset} out of {len}");
            }
        }
        // both corruption modes occur across steps
        let modes: Vec<bool> =
            (0..200).map(|s| plan.corruption(s, 1024).1 == 0).collect();
        assert!(modes.iter().any(|&t| t), "no truncation mode seen");
        assert!(modes.iter().any(|&t| !t), "no flip mode seen");
    }
}

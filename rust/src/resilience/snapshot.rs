//! Crash-consistent training snapshots: versioned, checksummed,
//! atomically written, bit-exact on restore.
//!
//! A [`TrainState`] captures everything the training loop needs to
//! resume *bit-for-bit*: the full [`ExpertStore`] (including SwiGLU
//! `w3` grids), the optimizer's exact state (Adam's bias-correction
//! exponent and both moment grids — recomputing moments would break
//! the resume pin), the optimizer-step cursor, and the run's
//! calibration. The data/RNG position needs no separate field: the
//! workload is a pure function of the config built once before the
//! loop, so the step counter IS the data position.
//!
//! The on-disk artifact is `[magic "MBSNAP01"][payload][FNV-1a-64 of
//! payload]`. Decoding is total — any magic mismatch, truncation, bit
//! flip, shape violation, or trailing garbage yields `None`, never a
//! panic and never a half-restored state (the corrupt-snapshot fuzz
//! tests walk every byte prefix and every single-byte flip).
//!
//! A [`SnapshotStore`] manages generations `{base}.g{step:010}`: each
//! save goes through the `calibrate.rs` tmp+rename pattern (readers
//! see the old complete artifact or the new complete artifact, never
//! a torn write), the oldest generations beyond `keep` are pruned, and
//! `load_latest` walks generations newest-first so a corrupted newest
//! generation falls back to the last good one.

use std::collections::BTreeMap;
use std::fs;

use crate::config::ep::EpConfig;
use crate::coordinator::calibrate::Calibration;
use crate::coordinator::optim::OptimizerState;
use crate::coordinator::params::{ExpertGrads, ExpertParams, ExpertStore};
use crate::util::bytes::{
    bytes_to_f32s, f32s_to_bytes, read_str, read_u64, write_str, write_u64,
};

/// Artifact magic + format version, bumped together on layout changes.
const MAGIC: &[u8; 8] = b"MBSNAP01";
/// Payload-level format version (inside the checksummed region).
const VERSION: u64 = 1;
/// Generations a store retains (newest `KEEP_GENERATIONS` survive).
pub const KEEP_GENERATIONS: usize = 3;

/// FNV-1a 64 over a byte slice — the artifact checksum.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a fold of one u64 (for the config fingerprint).
fn fnv_u64(h: u64, v: u64) -> u64 {
    let mut h = h;
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a fold of a string (length-prefixed so `("ab","c")` and
/// `("a","bc")` fingerprint differently).
fn fnv_str(h: u64, s: &str) -> u64 {
    let mut h = fnv_u64(h, s.len() as u64);
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of the numerics-affecting config fields. A snapshot
/// resumes only into a run whose fingerprint matches — and ONLY the
/// fields that shape the loss curve participate: topology (`ranks`,
/// `pipeline_chunks`, placement), checkpoint policy, and tile size are
/// deliberately excluded, because the engines are pinned bit-identical
/// across them. A snapshot taken at R=1 restores at R=4.
pub fn config_fingerprint(cfg: &EpConfig) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    h = fnv_u64(h, cfg.seed);
    h = fnv_u64(h, cfg.tokens as u64);
    h = fnv_u64(h, cfg.num_experts as u64);
    h = fnv_u64(h, cfg.top_k as u64);
    h = fnv_u64(h, cfg.d_model as u64);
    h = fnv_u64(h, cfg.d_hidden as u64);
    h = fnv_u64(h, cfg.steps as u64);
    h = fnv_u64(h, cfg.grad_accum as u64);
    h = fnv_u64(h, cfg.lr.to_bits());
    h = fnv_u64(h, cfg.clip_norm.to_bits());
    h = fnv_u64(h, cfg.skew.to_bits());
    h = fnv_u64(h, cfg.num_layers as u64);
    h = fnv_str(h, &cfg.optimizer);
    h = fnv_str(h, &cfg.lr_schedule);
    h = fnv_str(h, cfg.activation.name());
    h
}

/// Everything a resumed run restores. See the module docs for the
/// bit-identity contract.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainState {
    /// `config_fingerprint` of the run that wrote the snapshot
    pub fingerprint: u64,
    /// optimizer steps completed when the snapshot was taken
    pub step: u64,
    /// microbatch cursor inside the current accumulation window —
    /// structurally 0 (snapshots land only at optimizer-step
    /// boundaries; a mid-accumulation due date defers), carried
    /// explicitly so the invariant is checked on load, not assumed
    pub micro_cursor: u64,
    /// full parameter state, `w3` included when gated
    pub params: ExpertStore,
    /// exact optimizer state (Adam: t + both moment grids)
    pub optimizer: OptimizerState,
    /// link/compute calibration active when the snapshot was taken
    pub calibration: Option<Calibration>,
}

fn write_f32_grid(out: &mut Vec<u8>, xs: &[f32]) {
    write_u64(out, xs.len() as u64);
    out.extend_from_slice(&f32s_to_bytes(xs));
}

fn read_f32_grid(b: &[u8], pos: &mut usize) -> Result<Vec<f32>, String> {
    let n = read_u64(b, pos)? as usize;
    let bytes = n.checked_mul(4).ok_or("grid length overflow")?;
    let end = pos.checked_add(bytes).ok_or("grid length overflow")?;
    if end > b.len() {
        return Err(format!("grid of {n} f32s overruns payload"));
    }
    let xs = bytes_to_f32s(&b[*pos..end])?;
    *pos = end;
    Ok(xs)
}

fn write_experts(out: &mut Vec<u8>, d_model: usize, d_hidden: usize,
                 experts: &[ExpertParams]) {
    write_u64(out, experts.len() as u64);
    write_u64(out, d_model as u64);
    write_u64(out, d_hidden as u64);
    for e in experts {
        write_f32_grid(out, &e.w1);
        write_f32_grid(out, &e.b1);
        write_f32_grid(out, &e.w2);
        write_f32_grid(out, &e.b2);
        write_f32_grid(out, &e.w3);
    }
}

fn read_experts(
    b: &[u8],
    pos: &mut usize,
) -> Result<(usize, usize, Vec<ExpertParams>), String> {
    let n = read_u64(b, pos)? as usize;
    let d = read_u64(b, pos)? as usize;
    let h = read_u64(b, pos)? as usize;
    if n > 1 << 20 || d > 1 << 20 || h > 1 << 20 {
        return Err("implausible expert grid header".into());
    }
    let mut experts = Vec::with_capacity(n);
    for _ in 0..n {
        let w1 = read_f32_grid(b, pos)?;
        let b1 = read_f32_grid(b, pos)?;
        let w2 = read_f32_grid(b, pos)?;
        let b2 = read_f32_grid(b, pos)?;
        let w3 = read_f32_grid(b, pos)?;
        // shape check here, not at restore time: a flipped length byte
        // must fail the LOAD, so fallback kicks in before any state is
        // touched
        if w1.len() != h * d || b1.len() != h || w2.len() != d * h
            || b2.len() != d || !(w3.is_empty() || w3.len() == h * d)
        {
            return Err("expert tensor shape mismatch".into());
        }
        experts.push(ExpertParams { w1, b1, w2, b2, w3 });
    }
    Ok((d, h, experts))
}

impl TrainState {
    /// Serialize to the checksummed artifact bytes.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut p = Vec::new();
        write_u64(&mut p, VERSION);
        write_u64(&mut p, self.fingerprint);
        write_u64(&mut p, self.step);
        write_u64(&mut p, self.micro_cursor);
        write_experts(&mut p, self.params.d_model, self.params.d_hidden,
                      &self.params.experts);
        match &self.optimizer {
            OptimizerState::Sgd => write_str(&mut p, "sgd"),
            OptimizerState::Adam { t, m, v } => {
                write_str(&mut p, "adam");
                write_u64(&mut p, *t);
                write_u64(&mut p, u64::from(m.is_some()));
                if let (Some(m), Some(v)) = (m, v) {
                    write_experts(&mut p, m.d_model, m.d_hidden, &m.experts);
                    write_experts(&mut p, v.d_model, v.d_hidden, &v.experts);
                }
            }
        }
        match &self.calibration {
            None => write_u64(&mut p, 0),
            Some(c) => {
                write_u64(&mut p, 1);
                write_u64(&mut p, c.link_gbps.to_bits());
                write_u64(&mut p, c.compute_gflops.to_bits());
                write_u64(&mut p, c.tiles.len() as u64);
                for (k, v) in &c.tiles {
                    write_str(&mut p, k);
                    write_u64(&mut p, *v as u64);
                }
            }
        }
        let mut out = Vec::with_capacity(MAGIC.len() + p.len() + 8);
        out.extend_from_slice(MAGIC);
        let sum = fnv1a(&p);
        out.extend_from_slice(&p);
        write_u64(&mut out, sum);
        out
    }

    /// Total decoder: `None` on ANY defect — wrong magic, truncation,
    /// checksum mismatch, bad version, shape violation, inconsistent
    /// optimizer state, or trailing bytes. Callers fall back to the
    /// previous generation; nothing partial ever escapes.
    pub fn from_bytes(b: &[u8]) -> Option<TrainState> {
        if b.len() < MAGIC.len() + 8 || &b[..MAGIC.len()] != MAGIC {
            return None;
        }
        let payload = &b[MAGIC.len()..b.len() - 8];
        let mut tail = b.len() - 8;
        let stored = read_u64(b, &mut tail).ok()?;
        if fnv1a(payload) != stored {
            return None;
        }
        Self::decode_payload(payload).ok()
    }

    fn decode_payload(p: &[u8]) -> Result<TrainState, String> {
        let mut pos = 0usize;
        let version = read_u64(p, &mut pos)?;
        if version != VERSION {
            return Err(format!("unknown snapshot version {version}"));
        }
        let fingerprint = read_u64(p, &mut pos)?;
        let step = read_u64(p, &mut pos)?;
        let micro_cursor = read_u64(p, &mut pos)?;
        if micro_cursor != 0 {
            // snapshots are taken only at optimizer-step boundaries
            return Err("snapshot taken mid-accumulation".into());
        }
        let (d_model, d_hidden, experts) = read_experts(p, &mut pos)?;
        let params = ExpertStore { d_model, d_hidden, experts };
        let optimizer = match read_str(p, &mut pos)?.as_str() {
            "sgd" => OptimizerState::Sgd,
            "adam" => {
                let t = read_u64(p, &mut pos)?;
                let has = read_u64(p, &mut pos)?;
                match has {
                    0 => OptimizerState::Adam { t, m: None, v: None },
                    1 => {
                        let (md, mh, me) = read_experts(p, &mut pos)?;
                        let (vd, vh, ve) = read_experts(p, &mut pos)?;
                        if (md, mh, me.len()) != (d_model, d_hidden, params.experts.len())
                            || (vd, vh, ve.len()) != (md, mh, me.len())
                        {
                            return Err("moment grids disagree with params".into());
                        }
                        OptimizerState::Adam {
                            t,
                            m: Some(ExpertGrads { d_model: md, d_hidden: mh,
                                                  experts: me }),
                            v: Some(ExpertGrads { d_model: vd, d_hidden: vh,
                                                  experts: ve }),
                        }
                    }
                    other => return Err(format!("bad moment flag {other}")),
                }
            }
            other => return Err(format!("unknown optimizer `{other}`")),
        };
        let calibration = match read_u64(p, &mut pos)? {
            0 => None,
            1 => {
                let link_gbps = f64::from_bits(read_u64(p, &mut pos)?);
                let compute_gflops = f64::from_bits(read_u64(p, &mut pos)?);
                let n = read_u64(p, &mut pos)? as usize;
                if n > 1 << 16 {
                    return Err("implausible tile-table length".into());
                }
                let mut tiles = BTreeMap::new();
                for _ in 0..n {
                    let k = read_str(p, &mut pos)?;
                    let v = read_u64(p, &mut pos)? as usize;
                    tiles.insert(k, v);
                }
                Some(Calibration { link_gbps, compute_gflops, tiles })
            }
            other => return Err(format!("bad calibration flag {other}")),
        };
        if pos != p.len() {
            return Err("trailing bytes after snapshot payload".into());
        }
        Ok(TrainState { fingerprint, step, micro_cursor, params, optimizer,
                        calibration })
    }
}

/// Generation-managed snapshot directory entry point (see module docs).
#[derive(Debug, Clone)]
pub struct SnapshotStore {
    /// generation files live at `{base}.g{step:010}`
    pub base: String,
    /// generations retained after each save
    pub keep: usize,
}

impl SnapshotStore {
    pub fn new(base: &str) -> SnapshotStore {
        SnapshotStore { base: base.to_string(), keep: KEEP_GENERATIONS }
    }

    /// Path of the generation written at optimizer step `step`.
    pub fn gen_path(&self, step: u64) -> String {
        format!("{}.g{step:010}", self.base)
    }

    /// All on-disk generations as `(step, path)`, ascending by step.
    pub fn generations(&self) -> Vec<(u64, String)> {
        let base = std::path::Path::new(&self.base);
        let dir = match base.parent() {
            Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
            _ => std::path::PathBuf::from("."),
        };
        let stem = match base.file_name().and_then(|s| s.to_str()) {
            Some(s) => format!("{s}.g"),
            None => return Vec::new(),
        };
        let mut out = Vec::new();
        let Ok(entries) = fs::read_dir(&dir) else {
            return Vec::new();
        };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(digits) = name.strip_prefix(&stem) else { continue };
            if digits.len() == 10 {
                if let Ok(step) = digits.parse::<u64>() {
                    out.push((step, entry.path().to_string_lossy().into_owned()));
                }
            }
        }
        out.sort();
        out
    }

    /// Atomically persist `state` as the generation for its step, then
    /// prune generations beyond `keep`. tmp+rename (the `calibrate.rs`
    /// pattern): a crash mid-write leaves either the old set of
    /// complete artifacts or the new one, never a torn file under the
    /// real name.
    pub fn save(&self, state: &TrainState) -> Result<String, String> {
        let path = self.gen_path(state.step);
        if let Some(dir) = std::path::Path::new(&path).parent() {
            if !dir.as_os_str().is_empty() {
                fs::create_dir_all(dir)
                    .map_err(|e| format!("create {}: {e}", dir.display()))?;
            }
        }
        let tmp = format!("{path}.tmp");
        fs::write(&tmp, state.to_bytes()).map_err(|e| format!("{tmp}: {e}"))?;
        fs::rename(&tmp, &path).map_err(|e| format!("{tmp} -> {path}: {e}"))?;
        let gens = self.generations();
        if gens.len() > self.keep {
            for (_, old) in &gens[..gens.len() - self.keep] {
                let _ = fs::remove_file(old);
            }
        }
        Ok(path)
    }

    /// Newest generation that decodes cleanly — a corrupt or truncated
    /// newest generation falls back to the previous one. `None` only
    /// when no generation is loadable at all.
    pub fn load_latest(&self) -> Option<TrainState> {
        for (_, path) in self.generations().into_iter().rev() {
            if let Ok(bytes) = fs::read(&path) {
                if let Some(state) = TrainState::from_bytes(&bytes) {
                    return Some(state);
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optim::{Adam, Optimizer, Sgd};
    use crate::coordinator::params::ExpertStore;

    fn tmp_base(tag: &str) -> String {
        let dir = std::env::temp_dir();
        dir.join(format!("moeblaze_snap_{}_{tag}", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn cleanup(base: &str) {
        for (_, p) in SnapshotStore::new(base).generations() {
            let _ = std::fs::remove_file(p);
        }
    }

    fn sample_state(gated: bool, with_moments: bool) -> TrainState {
        let store = ExpertStore::init_gated(4, 6, 8, 17, gated);
        let optimizer = if with_moments {
            // drive a real Adam two steps so both moment grids are live
            let mut adam = Adam::default();
            let mut g = ExpertGrads::zeros_gated(4, 6, 8, gated);
            for e in &mut g.experts {
                for x in e.w1.iter_mut().chain(e.b1.iter_mut()) {
                    *x = 0.25;
                }
            }
            adam.step(&g, 1e-3).unwrap();
            adam.step(&g, 1e-3).unwrap();
            adam.export_state()
        } else {
            OptimizerState::Sgd
        };
        TrainState {
            fingerprint: 0xDEAD_BEEF_CAFE_F00D,
            step: 3,
            micro_cursor: 0,
            params: store,
            optimizer,
            calibration: Some(Calibration {
                link_gbps: 42.5,
                compute_gflops: 980.0,
                tiles: BTreeMap::from([("fwd".to_string(), 64usize)]),
            }),
        }
    }

    #[test]
    fn round_trips_bit_exact_all_variants() {
        for gated in [false, true] {
            for with_moments in [false, true] {
                let s = sample_state(gated, with_moments);
                let b = s.to_bytes();
                let r = TrainState::from_bytes(&b)
                    .expect("clean artifact must decode");
                // PartialEq on f32 grids == bitwise here (no NaNs in play)
                assert_eq!(s, r, "gated={gated} moments={with_moments}");
            }
        }
    }

    #[test]
    fn every_byte_prefix_fails_closed() {
        // satellite (a), half 1: no truncation point decodes — each
        // must read as "fall back", never panic or half-restore
        let full = sample_state(true, true).to_bytes();
        for cut in 0..full.len() {
            assert!(
                TrainState::from_bytes(&full[..cut]).is_none(),
                "prefix of {cut}/{} bytes decoded",
                full.len()
            );
        }
    }

    #[test]
    fn every_single_byte_flip_fails_closed() {
        // satellite (a), half 2: any one-bit-pattern change anywhere in
        // the artifact must be caught (magic check or FNV mismatch)
        let full = sample_state(true, true).to_bytes();
        for i in 0..full.len() {
            let mut bad = full.clone();
            bad[i] ^= 0x5A;
            assert!(
                TrainState::from_bytes(&bad).is_none(),
                "flip at byte {i}/{} decoded",
                full.len()
            );
        }
    }

    #[test]
    fn store_keeps_n_generations_and_prunes_oldest() {
        let base = tmp_base("gens");
        cleanup(&base);
        let store = SnapshotStore::new(&base);
        let mut s = sample_state(false, false);
        for step in 1..=5u64 {
            s.step = step;
            store.save(&s).unwrap();
        }
        let gens = store.generations();
        assert_eq!(gens.iter().map(|(g, _)| *g).collect::<Vec<_>>(),
                   vec![3, 4, 5]);
        assert_eq!(store.load_latest().unwrap().step, 5);
        // no stray .tmp files survive a save
        assert!(!std::path::Path::new(&format!("{}.tmp", store.gen_path(5)))
            .exists());
        cleanup(&base);
    }

    #[test]
    fn corrupt_newest_falls_back_to_previous_generation() {
        let base = tmp_base("fallback");
        cleanup(&base);
        let store = SnapshotStore::new(&base);
        let mut s = sample_state(true, true);
        s.step = 1;
        store.save(&s).unwrap();
        s.step = 2;
        store.save(&s).unwrap();
        // flip a byte in the middle of the newest generation
        let newest = store.gen_path(2);
        let mut bytes = std::fs::read(&newest).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&newest, &bytes).unwrap();
        let loaded = store.load_latest().expect("gen 1 must remain loadable");
        assert_eq!(loaded.step, 1);
        // corrupting the last good one too -> None, still no panic
        let prev = store.gen_path(1);
        std::fs::write(&prev, b"MBSNAP01 junk").unwrap();
        assert!(store.load_latest().is_none());
        cleanup(&base);
    }

    #[test]
    fn fingerprint_tracks_numerics_and_ignores_topology() {
        let mut a = EpConfig::default();
        let f0 = config_fingerprint(&a);
        // topology / schedule / policy axes leave the fingerprint alone
        a.ranks = 4;
        a.pipeline_chunks = 3;
        a.tile_rows = 96;
        assert_eq!(config_fingerprint(&a), f0);
        // numerics-affecting fields move it
        for mutate in [
            (|c: &mut EpConfig| c.seed += 1) as fn(&mut EpConfig),
            |c| c.lr *= 2.0,
            |c| c.grad_accum += 1,
            |c| c.optimizer = "adam".to_string(),
            |c| c.num_experts += 1,
            |c| c.activation = crate::config::Activation::Swiglu,
        ] {
            let mut b = EpConfig::default();
            mutate(&mut b);
            assert_ne!(config_fingerprint(&b), f0);
        }
    }

    #[test]
    fn sgd_and_adam_states_survive_the_artifact() {
        // export -> artifact -> import must land the optimizer exactly
        // where it was (the trainer relies on this for resume)
        let s = sample_state(false, true);
        let r = TrainState::from_bytes(&s.to_bytes()).unwrap();
        let mut adam = Adam::default();
        adam.import_state(r.optimizer).unwrap();
        let mut g = ExpertGrads::zeros(4, 6, 8);
        for e in &mut g.experts {
            for x in e.w2.iter_mut() {
                *x = -0.125;
            }
        }
        let mut adam2 = Adam::default();
        adam2.import_state(s.optimizer.clone()).unwrap();
        assert_eq!(adam.step(&g, 1e-3).unwrap(), adam2.step(&g, 1e-3).unwrap());
        // and SGD stays stateless
        let s = sample_state(false, false);
        let r = TrainState::from_bytes(&s.to_bytes()).unwrap();
        let mut sgd = Sgd;
        sgd.import_state(r.optimizer).unwrap();
    }
}

//! Fault tolerance: crash-consistent snapshots, bit-identical resume,
//! deterministic fault injection.
//!
//! Three co-designed pieces (see `lib.rs` § Robustness for the knob
//! table):
//!
//! - [`snapshot`] — versioned, checksummed [`TrainState`] artifacts
//!   written atomically every `[ep] snapshot_interval` optimizer steps
//!   and retained as N last-good generations; `--resume` restores the
//!   exact parameter/optimizer bits, so an interrupted-and-resumed run
//!   reproduces the never-interrupted loss curve bit-for-bit.
//! - [`fault`] — a seeded [`FaultPlan`] ([`[fault]` config]
//!   [crate::config::FaultConfig]) injecting rank stalls, transient
//!   exchange failures, and snapshot corruption at deterministic,
//!   replayable sites; the [`FaultInjector`] enforces that every
//!   injected fault is either recovered (bounded retry / generation
//!   fallback) or surfaced as a typed [`FaultEvent`] — never silent.
//! - graceful degradation in serving (`serving::driver`) — deadlines
//!   and a stall-triggered shed mode, accounted in the request
//!   conservation law and the Prometheus exposition.

pub mod fault;
pub mod snapshot;

pub use fault::{FaultEvent, FaultInjector, FaultKind, FaultPlan};
pub use snapshot::{
    config_fingerprint, SnapshotStore, TrainState, KEEP_GENERATIONS,
};

//! Training-run configuration (TOML-file driven, CLI-overridable).

use super::toml::Toml;

#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// total optimizer steps
    pub steps: usize,
    /// microbatches accumulated per optimizer step
    pub grad_accum: usize,
    pub lr: f64,
    /// linear warmup steps then cosine decay to `lr * min_lr_frac`
    pub warmup_steps: usize,
    pub min_lr_frac: f64,
    pub seed: u64,
    /// checkpoint every N steps (0 = never)
    pub checkpoint_every: usize,
    pub checkpoint_dir: String,
    /// evaluate every N steps (0 = never)
    pub eval_every: usize,
    /// log every N steps
    pub log_every: usize,
    /// metrics output (JSONL); empty = stdout only
    pub metrics_path: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            grad_accum: 1,
            lr: 1e-3,
            warmup_steps: 20,
            min_lr_frac: 0.1,
            seed: 42,
            checkpoint_every: 0,
            checkpoint_dir: "checkpoints".into(),
            eval_every: 50,
            log_every: 10,
            metrics_path: String::new(),
        }
    }
}

impl TrainConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.steps == 0 {
            return Err("steps must be > 0".into());
        }
        if self.grad_accum == 0 {
            return Err("grad_accum must be > 0".into());
        }
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            return Err(format!("lr must be positive, got {}", self.lr));
        }
        if !(0.0..=1.0).contains(&self.min_lr_frac) {
            return Err("min_lr_frac must be in [0, 1]".into());
        }
        Ok(())
    }

    pub fn from_toml(t: &Toml, prefix: &str) -> Result<TrainConfig, String> {
        let d = TrainConfig::default();
        let key = |k: &str| format!("{prefix}.{k}");
        let cfg = TrainConfig {
            steps: t.usize_or(&key("steps"), d.steps),
            grad_accum: t.usize_or(&key("grad_accum"), d.grad_accum),
            lr: t.f64_or(&key("lr"), d.lr),
            warmup_steps: t.usize_or(&key("warmup_steps"), d.warmup_steps),
            min_lr_frac: t.f64_or(&key("min_lr_frac"), d.min_lr_frac),
            seed: t.usize_or(&key("seed"), d.seed as usize) as u64,
            checkpoint_every: t.usize_or(&key("checkpoint_every"), d.checkpoint_every),
            checkpoint_dir: t.str_or(&key("checkpoint_dir"), &d.checkpoint_dir),
            eval_every: t.usize_or(&key("eval_every"), d.eval_every),
            log_every: t.usize_or(&key("log_every"), d.log_every),
            metrics_path: t.str_or(&key("metrics_path"), &d.metrics_path),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Learning rate at `step` (0-based): linear warmup then cosine decay.
    pub fn lr_at(&self, step: usize) -> f64 {
        if step < self.warmup_steps {
            return self.lr * (step + 1) as f64 / self.warmup_steps.max(1) as f64;
        }
        let progress = (step - self.warmup_steps) as f64
            / (self.steps.saturating_sub(self.warmup_steps)).max(1) as f64;
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * progress.min(1.0)).cos());
        let min_lr = self.lr * self.min_lr_frac;
        min_lr + (self.lr - min_lr) * cos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_shape() {
        let c = TrainConfig { steps: 100, warmup_steps: 10, lr: 1.0,
                              min_lr_frac: 0.1, ..Default::default() };
        assert!(c.lr_at(0) < c.lr_at(5));
        assert!((c.lr_at(9) - 1.0).abs() < 1e-9);
        assert!(c.lr_at(50) < 1.0);
        assert!(c.lr_at(99) >= 0.1 - 1e-9);
        assert!(c.lr_at(99) < c.lr_at(50));
    }

    #[test]
    fn validation() {
        assert!(TrainConfig::default().validate().is_ok());
        assert!(TrainConfig { steps: 0, ..Default::default() }.validate().is_err());
        assert!(TrainConfig { lr: -1.0, ..Default::default() }.validate().is_err());
        assert!(TrainConfig { lr: f64::NAN, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn from_toml_overrides() {
        let t = Toml::parse("[train]\nsteps = 7\nlr = 0.5\nmetrics_path = \"m.jsonl\"").unwrap();
        let c = TrainConfig::from_toml(&t, "train").unwrap();
        assert_eq!(c.steps, 7);
        assert_eq!(c.lr, 0.5);
        assert_eq!(c.metrics_path, "m.jsonl");
        assert_eq!(c.grad_accum, 1); // default preserved
    }
}

//! Serving configuration (`[serving]` TOML section, CLI-overridable).
//!
//! Drives the forward-only serving loop (`serving::ServeLoop`): how many
//! engine ticks to run, the per-tick token budget the continuous batcher
//! aggregates up to, the request-queue depth, the admission policy for
//! requests the capacity projection cannot fit, and the synthetic
//! open-loop traffic process (seeded arrival rate + request-size range).
//! The engine/workload shape itself stays in `[ep]` — serving reuses the
//! exact training data path.

use std::fmt;

use super::toml::Toml;

/// What happens to a queued request the current tick cannot fit (token
/// budget or projected per-rank bytes over `[ep] mem_budget_bytes`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionPolicy {
    /// Strict-FIFO wait: the request stays at the queue head and blocks
    /// the tick's drain until a later, smaller tick fits it. Lossless
    /// for every feasible request, at the cost of head-of-line latency.
    /// (A request whose projection exceeds the budget even alone can
    /// never be served and is rejected at arrival under both policies.)
    #[default]
    Queue,
    /// Load shedding: a request that does not fit the tick's remaining
    /// capacity is rejected immediately and the drain continues with
    /// the next queued request — bounded latency, no head-of-line
    /// blocking, maximal tick utilization.
    Reject,
}

impl AdmissionPolicy {
    pub fn parse(s: &str) -> Result<AdmissionPolicy, String> {
        match s.to_ascii_lowercase().as_str() {
            "queue" | "wait" => Ok(AdmissionPolicy::Queue),
            "reject" | "shed" => Ok(AdmissionPolicy::Reject),
            _ => Err(format!("unknown admission policy `{s}` (queue|reject)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AdmissionPolicy::Queue => "queue",
            AdmissionPolicy::Reject => "reject",
        }
    }
}

impl fmt::Display for AdmissionPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Configuration of one `ep-serve` run.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingConfig {
    /// engine ticks to run (one aggregated forward per non-empty tick)
    pub ticks: usize,
    /// per-tick token budget: the continuous batcher aggregates queued
    /// requests into one `StepBatch` of at most this many tokens
    pub tick_tokens: usize,
    /// request-queue capacity; arrivals beyond it are rejected
    pub max_queue_depth: usize,
    /// what happens to requests the current tick cannot fit
    pub admission: AdmissionPolicy,
    /// open-loop traffic: mean request arrivals per tick (Poisson)
    pub arrival_rate: f64,
    /// request-size distribution: tokens per request drawn uniformly
    /// from `min_request_tokens..=max_request_tokens`
    pub min_request_tokens: usize,
    pub max_request_tokens: usize,
    /// traffic-generator seed (separate stream from `[ep] seed`, which
    /// keeps seeding the expert weights)
    pub seed: u64,
    /// when tracing is on (`[ep] trace_out`), record one `batcher_tick`
    /// host span per tick (tokens/requests batched); off leaves only
    /// the engine phase spans in the trace
    pub trace_ticks: bool,
    /// per-request deadline in ticks: a request still queued after
    /// waiting this many ticks is shed (counted, never silently
    /// dropped). 0 = no deadlines
    pub deadline_ticks: usize,
    /// how many ticks shed mode lasts after an injected rank stall:
    /// admission flips to reject (arrivals are shed) while the queue
    /// keeps draining; 0 makes stalls shed only the stalled tick itself
    pub shed_recovery_ticks: usize,
}

impl Default for ServingConfig {
    fn default() -> Self {
        ServingConfig {
            ticks: 32,
            tick_tokens: 256,
            max_queue_depth: 64,
            admission: AdmissionPolicy::default(),
            arrival_rate: 4.0,
            min_request_tokens: 1,
            max_request_tokens: 32,
            seed: 7,
            trace_ticks: true,
            deadline_ticks: 0,
            shed_recovery_ticks: 2,
        }
    }
}

impl ServingConfig {
    /// Every key `[serving]` understands — `from_toml` rejects anything
    /// else by name instead of silently ignoring it.
    pub const KNOWN_KEYS: &'static [&'static str] = &[
        "ticks",
        "tick_tokens",
        "max_queue_depth",
        "admission",
        "arrival_rate",
        "min_request_tokens",
        "max_request_tokens",
        "seed",
        "trace_ticks",
        "deadline_ticks",
        "shed_recovery_ticks",
    ];

    pub fn validate(&self) -> Result<(), String> {
        if self.ticks == 0 {
            return Err("serving.ticks must be > 0".into());
        }
        if self.tick_tokens == 0 {
            return Err("serving.tick_tokens must be > 0".into());
        }
        if self.max_queue_depth == 0 {
            return Err("serving.max_queue_depth must be > 0".into());
        }
        if !(self.arrival_rate > 0.0 && self.arrival_rate.is_finite()) {
            return Err(format!(
                "serving.arrival_rate must be positive, got {}",
                self.arrival_rate
            ));
        }
        // exp(-rate) must stay a positive f64 for the Poisson sampler
        if self.arrival_rate > 256.0 {
            return Err(format!(
                "serving.arrival_rate {} is out of range (max 256 per tick)",
                self.arrival_rate
            ));
        }
        if self.min_request_tokens == 0 {
            return Err("serving.min_request_tokens must be > 0".into());
        }
        if self.min_request_tokens > self.max_request_tokens {
            return Err(format!(
                "serving.min_request_tokens {} exceeds max_request_tokens {}",
                self.min_request_tokens, self.max_request_tokens
            ));
        }
        // a request larger than the tick budget could never be batched
        if self.max_request_tokens > self.tick_tokens {
            return Err(format!(
                "serving.max_request_tokens {} exceeds tick_tokens {}",
                self.max_request_tokens, self.tick_tokens
            ));
        }
        if self.shed_recovery_ticks > self.ticks {
            return Err(format!(
                "serving.shed_recovery_ticks {} exceeds the run's {} ticks \
                 (shed mode would never clear)",
                self.shed_recovery_ticks, self.ticks
            ));
        }
        Ok(())
    }

    pub fn from_toml(t: &Toml, prefix: &str) -> Result<ServingConfig, String> {
        t.reject_unknown_keys(prefix, Self::KNOWN_KEYS)?;
        let d = ServingConfig::default();
        let key = |k: &str| format!("{prefix}.{k}");
        let cfg = ServingConfig {
            ticks: t.usize_or(&key("ticks"), d.ticks),
            tick_tokens: t.usize_or(&key("tick_tokens"), d.tick_tokens),
            max_queue_depth: t.usize_or(&key("max_queue_depth"), d.max_queue_depth),
            admission: AdmissionPolicy::parse(
                &t.str_or(&key("admission"), d.admission.name()),
            )?,
            arrival_rate: t.f64_or(&key("arrival_rate"), d.arrival_rate),
            min_request_tokens: t.usize_or(&key("min_request_tokens"),
                                           d.min_request_tokens),
            max_request_tokens: t.usize_or(&key("max_request_tokens"),
                                           d.max_request_tokens),
            seed: t.usize_or(&key("seed"), d.seed as usize) as u64,
            trace_ticks: t.bool_or(&key("trace_ticks"), d.trace_ticks),
            deadline_ticks: t.usize_or(&key("deadline_ticks"), d.deadline_ticks),
            shed_recovery_ticks: t.usize_or(&key("shed_recovery_ticks"),
                                            d.shed_recovery_ticks),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admission_policy_parse() {
        assert_eq!(AdmissionPolicy::parse("Queue").unwrap(), AdmissionPolicy::Queue);
        assert_eq!(AdmissionPolicy::parse("shed").unwrap(), AdmissionPolicy::Reject);
        assert_eq!(AdmissionPolicy::Reject.name(), "reject");
        assert!(AdmissionPolicy::parse("drop-newest").is_err());
        assert_eq!(AdmissionPolicy::default(), AdmissionPolicy::Queue);
    }

    #[test]
    fn defaults_validate() {
        let d = ServingConfig::default();
        d.validate().unwrap();
        assert_eq!(d.admission, AdmissionPolicy::Queue);
        assert!(d.max_request_tokens <= d.tick_tokens);
    }

    #[test]
    fn from_toml_overrides() {
        let t = Toml::parse(
            "[serving]\nticks = 10\ntick_tokens = 128\nmax_queue_depth = 8\n\
             admission = \"reject\"\narrival_rate = 2.5\n\
             min_request_tokens = 4\nmax_request_tokens = 16\nseed = 11",
        )
        .unwrap();
        let c = ServingConfig::from_toml(&t, "serving").unwrap();
        assert_eq!(c.ticks, 10);
        assert_eq!(c.tick_tokens, 128);
        assert_eq!(c.max_queue_depth, 8);
        assert_eq!(c.admission, AdmissionPolicy::Reject);
        assert_eq!(c.arrival_rate, 2.5);
        assert_eq!(c.min_request_tokens, 4);
        assert_eq!(c.max_request_tokens, 16);
        assert_eq!(c.seed, 11);
        assert!(c.trace_ticks, "defaults to on when unset");
        let t = Toml::parse("[serving]\ntrace_ticks = false").unwrap();
        assert!(!ServingConfig::from_toml(&t, "serving").unwrap().trace_ticks);
    }

    #[test]
    fn validation_rejects_bad_shapes() {
        let d = ServingConfig::default;
        assert!(ServingConfig { ticks: 0, ..d() }.validate().is_err());
        assert!(ServingConfig { tick_tokens: 0, ..d() }.validate().is_err());
        assert!(ServingConfig { max_queue_depth: 0, ..d() }.validate().is_err());
        assert!(ServingConfig { arrival_rate: 0.0, ..d() }.validate().is_err());
        assert!(ServingConfig { arrival_rate: f64::NAN, ..d() }.validate().is_err());
        assert!(ServingConfig { arrival_rate: 1e6, ..d() }.validate().is_err());
        assert!(ServingConfig { min_request_tokens: 0, ..d() }.validate().is_err());
        assert!(ServingConfig { min_request_tokens: 9, max_request_tokens: 8, ..d() }
            .validate()
            .is_err());
        assert!(ServingConfig { max_request_tokens: 512, tick_tokens: 256, ..d() }
            .validate()
            .is_err());
    }

    #[test]
    fn unknown_keys_are_named_errors() {
        let t = Toml::parse("[serving]\nticks = 4\ntick_budget = 99").unwrap();
        let err = ServingConfig::from_toml(&t, "serving").unwrap_err();
        assert!(err.contains("tick_budget"), "{err}");
        assert!(err.contains("serving"), "{err}");
    }

    #[test]
    fn resilience_keys_parse_and_misspellings_are_rejected() {
        // the graceful-degradation knobs parse with defaults off/short
        let t = Toml::parse(
            "[serving]\ndeadline_ticks = 3\nshed_recovery_ticks = 5",
        )
        .unwrap();
        let c = ServingConfig::from_toml(&t, "serving").unwrap();
        assert_eq!(c.deadline_ticks, 3);
        assert_eq!(c.shed_recovery_ticks, 5);
        let d = ServingConfig::default();
        assert_eq!(d.deadline_ticks, 0, "deadlines default off");
        // misspellings of the new keys fail loudly, naming the real key
        for (bad, good) in [
            ("deadline", "deadline_ticks"),
            ("request_deadline_ticks", "deadline_ticks"),
            ("shed_recovery", "shed_recovery_ticks"),
            ("shed_ticks", "shed_recovery_ticks"),
        ] {
            let t = Toml::parse(&format!("[serving]\n{bad} = 2")).unwrap();
            let err = ServingConfig::from_toml(&t, "serving").unwrap_err();
            assert!(err.contains(&format!("`{bad}`")), "{err}");
            assert!(err.contains(good),
                    "error for `{bad}` should name `{good}`: {err}");
        }
        // a recovery window longer than the run can never clear
        assert!(ServingConfig { shed_recovery_ticks: 99,
                                ticks: 10,
                                ..ServingConfig::default() }
            .validate()
            .is_err());
    }
}

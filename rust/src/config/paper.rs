//! Paper Table-1 presets (full scale + CPU-bench scale).
//!
//! Mirrors `python/compile/configs.py`; `tests/config_parity.rs` checks the
//! two stay in sync through the artifact manifest.

use super::model::{Activation, MoeConfig};

pub const PAPER_BLOCK: usize = 128;
pub const SCALED_BLOCK: usize = 32;

#[derive(Debug, Clone, PartialEq)]
pub struct PaperConfig {
    pub name: &'static str,
    pub input_d: usize,
    pub num_experts: usize,
    pub top_k: usize,
    pub batch: usize,
    pub seq_len: usize,
}

impl PaperConfig {
    pub fn hidden(&self) -> usize {
        4 * self.input_d
    }

    pub fn tokens(&self) -> usize {
        self.batch * self.seq_len
    }

    pub fn moe(&self, activation: Activation, block: usize) -> MoeConfig {
        MoeConfig {
            d_model: self.input_d,
            d_hidden: self.hidden(),
            num_experts: self.num_experts,
            top_k: self.top_k,
            tokens: self.tokens(),
            activation,
            block,
        }
    }
}

/// Paper Table 1, full scale.
pub fn paper_configs() -> Vec<PaperConfig> {
    vec![
        PaperConfig {
            name: "conf1",
            input_d: 512,
            num_experts: 4,
            top_k: 1,
            batch: 32,
            seq_len: 2048,
        },
        PaperConfig {
            name: "conf2",
            input_d: 1024,
            num_experts: 8,
            top_k: 2,
            batch: 32,
            seq_len: 2048,
        },
        PaperConfig {
            name: "conf3",
            input_d: 1024,
            num_experts: 16,
            top_k: 4,
            batch: 32,
            seq_len: 2048,
        },
        PaperConfig {
            name: "conf4",
            input_d: 2048,
            num_experts: 16,
            top_k: 4,
            batch: 32,
            seq_len: 1024,
        },
        PaperConfig {
            name: "conf5",
            input_d: 512,
            num_experts: 16,
            top_k: 4,
            batch: 32,
            seq_len: 1024,
        },
        PaperConfig {
            name: "conf6",
            input_d: 1024,
            num_experts: 16,
            top_k: 4,
            batch: 16,
            seq_len: 1024,
        },
        PaperConfig {
            name: "conf7",
            input_d: 2048,
            num_experts: 8,
            top_k: 4,
            batch: 16,
            seq_len: 512,
        },
    ]
}

/// CPU-bench scale (ratios preserved: d ÷ 8, batch → 4/2, seq ÷ 16).
pub fn scaled_configs() -> Vec<PaperConfig> {
    vec![
        PaperConfig {
            name: "conf1",
            input_d: 64,
            num_experts: 4,
            top_k: 1,
            batch: 4,
            seq_len: 128,
        },
        PaperConfig {
            name: "conf2",
            input_d: 128,
            num_experts: 8,
            top_k: 2,
            batch: 4,
            seq_len: 128,
        },
        PaperConfig {
            name: "conf3",
            input_d: 128,
            num_experts: 16,
            top_k: 4,
            batch: 4,
            seq_len: 128,
        },
        PaperConfig {
            name: "conf4",
            input_d: 256,
            num_experts: 16,
            top_k: 4,
            batch: 4,
            seq_len: 64,
        },
        PaperConfig {
            name: "conf5",
            input_d: 64,
            num_experts: 16,
            top_k: 4,
            batch: 4,
            seq_len: 64,
        },
        PaperConfig {
            name: "conf6",
            input_d: 128,
            num_experts: 16,
            top_k: 4,
            batch: 2,
            seq_len: 64,
        },
        PaperConfig {
            name: "conf7",
            input_d: 256,
            num_experts: 8,
            top_k: 4,
            batch: 2,
            seq_len: 32,
        },
    ]
}

pub fn by_name(name: &str, scaled: bool) -> Option<PaperConfig> {
    let src = if scaled { scaled_configs() } else { paper_configs() };
    src.into_iter().find(|c| c.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seven_configs_each() {
        assert_eq!(paper_configs().len(), 7);
        assert_eq!(scaled_configs().len(), 7);
    }

    #[test]
    fn table1_values() {
        let c3 = by_name("conf3", false).unwrap();
        assert_eq!(
            (c3.input_d, c3.num_experts, c3.top_k, c3.batch, c3.seq_len),
            (1024, 16, 4, 32, 2048)
        );
        assert_eq!(c3.hidden(), 4096);
        assert_eq!(c3.tokens(), 65536);
    }

    #[test]
    fn scaled_preserves_ratios() {
        for (p, s) in paper_configs().iter().zip(scaled_configs()) {
            assert_eq!(p.num_experts, s.num_experts, "{}", p.name);
            assert_eq!(p.top_k, s.top_k, "{}", p.name);
            assert_eq!(p.input_d / s.input_d, 8, "{}", p.name);
            assert_eq!(p.hidden() / p.input_d, 4);
        }
    }

    #[test]
    fn all_valid_moe_configs() {
        for c in paper_configs().iter().chain(scaled_configs().iter()) {
            c.moe(Activation::Swiglu, SCALED_BLOCK).validate().unwrap();
        }
    }
}

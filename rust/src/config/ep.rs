//! Expert-parallel configuration (`[ep]` TOML section, CLI-overridable).
//!
//! Drives the rank-sharded execution engine: how many simulated ranks,
//! how experts are placed on them, and the shape of the host-side expert
//! workload the engine runs (`coordinator::engine`).

use std::fmt;

use crate::memory::model::CheckpointPolicy;

use super::model::Activation;
use super::toml::Toml;

/// Expert→rank placement policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Rank r owns the block [r·E/R, (r+1)·E/R) — MegaBlocks/DeepSpeed
    /// default, best for expert-locality.
    Contiguous,
    /// Round-robin (e mod R) — spreads the hot low-id experts of a
    /// skewed router across ranks.
    Strided,
    /// Greedy rebalance from the previous step's per-expert routed-row
    /// loads (`EpTopology::load_aware`): heaviest expert first onto the
    /// least-loaded rank with spare capacity, never worse than
    /// `Contiguous` in max-rank load.
    LoadAware,
}

impl Placement {
    pub fn parse(s: &str) -> Result<Placement, String> {
        match s.to_ascii_lowercase().as_str() {
            "contiguous" | "block" => Ok(Placement::Contiguous),
            "strided" | "round-robin" | "round_robin" => Ok(Placement::Strided),
            "load-aware" | "load_aware" | "loadaware" => Ok(Placement::LoadAware),
            _ => Err(format!(
                "unknown placement `{s}` (contiguous|strided|load-aware)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Placement::Contiguous => "contiguous",
            Placement::Strided => "strided",
            Placement::LoadAware => "load-aware",
        }
    }
}

impl fmt::Display for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// How the chunk-pipelined engine cuts a batch into chunks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ChunkBalance {
    /// Even token counts per chunk (the original splitter).
    #[default]
    Tokens,
    /// Balance the summed routed-row load per chunk: each token is
    /// weighted by the total routed rows of the experts it feeds, so a
    /// skewed router's hot-expert tokens spread across more chunks and
    /// per-chunk busiest-rank load evens out. Bit-identical outputs —
    /// only chunk boundaries move.
    Rows,
}

impl ChunkBalance {
    pub fn parse(s: &str) -> Result<ChunkBalance, String> {
        match s.to_ascii_lowercase().as_str() {
            "tokens" | "token" => Ok(ChunkBalance::Tokens),
            "rows" | "row" | "routed-rows" => Ok(ChunkBalance::Rows),
            _ => Err(format!("unknown chunk balance `{s}` (tokens|rows)")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ChunkBalance::Tokens => "tokens",
            ChunkBalance::Rows => "rows",
        }
    }
}

impl fmt::Display for ChunkBalance {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Configuration of one expert-parallel engine run.
#[derive(Debug, Clone, PartialEq)]
pub struct EpConfig {
    /// simulated ranks R (each backed by one worker thread)
    pub ranks: usize,
    pub placement: Placement,
    /// routed tokens per step L
    pub tokens: usize,
    /// experts E (must be divisible by ranks)
    pub num_experts: usize,
    /// experts per token k
    pub top_k: usize,
    /// model dimension d of the exchanged activation rows
    pub d_model: usize,
    /// expert FFN hidden dimension h
    pub d_hidden: usize,
    /// router skew for the synthetic gating (0 = balanced)
    pub skew: f64,
    pub seed: u64,
    /// ep-train: optimizer steps and learning rate
    pub steps: usize,
    pub lr: f64,
    /// microbatches per optimizer step (contiguous token splits of the
    /// global batch; loss curves are bit-invariant to this)
    pub grad_accum: usize,
    /// optimizer name (`sgd` | `adam`)
    pub optimizer: String,
    /// fwd→bwd save/recompute policy (engine- and memory-model axis);
    /// with `checkpoint_auto` set this is only the fallback the planner
    /// overrides per layer
    pub checkpoint: CheckpointPolicy,
    /// `checkpoint = "auto"`: let `memory::planner::CheckpointPlanner`
    /// choose a per-layer policy vector that fits `mem_budget_bytes` at
    /// minimum estimated recompute + re-exchange cost
    pub checkpoint_auto: bool,
    /// MoE layers stacked per step (`coordinator::stack::MoeStack`);
    /// 1 = today's single-layer engines
    pub num_layers: usize,
    /// per-rank activation-memory budget for `checkpoint = auto`
    /// (data-class bytes); 0 = unlimited (planner picks all-save-all)
    pub mem_budget_bytes: u64,
    /// chunk-pipelined engine: split each step into this many
    /// token-contiguous chunks and overlap their dispatch exchange with
    /// expert compute (`coordinator::pipeline`). 0 = barrier engines
    /// (the pre-pipeline behavior); values above the token count clamp.
    pub pipeline_chunks: usize,
    /// chunk-boundary policy for the pipelined engine (`tokens` | `rows`)
    pub chunk_balance: ChunkBalance,
    /// expert FFN activation (`silu` = the 2-GEMM FFN, `swiglu` = the
    /// gated 3-GEMM FFN with the W3 gate streamed through the same
    /// staging tile) — reuses `config::model::Activation`, restricted
    /// to the two the expert kernels implement
    pub activation: Activation,
    /// routed-row tile of the blocked expert kernels: each expert's
    /// segment is processed `tile_rows` rows at a time, gathered
    /// straight from the batch into one staging tile. Numerics are
    /// bit-identical for every value; only throughput and staging
    /// residency move. 0 = autotune: probe the candidate tiles on the
    /// first real microbatch (or reuse the calibration artifact's
    /// choice for this shape bucket) and pick the fastest.
    pub tile_rows: usize,
    /// simulated cross-rank link bandwidth for the pipeline's phase
    /// timeline (decimal GB/s)
    pub link_gbps: f64,
    /// simulated per-rank expert-compute rate for the phase timeline
    /// (GFLOP/s)
    pub compute_gflops: f64,
    /// fold each step's measured-vs-simulated phase ratios back into the
    /// effective `link_gbps`/`compute_gflops` (EWMA across trainer
    /// steps) — the self-tuning cost model
    pub calibrate: bool,
    /// ep-train LR schedule (`constant` | `cosine` | `linear-warmup`)
    pub lr_schedule: String,
    /// ep-train global-norm gradient clipping threshold; 0 = off
    pub clip_norm: f64,
    /// metrics output (JSONL); empty = stdout only
    pub metrics_path: String,
    /// persistent calibration artifact (JSON): effective
    /// `link_gbps`/`compute_gflops` and autotuned tiles per shape
    /// bucket, loaded at engine build for a warm start and saved back
    /// by `ep-train`; empty = no artifact
    pub calibration_path: String,
    /// Chrome trace-event JSON output (`crate::trace`): attach a
    /// tracer to the engines and write the per-rank phase spans +
    /// counter tracks here at end of run; empty = tracing off (the
    /// engines pay nothing)
    pub trace_out: String,
    /// Prometheus text exposition file (`metrics::registry`): render
    /// the run's typed metrics registry here atomically on the
    /// console-log cadence and at end of run, as a file-based scrape
    /// target; empty = no registry attached (the engines pay nothing)
    pub metrics_expose_path: String,
    /// expert-load skew alarm threshold (`trace::load`): raise a
    /// `PlacementSignal` when a layer's per-rank load imbalance factor
    /// (max-rank / mean-rank routed-row EWMA) stays above this for
    /// `LOAD_HYSTERESIS` steps after warmup; 0 = alarm off (load EWMAs
    /// still track whenever a tracker is attached)
    pub skew_alarm: f64,
    /// crash-consistent training snapshots (`resilience::snapshot`):
    /// write a checksummed `TrainState` generation every this many
    /// optimizer steps (plus one at run end), keeping the last
    /// `KEEP_GENERATIONS`; 0 = snapshots off. Snapshots land only at
    /// optimizer-step boundaries — a mid-grad-accum request defers to
    /// the step boundary so resume stays bit-identical.
    pub snapshot_interval: usize,
    /// base path of the snapshot generations (`{path}.g<step>`);
    /// empty = snapshots off regardless of the interval
    pub snapshot_path: String,
    /// resume from the newest loadable snapshot generation at
    /// `snapshot_path` before stepping; a corrupt newest generation
    /// falls back to the previous one, a config whose numerics disagree
    /// with the snapshot's fingerprint is a hard error
    pub resume: bool,
}

impl Default for EpConfig {
    fn default() -> Self {
        EpConfig {
            ranks: 4,
            placement: Placement::Contiguous,
            tokens: 1024,
            num_experts: 16,
            top_k: 2,
            d_model: 64,
            d_hidden: 128,
            skew: 0.7,
            seed: 1,
            steps: 20,
            lr: 5e-2,
            grad_accum: 1,
            optimizer: "sgd".into(),
            checkpoint: CheckpointPolicy::default(),
            checkpoint_auto: false,
            num_layers: 1,
            mem_budget_bytes: 0,
            pipeline_chunks: 0,
            chunk_balance: ChunkBalance::default(),
            activation: Activation::Silu,
            tile_rows: crate::coordinator::kernels::DEFAULT_TILE_ROWS,
            link_gbps: 50.0,
            compute_gflops: 200.0,
            calibrate: false,
            lr_schedule: "constant".into(),
            clip_norm: 0.0,
            metrics_path: String::new(),
            calibration_path: String::new(),
            trace_out: String::new(),
            metrics_expose_path: String::new(),
            skew_alarm: 0.0,
            snapshot_interval: 0,
            snapshot_path: String::new(),
            resume: false,
        }
    }
}

impl EpConfig {
    /// Every key `[ep]` understands — `from_toml` rejects anything else
    /// by name instead of silently ignoring it.
    pub const KNOWN_KEYS: &'static [&'static str] = &[
        "ranks",
        "placement",
        "tokens",
        "num_experts",
        "top_k",
        "d_model",
        "d_hidden",
        "skew",
        "seed",
        "steps",
        "lr",
        "grad_accum",
        "optimizer",
        "checkpoint",
        "num_layers",
        "mem_budget_bytes",
        "pipeline_chunks",
        "chunk_balance",
        "activation",
        "tile_rows",
        "link_gbps",
        "compute_gflops",
        "calibrate",
        "lr_schedule",
        "clip_norm",
        "metrics_path",
        "calibration_path",
        "trace_out",
        "metrics_expose_path",
        "skew_alarm",
        "snapshot_interval",
        "snapshot_path",
        "resume",
    ];

    pub fn validate(&self) -> Result<(), String> {
        if self.ranks == 0 {
            return Err("ep.ranks must be > 0".into());
        }
        if self.num_experts == 0 || self.num_experts % self.ranks != 0 {
            return Err(format!(
                "ep.num_experts {} must be a positive multiple of ranks {}",
                self.num_experts, self.ranks
            ));
        }
        if self.top_k == 0 || self.top_k > self.num_experts {
            return Err(format!(
                "ep.top_k {} must be in 1..={}",
                self.top_k, self.num_experts
            ));
        }
        if self.tokens == 0 || self.d_model == 0 || self.d_hidden == 0 {
            return Err("ep dimensions must be positive".into());
        }
        if self.steps == 0 {
            return Err("ep.steps must be > 0".into());
        }
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            return Err(format!("ep.lr must be positive, got {}", self.lr));
        }
        if !(self.skew >= 0.0 && self.skew.is_finite()) {
            return Err(format!("ep.skew must be >= 0, got {}", self.skew));
        }
        if self.grad_accum == 0 || self.grad_accum > self.tokens {
            return Err(format!(
                "ep.grad_accum {} must be in 1..={} (tokens)",
                self.grad_accum, self.tokens
            ));
        }
        if self.num_layers == 0 {
            return Err("ep.num_layers must be >= 1".into());
        }
        if !matches!(self.activation, Activation::Silu | Activation::Swiglu) {
            return Err(format!(
                "ep.activation must be silu or swiglu, got {}",
                self.activation
            ));
        }
        // tile_rows = 0 is legal: it means autotune at engine build
        if !(self.link_gbps > 0.0 && self.link_gbps.is_finite()) {
            return Err(format!("ep.link_gbps must be positive, got {}", self.link_gbps));
        }
        if !(self.compute_gflops > 0.0 && self.compute_gflops.is_finite()) {
            return Err(format!(
                "ep.compute_gflops must be positive, got {}",
                self.compute_gflops
            ));
        }
        if !(self.clip_norm >= 0.0 && self.clip_norm.is_finite()) {
            return Err(format!("ep.clip_norm must be >= 0, got {}", self.clip_norm));
        }
        if !(self.skew_alarm >= 0.0 && self.skew_alarm.is_finite()) {
            return Err(format!(
                "ep.skew_alarm must be >= 0 (0 = off), got {}",
                self.skew_alarm
            ));
        }
        if self.resume && self.snapshot_path.is_empty() {
            return Err("ep.resume = true needs ep.snapshot_path set".into());
        }
        // single sources of truth for names: the respective registries
        let _ = crate::coordinator::optim::optimizer_from_name(&self.optimizer)?;
        let _ = crate::coordinator::optim::LrSchedule::parse(&self.lr_schedule)?;
        Ok(())
    }

    pub fn from_toml(t: &Toml, prefix: &str) -> Result<EpConfig, String> {
        t.reject_unknown_keys(prefix, Self::KNOWN_KEYS)?;
        let d = EpConfig::default();
        let key = |k: &str| format!("{prefix}.{k}");
        // one read of the checkpoint key feeds both the policy and the
        // auto flag — they must never desynchronize
        let checkpoint_key = t.str_or(&key("checkpoint"), d.checkpoint.name());
        let cfg = EpConfig {
            ranks: t.usize_or(&key("ranks"), d.ranks),
            placement: Placement::parse(
                &t.str_or(&key("placement"), d.placement.name()),
            )?,
            tokens: t.usize_or(&key("tokens"), d.tokens),
            num_experts: t.usize_or(&key("num_experts"), d.num_experts),
            top_k: t.usize_or(&key("top_k"), d.top_k),
            d_model: t.usize_or(&key("d_model"), d.d_model),
            d_hidden: t.usize_or(&key("d_hidden"), d.d_hidden),
            skew: t.f64_or(&key("skew"), d.skew),
            seed: t.usize_or(&key("seed"), d.seed as usize) as u64,
            steps: t.usize_or(&key("steps"), d.steps),
            lr: t.f64_or(&key("lr"), d.lr),
            grad_accum: t.usize_or(&key("grad_accum"), d.grad_accum),
            optimizer: t.str_or(&key("optimizer"), &d.optimizer),
            checkpoint: match checkpoint_key.as_str() {
                "auto" => d.checkpoint,
                other => CheckpointPolicy::parse(other)?,
            },
            checkpoint_auto: checkpoint_key == "auto",
            num_layers: t.usize_or(&key("num_layers"), d.num_layers),
            mem_budget_bytes: t.usize_or(&key("mem_budget_bytes"),
                                         d.mem_budget_bytes as usize) as u64,
            pipeline_chunks: t.usize_or(&key("pipeline_chunks"), d.pipeline_chunks),
            chunk_balance: ChunkBalance::parse(
                &t.str_or(&key("chunk_balance"), d.chunk_balance.name()),
            )?,
            activation: Activation::parse(
                &t.str_or(&key("activation"), d.activation.name()),
            )?,
            tile_rows: t.usize_or(&key("tile_rows"), d.tile_rows),
            link_gbps: t.f64_or(&key("link_gbps"), d.link_gbps),
            compute_gflops: t.f64_or(&key("compute_gflops"), d.compute_gflops),
            calibrate: t.bool_or(&key("calibrate"), d.calibrate),
            lr_schedule: t.str_or(&key("lr_schedule"), &d.lr_schedule),
            clip_norm: t.f64_or(&key("clip_norm"), d.clip_norm),
            metrics_path: t.str_or(&key("metrics_path"), &d.metrics_path),
            calibration_path: t.str_or(&key("calibration_path"),
                                       &d.calibration_path),
            trace_out: t.str_or(&key("trace_out"), &d.trace_out),
            metrics_expose_path: t.str_or(&key("metrics_expose_path"),
                                          &d.metrics_expose_path),
            skew_alarm: t.f64_or(&key("skew_alarm"), d.skew_alarm),
            snapshot_interval: t.usize_or(&key("snapshot_interval"),
                                          d.snapshot_interval),
            snapshot_path: t.str_or(&key("snapshot_path"), &d.snapshot_path),
            resume: t.bool_or(&key("resume"), d.resume),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// n = L·k routed slots.
    pub fn slots(&self) -> usize {
        self.tokens * self.top_k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_parse() {
        assert_eq!(Placement::parse("Contiguous").unwrap(), Placement::Contiguous);
        assert_eq!(Placement::parse("round-robin").unwrap(), Placement::Strided);
        assert_eq!(Placement::parse("Load-Aware").unwrap(), Placement::LoadAware);
        assert_eq!(Placement::parse("load_aware").unwrap(), Placement::LoadAware);
        assert_eq!(Placement::LoadAware.name(), "load-aware");
        assert!(Placement::parse("diagonal").is_err());
    }

    #[test]
    fn pipeline_and_schedule_keys() {
        let t = Toml::parse(
            "[ep]\npipeline_chunks = 4\nlink_gbps = 25.0\ncompute_gflops = 80.0\n\
             lr_schedule = \"cosine\"\nclip_norm = 1.5",
        )
        .unwrap();
        let c = EpConfig::from_toml(&t, "ep").unwrap();
        assert_eq!(c.pipeline_chunks, 4);
        assert_eq!(c.link_gbps, 25.0);
        assert_eq!(c.compute_gflops, 80.0);
        assert_eq!(c.lr_schedule, "cosine");
        assert_eq!(c.clip_norm, 1.5);
        // defaults: barrier engines, constant LR, clipping off
        let d = EpConfig::default();
        assert_eq!(d.pipeline_chunks, 0);
        assert_eq!(d.lr_schedule, "constant");
        assert_eq!(d.clip_norm, 0.0);
        d.validate().unwrap();
        // invalid values rejected
        assert!(EpConfig { link_gbps: 0.0, ..Default::default() }.validate().is_err());
        assert!(EpConfig { compute_gflops: -1.0, ..Default::default() }
            .validate()
            .is_err());
        assert!(EpConfig { clip_norm: f64::NAN, ..Default::default() }
            .validate()
            .is_err());
        assert!(EpConfig { lr_schedule: "sawtooth".into(), ..Default::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn tile_rows_and_calibrate_keys() {
        let t = Toml::parse("[ep]\ntile_rows = 8\ncalibrate = true").unwrap();
        let c = EpConfig::from_toml(&t, "ep").unwrap();
        assert_eq!(c.tile_rows, 8);
        assert!(c.calibrate);
        // defaults: the kernel tile constant, calibration off
        let d = EpConfig::default();
        assert_eq!(d.tile_rows,
                   crate::coordinator::kernels::DEFAULT_TILE_ROWS);
        assert!(!d.calibrate);
        d.validate().unwrap();
        // tile_rows = 0 means autotune — a legal config since PR 6
        assert!(EpConfig { tile_rows: 0, ..Default::default() }
            .validate()
            .is_ok());
    }

    #[test]
    fn activation_and_calibration_keys() {
        let t = Toml::parse(
            "[ep]\nactivation = \"swiglu\"\ntile_rows = 0\n\
             calibration_path = \"/tmp/calib.json\"\n\
             trace_out = \"/tmp/trace.json\"",
        )
        .unwrap();
        let c = EpConfig::from_toml(&t, "ep").unwrap();
        assert_eq!(c.activation, Activation::Swiglu);
        assert!(c.activation.gated());
        assert_eq!(c.tile_rows, 0);
        assert_eq!(c.calibration_path, "/tmp/calib.json");
        assert_eq!(c.trace_out, "/tmp/trace.json");
        assert!(EpConfig::default().trace_out.is_empty());
        // defaults: ungated SiLU, no artifact
        let d = EpConfig::default();
        assert_eq!(d.activation, Activation::Silu);
        assert!(!d.activation.gated());
        assert!(d.calibration_path.is_empty());
        d.validate().unwrap();
        // the expert kernels implement silu and swiglu only
        assert!(EpConfig { activation: Activation::Gelu, ..Default::default() }
            .validate()
            .is_err());
        assert!(EpConfig { activation: Activation::Relu, ..Default::default() }
            .validate()
            .is_err());
        assert!(Toml::parse("[ep]\nactivation = \"tanh\"")
            .map(|t| EpConfig::from_toml(&t, "ep"))
            .unwrap()
            .is_err());
    }

    #[test]
    fn validation() {
        assert!(EpConfig::default().validate().is_ok());
        assert!(EpConfig { ranks: 0, ..Default::default() }.validate().is_err());
        assert!(EpConfig { num_experts: 10, ranks: 4, ..Default::default() }
            .validate()
            .is_err());
        assert!(EpConfig { top_k: 99, ..Default::default() }.validate().is_err());
        assert!(EpConfig { lr: 0.0, ..Default::default() }.validate().is_err());
    }

    #[test]
    fn from_toml_overrides() {
        let t = Toml::parse(
            "[ep]\nranks = 8\nnum_experts = 32\nplacement = \"strided\"\nskew = 1.5",
        )
        .unwrap();
        let c = EpConfig::from_toml(&t, "ep").unwrap();
        assert_eq!(c.ranks, 8);
        assert_eq!(c.num_experts, 32);
        assert_eq!(c.placement, Placement::Strided);
        assert_eq!(c.skew, 1.5);
        assert_eq!(c.top_k, EpConfig::default().top_k);
        assert_eq!(c.grad_accum, 1);
        assert_eq!(c.optimizer, "sgd");
        assert_eq!(c.checkpoint, CheckpointPolicy::SaveInputs);
    }

    #[test]
    fn from_toml_step_session_keys() {
        let t = Toml::parse(
            "[ep]\ngrad_accum = 4\noptimizer = \"adam\"\ncheckpoint = \"recompute-all\"",
        )
        .unwrap();
        let c = EpConfig::from_toml(&t, "ep").unwrap();
        assert_eq!(c.grad_accum, 4);
        assert_eq!(c.optimizer, "adam");
        assert_eq!(c.checkpoint, CheckpointPolicy::RecomputeAll);
        assert!(Toml::parse("[ep]\ncheckpoint = \"maybe\"")
            .map(|t| EpConfig::from_toml(&t, "ep"))
            .unwrap()
            .is_err());
    }

    #[test]
    fn grad_accum_and_optimizer_validation() {
        assert!(EpConfig { grad_accum: 0, ..Default::default() }
            .validate()
            .is_err());
        assert!(EpConfig { grad_accum: 2048, tokens: 1024, ..Default::default() }
            .validate()
            .is_err());
        assert!(EpConfig { optimizer: "lion".into(), ..Default::default() }
            .validate()
            .is_err());
        assert!(EpConfig { optimizer: "Adam".into(), ..Default::default() }
            .validate()
            .is_ok());
    }

    #[test]
    fn from_toml_rejects_invalid() {
        let t = Toml::parse("[ep]\nranks = 3\nnum_experts = 16").unwrap();
        assert!(EpConfig::from_toml(&t, "ep").is_err());
    }

    #[test]
    fn from_toml_rejects_unknown_keys_by_name() {
        // a typo'd key fails loudly instead of silently using the default
        let t = Toml::parse("[ep]\nranks = 4\ntopk = 2").unwrap();
        let err = EpConfig::from_toml(&t, "ep").unwrap_err();
        assert!(err.contains("`topk`"), "{err}");
        assert!(err.contains("[ep]"), "{err}");
        assert!(err.contains("top_k"), "named-key error lists known keys: {err}");
        // every documented key passes the check
        let all = EpConfig::KNOWN_KEYS
            .iter()
            .map(|k| match *k {
                "placement" => format!("{k} = \"contiguous\""),
                "optimizer" => format!("{k} = \"sgd\""),
                "checkpoint" => format!("{k} = \"save-inputs\""),
                "chunk_balance" => format!("{k} = \"tokens\""),
                "activation" => format!("{k} = \"silu\""),
                "lr_schedule" => format!("{k} = \"constant\""),
                "metrics_path" | "calibration_path" | "trace_out"
                | "metrics_expose_path" | "snapshot_path" => {
                    format!("{k} = \"\"")
                }
                "calibrate" | "resume" => format!("{k} = false"),
                "snapshot_interval" => format!("{k} = 0"),
                "skew" => format!("{k} = 0.7"),
                "lr" => format!("{k} = 0.05"),
                "link_gbps" => format!("{k} = 50.0"),
                "compute_gflops" => format!("{k} = 200.0"),
                "clip_norm" | "skew_alarm" => format!("{k} = 0.0"),
                "pipeline_chunks" | "mem_budget_bytes" => format!("{k} = 0"),
                "tokens" => format!("{k} = 64"),
                "num_experts" => format!("{k} = 8"),
                _ => format!("{k} = 1"),
            })
            .collect::<Vec<_>>()
            .join("\n");
        let t = Toml::parse(&format!("[ep]\n{all}")).unwrap();
        EpConfig::from_toml(&t, "ep").unwrap();
        // sections other than [ep] stay out of scope for the check
        let t = Toml::parse("[ep]\nranks = 2\nnum_experts = 8\n\
                             [serving]\nticks = 5")
            .unwrap();
        EpConfig::from_toml(&t, "ep").unwrap();
    }

    #[test]
    fn from_toml_rejects_misspelled_observability_keys_by_name() {
        // the PR-9 keys obey the PR-7 contract: misspellings fail loudly
        for (bad, good) in [
            ("metrics_expose", "metrics_expose_path"),
            ("metrics_expose_file", "metrics_expose_path"),
            ("skew_alarm_threshold", "skew_alarm"),
            ("skew_alert", "skew_alarm"),
        ] {
            let t = Toml::parse(&format!("[ep]\n{bad} = 1")).unwrap();
            let err = EpConfig::from_toml(&t, "ep").unwrap_err();
            assert!(err.contains(&format!("`{bad}`")), "{err}");
            assert!(err.contains(good),
                    "error for `{bad}` should name `{good}`: {err}");
        }
        // the real spellings parse and land in the config
        let t = Toml::parse(
            "[ep]\nmetrics_expose_path = \"m.prom\"\nskew_alarm = 1.5",
        )
        .unwrap();
        let c = EpConfig::from_toml(&t, "ep").unwrap();
        assert_eq!(c.metrics_expose_path, "m.prom");
        assert_eq!(c.skew_alarm, 1.5);
        // defaults: both off
        let d = EpConfig::default();
        assert!(d.metrics_expose_path.is_empty());
        assert_eq!(d.skew_alarm, 0.0);
        // negative / non-finite thresholds are invalid
        assert!(EpConfig { skew_alarm: -0.5, ..Default::default() }
            .validate()
            .is_err());
        assert!(EpConfig { skew_alarm: f64::NAN, ..Default::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn from_toml_rejects_misspelled_resilience_keys_by_name() {
        // the PR-10 snapshot/resume keys obey the same loud-typo contract
        for (bad, good) in [
            ("snapshot_every", "snapshot_interval"),
            ("snapshot_steps", "snapshot_interval"),
            ("snapshot_file", "snapshot_path"),
            ("checkpoint_path", "snapshot_path"),
            ("restore", "resume"),
        ] {
            let t = Toml::parse(&format!("[ep]\n{bad} = 1")).unwrap();
            let err = EpConfig::from_toml(&t, "ep").unwrap_err();
            assert!(err.contains(&format!("`{bad}`")), "{err}");
            assert!(err.contains(good),
                    "error for `{bad}` should name `{good}`: {err}");
        }
        // the real spellings parse and land in the config
        let t = Toml::parse(
            "[ep]\nsnapshot_interval = 5\nsnapshot_path = \"/tmp/snap\"\n\
             resume = true",
        )
        .unwrap();
        let c = EpConfig::from_toml(&t, "ep").unwrap();
        assert_eq!(c.snapshot_interval, 5);
        assert_eq!(c.snapshot_path, "/tmp/snap");
        assert!(c.resume);
        // defaults: snapshots off, no resume
        let d = EpConfig::default();
        assert_eq!(d.snapshot_interval, 0);
        assert!(d.snapshot_path.is_empty());
        assert!(!d.resume);
        d.validate().unwrap();
        // resume without a snapshot path has nothing to restore from
        assert!(EpConfig { resume: true, ..Default::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn chunk_balance_parse() {
        assert_eq!(ChunkBalance::parse("tokens").unwrap(), ChunkBalance::Tokens);
        assert_eq!(ChunkBalance::parse("Rows").unwrap(), ChunkBalance::Rows);
        assert_eq!(ChunkBalance::parse("routed-rows").unwrap(), ChunkBalance::Rows);
        assert!(ChunkBalance::parse("bytes").is_err());
        assert_eq!(ChunkBalance::default(), ChunkBalance::Tokens);
        assert_eq!(ChunkBalance::Rows.name(), "rows");
    }

    #[test]
    fn from_toml_stack_and_planner_keys() {
        let t = Toml::parse(
            "[ep]\nnum_layers = 4\nmem_budget_bytes = 1048576\n\
             checkpoint = \"auto\"\nchunk_balance = \"rows\"",
        )
        .unwrap();
        let c = EpConfig::from_toml(&t, "ep").unwrap();
        assert_eq!(c.num_layers, 4);
        assert_eq!(c.mem_budget_bytes, 1_048_576);
        assert!(c.checkpoint_auto);
        // `auto` leaves the fixed policy at its default — the planner
        // overrides it per layer
        assert_eq!(c.checkpoint, CheckpointPolicy::SaveInputs);
        assert_eq!(c.chunk_balance, ChunkBalance::Rows);
        // defaults: single layer, unlimited budget, fixed policy
        let d = EpConfig::default();
        assert_eq!(d.num_layers, 1);
        assert_eq!(d.mem_budget_bytes, 0);
        assert!(!d.checkpoint_auto);
        d.validate().unwrap();
        assert!(EpConfig { num_layers: 0, ..Default::default() }
            .validate()
            .is_err());
        // a non-auto checkpoint string still parses strictly
        assert!(Toml::parse("[ep]\ncheckpoint = \"maybe\"")
            .map(|t| EpConfig::from_toml(&t, "ep"))
            .unwrap()
            .is_err());
    }
}

//! Deterministic fault-injection configuration (`[fault]` TOML section).
//!
//! All-zero (the default) means no faults: the trainer and serve loop
//! consult nothing and pay nothing. Any positive probability arms the
//! seeded `resilience::fault::FaultPlan`, whose every decision is a
//! pure mixing function of `(seed, site, step, lane)` — two runs with
//! the same `[fault]` section raise the identical fault sequence, which
//! is what lets the recovery paths be pinned by tests (and mirrored
//! bit-for-bit in `tools/ep_sim.py`).

use super::toml::Toml;

/// Configuration of one deterministic fault-injection plan.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// decision seed — same seed, same fault sequence
    pub seed: u64,
    /// per-step probability that one rank stalls (numerics-neutral:
    /// surfaced as a recovered `FaultEvent`, and the serve loop's shed
    /// trigger)
    pub stall_prob: f64,
    /// simulated stall duration (host sleep; 0 = record only)
    pub stall_ms: u64,
    /// per-(step, microbatch, attempt) probability that the exchange
    /// transiently fails — recovered by bounded retry with exponential
    /// backoff, or surfaced unrecovered when the budget is exhausted
    pub exchange_fail_prob: f64,
    /// per-snapshot probability that the just-written generation is
    /// corrupted (byte flip or truncation) — recovered by the
    /// last-good-generation fallback
    pub snapshot_corrupt_prob: f64,
    /// retry budget for transient exchange/IO faults
    pub max_retries: usize,
    /// base backoff between retries (doubles per attempt)
    pub backoff_ms: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0,
            stall_prob: 0.0,
            stall_ms: 0,
            exchange_fail_prob: 0.0,
            snapshot_corrupt_prob: 0.0,
            max_retries: 3,
            backoff_ms: 1,
        }
    }
}

impl FaultConfig {
    /// Every key `[fault]` understands — `from_toml` rejects anything
    /// else by name instead of silently ignoring it.
    pub const KNOWN_KEYS: &'static [&'static str] = &[
        "seed",
        "stall_prob",
        "stall_ms",
        "exchange_fail_prob",
        "snapshot_corrupt_prob",
        "max_retries",
        "backoff_ms",
    ];

    /// Whether any fault family is armed.
    pub fn enabled(&self) -> bool {
        self.stall_prob > 0.0
            || self.exchange_fail_prob > 0.0
            || self.snapshot_corrupt_prob > 0.0
    }

    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in [
            ("fault.stall_prob", self.stall_prob),
            ("fault.exchange_fail_prob", self.exchange_fail_prob),
            ("fault.snapshot_corrupt_prob", self.snapshot_corrupt_prob),
        ] {
            if !(p.is_finite() && (0.0..=1.0).contains(&p)) {
                return Err(format!("{name} must be in [0, 1], got {p}"));
            }
        }
        if self.max_retries > 16 {
            return Err(format!(
                "fault.max_retries {} is past any sane budget (max 16)",
                self.max_retries
            ));
        }
        if self.backoff_ms > 10_000 {
            return Err(format!(
                "fault.backoff_ms {} would stall tests (max 10000)",
                self.backoff_ms
            ));
        }
        if self.stall_ms > 10_000 {
            return Err(format!(
                "fault.stall_ms {} would stall tests (max 10000)",
                self.stall_ms
            ));
        }
        Ok(())
    }

    pub fn from_toml(t: &Toml, prefix: &str) -> Result<FaultConfig, String> {
        t.reject_unknown_keys(prefix, Self::KNOWN_KEYS)?;
        let d = FaultConfig::default();
        let key = |k: &str| format!("{prefix}.{k}");
        let cfg = FaultConfig {
            seed: t.usize_or(&key("seed"), d.seed as usize) as u64,
            stall_prob: t.f64_or(&key("stall_prob"), d.stall_prob),
            stall_ms: t.usize_or(&key("stall_ms"), d.stall_ms as usize) as u64,
            exchange_fail_prob: t.f64_or(&key("exchange_fail_prob"),
                                         d.exchange_fail_prob),
            snapshot_corrupt_prob: t.f64_or(&key("snapshot_corrupt_prob"),
                                            d.snapshot_corrupt_prob),
            max_retries: t.usize_or(&key("max_retries"), d.max_retries),
            backoff_ms: t.usize_or(&key("backoff_ms"), d.backoff_ms as usize)
                as u64,
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_disabled_and_valid() {
        let d = FaultConfig::default();
        assert!(!d.enabled());
        d.validate().unwrap();
        // arming any one family enables the plan
        assert!(FaultConfig { stall_prob: 0.1, ..Default::default() }.enabled());
        assert!(FaultConfig { exchange_fail_prob: 0.1, ..Default::default() }
            .enabled());
        assert!(FaultConfig { snapshot_corrupt_prob: 0.1, ..Default::default() }
            .enabled());
    }

    #[test]
    fn from_toml_parses_and_validates() {
        let t = Toml::parse(
            "[fault]\nseed = 7\nstall_prob = 0.15\nexchange_fail_prob = 0.25\n\
             snapshot_corrupt_prob = 0.2\nmax_retries = 4\nbackoff_ms = 2",
        )
        .unwrap();
        let c = FaultConfig::from_toml(&t, "fault").unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.stall_prob, 0.15);
        assert_eq!(c.exchange_fail_prob, 0.25);
        assert_eq!(c.snapshot_corrupt_prob, 0.2);
        assert_eq!(c.max_retries, 4);
        assert_eq!(c.backoff_ms, 2);
        assert!(c.enabled());
        // a missing section yields the disabled default
        let t = Toml::parse("[ep]\nranks = 2").unwrap();
        assert_eq!(FaultConfig::from_toml(&t, "fault").unwrap(),
                   FaultConfig::default());
        // out-of-range values fail loudly
        assert!(FaultConfig { stall_prob: 1.5, ..Default::default() }
            .validate()
            .is_err());
        assert!(FaultConfig { exchange_fail_prob: -0.1, ..Default::default() }
            .validate()
            .is_err());
        assert!(FaultConfig { snapshot_corrupt_prob: f64::NAN,
                              ..Default::default() }
            .validate()
            .is_err());
        assert!(FaultConfig { max_retries: 99, ..Default::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn from_toml_rejects_unknown_keys_by_name() {
        // typos in [fault] fail loudly, naming the known keys
        for (bad, good) in [
            ("stall_probability", "stall_prob"),
            ("exchange_prob", "exchange_fail_prob"),
            ("corrupt_prob", "snapshot_corrupt_prob"),
            ("retries", "max_retries"),
        ] {
            let t = Toml::parse(&format!("[fault]\n{bad} = 1")).unwrap();
            let err = FaultConfig::from_toml(&t, "fault").unwrap_err();
            assert!(err.contains(&format!("`{bad}`")), "{err}");
            assert!(err.contains(good),
                    "error for `{bad}` should name `{good}`: {err}");
        }
        // every documented key passes the check
        let all = FaultConfig::KNOWN_KEYS
            .iter()
            .map(|k| match *k {
                "stall_prob" | "exchange_fail_prob" | "snapshot_corrupt_prob" => {
                    format!("{k} = 0.5")
                }
                _ => format!("{k} = 1"),
            })
            .collect::<Vec<_>>()
            .join("\n");
        let t = Toml::parse(&format!("[fault]\n{all}")).unwrap();
        FaultConfig::from_toml(&t, "fault").unwrap();
    }
}

//! Typed model/MoE configuration with validation.

use std::fmt;

use super::toml::Toml;

/// Activation family (paper §5.1). `SwiGLU` is the gated family that
/// drives the paper's Figures 5/6.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activation {
    Relu,
    Silu,
    Gelu,
    Swiglu,
}

impl Activation {
    pub fn parse(s: &str) -> Result<Activation, String> {
        match s.to_ascii_lowercase().as_str() {
            "relu" => Ok(Activation::Relu),
            "silu" => Ok(Activation::Silu),
            "gelu" => Ok(Activation::Gelu),
            "swiglu" => Ok(Activation::Swiglu),
            _ => Err(format!("unknown activation `{s}`")),
        }
    }

    pub fn gated(self) -> bool {
        self == Activation::Swiglu
    }

    pub fn name(self) -> &'static str {
        match self {
            Activation::Relu => "relu",
            Activation::Silu => "silu",
            Activation::Gelu => "gelu",
            Activation::Swiglu => "swiglu",
        }
    }
}

impl fmt::Display for Activation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Which MoE implementation a computation uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Impl {
    /// Paper contribution: index-driven dispatch + checkpointed kernels.
    MoeBlaze,
    /// Conventional dropless pipeline (MegaBlocks-style).
    Baseline,
}

impl Impl {
    pub fn parse(s: &str) -> Result<Impl, String> {
        match s.to_ascii_lowercase().as_str() {
            "moeblaze" => Ok(Impl::MoeBlaze),
            "baseline" | "megablocks" => Ok(Impl::Baseline),
            _ => Err(format!("unknown impl `{s}`")),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Impl::MoeBlaze => "moeblaze",
            Impl::Baseline => "baseline",
        }
    }
}

impl fmt::Display for Impl {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// One MoE layer's shape (paper §2 notation).
#[derive(Debug, Clone, PartialEq)]
pub struct MoeConfig {
    /// model dimension d
    pub d_model: usize,
    /// FFN hidden dimension h (paper Table 1: 4d)
    pub d_hidden: usize,
    /// number of experts E
    pub num_experts: usize,
    /// experts per token k
    pub top_k: usize,
    /// routed tokens per step L (batch × seq)
    pub tokens: usize,
    pub activation: Activation,
    /// slot-block size for the block-aligned index layout
    pub block: usize,
}

impl MoeConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.top_k == 0 || self.top_k > self.num_experts {
            return Err(format!(
                "top_k {} must be in 1..={}",
                self.top_k, self.num_experts
            ));
        }
        if self.d_model == 0 || self.d_hidden == 0 || self.tokens == 0 {
            return Err("dimensions must be positive".into());
        }
        if self.block == 0 {
            return Err("block must be positive".into());
        }
        Ok(())
    }

    /// n = L·k routed slots.
    pub fn slots(&self) -> usize {
        self.tokens * self.top_k
    }

    /// Static worst-case padded slot count (mirror of ref.padded_len).
    pub fn padded_slots(&self) -> usize {
        let worst = self.slots() + self.num_experts * (self.block - 1);
        worst.div_ceil(self.block) * self.block
    }

    /// Forward FLOPs of the expert MLPs (2·n·d·h per GEMM).
    pub fn forward_flops(&self) -> u64 {
        let gemms = if self.activation.gated() { 3 } else { 2 };
        2 * self.slots() as u64
            * self.d_model as u64
            * self.d_hidden as u64
            * gemms as u64
    }

    pub fn from_toml(t: &Toml, prefix: &str) -> Result<MoeConfig, String> {
        let key = |k: &str| format!("{prefix}.{k}");
        let d_model = t.usize_or(&key("d_model"), 0);
        let cfg = MoeConfig {
            d_model,
            d_hidden: t.usize_or(&key("d_hidden"), 4 * d_model),
            num_experts: t.usize_or(&key("num_experts"), 8),
            top_k: t.usize_or(&key("top_k"), 2),
            tokens: t.usize_or(&key("tokens"), 0),
            activation: Activation::parse(&t.str_or(&key("activation"), "swiglu"))?,
            block: t.usize_or(&key("block"), 128),
        };
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MoeConfig {
        MoeConfig {
            d_model: 128,
            d_hidden: 512,
            num_experts: 8,
            top_k: 2,
            tokens: 512,
            activation: Activation::Swiglu,
            block: 32,
        }
    }

    #[test]
    fn validation() {
        assert!(cfg().validate().is_ok());
        let mut bad = cfg();
        bad.top_k = 9;
        assert!(bad.validate().is_err());
        bad = cfg();
        bad.tokens = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn derived_sizes() {
        let c = cfg();
        assert_eq!(c.slots(), 1024);
        // 1024 + 8*31 = 1272 → roundup 32 = 1280
        assert_eq!(c.padded_slots(), 1280);
        assert_eq!(c.forward_flops(), 2 * 1024 * 128 * 512 * 3);
    }

    #[test]
    fn activation_parse() {
        assert_eq!(Activation::parse("SwiGLU").unwrap(), Activation::Swiglu);
        assert!(Activation::Swiglu.gated());
        assert!(!Activation::Silu.gated());
        assert!(Activation::parse("tanh").is_err());
    }

    #[test]
    fn from_toml() {
        let t = Toml::parse(
            "[moe]\nd_model = 64\ntokens = 256\nnum_experts = 4\ntop_k = 1\nactivation = \"silu\"",
        )
        .unwrap();
        let c = MoeConfig::from_toml(&t, "moe").unwrap();
        assert_eq!(c.d_hidden, 256);
        assert_eq!(c.activation, Activation::Silu);
    }
}

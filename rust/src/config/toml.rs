//! TOML-subset parser — enough for real config files (the `toml` crate is
//! unavailable offline).
//!
//! Supported: `[section]` / `[a.b]` tables, `key = value` with strings,
//! integers, floats, booleans, flat arrays, inline comments (`#`), and
//! bare/quoted keys. Unsupported (rejected, not silently ignored): array
//! tables, multi-line strings, datetimes, nested inline tables.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat map of `section.key` → value.
#[derive(Debug, Default, Clone)]
pub struct Toml {
    pub entries: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl Toml {
    pub fn parse(text: &str) -> Result<Toml, TomlError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError { line: ln + 1, msg: msg.to_string() };
            if let Some(rest) = line.strip_prefix('[') {
                if line.starts_with("[[") {
                    return Err(err("array tables are not supported"));
                }
                let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(err("empty section name"));
                }
                continue;
            }
            let (k, v) = line.split_once('=').ok_or_else(|| err("expected key = value"))?;
            let key = k.trim().trim_matches('"').to_string();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let value = parse_value(v.trim()).map_err(|m| err(&m))?;
            let full = if section.is_empty() { key } else { format!("{section}.{key}") };
            entries.insert(full, value);
        }
        Ok(Toml { entries })
    }

    pub fn load(path: &str) -> Result<Toml, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        Toml::parse(&text).map_err(|e| format!("{path}: {e}"))
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(Value::as_str).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    /// Keys under a section prefix (`section.` stripped).
    pub fn section(&self, prefix: &str) -> BTreeMap<String, Value> {
        let pre = format!("{prefix}.");
        self.entries
            .iter()
            .filter_map(|(k, v)| k.strip_prefix(&pre).map(|r| (r.to_string(), v.clone())))
            .collect()
    }

    /// Config hardening: error on any `[prefix]` key not in `known`, by
    /// name, instead of letting a typo silently fall back to the
    /// default. Nested `[prefix.sub]` keys surface as `sub.key` and are
    /// rejected the same way.
    pub fn reject_unknown_keys(&self, prefix: &str, known: &[&str]) -> Result<(), String> {
        for k in self.section(prefix).keys() {
            if !known.contains(&k.as_str()) {
                return Err(format!(
                    "unknown key `{k}` in [{prefix}] (known keys: {})",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(unescape(inner)?));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim())?);
        }
        return Ok(Value::Array(items));
    }
    let cleaned = s.replace('_', "");
    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
        if let Ok(i) = cleaned.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value `{s}`"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::new();
    let mut it = s.chars();
    while let Some(c) = it.next() {
        if c == '\\' {
            match it.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                other => return Err(format!("bad escape \\{other:?}")),
            }
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# training config
name = "tiny"
steps = 1_000
lr = 3e-4

[model]
d_model = 128
experts = 8
use_pallas = true
dims = [1, 2, 3]

[model.moe]
top_k = 2
"#;

    #[test]
    fn parses_sections_and_types() {
        let t = Toml::parse(SAMPLE).unwrap();
        assert_eq!(t.str_or("name", ""), "tiny");
        assert_eq!(t.usize_or("steps", 0), 1000);
        assert!((t.f64_or("lr", 0.0) - 3e-4).abs() < 1e-12);
        assert_eq!(t.usize_or("model.d_model", 0), 128);
        assert!(t.bool_or("model.use_pallas", false));
        assert_eq!(t.usize_or("model.moe.top_k", 0), 2);
        assert_eq!(
            t.get("model.dims").unwrap(),
            &Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
    }

    #[test]
    fn comments_and_strings() {
        let t = Toml::parse("s = \"a # not comment\" # real comment").unwrap();
        assert_eq!(t.str_or("s", ""), "a # not comment");
    }

    #[test]
    fn rejects_malformed() {
        assert!(Toml::parse("[unclosed").is_err());
        assert!(Toml::parse("novalue =").is_err());
        assert!(Toml::parse("x = [1, 2").is_err());
        assert!(Toml::parse("[[arr]]").is_err());
    }

    #[test]
    fn section_extraction() {
        let t = Toml::parse(SAMPLE).unwrap();
        let m = t.section("model");
        assert!(m.contains_key("d_model"));
        assert!(m.contains_key("moe.top_k"));
    }

    #[test]
    fn unknown_key_rejection_names_the_key() {
        let t = Toml::parse("[model]\nd_model = 8\ndmodel = 9").unwrap();
        t.reject_unknown_keys("model", &["d_model", "dmodel"]).unwrap();
        let err = t.reject_unknown_keys("model", &["d_model"]).unwrap_err();
        assert!(err.contains("`dmodel`"), "{err}");
        assert!(err.contains("[model]"), "{err}");
        assert!(err.contains("d_model"), "{err}");
        // other sections' keys don't leak into the check
        let t = Toml::parse("[a]\nx = 1\n[b]\nbogus = 2").unwrap();
        t.reject_unknown_keys("a", &["x"]).unwrap();
    }
}

//! Configuration system: TOML-subset parser + typed configs + paper presets.

pub mod ep;
pub mod fault;
pub mod model;
pub mod paper;
pub mod serving;
pub mod toml;
pub mod train;

pub use ep::{EpConfig, Placement};
pub use fault::FaultConfig;
pub use serving::{AdmissionPolicy, ServingConfig};
pub use model::{Activation, Impl, MoeConfig};
pub use paper::{paper_configs, scaled_configs, PaperConfig, PAPER_BLOCK, SCALED_BLOCK};
pub use train::TrainConfig;

//! Persistent calibration artifact (`[ep] calibration_path`).
//!
//! One training run learns two kinds of host-specific state worth
//! keeping: the EWMA-folded effective `link_gbps` / `compute_gflops`
//! the timeline's `recalibrate_cost_model` converges to, and the
//! `tile_rows` the autotune probe picked per shape bucket
//! (`engine::tile_bucket`). This module round-trips both through a
//! small JSON artifact so the *next* run starts warm:
//! `engine_from_config_with_info` loads it at build time, overriding
//! the config's cold-start rates and skipping the tile probe for any
//! bucket the artifact already answers; `EpTrainer` saves it back at
//! run end with the rates it just calibrated.
//!
//! Robustness contract: [`Calibration::load`] returns `None` for a
//! missing, unreadable, or corrupt artifact (bad JSON, missing keys,
//! non-positive rates) — the caller falls back to cold-start defaults
//! without error, which the artifact-fallback tests pin.
//! [`Calibration::save`] writes via a temp file + rename, so a crash
//! mid-write can never leave a half-written artifact behind for the
//! next run to trip over.

use std::collections::BTreeMap;
use std::fs;

use crate::util::json::Json;

/// The persisted calibration state: effective cost-model rates plus the
/// chosen blocked-kernel tile per shape bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// EWMA-folded effective link bandwidth (GB/s)
    pub link_gbps: f64,
    /// EWMA-folded effective compute rate (GFLOP/s)
    pub compute_gflops: f64,
    /// autotuned `tile_rows` keyed by `engine::tile_bucket` strings
    pub tiles: BTreeMap<String, usize>,
}

impl Calibration {
    /// Read an artifact, or `None` if the file is missing or corrupt in
    /// any way — the cold-start fallback path, never an error.
    pub fn load(path: &str) -> Option<Calibration> {
        let text = fs::read_to_string(path).ok()?;
        let j = Json::parse(&text).ok()?;
        let link_gbps = j.get("link_gbps")?.as_f64()?;
        let compute_gflops = j.get("compute_gflops")?.as_f64()?;
        if !link_gbps.is_finite() || link_gbps <= 0.0
            || !compute_gflops.is_finite() || compute_gflops <= 0.0
        {
            return None;
        }
        let mut tiles = BTreeMap::new();
        if let Some(map) = j.get("tiles").and_then(|t| t.as_obj()) {
            for (bucket, tile) in map {
                let t = tile.as_usize()?;
                if t == 0 {
                    return None;
                }
                tiles.insert(bucket.clone(), t);
            }
        }
        Some(Calibration { link_gbps, compute_gflops, tiles })
    }

    /// Write the artifact atomically (temp file + rename). The JSON
    /// serializer walks `BTreeMap`s in key order, so equal state always
    /// produces byte-identical artifacts.
    pub fn save(&self, path: &str) -> Result<(), String> {
        let tiles: Vec<(&str, Json)> = self
            .tiles
            .iter()
            .map(|(bucket, &tile)| (bucket.as_str(), Json::num(tile as f64)))
            .collect();
        let j = Json::obj(vec![
            ("link_gbps", Json::num(self.link_gbps)),
            ("compute_gflops", Json::num(self.compute_gflops)),
            ("tiles", Json::obj(tiles)),
        ]);
        let tmp = format!("{path}.tmp");
        fs::write(&tmp, j.to_string())
            .map_err(|e| format!("writing {tmp}: {e}"))?;
        fs::rename(&tmp, path)
            .map_err(|e| format!("renaming {tmp} -> {path}: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> String {
        let dir = std::env::temp_dir();
        dir.join(format!("moeblaze-calib-{tag}-{}.json", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    #[test]
    fn round_trips_rates_and_tiles() {
        let path = tmp_path("roundtrip");
        let mut tiles = BTreeMap::new();
        tiles.insert("tile:d32:h64:r256:swiglu".to_string(), 32usize);
        tiles.insert("tile:d32:h64:r256:silu".to_string(), 16usize);
        let c = Calibration { link_gbps: 37.5, compute_gflops: 91.25, tiles };
        c.save(&path).unwrap();
        let back = Calibration::load(&path).expect("artifact should load");
        assert_eq!(back, c);
        fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_and_corrupt_artifacts_fall_back_to_none() {
        assert!(Calibration::load("/nonexistent/dir/calib.json").is_none());
        let path = tmp_path("corrupt");
        fs::write(&path, "{ not json").unwrap();
        assert!(Calibration::load(&path).is_none(), "bad JSON must be None");
        fs::write(&path, "{\"link_gbps\": 10.0}").unwrap();
        assert!(Calibration::load(&path).is_none(), "missing keys must be None");
        fs::write(&path, "{\"link_gbps\": -1.0, \"compute_gflops\": 5.0}")
            .unwrap();
        assert!(Calibration::load(&path).is_none(),
                "non-positive rates must be None");
        fs::write(
            &path,
            "{\"link_gbps\": 1.0, \"compute_gflops\": 5.0, \
             \"tiles\": {\"b\": 0}}",
        )
        .unwrap();
        assert!(Calibration::load(&path).is_none(), "zero tile must be None");
        fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_artifacts_fall_back_to_none() {
        // a valid artifact cut off at every possible byte boundary must
        // load as None (cold start), never error or half-load
        let path = tmp_path("truncated");
        let mut tiles = BTreeMap::new();
        tiles.insert("tile:d32:h64:r256:swiglu".to_string(), 32usize);
        let c = Calibration { link_gbps: 37.5, compute_gflops: 91.25, tiles };
        c.save(&path).unwrap();
        let full = fs::read_to_string(&path).unwrap();
        assert!(Calibration::load(&path).is_some(), "untruncated loads");
        for cut in 1..full.len() {
            fs::write(&path, &full[..cut]).unwrap();
            assert!(Calibration::load(&path).is_none(),
                    "truncation at byte {cut} must fall back to None, \
                     got Some from {:?}", &full[..cut]);
        }
        fs::remove_file(&path).ok();
    }

    #[test]
    fn save_is_atomic_no_tmp_left_behind() {
        let path = tmp_path("atomic");
        let c = Calibration {
            link_gbps: 1.0,
            compute_gflops: 2.0,
            tiles: BTreeMap::new(),
        };
        c.save(&path).unwrap();
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        assert!(Calibration::load(&path).is_some());
        fs::remove_file(&path).ok();
    }
}

//! Multi-layer MoE stack: L expert layers chained through the existing
//! [`ExecutionEngine`] implementations.
//!
//! [`MoeStack`] owns one engine per layer — each with its own
//! router/gates draw, its own [`ExpertStore`] segment, and therefore its
//! own dispatch plan — and is itself an [`ExecutionEngine`], so
//! `EpTrainer`, `ep-bench`, and the optimizers drive an L-layer model
//! through the unchanged step-session API:
//!
//! * **forward** runs the layers bottom-up: layer 0 consumes the
//!   caller's [`StepBatch`] as-is; each deeper layer's input is the
//!   previous layer's combined output, bound zero-copy to that layer's
//!   fixed routing via [`LayerRouting::bind`] (the derived batch reuses
//!   the parent's id + the layer tag, so engine plan caches — keyed
//!   `(batch id, layer)` — stay warm while `x` changes every step). The
//!   per-layer [`StepHandle`]s are retained in a `LayerSession`.
//! * **backward** walks the layers in reverse, chaining
//!   [`ExecutionEngine::backward_into_dx`]: layer l's ∂x is layer l−1's
//!   ∂out. Gradients land in one layer-major [`ExpertGrads`] (layer l's
//!   expert e at global id `l·E + e`), each segment extended in the
//!   engines' usual expert-segment order — so grad-accum microbatching
//!   stays bit-identical through the stack.
//!
//! Bit-identity contract (pinned by `rust/tests/ep_stack.rs` and the
//! `tools/ep_sim.py` stack mirror): an L-layer stack reproduces L
//! manually-chained single-layer sessions exactly, for every rank count
//! R, pipeline chunking K, and per-layer policy vector; and an L = 1
//! stack with a uniform policy reproduces today's
//! `ShardedEngine`/`PipelinedEngine` outputs, gradients, and loss
//! curves bit-for-bit.
//!
//! Per-layer checkpoint policies are where the paper's "smart
//! activation checkpoint" plugs in: [`stack_from_config`] asks
//! `memory::planner::CheckpointPlanner` for a per-layer policy vector
//! when `[ep] checkpoint = "auto"`, budgeted by `mem_budget_bytes`
//! (see [`plan_from_config`]).

use crate::config::ep::EpConfig;
use crate::dispatch::parallel_build::parallel_build;
use crate::dispatch::structures::DispatchStructures;
use crate::memory::model::{CheckpointPolicy, MemoryBreakdown};
use crate::memory::planner::{CheckpointPlan, CheckpointPlanner, LayerModel};
use crate::trace::load::ExpertLoadTracker;
use crate::trace::Tracer;
use crate::util::prng::Rng;

use super::engine::{config_gating, layer_engine_from_config, lru_get_or_insert,
                    next_engine_tag, topology_from_config, ExecutionEngine,
                    LayerRouting, StepBatch, StepHandle, Traffic, PLAN_CACHE_CAP};
use super::params::{ExpertGrads, ExpertStore};
use super::pipeline::timeline::{CostModel, OverlapReport};

/// Per-layer salt mixed into seeds and gating draws. Zero for layer 0,
/// so an L = 1 stack sees exactly the config workload's own draws —
/// the foundation of the L = 1 equivalence guarantee.
fn layer_salt(layer: usize) -> u64 {
    (layer as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Layer `layer`'s fixed routing draw for a config: the engines' one
/// shared `config_gating` definition under a layer-salted seed. Layer
/// 0's salt is zero, so it is *exactly* the config workload's own
/// gating (same rng, same draw — an L = 1 stack reproduces today's
/// engines bit-for-bit); deeper layers re-draw over the same shape and
/// skew.
pub fn layer_gating_from_config(cfg: &EpConfig, layer: usize) -> (Vec<u32>, Vec<f32>) {
    let mut rng = Rng::new(cfg.seed ^ 0xE9E9 ^ layer_salt(layer));
    let (disp, gates) = config_gating(cfg, &mut rng);
    (disp.token_expert_indices, gates)
}

/// Layer `layer`'s dispatch structures for a config (the routing half
/// of [`layer_gating_from_config`], built once for planner models and
/// tests).
pub fn layer_routing_from_config(cfg: &EpConfig, layer: usize) -> DispatchStructures {
    let mut rng = Rng::new(cfg.seed ^ 0xE9E9 ^ layer_salt(layer));
    config_gating(cfg, &mut rng).0
}

/// The full-workload routing draw one stack layer carries (layers ≥ 1;
/// layer 0 consumes the caller's batch).
struct LayerDraw {
    topk_ids: Vec<u32>,
    gates: Vec<f32>,
}

struct StackLayer {
    engine: Box<dyn ExecutionEngine>,
    /// `None` for layer 0 — it consumes the caller's batch routing
    draw: Option<LayerDraw>,
}

/// One open multi-layer step session — the `LayerSession` extension of
/// [`StepHandle`]: the per-layer handles, layer-ascending, consumed by
/// the stack's reverse walk.
struct LayerSession {
    id: u64,
    handles: Vec<StepHandle>,
}

/// L chained expert layers behind one [`ExecutionEngine`] face. See the
/// module docs for the forward/backward contract.
pub struct MoeStack {
    layers: Vec<StackLayer>,
    /// whether the experts are gated (SwiGLU) — every layer must agree
    gated: bool,
    /// token count the per-layer routing draws cover (0 until a second
    /// layer is pushed; an L = 1 stack accepts any batch)
    tokens: usize,
    top_k: usize,
    num_experts: usize,
    d_model: usize,
    d_hidden: usize,
    engine_tag: u64,
    sessions_opened: u64,
    session: Option<LayerSession>,
    /// derived per-batch layer routings (layers 1..L, sliced to the
    /// batch's token span), LRU by batch id — microbatches re-derive
    /// nothing across steps
    routings: Vec<(u64, Vec<LayerRouting>)>,
    cache_cap: usize,
    /// attached observability handle — each layer engine gets a
    /// layer-tagged clone (see [`Tracer::for_layer`])
    tracer: Option<Tracer>,
    /// attached expert-load tracker — each layer engine gets a
    /// layer-tagged clone (see [`ExpertLoadTracker::for_layer`])
    load: Option<ExpertLoadTracker>,
}

impl MoeStack {
    /// Start a stack with its first (bottom) layer, which consumes the
    /// caller's batch routing directly. An L = 1 stack is a transparent
    /// wrapper: forward/backward delegate to the engine on the caller's
    /// batch unchanged.
    pub fn new(first: Box<dyn ExecutionEngine>) -> MoeStack {
        let g = first.zero_grads();
        MoeStack {
            num_experts: g.num_experts(),
            d_model: g.d_model,
            d_hidden: g.d_hidden,
            gated: g.experts.first().map_or(false, |p| p.gated()),
            layers: vec![StackLayer { engine: first, draw: None }],
            tokens: 0,
            top_k: 0,
            engine_tag: next_engine_tag(),
            sessions_opened: 0,
            session: None,
            routings: Vec::new(),
            cache_cap: PLAN_CACHE_CAP,
            tracer: None,
            load: None,
        }
    }

    /// Append a layer with its own full-workload routing draw
    /// (`topk_ids`/`gates`, token-major, `tokens · top_k` entries).
    /// Every layer must agree on expert count, dimensions, rank count,
    /// and — beyond the first pushed draw — the workload shape.
    pub fn push_layer(&mut self, engine: Box<dyn ExecutionEngine>, tokens: usize,
                      top_k: usize, topk_ids: Vec<u32>,
                      gates: Vec<f32>) -> Result<(), String> {
        let g = engine.zero_grads();
        if g.num_experts() != self.num_experts
            || g.d_model != self.d_model
            || g.d_hidden != self.d_hidden
        {
            return Err(format!(
                "layer {} shape (E={}, d={}, h={}) != stack (E={}, d={}, h={})",
                self.layers.len(),
                g.num_experts(),
                g.d_model,
                g.d_hidden,
                self.num_experts,
                self.d_model,
                self.d_hidden
            ));
        }
        if g.experts.first().map_or(false, |p| p.gated()) != self.gated {
            return Err(format!(
                "layer {} activation gating disagrees with the stack's",
                self.layers.len()
            ));
        }
        if engine.ranks() != self.layers[0].engine.ranks() {
            return Err(format!(
                "layer {} runs {} ranks, stack runs {}",
                self.layers.len(),
                engine.ranks(),
                self.layers[0].engine.ranks()
            ));
        }
        if tokens == 0 || topk_ids.len() != tokens * top_k
            || gates.len() != tokens * top_k
        {
            return Err(format!(
                "layer draw has {} ids / {} gates, expected tokens·k = {}",
                topk_ids.len(),
                gates.len(),
                tokens * top_k
            ));
        }
        if self.layers.len() > 1 && (tokens != self.tokens || top_k != self.top_k) {
            return Err("layer draws disagree on the workload shape".into());
        }
        self.tokens = tokens;
        self.top_k = top_k;
        self.routings.clear();
        let mut engine = engine;
        if let Some(tr) = &self.tracer {
            engine.set_tracer(tr.for_layer(self.layers.len()));
        }
        if let Some(lt) = &self.load {
            engine.set_load_tracker(lt.for_layer(self.layers.len()));
        }
        self.layers.push(StackLayer {
            engine,
            draw: Some(LayerDraw { topk_ids, gates }),
        });
        Ok(())
    }

    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Per-layer checkpoint policies, layer-ascending (the trait-level
    /// `policy()` reports only the bottom layer's).
    pub fn layer_policies(&self) -> Vec<CheckpointPolicy> {
        self.layers.iter().map(|l| l.engine.policy()).collect()
    }

    /// Per-layer per-rank memory of the last forward (the summed view is
    /// `memory_per_rank`).
    pub fn layer_memory(&self) -> Vec<Vec<MemoryBreakdown>> {
        self.layers.iter().map(|l| l.engine.memory_per_rank()).collect()
    }

    /// Bound of the stack's derived-routing cache (the layer engines'
    /// plan caches are sized at construction); grad-accum callers need
    /// at least their microbatch count, as with the engines.
    pub fn set_plan_cache_cap(&mut self, cap: usize) {
        self.cache_cap = cap.max(1);
        while self.routings.len() > self.cache_cap {
            self.routings.remove(0);
        }
    }

    /// Index into `routings` of this batch's per-layer routing slices,
    /// deriving them on first sight: each deeper layer's full-workload
    /// draw is cut to the batch's token span (`token_offset`), so
    /// grad-accum microbatches route exactly as their slice of the
    /// full batch — the contiguous-split argument that keeps stacked
    /// grad-accum bit-identical.
    fn routing_index(&mut self, batch: &StepBatch) -> Result<usize, String> {
        let nl = self.layers.len();
        let lm = batch.num_tokens();
        let off = batch.token_offset();
        if off + lm > self.tokens {
            return Err(format!(
                "batch spans tokens {off}..{} beyond the stack's {}-token routing",
                off + lm,
                self.tokens
            ));
        }
        let (e, k) = (self.num_experts, self.top_k);
        let layers = &self.layers;
        lru_get_or_insert(&mut self.routings, self.cache_cap, batch.id(), || {
            (1..nl)
                .map(|l| {
                    let draw = layers[l]
                        .draw
                        .as_ref()
                        .expect("layers above 0 always carry a draw");
                    let ids = &draw.topk_ids[off * k..(off + lm) * k];
                    let disp = parallel_build(ids, lm, e, k);
                    LayerRouting::new(l as u32, disp,
                                      draw.gates[off * k..(off + lm) * k].to_vec())
                })
                .collect()
        })
    }

    fn check_session(&self, handle: &StepHandle) -> Result<(), String> {
        if handle.engine_tag != self.engine_tag {
            return Err("step handle belongs to a different engine".into());
        }
        match &self.session {
            None => Err("no open step session (forward not called)".into()),
            Some(s) if s.id != handle.session => Err(format!(
                "stale step handle: session {} superseded by {}",
                handle.session, s.id
            )),
            Some(_) => Ok(()),
        }
    }

    /// The reverse walk: pop layer handles top-down, chain ∂x, extend
    /// each layer's grad segment in place.
    fn backward_impl(&mut self, handle: StepHandle, d_out: &[f32],
                     grads: &mut ExpertGrads,
                     d_x: Option<&mut [f32]>) -> Result<(), String> {
        self.check_session(&handle)?;
        let nl = self.layers.len();
        grads
            .check_like(nl * self.num_experts, self.d_model, self.d_hidden)
            .map_err(|e| e.to_string())?;
        // validate the ∂x shape *before* any layer mutates `grads` or a
        // session is consumed — the same error-before-mutation contract
        // the engines keep
        if let Some(dx) = &d_x {
            if dx.len() != d_out.len() {
                return Err(format!(
                    "d_x has {} elements, expected L·d = {}",
                    dx.len(),
                    d_out.len()
                ));
            }
        }
        let st = self.session.take().unwrap();
        let lm = d_out.len() / self.d_model.max(1);
        let mut handles = st.handles;
        let mut d_cur: Vec<f32> = d_out.to_vec();
        for l in (0..nl).rev() {
            let h = handles.pop().expect("one handle per layer");
            let mut seg = grads.take_layer(l, self.num_experts);
            let result = if l > 0 || d_x.is_some() {
                let mut d_prev = vec![0.0f32; lm * self.d_model];
                let r = self.layers[l]
                    .engine
                    .backward_into_dx(h, &d_cur, &mut seg, &mut d_prev);
                d_cur = d_prev;
                r
            } else {
                self.layers[l].engine.backward_into(h, &d_cur, &mut seg)
            };
            grads.restore_layer(l, seg);
            result?;
        }
        if let Some(dx) = d_x {
            for (o, v) in dx.iter_mut().zip(&d_cur) {
                *o += v;
            }
        }
        Ok(())
    }
}

impl ExecutionEngine for MoeStack {
    fn name(&self) -> String {
        format!("stack-l{}-{}", self.layers.len(), self.layers[0].engine.name())
    }

    fn ranks(&self) -> usize {
        self.layers[0].engine.ranks()
    }

    /// The bottom layer's policy (layers may differ under a planner
    /// assignment — see [`MoeStack::layer_policies`]).
    fn policy(&self) -> CheckpointPolicy {
        self.layers[0].engine.policy()
    }

    fn forward(&mut self, batch: &StepBatch) -> Result<StepHandle, String> {
        let nl = self.layers.len();
        if batch.d_model() != self.d_model {
            return Err(format!(
                "batch has d_model {}, stack expects {}",
                batch.d_model(),
                self.d_model
            ));
        }
        let routing_idx = if nl > 1 { Some(self.routing_index(batch)?) } else { None };
        let mut handles = Vec::with_capacity(nl);
        handles.push(self.layers[0].engine.forward(batch)?);
        for l in 1..nl {
            let x = handles[l - 1].output().to_vec();
            let routing = &self.routings[routing_idx.unwrap()].1[l - 1];
            let bound = routing.bind(batch, x)?;
            let h = self.layers[l].engine.forward(&bound)?;
            handles.push(h);
        }
        let out = handles[nl - 1].output().to_vec();
        self.sessions_opened += 1;
        let session = self.sessions_opened;
        self.session = Some(LayerSession { id: session, handles });
        Ok(StepHandle { engine_tag: self.engine_tag, session, out })
    }

    fn backward_into(&mut self, handle: StepHandle, d_out: &[f32],
                     grads: &mut ExpertGrads) -> Result<(), String> {
        self.backward_impl(handle, d_out, grads, None)
    }

    fn backward_into_dx(&mut self, handle: StepHandle, d_out: &[f32],
                        grads: &mut ExpertGrads, d_x: &mut [f32]) -> Result<(), String> {
        self.backward_impl(handle, d_out, grads, Some(d_x))
    }

    fn zero_grads(&self) -> ExpertGrads {
        ExpertGrads::zeros_gated(self.layers.len() * self.num_experts,
                                 self.d_model, self.d_hidden, self.gated)
    }

    fn apply_update(&mut self, delta: &ExpertGrads) -> Result<(), String> {
        delta
            .check_like(self.layers.len() * self.num_experts, self.d_model,
                        self.d_hidden)
            .map_err(|e| e.to_string())?;
        let per_layer = self.num_experts;
        for (l, layer) in self.layers.iter_mut().enumerate() {
            layer.engine.apply_update(&delta.layer_slice(l, per_layer))?;
        }
        Ok(())
    }

    /// Element-wise sum across layers. Each layer's counters reset at
    /// its forward and accumulate through its backward, and the stack
    /// runs every layer exactly once per session — so the sum describes
    /// one whole stack step.
    fn traffic(&self) -> Traffic {
        let mut total = Traffic::default();
        for layer in &self.layers {
            let t = layer.engine.traffic();
            total.dispatch_bytes += t.dispatch_bytes;
            total.combine_bytes += t.combine_bytes;
            total.grad_bytes += t.grad_bytes;
            total.recompute_bytes += t.recompute_bytes;
            total.cross_rows += t.cross_rows;
            total.local_rows += t.local_rows;
        }
        total
    }

    /// Per-rank sums across layers — the stacked-residency view the
    /// planner budgets: every layer's saved tensors are live at the
    /// fwd→bwd boundary simultaneously.
    fn memory_per_rank(&self) -> Vec<MemoryBreakdown> {
        let r = self.ranks();
        let mut out = vec![
            MemoryBreakdown { data_bytes: 0, index_bytes: 0, extra_bytes: 0 };
            r
        ];
        for layer in &self.layers {
            for (acc, m) in out.iter_mut().zip(layer.engine.memory_per_rank()) {
                acc.data_bytes += m.data_bytes;
                acc.index_bytes += m.index_bytes;
                acc.extra_bytes += m.extra_bytes;
            }
        }
        out
    }

    fn gather_params(&self) -> Result<ExpertStore, String> {
        let stores = self
            .layers
            .iter()
            .map(|l| l.engine.gather_params())
            .collect::<Result<Vec<_>, String>>()?;
        ExpertStore::concat(&stores)
    }

    /// Layer-major inverse of `gather_params`: segment l of
    /// `num_experts` experts restores into layer l's engine. All-or-
    /// nothing at the shape level — every segment is shape-checked by
    /// the layer engine before any parameter moves, because the layers
    /// share one store clone whose per-expert tensors were already
    /// validated identically.
    fn load_params(&mut self, store: &ExpertStore) -> Result<(), String> {
        let per = self.num_experts;
        if store.experts.len() != self.layers.len() * per {
            return Err(format!(
                "snapshot store has {} experts, stack holds {} layers x {}",
                store.experts.len(),
                self.layers.len(),
                per
            ));
        }
        for (l, layer) in self.layers.iter_mut().enumerate() {
            let sub = ExpertStore {
                d_model: store.d_model,
                d_hidden: store.d_hidden,
                experts: store.experts[l * per..(l + 1) * per].to_vec(),
            };
            layer.engine.load_params(&sub)?;
        }
        self.session = None;
        Ok(())
    }

    /// The final layer's timeline (chunk-pipelined layer engines only).
    fn overlap_report(&self) -> Option<OverlapReport> {
        self.layers.last().and_then(|l| l.engine.overlap_report())
    }

    /// Σ measured wall-clock over every layer's session — `None` unless
    /// every layer carries a timeline, so a stacked step is never
    /// undercounted by reporting one layer's time as the whole step's.
    fn measured_step_s(&self) -> Option<f64> {
        let mut total = 0.0;
        for layer in &self.layers {
            total += layer.engine.measured_step_s()?;
        }
        Some(total)
    }

    /// Hand every layer engine a layer-tagged clone of the shared
    /// tracer, so stacked spans carry their layer id; layers pushed
    /// later inherit it too.
    fn set_tracer(&mut self, tracer: Tracer) {
        for (l, layer) in self.layers.iter_mut().enumerate() {
            layer.engine.set_tracer(tracer.for_layer(l));
        }
        self.tracer = Some(tracer);
    }

    /// Hand every layer engine a layer-tagged clone of the shared load
    /// tracker, so each layer's routed-row EWMAs and skew alarms carry
    /// their layer id; layers pushed later inherit it too.
    fn set_load_tracker(&mut self, tracker: ExpertLoadTracker) {
        for (l, layer) in self.layers.iter_mut().enumerate() {
            layer.engine.set_load_tracker(tracker.for_layer(l));
        }
        self.load = Some(tracker);
    }

    /// Recalibrate every layer engine's cost model from its own
    /// measured-vs-simulated phases; returns the deepest pipelined
    /// layer's updated model (`None` when no layer carries a timeline).
    fn recalibrate_cost_model(&mut self, alpha: f64) -> Option<CostModel> {
        let mut last = None;
        for layer in &mut self.layers {
            if let Some(cm) = layer.engine.recalibrate_cost_model(alpha) {
                last = Some(cm);
            }
        }
        last
    }
}

// -- config-driven construction ---------------------------------------------

/// The smart-checkpoint plan for a config, or `None` when neither
/// multi-layer nor `checkpoint = "auto"` asks for one: per-layer
/// [`LayerModel`]s from each layer's routing under the config topology,
/// solved against `[ep] mem_budget_bytes` on the config's cost model.
/// Fixed-policy multi-layer configs get a `fixed` plan (projections
/// only) so `ep-bench`/`ep-train` can still explain the memory story.
pub fn plan_from_config(cfg: &EpConfig) -> Result<Option<CheckpointPlan>, String> {
    if cfg.num_layers <= 1 && !cfg.checkpoint_auto {
        return Ok(None);
    }
    let topo = topology_from_config(cfg, cfg.ranks)?;
    let cost = CostModel::new(cfg.link_gbps, cfg.compute_gflops)?;
    let models: Vec<LayerModel> = (0..cfg.num_layers)
        .map(|l| {
            let disp = layer_routing_from_config(cfg, l);
            LayerModel::from_routing(l, &disp, &topo, cfg.d_model, cfg.d_hidden,
                                     cfg.activation.gated())
        })
        .collect();
    let planner = CheckpointPlanner::new(cost);
    let plan = if cfg.checkpoint_auto {
        planner.plan(&models, cfg.mem_budget_bytes)
    } else {
        planner.fixed(&models, cfg.checkpoint)
    };
    Ok(Some(plan))
}

/// The per-layer policy vector a config resolves to: the planner's
/// choice under `checkpoint = "auto"`, else the config's uniform
/// policy.
pub fn stack_policies_from_config(cfg: &EpConfig) -> Result<Vec<CheckpointPolicy>, String> {
    if cfg.checkpoint_auto {
        let plan = plan_from_config(cfg)?.expect("auto always plans");
        Ok(plan.policies())
    } else {
        Ok(vec![cfg.checkpoint; cfg.num_layers])
    }
}

/// Build the multi-layer stack an `[ep]` config describes: one engine
/// per layer — the same engine type `engine_from_config` would build,
/// each owning its own per-layer-seeded [`ExpertStore`] segment — and
/// per-layer routing draws. Layer 0's seed and routing are exactly the
/// config's own, so `num_layers = 1` with a fixed policy reproduces
/// today's single engines bit-for-bit (wrapped one deep). `LoadAware`
/// placement derives every layer's topology from the config workload's
/// routing, as `engine_from_config` does. Solves the checkpoint plan
/// itself under `checkpoint = "auto"`; callers already holding the plan
/// should use [`stack_with_plan`] instead of re-solving it.
pub fn stack_from_config(cfg: &EpConfig) -> Result<MoeStack, String> {
    let plan = if cfg.checkpoint_auto { plan_from_config(cfg)? } else { None };
    stack_with_plan(cfg, plan.as_ref())
}

/// [`stack_from_config`] with a pre-solved [`CheckpointPlan`]: the
/// plan's per-layer policies are used under `checkpoint = "auto"`
/// (`None`, or a non-auto config, falls back to the uniform policy), so
/// `ep-bench` and the planner bench — which render the plan anyway —
/// build their stacks without running the solver again.
pub fn stack_with_plan(cfg: &EpConfig,
                       plan: Option<&CheckpointPlan>) -> Result<MoeStack, String> {
    cfg.validate()?;
    let policies = match plan {
        Some(p) if cfg.checkpoint_auto => {
            let pols = p.policies();
            if pols.len() != cfg.num_layers {
                return Err(format!(
                    "plan covers {} layers, config stacks {}",
                    pols.len(),
                    cfg.num_layers
                ));
            }
            pols
        }
        _ => vec![cfg.checkpoint; cfg.num_layers],
    };
    let cache_cap = PLAN_CACHE_CAP.max(cfg.grad_accum);
    let mut stack: Option<MoeStack> = None;
    for l in 0..cfg.num_layers {
        let store = ExpertStore::init_gated(cfg.num_experts, cfg.d_model,
                                            cfg.d_hidden,
                                            cfg.seed ^ layer_salt(l),
                                            cfg.activation.gated());
        let engine = layer_engine_from_config(cfg, store, policies[l])?;
        match &mut stack {
            None => {
                let mut s = MoeStack::new(engine);
                s.set_plan_cache_cap(cache_cap);
                stack = Some(s);
            }
            Some(s) => {
                let (ids, gates) = layer_gating_from_config(cfg, l);
                s.push_layer(engine, cfg.tokens, cfg.top_k, ids, gates)?;
            }
        }
    }
    Ok(stack.expect("num_layers >= 1 is validated"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{engine_from_config, step_batch_from_config};
    use crate::memory::model::CheckpointPolicy;

    fn tiny_cfg(layers: usize, ranks: usize) -> EpConfig {
        EpConfig {
            num_layers: layers,
            ranks,
            tokens: 24,
            num_experts: 4,
            top_k: 2,
            d_model: 6,
            d_hidden: 10,
            steps: 3,
            seed: 11,
            ..EpConfig::default()
        }
    }

    #[test]
    fn single_layer_stack_matches_plain_engine_bitwise() {
        let cfg = tiny_cfg(1, 2);
        let (batch, _) = step_batch_from_config(&cfg).unwrap();
        let mut plain = engine_from_config(&cfg).unwrap();
        let mut stack = stack_from_config(&cfg).unwrap();
        assert_eq!(stack.num_layers(), 1);
        let a = plain.forward(&batch).unwrap();
        let b = stack.forward(&batch).unwrap();
        assert_eq!(a.output(), b.output());
        let d_out = vec![0.1f32; batch.num_tokens() * 6];
        let ga = a.backward(plain.as_mut(), &d_out).unwrap();
        let mut gb = stack.zero_grads();
        b.backward_into(&mut stack, &d_out, &mut gb).unwrap();
        assert_eq!(ga, gb);
        assert_eq!(stack.gather_params().unwrap(), plain.gather_params().unwrap());
        assert_eq!(batch.copy_count(), 0);
    }

    #[test]
    fn stack_equals_manually_chained_layers() {
        let cfg = tiny_cfg(3, 2);
        let (batch, _) = step_batch_from_config(&cfg).unwrap();
        let d = cfg.d_model;
        let mut stack = stack_from_config(&cfg).unwrap();

        // the reference: three independent single-layer engines chained
        // by hand through fresh StepBatches and backward_into_dx
        let mut engines: Vec<Box<dyn ExecutionEngine>> = (0..3)
            .map(|l| {
                let store = ExpertStore::init(cfg.num_experts, d, cfg.d_hidden,
                                              cfg.seed ^ layer_salt(l));
                layer_engine_from_config(&cfg, store, cfg.checkpoint).unwrap()
            })
            .collect();
        let mut xs = vec![batch.x().to_vec()];
        let mut handles = Vec::new();
        for (l, eng) in engines.iter_mut().enumerate() {
            let b = if l == 0 {
                batch.share()
            } else {
                let (ids, gates) = layer_gating_from_config(&cfg, l);
                let disp = parallel_build(&ids, cfg.tokens, cfg.num_experts,
                                          cfg.top_k);
                StepBatch::new(disp, xs[l].clone(), gates).unwrap()
            };
            let h = eng.forward(&b).unwrap();
            xs.push(h.output().to_vec());
            handles.push(h);
        }
        let ref_out = xs.last().unwrap().clone();
        let d_out = vec![0.05f32; cfg.tokens * d];
        let mut ref_grads: Vec<ExpertGrads> = Vec::new();
        let mut d_cur = d_out.clone();
        for (l, (eng, h)) in engines
            .iter_mut()
            .zip(handles.into_iter())
            .enumerate()
            .collect::<Vec<_>>()
            .into_iter()
            .rev()
        {
            let mut g = eng.zero_grads();
            if l > 0 {
                let mut d_prev = vec![0.0f32; cfg.tokens * d];
                eng.backward_into_dx(h, &d_cur, &mut g, &mut d_prev).unwrap();
                d_cur = d_prev;
            } else {
                eng.backward_into(h, &d_cur, &mut g).unwrap();
            }
            ref_grads.push(g);
        }
        ref_grads.reverse();

        // the stack must reproduce all of it bit-for-bit
        let h = stack.forward(&batch).unwrap();
        assert_eq!(h.output(), &ref_out[..], "stacked forward diverged");
        let mut grads = stack.zero_grads();
        h.backward_into(&mut stack, &d_out, &mut grads).unwrap();
        for l in 0..3 {
            assert_eq!(grads.layer_slice(l, cfg.num_experts), ref_grads[l],
                       "layer {l} grads diverged");
        }
    }

    #[test]
    fn stack_session_handles_are_guarded() {
        let cfg = tiny_cfg(2, 1);
        let (batch, _) = step_batch_from_config(&cfg).unwrap();
        let mut stack = stack_from_config(&cfg).unwrap();
        let d_out = vec![0.1f32; batch.num_tokens() * cfg.d_model];
        let mut grads = stack.zero_grads();
        let stale = stack.forward(&batch).unwrap();
        let fresh = stack.forward(&batch).unwrap();
        assert!(stack.backward_into(stale, &d_out, &mut grads).is_err());
        stack.backward_into(fresh, &d_out, &mut grads).unwrap();
        // wrong-shape accumulators are rejected before any layer runs
        let fresh = stack.forward(&batch).unwrap();
        let mut wrong = ExpertGrads::zeros(cfg.num_experts, cfg.d_model,
                                           cfg.d_hidden);
        assert!(stack.backward_into(fresh, &d_out, &mut wrong).is_err());
    }

    #[test]
    fn stack_validates_layer_shapes() {
        let cfg = tiny_cfg(1, 2);
        let store = ExpertStore::init(cfg.num_experts, cfg.d_model, cfg.d_hidden, 1);
        let engine = layer_engine_from_config(&cfg, store, cfg.checkpoint).unwrap();
        let mut stack = MoeStack::new(engine);
        // mismatched expert count
        let bad_cfg = EpConfig { num_experts: 8, ranks: 2, ..tiny_cfg(1, 2) };
        let bad_store = ExpertStore::init(8, cfg.d_model, cfg.d_hidden, 1);
        let bad = layer_engine_from_config(&bad_cfg, bad_store, cfg.checkpoint)
            .unwrap();
        let (ids, gates) = layer_gating_from_config(&cfg, 1);
        assert!(stack
            .push_layer(bad, cfg.tokens, cfg.top_k, ids.clone(), gates.clone())
            .is_err());
        // ragged draw
        let store = ExpertStore::init(cfg.num_experts, cfg.d_model, cfg.d_hidden, 2);
        let eng = layer_engine_from_config(&cfg, store, cfg.checkpoint).unwrap();
        assert!(stack
            .push_layer(eng, cfg.tokens, cfg.top_k, ids[..4].to_vec(), gates)
            .is_err());
    }

    #[test]
    fn auto_policies_fall_back_to_uniform_without_auto() {
        let cfg = tiny_cfg(3, 2);
        let pols = stack_policies_from_config(&cfg).unwrap();
        assert_eq!(pols, vec![CheckpointPolicy::SaveInputs; 3]);
        assert!(plan_from_config(&tiny_cfg(1, 2)).unwrap().is_none());
        let plan = plan_from_config(&cfg).unwrap().unwrap();
        assert_eq!(plan.strategy, "fixed");
        assert_eq!(plan.choices.len(), 3);
    }

    #[test]
    fn auto_plan_respects_budget_in_the_stack() {
        let base = EpConfig { checkpoint_auto: true, ..tiny_cfg(3, 2) };
        let hi = plan_from_config(&EpConfig { mem_budget_bytes: 0, ..base.clone() })
            .unwrap()
            .unwrap()
            .save_all_peak_bytes;
        let floor = plan_from_config(&base)
            .unwrap()
            .unwrap()
            .floor_peak_bytes;
        let budget = (hi + floor) / 2;
        let cfg = EpConfig { mem_budget_bytes: budget, ..base };
        let plan = plan_from_config(&cfg).unwrap().unwrap();
        assert!(plan.feasible);
        let pols = plan.policies();
        assert!(pols.iter().any(|&p| p != CheckpointPolicy::SaveAll));
        let mut stack = stack_from_config(&cfg).unwrap();
        assert_eq!(stack.layer_policies(), pols);
        let (batch, _) = step_batch_from_config(&cfg).unwrap();
        let _ = stack.forward(&batch).unwrap();
        let measured_peak = stack
            .memory_per_rank()
            .iter()
            .map(|m| m.data_bytes)
            .max()
            .unwrap();
        assert!(measured_peak <= budget,
                "measured per-rank peak {measured_peak} over budget {budget}");
    }
}

//! Deterministic phase-timeline cost model for the chunked pipeline.
//!
//! The pipelined engine *executes* the overlap for real (threads); this
//! module *prices* it on a simulated clock so overlap quality is a
//! reproducible number rather than a wall-clock artifact of the host.
//!
//! # Model assumptions
//!
//! * Every rank has two lanes: a **comm lane** (dispatch exchange and
//!   combine scatter buffers move at `link_gbps` decimal GB/s) and a
//!   **compute lane** (expert FLOPs retire at `compute_gflops` GFLOP/s).
//!   A lane executes one span at a time — the contention-consistency
//!   invariant the property suite pins.
//! * A chunk's exchange is an all-to-all barrier: expert compute for
//!   chunk *m* starts only after every rank's chunk-*m* buffers landed.
//!   Its combine starts only after every rank finished chunk-*m* compute.
//! * Pipelining is depth-2 (what the engine actually runs): chunk
//!   *m+1*'s exchange may begin when chunk *m*'s compute begins, not
//!   earlier — one chunk of exchange buffers is in flight at a time.
//! * FLOP counts are the per-row GEMV costs of the expert FFN
//!   ([`fwd_flops_per_row`] / [`bwd_flops_per_row`]); bias adds and the
//!   SiLU are ignored as lower-order terms.
//! * Zero-byte / zero-FLOP phases take zero time and record no span.
//!
//! All inputs are integers or config constants, so the timeline — and
//! every number in [`OverlapReport`] — is bit-reproducible.

use crate::util::json::Json;

/// Simulated hardware rates for the timeline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// cross-rank link bandwidth, decimal GB/s
    pub link_gbps: f64,
    /// per-rank expert-compute rate, GFLOP/s
    pub compute_gflops: f64,
}

impl CostModel {
    pub fn new(link_gbps: f64, compute_gflops: f64) -> Result<CostModel, String> {
        if !(link_gbps > 0.0 && link_gbps.is_finite()) {
            return Err(format!("link_gbps must be positive, got {link_gbps}"));
        }
        if !(compute_gflops > 0.0 && compute_gflops.is_finite()) {
            return Err(format!("compute_gflops must be positive, got {compute_gflops}"));
        }
        Ok(CostModel { link_gbps, compute_gflops })
    }

    /// Seconds to move `bytes` over the link.
    pub fn comm_seconds(&self, bytes: u64) -> f64 {
        bytes as f64 / (self.link_gbps * 1e9)
    }

    /// Seconds to retire `flops` on one rank.
    pub fn compute_seconds(&self, flops: u64) -> f64 {
        flops as f64 / (self.compute_gflops * 1e9)
    }
}

impl Default for CostModel {
    fn default() -> CostModel {
        CostModel { link_gbps: 50.0, compute_gflops: 200.0 }
    }
}

/// Forward FLOPs of one routed row through the expert FFN: two GEMVs
/// (W1·x and W2·act), 2·d·h MACs → FLOPs each; a gated (SwiGLU) expert
/// adds the third GEMV W3·x in the same pass.
pub fn fwd_flops_per_row(d: usize, h: usize, gated: bool) -> u64 {
    let gemv = 2 * d as u64 * h as u64;
    (2 + gated as u64) * gemv
}

/// Backward FLOPs of one routed row: the W2-grad/dz pass, the W1-grad
/// pass, and the dz projection (three GEMV-shaped sweeps — gated adds
/// the W3-grad/∂x sweep), plus the forward-shaped hidden recompute for
/// policies that did not save it.
pub fn bwd_flops_per_row(d: usize, h: usize, recompute_hidden: bool,
                         gated: bool) -> u64 {
    let gemv = 2 * d as u64 * h as u64;
    (3 + gated as u64) * gemv
        + if recompute_hidden { (2 + gated as u64) * gemv } else { 0 }
}

/// Which lane a phase occupies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// dispatch all-to-all (fwd: routed rows; bwd: gated gradient rows
    /// plus the `RecomputeAll` re-gather)
    Exchange,
    /// per-rank expert FFN work (fwd or bwd)
    Compute,
    /// expert outputs returning to their home ranks (fwd only)
    Combine,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Exchange => "exchange",
            Phase::Compute => "compute",
            Phase::Combine => "combine",
        }
    }

    /// `true` for the phases that occupy a rank's comm lane.
    pub fn is_comm(self) -> bool {
        self != Phase::Compute
    }
}

/// One simulated phase occupancy on one rank's lane.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseSpan {
    pub chunk: usize,
    pub rank: usize,
    pub phase: Phase,
    /// `true` for backward-pass spans (they share the same lanes)
    pub backward: bool,
    /// cross-rank bytes this span moves (0 for compute spans)
    pub bytes: u64,
    /// FLOPs this span retires (0 for comm spans)
    pub flops: u64,
    pub start_s: f64,
    pub end_s: f64,
}

/// Builds the per-rank lane schedule chunk by chunk as the engine runs.
#[derive(Debug, Clone)]
pub struct TimelineBuilder {
    ranks: usize,
    cost: CostModel,
    /// next-free time of each rank's comm lane
    comm_free: Vec<f64>,
    /// next-free time of each rank's compute lane
    comp_free: Vec<f64>,
    spans: Vec<PhaseSpan>,
    chunks: usize,
    /// no-overlap backbone: Σ per-phase max duration across ranks
    comm_backbone_s: f64,
    compute_backbone_s: f64,
    exchange_bytes: u64,
    combine_bytes: u64,
    backward_bytes: u64,
    flops: u64,
    /// measured host wall-clock per phase kind (both directions),
    /// recorded by the engine around the real work — the calibration
    /// counterpart of the simulated spans
    measured_s: [f64; 3],
}

impl TimelineBuilder {
    pub fn new(ranks: usize, cost: CostModel) -> TimelineBuilder {
        TimelineBuilder {
            ranks,
            cost,
            comm_free: vec![0.0; ranks],
            comp_free: vec![0.0; ranks],
            spans: Vec::new(),
            chunks: 0,
            comm_backbone_s: 0.0,
            compute_backbone_s: 0.0,
            exchange_bytes: 0,
            combine_bytes: 0,
            backward_bytes: 0,
            flops: 0,
            measured_s: [0.0; 3],
        }
    }

    /// Record measured wall-clock seconds of real `phase` work (the
    /// calibration hook: the pipelined engine times its pack / expert /
    /// combine sections around the actual threaded execution). Purely
    /// additive — the simulated clock never reads it.
    pub fn record_measured(&mut self, phase: Phase, seconds: f64) {
        self.measured_s[phase as usize] += seconds;
    }

    /// Current makespan (the latest busy-until time of any lane).
    pub fn now(&self) -> f64 {
        self.comm_free
            .iter()
            .chain(&self.comp_free)
            .fold(0.0f64, |a, &b| a.max(b))
    }

    fn queue(&mut self, chunk: usize, backward: bool, phase: Phase, rank: usize,
             bytes: u64, flops: u64, ready_s: f64) -> f64 {
        let dur = if phase.is_comm() {
            self.cost.comm_seconds(bytes)
        } else {
            self.cost.compute_seconds(flops)
        };
        let lane = if phase.is_comm() {
            &mut self.comm_free[rank]
        } else {
            &mut self.comp_free[rank]
        };
        let start = lane.max(ready_s);
        let end = start + dur;
        *lane = end;
        self.spans.push(PhaseSpan {
            chunk, rank, phase, backward, bytes, flops, start_s: start, end_s: end,
        });
        end
    }

    /// Queue one chunk's phase across ranks (`amounts[r]` = bytes for
    /// comm phases, FLOPs for compute). Ranks with zero work record no
    /// span. Returns `(first_start, barrier_end)`: the earliest span
    /// start (= `ready_s` when nobody participates) and the time every
    /// participating rank is done — the all-to-all / compute barrier the
    /// next phase depends on.
    pub fn phase(&mut self, chunk: usize, backward: bool, phase: Phase,
                 amounts: &[u64], ready_s: f64) -> (f64, f64) {
        assert_eq!(amounts.len(), self.ranks);
        self.chunks = self.chunks.max(chunk + 1);
        let mut first_start = f64::INFINITY;
        let mut barrier = ready_s;
        let mut max_dur = 0.0f64;
        for (rank, &amount) in amounts.iter().enumerate() {
            if amount == 0 {
                continue;
            }
            let (bytes, flops) = if phase.is_comm() { (amount, 0) } else { (0, amount) };
            let end = self.queue(chunk, backward, phase, rank, bytes, flops, ready_s);
            let span = self.spans.last().unwrap();
            first_start = first_start.min(span.start_s);
            barrier = barrier.max(end);
            max_dur = max_dur.max(end - span.start_s);
            if phase.is_comm() {
                if backward {
                    self.backward_bytes += bytes;
                } else if phase == Phase::Exchange {
                    self.exchange_bytes += bytes;
                } else {
                    self.combine_bytes += bytes;
                }
            } else {
                self.flops += flops;
            }
        }
        if phase.is_comm() {
            self.comm_backbone_s += max_dur;
        } else {
            self.compute_backbone_s += max_dur;
        }
        if first_start.is_infinite() {
            first_start = ready_s;
        }
        (first_start, barrier)
    }

    /// Snapshot the schedule into a report (callable after the forward
    /// pass and again after the backward extends the same lanes).
    pub fn report(&self) -> OverlapReport {
        OverlapReport {
            ranks: self.ranks,
            chunks: self.chunks,
            critical_path_s: self.now(),
            comm_s: self.comm_backbone_s,
            compute_s: self.compute_backbone_s,
            exchange_bytes: self.exchange_bytes,
            combine_bytes: self.combine_bytes,
            backward_bytes: self.backward_bytes,
            flops: self.flops,
            measured_s: self.measured_s,
            spans: self.spans.clone(),
        }
    }
}

/// Roll-up of one step session's simulated timeline: how long the
/// schedule took, how much of the communication was exposed (not hidden
/// behind compute), and how close the overlap came to ideal.
#[derive(Debug, Clone, PartialEq)]
pub struct OverlapReport {
    pub ranks: usize,
    pub chunks: usize,
    /// makespan of the simulated (overlapped) schedule
    pub critical_path_s: f64,
    /// communication backbone: Σ per-chunk max comm duration — what a
    /// barrier execution spends communicating
    pub comm_s: f64,
    /// compute backbone: Σ per-chunk max compute duration
    pub compute_s: f64,
    /// forward dispatch cross-rank bytes (Σ Exchange spans, fwd)
    pub exchange_bytes: u64,
    /// forward combine cross-rank bytes
    pub combine_bytes: u64,
    /// backward cross-rank bytes (gradient exchange + recompute re-gather)
    pub backward_bytes: u64,
    /// total expert FLOPs priced
    pub flops: u64,
    /// measured host wall-clock per phase kind (indexed by `Phase as
    /// usize`, both directions) — see [`TimelineBuilder::record_measured`]
    pub measured_s: [f64; 3],
    pub spans: Vec<PhaseSpan>,
}

/// One phase kind's simulated-cost vs measured-wall-clock comparison —
/// the first step of calibrating the cost model from real engine steps
/// (ROADMAP "calibrate the cost model"). The simulated side sums span
/// durations across ranks and directions; the measured side sums the
/// host wall-clock the engine recorded around the same work. Their ratio
/// is what a self-calibrating cost model would fold back into
/// `link_gbps` / `compute_gflops`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PhaseCalibration {
    pub phase: Phase,
    pub simulated_s: f64,
    pub measured_s: f64,
}

impl PhaseCalibration {
    /// simulated / measured (0 when nothing was measured).
    pub fn ratio(&self) -> f64 {
        if self.measured_s > 0.0 {
            self.simulated_s / self.measured_s
        } else {
            0.0
        }
    }
}

impl OverlapReport {
    /// Barrier (no-overlap) execution time: every phase serialized.
    pub fn serial_path_s(&self) -> f64 {
        self.comm_s + self.compute_s
    }

    /// Perfect-overlap lower bound: the longer backbone fully hides the
    /// shorter one.
    pub fn ideal_path_s(&self) -> f64 {
        self.comm_s.max(self.compute_s)
    }

    /// Fraction of communication time left on the critical path
    /// (1.0 = fully exposed, i.e. the barrier schedule; 0.0 = fully
    /// hidden or no communication at all).
    pub fn exposed_comm_fraction(&self) -> f64 {
        if self.comm_s <= 0.0 {
            return 0.0;
        }
        ((self.critical_path_s - self.compute_s).max(0.0) / self.comm_s).min(1.0)
    }

    /// Achieved overlap as a fraction of the ideal: 0.0 = barrier
    /// schedule, 1.0 = critical path down to `ideal_path_s`.
    pub fn overlap_efficiency(&self) -> f64 {
        let serial = self.serial_path_s();
        let ideal = self.ideal_path_s();
        if serial - ideal <= 0.0 {
            return 1.0;
        }
        ((serial - self.critical_path_s) / (serial - ideal)).clamp(0.0, 1.0)
    }

    /// Total bytes of `phase` spans in the given direction.
    pub fn phase_bytes(&self, phase: Phase, backward: bool) -> u64 {
        self.spans
            .iter()
            .filter(|s| s.phase == phase && s.backward == backward)
            .map(|s| s.bytes)
            .sum()
    }

    /// Simulated seconds of `phase` spans, both directions, summed
    /// across ranks and chunks (the span-sum counterpart of
    /// [`measured_s`](OverlapReport::measured_s)).
    pub fn simulated_phase_s(&self, phase: Phase) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.phase == phase)
            .map(|s| s.end_s - s.start_s)
            .sum()
    }

    /// Measured host wall-clock of the whole session — the sum of every
    /// phase's calibration samples — or `None` when nothing was measured
    /// (a timeline built without `record_measured`). This is what
    /// tokens/s reporting uses when calibration samples exist, so the
    /// rate reflects the wall the host actually spent, not only the
    /// simulated schedule.
    pub fn measured_step_s(&self) -> Option<f64> {
        let total: f64 = self.measured_s.iter().sum();
        (total > 0.0).then_some(total)
    }

    /// Simulated-vs-measured roll-up per phase kind, in `Phase`
    /// declaration order — the calibration report the engine step
    /// produced alongside its timeline.
    pub fn calibration(&self) -> Vec<PhaseCalibration> {
        [Phase::Exchange, Phase::Compute, Phase::Combine]
            .into_iter()
            .map(|phase| PhaseCalibration {
                phase,
                simulated_s: self.simulated_phase_s(phase),
                measured_s: self.measured_s[phase as usize],
            })
            .collect()
    }

    /// Scalar roll-up (spans elided) for JSONL metrics and benches.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("ranks", Json::num(self.ranks as f64)),
            ("chunks", Json::num(self.chunks as f64)),
            ("critical_path_s", Json::num(self.critical_path_s)),
            ("serial_path_s", Json::num(self.serial_path_s())),
            ("ideal_path_s", Json::num(self.ideal_path_s())),
            ("comm_s", Json::num(self.comm_s)),
            ("compute_s", Json::num(self.compute_s)),
            ("exposed_comm_fraction", Json::num(self.exposed_comm_fraction())),
            ("overlap_efficiency", Json::num(self.overlap_efficiency())),
            ("exchange_bytes", Json::num(self.exchange_bytes as f64)),
            ("combine_bytes", Json::num(self.combine_bytes as f64)),
            ("backward_bytes", Json::num(self.backward_bytes as f64)),
            ("flops", Json::num(self.flops as f64)),
            ("calibration", Json::arr(self.calibration().into_iter().map(|c| {
                Json::obj(vec![
                    ("phase", Json::str(c.phase.name())),
                    ("simulated_s", Json::num(c.simulated_s)),
                    ("measured_s", Json::num(c.measured_s)),
                    ("ratio", Json::num(c.ratio())),
                ])
            }))),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost() -> CostModel {
        CostModel::new(1.0, 1.0).unwrap() // 1 GB/s, 1 GFLOP/s: 1e9 units = 1 s
    }

    #[test]
    fn cost_model_validates_and_prices() {
        assert!(CostModel::new(0.0, 1.0).is_err());
        assert!(CostModel::new(1.0, -2.0).is_err());
        assert!(CostModel::new(f64::NAN, 1.0).is_err());
        let c = cost();
        assert!((c.comm_seconds(2_000_000_000) - 2.0).abs() < 1e-12);
        assert!((c.compute_seconds(500_000_000) - 0.5).abs() < 1e-12);
        assert_eq!(fwd_flops_per_row(8, 16, false), 4 * 8 * 16);
        assert_eq!(fwd_flops_per_row(8, 16, true), 6 * 8 * 16);
        assert_eq!(bwd_flops_per_row(8, 16, false, false), 3 * 2 * 8 * 16);
        assert_eq!(bwd_flops_per_row(8, 16, true, false), 5 * 2 * 8 * 16);
        assert_eq!(bwd_flops_per_row(8, 16, false, true), 4 * 2 * 8 * 16);
        assert_eq!(bwd_flops_per_row(8, 16, true, true), 7 * 2 * 8 * 16);
    }

    #[test]
    fn single_chunk_is_fully_exposed() {
        // K=1: exchange → compute → combine strictly serialized
        let mut tb = TimelineBuilder::new(2, cost());
        let (_, e) = tb.phase(0, false, Phase::Exchange, &[1_000_000_000, 0], 0.0);
        let (cs, cd) = tb.phase(0, false, Phase::Compute, &[2_000_000_000, 1_000_000_000], e);
        assert!((cs - 1.0).abs() < 1e-12);
        let (_, done) = tb.phase(0, false, Phase::Combine, &[1_000_000_000, 0], cd);
        assert!((done - 4.0).abs() < 1e-12);
        let r = tb.report();
        assert!((r.critical_path_s - 4.0).abs() < 1e-12);
        assert!((r.serial_path_s() - 4.0).abs() < 1e-12);
        assert!((r.exposed_comm_fraction() - 1.0).abs() < 1e-12);
        assert_eq!(r.exchange_bytes, 1_000_000_000);
        assert_eq!(r.combine_bytes, 1_000_000_000);
    }

    #[test]
    fn pipelined_chunks_hide_communication() {
        // two chunks: chunk 1's exchange runs during chunk 0's compute
        let mut tb = TimelineBuilder::new(1, cost());
        let b = 1_000_000_000u64; // 1 s of comm
        let f = 3_000_000_000u64; // 3 s of compute
        let (_, e0) = tb.phase(0, false, Phase::Exchange, &[b], 0.0);
        let (c0s, c0d) = tb.phase(0, false, Phase::Compute, &[f], e0);
        let (_, e1) = tb.phase(1, false, Phase::Exchange, &[b], c0s);
        assert!(e1 < c0d, "exchange 1 should finish inside compute 0");
        let (_, c1d) = tb.phase(1, false, Phase::Compute, &[f], e1.max(c0d));
        let r_mid = tb.report();
        assert!(r_mid.exposed_comm_fraction() < 1.0);
        assert!((c1d - 7.0).abs() < 1e-12); // 1 + 3 + 3: second exchange hidden
        let r = tb.report();
        assert!(r.critical_path_s < r.serial_path_s());
        assert!(r.overlap_efficiency() > 0.0);
    }

    #[test]
    fn lanes_never_double_book() {
        let mut tb = TimelineBuilder::new(3, cost());
        let mut ready = 0.0;
        for chunk in 0..4 {
            let bytes = [(chunk as u64 + 1) * 1_000_000; 3];
            let flops = [(chunk as u64 + 2) * 2_000_000; 3];
            let (_, e) = tb.phase(chunk, false, Phase::Exchange, &bytes, ready);
            let (_, c) = tb.phase(chunk, false, Phase::Compute, &flops, e);
            let (_, done) = tb.phase(chunk, false, Phase::Combine, &bytes, c);
            ready = done * 0.5; // deliberately early: lanes must still serialize
        }
        let r = tb.report();
        for rank in 0..3 {
            for comm in [true, false] {
                let mut lane: Vec<&PhaseSpan> = r
                    .spans
                    .iter()
                    .filter(|s| s.rank == rank && s.phase.is_comm() == comm)
                    .collect();
                lane.sort_by(|a, b| a.start_s.partial_cmp(&b.start_s).unwrap());
                for w in lane.windows(2) {
                    assert!(w[0].end_s <= w[1].start_s + 1e-12,
                            "lane overlap on rank {rank}");
                }
            }
        }
        assert!(r.critical_path_s <= r.serial_path_s() + 1e-12);
    }

    #[test]
    fn zero_work_phases_record_nothing() {
        let mut tb = TimelineBuilder::new(2, cost());
        let (s, e) = tb.phase(0, false, Phase::Exchange, &[0, 0], 1.5);
        assert_eq!((s, e), (1.5, 1.5));
        let r = tb.report();
        assert!(r.spans.is_empty());
        assert_eq!(r.exposed_comm_fraction(), 0.0);
        assert_eq!(r.overlap_efficiency(), 1.0);
    }

    #[test]
    fn report_json_is_valid() {
        let mut tb = TimelineBuilder::new(1, cost());
        let (_, e) = tb.phase(0, false, Phase::Exchange, &[4_000_000], 0.0);
        let _ = tb.phase(0, false, Phase::Compute, &[8_000_000], e);
        let j = tb.report().to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("chunks").unwrap().as_usize(), Some(1));
        assert!(parsed.get("critical_path_s").unwrap().as_f64().unwrap() > 0.0);
    }
}

//! Chunked all-to-all pipeline: dispatch exchange overlapped with expert
//! compute, with a deterministic phase-timeline cost model.
//!
//! The barrier engines run dispatch → expert compute → combine as three
//! globally-separated phases, so cross-rank bytes serialize with FLOPs.
//! [`PipelinedEngine`] breaks one step into K token-contiguous chunks
//! (via [`StepBatch::split`]) and software-pipelines them at depth 2:
//!
//! ```text
//!            chunk 0         chunk 1         chunk 2
//! comm lane  [exch 0]        [exch 1]        [exch 2]   [comb 0] ...
//!                     \              \              \
//! compute lane         [expert compute 0][compute 1][compute 2] ...
//!                      ^ exch 1 packs here, on a scoped thread,
//!                        while chunk 0's experts run on the pool
//! ```
//!
//! # Chunk-pipeline lifecycle
//!
//! One `forward` session:
//!
//! 1. **Plan** (cached per batch id, LRU like the barrier engine): split
//!    the batch into K contiguous-token chunks and derive each chunk's
//!    routing plan. Token residency stays in *global* coordinates
//!    (`rank_of_token(token_base + t, L)`), so the summed chunk exchange
//!    moves exactly the whole-batch [`AllToAllPlan::cross_rank_bytes`] —
//!    chunking changes *when* bytes move, never *how many*.
//! 2. **Pipeline**: pack chunk 0's send buffers; then for each chunk m,
//!    run its per-rank expert compute on the worker pool while a scoped
//!    thread packs chunk m+1's exchange buffers, and drain chunk m's
//!    combine scatter into the output as soon as its compute lands.
//! 3. **Save**: each chunk's policy-dependent activations
//!    (`CheckpointPolicy`) are retained per chunk for the backward.
//!
//! `backward_into` mirrors it: chunk m+1's gated gradient buffers (and,
//! under `RecomputeAll`, its re-gathered routed inputs — measured as
//! `Traffic::recompute_bytes`) are packed while chunk m's gradient
//! accumulation runs. Chunks accumulate in ascending token order, which
//! is the exact float-op sequence of the unchunked batch (the same
//! argument that makes grad-accum bit-identical), so outputs, gradients,
//! and loss curves are bit-identical to [`ShardedEngine`] for every
//! checkpoint policy × rank count × K — pinned by
//! `rust/tests/ep_pipeline.rs` and the `tools/ep_sim.py` mirror.
//!
//! Alongside the real (threaded) overlap, every session is priced on the
//! [`timeline`] cost model's simulated clock, producing per-chunk
//! [`PhaseSpan`]s and an [`OverlapReport`] (critical path, exposed
//! communication, overlap efficiency) rendered by `ep-bench` and emitted
//! through `MetricsSink` — see the [`timeline`] docs for the model's
//! assumptions.
//!
//! Memory: only one chunk's transient buffers (routed rows, send/return
//! buffers of the depth-2 window) are live at a time, so per-rank peak
//! resident bytes *drop* versus the barrier engine's whole-batch buffers
//! while the policy-saved bytes stay identical. Cached chunk plans are
//! pure index data — activations and gates are always read from the
//! parent `StepBatch` with token offsets, never copied per chunk — at
//! the cost of per-chunk routing metadata (`index_bytes`) summing
//! slightly above the whole-batch plan's.
//!
//! [`AllToAllPlan::cross_rank_bytes`]: super::expert_parallel::AllToAllPlan::cross_rank_bytes
//! [`ShardedEngine`]: super::engine::ShardedEngine
//! [`PhaseSpan`]: timeline::PhaseSpan
//! [`OverlapReport`]: timeline::OverlapReport

pub mod timeline;

use std::mem;
use std::time::Instant;

use crate::config::ep::ChunkBalance;
use crate::memory::model::{pipeline_window_bytes, CheckpointPolicy, MemoryBreakdown};
use crate::util::threadpool::{par_map, scope_chunks};

use self::timeline::{bwd_flops_per_row, fwd_flops_per_row, CostModel, OverlapReport,
                     Phase, TimelineBuilder};
use super::engine::{add_params, check_batch, expert_backward_row, expert_forward,
                    expert_forward_saving, fold_dx, lru_get_or_insert,
                    next_engine_tag, recompute_hidden, split_bounds_weighted,
                    BatchPlan, ExecutionEngine, RankBwdWork, SavedActs, StepBatch,
                    StepHandle, Traffic, PLAN_CACHE_CAP};
use super::expert_parallel::EpTopology;
use super::params::{ExpertGrads, ExpertParams, ExpertStore, RankExperts};

/// One chunk of a batch: its token offset in the parent and the routing
/// plan in global token coordinates. Pure index data — activations and
/// gates are always read from the parent [`StepBatch`] with token
/// offsets, so caching chunk plans duplicates no payload bytes (the
/// zero-copy property the `StepBatch` design exists for).
struct ChunkPlan {
    token_base: usize,
    plan: BatchPlan,
}

struct PipeSession {
    id: u64,
    batch: StepBatch,
    /// saved[chunk][rank], policy-dependent
    saved: Vec<Vec<SavedActs>>,
    /// simulated clock continued by the backward pass
    timeline: TimelineBuilder,
}

/// Chunk-pipelined expert-parallel engine: R simulated ranks, K-deep
/// chunk stream, real threaded overlap of exchange packing with expert
/// compute, measured traffic, and a simulated-cost [`OverlapReport`].
pub struct PipelinedEngine {
    pub topo: EpTopology,
    pub rank_params: Vec<RankExperts>,
    d_model: usize,
    d_hidden: usize,
    workers: usize,
    policy: CheckpointPolicy,
    /// requested chunk count (clamped to the batch's token count)
    chunks: usize,
    /// how chunk boundaries are chosen: even token counts, or balanced
    /// by routed-row load so a skewed router stops making ragged chunks
    balance: ChunkBalance,
    cost: CostModel,
    engine_tag: u64,
    sessions_opened: u64,
    session: Option<PipeSession>,
    /// LRU chunk-plan cache by (batch id, layer), bounded at
    /// `plan_cache_cap`
    plans: Vec<((u64, u32), Vec<ChunkPlan>)>,
    plan_cache_cap: usize,
    traffic: Traffic,
    mem: Vec<MemoryBreakdown>,
    report: Option<OverlapReport>,
}

impl PipelinedEngine {
    /// Default checkpoint policy and cost model; see
    /// [`with_policy`](PipelinedEngine::with_policy).
    pub fn new(topo: EpTopology, store: &ExpertStore, workers: usize,
               chunks: usize) -> Result<PipelinedEngine, String> {
        PipelinedEngine::with_policy(topo, store, workers, CheckpointPolicy::default(),
                                     chunks, CostModel::default())
    }

    pub fn with_policy(topo: EpTopology, store: &ExpertStore, workers: usize,
                       policy: CheckpointPolicy, chunks: usize,
                       cost: CostModel) -> Result<PipelinedEngine, String> {
        if topo.num_experts != store.experts.len() {
            return Err(format!(
                "topology has {} experts, store has {}",
                topo.num_experts,
                store.experts.len()
            ));
        }
        if chunks == 0 {
            return Err("pipeline needs at least one chunk".into());
        }
        let rank_params = store.shard(&topo.assignment());
        Ok(PipelinedEngine {
            topo,
            rank_params,
            d_model: store.d_model,
            d_hidden: store.d_hidden,
            workers: workers.max(1),
            policy,
            chunks,
            balance: ChunkBalance::Tokens,
            cost,
            engine_tag: next_engine_tag(),
            sessions_opened: 0,
            session: None,
            plans: Vec::new(),
            plan_cache_cap: PLAN_CACHE_CAP,
            traffic: Traffic::default(),
            mem: Vec::new(),
            report: None,
        })
    }

    /// Chunk plans currently cached (≤ the cache bound, in batches).
    pub fn cached_plans(&self) -> usize {
        self.plans.len()
    }

    /// Raise/lower the chunk-plan cache bound (≥ 1, trimming
    /// immediately); see [`PLAN_CACHE_CAP`] and
    /// `ShardedEngine::set_plan_cache_cap` for why grad-accum callers
    /// need at least their microbatch count.
    pub fn set_plan_cache_cap(&mut self, cap: usize) {
        self.plan_cache_cap = cap.max(1);
        while self.plans.len() > self.plan_cache_cap {
            self.plans.remove(0);
        }
    }

    /// Switch the chunk-boundary policy (`[ep] chunk_balance`). Tokens
    /// (the default) cuts even token counts; Rows balances the summed
    /// routed-row *load* of each chunk — every token is weighted by the
    /// total routed rows of the experts it feeds, so tokens bound for
    /// hot experts spread across more, smaller chunks and the per-chunk
    /// busiest-rank load evens out. Any contiguous partition keeps the
    /// token-residency invariant (summed chunk traffic == the
    /// whole-batch plan), so outputs stay bit-identical. Cached plans
    /// are cleared: they encode the old boundaries.
    pub fn set_chunk_balance(&mut self, balance: ChunkBalance) {
        if self.balance != balance {
            self.balance = balance;
            self.plans.clear();
            // an open session saved per-chunk activations sized to the
            // OLD bounds; its backward would re-plan with the new ones
            // and pair wrong (or wrong-sized) tensors. Drop it —
            // outstanding handles fail cleanly with "no open session".
            self.session = None;
        }
    }

    /// Index of the cached chunk plans for `batch`, splitting the
    /// routing and planning each chunk on first sight
    /// ([`lru_get_or_insert`] semantics, as the barrier engine).
    fn plan_index(&mut self, batch: &StepBatch) -> Result<usize, String> {
        let topo = &self.topo;
        let l = batch.num_tokens();
        let kc = self.chunks.min(l);
        let balance = self.balance;
        lru_get_or_insert(&mut self.plans, self.plan_cache_cap, batch.plan_key(), || {
            let parts = match balance {
                ChunkBalance::Tokens => batch.split_routing(kc)?,
                ChunkBalance::Rows => {
                    let disp = batch.disp();
                    let loads: Vec<u64> = (0..disp.num_experts)
                        .map(|e| disp.expert_len(e) as u64)
                        .collect();
                    let weights: Vec<u64> = (0..l)
                        .map(|t| {
                            disp.token_experts(t)
                                .iter()
                                .map(|&e| loads[e as usize])
                                .sum()
                        })
                        .collect();
                    let bounds = split_bounds_weighted(&weights, kc)?;
                    batch.split_routing_at(&bounds)?
                }
            };
            parts
                .into_iter()
                .map(|(t0, disp)| {
                    let plan = BatchPlan::build(&disp, topo, t0, l)?;
                    Ok(ChunkPlan { token_base: t0, plan })
                })
                .collect()
        })
    }

    /// The one backward: chunk m+1's gradient exchange (and
    /// `RecomputeAll` re-gather) packs while chunk m's accumulation
    /// runs; per-chunk ∂x rows are folded home in ascending chunk order
    /// (each chunk in global expert-major position order — `fold_dx`),
    /// which is the unchunked accumulation sequence per token. Parameter
    /// grads are bit-identical whether or not ∂x is requested.
    fn backward_impl(&mut self, handle: StepHandle, d_out: &[f32],
                     grads: &mut ExpertGrads,
                     d_x: Option<&mut [f32]>) -> Result<(), String> {
        let (d, h) = (self.d_model, self.d_hidden);
        if handle.engine_tag != self.engine_tag {
            return Err("step handle belongs to a different engine".into());
        }
        match &self.session {
            None => return Err("no open step session (forward not called)".into()),
            Some(s) if s.id != handle.session => {
                return Err(format!(
                    "stale step handle: session {} superseded by {}",
                    handle.session, s.id
                ));
            }
            Some(_) => {}
        }
        grads
            .check_like(self.topo.num_experts, d, h)
            .map_err(|e| e.to_string())?;
        // shape checks before the session is consumed (see the
        // single-rank engine for the retryability contract)
        let l_tokens = self.session.as_ref().unwrap().batch.num_tokens();
        if d_out.len() != l_tokens * d {
            return Err(format!(
                "d_out has {} elements, expected L·d = {}",
                d_out.len(),
                l_tokens * d
            ));
        }
        if let Some(dx) = &d_x {
            if dx.len() != l_tokens * d {
                return Err(format!(
                    "d_x has {} elements, expected L·d = {}",
                    dx.len(),
                    l_tokens * d
                ));
            }
        }
        let st = self.session.take().unwrap();
        let mut d_x = d_x;
        let want_dx = d_x.is_some();
        let r = self.topo.ranks;
        let workers = self.workers.min(r);
        let policy = self.policy;
        let plan_idx = self.plan_index(&st.batch)?;

        // move each expert's accumulator into its owning rank's work
        // item once for the whole chunk stream; chunks then extend
        // segments in ascending token order — the unchunked float-op
        // sequence. The per-rank ∂x buffers are re-sized per chunk.
        let assignment = self.topo.assignment();
        let mut work: Vec<RankBwdWork> = (0..r)
            .map(|_| RankBwdWork { bucket: Vec::new(), dxs: Vec::new() })
            .collect();
        for (e, g) in grads.experts.drain(..).enumerate() {
            work[assignment.rank_of[e] as usize].bucket.push((e, g));
        }

        let x = st.batch.x();
        let gates = st.batch.gates();
        let k_top = st.batch.disp().top_k;
        let mut timeline = st.timeline;
        let mut grad_bytes = 0u64;
        let mut recompute_bytes = 0u64;
        {
            let chunks = &self.plans[plan_idx].1;
            let params = &self.rank_params;
            let kc = chunks.len();
            let mut saved_iter = st.saved.into_iter();

            // one chunk's backward inputs: gated gradient buffers per
            // (home → dst), plus — under RecomputeAll — the re-gathered
            // routed inputs (the backward re-run of the dispatch
            // exchange). Gates and activations come from the parent
            // batch, offset by the chunk's token base. Returns its own
            // wall-clock for the calibration hook.
            let pack_bwd = |m: usize| -> (f64, Vec<Vec<Vec<f32>>>, Option<Vec<Vec<f32>>>) {
                let t0 = Instant::now();
                let cp = &chunks[m];
                let routes = &cp.plan.routes;
                let base = cp.token_base * d;
                let gate_base = cp.token_base * k_top;
                let dsend = par_map(r, workers, |home| {
                    (0..r)
                        .map(|dst| {
                            let hops = &routes[dst][home];
                            let mut buf = Vec::with_capacity(hops.len() * d);
                            for hop in hops {
                                let t = hop.token as usize;
                                let g = gates[gate_base + hop.origin as usize];
                                for c in 0..d {
                                    buf.push(g * d_out[base + t * d + c]);
                                }
                            }
                            buf
                        })
                        .collect()
                });
                let xs_re = (policy == CheckpointPolicy::RecomputeAll).then(|| {
                    let shards = &cp.plan.shards;
                    par_map(r, workers, |dst| {
                        let n_local = shards[dst].local_slots();
                        let mut xs = vec![0.0f32; n_local * d];
                        for per_src in routes[dst].iter() {
                            for hop in per_src {
                                let ls = hop.local_slot as usize;
                                let t = cp.token_base + hop.token as usize;
                                xs[ls * d..(ls + 1) * d]
                                    .copy_from_slice(&x[t * d..(t + 1) * d]);
                            }
                        }
                        xs
                    })
                });
                (t0.elapsed().as_secs_f64(), dsend, xs_re)
            };

            let bwd_start = timeline.now();
            let mut prev_acc_start = bwd_start;
            let mut next = pack_bwd(0);
            for m in 0..kc {
                let cp = &chunks[m];
                let (pack_dur, dsend, xs_re) = next;
                timeline.record_measured(Phase::Exchange, pack_dur);
                let mut cross = vec![0u64; r];
                for home in 0..r {
                    for dst in 0..r {
                        if home != dst {
                            let b = (dsend[home][dst].len() * 4) as u64;
                            grad_bytes += b;
                            cross[home] += b;
                        }
                    }
                }
                if xs_re.is_some() {
                    // the re-gather moves exactly the fwd dispatch rows again
                    for (dst, per_src) in cp.plan.routes.iter().enumerate() {
                        for (src, hops) in per_src.iter().enumerate() {
                            if src != dst {
                                let b = (hops.len() * d * 4) as u64;
                                recompute_bytes += b;
                                cross[src] += b;
                            }
                        }
                    }
                }
                let ready = if m == 0 { bwd_start } else { prev_acc_start };
                let (_, exch_done) =
                    timeline.phase(m, true, Phase::Exchange, &cross, ready);

                let saved_m = saved_iter.next().expect("chunk saved state missing");
                let (xs_all, hidden_all): (Vec<Vec<f32>>, Vec<Option<(Vec<f32>, Vec<f32>)>>) =
                    match xs_re {
                        Some(xs) => (xs, (0..r).map(|_| None).collect()),
                        None => {
                            let mut xs_all = Vec::with_capacity(r);
                            let mut hidden_all = Vec::with_capacity(r);
                            for sv in saved_m {
                                match sv {
                                    SavedActs::All { xs, pre, act } => {
                                        xs_all.push(xs);
                                        hidden_all.push(Some((pre, act)));
                                    }
                                    SavedActs::Inputs { xs } => {
                                        xs_all.push(xs);
                                        hidden_all.push(None);
                                    }
                                    SavedActs::Nothing => unreachable!(
                                        "saving policy stored nothing for a chunk"
                                    ),
                                }
                            }
                            (xs_all, hidden_all)
                        }
                    };

                // this chunk's ∂x rows live per rank, sized to the
                // chunk's local slots, zeroed each chunk
                if want_dx {
                    for (dst, w) in work.iter_mut().enumerate() {
                        w.dxs.clear();
                        w.dxs.resize(cp.plan.shards[dst].local_slots() * d, 0.0);
                    }
                }

                // accumulate chunk m per rank while a scoped thread packs
                // chunk m+1's gradient exchange (and RecomputeAll re-gather)
                let (acc_dur, packed_next) = std::thread::scope(|s| {
                    let pack_handle = (m + 1 < kc).then(|| s.spawn(|| pack_bwd(m + 1)));
                    let dsend_ref = &dsend;
                    let xs_ref = &xs_all;
                    let hidden_ref = &hidden_all;
                    let routes = &cp.plan.routes;
                    let shards = &cp.plan.shards;
                    // time the accumulation alone, as the forward times
                    // compute_chunk alone — joining the pack thread is
                    // Exchange time and is measured there, not here
                    let acc_t0 = Instant::now();
                    scope_chunks(&mut work, 1, workers, |dst, chunk| {
                        let RankBwdWork { bucket, dxs } = &mut chunk[0];
                        let sh = &shards[dst];
                        let n_local = sh.local_slots();
                        let mut dys = vec![0.0f32; n_local * d];
                        for (src, bufs) in dsend_ref.iter().enumerate() {
                            for (i, hop) in routes[dst][src].iter().enumerate() {
                                let ls = hop.local_slot as usize;
                                dys[ls * d..(ls + 1) * d]
                                    .copy_from_slice(&bufs[dst][i * d..(i + 1) * d]);
                            }
                        }
                        let xs = &xs_ref[dst];
                        let mut pre_row = vec![0.0f32; h];
                        let mut act_row = vec![0.0f32; h];
                        let mut dz = vec![0.0f32; h];
                        for (i, (e, g)) in bucket.iter_mut().enumerate() {
                            debug_assert_eq!(*e as u32, sh.experts[i]);
                            let p = &params[dst].experts[i].1;
                            let lo = sh.expert_token_offsets[i] as usize;
                            let hi = sh.expert_token_offsets[i + 1] as usize;
                            for ls in lo..hi {
                                let xrow = &xs[ls * d..(ls + 1) * d];
                                let dy = &dys[ls * d..(ls + 1) * d];
                                let (pre, act): (&[f32], &[f32]) = match &hidden_ref[dst] {
                                    Some((pre, act)) => (&pre[ls * h..(ls + 1) * h],
                                                         &act[ls * h..(ls + 1) * h]),
                                    None => {
                                        recompute_hidden(p, d, h, xrow, &mut pre_row,
                                                         &mut act_row);
                                        (&pre_row[..], &act_row[..])
                                    }
                                };
                                let dx_row = if want_dx {
                                    Some(&mut dxs[ls * d..(ls + 1) * d])
                                } else {
                                    None
                                };
                                expert_backward_row(p, g, d, h, xrow, dy, pre,
                                                    act, &mut dz, dx_row);
                            }
                        }
                    });
                    let acc_dur = acc_t0.elapsed().as_secs_f64();
                    (acc_dur,
                     pack_handle.map(|hd| hd.join().expect("bwd pack thread panicked")))
                });
                timeline.record_measured(Phase::Compute, acc_dur);
                if let Some(dx) = d_x.as_deref_mut() {
                    fold_dx(&cp.plan.shards, &work, d, self.topo.num_experts,
                            cp.token_base, dx);
                }
                next = packed_next.unwrap_or_else(|| (0.0, Vec::new(), None));

                let recompute = policy != CheckpointPolicy::SaveAll;
                let flops: Vec<u64> = (0..r)
                    .map(|rank| {
                        cp.plan.shards[rank].local_slots() as u64
                            * bwd_flops_per_row(d, h, recompute)
                    })
                    .collect();
                let (acc_start, _) =
                    timeline.phase(m, true, Phase::Compute, &flops, exch_done);
                prev_acc_start = acc_start;
            }
        }

        let mut dense: Vec<Option<ExpertParams>> =
            (0..self.topo.num_experts).map(|_| None).collect();
        for w in work {
            for (e, g) in w.bucket {
                dense[e] = Some(g);
            }
        }
        grads.experts = dense
            .into_iter()
            .enumerate()
            .map(|(e, g)| g.ok_or_else(|| format!("expert {e} grads lost")))
            .collect::<Result<Vec<_>, String>>()?;
        self.traffic.grad_bytes += grad_bytes;
        self.traffic.recompute_bytes += recompute_bytes;
        self.report = Some(timeline.report());
        Ok(())
    }
}

/// Pack one chunk's dispatch buffers: `send[src][dst]` holds the routed
/// rows src contributes to dst, in dst-local slot order. `x` is the
/// *parent* batch's activations — chunk-local tokens are offset by
/// `token_base`, so no chunk-payload copies ever exist. Shared with
/// `ShardedEngine::forward` (its "chunk" is the whole batch,
/// `token_base = 0`), so the engines can never drift apart on the
/// packing layout.
pub(crate) fn pack_sends(plan: &BatchPlan, x: &[f32], token_base: usize, d: usize,
                         workers: usize) -> Vec<Vec<Vec<f32>>> {
    let r = plan.routes.len();
    let routes = &plan.routes;
    par_map(r, workers, |src| {
        (0..r)
            .map(|dst| {
                let hops = &routes[dst][src];
                let mut buf = Vec::with_capacity(hops.len() * d);
                for hop in hops {
                    let t = token_base + hop.token as usize;
                    buf.extend_from_slice(&x[t * d..(t + 1) * d]);
                }
                buf
            })
            .collect()
    })
}

/// Per-outer-rank byte views of a buffer set: total resident bytes (all
/// peers, local loopback included — the memory view) and cross-rank
/// bytes (peers ≠ self — the traffic/timeline view).
fn buffer_bytes(bufs: &[Vec<Vec<f32>>]) -> (Vec<u64>, Vec<u64>) {
    let r = bufs.len();
    let mut resident = vec![0u64; r];
    let mut cross = vec![0u64; r];
    for (outer, per_peer) in bufs.iter().enumerate() {
        for (peer, buf) in per_peer.iter().enumerate() {
            let b = (buf.len() * 4) as u64;
            resident[outer] += b;
            if peer != outer {
                cross[outer] += b;
            }
        }
    }
    (resident, cross)
}

/// One chunk's per-rank expert compute: unpack routed rows, run the
/// owned experts, and pack the return buffers toward each home rank.
/// Shared with `ShardedEngine::forward` — one definition of the
/// unpack/compute/save/repack sequence keeps the engines bit-identical
/// by construction.
pub(crate) fn compute_chunk(plan: &BatchPlan, params: &[RankExperts],
                            policy: CheckpointPolicy, d: usize, h: usize,
                            workers: usize,
                            send: &[Vec<Vec<f32>>]) -> Vec<(SavedActs, Vec<Vec<f32>>)> {
    let r = plan.routes.len();
    let routes = &plan.routes;
    let shards = &plan.shards;
    par_map(r, workers, |dst| {
        let s = &shards[dst];
        let n_local = s.local_slots();
        let mut xs = vec![0.0f32; n_local * d];
        for src in 0..r {
            for (i, hop) in routes[dst][src].iter().enumerate() {
                let ls = hop.local_slot as usize;
                xs[ls * d..(ls + 1) * d]
                    .copy_from_slice(&send[src][dst][i * d..(i + 1) * d]);
            }
        }
        let save_hidden = policy == CheckpointPolicy::SaveAll;
        let mut ys = vec![0.0f32; n_local * d];
        let mut pre = vec![0.0f32; if save_hidden { n_local * h } else { 0 }];
        let mut act = vec![0.0f32; if save_hidden { n_local * h } else { 0 }];
        let mut hidden = vec![0.0f32; h];
        for (i, (e, p)) in params[dst].experts.iter().enumerate() {
            debug_assert_eq!(*e, s.experts[i]);
            let lo = s.expert_token_offsets[i] as usize;
            let hi = s.expert_token_offsets[i + 1] as usize;
            for ls in lo..hi {
                if save_hidden {
                    expert_forward_saving(p, d, h, &xs[ls * d..(ls + 1) * d],
                                          &mut ys[ls * d..(ls + 1) * d],
                                          &mut pre[ls * h..(ls + 1) * h],
                                          &mut act[ls * h..(ls + 1) * h]);
                } else {
                    expert_forward(p, d, h, &xs[ls * d..(ls + 1) * d],
                                   &mut ys[ls * d..(ls + 1) * d], &mut hidden);
                }
            }
        }
        let rets: Vec<Vec<f32>> = (0..r)
            .map(|src| {
                let hops = &routes[dst][src];
                let mut buf = Vec::with_capacity(hops.len() * d);
                for hop in hops {
                    let ls = hop.local_slot as usize;
                    buf.extend_from_slice(&ys[ls * d..(ls + 1) * d]);
                }
                buf
            })
            .collect();
        let saved = match policy {
            CheckpointPolicy::SaveAll => SavedActs::All { xs, pre, act },
            CheckpointPolicy::SaveInputs => SavedActs::Inputs { xs },
            CheckpointPolicy::RecomputeAll => SavedActs::Nothing,
        };
        (saved, rets)
    })
}

/// Drain one chunk's combine scatter into the global output rows (fixed
/// j-order accumulation per token). `gates` is the *parent* batch's
/// gate vector — chunk-local slots are offset through `token_base`.
/// Shared with `ShardedEngine::forward` (`token_base = 0`, the chunk is
/// the whole batch).
pub(crate) fn combine_chunk(plan: &BatchPlan, gates: &[f32], rets: &[Vec<Vec<f32>>],
                            d: usize, k: usize, workers: usize, token_base: usize,
                            out: &mut [f32]) {
    let r = plan.routes.len();
    let lookup = &plan.ret_lookup;
    let tokens = &plan.tokens_of_rank;
    let home_rows: Vec<Vec<f32>> = par_map(r, workers, |home| {
        let toks = &tokens[home];
        let mut rows = vec![0.0f32; toks.len() * d];
        for (ti, &t) in toks.iter().enumerate() {
            let o = &mut rows[ti * d..(ti + 1) * d];
            for j in 0..k {
                let slot = t as usize * k + j;
                let g = gates[(token_base + t as usize) * k + j];
                let (dst, idx) = lookup[slot];
                let buf = &rets[dst as usize][home];
                let row = &buf[idx as usize * d..(idx as usize + 1) * d];
                for c in 0..d {
                    o[c] += g * row[c];
                }
            }
        }
        rows
    });
    for (home, rows) in home_rows.iter().enumerate() {
        for (ti, &t) in tokens[home].iter().enumerate() {
            let gt = token_base + t as usize;
            out[gt * d..(gt + 1) * d].copy_from_slice(&rows[ti * d..(ti + 1) * d]);
        }
    }
}

impl ExecutionEngine for PipelinedEngine {
    fn name(&self) -> String {
        format!("pipelined-r{}-k{}-{}", self.topo.ranks, self.chunks,
                self.topo.placement)
    }

    fn ranks(&self) -> usize {
        self.topo.ranks
    }

    fn policy(&self) -> CheckpointPolicy {
        self.policy
    }

    fn forward(&mut self, batch: &StepBatch) -> Result<StepHandle, String> {
        let (d, h) = (self.d_model, self.d_hidden);
        check_batch(batch, d, self.topo.num_experts)?;
        let r = self.topo.ranks;
        let workers = self.workers.min(r);
        let policy = self.policy;
        let plan_idx = self.plan_index(batch)?;
        let l = batch.num_tokens();
        let k = batch.disp().top_k;

        let x = batch.x();
        let gates = batch.gates();
        let (out, saved_all, traffic, mem, tb) = {
            let chunks = &self.plans[plan_idx].1;
            let params = &self.rank_params;
            let kc = chunks.len();
            let mut out = vec![0.0f32; l * d];
            let mut saved_all: Vec<Vec<SavedActs>> = Vec::with_capacity(kc);
            let mut traffic = Traffic::default();
            let mut tb = TimelineBuilder::new(r, self.cost);

            // per-rank memory accounting across the chunk stream
            let mut peak_slots = vec![0u64; r];
            let mut total_slots = vec![0u64; r];
            let mut index_bytes = vec![0u64; r];
            let mut resident = vec![0u64; r];
            let mut send_res_per_chunk: Vec<Vec<u64>> = Vec::with_capacity(kc);
            let mut ret_res_per_chunk: Vec<Vec<u64>> = Vec::with_capacity(kc);

            let pack_t0 = Instant::now();
            let mut send_next =
                pack_sends(&chunks[0].plan, x, chunks[0].token_base, d, workers);
            tb.record_measured(Phase::Exchange, pack_t0.elapsed().as_secs_f64());
            let mut prev_compute_start = 0.0f64;
            for m in 0..kc {
                let cp = &chunks[m];
                let send = mem::take(&mut send_next);
                let (send_res, send_cross) = buffer_bytes(&send);
                for src in 0..r {
                    for dst in 0..r {
                        let rows = cp.plan.routes[dst][src].len() as u64;
                        if src == dst {
                            traffic.local_rows += rows;
                        } else {
                            traffic.cross_rows += rows;
                            traffic.dispatch_bytes += (send[src][dst].len() * 4) as u64;
                        }
                    }
                }
                // depth-2 pipeline: chunk m's exchange could begin when
                // chunk m-1's compute began (that is when its pack ran)
                let ready = if m == 0 { 0.0 } else { prev_compute_start };
                let (_, exch_done) =
                    tb.phase(m, false, Phase::Exchange, &send_cross, ready);

                // the real overlap: chunk m's expert compute on the
                // worker pool while a scoped thread packs chunk m+1
                let (computed, compute_dur, packed_next) = std::thread::scope(|s| {
                    let pack_handle = (m + 1 < kc).then(|| {
                        let nc = &chunks[m + 1];
                        s.spawn(move || {
                            let t0 = Instant::now();
                            let p = pack_sends(&nc.plan, x, nc.token_base, d, workers);
                            (t0.elapsed().as_secs_f64(), p)
                        })
                    });
                    let t0 = Instant::now();
                    let computed =
                        compute_chunk(&cp.plan, params, policy, d, h, workers, &send);
                    (computed, t0.elapsed().as_secs_f64(),
                     pack_handle.map(|hd| hd.join().expect("pack thread panicked")))
                });
                tb.record_measured(Phase::Compute, compute_dur);
                if let Some((pack_dur, p)) = packed_next {
                    tb.record_measured(Phase::Exchange, pack_dur);
                    send_next = p;
                }
                let flops: Vec<u64> = (0..r)
                    .map(|rank| {
                        cp.plan.shards[rank].local_slots() as u64
                            * fwd_flops_per_row(d, h)
                    })
                    .collect();
                let (comp_start, comp_done) =
                    tb.phase(m, false, Phase::Compute, &flops, exch_done);
                prev_compute_start = comp_start;

                let mut saved = Vec::with_capacity(r);
                let mut rets = Vec::with_capacity(r);
                for (sv, ret) in computed {
                    saved.push(sv);
                    rets.push(ret);
                }
                let mut combine_recv = vec![0u64; r];
                for dst in 0..r {
                    for home in 0..r {
                        if dst != home {
                            let b = (rets[dst][home].len() * 4) as u64;
                            combine_recv[home] += b;
                            traffic.combine_bytes += b;
                        }
                    }
                }
                let _ = tb.phase(m, false, Phase::Combine, &combine_recv, comp_done);
                let combine_t0 = Instant::now();
                combine_chunk(&cp.plan, gates, &rets, d, k, workers,
                              cp.token_base, &mut out);
                tb.record_measured(Phase::Combine, combine_t0.elapsed().as_secs_f64());

                let (ret_res, _) = buffer_bytes(&rets);
                for rank in 0..r {
                    let nl = cp.plan.shards[rank].local_slots() as u64;
                    peak_slots[rank] = peak_slots[rank].max(nl);
                    total_slots[rank] += nl;
                    index_bytes[rank] += cp.plan.shards[rank].metadata_bytes() as u64;
                    resident[rank] += cp.plan.tokens_of_rank[rank].len() as u64;
                }
                send_res_per_chunk.push(send_res);
                ret_res_per_chunk.push(ret_res);
                saved_all.push(saved);
            }

            // per-rank accounting: policy-saved bytes cover every chunk
            // (they live until backward); transient routed rows are only
            // one chunk deep; comm buffers are the depth-2 window
            let mem: Vec<MemoryBreakdown> = (0..r)
                .map(|rank| {
                    let send_seq: Vec<u64> =
                        send_res_per_chunk.iter().map(|v| v[rank]).collect();
                    let ret_seq: Vec<u64> =
                        ret_res_per_chunk.iter().map(|v| v[rank]).collect();
                    MemoryBreakdown {
                        data_bytes: 4 * d as u64 * (peak_slots[rank] + 2 * resident[rank])
                            + total_slots[rank]
                                * policy.saved_bytes_per_slot(d as u64, h as u64, 4),
                        index_bytes: index_bytes[rank],
                        extra_bytes: pipeline_window_bytes(&send_seq, &ret_seq),
                    }
                })
                .collect();
            (out, saved_all, traffic, mem, tb)
        };

        self.mem = mem;
        self.traffic = traffic;
        self.report = Some(tb.report());
        self.sessions_opened += 1;
        let session = self.sessions_opened;
        self.session = Some(PipeSession {
            id: session,
            batch: batch.share(),
            saved: saved_all,
            timeline: tb,
        });
        Ok(StepHandle { engine_tag: self.engine_tag, session, out })
    }

    fn backward_into(&mut self, handle: StepHandle, d_out: &[f32],
                     grads: &mut ExpertGrads) -> Result<(), String> {
        self.backward_impl(handle, d_out, grads, None)
    }

    fn backward_into_dx(&mut self, handle: StepHandle, d_out: &[f32],
                        grads: &mut ExpertGrads, d_x: &mut [f32]) -> Result<(), String> {
        self.backward_impl(handle, d_out, grads, Some(d_x))
    }

    fn zero_grads(&self) -> ExpertGrads {
        ExpertGrads::zeros(self.topo.num_experts, self.d_model, self.d_hidden)
    }

    fn apply_update(&mut self, delta: &ExpertGrads) -> Result<(), String> {
        delta
            .check_like(self.topo.num_experts, self.d_model, self.d_hidden)
            .map_err(|e| e.to_string())?;
        for rp in &mut self.rank_params {
            for (e, p) in &mut rp.experts {
                add_params(p, &delta.experts[*e as usize]);
            }
        }
        Ok(())
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn memory_per_rank(&self) -> Vec<MemoryBreakdown> {
        if self.mem.is_empty() {
            vec![
                MemoryBreakdown { data_bytes: 0, index_bytes: 0, extra_bytes: 0 };
                self.topo.ranks
            ]
        } else {
            self.mem.clone()
        }
    }

    fn gather_params(&self) -> Result<ExpertStore, String> {
        ExpertStore::gather(&self.rank_params, self.topo.num_experts)
    }

    fn overlap_report(&self) -> Option<OverlapReport> {
        self.report.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::ShardedEngine;
    use crate::dispatch::gating::synthetic_gating;
    use crate::dispatch::parallel_build::parallel_build;
    use crate::util::prng::Rng;

    fn workload(l: usize, e: usize, k: usize, d: usize, skew: f64,
                seed: u64) -> StepBatch {
        let mut rng = Rng::new(seed);
        let g = synthetic_gating(&mut rng, l, e, k, skew);
        let disp = parallel_build(&g.topk_ids, l, e, k);
        let x = rng.normal_vec(l * d, 1.0);
        StepBatch::new(disp, x, g.gates).unwrap()
    }

    #[test]
    fn chunk_traffic_sums_to_the_whole_batch_exchange() {
        let batch = workload(96, 8, 2, 10, 0.8, 3);
        let store = ExpertStore::init(8, 10, 14, 5);
        let topo = EpTopology::new(4, 8).unwrap();
        let plan = topo.plan(batch.disp(), 10, 4);
        for chunks in [1usize, 2, 4, 7] {
            let mut eng =
                PipelinedEngine::new(topo.clone(), &store, 4, chunks).unwrap();
            let _ = eng.forward(&batch).unwrap();
            let t = eng.traffic();
            assert_eq!(t.dispatch_bytes, plan.cross_rank_bytes(),
                       "K={chunks}: chunking changed the exchanged bytes");
            assert_eq!(t.cross_rows + t.local_rows, batch.disp().slots() as u64);
            assert_eq!(t.combine_bytes, t.dispatch_bytes);
        }
    }

    #[test]
    fn pipelined_forward_is_bit_identical_to_barrier() {
        let batch = workload(64, 8, 2, 8, 0.6, 9);
        let store = ExpertStore::init(8, 8, 12, 7);
        let topo = EpTopology::new(4, 8).unwrap();
        let mut barrier = ShardedEngine::new(topo.clone(), &store, 4).unwrap();
        let reference = barrier.forward(&batch).unwrap().into_output();
        for chunks in [1usize, 2, 4] {
            let mut eng =
                PipelinedEngine::new(topo.clone(), &store, 4, chunks).unwrap();
            let out = eng.forward(&batch).unwrap().into_output();
            assert_eq!(out, reference, "K={chunks} forward diverged");
        }
    }

    #[test]
    fn oversized_chunk_count_clamps_to_tokens() {
        let batch = workload(6, 4, 2, 6, 0.2, 4);
        let store = ExpertStore::init(4, 6, 8, 2);
        let topo = EpTopology::new(2, 4).unwrap();
        let mut eng = PipelinedEngine::new(topo.clone(), &store, 2, 64).unwrap();
        let mut barrier = ShardedEngine::new(topo, &store, 2).unwrap();
        let a = eng.forward(&batch).unwrap().into_output();
        let b = barrier.forward(&batch).unwrap().into_output();
        assert_eq!(a, b);
        assert_eq!(eng.overlap_report().unwrap().chunks, 6);
    }

    #[test]
    fn constructor_validation() {
        let store = ExpertStore::init(8, 8, 12, 1);
        let topo = EpTopology::new(4, 8).unwrap();
        assert!(PipelinedEngine::new(topo.clone(), &store, 4, 0).is_err());
        let wrong = ExpertStore::init(6, 8, 12, 1);
        assert!(PipelinedEngine::new(topo, &wrong, 4, 2).is_err());
    }

    #[test]
    fn stale_and_foreign_handles_rejected() {
        let batch = workload(24, 4, 2, 6, 0.0, 8);
        let store = ExpertStore::init(4, 6, 8, 3);
        let topo = EpTopology::new(2, 4).unwrap();
        let mut eng = PipelinedEngine::new(topo.clone(), &store, 2, 2).unwrap();
        let d_out = vec![0.1f32; batch.num_tokens() * 6];
        let mut grads = eng.zero_grads();
        let stale = eng.forward(&batch).unwrap();
        let fresh = eng.forward(&batch).unwrap();
        assert!(eng.backward_into(stale, &d_out, &mut grads).is_err());
        eng.backward_into(fresh, &d_out, &mut grads).unwrap();
        let mut other = PipelinedEngine::new(topo, &store, 2, 2).unwrap();
        let foreign = other.forward(&batch).unwrap();
        assert!(eng.backward_into(foreign, &d_out, &mut grads).is_err());
    }
}

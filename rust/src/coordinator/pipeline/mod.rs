//! Chunked all-to-all pipeline: the dispatch exchange streamed in K
//! chunks against expert compute, with a deterministic phase-timeline
//! cost model.
//!
//! The barrier engines run dispatch → expert compute → combine as three
//! globally-separated phases, so cross-rank bytes serialize with FLOPs.
//! [`PipelinedEngine`] breaks one step into K token-contiguous chunks
//! (via [`StepBatch::split_routing`]) and prices them at pipeline
//! depth 2 on the simulated clock:
//!
//! ```text
//!            chunk 0         chunk 1         chunk 2
//! comm lane  [exch 0]        [exch 1]        [exch 2]   [comb 0] ...
//!                     \              \              \
//! compute lane         [expert compute 0][compute 1][compute 2] ...
//!                      ^ exch m+1 may start when compute m starts —
//!                        one chunk of exchange in flight at a time
//! ```
//!
//! # Chunk-pipeline lifecycle
//!
//! One `forward` session:
//!
//! 1. **Plan** (cached per batch id, LRU like the barrier engine): split
//!    the batch into K contiguous-token chunks and derive each chunk's
//!    index-driven routing plan (`RowIndexPlan` + return lookup). Token
//!    residency stays in *global* coordinates
//!    (`rank_of_token(token_base + t, L)`), so the summed chunk exchange
//!    moves exactly the whole-batch [`AllToAllPlan::cross_rank_bytes`] —
//!    chunking changes *when* bytes move, never *how many*.
//! 2. **Stream**: per chunk, the per-rank blocked expert compute gathers
//!    routed rows straight from the parent batch (`compute_chunk_indexed`
//!    — one definition with `ShardedEngine`), and the combine scatter
//!    drains the chunk into the output reading expert outputs in place.
//!    Since the zero-materialization redesign (PR 5) there are **no**
//!    send/return buffers and therefore no host-side pack thread — the
//!    chunk exchange a real interconnect would run is priced on the
//!    simulated comm lanes from the chunk's analytic row matrix, while
//!    the *measured* exchange wall-clock is the gather/staging time the
//!    kernels report (the packing cost that remains).
//! 3. **Save**: each chunk's policy-dependent activations
//!    (`CheckpointPolicy`) are retained per chunk for the backward.
//!
//! `backward_into` mirrors it: chunk m's gated gradient rows are
//! gathered per tile (and, under `RecomputeAll`, its routed inputs are
//! re-gathered by *index* — the re-exchange is still measured as
//! `Traffic::recompute_bytes`). Chunks accumulate in ascending token
//! order, which is the exact float-op sequence of the unchunked batch
//! (the same argument that makes grad-accum bit-identical), so outputs,
//! gradients, and loss curves are bit-identical to [`ShardedEngine`] for
//! every checkpoint policy × rank count × K — pinned by
//! `rust/tests/ep_pipeline.rs` and the `tools/ep_sim.py` mirror.
//!
//! Every session is priced on the [`timeline`] cost model's simulated
//! clock, producing per-chunk [`PhaseSpan`]s and an [`OverlapReport`]
//! (critical path, exposed communication, overlap efficiency) rendered
//! by `ep-bench` and emitted through `MetricsSink` — see the
//! [`timeline`] docs for the model's assumptions. With
//! `[ep] calibrate = true` the engine folds the measured-vs-simulated
//! phase ratios back into its effective rates each step
//! (`recalibrate_cost_model`).
//!
//! Memory: comm residency is the kernels' staging tiles — at most one
//! inbound gather tile and one outbound return tile per rank
//! (`memory::model::staging_bytes`), strictly below the packed per-peer
//! buffers the pre-PR-5 path kept resident. Cached chunk plans are pure
//! index data — activations and gates are always read from the parent
//! `StepBatch` with token offsets, never copied per chunk — at the cost
//! of per-chunk routing metadata (`index_bytes`) summing slightly above
//! the whole-batch plan's.
//!
//! [`AllToAllPlan::cross_rank_bytes`]: super::expert_parallel::AllToAllPlan::cross_rank_bytes
//! [`ShardedEngine`]: super::engine::ShardedEngine
//! [`PhaseSpan`]: timeline::PhaseSpan
//! [`OverlapReport`]: timeline::OverlapReport

pub mod timeline;

use std::time::Instant;

use crate::config::ep::ChunkBalance;
use crate::memory::model::{staging_bytes, CheckpointPolicy, MemoryBreakdown};
use crate::util::threadpool::{par_map, scope_chunks};

use self::timeline::{bwd_flops_per_row, fwd_flops_per_row, CostModel, OverlapReport,
                     Phase, TimelineBuilder};
use crate::trace::load::ExpertLoadTracker;
use crate::trace::{SpanRecord, TracePhase, Tracer};

use super::engine::{add_params, check_batch, check_store_like, fold_dx,
                    lru_get_or_insert, mem_peak_phase, next_engine_tag,
                    record_compute_spans, split_bounds_weighted, BatchPlan,
                    ExecutionEngine, RankBwdWork, SavedActs, StepBatch,
                    StepHandle, Traffic, PLAN_CACHE_CAP};
use super::expert_parallel::EpTopology;
use super::kernels::{backward_segment, forward_segment, KernelScratch,
                     KernelTimers, RowsSrc, SavedHiddenMut, SavedHiddenRef,
                     DEFAULT_TILE_ROWS};
use super::params::{ExpertGrads, ExpertParams, ExpertStore, RankExperts};

/// One chunk of a batch: its token offset in the parent and the routing
/// plan in global token coordinates. Pure index data — activations and
/// gates are always read from the parent [`StepBatch`] with token
/// offsets, so caching chunk plans duplicates no payload bytes (the
/// zero-copy property the `StepBatch` design exists for).
struct ChunkPlan {
    token_base: usize,
    plan: BatchPlan,
}

struct PipeSession {
    id: u64,
    batch: StepBatch,
    /// saved[chunk][rank], policy-dependent
    saved: Vec<Vec<SavedActs>>,
    /// simulated clock continued by the backward pass
    timeline: TimelineBuilder,
}

/// Chunk-pipelined expert-parallel engine: R simulated ranks, K-deep
/// chunk stream through the index-driven exchange, analytic traffic,
/// and a simulated-cost [`OverlapReport`] with measured-phase
/// calibration.
pub struct PipelinedEngine {
    pub topo: EpTopology,
    pub rank_params: Vec<RankExperts>,
    d_model: usize,
    d_hidden: usize,
    workers: usize,
    policy: CheckpointPolicy,
    /// requested chunk count (clamped to the batch's token count)
    chunks: usize,
    /// how chunk boundaries are chosen: even token counts, or balanced
    /// by routed-row load so a skewed router stops making ragged chunks
    balance: ChunkBalance,
    /// routed-row tile of the blocked kernels (`[ep] tile_rows`)
    tile_rows: usize,
    /// whether the experts are gated (SwiGLU) — from the store at build
    gated: bool,
    cost: CostModel,
    engine_tag: u64,
    sessions_opened: u64,
    session: Option<PipeSession>,
    /// LRU chunk-plan cache by (batch id, layer), bounded at
    /// `plan_cache_cap`
    plans: Vec<((u64, u32), Vec<ChunkPlan>)>,
    plan_cache_cap: usize,
    traffic: Traffic,
    mem: Vec<MemoryBreakdown>,
    report: Option<OverlapReport>,
    /// attached observability handle; `None` keeps the hot path free
    /// of any tracing cost at all (see [`crate::trace`])
    tracer: Option<Tracer>,
    /// attached expert-load tracker, same Option-gating contract
    load: Option<ExpertLoadTracker>,
}

impl PipelinedEngine {
    /// Default checkpoint policy and cost model; see
    /// [`with_policy`](PipelinedEngine::with_policy).
    pub fn new(topo: EpTopology, store: &ExpertStore, workers: usize,
               chunks: usize) -> Result<PipelinedEngine, String> {
        PipelinedEngine::with_policy(topo, store, workers, CheckpointPolicy::default(),
                                     chunks, CostModel::default())
    }

    pub fn with_policy(topo: EpTopology, store: &ExpertStore, workers: usize,
                       policy: CheckpointPolicy, chunks: usize,
                       cost: CostModel) -> Result<PipelinedEngine, String> {
        if topo.num_experts != store.experts.len() {
            return Err(format!(
                "topology has {} experts, store has {}",
                topo.num_experts,
                store.experts.len()
            ));
        }
        if chunks == 0 {
            return Err("pipeline needs at least one chunk".into());
        }
        let rank_params = store.shard(&topo.assignment());
        Ok(PipelinedEngine {
            topo,
            rank_params,
            d_model: store.d_model,
            d_hidden: store.d_hidden,
            workers: workers.max(1),
            policy,
            chunks,
            balance: ChunkBalance::Tokens,
            tile_rows: DEFAULT_TILE_ROWS,
            gated: store.gated(),
            cost,
            engine_tag: next_engine_tag(),
            sessions_opened: 0,
            session: None,
            plans: Vec::new(),
            plan_cache_cap: PLAN_CACHE_CAP,
            traffic: Traffic::default(),
            mem: Vec::new(),
            report: None,
            tracer: None,
            load: None,
        })
    }

    /// Chunk plans currently cached (≤ the cache bound, in batches).
    pub fn cached_plans(&self) -> usize {
        self.plans.len()
    }

    /// Set the blocked-kernel row tile (≥ 1). Outputs and gradients are
    /// bit-identical for every tile size — the knob only moves
    /// throughput and per-rank staging-tile residency.
    pub fn set_tile_rows(&mut self, tile_rows: usize) {
        self.tile_rows = tile_rows.max(1);
    }

    /// Raise/lower the chunk-plan cache bound (≥ 1, trimming
    /// immediately); see [`PLAN_CACHE_CAP`] and
    /// `ShardedEngine::set_plan_cache_cap` for why grad-accum callers
    /// need at least their microbatch count.
    pub fn set_plan_cache_cap(&mut self, cap: usize) {
        self.plan_cache_cap = cap.max(1);
        while self.plans.len() > self.plan_cache_cap {
            self.plans.remove(0);
        }
    }

    /// Switch the chunk-boundary policy (`[ep] chunk_balance`). Tokens
    /// (the default) cuts even token counts; Rows balances the summed
    /// routed-row *load* of each chunk — every token is weighted by the
    /// total routed rows of the experts it feeds, so tokens bound for
    /// hot experts spread across more, smaller chunks and the per-chunk
    /// busiest-rank load evens out. Any contiguous partition keeps the
    /// token-residency invariant (summed chunk traffic == the
    /// whole-batch plan), so outputs stay bit-identical. Cached plans
    /// are cleared: they encode the old boundaries.
    pub fn set_chunk_balance(&mut self, balance: ChunkBalance) {
        if self.balance != balance {
            self.balance = balance;
            self.plans.clear();
            // an open session saved per-chunk activations sized to the
            // OLD bounds; its backward would re-plan with the new ones
            // and pair wrong (or wrong-sized) tensors. Drop it —
            // outstanding handles fail cleanly with "no open session".
            self.session = None;
        }
    }

    /// Index of the cached chunk plans for `batch`, splitting the
    /// routing and planning each chunk on first sight
    /// ([`lru_get_or_insert`] semantics, as the barrier engine).
    fn plan_index(&mut self, batch: &StepBatch) -> Result<usize, String> {
        let topo = &self.topo;
        let l = batch.num_tokens();
        let kc = self.chunks.min(l);
        let balance = self.balance;
        lru_get_or_insert(&mut self.plans, self.plan_cache_cap, batch.plan_key(), || {
            let parts = match balance {
                ChunkBalance::Tokens => batch.split_routing(kc)?,
                ChunkBalance::Rows => {
                    let disp = batch.disp();
                    let loads: Vec<u64> = (0..disp.num_experts)
                        .map(|e| disp.expert_len(e) as u64)
                        .collect();
                    let weights: Vec<u64> = (0..l)
                        .map(|t| {
                            disp.token_experts(t)
                                .iter()
                                .map(|&e| loads[e as usize])
                                .sum()
                        })
                        .collect();
                    let bounds = split_bounds_weighted(&weights, kc)?;
                    batch.split_routing_at(&bounds)?
                }
            };
            parts
                .into_iter()
                .map(|(t0, disp)| {
                    let plan = BatchPlan::build(&disp, topo, t0, l)?;
                    Ok(ChunkPlan { token_base: t0, plan })
                })
                .collect()
        })
    }

    /// The one backward: per chunk, gated gradient rows (and
    /// `RecomputeAll`'s routed inputs) are gathered by index inside the
    /// blocked kernels — no gradient-exchange buffer — while the
    /// simulated timeline still prices the chunk's backward exchange on
    /// the comm lanes at depth 2. Per-chunk ∂x rows are folded home in
    /// ascending chunk order (each chunk in global expert-major position
    /// order — `fold_dx`), which is the unchunked accumulation sequence
    /// per token. Parameter grads are bit-identical whether or not ∂x is
    /// requested.
    fn backward_impl(&mut self, handle: StepHandle, d_out: &[f32],
                     grads: &mut ExpertGrads,
                     d_x: Option<&mut [f32]>) -> Result<(), String> {
        let (d, h) = (self.d_model, self.d_hidden);
        if handle.engine_tag != self.engine_tag {
            return Err("step handle belongs to a different engine".into());
        }
        match &self.session {
            None => return Err("no open step session (forward not called)".into()),
            Some(s) if s.id != handle.session => {
                return Err(format!(
                    "stale step handle: session {} superseded by {}",
                    handle.session, s.id
                ));
            }
            Some(_) => {}
        }
        grads
            .check_like(self.topo.num_experts, d, h)
            .map_err(|e| e.to_string())?;
        // shape checks before the session is consumed (see the
        // single-rank engine for the retryability contract)
        let l_tokens = self.session.as_ref().unwrap().batch.num_tokens();
        if d_out.len() != l_tokens * d {
            return Err(format!(
                "d_out has {} elements, expected L·d = {}",
                d_out.len(),
                l_tokens * d
            ));
        }
        if let Some(dx) = &d_x {
            if dx.len() != l_tokens * d {
                return Err(format!(
                    "d_x has {} elements, expected L·d = {}",
                    dx.len(),
                    l_tokens * d
                ));
            }
        }
        let st = self.session.take().unwrap();
        let mut d_x = d_x;
        let want_dx = d_x.is_some();
        let r = self.topo.ranks;
        let workers = self.workers.min(r);
        let policy = self.policy;
        let tile = self.tile_rows;
        let gated = self.gated;
        let plan_idx = self.plan_index(&st.batch)?;

        // move each expert's accumulator into its owning rank's work
        // item once for the whole chunk stream; chunks then extend
        // segments in ascending token order — the unchunked float-op
        // sequence. The per-rank ∂x buffers are re-sized per chunk.
        let assignment = self.topo.assignment();
        let mut work: Vec<RankBwdWork> = (0..r)
            .map(|_| RankBwdWork {
                bucket: Vec::new(),
                dxs: Vec::new(),
                timers: KernelTimers::default(),
            })
            .collect();
        for (e, g) in grads.experts.drain(..).enumerate() {
            work[assignment.rank_of[e] as usize].bucket.push((e, g));
        }

        let x = st.batch.x();
        let gates = st.batch.gates();
        let k_top = st.batch.disp().top_k;
        let mut timeline = st.timeline;
        let mut grad_bytes = 0u64;
        let mut recompute_bytes = 0u64;
        let row_bytes = (d * 4) as u64;
        {
            let chunks = &self.plans[plan_idx].1;
            let params = &self.rank_params;
            let kc = chunks.len();
            let mut saved_iter = st.saved.into_iter();

            let bwd_start = timeline.now();
            let mut prev_acc_start = bwd_start;
            for m in 0..kc {
                let cp = &chunks[m];
                let rows = &cp.plan.rows;
                // backward exchange, analytic: gated gradient rows mirror
                // the fwd dispatch row-for-row (home → expert rank), and
                // RecomputeAll's re-gather moves the dispatch rows once
                // more — the index plan drives both, no buffer is packed
                let mut cross = vec![0u64; r];
                for home in 0..r {
                    for dst in 0..r {
                        if home != dst {
                            let b = rows.rows(home, dst) * row_bytes;
                            grad_bytes += b;
                            cross[home] += b;
                        }
                    }
                }
                if policy == CheckpointPolicy::RecomputeAll {
                    for dst in 0..r {
                        for src in 0..r {
                            if src != dst {
                                let b = rows.rows(src, dst) * row_bytes;
                                recompute_bytes += b;
                                cross[src] += b;
                            }
                        }
                    }
                }
                let ready = if m == 0 { bwd_start } else { prev_acc_start };
                let (_, exch_done) =
                    timeline.phase(m, true, Phase::Exchange, &cross, ready);

                let saved_m = saved_iter.next().expect("chunk saved state missing");
                // a saving policy whose chunk stored nothing is a
                // corrupted session — fail loudly, never silently
                // re-gather
                if policy != CheckpointPolicy::RecomputeAll
                    && saved_m.iter().any(|sv| matches!(sv, SavedActs::Nothing))
                {
                    return Err(
                        "chunk saved nothing under a saving policy".into(),
                    );
                }
                // this chunk's ∂x rows live per rank, sized to the
                // chunk's local slots, zeroed each chunk
                if want_dx {
                    for (dst, w) in work.iter_mut().enumerate() {
                        w.dxs.clear();
                        w.dxs.resize(rows.per_rank[dst].local_slots() * d, 0.0);
                    }
                }

                // accumulate chunk m per rank through the blocked
                // kernels: gradient and routed-input rows are gathered
                // by index per tile (RecomputeAll re-gathers indices,
                // not rows)
                let gate_base = cp.token_base * k_top;
                let token_base = cp.token_base;
                let saved_ref = &saved_m;
                let trace_t0 = self.tracer.as_ref().map(|tr| tr.now_s());
                let wall_t0 = Instant::now();
                scope_chunks(&mut work, 1, workers, |dst, chunk| {
                    let RankBwdWork { bucket, dxs, timers } = &mut chunk[0];
                    let rr = &rows.per_rank[dst];
                    let (xsrc, hidden): (RowsSrc, Option<SavedHiddenRef<'_>>) =
                        match &saved_ref[dst] {
                            SavedActs::All { xs, pre, act, gate } => (
                                RowsSrc::Packed(&xs[..]),
                                Some(SavedHiddenRef {
                                    pre: &pre[..],
                                    act: &act[..],
                                    gate: (!gate.is_empty())
                                        .then_some(&gate[..]),
                                }),
                            ),
                            SavedActs::Inputs { xs } => {
                                (RowsSrc::Packed(&xs[..]), None)
                            }
                            // RecomputeAll: straight from the shared batch
                            SavedActs::Nothing => (RowsSrc::Tokens(x), None),
                        };
                    let mut scratch = KernelScratch::new(d, h, tile);
                    for (i, (e, g)) in bucket.iter_mut().enumerate() {
                        debug_assert_eq!(*e as u32, rr.experts[i]);
                        let p = &params[dst].experts[i].1;
                        let lo = rr.expert_offsets[i] as usize;
                        let hi = rr.expert_offsets[i + 1] as usize;
                        if lo == hi {
                            continue;
                        }
                        backward_segment(p, g, d, h, lo, hi, &xsrc, &rr.tokens,
                                         token_base, &rr.gate_slots, gate_base,
                                         d_out, gates, hidden,
                                         if want_dx {
                                             Some(&mut dxs[..])
                                         } else {
                                             None
                                         },
                                         &mut scratch, Some(&mut *timers));
                    }
                });
                // measured time is the parallel section's WALL clock,
                // apportioned between the calibration channels by the
                // workers' summed gather/compute split: gather = the
                // staging rump of the old gradient-exchange packing,
                // kernels = Compute
                let wall = wall_t0.elapsed().as_secs_f64();
                let mut tm = KernelTimers::default();
                let mut rank_timers = Vec::with_capacity(r);
                for w in work.iter_mut() {
                    tm.add(w.timers);
                    rank_timers.push(w.timers);
                    w.timers = KernelTimers::default();
                }
                let (gather_wall, compute_wall) =
                    split_wall(wall, tm.gather_s, tm.compute_s);
                timeline.record_measured(Phase::Exchange, gather_wall);
                timeline.record_measured(Phase::Compute, compute_wall);
                if let (Some(tr), Some(t0)) = (&self.tracer, trace_t0) {
                    record_compute_spans(tr, t0, gather_wall, compute_wall,
                                         &rank_timers,
                                         cross.iter().sum::<u64>(),
                                         rows.local_rows() + rows.cross_rows(),
                                         0, Some(m), true);
                }
                if let Some(dx) = d_x.as_deref_mut() {
                    fold_dx(rows, &work, d, self.topo.num_experts,
                            cp.token_base, dx);
                }

                let recompute = policy != CheckpointPolicy::SaveAll;
                let flops: Vec<u64> = (0..r)
                    .map(|rank| {
                        rows.per_rank[rank].local_slots() as u64
                            * bwd_flops_per_row(d, h, recompute, gated)
                    })
                    .collect();
                let (acc_start, _) =
                    timeline.phase(m, true, Phase::Compute, &flops, exch_done);
                prev_acc_start = acc_start;
            }
        }

        let mut dense: Vec<Option<ExpertParams>> =
            (0..self.topo.num_experts).map(|_| None).collect();
        for w in work {
            for (e, g) in w.bucket {
                dense[e] = Some(g);
            }
        }
        grads.experts = dense
            .into_iter()
            .enumerate()
            .map(|(e, g)| g.ok_or_else(|| format!("expert {e} grads lost")))
            .collect::<Result<Vec<_>, String>>()?;
        self.traffic.grad_bytes += grad_bytes;
        self.traffic.recompute_bytes += recompute_bytes;
        self.report = Some(timeline.report());
        Ok(())
    }
}

/// Apportion one parallel section's measured wall-clock between the
/// Exchange (gather/staging) and Compute channels, using the workers'
/// summed per-channel time only as the *split ratio*. Workers run
/// concurrently, so their summed durations overcount real time by up to
/// the worker count — the wall clock is the truth, the ratio just says
/// which channel the section spent it on. With no worker samples the
/// whole section is Compute.
pub(crate) fn split_wall(wall_s: f64, gather_sum_s: f64,
                         compute_sum_s: f64) -> (f64, f64) {
    let total = gather_sum_s + compute_sum_s;
    if total > 0.0 {
        (wall_s * gather_sum_s / total, wall_s * compute_sum_s / total)
    } else {
        (0.0, wall_s)
    }
}

/// One chunk's per-rank blocked expert compute, index-driven: each rank
/// walks its owned experts' segments in tiles, gathering routed rows
/// straight from the *parent* batch's activations (chunk-local tokens
/// offset by `token_base`) — no send buffer, no unpack buffer, no
/// return buffer. Returns per rank the policy-saved activations, the
/// expert outputs (`ys`, per local slot — what the combine scatter reads
/// in place), and the worker's measured gather/kernel time (zeros unless
/// `timed` — only the pipelined engine's calibration reads it, so the
/// barrier engine skips the per-tile clock reads entirely).
/// Shared with `ShardedEngine::forward` (its "chunk" is the whole batch,
/// `token_base = 0`), so the engines can never drift apart on the
/// kernel path.
pub(crate) fn compute_chunk_indexed(
    plan: &BatchPlan, params: &[RankExperts], policy: CheckpointPolicy, d: usize,
    h: usize, workers: usize, tile_rows: usize, x: &[f32], token_base: usize,
    timed: bool,
) -> Vec<(SavedActs, Vec<f32>, KernelTimers)> {
    let r = plan.ranks();
    let rows = &plan.rows;
    par_map(r, workers, |dst| {
        let rr = &rows.per_rank[dst];
        let n_local = rr.local_slots();
        let save_hidden = policy == CheckpointPolicy::SaveAll;
        let save_inputs = policy != CheckpointPolicy::RecomputeAll;
        // gatedness from this rank's own experts — every expert in a
        // store shares it, so the first is authoritative
        let gated = params[dst]
            .experts
            .first()
            .map_or(false, |(_, p)| p.gated());
        let mut ys = vec![0.0f32; n_local * d];
        let mut xs = vec![0.0f32; if save_inputs { n_local * d } else { 0 }];
        let mut pre = vec![0.0f32; if save_hidden { n_local * h } else { 0 }];
        let mut act = vec![0.0f32; if save_hidden { n_local * h } else { 0 }];
        let mut gate =
            vec![0.0f32; if save_hidden && gated { n_local * h } else { 0 }];
        let mut scratch = KernelScratch::new(d, h, tile_rows);
        let mut timers = KernelTimers::default();
        for (i, (e, p)) in params[dst].experts.iter().enumerate() {
            debug_assert_eq!(*e, rr.experts[i]);
            let lo = rr.expert_offsets[i] as usize;
            let hi = rr.expert_offsets[i + 1] as usize;
            if lo == hi {
                continue;
            }
            forward_segment(p, d, h, lo, hi, x, &rr.tokens, token_base, &mut ys,
                            if save_inputs { Some(&mut xs[..]) } else { None },
                            if save_hidden {
                                Some(SavedHiddenMut {
                                    pre: &mut pre[..],
                                    act: &mut act[..],
                                    gate: gated.then_some(&mut gate[..]),
                                })
                            } else {
                                None
                            },
                            &mut scratch,
                            if timed { Some(&mut timers) } else { None });
        }
        let saved = match policy {
            CheckpointPolicy::SaveAll => SavedActs::All { xs, pre, act, gate },
            CheckpointPolicy::SaveInputs => SavedActs::Inputs { xs },
            CheckpointPolicy::RecomputeAll => SavedActs::Nothing,
        };
        (saved, ys, timers)
    })
}

/// Drain one chunk's combine scatter into the global output rows (fixed
/// j-order accumulation per token), reading each expert-output row **in
/// place** from its owning rank's `ys` through the plan's return lookup
/// — the return buffers of the packed path are gone. `gates` is the
/// *parent* batch's gate vector — chunk-local slots are offset through
/// `token_base`. Shared with `ShardedEngine::forward` (`token_base = 0`,
/// the chunk is the whole batch).
pub(crate) fn combine_chunk(plan: &BatchPlan, gates: &[f32], ys_of: &[Vec<f32>],
                            d: usize, k: usize, workers: usize, token_base: usize,
                            out: &mut [f32]) {
    let r = plan.ranks();
    let lookup = &plan.ret_lookup;
    let tokens = &plan.tokens_of_rank;
    let home_rows: Vec<Vec<f32>> = par_map(r, workers, |home| {
        let toks = &tokens[home];
        let mut rows = vec![0.0f32; toks.len() * d];
        for (ti, &t) in toks.iter().enumerate() {
            let o = &mut rows[ti * d..(ti + 1) * d];
            for j in 0..k {
                let slot = t as usize * k + j;
                let g = gates[(token_base + t as usize) * k + j];
                let (dst, ls) = lookup[slot];
                let buf = &ys_of[dst as usize];
                let row = &buf[ls as usize * d..(ls as usize + 1) * d];
                for c in 0..d {
                    o[c] += g * row[c];
                }
            }
        }
        rows
    });
    for (home, rows) in home_rows.iter().enumerate() {
        for (ti, &t) in tokens[home].iter().enumerate() {
            let gt = token_base + t as usize;
            out[gt * d..(gt + 1) * d].copy_from_slice(&rows[ti * d..(ti + 1) * d]);
        }
    }
}

impl ExecutionEngine for PipelinedEngine {
    fn name(&self) -> String {
        format!("pipelined-r{}-k{}-{}", self.topo.ranks, self.chunks,
                self.topo.placement)
    }

    fn ranks(&self) -> usize {
        self.topo.ranks
    }

    fn policy(&self) -> CheckpointPolicy {
        self.policy
    }

    fn forward(&mut self, batch: &StepBatch) -> Result<StepHandle, String> {
        let (d, h) = (self.d_model, self.d_hidden);
        check_batch(batch, d, self.topo.num_experts)?;
        let r = self.topo.ranks;
        let workers = self.workers.min(r);
        let policy = self.policy;
        let tile = self.tile_rows;
        let plan_idx = self.plan_index(batch)?;
        let l = batch.num_tokens();
        let k = batch.disp().top_k;

        let x = batch.x();
        let gates = batch.gates();
        let row_bytes = (d * 4) as u64;
        let (out, saved_all, traffic, mem, tb) = {
            let chunks = &self.plans[plan_idx].1;
            let params = &self.rank_params;
            let kc = chunks.len();
            let mut out = vec![0.0f32; l * d];
            let mut saved_all: Vec<Vec<SavedActs>> = Vec::with_capacity(kc);
            let mut traffic = Traffic::default();
            let mut tb = TimelineBuilder::new(r, self.cost);

            // per-rank memory accounting across the chunk stream
            let mut peak_slots = vec![0u64; r];
            let mut total_slots = vec![0u64; r];
            let mut index_bytes = vec![0u64; r];
            let mut resident = vec![0u64; r];
            let mut staging_peak = vec![0u64; r];
            // per-expert routed rows across chunks, only when a load
            // tracker is attached (Option-gated like the tracer)
            let mut load_rows = self
                .load
                .as_ref()
                .map(|_| vec![0u64; self.topo.num_experts]);

            let mut prev_compute_start = 0.0f64;
            for m in 0..kc {
                let cp = &chunks[m];
                let rows = &cp.plan.rows;
                // analytic chunk traffic from the index plan — the exact
                // bytes the packed path measured at its buffers
                traffic.local_rows += rows.local_rows();
                traffic.cross_rows += rows.cross_rows();
                let cross_bytes = rows.cross_rank_bytes(d, 4);
                traffic.dispatch_bytes += cross_bytes;
                traffic.combine_bytes += cross_bytes;
                let send_cross: Vec<u64> = (0..r)
                    .map(|src| rows.remote_return_rows(src) * row_bytes)
                    .collect();
                // depth-2 pipeline: chunk m's exchange could begin when
                // chunk m-1's compute began
                let ready = if m == 0 { 0.0 } else { prev_compute_start };
                let (_, exch_done) =
                    tb.phase(m, false, Phase::Exchange, &send_cross, ready);

                // blocked expert compute with the gather fused in: there
                // is no pack step left to overlap on the host — the
                // simulated comm lanes above still price the wire time a
                // real interconnect would pipeline against this compute.
                // Measured time is the parallel section's WALL clock
                // (workers run concurrently — summing their per-worker
                // timers would overcount by up to the worker count),
                // apportioned between the Exchange (gather/staging) and
                // Compute channels by the workers' summed split.
                let trace_t0 = self.tracer.as_ref().map(|tr| tr.now_s());
                let wall_t0 = Instant::now();
                let computed = compute_chunk_indexed(&cp.plan, params, policy,
                                                     d, h, workers, tile, x,
                                                     cp.token_base, true);
                let wall = wall_t0.elapsed().as_secs_f64();
                let mut tm = KernelTimers::default();
                let mut saved = Vec::with_capacity(r);
                let mut ys_of = Vec::with_capacity(r);
                let mut rank_timers = Vec::with_capacity(r);
                for (sv, ys, t) in computed {
                    saved.push(sv);
                    ys_of.push(ys);
                    tm.add(t);
                    rank_timers.push(t);
                }
                let (gather_wall, compute_wall) =
                    split_wall(wall, tm.gather_s, tm.compute_s);
                tb.record_measured(Phase::Exchange, gather_wall);
                tb.record_measured(Phase::Compute, compute_wall);
                if let (Some(tr), Some(t0)) = (&self.tracer, trace_t0) {
                    // section spans carry the exact `split_wall` values
                    // fed to `record_measured`, so the step's span sum
                    // reproduces `measured_step_s()`
                    let next = if m + 1 < kc { chunks[m + 1].token_base } else { l };
                    record_compute_spans(tr, t0, gather_wall, compute_wall,
                                         &rank_timers, cross_bytes,
                                         rows.local_rows() + rows.cross_rows(),
                                         (next - cp.token_base) as u64,
                                         Some(m), false);
                }
                let flops: Vec<u64> = (0..r)
                    .map(|rank| {
                        rows.per_rank[rank].local_slots() as u64
                            * fwd_flops_per_row(d, h, self.gated)
                    })
                    .collect();
                let (comp_start, comp_done) =
                    tb.phase(m, false, Phase::Compute, &flops, exch_done);
                prev_compute_start = comp_start;

                let combine_recv: Vec<u64> = (0..r)
                    .map(|home| rows.remote_return_rows(home) * row_bytes)
                    .collect();
                let _ = tb.phase(m, false, Phase::Combine, &combine_recv, comp_done);
                let trace_tc = self.tracer.as_ref().map(|tr| tr.now_s());
                let combine_t0 = Instant::now();
                combine_chunk(&cp.plan, gates, &ys_of, d, k, workers,
                              cp.token_base, &mut out);
                let combine_s = combine_t0.elapsed().as_secs_f64();
                tb.record_measured(Phase::Combine, combine_s);
                if let (Some(tr), Some(t0)) = (&self.tracer, trace_tc) {
                    let mut s = SpanRecord::new(TracePhase::Combine, t0, combine_s);
                    s.bytes = cross_bytes;
                    s.rows = rows.local_rows() + rows.cross_rows();
                    s.chunk = Some(m);
                    tr.record_span(s);
                }

                if let Some(lr) = &mut load_rows {
                    for rr in &rows.per_rank {
                        for (i, &e) in rr.experts.iter().enumerate() {
                            lr[e as usize] += rr.expert_len(i) as u64;
                        }
                    }
                }
                for rank in 0..r {
                    let nl = rows.per_rank[rank].local_slots() as u64;
                    peak_slots[rank] = peak_slots[rank].max(nl);
                    total_slots[rank] += nl;
                    index_bytes[rank] += rows.per_rank[rank].metadata_bytes() as u64;
                    resident[rank] += cp.plan.tokens_of_rank[rank].len() as u64;
                    staging_peak[rank] = staging_peak[rank].max(staging_bytes(
                        tile as u64, d as u64, 4,
                        rows.remote_in_rows(rank),
                        rows.remote_return_rows(rank),
                        if self.gated { h as u64 } else { 0 }));
                }
                saved_all.push(saved);
            }

            // per-rank accounting: policy-saved bytes cover every chunk
            // (they live until backward); transient routed rows are only
            // one chunk deep; comm residency is the kernels' staging
            // tiles, peak over chunks
            let mem: Vec<MemoryBreakdown> = (0..r)
                .map(|rank| {
                    MemoryBreakdown {
                        data_bytes: 4 * d as u64 * (peak_slots[rank] + 2 * resident[rank])
                            + total_slots[rank]
                                * policy.saved_bytes_per_slot(d as u64, h as u64,
                                                              4, self.gated),
                        index_bytes: index_bytes[rank],
                        extra_bytes: staging_peak[rank],
                    }
                })
                .collect();
            if let Some(tr) = &self.tracer {
                for (rank, mb) in mem.iter().enumerate() {
                    tr.gauge(rank, "resident_bytes", mb.data_bytes as f64,
                             mem_peak_phase(mb));
                    tr.gauge(rank, "routed_rows", total_slots[rank] as f64,
                             "gather");
                }
            }
            if let (Some(lt), Some(lr)) = (&self.load, &load_rows) {
                lt.record_rows(lr, &self.topo.assignment().rank_of, gates);
            }
            (out, saved_all, traffic, mem, tb)
        };

        self.mem = mem;
        self.traffic = traffic;
        self.report = Some(tb.report());
        self.sessions_opened += 1;
        let session = self.sessions_opened;
        self.session = Some(PipeSession {
            id: session,
            batch: batch.share(),
            saved: saved_all,
            timeline: tb,
        });
        Ok(StepHandle { engine_tag: self.engine_tag, session, out })
    }

    fn backward_into(&mut self, handle: StepHandle, d_out: &[f32],
                     grads: &mut ExpertGrads) -> Result<(), String> {
        self.backward_impl(handle, d_out, grads, None)
    }

    fn backward_into_dx(&mut self, handle: StepHandle, d_out: &[f32],
                        grads: &mut ExpertGrads, d_x: &mut [f32]) -> Result<(), String> {
        self.backward_impl(handle, d_out, grads, Some(d_x))
    }

    fn zero_grads(&self) -> ExpertGrads {
        ExpertGrads::zeros_gated(self.topo.num_experts, self.d_model,
                                 self.d_hidden, self.gated)
    }

    fn apply_update(&mut self, delta: &ExpertGrads) -> Result<(), String> {
        delta
            .check_like(self.topo.num_experts, self.d_model, self.d_hidden)
            .map_err(|e| e.to_string())?;
        for rp in &mut self.rank_params {
            for (e, p) in &mut rp.experts {
                add_params(p, &delta.experts[*e as usize]);
            }
        }
        Ok(())
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn memory_per_rank(&self) -> Vec<MemoryBreakdown> {
        if self.mem.is_empty() {
            vec![
                MemoryBreakdown { data_bytes: 0, index_bytes: 0, extra_bytes: 0 };
                self.topo.ranks
            ]
        } else {
            self.mem.clone()
        }
    }

    fn gather_params(&self) -> Result<ExpertStore, String> {
        ExpertStore::gather(&self.rank_params, self.topo.num_experts)
    }

    fn load_params(&mut self, store: &ExpertStore) -> Result<(), String> {
        check_store_like(store, self.topo.num_experts, self.d_model,
                         self.d_hidden, self.gated)?;
        self.rank_params = store.shard(&self.topo.assignment());
        self.session = None;
        Ok(())
    }

    fn overlap_report(&self) -> Option<OverlapReport> {
        self.report.clone()
    }

    fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = Some(tracer);
    }

    fn set_load_tracker(&mut self, tracker: ExpertLoadTracker) {
        self.load = Some(tracker);
    }

    /// The self-tuning cost model: per channel (comm = exchange +
    /// combine, compute), the last session's simulated/measured ratio is
    /// EWMA-folded into the effective rate — a host that measured a
    /// phase slower than the model predicted drags `link_gbps` /
    /// `compute_gflops` down, and subsequent timelines are priced at the
    /// calibrated rates. Ratios are clamped to `[1e-3, 1e3]` so one
    /// noisy step cannot explode the model; channels with no measured or
    /// no simulated time leave their rate untouched.
    fn recalibrate_cost_model(&mut self, alpha: f64) -> Option<CostModel> {
        let rep = self.report.as_ref()?;
        let alpha = alpha.clamp(0.0, 1.0);
        let sim_comm = rep.simulated_phase_s(Phase::Exchange)
            + rep.simulated_phase_s(Phase::Combine);
        let meas_comm = rep.measured_s[Phase::Exchange as usize]
            + rep.measured_s[Phase::Combine as usize];
        let sim_comp = rep.simulated_phase_s(Phase::Compute);
        let meas_comp = rep.measured_s[Phase::Compute as usize];
        let fold = |rate: f64, sim: f64, meas: f64| -> f64 {
            if sim > 0.0 && meas > 0.0 {
                let ratio = (sim / meas).clamp(1e-3, 1e3);
                rate * (1.0 - alpha) + rate * ratio * alpha
            } else {
                rate
            }
        };
        let link = fold(self.cost.link_gbps, sim_comm, meas_comm);
        let gflops = fold(self.cost.compute_gflops, sim_comp, meas_comp);
        if let Ok(cost) = CostModel::new(link, gflops) {
            self.cost = cost;
        }
        Some(self.cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::ShardedEngine;
    use crate::dispatch::gating::synthetic_gating;
    use crate::dispatch::parallel_build::parallel_build;
    use crate::util::prng::Rng;

    fn workload(l: usize, e: usize, k: usize, d: usize, skew: f64,
                seed: u64) -> StepBatch {
        let mut rng = Rng::new(seed);
        let g = synthetic_gating(&mut rng, l, e, k, skew);
        let disp = parallel_build(&g.topk_ids, l, e, k);
        let x = rng.normal_vec(l * d, 1.0);
        StepBatch::new(disp, x, g.gates).unwrap()
    }

    #[test]
    fn chunk_traffic_sums_to_the_whole_batch_exchange() {
        let batch = workload(96, 8, 2, 10, 0.8, 3);
        let store = ExpertStore::init(8, 10, 14, 5);
        let topo = EpTopology::new(4, 8).unwrap();
        let plan = topo.plan(batch.disp(), 10, 4);
        for chunks in [1usize, 2, 4, 7] {
            let mut eng =
                PipelinedEngine::new(topo.clone(), &store, 4, chunks).unwrap();
            let _ = eng.forward(&batch).unwrap();
            let t = eng.traffic();
            assert_eq!(t.dispatch_bytes, plan.cross_rank_bytes(),
                       "K={chunks}: chunking changed the exchanged bytes");
            assert_eq!(t.cross_rows + t.local_rows, batch.disp().slots() as u64);
            assert_eq!(t.combine_bytes, t.dispatch_bytes);
        }
    }

    #[test]
    fn pipelined_forward_is_bit_identical_to_barrier() {
        let batch = workload(64, 8, 2, 8, 0.6, 9);
        let store = ExpertStore::init(8, 8, 12, 7);
        let topo = EpTopology::new(4, 8).unwrap();
        let mut barrier = ShardedEngine::new(topo.clone(), &store, 4).unwrap();
        let reference = barrier.forward(&batch).unwrap().into_output();
        for chunks in [1usize, 2, 4] {
            let mut eng =
                PipelinedEngine::new(topo.clone(), &store, 4, chunks).unwrap();
            let out = eng.forward(&batch).unwrap().into_output();
            assert_eq!(out, reference, "K={chunks} forward diverged");
        }
    }

    #[test]
    fn oversized_chunk_count_clamps_to_tokens() {
        let batch = workload(6, 4, 2, 6, 0.2, 4);
        let store = ExpertStore::init(4, 6, 8, 2);
        let topo = EpTopology::new(2, 4).unwrap();
        let mut eng = PipelinedEngine::new(topo.clone(), &store, 2, 64).unwrap();
        let mut barrier = ShardedEngine::new(topo, &store, 2).unwrap();
        let a = eng.forward(&batch).unwrap().into_output();
        let b = barrier.forward(&batch).unwrap().into_output();
        assert_eq!(a, b);
        assert_eq!(eng.overlap_report().unwrap().chunks, 6);
    }

    #[test]
    fn constructor_validation() {
        let store = ExpertStore::init(8, 8, 12, 1);
        let topo = EpTopology::new(4, 8).unwrap();
        assert!(PipelinedEngine::new(topo.clone(), &store, 4, 0).is_err());
        let wrong = ExpertStore::init(6, 8, 12, 1);
        assert!(PipelinedEngine::new(topo, &wrong, 4, 2).is_err());
    }

    #[test]
    fn stale_and_foreign_handles_rejected() {
        let batch = workload(24, 4, 2, 6, 0.0, 8);
        let store = ExpertStore::init(4, 6, 8, 3);
        let topo = EpTopology::new(2, 4).unwrap();
        let mut eng = PipelinedEngine::new(topo.clone(), &store, 2, 2).unwrap();
        let d_out = vec![0.1f32; batch.num_tokens() * 6];
        let mut grads = eng.zero_grads();
        let stale = eng.forward(&batch).unwrap();
        let fresh = eng.forward(&batch).unwrap();
        assert!(eng.backward_into(stale, &d_out, &mut grads).is_err());
        eng.backward_into(fresh, &d_out, &mut grads).unwrap();
        let mut other = PipelinedEngine::new(topo, &store, 2, 2).unwrap();
        let foreign = other.forward(&batch).unwrap();
        assert!(eng.backward_into(foreign, &d_out, &mut grads).is_err());
    }
}

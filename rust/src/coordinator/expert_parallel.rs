//! Expert-parallel topology + all-to-all planner (paper §8).
//!
//! Experts are partitioned across R simulated ranks (contiguously or
//! strided, see [`Placement`]); tokens are partitioned contiguously. From
//! a [`DispatchStructures`] the planner derives the all-to-all exchange:
//! which (src, dst) rank pairs move how many routed token activations,
//! total comm bytes, and the load balance.
//!
//! Since the rank-sharded execution engine landed
//! (`coordinator::engine::ShardedEngine`), this planner is the executor's
//! **dry-run mode**: [`EpTopology::plan`] predicts the exchange the
//! engine executes. The engine's byte counts — measured at packed
//! buffers before PR 5, derived from the index-driven
//! `dispatch::RowIndexPlan` since — are asserted equal to
//! [`AllToAllPlan::cross_rank_bytes`] (see `rust/tests/ep_engine.rs`,
//! `rust/tests/row_plan_properties.rs`, and the `ep-bench` subcommand).

use crate::config::ep::Placement;
use crate::dispatch::shard::ExpertAssignment;
use crate::dispatch::structures::DispatchStructures;

/// Static expert-parallel topology.
#[derive(Debug, Clone)]
pub struct EpTopology {
    pub ranks: usize,
    pub num_experts: usize,
    pub placement: Placement,
    /// explicit expert→rank map for data-dependent placements
    /// (`Placement::LoadAware`); `None` for the formulaic ones
    custom: Option<Vec<u32>>,
}

impl EpTopology {
    /// Contiguous placement (MegaBlocks/DeepSpeed default).
    pub fn new(ranks: usize, num_experts: usize) -> Result<EpTopology, String> {
        EpTopology::with_placement(ranks, num_experts, Placement::Contiguous)
    }

    pub fn with_placement(ranks: usize, num_experts: usize,
                          placement: Placement) -> Result<EpTopology, String> {
        if ranks == 0 || num_experts == 0 {
            return Err("ranks and experts must be positive".into());
        }
        if num_experts % ranks != 0 {
            return Err(format!(
                "experts {num_experts} not divisible by ranks {ranks}"
            ));
        }
        if placement == Placement::LoadAware {
            return Err("load-aware placement needs per-expert loads — \
                        use EpTopology::load_aware"
                .into());
        }
        Ok(EpTopology { ranks, num_experts, placement, custom: None })
    }

    /// Load-aware placement: greedily rebalance the expert→rank map from
    /// the previous step's per-expert routed-row loads (the per-expert
    /// refinement of `AllToAllPlan::per_rank_tokens`). Heaviest expert
    /// first onto the least-loaded rank that still has capacity (every
    /// rank keeps exactly E/R experts, so parameter memory stays
    /// balanced); if the greedy pass somehow loses to the contiguous
    /// blocks it falls back to them — the rebalancer is never worse than
    /// the default, which the property suite pins on skewed gatings.
    pub fn load_aware(ranks: usize,
                      per_expert_tokens: &[u64]) -> Result<EpTopology, String> {
        let num_experts = per_expert_tokens.len();
        let base = EpTopology::with_placement(ranks, num_experts, Placement::Contiguous)?;
        let cap = num_experts / ranks;
        let mut order: Vec<usize> = (0..num_experts).collect();
        order.sort_by_key(|&e| (std::cmp::Reverse(per_expert_tokens[e]), e));
        let mut rank_of = vec![0u32; num_experts];
        let mut load = vec![0u64; ranks];
        let mut count = vec![0usize; ranks];
        for &e in &order {
            let r = (0..ranks)
                .filter(|&r| count[r] < cap)
                .min_by_key(|&r| (load[r], r))
                .expect("capacity always leaves an open rank");
            rank_of[e] = r as u32;
            load[r] += per_expert_tokens[e];
            count[r] += 1;
        }
        let greedy_max = load.iter().max().copied().unwrap_or(0);
        let mut cont_load = vec![0u64; ranks];
        for (e, &t) in per_expert_tokens.iter().enumerate() {
            cont_load[base.rank_of_expert(e)] += t;
        }
        let cont_max = cont_load.iter().max().copied().unwrap_or(0);
        let custom = if greedy_max <= cont_max {
            rank_of
        } else {
            base.assignment().rank_of
        };
        Ok(EpTopology {
            ranks,
            num_experts,
            placement: Placement::LoadAware,
            custom: Some(custom),
        })
    }

    /// Owning rank of an expert under the placement policy: contiguous
    /// gives rank r the block [r·E/R, (r+1)·E/R); strided deals experts
    /// round-robin (e mod R) — the layout that spreads "hot" low-id
    /// experts of a skewed router across ranks; load-aware carries the
    /// explicit map its constructor computed.
    pub fn rank_of_expert(&self, e: usize) -> usize {
        if let Some(map) = &self.custom {
            return map[e] as usize;
        }
        match self.placement {
            Placement::Contiguous => e / (self.num_experts / self.ranks),
            Placement::Strided => e % self.ranks,
            Placement::LoadAware => {
                unreachable!("LoadAware topology always carries a custom map")
            }
        }
    }

    /// Contiguous-placement block of rank `r` (kept for the analytic
    /// benches; panics under strided placement — use [`owned_experts`]).
    ///
    /// [`owned_experts`]: EpTopology::owned_experts
    pub fn experts_of_rank(&self, r: usize) -> std::ops::Range<usize> {
        assert_eq!(self.placement, Placement::Contiguous,
                   "experts_of_rank is contiguous-only");
        let per = self.num_experts / self.ranks;
        r * per..(r + 1) * per
    }

    /// Global expert ids owned by rank `r`, ascending, any placement
    /// (delegates to the shard layer's assignment so the two can never
    /// diverge).
    pub fn owned_experts(&self, r: usize) -> Vec<usize> {
        self.assignment().owned_experts(r)
    }

    /// The expert→rank map in the form the dispatch shard layer consumes.
    pub fn assignment(&self) -> ExpertAssignment {
        ExpertAssignment {
            ranks: self.ranks,
            rank_of: (0..self.num_experts)
                .map(|e| self.rank_of_expert(e) as u32)
                .collect(),
        }
    }

    /// Contiguous token partition: token t lives on rank t·R/L.
    pub fn rank_of_token(&self, t: usize, num_tokens: usize) -> usize {
        (t * self.ranks / num_tokens).min(self.ranks - 1)
    }

    /// Plan the all-to-all for one layer step.
    pub fn plan(&self, disp: &DispatchStructures, d_model: usize,
                dtype_bytes: usize) -> AllToAllPlan {
        let r = self.ranks;
        let l = disp.num_tokens;
        let mut matrix = vec![0u64; r * r]; // routed copies src→dst
        let mut per_rank_tokens = vec![0u64; r]; // expert-side load
        for e in 0..disp.num_experts {
            let dst = self.rank_of_expert(e);
            for &tok in disp.expert_tokens(e) {
                let src = self.rank_of_token(tok as usize, l);
                matrix[src * r + dst] += 1;
                per_rank_tokens[dst] += 1;
            }
        }
        let row_bytes = (d_model * dtype_bytes) as u64;
        let cross: u64 = (0..r)
            .flat_map(|s| (0..r).map(move |d| (s, d)))
            .filter(|&(s, d)| s != d)
            .map(|(s, d)| matrix[s * r + d])
            .sum();
        let total: u64 = matrix.iter().sum();
        AllToAllPlan {
            ranks: r,
            matrix,
            per_rank_tokens,
            bytes_per_row: row_bytes,
            cross_rank_rows: cross,
            total_rows: total,
        }
    }
}

/// The planned exchange for one MoE layer (fwd direction; bwd mirrors it).
#[derive(Debug, Clone)]
pub struct AllToAllPlan {
    pub ranks: usize,
    /// routed copies moved src→dst (R×R, row-major)
    pub matrix: Vec<u64>,
    /// routed copies landing on each rank's experts
    pub per_rank_tokens: Vec<u64>,
    pub bytes_per_row: u64,
    pub cross_rank_rows: u64,
    pub total_rows: u64,
}

impl AllToAllPlan {
    /// Routed copies moved src → dst.
    pub fn rows(&self, src: usize, dst: usize) -> u64 {
        self.matrix[src * self.ranks + dst]
    }

    /// Total bytes crossing rank boundaries (one direction).
    pub fn cross_rank_bytes(&self) -> u64 {
        self.cross_rank_rows * self.bytes_per_row
    }

    /// Load imbalance: max over mean per-rank expert load.
    pub fn imbalance(&self) -> f64 {
        let max = *self.per_rank_tokens.iter().max().unwrap_or(&0) as f64;
        let mean = self.total_rows as f64 / self.ranks as f64;
        if mean == 0.0 { 0.0 } else { max / mean }
    }

    /// Tokens that a capacity-limited router (cap = γ·mean) would drop —
    /// the quality/throughput trade the paper's §2.1 discusses; MoEBlaze
    /// is dropless so its plan always processes all rows.
    pub fn dropped_under_capacity(&self, gamma: f64) -> u64 {
        let mean = self.total_rows as f64 / self.ranks as f64;
        let cap = (gamma * mean).floor() as u64;
        self.per_rank_tokens
            .iter()
            .map(|&t| t.saturating_sub(cap))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ep::Placement;
    use crate::dispatch::gating::synthetic_gating;
    use crate::dispatch::parallel_build::parallel_build;
    use crate::util::prng::Rng;

    fn plan(l: usize, e: usize, k: usize, ranks: usize, skew: f64) -> AllToAllPlan {
        let mut rng = Rng::new(11);
        let g = synthetic_gating(&mut rng, l, e, k, skew);
        let d = parallel_build(&g.topk_ids, l, e, k);
        EpTopology::new(ranks, e).unwrap().plan(&d, 64, 2)
    }

    #[test]
    fn conservation() {
        let p = plan(256, 16, 2, 4, 0.0);
        assert_eq!(p.total_rows, 512);
        assert_eq!(p.per_rank_tokens.iter().sum::<u64>(), 512);
        // matrix row/col sums consistent
        let col_sums: u64 = p.matrix.iter().sum();
        assert_eq!(col_sums, 512);
    }

    #[test]
    fn balanced_routing_low_imbalance() {
        let p = plan(4096, 16, 2, 4, 0.0);
        assert!(p.imbalance() < 1.2, "{}", p.imbalance());
        assert_eq!(p.dropped_under_capacity(1.5), 0);
    }

    #[test]
    fn skewed_routing_drops_under_capacity() {
        let p = plan(4096, 16, 2, 4, 2.0);
        assert!(p.imbalance() > 1.5, "{}", p.imbalance());
        assert!(p.dropped_under_capacity(1.0) > 0);
    }

    #[test]
    fn single_rank_has_no_cross_traffic() {
        let p = plan(128, 8, 2, 1, 1.0);
        assert_eq!(p.cross_rank_bytes(), 0);
        assert_eq!(p.imbalance(), 1.0);
    }

    #[test]
    fn topology_validation() {
        assert!(EpTopology::new(3, 16).is_err());
        assert!(EpTopology::new(0, 16).is_err());
        let t = EpTopology::new(4, 16).unwrap();
        assert_eq!(t.rank_of_expert(0), 0);
        assert_eq!(t.rank_of_expert(15), 3);
        assert_eq!(t.experts_of_rank(1), 4..8);
        assert_eq!(t.owned_experts(1), vec![4, 5, 6, 7]);
    }

    #[test]
    fn strided_placement_deals_round_robin() {
        let t = EpTopology::with_placement(4, 16, Placement::Strided).unwrap();
        assert_eq!(t.rank_of_expert(0), 0);
        assert_eq!(t.rank_of_expert(5), 1);
        assert_eq!(t.owned_experts(2), vec![2, 6, 10, 14]);
        let a = t.assignment();
        assert_eq!(a.ranks, 4);
        assert_eq!(a.rank_of[7], 3);
    }

    #[test]
    fn load_aware_never_exceeds_contiguous_max_load() {
        // property: on skewed gate distributions the greedy rebalance's
        // most-loaded rank carries no more rows than contiguous blocks'
        for seed in 0..40u64 {
            let mut rng = Rng::new(seed);
            let skew = 0.5 + (seed % 5) as f64 * 0.5;
            let (l, e, k, ranks) = (512, 16, 2, 4);
            let g = synthetic_gating(&mut rng, l, e, k, skew);
            let d = parallel_build(&g.topk_ids, l, e, k);
            let loads: Vec<u64> =
                (0..e).map(|ex| d.expert_tokens(ex).len() as u64).collect();
            let aware = EpTopology::load_aware(ranks, &loads).unwrap();
            let cont = EpTopology::new(ranks, e).unwrap();
            let aware_max = *aware.plan(&d, 64, 2).per_rank_tokens.iter().max().unwrap();
            let cont_max = *cont.plan(&d, 64, 2).per_rank_tokens.iter().max().unwrap();
            assert!(aware_max <= cont_max,
                    "seed {seed} skew {skew}: load-aware max {aware_max} > \
                     contiguous {cont_max}");
        }
    }

    #[test]
    fn load_aware_keeps_balanced_expert_counts() {
        let loads = vec![100u64, 1, 1, 1, 90, 1, 1, 80];
        let t = EpTopology::load_aware(4, &loads).unwrap();
        assert_eq!(t.placement, Placement::LoadAware);
        let a = t.assignment();
        a.validate().unwrap();
        for r in 0..4 {
            assert_eq!(a.owned_experts(r).len(), 2, "rank {r} capacity violated");
        }
        // the three hot experts land on three different ranks
        let hot: Vec<usize> =
            [0, 4, 7].iter().map(|&e| t.rank_of_expert(e)).collect();
        assert_eq!(hot.iter().collect::<std::collections::BTreeSet<_>>().len(), 3);
        // constructor validation mirrors with_placement
        assert!(EpTopology::load_aware(4, &[1, 2, 3]).is_err());
        assert!(EpTopology::with_placement(4, 16, Placement::LoadAware).is_err());
    }

    #[test]
    fn strided_placement_spreads_skewed_load() {
        // skewed routing concentrates on low expert ids; strided placement
        // must balance it strictly better than contiguous blocks
        let mut rng = Rng::new(5);
        let g = synthetic_gating(&mut rng, 4096, 16, 2, 2.0);
        let d = parallel_build(&g.topk_ids, 4096, 16, 2);
        let cont = EpTopology::new(4, 16).unwrap().plan(&d, 64, 2);
        let strided = EpTopology::with_placement(4, 16, Placement::Strided)
            .unwrap()
            .plan(&d, 64, 2);
        assert!(strided.imbalance() < cont.imbalance(),
                "strided {} vs contiguous {}", strided.imbalance(),
                cont.imbalance());
    }
}

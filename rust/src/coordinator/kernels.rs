//! Tile-blocked expert kernels — the compute half of the
//! zero-materialization hot path.
//!
//! The row kernels in `coordinator::engine` (`expert_forward`,
//! `expert_backward_row`) stream every weight and gradient matrix from
//! memory once **per routed row** and run one scalar accumulation chain
//! at a time. These blocked kernels process each expert's routed-row
//! segment in tiles of `tile_rows` rows instead:
//!
//! * routed inputs are gathered straight from the caller-owned batch
//!   activations into a transposed `(d × T)` staging tile (`xt[j][t]`),
//!   so the innermost loops run over `T` independent rows with
//!   unit-stride access — `T` independent accumulation chains the
//!   compiler can vectorize, where the row kernels had one serial chain;
//! * each weight/gradient matrix row is streamed once per **tile**
//!   rather than once per row — a `T`-fold cut in the memory traffic
//!   that dominates the backward pass (the `∂W` matrices are read and
//!   written per row in the row kernels);
//! * the `∂x` pass reads a transposed-`w1` layout (`(d × h)`, built once
//!   per expert segment per step by [`transpose_w1`]) so its inner
//!   `j`-chains are unit-stride too.
//!
//! # Bit-identity contract
//!
//! Every scalar output element accumulates **in exactly the row
//! kernels' op order**, so blocked results are bit-identical to the
//! per-row path for any tile size (pinned by the unit tests below and
//! the engine matrices):
//!
//! * `pre[t][i]` starts from `b1[i]` and adds `w1[i][j]·x[t][j]` for
//!   `j` ascending — `recompute_hidden`'s chain;
//! * `y[t][i]` starts from `b2[i]` and adds over `j` ascending in `h` —
//!   `expert_forward`'s chain;
//! * `dz[t][j]` accumulates `dy[t][i]·w2[i][j]` for `i` ascending from
//!   zero, `dx[t][c]` accumulates `da[t][j]·w1[j][c]` for `j` ascending
//!   from zero — `expert_backward_row`'s chains;
//! * every gradient element (`∂W1`, `∂b1`, `∂W2`, `∂b2`) extends its
//!   running value one routed row at a time, rows ascending within the
//!   tile and tiles ascending within the segment — the exact row order
//!   of the per-row walk. Crucially there is **no** per-tile partial sum
//!   that gets added afterwards: `g += c₀; g += c₁; …` is performed
//!   element-wise in row order, never `g += (c₀ + c₁)`.
//!
//! ## SwiGLU gate chains
//!
//! Gated (SwiGLU) experts run both first-layer GEMMs in the **same**
//! staging-tile pass — one gather, `w1` and `w3` each stream the tile
//! once — and keep the same per-element discipline against the
//! `expert_forward_swiglu` / `expert_backward_row_swiglu` row oracles:
//!
//! * `pre[t][i]` is the SiLU chain above, unchanged; `gate[t][i]`
//!   accumulates `w3[i][j]·x[t][j]` for `j` ascending **from zero** (no
//!   gate bias); the hidden is `z[t][i] = silu(pre[t][i])·gate[t][i]`,
//!   evaluated exactly in that order;
//! * the output projection and its `∂W2`/`∂b2`/`dz` chains are the SiLU
//!   chains verbatim (they see only `z`);
//! * `da[t][j] = (dz[t][j]·gate[t][j])·σ·(1 + pre·(1 − σ))` and
//!   `dg[t][j] = dz[t][j]·silu(pre[t][j])`, each with that exact
//!   expression shape (`σ = 1/(1 + exp(−pre))`, `silu` the shared
//!   helper);
//! * `∂b1`/`∂W1` extend from `da` and `∂W3` from `dg`, per element in
//!   row order, `∂W1`'s row before `∂W3`'s row for each `j`;
//! * `dx[t][c]` accumulates `da[t][j]·w1[j][c]` for `j` ascending from
//!   zero and **then** `dg[t][j]·w3[j][c]` for `j` ascending — two
//!   back-to-back chains through the transposed layouts, never
//!   interleaved.
//!
//! Rust never contracts `a*b + c` into an FMA or reassociates float
//! ops, so matching the op order per element is sufficient for bitwise
//! equality.

use std::time::Instant;

use super::params::ExpertParams;

/// Default routed-row tile (`[ep] tile_rows`): big enough to amortize
/// one weight-matrix stream across many rows and fill SIMD lanes, small
/// enough that the staging tiles (`(d + h) × T` floats twice over) stay
/// cache-resident for the bench shapes.
pub const DEFAULT_TILE_ROWS: usize = 16;

/// Candidate tiles the `tile_rows = 0` (auto) first-step probe sweeps,
/// ascending. Ascending order + smallest-wins tie-break keep the pick a
/// pure function of the measured times.
pub const AUTOTUNE_TILE_CANDIDATES: [usize; 5] = [4, 8, 16, 32, 64];

/// Pick the fastest tile from `candidates` given per-candidate measured
/// seconds. Candidates are measured in the given (ascending) order and
/// ties go to the earliest candidate, so the choice is a deterministic
/// function of the measurements — the autotune-determinism pin.
pub fn pick_tile(candidates: &[usize], mut measure: impl FnMut(usize) -> f64) -> usize {
    let mut best = candidates.first().copied().unwrap_or(DEFAULT_TILE_ROWS);
    let mut best_t = f64::INFINITY;
    for &c in candidates {
        let t = measure(c);
        if t < best_t {
            best_t = t;
            best = c;
        }
    }
    best
}

#[inline]
pub(crate) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// Measured host wall-clock of one engine phase pair, accumulated by the
/// segment drivers: `gather_s` is staging (the index-driven rump of the
/// old exchange packing), `compute_s` the blocked kernels themselves.
/// Feeds `TimelineBuilder::record_measured` and the calibration hook.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct KernelTimers {
    pub(crate) gather_s: f64,
    pub(crate) compute_s: f64,
}

impl KernelTimers {
    pub(crate) fn add(&mut self, other: KernelTimers) {
        self.gather_s += other.gather_s;
        self.compute_s += other.compute_s;
    }
}

/// Per-worker staging tiles, allocated once per rank per step and reused
/// across every segment and tile — the "one staging tile, not a whole
/// buffer" object the memory model accounts as comm residency.
pub(crate) struct KernelScratch {
    tile: usize,
    /// (d × T) transposed routed inputs
    xt: Vec<f32>,
    /// (d × T) transposed expert outputs
    yt: Vec<f32>,
    /// (d × T) transposed gated output gradients
    dyt: Vec<f32>,
    /// (d × T) transposed input gradients
    dxt: Vec<f32>,
    /// (h × T) transposed hidden pre-activations
    pre: Vec<f32>,
    /// (h × T) transposed hidden activations
    act: Vec<f32>,
    /// (h × T) transposed ∂act
    dzt: Vec<f32>,
    /// (h × T) transposed ∂pre
    dat: Vec<f32>,
    /// (h × T) transposed SwiGLU gate values (`w3·x`) — the "one extra
    /// h-row per staging tile" of gated residency
    gt: Vec<f32>,
    /// (h × T) transposed ∂gate
    dgt: Vec<f32>,
    /// transposed w1 (d × h), rebuilt once per expert segment when the
    /// ∂x pass needs it
    w1t: Vec<f32>,
    /// transposed w3 (d × h), rebuilt alongside `w1t` for gated ∂x
    w3t: Vec<f32>,
}

impl KernelScratch {
    pub(crate) fn new(d: usize, h: usize, tile_rows: usize) -> KernelScratch {
        let t = tile_rows.max(1);
        KernelScratch {
            tile: t,
            xt: vec![0.0; d * t],
            yt: vec![0.0; d * t],
            dyt: vec![0.0; d * t],
            dxt: vec![0.0; d * t],
            pre: vec![0.0; h * t],
            act: vec![0.0; h * t],
            dzt: vec![0.0; h * t],
            dat: vec![0.0; h * t],
            gt: vec![0.0; h * t],
            dgt: vec![0.0; h * t],
            w1t: Vec::new(),
            w3t: Vec::new(),
        }
    }
}

/// Transposed-`w1` layout: `w1t[c·h + j] = w1[j·d + c]`, so the ∂x
/// pass's inner `j`-chains read unit-stride. Built once per expert
/// segment per step (the segment is visited once per backward), then
/// reused by every tile.
pub(crate) fn transpose_w1(w1: &[f32], d: usize, h: usize, out: &mut Vec<f32>) {
    out.clear();
    out.resize(d * h, 0.0);
    for j in 0..h {
        let row = &w1[j * d..(j + 1) * d];
        for c in 0..d {
            out[c * h + j] = row[c];
        }
    }
}

/// Mutable saved-hidden buffers the forward scatters into (the
/// `SaveAll` residuals): `pre`/`act` always, `gate` only for gated
/// (SwiGLU) experts.
pub(crate) struct SavedHiddenMut<'a> {
    pub(crate) pre: &'a mut [f32],
    pub(crate) act: &'a mut [f32],
    pub(crate) gate: Option<&'a mut [f32]>,
}

/// Saved-hidden buffers the backward reads (mirror of
/// [`SavedHiddenMut`]).
#[derive(Clone, Copy)]
pub(crate) struct SavedHiddenRef<'a> {
    pub(crate) pre: &'a [f32],
    pub(crate) act: &'a [f32],
    pub(crate) gate: Option<&'a [f32]>,
}

/// Where a tile's routed-input rows come from.
pub(crate) enum RowsSrc<'a> {
    /// packed per-local-slot rows (the policy-saved `xs`): slot `ls`
    /// lives at `data[ls·d ..]`
    Packed(&'a [f32]),
    /// gather straight from the caller's activations via the index plan
    /// (`RecomputeAll`'s backward re-gather — indices, not rows)
    Tokens(&'a [f32]),
}

/// Gather one tile of routed-input rows into the transposed staging
/// tile, optionally saving the untransposed rows (the `SaveInputs` /
/// `SaveAll` residuals) on the way through.
#[allow(clippy::too_many_arguments)]
fn gather_x_tile(src: &RowsSrc, d: usize, tile: usize, lo: usize, rows: usize,
                 tokens: &[u32], token_base: usize, xt: &mut [f32],
                 mut saved_xs: Option<&mut [f32]>) {
    for r in 0..rows {
        let ls = lo + r;
        let row = match src {
            RowsSrc::Packed(data) => &data[ls * d..(ls + 1) * d],
            RowsSrc::Tokens(x) => {
                let tok = token_base + tokens[ls] as usize;
                &x[tok * d..(tok + 1) * d]
            }
        };
        for j in 0..d {
            xt[j * tile + r] = row[j];
        }
        if let Some(xs) = saved_xs.as_deref_mut() {
            xs[ls * d..(ls + 1) * d].copy_from_slice(row);
        }
    }
}

/// Gather one tile of gated output-gradient rows (`dy = gate · d_out`)
/// into the transposed staging tile — the backward mirror of the
/// dispatch gather, replacing the packed gradient exchange.
#[allow(clippy::too_many_arguments)]
fn gather_dy_tile(d_out: &[f32], gates: &[f32], d: usize, tile: usize, lo: usize,
                  rows: usize, tokens: &[u32], token_base: usize,
                  gate_slots: &[u32], gate_base: usize, dyt: &mut [f32]) {
    for r in 0..rows {
        let ls = lo + r;
        let tok = token_base + tokens[ls] as usize;
        let g = gates[gate_base + gate_slots[ls] as usize];
        let row = &d_out[tok * d..(tok + 1) * d];
        for j in 0..d {
            dyt[j * tile + r] = g * row[j];
        }
    }
}

/// Gather one tile of saved hidden rows (packed per local slot) into the
/// transposed tiles — a pure copy, values untouched. Gated experts carry
/// a third saved buffer (the `w3·x` gate values).
#[allow(clippy::too_many_arguments)]
fn gather_hidden_tile(pre_s: &[f32], act_s: &[f32], h: usize, tile: usize,
                      lo: usize, rows: usize, pre_t: &mut [f32],
                      act_t: &mut [f32], gate: Option<(&[f32], &mut [f32])>) {
    for r in 0..rows {
        let ls = lo + r;
        for i in 0..h {
            pre_t[i * tile + r] = pre_s[ls * h + i];
            act_t[i * tile + r] = act_s[ls * h + i];
        }
    }
    if let Some((gate_s, gate_t)) = gate {
        for r in 0..rows {
            let ls = lo + r;
            for i in 0..h {
                gate_t[i * tile + r] = gate_s[ls * h + i];
            }
        }
    }
}

/// Scatter a transposed (width × T) tile back into packed per-local-slot
/// rows.
fn scatter_tile(src_t: &[f32], width: usize, tile: usize, lo: usize, rows: usize,
                out: &mut [f32]) {
    for r in 0..rows {
        let ls = lo + r;
        let row = &mut out[ls * width..(ls + 1) * width];
        for j in 0..width {
            row[j] = src_t[j * tile + r];
        }
    }
}

/// Hidden pass over one tile: `pre[t][i] = b1[i] + Σ_j w1[i][j]·x[t][j]`
/// (`j` ascending — `recompute_hidden`'s chain), `act = silu(pre)`.
fn hidden_tile(p: &ExpertParams, d: usize, h: usize, tile: usize, rows: usize,
               xt: &[f32], pre_t: &mut [f32], act_t: &mut [f32]) {
    for i in 0..h {
        let wrow = &p.w1[i * d..(i + 1) * d];
        let b = p.b1[i];
        let prow = &mut pre_t[i * tile..i * tile + rows];
        for v in prow.iter_mut() {
            *v = b;
        }
        for j in 0..d {
            let w = wrow[j];
            let xr = &xt[j * tile..j * tile + rows];
            let prow = &mut pre_t[i * tile..i * tile + rows];
            for t in 0..rows {
                prow[t] += w * xr[t];
            }
        }
        for t in 0..rows {
            act_t[i * tile + t] = silu(pre_t[i * tile + t]);
        }
    }
}

/// Gated (SwiGLU) hidden pass over one tile: both first-layer GEMMs run
/// in the same sweep — each `xt` slice `j` is read once and feeds
/// `pre[t][i] += w1[i][j]·x` and `gate[t][i] += w3[i][j]·x` (`pre` from
/// `b1[i]`, `gate` from zero, `j` ascending), then
/// `z[t][i] = silu(pre)·gate`.
fn hidden_tile_swiglu(p: &ExpertParams, d: usize, h: usize, tile: usize,
                      rows: usize, xt: &[f32], pre_t: &mut [f32],
                      act_t: &mut [f32], gate_t: &mut [f32]) {
    for i in 0..h {
        let wrow = &p.w1[i * d..(i + 1) * d];
        let vrow = &p.w3[i * d..(i + 1) * d];
        let b = p.b1[i];
        for v in pre_t[i * tile..i * tile + rows].iter_mut() {
            *v = b;
        }
        for v in gate_t[i * tile..i * tile + rows].iter_mut() {
            *v = 0.0;
        }
        for j in 0..d {
            let w = wrow[j];
            let wg = vrow[j];
            let xr = &xt[j * tile..j * tile + rows];
            let prow = &mut pre_t[i * tile..i * tile + rows];
            for t in 0..rows {
                prow[t] += w * xr[t];
            }
            let grow = &mut gate_t[i * tile..i * tile + rows];
            for t in 0..rows {
                grow[t] += wg * xr[t];
            }
        }
        for t in 0..rows {
            act_t[i * tile + t] = silu(pre_t[i * tile + t]) * gate_t[i * tile + t];
        }
    }
}

/// Output projection over one tile: `y[t][i] = b2[i] + Σ_j w2[i][j]·act[t][j]`
/// (`j` ascending in `h` — `expert_forward`'s chain).
fn project_tile(p: &ExpertParams, d: usize, h: usize, tile: usize, rows: usize,
                act_t: &[f32], yt: &mut [f32]) {
    for i in 0..d {
        let wrow = &p.w2[i * h..(i + 1) * h];
        let b = p.b2[i];
        let yrow = &mut yt[i * tile..i * tile + rows];
        for v in yrow.iter_mut() {
            *v = b;
        }
        for j in 0..h {
            let w = wrow[j];
            let ar = &act_t[j * tile..j * tile + rows];
            let yrow = &mut yt[i * tile..i * tile + rows];
            for t in 0..rows {
                yrow[t] += w * ar[t];
            }
        }
    }
}

/// Backward over one tile, extending `g` element-wise in row order and
/// (optionally) producing the transposed ∂x tile. Chains mirror
/// `expert_backward_row` exactly — see the module docs.
#[allow(clippy::too_many_arguments)]
fn backward_tile(p: &ExpertParams, g: &mut ExpertParams, d: usize, h: usize,
                 tile: usize, rows: usize, xt: &[f32], dyt: &[f32],
                 pre_t: &[f32], act_t: &[f32], dzt: &mut [f32],
                 dat: &mut [f32], w1t: Option<&[f32]>,
                 dxt: Option<&mut [f32]>) {
    // dz[t][j] = Σ_i dy[t][i]·w2[i][j], i ascending from zero; W2/b2
    // grads extend per element in row order
    for j in 0..h {
        for v in dzt[j * tile..j * tile + rows].iter_mut() {
            *v = 0.0;
        }
    }
    for i in 0..d {
        let dyr = &dyt[i * tile..i * tile + rows];
        let mut acc = g.b2[i];
        for t in 0..rows {
            acc += dyr[t];
        }
        g.b2[i] = acc;
        let wrow = &p.w2[i * h..(i + 1) * h];
        let grow = &mut g.w2[i * h..(i + 1) * h];
        for j in 0..h {
            let ar = &act_t[j * tile..j * tile + rows];
            let mut acc = grow[j];
            for t in 0..rows {
                acc += dyr[t] * ar[t];
            }
            grow[j] = acc;
            let w = wrow[j];
            let dzr = &mut dzt[j * tile..j * tile + rows];
            for t in 0..rows {
                dzr[t] += dyr[t] * w;
            }
        }
    }
    // through silu, then W1/b1 grads — same element chains as the row
    // kernel: da = dz·σ·(1 + pre·(1 − σ)) evaluated with the identical
    // expression shape
    for j in 0..h {
        let dzr = &dzt[j * tile..j * tile + rows];
        let prer = &pre_t[j * tile..j * tile + rows];
        {
            let dar = &mut dat[j * tile..j * tile + rows];
            for t in 0..rows {
                let sig = 1.0 / (1.0 + (-prer[t]).exp());
                dar[t] = dzr[t] * sig * (1.0 + prer[t] * (1.0 - sig));
            }
        }
        let dar = &dat[j * tile..j * tile + rows];
        let mut acc = g.b1[j];
        for t in 0..rows {
            acc += dar[t];
        }
        g.b1[j] = acc;
        let grow = &mut g.w1[j * d..(j + 1) * d];
        for c in 0..d {
            let xr = &xt[c * tile..c * tile + rows];
            let mut acc = grow[c];
            for t in 0..rows {
                acc += dar[t] * xr[t];
            }
            grow[c] = acc;
        }
    }
    // ∂x[t][c] = Σ_j da[t][j]·w1[j][c], j ascending from zero, read
    // through the transposed-w1 layout for unit stride
    if let Some(dxt) = dxt {
        let w1t = w1t.expect("dx pass needs the transposed w1");
        for c in 0..d {
            let wcol = &w1t[c * h..(c + 1) * h];
            for v in dxt[c * tile..c * tile + rows].iter_mut() {
                *v = 0.0;
            }
            for j in 0..h {
                let w = wcol[j];
                let dar = &dat[j * tile..j * tile + rows];
                let dxr = &mut dxt[c * tile..c * tile + rows];
                for t in 0..rows {
                    dxr[t] += dar[t] * w;
                }
            }
        }
    }
}

/// Gated (SwiGLU) backward over one tile. The `dz`/`∂W2`/`∂b2` chains
/// are [`backward_tile`]'s verbatim (they see only `z`); the gate
/// product then splits `dz` into `da` (through SiLU') and `dg`
/// (`dz·silu(pre)`), extends `∂b1`/`∂W1` from `da` and `∂W3` from `dg`
/// per element in row order, and runs the two ∂x chains back-to-back
/// (`w1ᵀ` then `w3ᵀ`). See the module docs for the exact op order.
#[allow(clippy::too_many_arguments)]
fn backward_tile_swiglu(p: &ExpertParams, g: &mut ExpertParams, d: usize,
                        h: usize, tile: usize, rows: usize, xt: &[f32],
                        dyt: &[f32], pre_t: &[f32], act_t: &[f32],
                        gate_t: &[f32], dzt: &mut [f32], dat: &mut [f32],
                        dgt: &mut [f32], w1t: Option<&[f32]>,
                        w3t: Option<&[f32]>, dxt: Option<&mut [f32]>) {
    // dz + ∂W2/∂b2 — identical to the ungated tile (act_t holds z)
    for j in 0..h {
        for v in dzt[j * tile..j * tile + rows].iter_mut() {
            *v = 0.0;
        }
    }
    for i in 0..d {
        let dyr = &dyt[i * tile..i * tile + rows];
        let mut acc = g.b2[i];
        for t in 0..rows {
            acc += dyr[t];
        }
        g.b2[i] = acc;
        let wrow = &p.w2[i * h..(i + 1) * h];
        let grow = &mut g.w2[i * h..(i + 1) * h];
        for j in 0..h {
            let ar = &act_t[j * tile..j * tile + rows];
            let mut acc = grow[j];
            for t in 0..rows {
                acc += dyr[t] * ar[t];
            }
            grow[j] = acc;
            let w = wrow[j];
            let dzr = &mut dzt[j * tile..j * tile + rows];
            for t in 0..rows {
                dzr[t] += dyr[t] * w;
            }
        }
    }
    // split through the gate product: da via SiLU', dg via silu(pre);
    // then ∂b1/∂W1 from da and ∂W3 from dg, per element in row order
    for j in 0..h {
        let dzr = &dzt[j * tile..j * tile + rows];
        let prer = &pre_t[j * tile..j * tile + rows];
        let gr = &gate_t[j * tile..j * tile + rows];
        {
            let dar = &mut dat[j * tile..j * tile + rows];
            let dgr = &mut dgt[j * tile..j * tile + rows];
            for t in 0..rows {
                let sig = 1.0 / (1.0 + (-prer[t]).exp());
                dar[t] = (dzr[t] * gr[t]) * sig * (1.0 + prer[t] * (1.0 - sig));
                dgr[t] = dzr[t] * silu(prer[t]);
            }
        }
        let dar = &dat[j * tile..j * tile + rows];
        let dgr = &dgt[j * tile..j * tile + rows];
        let mut acc = g.b1[j];
        for t in 0..rows {
            acc += dar[t];
        }
        g.b1[j] = acc;
        let grow = &mut g.w1[j * d..(j + 1) * d];
        for c in 0..d {
            let xr = &xt[c * tile..c * tile + rows];
            let mut acc = grow[c];
            for t in 0..rows {
                acc += dar[t] * xr[t];
            }
            grow[c] = acc;
        }
        let grow3 = &mut g.w3[j * d..(j + 1) * d];
        for c in 0..d {
            let xr = &xt[c * tile..c * tile + rows];
            let mut acc = grow3[c];
            for t in 0..rows {
                acc += dgr[t] * xr[t];
            }
            grow3[c] = acc;
        }
    }
    // ∂x: the w1ᵀ·da chain first, then the w3ᵀ·dg chain — two full
    // j-ascending sweeps, never interleaved
    if let Some(dxt) = dxt {
        let w1t = w1t.expect("dx pass needs the transposed w1");
        let w3t = w3t.expect("gated dx pass needs the transposed w3");
        for c in 0..d {
            let wcol = &w1t[c * h..(c + 1) * h];
            for v in dxt[c * tile..c * tile + rows].iter_mut() {
                *v = 0.0;
            }
            for j in 0..h {
                let w = wcol[j];
                let dar = &dat[j * tile..j * tile + rows];
                let dxr = &mut dxt[c * tile..c * tile + rows];
                for t in 0..rows {
                    dxr[t] += dar[t] * w;
                }
            }
            let wcol3 = &w3t[c * h..(c + 1) * h];
            for j in 0..h {
                let w = wcol3[j];
                let dgr = &dgt[j * tile..j * tile + rows];
                let dxr = &mut dxt[c * tile..c * tile + rows];
                for t in 0..rows {
                    dxr[t] += dgr[t] * w;
                }
            }
        }
    }
}

/// Forward one expert's routed-row segment `[lo, hi)` in tiles: gather
/// rows straight from the caller's activations (`tokens` + `token_base`
/// index into `x`), run the blocked FFN, scatter outputs into `ys`, and
/// save what the checkpoint policy asks for. With `timers` set, gather
/// time lands in `gather_s` (the staging rump of the old exchange) and
/// kernel time in `compute_s`; `None` skips the per-tile clock reads
/// entirely — engines without a timeline pay nothing for calibration
/// they never read.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_segment(p: &ExpertParams, d: usize, h: usize, lo: usize,
                              hi: usize, x: &[f32], tokens: &[u32],
                              token_base: usize, ys: &mut [f32],
                              mut saved_xs: Option<&mut [f32]>,
                              mut saved_hidden: Option<SavedHiddenMut<'_>>,
                              scratch: &mut KernelScratch,
                              mut timers: Option<&mut KernelTimers>) {
    let tile = scratch.tile;
    let gated = p.gated();
    let src = RowsSrc::Tokens(x);
    let mut t0 = lo;
    while t0 < hi {
        let rows = tile.min(hi - t0);
        let g0 = timers.is_some().then(Instant::now);
        gather_x_tile(&src, d, tile, t0, rows, tokens, token_base,
                      &mut scratch.xt, saved_xs.as_deref_mut());
        let c0 = if let (Some(tm), Some(g0)) = (timers.as_deref_mut(), g0) {
            tm.gather_s += g0.elapsed().as_secs_f64();
            Some(Instant::now())
        } else {
            None
        };
        if gated {
            hidden_tile_swiglu(p, d, h, tile, rows, &scratch.xt,
                               &mut scratch.pre, &mut scratch.act,
                               &mut scratch.gt);
        } else {
            hidden_tile(p, d, h, tile, rows, &scratch.xt, &mut scratch.pre,
                        &mut scratch.act);
        }
        project_tile(p, d, h, tile, rows, &scratch.act, &mut scratch.yt);
        scatter_tile(&scratch.yt, d, tile, t0, rows, ys);
        if let Some(saved) = saved_hidden.as_mut() {
            scatter_tile(&scratch.pre, h, tile, t0, rows, saved.pre);
            scatter_tile(&scratch.act, h, tile, t0, rows, saved.act);
            if let Some(gate_s) = saved.gate.as_deref_mut() {
                scatter_tile(&scratch.gt, h, tile, t0, rows, gate_s);
            }
        }
        if let (Some(tm), Some(c0)) = (timers.as_deref_mut(), c0) {
            tm.compute_s += c0.elapsed().as_secs_f64();
        }
        t0 += rows;
    }
}

/// Backward one expert's routed-row segment `[lo, hi)` in tiles:
/// gated-gradient rows and routed inputs are gathered directly (no
/// packed gradient exchange, no re-gather buffer), hidden rows come from
/// the saved tensors or the blocked recompute, parameter gradients
/// extend `g` in exact row order, and per-slot ∂x rows land in `dxs`
/// when requested. The transposed-`w1` layout is rebuilt once per call
/// (= once per expert segment per step). `timers: None` skips every
/// per-tile clock read (see [`forward_segment`]).
#[allow(clippy::too_many_arguments)]
pub(crate) fn backward_segment(p: &ExpertParams, g: &mut ExpertParams, d: usize,
                               h: usize, lo: usize, hi: usize, xsrc: &RowsSrc,
                               tokens: &[u32], token_base: usize,
                               gate_slots: &[u32], gate_base: usize,
                               d_out: &[f32], gates: &[f32],
                               saved_hidden: Option<SavedHiddenRef<'_>>,
                               mut dxs: Option<&mut [f32]>,
                               scratch: &mut KernelScratch,
                               mut timers: Option<&mut KernelTimers>) {
    let tile = scratch.tile;
    let gated = p.gated();
    let want_dx = dxs.is_some();
    if want_dx {
        let mut w1t = std::mem::take(&mut scratch.w1t);
        transpose_w1(&p.w1, d, h, &mut w1t);
        scratch.w1t = w1t;
        if gated {
            let mut w3t = std::mem::take(&mut scratch.w3t);
            transpose_w1(&p.w3, d, h, &mut w3t);
            scratch.w3t = w3t;
        }
    }
    let mut t0 = lo;
    while t0 < hi {
        let rows = tile.min(hi - t0);
        let g0 = timers.is_some().then(Instant::now);
        gather_x_tile(xsrc, d, tile, t0, rows, tokens, token_base,
                      &mut scratch.xt, None);
        gather_dy_tile(d_out, gates, d, tile, t0, rows, tokens, token_base,
                       gate_slots, gate_base, &mut scratch.dyt);
        let c0 = if let (Some(tm), Some(g0)) = (timers.as_deref_mut(), g0) {
            tm.gather_s += g0.elapsed().as_secs_f64();
            Some(Instant::now())
        } else {
            None
        };
        match saved_hidden {
            Some(saved) => {
                gather_hidden_tile(
                    saved.pre, saved.act, h, tile, t0, rows, &mut scratch.pre,
                    &mut scratch.act,
                    saved.gate.map(|gs| (gs, &mut scratch.gt[..])),
                );
                // a saving policy on a gated expert must have saved the
                // gate buffer — recompute it if an ungated-era saver
                // dropped it (defensive; the engines always save it)
                if gated && saved.gate.is_none() {
                    hidden_tile_swiglu(p, d, h, tile, rows, &scratch.xt,
                                       &mut scratch.pre, &mut scratch.act,
                                       &mut scratch.gt);
                }
            }
            None => {
                if gated {
                    hidden_tile_swiglu(p, d, h, tile, rows, &scratch.xt,
                                       &mut scratch.pre, &mut scratch.act,
                                       &mut scratch.gt);
                } else {
                    hidden_tile(p, d, h, tile, rows, &scratch.xt,
                                &mut scratch.pre, &mut scratch.act);
                }
            }
        }
        if gated {
            backward_tile_swiglu(
                p, g, d, h, tile, rows, &scratch.xt, &scratch.dyt,
                &scratch.pre, &scratch.act, &scratch.gt, &mut scratch.dzt,
                &mut scratch.dat, &mut scratch.dgt,
                if want_dx { Some(&scratch.w1t) } else { None },
                if want_dx { Some(&scratch.w3t) } else { None },
                if want_dx { Some(&mut scratch.dxt) } else { None },
            );
        } else {
            backward_tile(p, g, d, h, tile, rows, &scratch.xt, &scratch.dyt,
                          &scratch.pre, &scratch.act, &mut scratch.dzt,
                          &mut scratch.dat,
                          if want_dx { Some(&scratch.w1t) } else { None },
                          if want_dx { Some(&mut scratch.dxt) } else { None });
        }
        if let Some(dxs) = dxs.as_deref_mut() {
            scatter_tile(&scratch.dxt, d, tile, t0, rows, dxs);
        }
        if let (Some(tm), Some(c0)) = (timers.as_deref_mut(), c0) {
            tm.compute_s += c0.elapsed().as_secs_f64();
        }
        t0 += rows;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{expert_backward_row, expert_forward,
                                     expert_forward_saving};
    use crate::util::prng::Rng;

    fn params(d: usize, h: usize, seed: u64) -> ExpertParams {
        ExpertParams::init(d, h, seed)
    }

    /// The blocked forward must match the row kernel bit-for-bit, for
    /// any tile size (1 = degenerate per-row tiles, > segment = one
    /// tile), including the saved pre/act tensors.
    #[test]
    fn blocked_forward_matches_row_kernel_for_any_tile() {
        let (d, h, n) = (7usize, 11usize, 29usize);
        let p = params(d, h, 3);
        let mut rng = Rng::new(5);
        let x = rng.normal_vec(n * d, 1.0);
        let tokens: Vec<u32> = (0..n as u32).rev().collect(); // scrambled gather
        // row-kernel reference
        let mut ys_ref = vec![0.0f32; n * d];
        let mut pre_ref = vec![0.0f32; n * h];
        let mut act_ref = vec![0.0f32; n * h];
        for ls in 0..n {
            let tok = tokens[ls] as usize;
            expert_forward_saving(&p, d, h, &x[tok * d..(tok + 1) * d],
                                  &mut ys_ref[ls * d..(ls + 1) * d],
                                  &mut pre_ref[ls * h..(ls + 1) * h],
                                  &mut act_ref[ls * h..(ls + 1) * h]);
        }
        // non-saving row kernel agrees with the saving one
        let mut hidden = vec![0.0f32; h];
        let mut y_row = vec![0.0f32; d];
        expert_forward(&p, d, h, &x[(tokens[0] as usize) * d..][..d], &mut y_row,
                       &mut hidden);
        assert_eq!(&y_row[..], &ys_ref[..d]);

        for tile in [1usize, 2, 5, 16, 64] {
            let mut ys = vec![0.0f32; n * d];
            let mut xs = vec![0.0f32; n * d];
            let mut pre = vec![0.0f32; n * h];
            let mut act = vec![0.0f32; n * h];
            let mut scratch = KernelScratch::new(d, h, tile);
            let mut timers = KernelTimers::default();
            forward_segment(&p, d, h, 0, n, &x, &tokens, 0, &mut ys,
                            Some(&mut xs[..]),
                            Some(SavedHiddenMut {
                                pre: &mut pre[..],
                                act: &mut act[..],
                                gate: None,
                            }),
                            &mut scratch, Some(&mut timers));
            assert_eq!(ys, ys_ref, "tile {tile}: outputs diverged");
            assert_eq!(pre, pre_ref, "tile {tile}: pre diverged");
            assert_eq!(act, act_ref, "tile {tile}: act diverged");
            for ls in 0..n {
                let tok = tokens[ls] as usize;
                assert_eq!(&xs[ls * d..(ls + 1) * d], &x[tok * d..(tok + 1) * d],
                           "tile {tile}: saved xs diverged");
            }
            assert!(timers.compute_s >= 0.0 && timers.gather_s >= 0.0);
        }
    }

    /// The blocked backward must extend gradients and produce ∂x rows
    /// bit-identically to the per-row walk, for any tile size, with
    /// saved and recomputed hidden rows, continuing a non-zero
    /// accumulator.
    #[test]
    fn blocked_backward_matches_row_kernel_for_any_tile() {
        let (d, h, n) = (6usize, 9usize, 23usize);
        let p = params(d, h, 7);
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(n * d, 1.0);
        let d_out = rng.normal_vec(n * d, 1.0);
        let gates: Vec<f32> = (0..n).map(|i| 0.1 + (i as f32) * 0.03).collect();
        let tokens: Vec<u32> = (0..n as u32).map(|t| (t * 7) % n as u32).collect();
        let gate_slots: Vec<u32> = (0..n as u32).collect();
        // row-kernel reference: saved pre/act + grads + dx rows
        let mut pre_s = vec![0.0f32; n * h];
        let mut act_s = vec![0.0f32; n * h];
        let mut ys = vec![0.0f32; n * d];
        for ls in 0..n {
            let tok = tokens[ls] as usize;
            expert_forward_saving(&p, d, h, &x[tok * d..(tok + 1) * d],
                                  &mut ys[ls * d..(ls + 1) * d],
                                  &mut pre_s[ls * h..(ls + 1) * h],
                                  &mut act_s[ls * h..(ls + 1) * h]);
        }
        let mut g_ref = ExpertParams::zeros(d, h);
        // a non-trivial starting accumulator (grad-accum continuation)
        for v in g_ref.w1.iter_mut() {
            *v = 0.25;
        }
        let mut dxs_ref = vec![0.0f32; n * d];
        let mut dz = vec![0.0f32; h];
        let mut dy = vec![0.0f32; d];
        for ls in 0..n {
            let tok = tokens[ls] as usize;
            let gate = gates[gate_slots[ls] as usize];
            for c in 0..d {
                dy[c] = gate * d_out[tok * d + c];
            }
            expert_backward_row(&p, &mut g_ref, d, h, &x[tok * d..(tok + 1) * d],
                                &dy, &pre_s[ls * h..(ls + 1) * h],
                                &act_s[ls * h..(ls + 1) * h], &mut dz,
                                Some(&mut dxs_ref[ls * d..(ls + 1) * d]));
        }

        for tile in [1usize, 3, 8, 32] {
            for saved in [true, false] {
                let mut g = ExpertParams::zeros(d, h);
                for v in g.w1.iter_mut() {
                    *v = 0.25;
                }
                let mut dxs = vec![0.0f32; n * d];
                let mut scratch = KernelScratch::new(d, h, tile);
                let mut timers = KernelTimers::default();
                backward_segment(
                    &p, &mut g, d, h, 0, n, &RowsSrc::Tokens(&x[..]), &tokens, 0,
                    &gate_slots, 0, &d_out, &gates,
                    if saved {
                        Some(SavedHiddenRef {
                            pre: &pre_s[..],
                            act: &act_s[..],
                            gate: None,
                        })
                    } else {
                        None
                    },
                    Some(&mut dxs[..]), &mut scratch, Some(&mut timers),
                );
                assert_eq!(g, g_ref, "tile {tile} saved {saved}: grads diverged");
                assert_eq!(dxs, dxs_ref, "tile {tile} saved {saved}: dx diverged");
            }
        }
        // packed-xs source (SaveInputs residuals) gathers the same rows
        let mut xs = vec![0.0f32; n * d];
        for ls in 0..n {
            let tok = tokens[ls] as usize;
            xs[ls * d..(ls + 1) * d].copy_from_slice(&x[tok * d..(tok + 1) * d]);
        }
        let mut g = ExpertParams::zeros(d, h);
        for v in g.w1.iter_mut() {
            *v = 0.25;
        }
        let mut scratch = KernelScratch::new(d, h, 4);
        let mut timers = KernelTimers::default();
        backward_segment(&p, &mut g, d, h, 0, n, &RowsSrc::Packed(&xs[..]),
                         &tokens, 0, &gate_slots, 0, &d_out, &gates, None, None,
                         &mut scratch, Some(&mut timers));
        // no dx requested: parameter grads still bit-identical
        assert_eq!(g, g_ref, "packed source / no-dx grads diverged");
    }

    /// Blocked SwiGLU forward vs the row oracle, bit-for-bit for every
    /// tile size, including all three saved hidden buffers.
    #[test]
    fn blocked_swiglu_forward_matches_row_kernel_for_any_tile() {
        use crate::coordinator::engine::expert_forward_saving_swiglu;
        let (d, h, n) = (7usize, 11usize, 29usize);
        let p = ExpertParams::init_gated(d, h, 3, true);
        let mut rng = Rng::new(5);
        let x = rng.normal_vec(n * d, 1.0);
        let tokens: Vec<u32> = (0..n as u32).rev().collect();
        let mut ys_ref = vec![0.0f32; n * d];
        let mut pre_ref = vec![0.0f32; n * h];
        let mut gate_ref = vec![0.0f32; n * h];
        let mut act_ref = vec![0.0f32; n * h];
        for ls in 0..n {
            let tok = tokens[ls] as usize;
            expert_forward_saving_swiglu(&p, d, h, &x[tok * d..(tok + 1) * d],
                                         &mut ys_ref[ls * d..(ls + 1) * d],
                                         &mut pre_ref[ls * h..(ls + 1) * h],
                                         &mut gate_ref[ls * h..(ls + 1) * h],
                                         &mut act_ref[ls * h..(ls + 1) * h]);
        }
        for tile in [1usize, 2, 3, 5, 8, 16, 32, 64] {
            let mut ys = vec![0.0f32; n * d];
            let mut pre = vec![0.0f32; n * h];
            let mut gate = vec![0.0f32; n * h];
            let mut act = vec![0.0f32; n * h];
            let mut scratch = KernelScratch::new(d, h, tile);
            forward_segment(&p, d, h, 0, n, &x, &tokens, 0, &mut ys, None,
                            Some(SavedHiddenMut {
                                pre: &mut pre[..],
                                act: &mut act[..],
                                gate: Some(&mut gate[..]),
                            }),
                            &mut scratch, None);
            assert_eq!(ys, ys_ref, "tile {tile}: swiglu outputs diverged");
            assert_eq!(pre, pre_ref, "tile {tile}: swiglu pre diverged");
            assert_eq!(gate, gate_ref, "tile {tile}: swiglu gate diverged");
            assert_eq!(act, act_ref, "tile {tile}: swiglu act diverged");
        }
    }

    /// Blocked SwiGLU backward vs the row oracle: grads (incl. ∂W3) and
    /// ∂x bit-identical for every tile size, with saved and recomputed
    /// hidden rows, continuing a non-zero accumulator.
    #[test]
    fn blocked_swiglu_backward_matches_row_kernel_for_any_tile() {
        use crate::coordinator::engine::{expert_backward_row_swiglu,
                                         expert_forward_saving_swiglu};
        let (d, h, n) = (6usize, 9usize, 23usize);
        let p = ExpertParams::init_gated(d, h, 7, true);
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(n * d, 1.0);
        let d_out = rng.normal_vec(n * d, 1.0);
        let gates: Vec<f32> = (0..n).map(|i| 0.1 + (i as f32) * 0.03).collect();
        let tokens: Vec<u32> = (0..n as u32).map(|t| (t * 7) % n as u32).collect();
        let gate_slots: Vec<u32> = (0..n as u32).collect();
        let mut pre_s = vec![0.0f32; n * h];
        let mut gate_s = vec![0.0f32; n * h];
        let mut act_s = vec![0.0f32; n * h];
        let mut ys = vec![0.0f32; n * d];
        for ls in 0..n {
            let tok = tokens[ls] as usize;
            expert_forward_saving_swiglu(&p, d, h, &x[tok * d..(tok + 1) * d],
                                         &mut ys[ls * d..(ls + 1) * d],
                                         &mut pre_s[ls * h..(ls + 1) * h],
                                         &mut gate_s[ls * h..(ls + 1) * h],
                                         &mut act_s[ls * h..(ls + 1) * h]);
        }
        let mut g_ref = ExpertParams::zeros_gated(d, h, true);
        for v in g_ref.w1.iter_mut() {
            *v = 0.25;
        }
        let mut dxs_ref = vec![0.0f32; n * d];
        let mut dz = vec![0.0f32; h];
        let mut da = vec![0.0f32; h];
        let mut dg = vec![0.0f32; h];
        let mut dy = vec![0.0f32; d];
        for ls in 0..n {
            let tok = tokens[ls] as usize;
            let gate = gates[gate_slots[ls] as usize];
            for c in 0..d {
                dy[c] = gate * d_out[tok * d + c];
            }
            expert_backward_row_swiglu(&p, &mut g_ref, d, h,
                                       &x[tok * d..(tok + 1) * d], &dy,
                                       &pre_s[ls * h..(ls + 1) * h],
                                       &gate_s[ls * h..(ls + 1) * h],
                                       &act_s[ls * h..(ls + 1) * h], &mut dz,
                                       &mut da, &mut dg,
                                       Some(&mut dxs_ref[ls * d..(ls + 1) * d]));
        }
        for tile in [1usize, 2, 3, 5, 8, 16, 32, 64] {
            for saved in [true, false] {
                let mut g = ExpertParams::zeros_gated(d, h, true);
                for v in g.w1.iter_mut() {
                    *v = 0.25;
                }
                let mut dxs = vec![0.0f32; n * d];
                let mut scratch = KernelScratch::new(d, h, tile);
                backward_segment(
                    &p, &mut g, d, h, 0, n, &RowsSrc::Tokens(&x[..]), &tokens, 0,
                    &gate_slots, 0, &d_out, &gates,
                    if saved {
                        Some(SavedHiddenRef {
                            pre: &pre_s[..],
                            act: &act_s[..],
                            gate: Some(&gate_s[..]),
                        })
                    } else {
                        None
                    },
                    Some(&mut dxs[..]), &mut scratch, None,
                );
                assert_eq!(g, g_ref, "tile {tile} saved {saved}: swiglu grads diverged");
                assert_eq!(dxs, dxs_ref, "tile {tile} saved {saved}: swiglu dx diverged");
            }
        }
    }

    #[test]
    fn pick_tile_is_deterministic_and_breaks_ties_low() {
        // pure function of the measurements; ties go to the earliest
        let times = |t: usize| match t {
            8 => 1.0,
            16 => 1.0,
            32 => 2.0,
            _ => 3.0,
        };
        assert_eq!(pick_tile(&[4, 8, 16, 32, 64], times), 8);
        assert_eq!(pick_tile(&AUTOTUNE_TILE_CANDIDATES, |_| 1.0), 4);
        assert_eq!(pick_tile(&[], |_| 0.0), DEFAULT_TILE_ROWS);
    }

    #[test]
    fn transpose_w1_round_trips() {
        let (d, h) = (5usize, 8usize);
        let p = params(d, h, 1);
        let mut t = Vec::new();
        transpose_w1(&p.w1, d, h, &mut t);
        for j in 0..h {
            for c in 0..d {
                assert_eq!(t[c * h + j], p.w1[j * d + c]);
            }
        }
    }
}

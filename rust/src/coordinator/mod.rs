//! L3 coordinator: the training orchestrator.
//!
//! For a training-systems paper the coordinator owns the step loop:
//! parameter/optimizer state, data feeding, LR scheduling, metrics,
//! checkpointing, and the (simulated) expert-parallel topology. The
//! compute itself is the AOT-compiled XLA step (runtime::Executable) —
//! Python never runs here.

pub mod expert_parallel;
pub mod params;
pub mod trainer;

pub use expert_parallel::{AllToAllPlan, EpTopology};
pub use params::ParamStore;
pub use trainer::{TrainReport, Trainer};

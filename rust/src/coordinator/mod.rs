//! L3 coordinator: the training orchestrator.
//!
//! For a training-systems paper the coordinator owns the step loop:
//! parameter/optimizer state, data feeding, LR scheduling, metrics,
//! checkpointing, and the expert-parallel topology. The LM compute is
//! the AOT-compiled XLA step (runtime::Executable) — Python never runs
//! here. The expert-parallel path runs through the [`ExecutionEngine`]
//! step-session API: a caller-owned [`StepBatch`] workload is shared
//! zero-copy into `forward`, the returned [`StepHandle`] is the only
//! ticket into the backward pass (which yields first-class
//! [`ExpertGrads`]), and a pluggable `optim::Optimizer` turns
//! accumulated gradients into the update. `engine::SingleRankEngine` is
//! the classic one-rank path, `engine::ShardedEngine` executes the
//! all-to-all plan across simulated ranks with measured communication.
//!
//! [`ExecutionEngine`]: engine::ExecutionEngine
//! [`StepBatch`]: engine::StepBatch
//! [`StepHandle`]: engine::StepHandle
//! [`ExpertGrads`]: params::ExpertGrads

pub mod engine;
pub mod expert_parallel;
pub mod optim;
pub mod params;
pub mod trainer;

pub use engine::{check_equivalence, engine_from_config, step_batch_from_config,
                 workload_from_config, ExecutionEngine, ShardedEngine,
                 SingleRankEngine, StepBatch, StepHandle, Traffic};
pub use expert_parallel::{AllToAllPlan, EpTopology};
pub use optim::{optimizer_from_name, Adam, Optimizer, Sgd};
pub use params::{ExpertGrads, ExpertStore, ParamStore, RankExperts};
pub use trainer::{EpTrainReport, EpTrainer, TrainReport, Trainer};

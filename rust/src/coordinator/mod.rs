//! L3 coordinator: the training orchestrator.
//!
//! For a training-systems paper the coordinator owns the step loop:
//! parameter/optimizer state, data feeding, LR scheduling, metrics,
//! checkpointing, and the expert-parallel topology. The LM compute is
//! the AOT-compiled XLA step (runtime::Executable) — Python never runs
//! here. The expert-parallel path runs through the [`ExecutionEngine`]
//! trait: `engine::SingleRankEngine` is the classic one-rank path,
//! `engine::ShardedEngine` executes the all-to-all plan across simulated
//! ranks with measured communication.
//!
//! [`ExecutionEngine`]: engine::ExecutionEngine

pub mod engine;
pub mod expert_parallel;
pub mod params;
pub mod trainer;

pub use engine::{check_equivalence, engine_from_config, workload_from_config,
                 ExecutionEngine, ShardedEngine, SingleRankEngine, Traffic};
pub use expert_parallel::{AllToAllPlan, EpTopology};
pub use params::{ExpertStore, ParamStore, RankExperts};
pub use trainer::{EpTrainReport, EpTrainer, TrainReport, Trainer};

//! L3 coordinator: the training orchestrator.
//!
//! For a training-systems paper the coordinator owns the step loop:
//! parameter/optimizer state, data feeding, LR scheduling, metrics,
//! checkpointing, and the expert-parallel topology. The LM compute is
//! the AOT-compiled XLA step (runtime::Executable) — Python never runs
//! here. The expert-parallel path runs through the [`ExecutionEngine`]
//! step-session API: a caller-owned [`StepBatch`] workload is shared
//! zero-copy into `forward`, the returned [`StepHandle`] is the only
//! ticket into the backward pass (which yields first-class
//! [`ExpertGrads`]), and a pluggable `optim::Optimizer` turns
//! accumulated gradients into the update. `engine::SingleRankEngine` is
//! the classic one-rank path, `engine::ShardedEngine` executes the
//! all-to-all plan across simulated ranks with measured communication,
//! and `pipeline::PipelinedEngine` streams K token-contiguous chunks
//! through the same exchange with the dispatch overlap running off the
//! critical path (plus a simulated phase-timeline `OverlapReport`).
//! `stack::MoeStack` chains L such engines into a multi-layer MoE model
//! behind the same trait — forward bottom-up, backward in reverse with
//! ∂x chaining — with per-layer checkpoint policies chosen by the
//! budget-driven `memory::planner::CheckpointPlanner` under
//! `[ep] checkpoint = "auto"`.
//!
//! [`ExecutionEngine`]: engine::ExecutionEngine
//! [`StepBatch`]: engine::StepBatch
//! [`StepHandle`]: engine::StepHandle
//! [`ExpertGrads`]: params::ExpertGrads

pub mod calibrate;
pub mod engine;
pub mod expert_parallel;
pub mod kernels;
pub mod optim;
pub mod params;
pub mod pipeline;
pub mod stack;
pub mod trainer;

pub use calibrate::Calibration;
pub use engine::{check_equivalence, engine_from_config,
                 engine_from_config_with_info, layer_engine_from_config,
                 packed_reference_step, split_bounds_weighted,
                 step_batch_from_config, tile_bucket, topology_from_config,
                 workload_from_config, BuildInfo, ExecutionEngine, LayerRouting,
                 PackedReference, ShardedEngine, SingleRankEngine, StepBatch,
                 StepHandle, Traffic};
pub use expert_parallel::{AllToAllPlan, EpTopology};
pub use kernels::DEFAULT_TILE_ROWS;
pub use optim::{clip_global_norm, optimizer_from_name, Adam, LrSchedule,
                Optimizer, Sgd};
pub use params::{ExpertGrads, ExpertStore, ParamStore, RankExperts};
pub use pipeline::timeline::{CostModel, OverlapReport, Phase, PhaseCalibration,
                             PhaseSpan};
pub use pipeline::PipelinedEngine;
pub use stack::{layer_gating_from_config, layer_routing_from_config,
                plan_from_config, stack_from_config, stack_policies_from_config,
                stack_with_plan, MoeStack};
pub use trainer::{EpTrainReport, EpTrainer, TrainReport, Trainer};

//! Optimizers over [`ExpertGrads`] — decoupled from the backward pass.
//!
//! The step-session engine API returns gradients as first-class values;
//! an [`Optimizer`] turns accumulated gradients into a parameter *delta*
//! (the additive update), which the engine applies to its rank-owned
//! parameters via `ExecutionEngine::apply_update`. This split is what
//! makes grad-accum and non-SGD optimizers possible at all: the old
//! `backward_update(d_out, lr)` fused all three stages.
//!
//! Both optimizers are elementwise and deterministic, so every
//! invariance the engines guarantee (rank count, placement, checkpoint
//! policy, accumulation split) extends through the update: identical
//! grads in, bit-identical delta out.
//!
//! Note [`Sgd`]'s delta `-(lr·g)` applied as `p + delta` is bitwise
//! equal to the classic in-place `p -= lr·g` (IEEE-754: `a - b` is
//! exactly `a + (-b)`), so the redesign preserves PR-1 numerics.

use super::params::ExpertGrads;

/// Turns accumulated expert gradients into an additive parameter delta.
pub trait Optimizer {
    fn name(&self) -> String;

    /// Optimizer-state bytes resident per model parameter (f32 units
    /// already included): 0 for SGD, 8 for Adam's two moments.
    fn state_bytes_per_param(&self) -> u64;

    /// Compute the delta to *add* to the parameters for one optimizer
    /// step over `grads` at learning rate `lr`. Stateful optimizers
    /// update their internal moments here.
    fn step(&mut self, grads: &ExpertGrads, lr: f32) -> Result<ExpertGrads, String>;
}

/// Plain SGD: `delta = -(lr · g)`.
#[derive(Debug, Clone, Copy, Default)]
pub struct Sgd;

impl Optimizer for Sgd {
    fn name(&self) -> String {
        "sgd".into()
    }

    fn state_bytes_per_param(&self) -> u64 {
        0
    }

    fn step(&mut self, grads: &ExpertGrads, lr: f32) -> Result<ExpertGrads, String> {
        if !(lr > 0.0 && lr.is_finite()) {
            return Err(format!("sgd: lr must be positive, got {lr}"));
        }
        let mut delta = grads.clone();
        for g in &mut delta.experts {
            for s in [&mut g.w1, &mut g.b1, &mut g.w2, &mut g.b2] {
                for v in s.iter_mut() {
                    *v = -(lr * *v);
                }
            }
        }
        Ok(delta)
    }
}

/// Adam (Kingma & Ba) with bias correction, f32 moments.
#[derive(Debug, Clone)]
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// optimizer steps taken (bias-correction exponent)
    t: u64,
    m: Option<ExpertGrads>,
    v: Option<ExpertGrads>,
}

impl Default for Adam {
    fn default() -> Adam {
        Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: None, v: None }
    }
}

impl Adam {
    pub fn new(beta1: f32, beta2: f32, eps: f32) -> Adam {
        Adam { beta1, beta2, eps, ..Adam::default() }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }
}

impl Optimizer for Adam {
    fn name(&self) -> String {
        "adam".into()
    }

    fn state_bytes_per_param(&self) -> u64 {
        8 // two f32 moments per parameter
    }

    fn step(&mut self, grads: &ExpertGrads, lr: f32) -> Result<ExpertGrads, String> {
        if !(lr > 0.0 && lr.is_finite()) {
            return Err(format!("adam: lr must be positive, got {lr}"));
        }
        let (e, d, h) = (grads.num_experts(), grads.d_model, grads.d_hidden);
        let m = self
            .m
            .get_or_insert_with(|| ExpertGrads::zeros(e, d, h));
        if (m.num_experts(), m.d_model, m.d_hidden) != (e, d, h) {
            return Err("adam: grads shape changed across steps".into());
        }
        let v = self
            .v
            .get_or_insert_with(|| ExpertGrads::zeros(e, d, h));
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let mut delta = grads.clone();
        for ei in 0..e {
            let ge = &grads.experts[ei];
            let me = &mut m.experts[ei];
            let ve = &mut v.experts[ei];
            let de = &mut delta.experts[ei];
            for (gs, ms, vs, ds) in [
                (&ge.w1, &mut me.w1, &mut ve.w1, &mut de.w1),
                (&ge.b1, &mut me.b1, &mut ve.b1, &mut de.b1),
                (&ge.w2, &mut me.w2, &mut ve.w2, &mut de.w2),
                (&ge.b2, &mut me.b2, &mut ve.b2, &mut de.b2),
            ] {
                for i in 0..gs.len() {
                    let g = gs[i];
                    ms[i] = self.beta1 * ms[i] + (1.0 - self.beta1) * g;
                    vs[i] = self.beta2 * vs[i] + (1.0 - self.beta2) * g * g;
                    let mhat = ms[i] / bc1;
                    let vhat = vs[i] / bc2;
                    ds[i] = -(lr * mhat / (vhat.sqrt() + self.eps));
                }
            }
        }
        Ok(delta)
    }
}

/// Build the optimizer an `[ep]` config names.
pub fn optimizer_from_name(name: &str) -> Result<Box<dyn Optimizer>, String> {
    match name.to_ascii_lowercase().as_str() {
        "sgd" => Ok(Box::new(Sgd)),
        "adam" => Ok(Box::new(Adam::default())),
        _ => Err(format!("unknown optimizer `{name}` (sgd|adam)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grads_of(vals: &[f32]) -> ExpertGrads {
        let mut g = ExpertGrads::zeros(1, 2, 1);
        // w1 is (h, d) = 2 elements; fill from vals
        g.experts[0].w1.copy_from_slice(&vals[..2]);
        g
    }

    #[test]
    fn sgd_delta_matches_in_place_update() {
        let g = grads_of(&[0.25, -3.5]);
        let mut opt = Sgd;
        let delta = opt.step(&g, 0.1).unwrap();
        let p0 = 1.75f32;
        let classic = p0 - 0.1 * g.experts[0].w1[0];
        let via_delta = p0 + delta.experts[0].w1[0];
        assert_eq!(classic.to_bits(), via_delta.to_bits());
        assert!(opt.step(&g, 0.0).is_err());
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // with bias correction, step 1 gives mhat = g, vhat = g², so
        // delta ≈ -lr·sign(g) for |g| >> eps
        let g = grads_of(&[2.0, -0.5]);
        let mut opt = Adam::default();
        let d = opt.step(&g, 0.01).unwrap();
        assert!((d.experts[0].w1[0] + 0.01).abs() < 1e-4, "{}", d.experts[0].w1[0]);
        assert!((d.experts[0].w1[1] - 0.01).abs() < 1e-4, "{}", d.experts[0].w1[1]);
        assert_eq!(opt.steps_taken(), 1);
    }

    #[test]
    fn adam_is_deterministic() {
        let g = grads_of(&[0.3, 0.7]);
        let run = || {
            let mut opt = Adam::default();
            let mut out = Vec::new();
            for _ in 0..5 {
                out.push(opt.step(&g, 0.05).unwrap());
            }
            out
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn adam_rejects_shape_change() {
        let mut opt = Adam::default();
        opt.step(&ExpertGrads::zeros(2, 2, 2), 0.1).unwrap();
        assert!(opt.step(&ExpertGrads::zeros(4, 2, 2), 0.1).is_err());
    }

    #[test]
    fn from_name() {
        assert_eq!(optimizer_from_name("SGD").unwrap().name(), "sgd");
        assert_eq!(optimizer_from_name("adam").unwrap().name(), "adam");
        assert!(optimizer_from_name("lion").is_err());
        assert_eq!(Sgd.state_bytes_per_param(), 0);
        assert_eq!(Adam::default().state_bytes_per_param(), 8);
    }
}

//! Optimizers over [`ExpertGrads`] — decoupled from the backward pass.
//!
//! The step-session engine API returns gradients as first-class values;
//! an [`Optimizer`] turns accumulated gradients into a parameter *delta*
//! (the additive update), which the engine applies to its rank-owned
//! parameters via `ExecutionEngine::apply_update`. This split is what
//! makes grad-accum and non-SGD optimizers possible at all: the old
//! `backward_update(d_out, lr)` fused all three stages.
//!
//! Both optimizers are elementwise and deterministic, so every
//! invariance the engines guarantee (rank count, placement, checkpoint
//! policy, accumulation split) extends through the update: identical
//! grads in, bit-identical delta out.
//!
//! Note [`Sgd`]'s delta `-(lr·g)` applied as `p + delta` is bitwise
//! equal to the classic in-place `p -= lr·g` (IEEE-754: `a - b` is
//! exactly `a + (-b)`), so the redesign preserves PR-1 numerics.

use super::params::ExpertGrads;

/// Serializable optimizer internals for crash-consistent snapshots
/// (`resilience::snapshot::TrainState`). Export/import round-trips the
/// exact moment bits — Adam's update divides by `√v̂ + ε`, so resuming
/// from approximate moments would break the bit-identical-resume pin.
#[derive(Debug, Clone, PartialEq)]
pub enum OptimizerState {
    /// SGD is stateless.
    Sgd,
    /// Adam's bias-correction exponent and first/second moments
    /// (`None` until the first step draws them).
    Adam { t: u64, m: Option<ExpertGrads>, v: Option<ExpertGrads> },
}

impl OptimizerState {
    /// The optimizer name this state belongs to (`optimizer_from_name`
    /// spelling).
    pub fn optimizer_name(&self) -> &'static str {
        match self {
            OptimizerState::Sgd => "sgd",
            OptimizerState::Adam { .. } => "adam",
        }
    }
}

/// Turns accumulated expert gradients into an additive parameter delta.
pub trait Optimizer {
    fn name(&self) -> String;

    /// Optimizer-state bytes resident per model parameter (f32 units
    /// already included): 0 for SGD, 8 for Adam's two moments.
    fn state_bytes_per_param(&self) -> u64;

    /// Compute the delta to *add* to the parameters for one optimizer
    /// step over `grads` at learning rate `lr`. Stateful optimizers
    /// update their internal moments here.
    fn step(&mut self, grads: &ExpertGrads, lr: f32) -> Result<ExpertGrads, String>;

    /// Snapshot the internal state (exact bits) for `TrainState`.
    fn export_state(&self) -> OptimizerState;

    /// Restore internal state from a snapshot. Fails on an optimizer
    /// kind mismatch — resuming an `adam` run as `sgd` silently would
    /// diverge the loss curve instead of erroring.
    fn import_state(&mut self, state: OptimizerState) -> Result<(), String>;
}

/// Plain SGD: `delta = -(lr · g)`.
#[derive(Debug, Clone, Copy, Default)]
pub struct Sgd;

impl Optimizer for Sgd {
    fn name(&self) -> String {
        "sgd".into()
    }

    fn state_bytes_per_param(&self) -> u64 {
        0
    }

    fn step(&mut self, grads: &ExpertGrads, lr: f32) -> Result<ExpertGrads, String> {
        if !(lr > 0.0 && lr.is_finite()) {
            return Err(format!("sgd: lr must be positive, got {lr}"));
        }
        let mut delta = grads.clone();
        for g in &mut delta.experts {
            for s in [&mut g.w1, &mut g.b1, &mut g.w2, &mut g.b2, &mut g.w3] {
                for v in s.iter_mut() {
                    *v = -(lr * *v);
                }
            }
        }
        Ok(delta)
    }

    fn export_state(&self) -> OptimizerState {
        OptimizerState::Sgd
    }

    fn import_state(&mut self, state: OptimizerState) -> Result<(), String> {
        match state {
            OptimizerState::Sgd => Ok(()),
            other => Err(format!(
                "sgd cannot resume from {} optimizer state",
                other.optimizer_name()
            )),
        }
    }
}

/// Adam (Kingma & Ba) with bias correction, f32 moments.
#[derive(Debug, Clone)]
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// optimizer steps taken (bias-correction exponent)
    t: u64,
    m: Option<ExpertGrads>,
    v: Option<ExpertGrads>,
}

impl Default for Adam {
    fn default() -> Adam {
        Adam { beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: None, v: None }
    }
}

impl Adam {
    pub fn new(beta1: f32, beta2: f32, eps: f32) -> Adam {
        Adam { beta1, beta2, eps, ..Adam::default() }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }
}

impl Optimizer for Adam {
    fn name(&self) -> String {
        "adam".into()
    }

    fn state_bytes_per_param(&self) -> u64 {
        8 // two f32 moments per parameter
    }

    fn step(&mut self, grads: &ExpertGrads, lr: f32) -> Result<ExpertGrads, String> {
        if !(lr > 0.0 && lr.is_finite()) {
            return Err(format!("adam: lr must be positive, got {lr}"));
        }
        let (e, d, h) = (grads.num_experts(), grads.d_model, grads.d_hidden);
        // moments are shaped like the incoming grads (zeros-like), so a
        // gated (SwiGLU) run gets w3 moments without special-casing
        let zeros_like = || {
            let mut z = grads.clone();
            z.clear();
            z
        };
        let m = self.m.get_or_insert_with(zeros_like);
        if (m.num_experts(), m.d_model, m.d_hidden) != (e, d, h)
            || m.experts.first().map(|p| p.gated())
                != grads.experts.first().map(|p| p.gated())
        {
            return Err("adam: grads shape changed across steps".into());
        }
        let v = self.v.get_or_insert_with(zeros_like);
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let mut delta = grads.clone();
        for ei in 0..e {
            let ge = &grads.experts[ei];
            let me = &mut m.experts[ei];
            let ve = &mut v.experts[ei];
            let de = &mut delta.experts[ei];
            for (gs, ms, vs, ds) in [
                (&ge.w1, &mut me.w1, &mut ve.w1, &mut de.w1),
                (&ge.b1, &mut me.b1, &mut ve.b1, &mut de.b1),
                (&ge.w2, &mut me.w2, &mut ve.w2, &mut de.w2),
                (&ge.b2, &mut me.b2, &mut ve.b2, &mut de.b2),
                (&ge.w3, &mut me.w3, &mut ve.w3, &mut de.w3),
            ] {
                for i in 0..gs.len() {
                    let g = gs[i];
                    ms[i] = self.beta1 * ms[i] + (1.0 - self.beta1) * g;
                    vs[i] = self.beta2 * vs[i] + (1.0 - self.beta2) * g * g;
                    let mhat = ms[i] / bc1;
                    let vhat = vs[i] / bc2;
                    ds[i] = -(lr * mhat / (vhat.sqrt() + self.eps));
                }
            }
        }
        Ok(delta)
    }

    fn export_state(&self) -> OptimizerState {
        OptimizerState::Adam { t: self.t, m: self.m.clone(), v: self.v.clone() }
    }

    fn import_state(&mut self, state: OptimizerState) -> Result<(), String> {
        match state {
            OptimizerState::Adam { t, m, v } => {
                if m.is_some() != v.is_some() {
                    return Err("adam: snapshot has one moment grid, not both".into());
                }
                self.t = t;
                self.m = m;
                self.v = v;
                Ok(())
            }
            other => Err(format!(
                "adam cannot resume from {} optimizer state",
                other.optimizer_name()
            )),
        }
    }
}

// -- LR schedules + gradient clipping ---------------------------------------

/// Per-step learning-rate schedule for `EpTrainer`, mirroring the LM
/// loop's shape (`TrainConfig::lr_at`: linear warmup, cosine decay
/// toward a tenth of the base rate). The warmup span is fixed at 10% of
/// the run (at least one step) for the schedules that have one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LrSchedule {
    /// The base LR at every step (the pre-schedule behavior).
    #[default]
    Constant,
    /// Linear warmup over the first 10% of steps, then cosine decay
    /// from the base LR *toward* `0.1 × base` — like the LM loop's
    /// `lr_at`, the floor is approached but not hit (the final step
    /// sits one cosine increment above it).
    Cosine,
    /// Linear warmup over the first 10% of steps, then the base LR.
    LinearWarmup,
}

impl LrSchedule {
    pub fn parse(s: &str) -> Result<LrSchedule, String> {
        match s.to_ascii_lowercase().as_str() {
            "constant" | "none" => Ok(LrSchedule::Constant),
            "cosine" => Ok(LrSchedule::Cosine),
            "linear-warmup" | "linear_warmup" | "warmup" => Ok(LrSchedule::LinearWarmup),
            _ => Err(format!(
                "unknown lr schedule `{s}` (constant|cosine|linear-warmup)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            LrSchedule::Constant => "constant",
            LrSchedule::Cosine => "cosine",
            LrSchedule::LinearWarmup => "linear-warmup",
        }
    }

    /// Learning rate at `step` (0-based) of a `total`-step run.
    pub fn lr_at(self, base: f64, step: usize, total: usize) -> f64 {
        if self == LrSchedule::Constant {
            return base;
        }
        let warmup = (total / 10).max(1);
        if step < warmup {
            return base * (step + 1) as f64 / warmup as f64;
        }
        match self {
            LrSchedule::LinearWarmup => base,
            LrSchedule::Cosine => {
                let min = 0.1 * base;
                let progress = (step - warmup) as f64
                    / total.saturating_sub(warmup).max(1) as f64;
                min + 0.5 * (base - min) * (1.0 + (std::f64::consts::PI * progress).cos())
            }
            LrSchedule::Constant => unreachable!(),
        }
    }
}

/// Global-norm gradient clipping: if ‖g‖₂ exceeds `max_norm`, scale every
/// accumulator by `max_norm / ‖g‖₂`. Returns `(pre_clip_norm, clipped)`.
/// The norm is the fixed-order f64 accumulation of
/// `ExpertGrads::l2_norm`, and identical grads scale identically — so
/// every engine invariance (rank count, placement, policy, chunk count,
/// accumulation split) extends through clipping.
pub fn clip_global_norm(grads: &mut ExpertGrads, max_norm: f64) -> (f64, bool) {
    let norm = grads.l2_norm();
    if max_norm > 0.0 && norm > max_norm {
        grads.scale((max_norm / norm) as f32);
        (norm, true)
    } else {
        (norm, false)
    }
}

/// Build the optimizer an `[ep]` config names.
pub fn optimizer_from_name(name: &str) -> Result<Box<dyn Optimizer>, String> {
    match name.to_ascii_lowercase().as_str() {
        "sgd" => Ok(Box::new(Sgd)),
        "adam" => Ok(Box::new(Adam::default())),
        _ => Err(format!("unknown optimizer `{name}` (sgd|adam)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grads_of(vals: &[f32]) -> ExpertGrads {
        let mut g = ExpertGrads::zeros(1, 2, 1);
        // w1 is (h, d) = 2 elements; fill from vals
        g.experts[0].w1.copy_from_slice(&vals[..2]);
        g
    }

    #[test]
    fn sgd_delta_matches_in_place_update() {
        let g = grads_of(&[0.25, -3.5]);
        let mut opt = Sgd;
        let delta = opt.step(&g, 0.1).unwrap();
        let p0 = 1.75f32;
        let classic = p0 - 0.1 * g.experts[0].w1[0];
        let via_delta = p0 + delta.experts[0].w1[0];
        assert_eq!(classic.to_bits(), via_delta.to_bits());
        assert!(opt.step(&g, 0.0).is_err());
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // with bias correction, step 1 gives mhat = g, vhat = g², so
        // delta ≈ -lr·sign(g) for |g| >> eps
        let g = grads_of(&[2.0, -0.5]);
        let mut opt = Adam::default();
        let d = opt.step(&g, 0.01).unwrap();
        assert!((d.experts[0].w1[0] + 0.01).abs() < 1e-4, "{}", d.experts[0].w1[0]);
        assert!((d.experts[0].w1[1] - 0.01).abs() < 1e-4, "{}", d.experts[0].w1[1]);
        assert_eq!(opt.steps_taken(), 1);
    }

    #[test]
    fn adam_is_deterministic() {
        let g = grads_of(&[0.3, 0.7]);
        let run = || {
            let mut opt = Adam::default();
            let mut out = Vec::new();
            for _ in 0..5 {
                out.push(opt.step(&g, 0.05).unwrap());
            }
            out
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn adam_rejects_shape_change() {
        let mut opt = Adam::default();
        opt.step(&ExpertGrads::zeros(2, 2, 2), 0.1).unwrap();
        assert!(opt.step(&ExpertGrads::zeros(4, 2, 2), 0.1).is_err());
        // gatedness is part of the shape: moments drawn for ungated
        // grads cannot absorb a w3 stream
        assert!(opt
            .step(&ExpertGrads::zeros_gated(2, 2, 2, true), 0.1)
            .is_err());
    }

    #[test]
    fn gated_grads_update_w3() {
        let mut g = ExpertGrads::zeros_gated(1, 2, 1, true);
        g.experts[0].w3.copy_from_slice(&[2.0, -0.5]);
        let d = Sgd.step(&g, 0.1).unwrap();
        assert_eq!(d.experts[0].w3, vec![-0.2, 0.05]);
        let mut adam = Adam::default();
        let d = adam.step(&g, 0.01).unwrap();
        assert!((d.experts[0].w3[0] + 0.01).abs() < 1e-4);
        assert!((d.experts[0].w3[1] - 0.01).abs() < 1e-4);
    }

    #[test]
    fn lr_schedule_shapes() {
        assert_eq!(LrSchedule::parse("Constant").unwrap(), LrSchedule::Constant);
        assert_eq!(LrSchedule::parse("cosine").unwrap(), LrSchedule::Cosine);
        assert_eq!(LrSchedule::parse("linear_warmup").unwrap(),
                   LrSchedule::LinearWarmup);
        assert!(LrSchedule::parse("sawtooth").is_err());
        assert_eq!(LrSchedule::default(), LrSchedule::Constant);

        let base = 1.0;
        let total = 100;
        for s in [0, 10, 50, 99] {
            assert_eq!(LrSchedule::Constant.lr_at(base, s, total), base);
        }
        // warmup ramps to the base by step 9 (10% of 100), then holds
        let lw = LrSchedule::LinearWarmup;
        assert!(lw.lr_at(base, 0, total) < lw.lr_at(base, 5, total));
        assert!((lw.lr_at(base, 9, total) - base).abs() < 1e-12);
        assert_eq!(lw.lr_at(base, 50, total), base);
        // cosine: same warmup, then monotone decay toward base/10
        let cos = LrSchedule::Cosine;
        assert!((cos.lr_at(base, 9, total) - base).abs() < 1e-12);
        assert!(cos.lr_at(base, 50, total) < base);
        assert!(cos.lr_at(base, 99, total) < cos.lr_at(base, 50, total));
        assert!(cos.lr_at(base, 99, total) >= 0.1 * base - 1e-9);
        // degenerate short runs never divide by zero
        assert!(cos.lr_at(base, 0, 1).is_finite());
        assert!(lw.lr_at(base, 0, 1).is_finite());
    }

    #[test]
    fn clip_global_norm_scales_only_above_threshold() {
        let mut g = grads_of(&[3.0, 4.0]); // ‖g‖ = 5
        let (norm, clipped) = clip_global_norm(&mut g, 10.0);
        assert!((norm - 5.0).abs() < 1e-12);
        assert!(!clipped);
        assert_eq!(g.experts[0].w1, vec![3.0, 4.0]);

        let (norm, clipped) = clip_global_norm(&mut g, 2.5);
        assert!((norm - 5.0).abs() < 1e-12);
        assert!(clipped);
        // direction preserved, norm halved
        assert_eq!(g.experts[0].w1, vec![1.5, 2.0]);
        assert!((g.l2_norm() - 2.5).abs() < 1e-6);

        // 0 disables clipping
        let mut g = grads_of(&[30.0, 40.0]);
        let (_, clipped) = clip_global_norm(&mut g, 0.0);
        assert!(!clipped);
    }

    #[test]
    fn optimizer_state_round_trips_exact_bits() {
        let g = grads_of(&[0.3, 0.7]);
        // drive one Adam two steps, export, import into a fresh Adam,
        // and the next steps must be bit-identical
        let mut a = Adam::default();
        a.step(&g, 0.05).unwrap();
        a.step(&g, 0.05).unwrap();
        let state = a.export_state();
        let mut b = Adam::default();
        b.import_state(state.clone()).unwrap();
        assert_eq!(b.steps_taken(), 2);
        for _ in 0..3 {
            let da = a.step(&g, 0.05).unwrap();
            let db = b.step(&g, 0.05).unwrap();
            assert_eq!(da, db, "resumed adam diverged");
        }
        // kind mismatches are loud
        assert!(Sgd.import_state(state).is_err());
        assert!(Adam::default().import_state(OptimizerState::Sgd).is_err());
        assert!(Sgd.import_state(OptimizerState::Sgd).is_ok());
        assert_eq!(Sgd.export_state(), OptimizerState::Sgd);
        // half a moment pair is corruption, not state
        assert!(Adam::default()
            .import_state(OptimizerState::Adam {
                t: 1,
                m: Some(ExpertGrads::zeros(1, 2, 1)),
                v: None,
            })
            .is_err());
    }

    #[test]
    fn from_name() {
        assert_eq!(optimizer_from_name("SGD").unwrap().name(), "sgd");
        assert_eq!(optimizer_from_name("adam").unwrap().name(), "adam");
        assert!(optimizer_from_name("lion").is_err());
        assert_eq!(Sgd.state_bytes_per_param(), 0);
        assert_eq!(Adam::default().state_bytes_per_param(), 8);
    }
}

//! Host-side parameter/optimizer state + checkpoint format.
//!
//! Initialization mirrors `transformer.init_params`: N(0, scale²) for
//! weights, ones for the `ln*` norm gains (scale is carried per-parameter
//! in the manifest). Checkpoints use a small self-describing binary
//! format (magic + version + named tensors) written atomically.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::artifact::LmSpec;
use crate::runtime::host::HostTensor;
use crate::util::bytes;
use crate::util::prng::Rng;

const MAGIC: &[u8; 8] = b"MOEBLZ01";

/// Parameters + Adam moments + step counter for the LM.
pub struct ParamStore {
    pub names: Vec<String>,
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: u64,
}

impl ParamStore {
    /// Fresh initialization from the manifest's parameter spec.
    pub fn init(lm: &LmSpec, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut names = Vec::new();
        let mut params = Vec::new();
        let mut m = Vec::new();
        let mut v = Vec::new();
        for p in &lm.params {
            let n: usize = p.shape.iter().product();
            let is_norm_gain = p
                .name
                .rsplit('.')
                .next()
                .map(|s| s.starts_with("ln"))
                .unwrap_or(false);
            let data = if is_norm_gain {
                vec![1.0f32; n]
            } else {
                rng.normal_vec(n, p.init_scale)
            };
            names.push(p.name.clone());
            params.push(HostTensor::F32 { shape: p.shape.clone(), data });
            m.push(HostTensor::F32 { shape: p.shape.clone(), data: vec![0.0; n] });
            v.push(HostTensor::F32 { shape: p.shape.clone(), data: vec![0.0; n] });
        }
        ParamStore { names, params, m, v, step: 0 }
    }

    pub fn num_params(&self) -> usize {
        self.params.iter().map(HostTensor::elements).sum()
    }

    // -- checkpointing -------------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        bytes::write_u64(&mut buf, self.step);
        bytes::write_u64(&mut buf, self.names.len() as u64);
        for i in 0..self.names.len() {
            bytes::write_str(&mut buf, &self.names[i]);
            for t in [&self.params[i], &self.m[i], &self.v[i]] {
                let shape = t.shape();
                bytes::write_u64(&mut buf, shape.len() as u64);
                for &d in shape {
                    bytes::write_u64(&mut buf, d as u64);
                }
                let data = t.as_f32().map_err(|e| anyhow::anyhow!("{e}"))?;
                buf.extend_from_slice(&bytes::f32s_to_bytes(data));
            }
        }
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        // atomic: write temp then rename
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, &buf).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ParamStore> {
        let buf = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if buf.len() < 8 || &buf[..8] != MAGIC {
            bail!("{path:?}: not a MoEBlaze checkpoint (bad magic)");
        }
        let mut pos = 8;
        let step = bytes::read_u64(&buf, &mut pos).map_err(anyhow::Error::msg)?;
        let count = bytes::read_u64(&buf, &mut pos).map_err(anyhow::Error::msg)? as usize;
        let mut names = Vec::with_capacity(count);
        let mut params = Vec::with_capacity(count);
        let mut m = Vec::with_capacity(count);
        let mut v = Vec::with_capacity(count);
        for _ in 0..count {
            names.push(bytes::read_str(&buf, &mut pos).map_err(anyhow::Error::msg)?);
            let mut three = Vec::with_capacity(3);
            for _ in 0..3 {
                let ndim = bytes::read_u64(&buf, &mut pos).map_err(anyhow::Error::msg)? as usize;
                let mut shape = Vec::with_capacity(ndim);
                for _ in 0..ndim {
                    shape.push(bytes::read_u64(&buf, &mut pos).map_err(anyhow::Error::msg)? as usize);
                }
                let n: usize = shape.iter().product();
                if pos + 4 * n > buf.len() {
                    bail!("{path:?}: truncated tensor data");
                }
                let data = bytes::bytes_to_f32s(&buf[pos..pos + 4 * n])
                    .map_err(anyhow::Error::msg)?;
                pos += 4 * n;
                three.push(HostTensor::F32 { shape, data });
            }
            v.push(three.pop().unwrap());
            m.push(three.pop().unwrap());
            params.push(three.pop().unwrap());
        }
        Ok(ParamStore { names, params, m, v, step })
    }

    /// Consistency with the manifest spec (names + shapes, in order).
    pub fn check_against(&self, lm: &LmSpec) -> Result<()> {
        if self.names.len() != lm.params.len() {
            bail!("checkpoint has {} tensors, manifest {}", self.names.len(),
                  lm.params.len());
        }
        for (i, p) in lm.params.iter().enumerate() {
            if self.names[i] != p.name {
                bail!("param {i}: name `{}` != manifest `{}`", self.names[i], p.name);
            }
            if self.params[i].shape() != p.shape.as_slice() {
                bail!("param `{}`: shape {:?} != manifest {:?}", p.name,
                      self.params[i].shape(), p.shape);
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::LmParam;
    use std::collections::BTreeMap;

    fn lm_spec() -> LmSpec {
        LmSpec {
            batch: 2,
            params: vec![
                LmParam { name: "embed".into(), shape: vec![8, 4], init_scale: 0.02 },
                LmParam { name: "layer0.ln1".into(), shape: vec![4], init_scale: 1.0 },
                LmParam { name: "layer0.wq".into(), shape: vec![4, 4], init_scale: 0.5 },
            ],
            config: BTreeMap::new(),
        }
    }

    #[test]
    fn init_norm_gains_are_ones() {
        let s = ParamStore::init(&lm_spec(), 1);
        assert_eq!(s.params[1].as_f32().unwrap(), &[1.0; 4]);
        // weights are not all equal
        let w = s.params[2].as_f32().unwrap();
        assert!(w.iter().any(|&x| x != w[0]));
        assert_eq!(s.num_params(), 32 + 4 + 16);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("moeblaze_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step10.ckpt");
        let mut s = ParamStore::init(&lm_spec(), 2);
        s.step = 10;
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.step, 10);
        assert_eq!(loaded.names, s.names);
        for i in 0..s.params.len() {
            assert_eq!(loaded.params[i].as_f32().unwrap(),
                       s.params[i].as_f32().unwrap());
        }
        loaded.check_against(&lm_spec()).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_corrupt() {
        let dir = std::env::temp_dir().join("moeblaze_ckpt_bad");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTMAGIC123").unwrap();
        assert!(ParamStore::load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn check_against_catches_mismatch() {
        let s = ParamStore::init(&lm_spec(), 3);
        let mut other = lm_spec();
        other.params[2].shape = vec![4, 5];
        assert!(s.check_against(&other).is_err());
    }
}

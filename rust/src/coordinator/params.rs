//! Host-side parameter/optimizer state + checkpoint format.
//!
//! Initialization mirrors `transformer.init_params`: N(0, scale²) for
//! weights, ones for the `ln*` norm gains (scale is carried per-parameter
//! in the manifest). Checkpoints use a small self-describing binary
//! format (magic + version + named tensors) written atomically.
//!
//! The expert-parallel execution engine owns its parameters through
//! [`ExpertStore`] / [`RankExperts`]: the store initializes every expert
//! FFN with a per-expert seed (so any sharding sees identical weights),
//! and [`ExpertStore::shard`] hands each rank *ownership* of its experts
//! — the engines mutate rank-local parameters only, and
//! [`ExpertStore::gather`] reassembles the global view.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::dispatch::shard::ExpertAssignment;
use crate::runtime::artifact::LmSpec;
use crate::runtime::host::HostTensor;
use crate::util::bytes;
use crate::util::prng::Rng;

const MAGIC: &[u8; 8] = b"MOEBLZ01";

/// Parameters + Adam moments + step counter for the LM.
pub struct ParamStore {
    pub names: Vec<String>,
    pub params: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
    pub step: u64,
}

impl ParamStore {
    /// Fresh initialization from the manifest's parameter spec.
    pub fn init(lm: &LmSpec, seed: u64) -> ParamStore {
        let mut rng = Rng::new(seed);
        let mut names = Vec::new();
        let mut params = Vec::new();
        let mut m = Vec::new();
        let mut v = Vec::new();
        for p in &lm.params {
            let n: usize = p.shape.iter().product();
            let is_norm_gain = p
                .name
                .rsplit('.')
                .next()
                .map(|s| s.starts_with("ln"))
                .unwrap_or(false);
            let data = if is_norm_gain {
                vec![1.0f32; n]
            } else {
                rng.normal_vec(n, p.init_scale)
            };
            names.push(p.name.clone());
            params.push(HostTensor::F32 { shape: p.shape.clone(), data });
            m.push(HostTensor::F32 { shape: p.shape.clone(), data: vec![0.0; n] });
            v.push(HostTensor::F32 { shape: p.shape.clone(), data: vec![0.0; n] });
        }
        ParamStore { names, params, m, v, step: 0 }
    }

    pub fn num_params(&self) -> usize {
        self.params.iter().map(HostTensor::elements).sum()
    }

    // -- checkpointing -------------------------------------------------------

    pub fn save(&self, path: &Path) -> Result<()> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        bytes::write_u64(&mut buf, self.step);
        bytes::write_u64(&mut buf, self.names.len() as u64);
        for i in 0..self.names.len() {
            bytes::write_str(&mut buf, &self.names[i]);
            for t in [&self.params[i], &self.m[i], &self.v[i]] {
                let shape = t.shape();
                bytes::write_u64(&mut buf, shape.len() as u64);
                for &d in shape {
                    bytes::write_u64(&mut buf, d as u64);
                }
                let data = t.as_f32().map_err(|e| anyhow::anyhow!("{e}"))?;
                buf.extend_from_slice(&bytes::f32s_to_bytes(data));
            }
        }
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        // atomic: write temp then rename
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, &buf).with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ParamStore> {
        let buf = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if buf.len() < 8 || &buf[..8] != MAGIC {
            bail!("{path:?}: not a MoEBlaze checkpoint (bad magic)");
        }
        let mut pos = 8;
        let step = bytes::read_u64(&buf, &mut pos).map_err(anyhow::Error::msg)?;
        let count = bytes::read_u64(&buf, &mut pos).map_err(anyhow::Error::msg)? as usize;
        let mut names = Vec::with_capacity(count);
        let mut params = Vec::with_capacity(count);
        let mut m = Vec::with_capacity(count);
        let mut v = Vec::with_capacity(count);
        for _ in 0..count {
            names.push(bytes::read_str(&buf, &mut pos).map_err(anyhow::Error::msg)?);
            let mut three = Vec::with_capacity(3);
            for _ in 0..3 {
                let ndim = bytes::read_u64(&buf, &mut pos).map_err(anyhow::Error::msg)? as usize;
                let mut shape = Vec::with_capacity(ndim);
                for _ in 0..ndim {
                    let dim = bytes::read_u64(&buf, &mut pos)
                        .map_err(anyhow::Error::msg)?;
                    shape.push(dim as usize);
                }
                let n: usize = shape.iter().product();
                if pos + 4 * n > buf.len() {
                    bail!("{path:?}: truncated tensor data");
                }
                let data = bytes::bytes_to_f32s(&buf[pos..pos + 4 * n])
                    .map_err(anyhow::Error::msg)?;
                pos += 4 * n;
                three.push(HostTensor::F32 { shape, data });
            }
            v.push(three.pop().unwrap());
            m.push(three.pop().unwrap());
            params.push(three.pop().unwrap());
        }
        Ok(ParamStore { names, params, m, v, step })
    }

    /// Consistency with the manifest spec (names + shapes, in order).
    pub fn check_against(&self, lm: &LmSpec) -> Result<()> {
        if self.names.len() != lm.params.len() {
            bail!("checkpoint has {} tensors, manifest {}", self.names.len(),
                  lm.params.len());
        }
        for (i, p) in lm.params.iter().enumerate() {
            if self.names[i] != p.name {
                bail!("param {i}: name `{}` != manifest `{}`", self.names[i], p.name);
            }
            if self.params[i].shape() != p.shape.as_slice() {
                bail!("param `{}`: shape {:?} != manifest {:?}", p.name,
                      self.params[i].shape(), p.shape);
            }
        }
        Ok(())
    }
}

// -- expert-sharded parameters (EP engine) ----------------------------------

/// One expert's FFN, f32 row-major. Ungated (SiLU-MLP):
/// y = W2·silu(W1·x + b1) + b2, `w3` empty. Gated (SwiGLU): an extra
/// (h, d) gate matrix `w3` (no gate bias) turns the hidden into
/// silu(W1·x + b1) ⊙ (W3·x).
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertParams {
    /// (h, d)
    pub w1: Vec<f32>,
    /// (h)
    pub b1: Vec<f32>,
    /// (d, h)
    pub w2: Vec<f32>,
    /// (d)
    pub b2: Vec<f32>,
    /// (h, d) SwiGLU gate projection; empty when the expert is ungated
    pub w3: Vec<f32>,
}

impl ExpertParams {
    /// N(0, 1/d) / N(0, 1/h) fan-in init, biases zero.
    pub fn init(d_model: usize, d_hidden: usize, seed: u64) -> ExpertParams {
        ExpertParams::init_gated(d_model, d_hidden, seed, false)
    }

    /// Like [`init`](ExpertParams::init), optionally drawing the SwiGLU
    /// gate matrix. `w3` is drawn AFTER `w2` from the same stream, so an
    /// ungated expert's w1/w2 bits are unchanged by this extension.
    pub fn init_gated(d_model: usize, d_hidden: usize, seed: u64, gated: bool) -> ExpertParams {
        let mut rng = Rng::new(seed);
        let s1 = (1.0 / d_model as f64).sqrt() as f32;
        let s2 = (1.0 / d_hidden as f64).sqrt() as f32;
        let w1 = rng.normal_vec(d_hidden * d_model, s1);
        let w2 = rng.normal_vec(d_model * d_hidden, s2);
        let w3 = if gated { rng.normal_vec(d_hidden * d_model, s1) } else { Vec::new() };
        ExpertParams { w1, b1: vec![0.0; d_hidden], w2, b2: vec![0.0; d_model], w3 }
    }

    /// All-zero parameters of the same shape (gradient accumulators).
    pub fn zeros(d_model: usize, d_hidden: usize) -> ExpertParams {
        ExpertParams::zeros_gated(d_model, d_hidden, false)
    }

    /// Zeros with an optional gate accumulator.
    pub fn zeros_gated(d_model: usize, d_hidden: usize, gated: bool) -> ExpertParams {
        ExpertParams {
            w1: vec![0.0; d_hidden * d_model],
            b1: vec![0.0; d_hidden],
            w2: vec![0.0; d_model * d_hidden],
            b2: vec![0.0; d_model],
            w3: if gated { vec![0.0; d_hidden * d_model] } else { Vec::new() },
        }
    }

    /// Whether this expert carries the SwiGLU gate projection.
    pub fn gated(&self) -> bool {
        !self.w3.is_empty()
    }

    pub fn num_params(&self) -> usize {
        self.w1.len() + self.b1.len() + self.w2.len() + self.b2.len() + self.w3.len()
    }
}

/// All experts of one MoE layer (the unsharded, single-rank view).
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertStore {
    pub d_model: usize,
    pub d_hidden: usize,
    pub experts: Vec<ExpertParams>,
}

impl ExpertStore {
    /// Every expert drawn from its own seed (`seed ^ f(e)`), so a rank
    /// initializing only its shard gets bit-identical weights to the
    /// single-rank store — placement-invariant by construction.
    pub fn init(num_experts: usize, d_model: usize, d_hidden: usize, seed: u64) -> ExpertStore {
        ExpertStore::init_gated(num_experts, d_model, d_hidden, seed, false)
    }

    /// Like [`init`](ExpertStore::init), with every expert optionally
    /// carrying the SwiGLU gate matrix (same per-expert seed stream).
    pub fn init_gated(
        num_experts: usize,
        d_model: usize,
        d_hidden: usize,
        seed: u64,
        gated: bool,
    ) -> ExpertStore {
        let experts = (0..num_experts)
            .map(|e| {
                let es = seed ^ (e as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15);
                ExpertParams::init_gated(d_model, d_hidden, es, gated)
            })
            .collect();
        ExpertStore { d_model, d_hidden, experts }
    }

    /// Whether the experts carry SwiGLU gate projections.
    pub fn gated(&self) -> bool {
        self.experts.first().map(ExpertParams::gated).unwrap_or(false)
    }

    pub fn num_params(&self) -> usize {
        self.experts.iter().map(ExpertParams::num_params).sum()
    }

    /// Split ownership: rank r receives (and exclusively mutates) the
    /// parameters of the experts the assignment places on it.
    pub fn shard(&self, assignment: &ExpertAssignment) -> Vec<RankExperts> {
        (0..assignment.ranks)
            .map(|r| RankExperts {
                rank: r,
                d_model: self.d_model,
                d_hidden: self.d_hidden,
                experts: assignment
                    .owned_experts(r)
                    .into_iter()
                    .map(|e| (e as u32, self.experts[e].clone()))
                    .collect(),
            })
            .collect()
    }

    /// Concatenate per-layer stores into one flat store, layer-major
    /// (layer l's expert e sits at global id `l·E + e`) — the view a
    /// multi-layer stack's `gather_params` exposes.
    pub fn concat(layers: &[ExpertStore]) -> std::result::Result<ExpertStore, String> {
        let first = layers.first().ok_or("concat needs at least one store")?;
        let (d, h) = (first.d_model, first.d_hidden);
        let mut experts = Vec::new();
        for s in layers {
            if (s.d_model, s.d_hidden) != (d, h) {
                return Err("layer stores disagree on expert dimensions".into());
            }
            experts.extend(s.experts.iter().cloned());
        }
        Ok(ExpertStore { d_model: d, d_hidden: h, experts })
    }

    /// Reassemble the global store from per-rank ownership (inverse of
    /// [`shard`](ExpertStore::shard)).
    pub fn gather(shards: &[RankExperts], num_experts: usize)
                  -> std::result::Result<ExpertStore, String> {
        let first = shards.first().ok_or("gather needs at least one shard")?;
        let (d, h) = (first.d_model, first.d_hidden);
        let mut experts: Vec<Option<ExpertParams>> = vec![None; num_experts];
        for s in shards {
            if (s.d_model, s.d_hidden) != (d, h) {
                return Err("shards disagree on expert dimensions".into());
            }
            for (e, p) in &s.experts {
                let slot = experts
                    .get_mut(*e as usize)
                    .ok_or_else(|| format!("expert {e} out of range"))?;
                if slot.is_some() {
                    return Err(format!("expert {e} owned by more than one rank"));
                }
                *slot = Some(p.clone());
            }
        }
        let experts = experts
            .into_iter()
            .enumerate()
            .map(|(e, p)| p.ok_or_else(|| format!("expert {e} owned by no rank")))
            .collect::<std::result::Result<Vec<_>, String>>()?;
        Ok(ExpertStore { d_model: d, d_hidden: h, experts })
    }
}

/// Per-expert gradients as a first-class value: one accumulator per
/// global expert, dense by expert id. Produced by the engines' step
/// sessions (`StepHandle::backward`), accumulated across microbatches by
/// `EpTrainer`, consumed by an `Optimizer` — gradient computation and
/// parameter update are decoupled.
///
/// Accumulation order is part of the numerics contract: the engines add
/// row contributions into an existing `ExpertGrads` in expert-segment
/// order, so accumulating A contiguous microbatches into one value
/// performs the exact same float-op sequence as one full batch — the
/// foundation of the grad-accum bit-identity guarantee.
#[derive(Debug, Clone, PartialEq)]
pub struct ExpertGrads {
    pub d_model: usize,
    pub d_hidden: usize,
    /// one gradient accumulator per global expert id (dense)
    pub experts: Vec<ExpertParams>,
}

impl ExpertGrads {
    /// All-zero accumulators for `num_experts` experts.
    pub fn zeros(num_experts: usize, d_model: usize, d_hidden: usize) -> ExpertGrads {
        ExpertGrads::zeros_gated(num_experts, d_model, d_hidden, false)
    }

    /// Zeros with optional SwiGLU gate accumulators — the shape must
    /// match the params being differentiated.
    pub fn zeros_gated(
        num_experts: usize,
        d_model: usize,
        d_hidden: usize,
        gated: bool,
    ) -> ExpertGrads {
        ExpertGrads {
            d_model,
            d_hidden,
            experts: (0..num_experts)
                .map(|_| ExpertParams::zeros_gated(d_model, d_hidden, gated))
                .collect(),
        }
    }

    pub fn num_experts(&self) -> usize {
        self.experts.len()
    }

    pub fn num_params(&self) -> usize {
        self.experts.iter().map(ExpertParams::num_params).sum()
    }

    /// Shape compatibility with another grads/params holder.
    pub fn check_like(&self, num_experts: usize, d_model: usize, d_hidden: usize) -> Result<()> {
        if self.experts.len() != num_experts
            || self.d_model != d_model
            || self.d_hidden != d_hidden
        {
            bail!(
                "ExpertGrads shape (E={}, d={}, h={}) != expected \
                 (E={num_experts}, d={d_model}, h={d_hidden})",
                self.experts.len(),
                self.d_model,
                self.d_hidden
            );
        }
        Ok(())
    }

    /// Reset every accumulator to zero in place (buffer reuse across
    /// optimizer steps — no reallocation).
    pub fn clear(&mut self) {
        for g in &mut self.experts {
            g.w1.iter_mut().for_each(|v| *v = 0.0);
            g.b1.iter_mut().for_each(|v| *v = 0.0);
            g.w2.iter_mut().for_each(|v| *v = 0.0);
            g.b2.iter_mut().for_each(|v| *v = 0.0);
            g.w3.iter_mut().for_each(|v| *v = 0.0);
        }
    }

    /// Multiply every accumulator element by `s` in place (global-norm
    /// gradient clipping).
    pub fn scale(&mut self, s: f32) {
        for g in &mut self.experts {
            for buf in [&mut g.w1, &mut g.b1, &mut g.w2, &mut g.b2, &mut g.w3] {
                for v in buf.iter_mut() {
                    *v *= s;
                }
            }
        }
    }

    /// Move layer `layer`'s segment (`per_layer` experts, layer-major
    /// ids) out into its own accumulator, leaving zero-sized
    /// placeholders. The stack's reverse walk hands each layer engine
    /// exactly its segment — continuing whatever that segment already
    /// held, so grad-accum order is untouched — and puts it back with
    /// [`restore_layer`](ExpertGrads::restore_layer).
    pub fn take_layer(&mut self, layer: usize, per_layer: usize) -> ExpertGrads {
        let base = layer * per_layer;
        let experts = self.experts[base..base + per_layer]
            .iter_mut()
            .map(|g| std::mem::replace(g, ExpertParams::zeros(0, 0)))
            .collect();
        ExpertGrads { d_model: self.d_model, d_hidden: self.d_hidden, experts }
    }

    /// Inverse of [`take_layer`](ExpertGrads::take_layer).
    pub fn restore_layer(&mut self, layer: usize, seg: ExpertGrads) {
        let base = layer * seg.experts.len();
        for (i, g) in seg.experts.into_iter().enumerate() {
            self.experts[base + i] = g;
        }
    }

    /// Clone layer `layer`'s segment (`per_layer` experts) as its own
    /// value — what the stack feeds each layer engine's `apply_update`.
    pub fn layer_slice(&self, layer: usize, per_layer: usize) -> ExpertGrads {
        let base = layer * per_layer;
        ExpertGrads {
            d_model: self.d_model,
            d_hidden: self.d_hidden,
            experts: self.experts[base..base + per_layer].to_vec(),
        }
    }

    /// Global L2 norm over every accumulator (metrics/diagnostics).
    pub fn l2_norm(&self) -> f64 {
        let mut acc = 0.0f64;
        for g in &self.experts {
            for s in [&g.w1, &g.b1, &g.w2, &g.b2, &g.w3] {
                for &v in s.iter() {
                    acc += (v as f64) * (v as f64);
                }
            }
        }
        acc.sqrt()
    }
}

/// The expert parameters owned by one EP rank.
#[derive(Debug, Clone, PartialEq)]
pub struct RankExperts {
    pub rank: usize,
    pub d_model: usize,
    pub d_hidden: usize,
    /// (global expert id, owned parameters), ascending by id
    pub experts: Vec<(u32, ExpertParams)>,
}

impl RankExperts {
    pub fn num_params(&self) -> usize {
        self.experts.iter().map(|(_, p)| p.num_params()).sum()
    }

    /// Parameter bytes resident on this rank (f32).
    pub fn param_bytes(&self) -> u64 {
        4 * self.num_params() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifact::LmParam;
    use std::collections::BTreeMap;

    fn lm_spec() -> LmSpec {
        LmSpec {
            batch: 2,
            params: vec![
                LmParam { name: "embed".into(), shape: vec![8, 4], init_scale: 0.02 },
                LmParam { name: "layer0.ln1".into(), shape: vec![4], init_scale: 1.0 },
                LmParam { name: "layer0.wq".into(), shape: vec![4, 4], init_scale: 0.5 },
            ],
            config: BTreeMap::new(),
        }
    }

    #[test]
    fn init_norm_gains_are_ones() {
        let s = ParamStore::init(&lm_spec(), 1);
        assert_eq!(s.params[1].as_f32().unwrap(), &[1.0; 4]);
        // weights are not all equal
        let w = s.params[2].as_f32().unwrap();
        assert!(w.iter().any(|&x| x != w[0]));
        assert_eq!(s.num_params(), 32 + 4 + 16);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("moeblaze_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("step10.ckpt");
        let mut s = ParamStore::init(&lm_spec(), 2);
        s.step = 10;
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.step, 10);
        assert_eq!(loaded.names, s.names);
        for i in 0..s.params.len() {
            assert_eq!(loaded.params[i].as_f32().unwrap(), s.params[i].as_f32().unwrap());
        }
        loaded.check_against(&lm_spec()).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_corrupt() {
        let dir = std::env::temp_dir().join("moeblaze_ckpt_bad");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTMAGIC123").unwrap();
        assert!(ParamStore::load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn check_against_catches_mismatch() {
        let s = ParamStore::init(&lm_spec(), 3);
        let mut other = lm_spec();
        other.params[2].shape = vec![4, 5];
        assert!(s.check_against(&other).is_err());
    }

    #[test]
    fn expert_store_shard_gather_roundtrip() {
        let store = ExpertStore::init(8, 16, 32, 7);
        assert_eq!(store.num_params(), 8 * (32 * 16 + 32 + 16 * 32 + 16));
        for rank_of in [vec![0, 0, 0, 0, 1, 1, 1, 1], vec![0, 1, 0, 1, 0, 1, 0, 1]] {
            let a = ExpertAssignment { ranks: 2, rank_of };
            let shards = store.shard(&a);
            assert_eq!(shards.iter().map(RankExperts::num_params).sum::<usize>(),
                       store.num_params());
            let back = ExpertStore::gather(&shards, 8).unwrap();
            assert_eq!(back, store);
        }
    }

    #[test]
    fn expert_init_is_placement_invariant() {
        // expert 5's weights are a pure function of (seed, 5)
        let a = ExpertStore::init(8, 4, 8, 42);
        let b = ExpertStore::init(16, 4, 8, 42);
        assert_eq!(a.experts[5], b.experts[5]);
        assert_ne!(a.experts[0], a.experts[1]);
    }

    #[test]
    fn expert_grads_zeros_clear_and_norm() {
        let mut g = ExpertGrads::zeros(4, 8, 12);
        assert_eq!(g.num_experts(), 4);
        assert_eq!(g.num_params(), 4 * (12 * 8 + 12 + 8 * 12 + 8));
        assert_eq!(g.l2_norm(), 0.0);
        g.check_like(4, 8, 12).unwrap();
        assert!(g.check_like(4, 8, 16).is_err());
        assert!(g.check_like(2, 8, 12).is_err());
        g.experts[1].w1[0] = 3.0;
        g.experts[2].b2[0] = 4.0;
        assert!((g.l2_norm() - 5.0).abs() < 1e-12);
        g.clear();
        assert_eq!(g.l2_norm(), 0.0);
    }

    #[test]
    fn gated_init_preserves_ungated_bits_and_adds_w3() {
        let plain = ExpertParams::init(6, 10, 99);
        let gated = ExpertParams::init_gated(6, 10, 99, true);
        // w3 is drawn after w2, so the ungated tensors are bit-identical
        assert_eq!(plain.w1, gated.w1);
        assert_eq!(plain.w2, gated.w2);
        assert!(plain.w3.is_empty() && !plain.gated());
        assert_eq!(gated.w3.len(), 10 * 6);
        assert!(gated.gated());
        assert_eq!(gated.num_params(), plain.num_params() + 10 * 6);
        let store = ExpertStore::init_gated(3, 6, 10, 99, true);
        assert!(store.gated());
        assert!(!ExpertStore::init(3, 6, 10, 99).gated());
        let mut g = ExpertGrads::zeros_gated(2, 6, 10, true);
        g.experts[0].w3[0] = 3.0;
        g.experts[1].w3[1] = 4.0;
        assert!((g.l2_norm() - 5.0).abs() < 1e-12);
        g.scale(2.0);
        assert_eq!(g.experts[0].w3[0], 6.0);
        g.clear();
        assert_eq!(g.l2_norm(), 0.0);
    }

    #[test]
    fn expert_grads_layer_segments_roundtrip() {
        let mut g = ExpertGrads::zeros(6, 4, 8); // 3 layers × 2 experts
        g.experts[2].w1[0] = 7.0; // layer 1, expert 0
        g.experts[5].b2[0] = 3.0; // layer 2, expert 1
        let seg = g.layer_slice(1, 2);
        assert_eq!(seg.experts.len(), 2);
        assert_eq!(seg.experts[0].w1[0], 7.0);
        let taken = g.take_layer(2, 2);
        assert_eq!(taken.experts[1].b2[0], 3.0);
        assert!(g.experts[4].w1.is_empty(), "placeholder left behind");
        g.restore_layer(2, taken);
        assert_eq!(g.experts[5].b2[0], 3.0);
        assert_eq!(g.num_experts(), 6);
    }

    #[test]
    fn expert_store_concat_is_layer_major() {
        let a = ExpertStore::init(2, 4, 8, 1);
        let b = ExpertStore::init(2, 4, 8, 2);
        let cat = ExpertStore::concat(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(cat.experts.len(), 4);
        assert_eq!(cat.experts[1], a.experts[1]);
        assert_eq!(cat.experts[2], b.experts[0]);
        let bad = ExpertStore::init(2, 6, 8, 3);
        assert!(ExpertStore::concat(&[a, bad]).is_err());
        assert!(ExpertStore::concat(&[]).is_err());
    }

    #[test]
    fn gather_rejects_incomplete_ownership() {
        let store = ExpertStore::init(4, 4, 8, 1);
        let a = ExpertAssignment { ranks: 2, rank_of: vec![0, 0, 1, 1] };
        let shards = store.shard(&a);
        assert!(ExpertStore::gather(&shards[..1], 4).is_err());
        let dup = vec![shards[0].clone(), shards[0].clone()];
        assert!(ExpertStore::gather(&dup, 4).is_err());
    }
}

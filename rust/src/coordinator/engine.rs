//! Rank-sharded expert-parallel execution engine.
//!
//! [`ExecutionEngine`] abstracts "run one MoE layer step over routed
//! activations" so the coordinator no longer assumes one rank and one
//! executable:
//!
//! * [`SingleRankEngine`] — the existing single-rank path: all experts
//!   local, gather → expert FFN → combine, no communication.
//! * [`ShardedEngine`] — R simulated ranks, each driven by one worker
//!   thread of the hand-rolled pool. Every step it (i) slices the
//!   [`DispatchStructures`] into per-rank views (`dispatch::shard`),
//!   (ii) executes the dispatch all-to-all with *real* buffer packing
//!   and unpacking so exchanged bytes are measured rather than
//!   estimated, (iii) runs per-rank expert compute and the combine
//!   scatter, and (iv) mirrors the exchange for routed gradients in
//!   `backward_update`.
//!
//! Both engines are bit-deterministic: identical inputs give bitwise
//! identical outputs and parameter updates for any R and any placement,
//! because per-row expert math is order-free and every accumulation
//! (combine over k, gradients over a segment) runs in the same fixed
//! order. `rust/tests/ep_engine.rs` pins this, and pins the measured
//! dispatch traffic to [`AllToAllPlan::cross_rank_bytes`] — the planner
//! in `expert_parallel` is this engine's dry-run mode.
//!
//! [`AllToAllPlan::cross_rank_bytes`]: super::expert_parallel::AllToAllPlan::cross_rank_bytes

use crate::config::ep::EpConfig;
use crate::dispatch::gating::synthetic_gating;
use crate::dispatch::parallel_build::parallel_build;
use crate::dispatch::shard::{shard, RankShard};
use crate::dispatch::structures::DispatchStructures;
use crate::memory::model::MemoryBreakdown;
use crate::util::prng::Rng;
use crate::util::threadpool::par_map;

use super::expert_parallel::EpTopology;
use super::params::{ExpertParams, ExpertStore, RankExperts};

/// Bytes and rows moved by the last forward/backward pass, measured at
/// the buffers (f32 rows, `4·d` bytes each).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Traffic {
    /// dispatch all-to-all: routed activation rows crossing ranks (fwd)
    pub dispatch_bytes: u64,
    /// combine: expert-output rows returned to their home rank (fwd)
    pub combine_bytes: u64,
    /// routed gradient rows crossing ranks (bwd mirror of dispatch)
    pub grad_bytes: u64,
    /// routed rows that crossed a rank boundary in the fwd dispatch
    pub cross_rows: u64,
    /// routed rows that stayed on their home rank
    pub local_rows: u64,
}

/// One MoE-layer step executor (forward + SGD backward on expert FFNs).
pub trait ExecutionEngine {
    fn name(&self) -> String;

    fn ranks(&self) -> usize;

    /// Combined (L, d) output for token activations `x` (L, d) routed by
    /// `disp` with per-slot combine weights `gates` (L·k, token-major).
    fn forward(&mut self, disp: &DispatchStructures, x: &[f32],
               gates: &[f32]) -> Result<Vec<f32>, String>;

    /// One SGD step on the expert parameters given `d_out` = ∂loss/∂out
    /// (L, d) from the last forward. Activations are recomputed from the
    /// cached routed inputs (the paper's Algorithm-1 policy: keep inputs,
    /// recompute intermediates).
    fn backward_update(&mut self, d_out: &[f32], lr: f32) -> Result<(), String>;

    /// Communication measured since the last forward began.
    fn traffic(&self) -> Traffic;

    /// Per-rank activation-memory breakdown of the last forward
    /// (`data` = activation rows, `index` = routing metadata, `extra` =
    /// packed comm buffers) — the Figures 3/5 accounting, per rank.
    fn memory_per_rank(&self) -> Vec<MemoryBreakdown>;

    /// Reassembled global expert parameters (for equivalence checks and
    /// checkpointing).
    fn gather_params(&self) -> Result<ExpertStore, String>;
}

// -- shared per-row expert math ---------------------------------------------

#[inline]
fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// y = W2·silu(W1·x + b1) + b2. Pure function of one row — bit-identical
/// wherever (and on whatever thread) it runs.
fn expert_forward(p: &ExpertParams, d: usize, h: usize, x: &[f32],
                  y: &mut [f32], hidden: &mut [f32]) {
    for i in 0..h {
        let row = &p.w1[i * d..(i + 1) * d];
        let mut acc = p.b1[i];
        for j in 0..d {
            acc += row[j] * x[j];
        }
        hidden[i] = silu(acc);
    }
    for i in 0..d {
        let row = &p.w2[i * h..(i + 1) * h];
        let mut acc = p.b2[i];
        for j in 0..h {
            acc += row[j] * hidden[j];
        }
        y[i] = acc;
    }
}

/// Accumulate one row's parameter gradients, recomputing the hidden
/// activations from `x` (they are not saved across the fwd/bwd boundary).
fn expert_backward(p: &ExpertParams, g: &mut ExpertParams, d: usize, h: usize,
                   x: &[f32], dy: &[f32], pre: &mut [f32], act: &mut [f32],
                   dz: &mut [f32]) {
    // recompute pre-activation and silu
    for i in 0..h {
        let row = &p.w1[i * d..(i + 1) * d];
        let mut acc = p.b1[i];
        for j in 0..d {
            acc += row[j] * x[j];
        }
        pre[i] = acc;
        act[i] = silu(acc);
    }
    // W2 / b2 grads and dz = W2ᵀ·dy
    for j in 0..h {
        dz[j] = 0.0;
    }
    for i in 0..d {
        g.b2[i] += dy[i];
        let grow = &mut g.w2[i * h..(i + 1) * h];
        let wrow = &p.w2[i * h..(i + 1) * h];
        for j in 0..h {
            grow[j] += dy[i] * act[j];
            dz[j] += dy[i] * wrow[j];
        }
    }
    // through silu: silu'(a) = σ(a)·(1 + a·(1 − σ(a)))
    for j in 0..h {
        let sig = 1.0 / (1.0 + (-pre[j]).exp());
        let da = dz[j] * sig * (1.0 + pre[j] * (1.0 - sig));
        g.b1[j] += da;
        let grow = &mut g.w1[j * d..(j + 1) * d];
        for c in 0..d {
            grow[c] += da * x[c];
        }
    }
}

fn sgd(p: &mut ExpertParams, g: &ExpertParams, lr: f32) {
    for (w, gw) in p.w1.iter_mut().zip(&g.w1) {
        *w -= lr * gw;
    }
    for (w, gw) in p.b1.iter_mut().zip(&g.b1) {
        *w -= lr * gw;
    }
    for (w, gw) in p.w2.iter_mut().zip(&g.w2) {
        *w -= lr * gw;
    }
    for (w, gw) in p.b2.iter_mut().zip(&g.b2) {
        *w -= lr * gw;
    }
}

fn check_shapes(disp: &DispatchStructures, x: &[f32], gates: &[f32],
                d: usize, num_experts: usize) -> Result<(), String> {
    if disp.num_experts != num_experts {
        return Err(format!(
            "dispatch has {} experts, engine owns {num_experts}",
            disp.num_experts
        ));
    }
    if x.len() != disp.num_tokens * d {
        return Err(format!(
            "x has {} elements, expected L·d = {}",
            x.len(),
            disp.num_tokens * d
        ));
    }
    if gates.len() != disp.slots() {
        return Err(format!(
            "gates has {} elements, expected L·k = {}",
            gates.len(),
            disp.slots()
        ));
    }
    Ok(())
}

// -- single-rank engine -----------------------------------------------------

struct SingleState {
    disp: DispatchStructures,
    x: Vec<f32>,
    gates: Vec<f32>,
}

/// All experts on one rank — the reference path the sharded engine is
/// verified against bit-for-bit.
pub struct SingleRankEngine {
    pub store: ExpertStore,
    state: Option<SingleState>,
}

impl SingleRankEngine {
    pub fn new(store: ExpertStore) -> SingleRankEngine {
        SingleRankEngine { store, state: None }
    }
}

impl ExecutionEngine for SingleRankEngine {
    fn name(&self) -> String {
        "single-rank".into()
    }

    fn ranks(&self) -> usize {
        1
    }

    fn forward(&mut self, disp: &DispatchStructures, x: &[f32],
               gates: &[f32]) -> Result<Vec<f32>, String> {
        let (d, h) = (self.store.d_model, self.store.d_hidden);
        check_shapes(disp, x, gates, d, self.store.experts.len())?;
        let (l, k, n) = (disp.num_tokens, disp.top_k, disp.slots());

        // expert compute, expert-major
        let mut ys = vec![0.0f32; n * d];
        let mut hidden = vec![0.0f32; h];
        for (e, p) in self.store.experts.iter().enumerate() {
            let lo = disp.expert_token_offsets[e] as usize;
            let hi = disp.expert_token_offsets[e + 1] as usize;
            for pos in lo..hi {
                let tok = disp.expert_token_indices[pos] as usize;
                expert_forward(p, d, h, &x[tok * d..(tok + 1) * d],
                               &mut ys[pos * d..(pos + 1) * d], &mut hidden);
            }
        }
        // combine scatter, token-major, fixed j order
        let mut out = vec![0.0f32; l * d];
        for i in 0..l {
            for j in 0..k {
                let slot = i * k + j;
                let g = gates[slot];
                let pos = disp.token_index_map[slot] as usize;
                let row = &ys[pos * d..(pos + 1) * d];
                let o = &mut out[i * d..(i + 1) * d];
                for c in 0..d {
                    o[c] += g * row[c];
                }
            }
        }
        self.state = Some(SingleState {
            disp: disp.clone(),
            x: x.to_vec(),
            gates: gates.to_vec(),
        });
        Ok(out)
    }

    fn backward_update(&mut self, d_out: &[f32], lr: f32) -> Result<(), String> {
        let (d, h) = (self.store.d_model, self.store.d_hidden);
        let st = self.state.as_ref().ok_or("backward_update before forward")?;
        if d_out.len() != st.disp.num_tokens * d {
            return Err(format!(
                "d_out has {} elements, expected L·d = {}",
                d_out.len(),
                st.disp.num_tokens * d
            ));
        }
        // origin slot per global position (for the per-slot gate)
        let mut origin_of_pos = vec![0u32; st.disp.slots()];
        for (slot, &pos) in st.disp.token_index_map.iter().enumerate() {
            origin_of_pos[pos as usize] = slot as u32;
        }
        let mut pre = vec![0.0f32; h];
        let mut act = vec![0.0f32; h];
        let mut dz = vec![0.0f32; h];
        let mut dy = vec![0.0f32; d];
        for (e, p) in self.store.experts.iter_mut().enumerate() {
            let mut g = ExpertParams::zeros(d, h);
            let lo = st.disp.expert_token_offsets[e] as usize;
            let hi = st.disp.expert_token_offsets[e + 1] as usize;
            for pos in lo..hi {
                let tok = st.disp.expert_token_indices[pos] as usize;
                let gate = st.gates[origin_of_pos[pos] as usize];
                for c in 0..d {
                    dy[c] = gate * d_out[tok * d + c];
                }
                expert_backward(p, &mut g, d, h, &st.x[tok * d..(tok + 1) * d],
                                &dy, &mut pre, &mut act, &mut dz);
            }
            sgd(p, &g, lr);
        }
        Ok(())
    }

    fn traffic(&self) -> Traffic {
        let local = self
            .state
            .as_ref()
            .map(|s| s.disp.slots() as u64)
            .unwrap_or(0);
        Traffic { local_rows: local, ..Traffic::default() }
    }

    fn memory_per_rank(&self) -> Vec<MemoryBreakdown> {
        let Some(st) = self.state.as_ref() else {
            return vec![MemoryBreakdown { data_bytes: 0, index_bytes: 0,
                                          extra_bytes: 0 }];
        };
        let d = self.store.d_model as u64;
        let n = st.disp.slots() as u64;
        let l = st.disp.num_tokens as u64;
        vec![MemoryBreakdown {
            // routed rows (ys) + resident token activations + output
            data_bytes: 4 * d * (n + 2 * l),
            index_bytes: st.disp.metadata_bytes() as u64,
            extra_bytes: 0,
        }]
    }

    fn gather_params(&self) -> Result<ExpertStore, String> {
        Ok(self.store.clone())
    }
}

// -- sharded engine ---------------------------------------------------------

/// One routed row's path through the exchange: destination-local slot,
/// its global token, and its token-major origin slot.
#[derive(Debug, Clone, Copy)]
struct RouteHop {
    local_slot: u32,
    token: u32,
    origin: u32,
}

struct ShardedState {
    shards: Vec<RankShard>,
    /// routes[dst][src]: hops served by `src`, in dst-local slot order
    routes: Vec<Vec<Vec<RouteHop>>>,
    /// per rank: routed input rows for its local slots (kept for bwd)
    xs_local: Vec<Vec<f32>>,
    gates: Vec<f32>,
    num_tokens: usize,
}

/// R simulated ranks over the worker pool, real buffer packing, measured
/// traffic.
pub struct ShardedEngine {
    pub topo: EpTopology,
    pub rank_params: Vec<RankExperts>,
    d_model: usize,
    d_hidden: usize,
    workers: usize,
    state: Option<ShardedState>,
    traffic: Traffic,
    mem: Vec<MemoryBreakdown>,
}

impl ShardedEngine {
    /// `workers` caps the threads driving ranks (one rank per worker at a
    /// time; R > workers just queues ranks, changing nothing observable).
    pub fn new(topo: EpTopology, store: &ExpertStore,
               workers: usize) -> Result<ShardedEngine, String> {
        if topo.num_experts != store.experts.len() {
            return Err(format!(
                "topology has {} experts, store has {}",
                topo.num_experts,
                store.experts.len()
            ));
        }
        let rank_params = store.shard(&topo.assignment());
        Ok(ShardedEngine {
            topo,
            rank_params,
            d_model: store.d_model,
            d_hidden: store.d_hidden,
            workers: workers.max(1),
            state: None,
            traffic: Traffic::default(),
            mem: Vec::new(),
        })
    }
}

impl ExecutionEngine for ShardedEngine {
    fn name(&self) -> String {
        format!("sharded-r{}-{}", self.topo.ranks, self.topo.placement)
    }

    fn ranks(&self) -> usize {
        self.topo.ranks
    }

    fn forward(&mut self, disp: &DispatchStructures, x: &[f32],
               gates: &[f32]) -> Result<Vec<f32>, String> {
        let (d, h) = (self.d_model, self.d_hidden);
        check_shapes(disp, x, gates, d, self.topo.num_experts)?;
        let (l, k, r) = (disp.num_tokens, disp.top_k, self.topo.ranks);
        let workers = self.workers.min(r);

        // (i) slice the dispatch structures into per-rank views
        let shards = shard(disp, &self.topo.assignment())?;

        // routing table of the exchange: who sends which rows where
        let mut routes: Vec<Vec<Vec<RouteHop>>> =
            (0..r).map(|_| vec![Vec::new(); r]).collect();
        let mut ret_lookup = vec![(0u32, 0u32); disp.slots()];
        for (dst, s) in shards.iter().enumerate() {
            for (local_slot, (&token, &origin)) in s
                .expert_token_indices
                .iter()
                .zip(&s.origin_slots)
                .enumerate()
            {
                let src = self.topo.rank_of_token(token as usize, l);
                let hops = &mut routes[dst][src];
                ret_lookup[origin as usize] = (dst as u32, hops.len() as u32);
                hops.push(RouteHop { local_slot: local_slot as u32, token,
                                     origin });
            }
        }
        let mut tokens_of_rank: Vec<Vec<u32>> = vec![Vec::new(); r];
        for t in 0..l {
            tokens_of_rank[self.topo.rank_of_token(t, l)].push(t as u32);
        }

        // (ii) dispatch all-to-all: each source rank packs one buffer per
        // destination from its resident token rows
        let routes_ref = &routes;
        let send: Vec<Vec<Vec<f32>>> = par_map(r, workers, |src| {
            (0..r)
                .map(|dst| {
                    let hops = &routes_ref[dst][src];
                    let mut buf = Vec::with_capacity(hops.len() * d);
                    for hop in hops {
                        let t = hop.token as usize;
                        buf.extend_from_slice(&x[t * d..(t + 1) * d]);
                    }
                    buf
                })
                .collect()
        });
        let mut traffic = Traffic::default();
        for src in 0..r {
            for dst in 0..r {
                let rows = routes[dst][src].len() as u64;
                if src == dst {
                    traffic.local_rows += rows;
                } else {
                    traffic.cross_rows += rows;
                    traffic.dispatch_bytes += (send[src][dst].len() * 4) as u64;
                }
            }
        }

        // (iii) per-rank unpack, expert compute, and combine-buffer pack
        let send_ref = &send;
        let shards_ref = &shards;
        let params_ref = &self.rank_params;
        let computed: Vec<(Vec<f32>, Vec<Vec<f32>>)> =
            par_map(r, workers, |dst| {
                let s = &shards_ref[dst];
                let n_local = s.local_slots();
                let mut xs = vec![0.0f32; n_local * d];
                for src in 0..r {
                    for (i, hop) in routes_ref[dst][src].iter().enumerate() {
                        let ls = hop.local_slot as usize;
                        xs[ls * d..(ls + 1) * d]
                            .copy_from_slice(&send_ref[src][dst][i * d..(i + 1) * d]);
                    }
                }
                let mut ys = vec![0.0f32; n_local * d];
                let mut hidden = vec![0.0f32; h];
                for (i, (e, p)) in params_ref[dst].experts.iter().enumerate() {
                    debug_assert_eq!(*e, s.experts[i]);
                    let lo = s.expert_token_offsets[i] as usize;
                    let hi = s.expert_token_offsets[i + 1] as usize;
                    for ls in lo..hi {
                        expert_forward(p, d, h, &xs[ls * d..(ls + 1) * d],
                                       &mut ys[ls * d..(ls + 1) * d],
                                       &mut hidden);
                    }
                }
                // pack expert outputs back toward each home rank
                let rets: Vec<Vec<f32>> = (0..r)
                    .map(|src| {
                        let hops = &routes_ref[dst][src];
                        let mut buf = Vec::with_capacity(hops.len() * d);
                        for hop in hops {
                            let ls = hop.local_slot as usize;
                            buf.extend_from_slice(&ys[ls * d..(ls + 1) * d]);
                        }
                        buf
                    })
                    .collect();
                (xs, rets)
            });
        let mut xs_local = Vec::with_capacity(r);
        let mut rets = Vec::with_capacity(r);
        for (xs, ret) in computed {
            xs_local.push(xs);
            rets.push(ret);
        }
        for dst in 0..r {
            for src in 0..r {
                if src != dst {
                    traffic.combine_bytes += (rets[dst][src].len() * 4) as u64;
                }
            }
        }

        // combine scatter on each token's home rank (same j order as the
        // single-rank path — bit-identical accumulation)
        let rets_ref = &rets;
        let lookup_ref = &ret_lookup;
        let tokens_ref = &tokens_of_rank;
        let home_rows: Vec<Vec<f32>> = par_map(r, workers, |home| {
            let toks = &tokens_ref[home];
            let mut rows = vec![0.0f32; toks.len() * d];
            for (ti, &t) in toks.iter().enumerate() {
                let o = &mut rows[ti * d..(ti + 1) * d];
                for j in 0..k {
                    let slot = t as usize * k + j;
                    let g = gates[slot];
                    let (dst, idx) = lookup_ref[slot];
                    let buf = &rets_ref[dst as usize][home];
                    let row = &buf[idx as usize * d..(idx as usize + 1) * d];
                    for c in 0..d {
                        o[c] += g * row[c];
                    }
                }
            }
            rows
        });
        let mut out = vec![0.0f32; l * d];
        for (home, rows) in home_rows.iter().enumerate() {
            for (ti, &t) in tokens_of_rank[home].iter().enumerate() {
                out[t as usize * d..(t as usize + 1) * d]
                    .copy_from_slice(&rows[ti * d..(ti + 1) * d]);
            }
        }

        // per-rank Figure-3/5 accounting from what was actually resident
        self.mem = (0..r)
            .map(|rank| {
                let n_local = shards[rank].local_slots() as u64;
                let resident = tokens_of_rank[rank].len() as u64;
                let comm: u64 = (0..r)
                    .map(|peer| {
                        (send[rank][peer].len() + rets[rank][peer].len()) as u64 * 4
                    })
                    .sum();
                MemoryBreakdown {
                    // xs + ys per local slot, plus resident token rows in
                    // and combined rows out
                    data_bytes: 4 * d as u64 * (2 * n_local + 2 * resident),
                    index_bytes: shards[rank].metadata_bytes() as u64,
                    extra_bytes: comm,
                }
            })
            .collect();
        self.traffic = traffic;
        self.state = Some(ShardedState {
            shards,
            routes,
            xs_local,
            gates: gates.to_vec(),
            num_tokens: l,
        });
        Ok(out)
    }

    fn backward_update(&mut self, d_out: &[f32], lr: f32) -> Result<(), String> {
        let (d, h) = (self.d_model, self.d_hidden);
        let st = self.state.as_ref().ok_or("backward_update before forward")?;
        if d_out.len() != st.num_tokens * d {
            return Err(format!(
                "d_out has {} elements, expected L·d = {}",
                d_out.len(),
                st.num_tokens * d
            ));
        }
        let r = self.topo.ranks;
        let workers = self.workers.min(r);

        // backward all-to-all: each home rank packs gated gradient rows
        // toward the expert ranks (mirror of the fwd dispatch)
        let routes_ref = &st.routes;
        let gates_ref = &st.gates;
        let dsend: Vec<Vec<Vec<f32>>> = par_map(r, workers, |home| {
            (0..r)
                .map(|dst| {
                    let hops = &routes_ref[dst][home];
                    let mut buf = Vec::with_capacity(hops.len() * d);
                    for hop in hops {
                        let t = hop.token as usize;
                        let g = gates_ref[hop.origin as usize];
                        for c in 0..d {
                            buf.push(g * d_out[t * d + c]);
                        }
                    }
                    buf
                })
                .collect()
        });
        let mut grad_bytes = 0u64;
        for home in 0..r {
            for dst in 0..r {
                if home != dst {
                    grad_bytes += (dsend[home][dst].len() * 4) as u64;
                }
            }
        }

        // per-rank gradient accumulation (recompute policy) + in-place
        // SGD update: scope_chunks hands each worker exclusive &mut
        // access to its rank's parameters — no per-step clone
        let dsend_ref = &dsend;
        let shards_ref = &st.shards;
        let xs_ref = &st.xs_local;
        crate::util::threadpool::scope_chunks(
            &mut self.rank_params, 1, workers, |dst, chunk| {
                let mine = &mut chunk[0];
                let s = &shards_ref[dst];
                let n_local = s.local_slots();
                let mut dys = vec![0.0f32; n_local * d];
                for src in 0..r {
                    for (i, hop) in routes_ref[dst][src].iter().enumerate() {
                        let ls = hop.local_slot as usize;
                        dys[ls * d..(ls + 1) * d]
                            .copy_from_slice(&dsend_ref[src][dst][i * d..(i + 1) * d]);
                    }
                }
                let xs = &xs_ref[dst];
                let mut pre = vec![0.0f32; h];
                let mut act = vec![0.0f32; h];
                let mut dz = vec![0.0f32; h];
                for (i, (_, p)) in mine.experts.iter_mut().enumerate() {
                    let mut g = ExpertParams::zeros(d, h);
                    let lo = s.expert_token_offsets[i] as usize;
                    let hi = s.expert_token_offsets[i + 1] as usize;
                    for ls in lo..hi {
                        expert_backward(p, &mut g, d, h,
                                        &xs[ls * d..(ls + 1) * d],
                                        &dys[ls * d..(ls + 1) * d], &mut pre,
                                        &mut act, &mut dz);
                    }
                    sgd(p, &g, lr);
                }
            });
        self.traffic.grad_bytes = grad_bytes;
        Ok(())
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn memory_per_rank(&self) -> Vec<MemoryBreakdown> {
        if self.mem.is_empty() {
            vec![
                MemoryBreakdown { data_bytes: 0, index_bytes: 0, extra_bytes: 0 };
                self.topo.ranks
            ]
        } else {
            self.mem.clone()
        }
    }

    fn gather_params(&self) -> Result<ExpertStore, String> {
        ExpertStore::gather(&self.rank_params, self.topo.num_experts)
    }
}

/// The synthetic workload an `[ep]` config describes — routing, token
/// activations `x` (L·d), combine gates (L·k), and regression targets
/// (L·d). A pure function of the config, shared by `EpTrainer` and the
/// `ep-bench` subcommand so they exercise the identical exchange.
pub fn workload_from_config(
    cfg: &EpConfig,
) -> (DispatchStructures, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (l, e, k, d) = (cfg.tokens, cfg.num_experts, cfg.top_k, cfg.d_model);
    let mut rng = Rng::new(cfg.seed ^ 0xE9E9);
    let gating = synthetic_gating(&mut rng, l, e, k, cfg.skew);
    let disp = parallel_build(&gating.topk_ids, l, e, k);
    let x = rng.normal_vec(l * d, 1.0);
    let target = rng.normal_vec(l * d, 1.0);
    (disp, x, gating.gates, target)
}

/// Build the engine an `[ep]` config describes: R = 1 gives the
/// single-rank path, R > 1 the sharded one (one worker per rank). The
/// expert parameters are initialized from `cfg.seed`, so any two engines
/// built from the same config hold bit-identical weights.
pub fn engine_from_config(cfg: &EpConfig) -> Result<Box<dyn ExecutionEngine>, String> {
    cfg.validate()?;
    let store = ExpertStore::init(cfg.num_experts, cfg.d_model, cfg.d_hidden,
                                  cfg.seed);
    if cfg.ranks == 1 {
        Ok(Box::new(SingleRankEngine::new(store)))
    } else {
        let topo = EpTopology::with_placement(cfg.ranks, cfg.num_experts,
                                              cfg.placement)?;
        Ok(Box::new(ShardedEngine::new(topo, &store, cfg.ranks)?))
    }
}

// -- equivalence harness ----------------------------------------------------

/// Outcome of one sharded-vs-single verification run.
#[derive(Debug, Clone)]
pub struct EquivalenceReport {
    pub ranks: usize,
    pub bitwise_equal: bool,
    pub max_abs_diff: f64,
    pub measured_dispatch_bytes: u64,
    pub planned_cross_bytes: u64,
}

impl EquivalenceReport {
    pub fn ok(&self) -> bool {
        self.bitwise_equal
            && self.measured_dispatch_bytes == self.planned_cross_bytes
    }
}

/// Run the same workload through [`SingleRankEngine`] and
/// [`ShardedEngine`], compare outputs bit-for-bit, and check the measured
/// dispatch traffic against the analytic plan (f32 rows, dtype = 4).
pub fn check_equivalence(topo: &EpTopology, store: &ExpertStore,
                         disp: &DispatchStructures, x: &[f32],
                         gates: &[f32]) -> Result<EquivalenceReport, String> {
    let mut single = SingleRankEngine::new(store.clone());
    let mut sharded = ShardedEngine::new(topo.clone(), store, topo.ranks)?;
    let a = single.forward(disp, x, gates)?;
    let b = sharded.forward(disp, x, gates)?;
    if a.len() != b.len() {
        return Err("engines returned different output sizes".into());
    }
    let bitwise_equal = a
        .iter()
        .zip(&b)
        .all(|(p, q)| p.to_bits() == q.to_bits());
    let max_abs_diff = a
        .iter()
        .zip(&b)
        .map(|(p, q)| (*p as f64 - *q as f64).abs())
        .fold(0.0f64, f64::max);
    let plan = topo.plan(disp, store.d_model, 4);
    Ok(EquivalenceReport {
        ranks: topo.ranks,
        bitwise_equal,
        max_abs_diff,
        measured_dispatch_bytes: sharded.traffic().dispatch_bytes,
        planned_cross_bytes: plan.cross_rank_bytes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ep::Placement;
    use crate::dispatch::gating::synthetic_gating;
    use crate::dispatch::parallel_build::parallel_build;
    use crate::testkit::fixtures::{fig2_expected, FIG2_EXPERTS, FIG2_TOKENS,
                                   FIG2_TOP_K};
    use crate::util::prng::Rng;

    fn workload(l: usize, e: usize, k: usize, d: usize, skew: f64,
                seed: u64) -> (DispatchStructures, Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let g = synthetic_gating(&mut rng, l, e, k, skew);
        let disp = parallel_build(&g.topk_ids, l, e, k);
        let x = rng.normal_vec(l * d, 1.0);
        (disp, x, g.gates)
    }

    #[test]
    fn figure2_bit_equality_across_rank_counts() {
        let disp = fig2_expected();
        let mut rng = Rng::new(3);
        let d = 8;
        let x = rng.normal_vec(FIG2_TOKENS * d, 1.0);
        let gates = vec![0.5f32; FIG2_TOKENS * FIG2_TOP_K];
        let store = ExpertStore::init(FIG2_EXPERTS, d, 16, 11);
        for ranks in [1, 2, 4] {
            let topo = EpTopology::new(ranks, FIG2_EXPERTS).unwrap();
            let rep = check_equivalence(&topo, &store, &disp, &x, &gates)
                .unwrap();
            assert!(rep.bitwise_equal, "R={ranks}: diff {}", rep.max_abs_diff);
            assert_eq!(rep.measured_dispatch_bytes, rep.planned_cross_bytes,
                       "R={ranks}");
        }
    }

    #[test]
    fn random_gating_bit_equality_and_measured_bytes() {
        let (disp, x, gates) = workload(96, 8, 2, 16, 1.2, 21);
        let store = ExpertStore::init(8, 16, 24, 5);
        for placement in [Placement::Contiguous, Placement::Strided] {
            for ranks in [1, 2, 4, 8] {
                let topo =
                    EpTopology::with_placement(ranks, 8, placement).unwrap();
                let rep = check_equivalence(&topo, &store, &disp, &x, &gates)
                    .unwrap();
                assert!(rep.ok(),
                        "R={ranks} {placement}: bitwise={} bytes {} vs {}",
                        rep.bitwise_equal, rep.measured_dispatch_bytes,
                        rep.planned_cross_bytes);
            }
        }
    }

    #[test]
    fn all_to_one_expert_skew_still_equal() {
        let l = 40;
        let d = 8;
        let ids = vec![0u32; l];
        let disp = parallel_build(&ids, l, 4, 1);
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(l * d, 1.0);
        let gates = vec![1.0f32; l];
        let store = ExpertStore::init(4, d, 12, 2);
        let topo = EpTopology::new(4, 4).unwrap();
        let rep = check_equivalence(&topo, &store, &disp, &x, &gates).unwrap();
        assert!(rep.ok());
    }

    #[test]
    fn training_is_bitwise_identical_across_sharding() {
        // 3 SGD steps on the same workload: losses and final parameters
        // must match bit-for-bit between R=1 and R=4
        let (disp, x, gates) = workload(64, 8, 2, 12, 0.8, 33);
        let l = disp.num_tokens;
        let d = 12;
        let store = ExpertStore::init(8, d, 16, 77);
        let mut rng = Rng::new(55);
        let target = rng.normal_vec(l * d, 1.0);

        let run = |engine: &mut dyn ExecutionEngine| -> Vec<f64> {
            let mut losses = Vec::new();
            for _ in 0..3 {
                let out = engine.forward(&disp, &x, &gates).unwrap();
                let mut loss = 0.0f64;
                let mut d_out = vec![0.0f32; out.len()];
                let scale = 2.0 / out.len() as f32;
                for i in 0..out.len() {
                    let diff = out[i] - target[i];
                    loss += (diff as f64) * (diff as f64);
                    d_out[i] = scale * diff;
                }
                engine.backward_update(&d_out, 0.1).unwrap();
                losses.push(loss / out.len() as f64);
            }
            losses
        };

        let mut single = SingleRankEngine::new(store.clone());
        let topo = EpTopology::new(4, 8).unwrap();
        let mut sharded = ShardedEngine::new(topo, &store, 4).unwrap();
        let la = run(&mut single);
        let lb = run(&mut sharded);
        assert_eq!(la, lb, "losses diverged");
        assert!(la[2] < la[0], "training did not reduce the loss: {la:?}");
        let pa = single.gather_params().unwrap();
        let pb = sharded.gather_params().unwrap();
        assert_eq!(pa, pb, "trained parameters diverged");
    }

    #[test]
    fn traffic_accounting_is_conserved() {
        let (disp, x, gates) = workload(128, 8, 2, 8, 0.5, 4);
        let store = ExpertStore::init(8, 8, 12, 1);
        let topo = EpTopology::new(2, 8).unwrap();
        let mut eng = ShardedEngine::new(topo, &store, 2).unwrap();
        eng.forward(&disp, &x, &gates).unwrap();
        let t = eng.traffic();
        assert_eq!(t.cross_rows + t.local_rows, disp.slots() as u64);
        assert_eq!(t.dispatch_bytes, t.cross_rows * 8 * 4);
        // combine returns exactly the rows that were dispatched
        assert_eq!(t.combine_bytes, t.dispatch_bytes);
        // memory accounting covers every rank and the routed rows
        let mem = eng.memory_per_rank();
        assert_eq!(mem.len(), 2);
        let data: u64 = mem.iter().map(|m| m.data_bytes).sum();
        assert!(data >= disp.slots() as u64 * 8 * 4);
    }

    #[test]
    fn shape_validation() {
        let (disp, x, gates) = workload(16, 4, 2, 4, 0.0, 8);
        let store = ExpertStore::init(4, 4, 8, 3);
        let mut eng = SingleRankEngine::new(store.clone());
        assert!(eng.backward_update(&[0.0; 64], 0.1).is_err());
        assert!(eng.forward(&disp, &x[..8], &gates).is_err());
        assert!(eng.forward(&disp, &x, &gates[..3]).is_err());
        let bad_store = ExpertStore::init(8, 4, 8, 3);
        let mut bad = SingleRankEngine::new(bad_store);
        assert!(bad.forward(&disp, &x, &gates).is_err());
    }
}

//! Rank-sharded expert-parallel execution engine — the step-session API.
//!
//! # Step-session lifecycle
//!
//! One training step is a *session* between a caller-owned workload and
//! an engine:
//!
//! ```text
//! StepBatch::new(disp, x, gates)        built once, Arc-shared, never
//!   │                                   copied again (copy counter = 0)
//!   ▼
//! engine.forward(&batch) ─────────────► StepHandle   (session opens)
//!   │                                     │ output()
//!   ▼                                     ▼
//! handle.backward(engine, d_out) ──────► ExpertGrads (session ends)
//!   │        or backward_into(…, &mut grads) to accumulate microbatches
//!   ▼
//! optimizer.step(&grads, lr) ──────────► delta
//! engine.apply_update(&delta)
//! ```
//!
//! [`StepHandle`] is a typestate token: it is the only way to reach the
//! backward pass, it is consumed by it, and it is invalidated by any
//! newer `forward` — "backward without forward" and "backward against
//! stale saved state" are unrepresentable rather than runtime footguns.
//! Gradient computation is decoupled from the update ([`ExpertGrads`] +
//! the `coordinator::optim::Optimizer` trait), which is what makes
//! grad-accum microbatching and Adam possible.
//!
//! # Checkpoint policies
//!
//! What a session saves across the fwd→bwd boundary is the measurable
//! [`CheckpointPolicy`] axis (per routed slot, f32):
//!
//! | policy         | saved                  | bytes/slot | bwd extra work        |
//! |----------------|------------------------|------------|-----------------------|
//! | `SaveAll`      | inputs + pre-act + act | `4(d+2h)`  | none                  |
//! | `SaveInputs`   | routed inputs          | `4d`       | recompute hidden      |
//! | `RecomputeAll` | nothing                | `0`        | re-gather + recompute |
//!
//! All three are bit-identical in outputs and gradients; they differ
//! only in `memory_per_rank()` `data` bytes and, for `RecomputeAll` on
//! the sharded engine, in `Traffic::recompute_bytes` (the backward
//! re-runs the dispatch exchange). `SaveInputs` is the paper's
//! Algorithm-1 policy and the default.
//!
//! In a multi-layer stack (`coordinator::stack::MoeStack`) the table
//! reads *per layer*: layer l's saved bytes are
//! `n_l · saved_bytes_per_slot(policy_l)` on top of its residency, and
//! every layer's saved set is live simultaneously at the fwd→bwd
//! boundary — which is why the per-layer policy *vector* is the knob
//! that matters at depth. `memory::planner::CheckpointPlanner` chooses
//! that vector under a per-rank byte budget (`[ep] checkpoint = "auto"`
//! + `mem_budget_bytes`), trading saved bytes against the recompute
//! FLOPs (`SaveInputs`, `RecomputeAll`) and re-exchange bytes
//! (`RecomputeAll`) each downgrade costs on the `pipeline::timeline`
//! cost model.
//!
//! # Engines
//!
//! * [`SingleRankEngine`] — all experts local; the bit-exact reference.
//! * [`ShardedEngine`] — R simulated ranks over the worker pool,
//!   index-driven exchange, analytic communication accounting. Per-batch
//!   routing plans ([`RowIndexPlan`] + return lookup) are cached by
//!   `StepBatch` identity, so repeated steps over one workload re-derive
//!   nothing.
//!
//! Both are bit-deterministic for any R and placement; every
//! accumulation runs in a fixed order, and `backward_into` continues an
//! existing [`ExpertGrads`] value in that same order — accumulating A
//! contiguous microbatches performs the identical float-op sequence as
//! one full batch. `rust/tests/ep_engine.rs` pins all of this, plus
//! derived dispatch traffic == [`AllToAllPlan::cross_rank_bytes`].
//!
//! # Hot path: zero-materialization dispatch + blocked expert GEMM
//!
//! Since PR 5 the engines no longer materialize the exchange. The old
//! hot path packed every routed row three times per step — into
//! per-(src, dst) send buffers, a per-rank routed-input buffer, and
//! per-(dst, src) return buffers — then ran the experts one
//! row-dot-product at a time. The current path:
//!
//! 1. **Index plans, not buffers.** A cached [`RowIndexPlan`] records,
//!    per (rank, expert), the source token indices and gate slots of
//!    every routed row. The dispatch "exchange" is the transfer of those
//!    index lists; cross-rank byte counts are *derived* from the plan's
//!    src→dst row matrix (bit-equal to what the packed buffers measured
//!    — `rust/tests/row_plan_properties.rs` pins the round trip against
//!    [`AllToAllPlan::cross_rank_bytes`] over fuzzed gatings).
//! 2. **Gather fused into tiled GEMM.** Expert compute
//!    (`coordinator::kernels`) walks each expert's routed segment in
//!    tiles of `[ep] tile_rows` rows, gathering rows straight from the
//!    caller-owned [`StepBatch`] activations into one transposed
//!    cache-sized staging tile — zero-copy for local rows, one tile (not
//!    a whole buffer) of staging for remote rows — and runs cache-blocked
//!    GEMM over `w1`/`w2`, with a transposed-`w1` layout built once per
//!    expert segment per step for the ∂x pass. The combine scatter reads
//!    expert outputs in place through the return lookup.
//! 3. **Backward without a gradient exchange buffer.** Gated gradient
//!    rows (`gate · d_out`) are gathered per tile on demand;
//!    `RecomputeAll`'s backward re-gathers *indices, not rows* (its
//!    re-exchange is still priced in `Traffic::recompute_bytes`), and
//!    ∂x/∂W accumulation folds into the same tile pass.
//!
//! Per-element float-op order is exactly the row kernels' (see
//! `coordinator::kernels`), so outputs, gradients, ∂x, and loss curves
//! are bit-identical to the pre-PR-5 engines for every tile size — the
//! retired path survives as [`packed_reference_step`], the measurable
//! baseline `ep-bench`/`benches/ep_alltoall.rs` compare against, and the
//! engine matrices pin new == old bit-for-bit.
//!
//! Gated (SwiGLU) experts ride the same hot path: `[ep] activation =
//! swiglu` grows each expert a `w3` gate matrix and the blocked kernels
//! run both first-layer GEMMs in one staging-tile pass (one gather, both
//! matrices stream the tile once — see `coordinator::kernels`), with
//! `expert_forward_saving_swiglu` / `expert_backward_row_swiglu` below
//! as the per-row bit-identity oracles. `[ep] tile_rows = 0` autotunes
//! the tile on the real first microbatch per
//! (d_model, d_hidden, rows/expert, activation) bucket, and
//! `[ep] calibration_path` persists EWMA-calibrated link/compute rates
//! plus the chosen tiles so a fresh run starts warm
//! ([`engine_from_config_with_info`] reports what happened).
//!
//! [`AllToAllPlan::cross_rank_bytes`]: super::expert_parallel::AllToAllPlan::cross_rank_bytes
//! [`RowIndexPlan`]: crate::dispatch::structures::RowIndexPlan

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::config::ep::{EpConfig, Placement};
use crate::dispatch::gating::synthetic_gating;
use crate::dispatch::parallel_build::parallel_build;
use crate::dispatch::structures::{DispatchStructures, RowIndexPlan};
use crate::memory::model::{staging_bytes, CheckpointPolicy, MemoryBreakdown};
use crate::trace::load::ExpertLoadTracker;
use crate::trace::{SpanRecord, TracePhase, Tracer};
use crate::util::prng::Rng;
use crate::util::threadpool::{par_map, scope_chunks};

use super::calibrate::Calibration;
use super::expert_parallel::EpTopology;
use super::kernels::{backward_segment, forward_segment, pick_tile, silu,
                     KernelScratch, KernelTimers, RowsSrc, SavedHiddenMut,
                     SavedHiddenRef, AUTOTUNE_TILE_CANDIDATES,
                     DEFAULT_TILE_ROWS};
use super::params::{ExpertGrads, ExpertParams, ExpertStore, RankExperts};
use super::pipeline::timeline::{CostModel, OverlapReport};
use super::pipeline::{combine_chunk, compute_chunk_indexed, split_wall,
                      PipelinedEngine};

static NEXT_BATCH_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_ENGINE_TAG: AtomicU64 = AtomicU64::new(1);

/// Default per-engine routing-plan cache bound: plans for at most this
/// many distinct batch ids are retained (LRU eviction beyond it), so a
/// caller streaming many one-shot batches no longer grows memory without
/// bound. Re-admission is transparent — an evicted batch is simply
/// re-planned on its next forward (or backward, which re-resolves by
/// batch id). Callers with a known working set above this (e.g. a
/// trainer cycling `grad_accum` microbatches — LRU's worst case) should
/// raise the bound via `set_plan_cache_cap`; `engine_from_config` does
/// so automatically.
pub const PLAN_CACHE_CAP: usize = 8;

// -- step batch -------------------------------------------------------------

/// The immutable routing half of a workload — dispatch structures plus
/// combine gates — behind its own `Arc` so a multi-layer stack binding
/// fresh activations to the same routing every step duplicates no index
/// or gate data.
struct RoutingPayload {
    disp: DispatchStructures,
    gates: Vec<f32>,
}

struct BatchPayload {
    id: u64,
    /// stack layer this batch feeds (0 for plain workloads). Part of the
    /// engines' plan-cache key, so one batch id can legally carry L
    /// distinct per-layer routings without the caches colliding.
    layer: u32,
    /// token offset of this batch within its parent workload (0 for
    /// whole batches; `split` stamps the microbatch offset so a
    /// multi-layer stack can slice its per-layer routing to the span).
    token_offset: usize,
    routing: Arc<RoutingPayload>,
    x: Vec<f32>,
    d_model: usize,
    deep_copies: AtomicU64,
}

/// One step's workload — dispatch structures, token activations `x`
/// (L, d), and combine gates (L·k) — behind an `Arc`. Built once by the
/// caller, then shared zero-copy across steps, engines, and simulated
/// ranks; `clone`/[`share`](StepBatch::share) duplicate the handle, not
/// the payload. The only way to duplicate the payload is the explicit
/// [`deep_copy`](StepBatch::deep_copy), which increments
/// [`copy_count`](StepBatch::copy_count) — the counter `EpTrainer`
/// asserts stays at zero across a whole training run.
pub struct StepBatch {
    inner: Arc<BatchPayload>,
}

impl Clone for StepBatch {
    fn clone(&self) -> StepBatch {
        self.share()
    }
}

impl StepBatch {
    /// Validate and wrap a workload. `d_model` is inferred from
    /// `x.len() / disp.num_tokens`.
    pub fn new(disp: DispatchStructures, x: Vec<f32>,
               gates: Vec<f32>) -> Result<StepBatch, String> {
        StepBatch::with_meta(disp, x, gates, 0, 0)
    }

    fn with_meta(disp: DispatchStructures, x: Vec<f32>, gates: Vec<f32>,
                 token_offset: usize, layer: u32) -> Result<StepBatch, String> {
        if disp.num_tokens == 0 {
            return Err("StepBatch needs at least one token".into());
        }
        if x.is_empty() || x.len() % disp.num_tokens != 0 {
            return Err(format!(
                "x has {} elements, not a positive multiple of L = {}",
                x.len(),
                disp.num_tokens
            ));
        }
        if gates.len() != disp.slots() {
            return Err(format!(
                "gates has {} elements, expected L·k = {}",
                gates.len(),
                disp.slots()
            ));
        }
        let d_model = x.len() / disp.num_tokens;
        Ok(StepBatch {
            inner: Arc::new(BatchPayload {
                id: NEXT_BATCH_ID.fetch_add(1, Ordering::Relaxed),
                layer,
                token_offset,
                routing: Arc::new(RoutingPayload { disp, gates }),
                x,
                d_model,
                deep_copies: AtomicU64::new(0),
            }),
        })
    }

    /// Stable identity of the payload (shared by all handles to it).
    pub fn id(&self) -> u64 {
        self.inner.id
    }

    /// Stack layer this batch feeds (0 for plain workloads; set by
    /// [`LayerRouting::bind`]).
    pub fn layer(&self) -> u32 {
        self.inner.layer
    }

    /// Token offset of this batch inside its parent workload (0 unless
    /// this batch came from [`split`](StepBatch::split)).
    pub fn token_offset(&self) -> usize {
        self.inner.token_offset
    }

    /// The key every engine plan cache uses: one batch id may carry L
    /// distinct per-layer routings, so the layer tag is load-bearing.
    pub(crate) fn plan_key(&self) -> (u64, u32) {
        (self.inner.id, self.inner.layer)
    }

    pub fn disp(&self) -> &DispatchStructures {
        &self.inner.routing.disp
    }

    pub fn x(&self) -> &[f32] {
        &self.inner.x
    }

    pub fn gates(&self) -> &[f32] {
        &self.inner.routing.gates
    }

    pub fn num_tokens(&self) -> usize {
        self.inner.routing.disp.num_tokens
    }

    pub fn d_model(&self) -> usize {
        self.inner.d_model
    }

    /// Share the payload: a reference-counted handle, no data copied.
    pub fn share(&self) -> StepBatch {
        StepBatch { inner: Arc::clone(&self.inner) }
    }

    /// Duplicate the payload into a fresh batch, counting the copy on
    /// *this* batch's [`copy_count`]. Nothing in the engine or trainer
    /// paths calls this — it exists so the zero-copy property is
    /// observable rather than assumed.
    ///
    /// [`copy_count`]: StepBatch::copy_count
    pub fn deep_copy(&self) -> Result<StepBatch, String> {
        self.inner.deep_copies.fetch_add(1, Ordering::Relaxed);
        // fresh id, but the token offset and layer tag survive: a copied
        // microbatch must still slice stack routing at its real span
        StepBatch::with_meta(self.inner.routing.disp.clone(), self.inner.x.clone(),
                             self.inner.routing.gates.clone(),
                             self.inner.token_offset, self.inner.layer)
    }

    /// Payload copies made since construction (deep copies only; shares
    /// are free and uncounted).
    pub fn copy_count(&self) -> u64 {
        self.inner.deep_copies.load(Ordering::Relaxed)
    }

    /// The routing half of [`split`](StepBatch::split): contiguous
    /// token-range chunk offsets with their chunk-local dispatch
    /// structures, and **no** activation/gate copies — the form the
    /// chunk-pipelined engine caches, reading payloads from this batch
    /// with token offsets instead. One part returns a clone of the
    /// batch's own structures.
    pub fn split_routing(
        &self, parts: usize,
    ) -> Result<Vec<(usize, DispatchStructures)>, String> {
        let l = self.num_tokens();
        if parts == 0 || parts > l {
            return Err(format!("cannot split {l} tokens into {parts} microbatches"));
        }
        let bounds: Vec<usize> = (0..=parts).map(|m| l * m / parts).collect();
        self.split_routing_at(&bounds)
    }

    /// [`split_routing`](StepBatch::split_routing) over explicit
    /// contiguous token bounds (ascending, `bounds[0] = 0`, last = token
    /// count): chunk m covers tokens `[bounds[m], bounds[m+1])`. Token
    /// residency stays a *global*-token property downstream, so any
    /// contiguous partition preserves the summed-traffic invariant —
    /// callers choose the bounds (even token counts, or routed-row
    /// weighted via [`split_bounds_weighted`]).
    pub fn split_routing_at(
        &self, bounds: &[usize],
    ) -> Result<Vec<(usize, DispatchStructures)>, String> {
        let l = self.num_tokens();
        let disp = &self.inner.routing.disp;
        if bounds.len() < 2 || bounds[0] != 0 || *bounds.last().unwrap() != l {
            return Err(format!("chunk bounds {bounds:?} do not span 0..{l}"));
        }
        if bounds.windows(2).any(|w| w[0] >= w[1]) {
            return Err(format!("chunk bounds {bounds:?} not strictly increasing"));
        }
        if bounds.len() == 2 {
            return Ok(vec![(0, disp.clone())]);
        }
        let (k, e) = (disp.top_k, disp.num_experts);
        let mut out = Vec::with_capacity(bounds.len() - 1);
        for w in bounds.windows(2) {
            let (t0, t1) = (w[0], w[1]);
            let ids = &disp.token_expert_indices[t0 * k..t1 * k];
            out.push((t0, parallel_build(ids, t1 - t0, e, k)));
        }
        Ok(out)
    }

    /// Split into `parts` contiguous token-range microbatches, returned
    /// as `(token_offset, micro_batch)` in token order. Each microbatch
    /// is a fresh `StepBatch` built once (construction, not a per-step
    /// copy) carrying its offset as [`token_offset`]. Contiguous splits
    /// keep every expert's row segment in the same relative order as the
    /// full batch, which is what makes grad-accum bit-identical to the
    /// unsplit step.
    ///
    /// [`token_offset`]: StepBatch::token_offset
    pub fn split(&self, parts: usize) -> Result<Vec<(usize, StepBatch)>, String> {
        let (d, k) = (self.d_model(), self.inner.routing.disp.top_k);
        self.split_routing(parts)?
            .into_iter()
            .map(|(t0, disp)| {
                let lm = disp.num_tokens;
                // the stamped offset is absolute (chained through this
                // batch's own offset), so re-splitting a microbatch
                // still locates each grandchild in the root workload —
                // what MoeStack's routing slices key on. The returned
                // offset stays relative to *this* batch, matching the
                // x/gates/target slices callers take from it.
                let batch = StepBatch::with_meta(
                    disp,
                    self.inner.x[t0 * d..(t0 + lm) * d].to_vec(),
                    self.inner.routing.gates[t0 * k..(t0 + lm) * k].to_vec(),
                    self.inner.token_offset + t0,
                    self.inner.layer,
                )?;
                Ok((t0, batch))
            })
            .collect()
    }
}

/// Contiguous chunk bounds balancing the summed per-token `weights`
/// instead of raw token counts: bound m is the earliest cut whose prefix
/// weight reaches `m/parts` of the total, clamped so every chunk keeps
/// at least one token. All-zero weights degrade to the even token split.
/// The chunk-pipelined engine feeds routed-row loads through this so a
/// skewed router no longer yields ragged chunks (`[ep] chunk_balance =
/// rows`).
pub fn split_bounds_weighted(weights: &[u64], parts: usize) -> Result<Vec<usize>, String> {
    let l = weights.len();
    if parts == 0 || parts > l {
        return Err(format!("cannot split {l} tokens into {parts} chunks"));
    }
    let total: u64 = weights.iter().sum();
    if total == 0 {
        return Ok((0..=parts).map(|m| l * m / parts).collect());
    }
    let mut prefix = vec![0u64; l + 1];
    for (i, &w) in weights.iter().enumerate() {
        prefix[i + 1] = prefix[i] + w;
    }
    let mut bounds = vec![0usize];
    for m in 1..parts {
        let target = total * m as u64 / parts as u64;
        let cut = prefix.partition_point(|&p| p < target);
        let lo = bounds[m - 1] + 1;
        let hi = l - (parts - m);
        bounds.push(cut.clamp(lo, hi));
    }
    bounds.push(l);
    Ok(bounds)
}

// -- layer routing ----------------------------------------------------------

/// One stack layer's fixed routing — dispatch structures plus combine
/// gates, shared zero-copy by every per-step batch bound to it.
/// `coordinator::stack::MoeStack` builds one per layer (above the
/// bottom) and re-[`bind`]s each step's fresh activations; the derived
/// batch reuses the *parent* batch's id plus this routing's layer tag,
/// so engine plan caches (keyed `(batch id, layer)`) stay warm across
/// steps even though `x` changes every step.
///
/// [`bind`]: LayerRouting::bind
pub struct LayerRouting {
    layer: u32,
    routing: Arc<RoutingPayload>,
}

impl LayerRouting {
    /// Validate and wrap a layer's routing. `layer` must be ≥ 1 — layer
    /// 0 is the caller's own batch.
    pub fn new(layer: u32, disp: DispatchStructures,
               gates: Vec<f32>) -> Result<LayerRouting, String> {
        if layer == 0 {
            return Err("layer 0 consumes the caller's batch routing".into());
        }
        if disp.num_tokens == 0 {
            return Err("LayerRouting needs at least one token".into());
        }
        if gates.len() != disp.slots() {
            return Err(format!(
                "gates has {} elements, expected L·k = {}",
                gates.len(),
                disp.slots()
            ));
        }
        Ok(LayerRouting { layer, routing: Arc::new(RoutingPayload { disp, gates }) })
    }

    pub fn layer(&self) -> u32 {
        self.layer
    }

    pub fn num_tokens(&self) -> usize {
        self.routing.disp.num_tokens
    }

    /// Bind one step's activations (the previous layer's output) to this
    /// routing: a fresh batch over `parent`'s id and token span, sharing
    /// this routing's index/gate payload untouched. The parent batch's
    /// deep-copy counter is not incremented — no payload is duplicated.
    pub fn bind(&self, parent: &StepBatch, x: Vec<f32>) -> Result<StepBatch, String> {
        let l = self.routing.disp.num_tokens;
        if parent.num_tokens() != l {
            return Err(format!(
                "parent batch has {} tokens, layer routing covers {l}",
                parent.num_tokens()
            ));
        }
        if x.is_empty() || x.len() % l != 0 {
            return Err(format!(
                "x has {} elements, not a positive multiple of L = {l}",
                x.len()
            ));
        }
        let d_model = x.len() / l;
        Ok(StepBatch {
            inner: Arc::new(BatchPayload {
                id: parent.id(),
                layer: self.layer,
                token_offset: parent.token_offset(),
                routing: Arc::clone(&self.routing),
                x,
                d_model,
                deep_copies: AtomicU64::new(0),
            }),
        })
    }
}

// -- traffic ----------------------------------------------------------------

/// Bytes and rows moved by the current/last step session, measured at
/// the buffers (f32 rows, `4·d` bytes each).
///
/// Reset semantics: every counter resets when `forward` starts and
/// accumulates across that session's backward — so after `forward` the
/// backward-side fields (`grad_bytes`, `recompute_bytes`) read 0, and
/// after `backward` the whole struct describes exactly one step.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Traffic {
    /// dispatch all-to-all: routed activation rows crossing ranks (fwd)
    pub dispatch_bytes: u64,
    /// combine: expert-output rows returned to their home rank (fwd)
    pub combine_bytes: u64,
    /// routed gradient rows crossing ranks (bwd mirror of dispatch)
    pub grad_bytes: u64,
    /// `RecomputeAll` only: the backward's re-run of the dispatch
    /// exchange to rebuild routed inputs it did not save
    pub recompute_bytes: u64,
    /// routed rows that crossed a rank boundary in the fwd dispatch
    pub cross_rows: u64,
    /// routed rows that stayed on their home rank
    pub local_rows: u64,
}

// -- step handle ------------------------------------------------------------

/// Proof that a forward pass ran and its saved state is current: the
/// only ticket into [`ExecutionEngine::backward_into`], consumed by it.
/// A newer `forward` on the same engine invalidates outstanding handles
/// (their backward returns an error); dropping a handle abandons the
/// session (inference-style forward).
#[derive(Debug)]
pub struct StepHandle {
    pub(crate) engine_tag: u64,
    pub(crate) session: u64,
    pub(crate) out: Vec<f32>,
}

/// Fresh engine identity for handle binding (shared by every
/// [`ExecutionEngine`] implementation in this crate).
pub(crate) fn next_engine_tag() -> u64 {
    NEXT_ENGINE_TAG.fetch_add(1, Ordering::Relaxed)
}

/// The one linear-scan LRU all the engines' per-batch caches share:
/// a hit refreshes recency (moves to the back) and returns its index; a
/// miss runs `build`, evicts from the front down to `cap - 1` entries,
/// and appends. Evicting in a loop (not once) means a lowered cap takes
/// effect on the next miss rather than pinning the high-water mark.
/// Keys are `(batch id, layer)` pairs for the plan caches — one batch id
/// legitimately maps to L distinct per-layer dispatch plans in a
/// multi-layer stack, so id-only keys would silently serve layer 0's
/// plan to every layer.
pub(crate) fn lru_get_or_insert<K: Copy + PartialEq, T>(
    cache: &mut Vec<(K, T)>, cap: usize, id: K,
    build: impl FnOnce() -> Result<T, String>,
) -> Result<usize, String> {
    if let Some(i) = cache.iter().position(|(key, _)| *key == id) {
        let hit = cache.remove(i);
        cache.push(hit);
        return Ok(cache.len() - 1);
    }
    let value = build()?;
    while cache.len() >= cap.max(1) {
        cache.remove(0);
    }
    cache.push((id, value));
    Ok(cache.len() - 1)
}

impl StepHandle {
    /// Combined (L, d) output of the forward pass.
    pub fn output(&self) -> &[f32] {
        &self.out
    }

    /// Abandon the session and keep the output (no backward).
    pub fn into_output(self) -> Vec<f32> {
        self.out
    }

    /// End the session: compute expert gradients for `d_out` =
    /// ∂loss/∂out into a fresh [`ExpertGrads`].
    pub fn backward(self, engine: &mut dyn ExecutionEngine,
                    d_out: &[f32]) -> Result<ExpertGrads, String> {
        let mut grads = engine.zero_grads();
        engine.backward_into(self, d_out, &mut grads)?;
        Ok(grads)
    }

    /// End the session, *accumulating* gradients into `grads` in
    /// expert-segment order (grad-accum microbatching: pass the same
    /// accumulator for every microbatch of a global step).
    pub fn backward_into(self, engine: &mut dyn ExecutionEngine, d_out: &[f32],
                         grads: &mut ExpertGrads) -> Result<(), String> {
        engine.backward_into(self, d_out, grads)
    }
}

// -- engine trait -----------------------------------------------------------

/// One MoE-layer step executor over shared [`StepBatch`] workloads.
pub trait ExecutionEngine {
    fn name(&self) -> String;

    fn ranks(&self) -> usize;

    /// The save/recompute policy this engine runs under.
    fn policy(&self) -> CheckpointPolicy;

    /// Run the forward pass, opening a step session. The engine keeps a
    /// zero-copy share of `batch` plus whatever the policy saves; the
    /// returned handle is the only way into the backward pass.
    fn forward(&mut self, batch: &StepBatch) -> Result<StepHandle, String>;

    /// Close the session `handle` proves: accumulate parameter
    /// gradients for `d_out` (L, d) into `grads` (expert-segment order,
    /// continuing whatever `grads` already holds). Fails on a stale or
    /// foreign handle, or a shape mismatch.
    fn backward_into(&mut self, handle: StepHandle, d_out: &[f32],
                     grads: &mut ExpertGrads) -> Result<(), String>;

    /// [`backward_into`] that additionally accumulates ∂loss/∂x — the
    /// gradient with respect to the batch's token activations — into
    /// `d_x` (length L·d, caller-zeroed). This is the layer-chaining
    /// half of `coordinator::stack::MoeStack`'s reverse walk: layer l's
    /// `d_x` is layer l−1's `d_out`. The parameter-gradient float-op
    /// sequence is exactly [`backward_into`]'s (the ∂x ops touch
    /// separate memory), so `grads` stays bit-identical whether or not
    /// ∂x is requested; and every engine folds per-slot ∂x rows into
    /// `d_x` in global expert-major position order, so ∂x itself is
    /// bit-identical across rank counts and chunkings.
    ///
    /// [`backward_into`]: ExecutionEngine::backward_into
    fn backward_into_dx(&mut self, handle: StepHandle, d_out: &[f32],
                        grads: &mut ExpertGrads, d_x: &mut [f32]) -> Result<(), String>;

    /// A zeroed gradient accumulator matching this engine's experts.
    fn zero_grads(&self) -> ExpertGrads;

    /// Apply an additive parameter update (an optimizer's delta) to the
    /// engine-owned expert parameters.
    fn apply_update(&mut self, delta: &ExpertGrads) -> Result<(), String>;

    /// Communication of the current/last session (see [`Traffic`] for
    /// the reset contract).
    fn traffic(&self) -> Traffic;

    /// Per-rank activation-memory breakdown of the last forward
    /// (`data` = activation rows + policy-saved tensors, `index` =
    /// routing metadata, `extra` = packed comm buffers) — the
    /// Figures 3/5 accounting, per rank and policy-parametric.
    fn memory_per_rank(&self) -> Vec<MemoryBreakdown>;

    /// Reassembled global expert parameters (for equivalence checks and
    /// checkpointing).
    fn gather_params(&self) -> Result<ExpertStore, String>;

    /// Replace the engine-owned expert parameters with `store`'s — the
    /// restore half of crash-consistent snapshots
    /// (`resilience::snapshot::TrainState`). `apply_update` cannot
    /// restore (IEEE-754: `a + (b − a) ≠ b`), so resume swaps the exact
    /// parameter bits in. The store must match the engine's expert
    /// count, dimensions, and gating; rank count, chunking, and
    /// checkpoint policy are *not* part of the contract (numerics are
    /// pinned invariant to them), so a snapshot taken at R = 1 restores
    /// into an R = 4 engine — the parameter-migration substrate the
    /// ROADMAP names. Any open step session is discarded. Engines
    /// without parameter storage reject the call (the default).
    fn load_params(&mut self, _store: &ExpertStore) -> Result<(), String> {
        Err("this engine cannot load parameters".into())
    }

    /// Phase timeline of the last step session under the simulated
    /// link-bandwidth/compute-rate cost model, when this engine overlaps
    /// communication with compute
    /// ([`PipelinedEngine`](super::pipeline::PipelinedEngine)). Barrier
    /// engines return `None`.
    fn overlap_report(&self) -> Option<OverlapReport> {
        None
    }

    /// Measured host wall-clock of the last step session (the sum of the
    /// timeline's per-phase calibration samples), or `None` for engines
    /// without a timeline. `MoeStack` overrides this to sum across *all*
    /// layer sessions — its `overlap_report` exposes only the deepest
    /// layer's timeline, which alone would undercount the step by the
    /// layer count.
    fn measured_step_s(&self) -> Option<f64> {
        self.overlap_report().and_then(|rep| rep.measured_step_s())
    }

    /// Fold the last session's measured-vs-simulated phase calibration
    /// back into this engine's [`CostModel`] (`[ep] calibrate = true`):
    /// each rate is EWMA-updated with weight `alpha` toward
    /// `rate · (simulated / measured)`, so a host that runs a phase
    /// slower than the model predicted drags the effective
    /// `link_gbps` / `compute_gflops` down across trainer steps — the
    /// ROADMAP's self-tuning cost model. Returns the updated model;
    /// engines without a timeline return `None` (the default) and
    /// change nothing. Numerics are untouched — only the simulated
    /// clock's rates move.
    fn recalibrate_cost_model(&mut self, _alpha: f64) -> Option<CostModel> {
        None
    }

    /// Attach a structured tracer (`crate::trace`): subsequent steps
    /// record per-rank phase spans and resident-bytes gauges into it.
    /// Engines without instrumentation ignore the attach (the default).
    /// Tracing never perturbs numerics — the bit-identity matrices hold
    /// with and without a tracer.
    fn set_tracer(&mut self, _tracer: Tracer) {}

    /// Attach an expert-load tracker (`crate::trace::load`): subsequent
    /// forwards feed it the step's per-expert routed-row counts from the
    /// `RowIndexPlan` (dispatch ground truth) plus the gate weights for
    /// router entropy. Engines without instrumentation ignore the
    /// attach (the default). Like tracing, an attached tracker is
    /// integer accounting off the numeric path — the bit-identity
    /// matrices hold with and without one (pinned in
    /// `rust/tests/ep_load.rs`).
    fn set_load_tracker(&mut self, _tracker: ExpertLoadTracker) {}
}

// -- reference per-row expert math ------------------------------------------
//
// The pre-PR-5 row kernels. The engines now run the tile-blocked kernels
// in `coordinator::kernels` (bit-identical per element — the kernel unit
// tests pin row == blocked for every tile size); these stay as the
// bit-identity oracle and as the measurable baseline inside
// [`packed_reference_step`].

/// y = W2·silu(W1·x + b1) + b2. Pure function of one row — bit-identical
/// wherever (and on whatever thread) it runs.
pub(crate) fn expert_forward(p: &ExpertParams, d: usize, h: usize, x: &[f32], y: &mut [f32],
                             hidden: &mut [f32]) {
    for i in 0..h {
        let row = &p.w1[i * d..(i + 1) * d];
        let mut acc = p.b1[i];
        for j in 0..d {
            acc += row[j] * x[j];
        }
        hidden[i] = silu(acc);
    }
    for i in 0..d {
        let row = &p.w2[i * h..(i + 1) * h];
        let mut acc = p.b2[i];
        for j in 0..h {
            acc += row[j] * hidden[j];
        }
        y[i] = acc;
    }
}

/// [`expert_forward`] that also saves the pre-activation and activation
/// rows (the `SaveAll` policy): the same hidden loop as
/// [`recompute_hidden`] followed by the output projection — identical
/// op sequence, so outputs are bit-identical to the non-saving path.
pub(crate) fn expert_forward_saving(p: &ExpertParams, d: usize, h: usize, x: &[f32],
                                    y: &mut [f32], pre: &mut [f32], act: &mut [f32]) {
    recompute_hidden(p, d, h, x, pre, act);
    for i in 0..d {
        let row = &p.w2[i * h..(i + 1) * h];
        let mut acc = p.b2[i];
        for j in 0..h {
            acc += row[j] * act[j];
        }
        y[i] = acc;
    }
}

/// Recompute one row's hidden pre-activation and activation from the
/// routed input (the recompute half of `SaveInputs`/`RecomputeAll`).
/// Same op sequence as the forward, so the values are bit-identical to
/// what `SaveAll` saved.
pub(crate) fn recompute_hidden(p: &ExpertParams, d: usize, h: usize, x: &[f32],
                               pre: &mut [f32], act: &mut [f32]) {
    for i in 0..h {
        let row = &p.w1[i * d..(i + 1) * d];
        let mut acc = p.b1[i];
        for j in 0..d {
            acc += row[j] * x[j];
        }
        pre[i] = acc;
        act[i] = silu(acc);
    }
}

/// Accumulate one row's parameter gradients into `g`, given the hidden
/// pre-activation/activation rows (saved or just recomputed). When `dx`
/// is provided, also accumulates this row's input gradient
/// `∂loss/∂x = W1ᵀ·da` into it — extra ops on separate memory, appended
/// after each `j`'s parameter update, so the `g` float-op sequence is
/// identical with or without it.
pub(crate) fn expert_backward_row(p: &ExpertParams, g: &mut ExpertParams, d: usize,
                                  h: usize, x: &[f32], dy: &[f32], pre: &[f32],
                                  act: &[f32], dz: &mut [f32],
                                  dx: Option<&mut [f32]>) {
    // W2 / b2 grads and dz = W2ᵀ·dy
    for j in 0..h {
        dz[j] = 0.0;
    }
    for i in 0..d {
        g.b2[i] += dy[i];
        let grow = &mut g.w2[i * h..(i + 1) * h];
        let wrow = &p.w2[i * h..(i + 1) * h];
        for j in 0..h {
            grow[j] += dy[i] * act[j];
            dz[j] += dy[i] * wrow[j];
        }
    }
    // through silu: silu'(a) = σ(a)·(1 + a·(1 − σ(a)))
    let mut dx = dx;
    for j in 0..h {
        let sig = 1.0 / (1.0 + (-pre[j]).exp());
        let da = dz[j] * sig * (1.0 + pre[j] * (1.0 - sig));
        g.b1[j] += da;
        let grow = &mut g.w1[j * d..(j + 1) * d];
        for c in 0..d {
            grow[c] += da * x[c];
        }
        if let Some(dxr) = dx.as_deref_mut() {
            let wrow = &p.w1[j * d..(j + 1) * d];
            for c in 0..d {
                dxr[c] += da * wrow[c];
            }
        }
    }
}

/// Recompute one row's SwiGLU hidden state from the routed input: the
/// pre-activation chain is [`recompute_hidden`]'s (`b1[i]` + `j`-asc
/// `w1·x`), the gate chain starts from zero (no gate bias) and adds
/// `j`-asc `w3·x` in the same sweep, and the hidden is
/// `z = silu(pre)·gate` evaluated exactly in that order — the blocked
/// `hidden_tile_swiglu` performs the identical per-element op sequence.
pub(crate) fn recompute_hidden_swiglu(p: &ExpertParams, d: usize, h: usize,
                                      x: &[f32], pre: &mut [f32],
                                      gate: &mut [f32], act: &mut [f32]) {
    for i in 0..h {
        let wrow = &p.w1[i * d..(i + 1) * d];
        let vrow = &p.w3[i * d..(i + 1) * d];
        let mut acc_a = p.b1[i];
        let mut acc_g = 0.0f32;
        for j in 0..d {
            acc_a += wrow[j] * x[j];
            acc_g += vrow[j] * x[j];
        }
        pre[i] = acc_a;
        gate[i] = acc_g;
        act[i] = silu(acc_a) * acc_g;
    }
}

/// `y = W2·(silu(W1·x + b1) ⊙ W3·x) + b2`, saving all three hidden rows
/// — the SwiGLU row-reference forward (the oracle the blocked kernels
/// are pinned against, exactly as [`expert_forward_saving`] is for the
/// SiLU expert). The output projection is [`expert_forward`]'s chain
/// verbatim (it sees only `z`).
pub(crate) fn expert_forward_saving_swiglu(p: &ExpertParams, d: usize, h: usize,
                                           x: &[f32], y: &mut [f32],
                                           pre: &mut [f32], gate: &mut [f32],
                                           act: &mut [f32]) {
    recompute_hidden_swiglu(p, d, h, x, pre, gate, act);
    for i in 0..d {
        let row = &p.w2[i * h..(i + 1) * h];
        let mut acc = p.b2[i];
        for j in 0..h {
            acc += row[j] * act[j];
        }
        y[i] = acc;
    }
}

/// Accumulate one row's SwiGLU parameter gradients into `g` — the
/// row-reference backward oracle. The `dz`/`∂W2`/`∂b2` section is
/// [`expert_backward_row`]'s verbatim; the gate product then splits
/// `dz` into `da = (dz·gate)·σ·(1 + pre·(1 − σ))` (through SiLU') and
/// `dg = dz·silu(pre)`, extends `∂b1`/`∂W1` from `da` and `∂W3` from
/// `dg` (`∂W1`'s row before `∂W3`'s for each `j`), and — when `dx` is
/// requested — runs the `w1ᵀ·da` chain over all `j` ascending inside
/// the main loop, then a trailing full `j`-ascending `w3ᵀ·dg` chain,
/// never interleaved. `da`/`dg` are caller scratch rows (length `h`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn expert_backward_row_swiglu(p: &ExpertParams, g: &mut ExpertParams,
                                         d: usize, h: usize, x: &[f32],
                                         dy: &[f32], pre: &[f32], gate: &[f32],
                                         act: &[f32], dz: &mut [f32],
                                         da: &mut [f32], dg: &mut [f32],
                                         dx: Option<&mut [f32]>) {
    // W2 / b2 grads and dz = W2ᵀ·dy — identical to the SiLU row kernel
    // (act holds z = silu(pre)·gate)
    for j in 0..h {
        dz[j] = 0.0;
    }
    for i in 0..d {
        g.b2[i] += dy[i];
        let grow = &mut g.w2[i * h..(i + 1) * h];
        let wrow = &p.w2[i * h..(i + 1) * h];
        for j in 0..h {
            grow[j] += dy[i] * act[j];
            dz[j] += dy[i] * wrow[j];
        }
    }
    // split through the gate product; w1-∂x contributions ride the main
    // loop (all j ascending), the w3 chain follows in full afterwards
    let mut dx = dx;
    for j in 0..h {
        let sig = 1.0 / (1.0 + (-pre[j]).exp());
        da[j] = (dz[j] * gate[j]) * sig * (1.0 + pre[j] * (1.0 - sig));
        dg[j] = dz[j] * silu(pre[j]);
        g.b1[j] += da[j];
        let grow = &mut g.w1[j * d..(j + 1) * d];
        for c in 0..d {
            grow[c] += da[j] * x[c];
        }
        let grow3 = &mut g.w3[j * d..(j + 1) * d];
        for c in 0..d {
            grow3[c] += dg[j] * x[c];
        }
        if let Some(dxr) = dx.as_deref_mut() {
            let wrow = &p.w1[j * d..(j + 1) * d];
            for c in 0..d {
                dxr[c] += da[j] * wrow[c];
            }
        }
    }
    if let Some(dxr) = dx.as_deref_mut() {
        for j in 0..h {
            let vrow = &p.w3[j * d..(j + 1) * d];
            for c in 0..d {
                dxr[c] += dg[j] * vrow[c];
            }
        }
    }
}

pub(crate) fn add_params(p: &mut ExpertParams, delta: &ExpertParams) {
    for (w, dv) in p.w1.iter_mut().zip(&delta.w1) {
        *w += dv;
    }
    for (w, dv) in p.b1.iter_mut().zip(&delta.b1) {
        *w += dv;
    }
    for (w, dv) in p.w2.iter_mut().zip(&delta.w2) {
        *w += dv;
    }
    for (w, dv) in p.b2.iter_mut().zip(&delta.b2) {
        *w += dv;
    }
    for (w, dv) in p.w3.iter_mut().zip(&delta.w3) {
        *w += dv;
    }
}

pub(crate) fn check_batch(batch: &StepBatch, d: usize, num_experts: usize) -> Result<(), String> {
    if batch.disp().num_experts != num_experts {
        return Err(format!(
            "batch routes over {} experts, engine owns {num_experts}",
            batch.disp().num_experts
        ));
    }
    if batch.d_model() != d {
        return Err(format!(
            "batch has d_model {}, engine expects {d}",
            batch.d_model()
        ));
    }
    Ok(())
}

/// One rank's backward work item for `scope_chunks`: the gradient
/// accumulators of the experts it owns, plus (when ∂x is requested) the
/// per-local-slot input-gradient rows it produces, plus the worker's
/// measured gather/compute wall-clock. Separate fields so a worker can
/// mutate all of them without aliasing.
pub(crate) struct RankBwdWork {
    pub(crate) bucket: Vec<(usize, ExpertParams)>,
    pub(crate) dxs: Vec<f32>,
    pub(crate) timers: KernelTimers,
}

/// Fold per-rank per-local-slot ∂x rows back into the caller's `d_x` in
/// global expert-major position order — the one accumulation order every
/// engine shares. Per token, its k slot contributions land in ascending
/// expert order exactly as the single-rank walk performs them, which is
/// what keeps ∂x bit-identical across rank counts and chunkings (a
/// chunk's tokens all live in that chunk, so chunk-local position order
/// preserves each token's relative contribution order).
pub(crate) fn fold_dx(rows: &RowIndexPlan, work: &[RankBwdWork], d: usize,
                      num_experts: usize, token_base: usize, d_x: &mut [f32]) {
    let mut seg_len = vec![0usize; num_experts];
    for rr in &rows.per_rank {
        for (i, &e) in rr.experts.iter().enumerate() {
            seg_len[e as usize] = rr.expert_len(i);
        }
    }
    let mut seg_off = vec![0usize; num_experts + 1];
    for e in 0..num_experts {
        seg_off[e + 1] = seg_off[e] + seg_len[e];
    }
    let n = seg_off[num_experts];
    let mut dxs = vec![0.0f32; n * d];
    let mut tok_of_pos = vec![0u32; n];
    for (dst, rr) in rows.per_rank.iter().enumerate() {
        let local = &work[dst].dxs;
        for (i, &e) in rr.experts.iter().enumerate() {
            let lo = rr.expert_offsets[i] as usize;
            let hi = rr.expert_offsets[i + 1] as usize;
            let base = seg_off[e as usize];
            for jj in 0..(hi - lo) {
                dxs[(base + jj) * d..(base + jj + 1) * d]
                    .copy_from_slice(&local[(lo + jj) * d..(lo + jj + 1) * d]);
                tok_of_pos[base + jj] = rr.tokens[lo + jj];
            }
        }
    }
    for pos in 0..n {
        let t = token_base + tok_of_pos[pos] as usize;
        let row = &dxs[pos * d..(pos + 1) * d];
        let out = &mut d_x[t * d..(t + 1) * d];
        for c in 0..d {
            out[c] += row[c];
        }
    }
}

/// What one session saved on one rank (policy-dependent).
pub(crate) enum SavedActs {
    /// `SaveAll`: routed inputs + hidden pre-activations + activations
    /// (+ the `w3·x` gate values for gated experts — `gate` stays empty
    /// for SiLU)
    All { xs: Vec<f32>, pre: Vec<f32>, act: Vec<f32>, gate: Vec<f32> },
    /// `SaveInputs`: routed inputs only
    Inputs { xs: Vec<f32> },
    /// `RecomputeAll`: nothing
    Nothing,
}

// -- single-rank engine -----------------------------------------------------

struct SingleSession {
    id: u64,
    batch: StepBatch,
    saved: SavedActs,
}

/// All experts on one rank — the reference path the sharded engine is
/// verified against bit-for-bit.
pub struct SingleRankEngine {
    pub store: ExpertStore,
    policy: CheckpointPolicy,
    /// routed-row tile of the blocked kernels (`[ep] tile_rows`);
    /// numerics are tile-size-invariant, only throughput moves
    tile_rows: usize,
    engine_tag: u64,
    sessions_opened: u64,
    session: Option<SingleSession>,
    /// cached `origin slot per expert-major position`, by
    /// (batch id, layer) (LRU, bounded at `cache_cap`)
    origin_cache: Vec<((u64, u32), Vec<u32>)>,
    cache_cap: usize,
    traffic: Traffic,
    /// last forward's accounting — persists across the session's
    /// backward, matching the sharded engine's contract
    mem: Vec<MemoryBreakdown>,
    /// attached observability handle; `None` keeps the hot path free
    /// of any tracing cost at all (see [`crate::trace`])
    tracer: Option<Tracer>,
    /// attached expert-load tracker, same Option-gating contract
    load: Option<ExpertLoadTracker>,
}

impl SingleRankEngine {
    pub fn new(store: ExpertStore) -> SingleRankEngine {
        SingleRankEngine::with_policy(store, CheckpointPolicy::default())
    }

    pub fn with_policy(store: ExpertStore, policy: CheckpointPolicy) -> SingleRankEngine {
        SingleRankEngine {
            store,
            policy,
            tile_rows: DEFAULT_TILE_ROWS,
            engine_tag: NEXT_ENGINE_TAG.fetch_add(1, Ordering::Relaxed),
            sessions_opened: 0,
            session: None,
            origin_cache: Vec::new(),
            cache_cap: PLAN_CACHE_CAP,
            traffic: Traffic::default(),
            mem: Vec::new(),
            tracer: None,
            load: None,
        }
    }

    /// Set the blocked-kernel row tile (≥ 1). Outputs and gradients are
    /// bit-identical for every tile size — the knob only moves
    /// throughput and staging-tile residency.
    pub fn set_tile_rows(&mut self, tile_rows: usize) {
        self.tile_rows = tile_rows.max(1);
    }

    /// Raise/lower the origin-cache bound (≥ 1, trimming immediately);
    /// see [`PLAN_CACHE_CAP`].
    pub fn set_plan_cache_cap(&mut self, cap: usize) {
        self.cache_cap = cap.max(1);
        while self.origin_cache.len() > self.cache_cap {
            self.origin_cache.remove(0);
        }
    }

    /// LRU-bounded like the sharded engine's plan cache (default cap
    /// [`PLAN_CACHE_CAP`]): hits refresh recency, misses beyond the cap
    /// evict the least-recently-used entry and re-derive on re-admission.
    fn origin_of_pos(&mut self, batch: &StepBatch) -> usize {
        let disp = batch.disp();
        lru_get_or_insert(&mut self.origin_cache, self.cache_cap, batch.plan_key(), || {
            let mut origin = vec![0u32; disp.slots()];
            for (slot, &pos) in disp.token_index_map.iter().enumerate() {
                origin[pos as usize] = slot as u32;
            }
            Ok(origin)
        })
        .expect("origin derivation is infallible")
    }

    /// The one backward: parameter grads always, ∂x rows when requested
    /// (`d_x` adds separate ops only, so grads are bit-identical either
    /// way — the trait's `backward_into`/`backward_into_dx` contract).
    fn backward_impl(&mut self, handle: StepHandle, d_out: &[f32],
                     grads: &mut ExpertGrads,
                     d_x: Option<&mut [f32]>) -> Result<(), String> {
        let (d, h) = (self.store.d_model, self.store.d_hidden);
        if handle.engine_tag != self.engine_tag {
            return Err("step handle belongs to a different engine".into());
        }
        match &self.session {
            None => return Err("no open step session (forward not called)".into()),
            Some(s) if s.id != handle.session => {
                return Err(format!(
                    "stale step handle: session {} superseded by {}",
                    handle.session, s.id
                ));
            }
            Some(_) => {}
        }
        grads
            .check_like(self.store.experts.len(), d, h)
            .map_err(|e| e.to_string())?;
        // shape checks run BEFORE the session is consumed, so a caller
        // can fix a bad buffer and retry with the same handle (the
        // error-before-mutation contract the stack relies on)
        let l_tokens = self.session.as_ref().unwrap().batch.num_tokens();
        if d_out.len() != l_tokens * d {
            return Err(format!(
                "d_out has {} elements, expected L·d = {}",
                d_out.len(),
                l_tokens * d
            ));
        }
        if let Some(dx) = &d_x {
            if dx.len() != l_tokens * d {
                return Err(format!(
                    "d_x has {} elements, expected L·d = {}",
                    dx.len(),
                    l_tokens * d
                ));
            }
        }
        let origin_idx = {
            let batch = self.session.as_ref().unwrap().batch.share();
            self.origin_of_pos(&batch)
        };
        let st = self.session.take().unwrap();
        let disp = st.batch.disp();
        let want_dx = d_x.is_some();
        let n = disp.slots();
        let mut dxs = vec![0.0f32; if want_dx { n * d } else { 0 }];
        let origin = &self.origin_cache[origin_idx].1;
        let x = st.batch.x();
        let gates = st.batch.gates();
        // blocked backward, expert segment by expert segment: routed
        // inputs come from the policy-saved rows or (RecomputeAll) a
        // direct re-gather of indices from the shared batch — local,
        // zero comm, zero re-gather buffer
        let (xsrc, hidden): (RowsSrc, Option<SavedHiddenRef<'_>>) = match &st.saved {
            SavedActs::All { xs, pre, act, gate } => (
                RowsSrc::Packed(&xs[..]),
                Some(SavedHiddenRef {
                    pre: &pre[..],
                    act: &act[..],
                    gate: (!gate.is_empty()).then_some(&gate[..]),
                }),
            ),
            SavedActs::Inputs { xs } => (RowsSrc::Packed(&xs[..]), None),
            SavedActs::Nothing => (RowsSrc::Tokens(x), None),
        };
        let mut scratch = KernelScratch::new(d, h, self.tile_rows);
        let trace_t0 = self.tracer.as_ref().map(|tr| tr.now_s());
        for (e, p) in self.store.experts.iter().enumerate() {
            let g = &mut grads.experts[e];
            let lo = disp.expert_token_offsets[e] as usize;
            let hi = disp.expert_token_offsets[e + 1] as usize;
            if lo == hi {
                continue;
            }
            // timers: None — no timeline consumes them here, so the
            // per-tile clock reads are skipped on this hot path
            backward_segment(p, g, d, h, lo, hi, &xsrc,
                             &disp.expert_token_indices, 0, origin, 0, d_out,
                             gates, hidden,
                             if want_dx { Some(&mut dxs[..]) } else { None },
                             &mut scratch, None);
        }
        if let (Some(tr), Some(t0)) = (&self.tracer, trace_t0) {
            let mut s = SpanRecord::new(TracePhase::ExpertGemm, t0,
                                        (tr.now_s() - t0).max(0.0));
            s.backward = true;
            s.rows = n as u64;
            tr.record_span(s);
        }
        // fold ∂x rows home in expert-major position order (the order
        // every engine shares — see `fold_dx`)
        if let Some(dx) = d_x {
            for pos in 0..n {
                let t = disp.expert_token_indices[pos] as usize;
                let row = &dxs[pos * d..(pos + 1) * d];
                let out = &mut dx[t * d..(t + 1) * d];
                for c in 0..d {
                    out[c] += row[c];
                }
            }
        }
        Ok(())
    }
}

impl ExecutionEngine for SingleRankEngine {
    fn name(&self) -> String {
        "single-rank".into()
    }

    fn ranks(&self) -> usize {
        1
    }

    fn policy(&self) -> CheckpointPolicy {
        self.policy
    }

    fn forward(&mut self, batch: &StepBatch) -> Result<StepHandle, String> {
        let (d, h) = (self.store.d_model, self.store.d_hidden);
        check_batch(batch, d, self.store.experts.len())?;
        let disp = batch.disp();
        let x = batch.x();
        let gates = batch.gates();
        let (l, k, n) = (disp.num_tokens, disp.top_k, disp.slots());
        let save_inputs = self.policy != CheckpointPolicy::RecomputeAll;
        let save_hidden = self.policy == CheckpointPolicy::SaveAll;
        let gated = self.store.gated();

        // blocked expert compute, expert-major: rows gathered straight
        // from the shared batch into the kernel staging tile
        let mut ys = vec![0.0f32; n * d];
        let mut xs = vec![0.0f32; if save_inputs { n * d } else { 0 }];
        let mut pre = vec![0.0f32; if save_hidden { n * h } else { 0 }];
        let mut act = vec![0.0f32; if save_hidden { n * h } else { 0 }];
        let mut gate = vec![0.0f32; if save_hidden && gated { n * h } else { 0 }];
        let mut scratch = KernelScratch::new(d, h, self.tile_rows);
        // clock reads happen only with a tracer attached — without one
        // this path is byte-for-byte the untraced hot path
        let trace_t0 = self.tracer.as_ref().map(|tr| tr.now_s());
        for (e, p) in self.store.experts.iter().enumerate() {
            let lo = disp.expert_token_offsets[e] as usize;
            let hi = disp.expert_token_offsets[e + 1] as usize;
            if lo == hi {
                continue;
            }
            // timers: None — the single-rank engine has no timeline
            forward_segment(p, d, h, lo, hi, x, &disp.expert_token_indices, 0,
                            &mut ys,
                            if save_inputs { Some(&mut xs[..]) } else { None },
                            if save_hidden {
                                Some(SavedHiddenMut {
                                    pre: &mut pre[..],
                                    act: &mut act[..],
                                    gate: gated.then_some(&mut gate[..]),
                                })
                            } else {
                                None
                            },
                            &mut scratch, None);
        }
        if let (Some(tr), Some(t0)) = (&self.tracer, trace_t0) {
            let mut s = SpanRecord::new(TracePhase::ExpertGemm, t0,
                                        (tr.now_s() - t0).max(0.0));
            s.rows = n as u64;
            s.tokens = l as u64;
            tr.record_span(s);
        }
        // combine scatter, token-major, fixed j order
        let trace_tc = self.tracer.as_ref().map(|tr| tr.now_s());
        let mut out = vec![0.0f32; l * d];
        for i in 0..l {
            for j in 0..k {
                let slot = i * k + j;
                let g = gates[slot];
                let pos = disp.token_index_map[slot] as usize;
                let row = &ys[pos * d..(pos + 1) * d];
                let o = &mut out[i * d..(i + 1) * d];
                for c in 0..d {
                    o[c] += g * row[c];
                }
            }
        }
        if let (Some(tr), Some(t0)) = (&self.tracer, trace_tc) {
            let mut s = SpanRecord::new(TracePhase::Combine, t0,
                                        (tr.now_s() - t0).max(0.0));
            s.rows = n as u64;
            s.tokens = l as u64;
            tr.record_span(s);
        }
        let saved = match self.policy {
            CheckpointPolicy::SaveAll => SavedActs::All { xs, pre, act, gate },
            CheckpointPolicy::SaveInputs => SavedActs::Inputs { xs },
            CheckpointPolicy::RecomputeAll => SavedActs::Nothing,
        };
        // session-scoped counters reset here
        self.traffic = Traffic { local_rows: n as u64, ..Traffic::default() };
        self.mem = vec![MemoryBreakdown {
            // routed rows (ys) + resident token activations + output,
            // plus what the policy saves for backward
            data_bytes: 4 * (d as u64) * (n as u64 + 2 * l as u64)
                + (n as u64)
                    * self.policy.saved_bytes_per_slot(d as u64, h as u64, 4,
                                                       gated),
            index_bytes: disp.metadata_bytes() as u64,
            extra_bytes: 0,
        }];
        if let Some(tr) = &self.tracer {
            tr.gauge(0, "resident_bytes", self.mem[0].data_bytes as f64,
                     mem_peak_phase(&self.mem[0]));
            tr.gauge(0, "routed_rows", n as f64, "gather");
        }
        if let Some(lt) = &self.load {
            // routed-row ground truth from the dispatch offsets; every
            // expert lives on the single rank
            let e_count = self.store.experts.len();
            let mut rows = vec![0u64; e_count];
            for (e, r) in rows.iter_mut().enumerate() {
                *r = (disp.expert_token_offsets[e + 1]
                    - disp.expert_token_offsets[e]) as u64;
            }
            lt.record_rows(&rows, &vec![0u32; e_count], gates);
        }
        self.sessions_opened += 1;
        let session = self.sessions_opened;
        self.session = Some(SingleSession { id: session, batch: batch.share(), saved });
        Ok(StepHandle { engine_tag: self.engine_tag, session, out })
    }

    fn backward_into(&mut self, handle: StepHandle, d_out: &[f32],
                     grads: &mut ExpertGrads) -> Result<(), String> {
        self.backward_impl(handle, d_out, grads, None)
    }

    fn backward_into_dx(&mut self, handle: StepHandle, d_out: &[f32],
                        grads: &mut ExpertGrads, d_x: &mut [f32]) -> Result<(), String> {
        self.backward_impl(handle, d_out, grads, Some(d_x))
    }

    fn zero_grads(&self) -> ExpertGrads {
        ExpertGrads::zeros_gated(self.store.experts.len(), self.store.d_model,
                                 self.store.d_hidden, self.store.gated())
    }

    fn apply_update(&mut self, delta: &ExpertGrads) -> Result<(), String> {
        delta
            .check_like(self.store.experts.len(), self.store.d_model, self.store.d_hidden)
            .map_err(|e| e.to_string())?;
        for (e, p) in self.store.experts.iter_mut().enumerate() {
            add_params(p, &delta.experts[e]);
        }
        Ok(())
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn memory_per_rank(&self) -> Vec<MemoryBreakdown> {
        if self.mem.is_empty() {
            vec![MemoryBreakdown { data_bytes: 0, index_bytes: 0, extra_bytes: 0 }]
        } else {
            self.mem.clone()
        }
    }

    fn gather_params(&self) -> Result<ExpertStore, String> {
        Ok(self.store.clone())
    }

    fn load_params(&mut self, store: &ExpertStore) -> Result<(), String> {
        check_store_like(store, self.store.experts.len(), self.store.d_model,
                         self.store.d_hidden, self.store.gated())?;
        self.store = store.clone();
        self.session = None;
        Ok(())
    }

    fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = Some(tracer);
    }

    fn set_load_tracker(&mut self, tracker: ExpertLoadTracker) {
        self.load = Some(tracker);
    }
}

/// Phase attribution for a rank's `resident_bytes` gauge sample: which
/// memory component dominates the step's footprint (staging tiles →
/// the gather/exchange, otherwise the routed rows + saved activations
/// held for the expert GEMM).
pub(crate) fn mem_peak_phase(m: &MemoryBreakdown) -> &'static str {
    if m.extra_bytes.max(m.index_bytes) > m.data_bytes {
        "gather"
    } else {
        "expert_gemm"
    }
}

/// Record the gather + expert-GEMM section spans covering one compute
/// wall interval starting at `t0`, with the exact `split_wall`
/// durations the caller feeds its timeline (`gather_wall` +
/// `compute_wall` = the section's wall clock — the span sum reproduces
/// the measured wall), plus one per-rank `detail` span pair carved
/// from each rank's own kernel timers.
pub(crate) fn record_compute_spans(tr: &Tracer, t0: f64, gather_wall: f64,
                                   compute_wall: f64, timers: &[KernelTimers],
                                   bytes: u64, rows: u64, tokens: u64,
                                   chunk: Option<usize>, backward: bool) {
    let mut g = SpanRecord::new(TracePhase::Gather, t0, gather_wall);
    g.bytes = bytes;
    g.rows = rows;
    g.tokens = tokens;
    g.chunk = chunk;
    g.backward = backward;
    tr.record_span(g);
    let mut cm = SpanRecord::new(TracePhase::ExpertGemm, t0 + gather_wall,
                                 compute_wall);
    cm.rows = rows;
    cm.tokens = tokens;
    cm.chunk = chunk;
    cm.backward = backward;
    tr.record_span(cm);
    for (rank, tm) in timers.iter().enumerate() {
        if tm.gather_s > 0.0 {
            let mut s = SpanRecord::new(TracePhase::Gather, t0, tm.gather_s);
            s.rank = Some(rank);
            s.chunk = chunk;
            s.backward = backward;
            s.detail = true;
            tr.record_span(s);
        }
        if tm.compute_s > 0.0 {
            let mut s = SpanRecord::new(TracePhase::ExpertGemm,
                                        t0 + tm.gather_s, tm.compute_s);
            s.rank = Some(rank);
            s.chunk = chunk;
            s.backward = backward;
            s.detail = true;
            tr.record_span(s);
        }
    }
}

// -- sharded engine ---------------------------------------------------------

/// Everything derivable from (routing, topology) alone — computed once
/// per distinct [`StepBatch`] (keyed by batch id in the engines' LRU
/// caches) and reused by every later session over it. Pure index data:
/// the [`RowIndexPlan`] is what the exchange *transfers*; no activation
/// row is ever copied into a plan.
pub(crate) struct BatchPlan {
    /// per (rank, expert) source token indices + gate slots + src ranks,
    /// plus the analytic src→dst row matrix
    pub(crate) rows: RowIndexPlan,
    /// origin slot → (dst rank, dst-local slot): where the combine
    /// scatter reads each routed output row, in place
    pub(crate) ret_lookup: Vec<(u32, u32)>,
    /// resident tokens per home rank (batch-local token ids)
    pub(crate) tokens_of_rank: Vec<Vec<u32>>,
}

impl BatchPlan {
    /// Derive the routing plan of `disp` under `topo`. Token residency
    /// is decided in *global* token coordinates: a token's home rank is
    /// `topo.rank_of_token(token_base + t, global_tokens)`, so a chunk
    /// of a larger batch (the pipelined engine's unit of work) keeps the
    /// exact residency — and therefore the exact cross-rank byte count —
    /// its tokens have in the whole batch. The barrier engine passes
    /// `token_base = 0` and `global_tokens = disp.num_tokens`.
    pub(crate) fn build(disp: &DispatchStructures, topo: &EpTopology, token_base: usize,
                        global_tokens: usize) -> Result<BatchPlan, String> {
        let (l, r) = (disp.num_tokens, topo.ranks);
        let token_rank: Vec<u32> = (0..l)
            .map(|t| topo.rank_of_token(token_base + t, global_tokens) as u32)
            .collect();
        let rows = RowIndexPlan::build(disp, r, &topo.assignment().rank_of,
                                       &token_rank)?;
        let mut ret_lookup = vec![(0u32, 0u32); disp.slots()];
        for (dst, rr) in rows.per_rank.iter().enumerate() {
            for (ls, &origin) in rr.gate_slots.iter().enumerate() {
                ret_lookup[origin as usize] = (dst as u32, ls as u32);
            }
        }
        let mut tokens_of_rank: Vec<Vec<u32>> = vec![Vec::new(); r];
        for (t, &home) in token_rank.iter().enumerate() {
            tokens_of_rank[home as usize].push(t as u32);
        }
        Ok(BatchPlan { rows, ret_lookup, tokens_of_rank })
    }

    pub(crate) fn ranks(&self) -> usize {
        self.rows.ranks
    }
}

struct ShardedSession {
    id: u64,
    batch: StepBatch,
    /// per-rank saved state (policy-dependent)
    saved: Vec<SavedActs>,
}

/// R simulated ranks over the worker pool, index-driven exchange,
/// analytic traffic derived from the cached [`RowIndexPlan`].
pub struct ShardedEngine {
    pub topo: EpTopology,
    pub rank_params: Vec<RankExperts>,
    d_model: usize,
    d_hidden: usize,
    /// whether the experts are gated (SwiGLU) — from the store at build
    gated: bool,
    workers: usize,
    policy: CheckpointPolicy,
    /// routed-row tile of the blocked kernels (`[ep] tile_rows`)
    tile_rows: usize,
    engine_tag: u64,
    sessions_opened: u64,
    session: Option<ShardedSession>,
    /// LRU routing-plan cache by (batch id, layer), bounded at
    /// `plan_cache_cap`
    plans: Vec<((u64, u32), BatchPlan)>,
    plan_cache_cap: usize,
    traffic: Traffic,
    mem: Vec<MemoryBreakdown>,
    /// attached observability handle; `None` keeps the hot path free
    /// of any tracing cost at all (see [`crate::trace`])
    tracer: Option<Tracer>,
    /// attached expert-load tracker, same Option-gating contract
    load: Option<ExpertLoadTracker>,
}

impl ShardedEngine {
    /// `workers` caps the threads driving ranks (one rank per worker at a
    /// time; R > workers just queues ranks, changing nothing observable).
    pub fn new(topo: EpTopology, store: &ExpertStore,
               workers: usize) -> Result<ShardedEngine, String> {
        ShardedEngine::with_policy(topo, store, workers, CheckpointPolicy::default())
    }

    pub fn with_policy(topo: EpTopology, store: &ExpertStore, workers: usize,
                       policy: CheckpointPolicy) -> Result<ShardedEngine, String> {
        if topo.num_experts != store.experts.len() {
            return Err(format!(
                "topology has {} experts, store has {}",
                topo.num_experts,
                store.experts.len()
            ));
        }
        let rank_params = store.shard(&topo.assignment());
        Ok(ShardedEngine {
            topo,
            rank_params,
            d_model: store.d_model,
            d_hidden: store.d_hidden,
            gated: store.gated(),
            workers: workers.max(1),
            policy,
            tile_rows: DEFAULT_TILE_ROWS,
            engine_tag: NEXT_ENGINE_TAG.fetch_add(1, Ordering::Relaxed),
            sessions_opened: 0,
            session: None,
            plans: Vec::new(),
            plan_cache_cap: PLAN_CACHE_CAP,
            traffic: Traffic::default(),
            mem: Vec::new(),
            tracer: None,
            load: None,
        })
    }

    /// Set the blocked-kernel row tile (≥ 1). Outputs and gradients are
    /// bit-identical for every tile size — the knob only moves
    /// throughput and per-rank staging-tile residency.
    pub fn set_tile_rows(&mut self, tile_rows: usize) {
        self.tile_rows = tile_rows.max(1);
    }

    /// Raise/lower the routing-plan cache bound (≥ 1, trimming
    /// immediately). A caller that cycles a known working set of batches
    /// (grad-accum microbatching is LRU's worst case: with cap < working
    /// set every access misses) should set this to at least that set's
    /// size; see [`PLAN_CACHE_CAP`].
    pub fn set_plan_cache_cap(&mut self, cap: usize) {
        self.plan_cache_cap = cap.max(1);
        while self.plans.len() > self.plan_cache_cap {
            self.plans.remove(0);
        }
    }

    /// Index of the cached routing plan for `batch`, building it on
    /// first sight of this (batch id, layer) key ([`lru_get_or_insert`]
    /// semantics: a hit refreshes recency, a miss beyond the cap evicts
    /// the least-recently-used plan, and an evicted batch is
    /// transparently re-planned on re-admission).
    fn plan_index(&mut self, batch: &StepBatch) -> Result<usize, String> {
        let topo = &self.topo;
        lru_get_or_insert(&mut self.plans, self.plan_cache_cap, batch.plan_key(), || {
            BatchPlan::build(batch.disp(), topo, 0, batch.num_tokens())
        })
    }

    /// Routing plans currently cached (≤ the cache bound).
    pub fn cached_plans(&self) -> usize {
        self.plans.len()
    }

    /// Whether `batch`'s routing plan is currently resident in the cache.
    pub fn has_cached_plan(&self, batch: &StepBatch) -> bool {
        self.plans.iter().any(|(key, _)| *key == batch.plan_key())
    }

    /// The one backward: parameter grads always, per-rank ∂x rows
    /// collected and folded home (global expert-major position order —
    /// see `fold_dx`) when requested. The ∂x ops touch separate memory,
    /// so parameter grads are bit-identical either way.
    fn backward_impl(&mut self, handle: StepHandle, d_out: &[f32],
                     grads: &mut ExpertGrads,
                     d_x: Option<&mut [f32]>) -> Result<(), String> {
        let (d, h) = (self.d_model, self.d_hidden);
        if handle.engine_tag != self.engine_tag {
            return Err("step handle belongs to a different engine".into());
        }
        match &self.session {
            None => return Err("no open step session (forward not called)".into()),
            Some(s) if s.id != handle.session => {
                return Err(format!(
                    "stale step handle: session {} superseded by {}",
                    handle.session, s.id
                ));
            }
            Some(_) => {}
        }
        grads
            .check_like(self.topo.num_experts, d, h)
            .map_err(|e| e.to_string())?;
        // shape checks before the session is consumed (see the
        // single-rank engine for the retryability contract)
        let l_tokens = self.session.as_ref().unwrap().batch.num_tokens();
        if d_out.len() != l_tokens * d {
            return Err(format!(
                "d_out has {} elements, expected L·d = {}",
                d_out.len(),
                l_tokens * d
            ));
        }
        if let Some(dx) = &d_x {
            if dx.len() != l_tokens * d {
                return Err(format!(
                    "d_x has {} elements, expected L·d = {}",
                    dx.len(),
                    l_tokens * d
                ));
            }
        }
        let st = self.session.take().unwrap();
        let want_dx = d_x.is_some();
        let r = self.topo.ranks;
        let workers = self.workers.min(r);
        let tile = self.tile_rows;
        // re-resolve by (batch id, layer): still cached in the common
        // case, and transparently re-planned if many other batches
        // evicted it between this session's forward and backward
        let plan_idx = self.plan_index(&st.batch)?;
        let plan = &self.plans[plan_idx].1;
        let rows_ref = &plan.rows;
        let gates = st.batch.gates();
        let x = st.batch.x();
        let saved = &st.saved;

        // backward "exchange": gated gradient rows mirror the forward
        // dispatch row-for-row, so the cross-rank bytes are the same
        // analytic count; under RecomputeAll the backward re-gathers
        // *indices, not rows* — the re-exchange a real interconnect
        // would run is still priced into `recompute_bytes`
        let grad_bytes = rows_ref.cross_rank_bytes(d, 4);
        let recompute_bytes = if self.policy == CheckpointPolicy::RecomputeAll {
            grad_bytes
        } else {
            0
        };
        // a saving policy whose session stored nothing is a corrupted
        // session — fail loudly rather than silently re-gathering
        if self.policy != CheckpointPolicy::RecomputeAll
            && saved.iter().any(|sv| matches!(sv, SavedActs::Nothing))
        {
            return Err("session saved nothing under a saving policy".into());
        }

        // per-rank gradient accumulation into the caller's accumulator:
        // move each expert's accumulator into its owning rank's work
        // item (plus a per-local-slot ∂x buffer when requested), let one
        // worker per rank extend it in segment order via the blocked
        // kernels, reassemble
        let assignment = self.topo.assignment();
        let mut work: Vec<RankBwdWork> = (0..r)
            .map(|dst| RankBwdWork {
                bucket: Vec::new(),
                dxs: vec![0.0f32; if want_dx {
                    rows_ref.per_rank[dst].local_slots() * d
                } else {
                    0
                }],
                timers: KernelTimers::default(),
            })
            .collect();
        for (e, g) in grads.experts.drain(..).enumerate() {
            work[assignment.rank_of[e] as usize].bucket.push((e, g));
        }
        let timed = self.tracer.is_some();
        let trace_t0 = self.tracer.as_ref().map(|tr| tr.now_s());
        scope_chunks(&mut work, 1, workers, |dst, chunk| {
            let RankBwdWork { bucket, dxs, timers } = &mut chunk[0];
            let rr = &rows_ref.per_rank[dst];
            let (xsrc, hidden): (RowsSrc, Option<SavedHiddenRef<'_>>) =
                match &saved[dst] {
                    SavedActs::All { xs, pre, act, gate } => (
                        RowsSrc::Packed(&xs[..]),
                        Some(SavedHiddenRef {
                            pre: &pre[..],
                            act: &act[..],
                            gate: (!gate.is_empty()).then_some(&gate[..]),
                        }),
                    ),
                    SavedActs::Inputs { xs } => (RowsSrc::Packed(&xs[..]), None),
                    // RecomputeAll: gather straight from the shared batch
                    SavedActs::Nothing => (RowsSrc::Tokens(x), None),
                };
            let mut scratch = KernelScratch::new(d, h, tile);
            for (i, (e, g)) in bucket.iter_mut().enumerate() {
                debug_assert_eq!(*e as u32, rr.experts[i]);
                let p = &self.rank_params[dst].experts[i].1;
                let lo = rr.expert_offsets[i] as usize;
                let hi = rr.expert_offsets[i + 1] as usize;
                if lo == hi {
                    continue;
                }
                // timers run only when a tracer is attached — the
                // untraced hot path skips every clock read
                backward_segment(p, g, d, h, lo, hi, &xsrc, &rr.tokens, 0,
                                 &rr.gate_slots, 0, d_out, gates, hidden,
                                 if want_dx { Some(&mut dxs[..]) } else { None },
                                 &mut scratch,
                                 if timed { Some(&mut *timers) } else { None });
            }
        });
        if let (Some(tr), Some(t0)) = (&self.tracer, trace_t0) {
            let wall = (tr.now_s() - t0).max(0.0);
            let timers: Vec<KernelTimers> = work.iter().map(|w| w.timers).collect();
            let (g_sum, c_sum) = timers.iter().fold((0.0f64, 0.0f64), |a, t| {
                (a.0 + t.gather_s, a.1 + t.compute_s)
            });
            let (gather_wall, compute_wall) = split_wall(wall, g_sum, c_sum);
            record_compute_spans(tr, t0, gather_wall, compute_wall, &timers,
                                 grad_bytes + recompute_bytes,
                                 rows_ref.local_rows() + rows_ref.cross_rows(),
                                 l_tokens as u64, None, true);
        }
        if let Some(dx) = d_x {
            fold_dx(rows_ref, &work, d, self.topo.num_experts, 0, dx);
        }
        let mut dense: Vec<Option<ExpertParams>> =
            (0..self.topo.num_experts).map(|_| None).collect();
        for w in work {
            for (e, g) in w.bucket {
                dense[e] = Some(g);
            }
        }
        grads.experts = dense
            .into_iter()
            .enumerate()
            .map(|(e, g)| g.ok_or_else(|| format!("expert {e} grads lost")))
            .collect::<Result<Vec<_>, String>>()?;
        self.traffic.grad_bytes += grad_bytes;
        self.traffic.recompute_bytes += recompute_bytes;
        Ok(())
    }
}

impl ExecutionEngine for ShardedEngine {
    fn name(&self) -> String {
        format!("sharded-r{}-{}", self.topo.ranks, self.topo.placement)
    }

    fn ranks(&self) -> usize {
        self.topo.ranks
    }

    fn policy(&self) -> CheckpointPolicy {
        self.policy
    }

    fn forward(&mut self, batch: &StepBatch) -> Result<StepHandle, String> {
        let (d, h) = (self.d_model, self.d_hidden);
        check_batch(batch, d, self.topo.num_experts)?;
        let r = self.topo.ranks;
        let workers = self.workers.min(r);
        let policy = self.policy;
        let plan_idx = self.plan_index(batch)?;
        let plan = &self.plans[plan_idx].1;
        let disp = batch.disp();
        let x = batch.x();
        let gates = batch.gates();
        let (l, k) = (disp.num_tokens, disp.top_k);

        // (i) dispatch "exchange": nothing is packed — the cached
        // RowIndexPlan already tells every rank where its routed rows
        // live, and the bytes a real interconnect would move are derived
        // from its src→dst row matrix (bit-equal to what the retired
        // packed buffers measured; the property suite pins it)
        let cross_bytes = plan.rows.cross_rank_bytes(d, 4);
        let traffic = Traffic {
            dispatch_bytes: cross_bytes,
            // every routed row returns to its home rank in the combine
            combine_bytes: cross_bytes,
            cross_rows: plan.rows.cross_rows(),
            local_rows: plan.rows.local_rows(),
            ..Traffic::default()
        };

        // (ii) per-rank blocked expert compute, gathering rows directly
        // from the shared batch (one definition with the pipelined
        // engine — the engines cannot drift apart on the kernel path).
        // The kernel timers (and every clock read) run only with a
        // tracer attached — numerics are identical either way.
        let trace_t0 = self.tracer.as_ref().map(|tr| tr.now_s());
        let computed =
            compute_chunk_indexed(plan, &self.rank_params, policy, d, h, workers,
                                  self.tile_rows, x, 0, self.tracer.is_some());
        let mut saved = Vec::with_capacity(r);
        let mut ys_of = Vec::with_capacity(r);
        let mut timers = Vec::with_capacity(r);
        for (sv, ys, tm) in computed {
            saved.push(sv);
            ys_of.push(ys);
            timers.push(tm);
        }
        if let (Some(tr), Some(t0)) = (&self.tracer, trace_t0) {
            let wall = (tr.now_s() - t0).max(0.0);
            let (g_sum, c_sum) = timers.iter().fold((0.0f64, 0.0f64), |a, t| {
                (a.0 + t.gather_s, a.1 + t.compute_s)
            });
            let (gather_wall, compute_wall) = split_wall(wall, g_sum, c_sum);
            record_compute_spans(tr, t0, gather_wall, compute_wall, &timers,
                                 cross_bytes,
                                 plan.rows.local_rows() + plan.rows.cross_rows(),
                                 l as u64, None, false);
        }

        // (iii) combine scatter on each token's home rank, reading each
        // expert-output row in place via the return lookup (same j order
        // as the single-rank path — bit-identical accumulation)
        let trace_tc = self.tracer.as_ref().map(|tr| tr.now_s());
        let mut out = vec![0.0f32; l * d];
        combine_chunk(plan, gates, &ys_of, d, k, workers, 0, &mut out);
        if let (Some(tr), Some(t0)) = (&self.tracer, trace_tc) {
            let mut s = SpanRecord::new(TracePhase::Combine, t0,
                                        (tr.now_s() - t0).max(0.0));
            s.bytes = cross_bytes;
            s.rows = plan.rows.local_rows() + plan.rows.cross_rows();
            s.tokens = l as u64;
            tr.record_span(s);
        }

        // per-rank Figure-3/5 accounting from what was actually resident:
        // the packed send/return buffers are gone, so comm residency is
        // one inbound gather tile + one outbound return tile per rank
        let mem: Vec<MemoryBreakdown> = (0..r)
            .map(|rank| {
                let n_local = plan.rows.per_rank[rank].local_slots() as u64;
                let resident = plan.tokens_of_rank[rank].len() as u64;
                MemoryBreakdown {
                    // ys per local slot + resident token rows in +
                    // combined rows out, plus the policy-saved tensors
                    data_bytes: 4 * d as u64 * (n_local + 2 * resident)
                        + n_local
                            * policy.saved_bytes_per_slot(d as u64, h as u64, 4,
                                                          self.gated),
                    index_bytes: plan.rows.per_rank[rank].metadata_bytes() as u64,
                    extra_bytes: staging_bytes(
                        self.tile_rows as u64, d as u64, 4,
                        plan.rows.remote_in_rows(rank),
                        plan.rows.remote_return_rows(rank),
                        if self.gated { h as u64 } else { 0 }),
                }
            })
            .collect();
        if let Some(tr) = &self.tracer {
            for (rank, m) in mem.iter().enumerate() {
                tr.gauge(rank, "resident_bytes", m.data_bytes as f64,
                         mem_peak_phase(m));
                tr.gauge(rank, "routed_rows",
                         plan.rows.per_rank[rank].local_slots() as f64, "gather");
            }
        }
        if let Some(lt) = &self.load {
            // routed-row ground truth per global expert, read off the
            // RowIndexPlan's per-rank segments, aggregated through the
            // live placement
            let mut rows = vec![0u64; self.topo.num_experts];
            for rr in &plan.rows.per_rank {
                for (i, &e) in rr.experts.iter().enumerate() {
                    rows[e as usize] += rr.expert_len(i) as u64;
                }
            }
            lt.record_rows(&rows, &self.topo.assignment().rank_of, gates);
        }
        self.mem = mem;
        self.traffic = traffic;
        self.sessions_opened += 1;
        let session = self.sessions_opened;
        self.session = Some(ShardedSession { id: session, batch: batch.share(), saved });
        Ok(StepHandle { engine_tag: self.engine_tag, session, out })
    }

    fn backward_into(&mut self, handle: StepHandle, d_out: &[f32],
                     grads: &mut ExpertGrads) -> Result<(), String> {
        self.backward_impl(handle, d_out, grads, None)
    }

    fn backward_into_dx(&mut self, handle: StepHandle, d_out: &[f32],
                        grads: &mut ExpertGrads, d_x: &mut [f32]) -> Result<(), String> {
        self.backward_impl(handle, d_out, grads, Some(d_x))
    }


    fn zero_grads(&self) -> ExpertGrads {
        ExpertGrads::zeros_gated(self.topo.num_experts, self.d_model,
                                 self.d_hidden, self.gated)
    }

    fn apply_update(&mut self, delta: &ExpertGrads) -> Result<(), String> {
        delta
            .check_like(self.topo.num_experts, self.d_model, self.d_hidden)
            .map_err(|e| e.to_string())?;
        for rp in &mut self.rank_params {
            for (e, p) in &mut rp.experts {
                add_params(p, &delta.experts[*e as usize]);
            }
        }
        Ok(())
    }

    fn traffic(&self) -> Traffic {
        self.traffic
    }

    fn memory_per_rank(&self) -> Vec<MemoryBreakdown> {
        if self.mem.is_empty() {
            vec![
                MemoryBreakdown { data_bytes: 0, index_bytes: 0, extra_bytes: 0 };
                self.topo.ranks
            ]
        } else {
            self.mem.clone()
        }
    }

    fn gather_params(&self) -> Result<ExpertStore, String> {
        ExpertStore::gather(&self.rank_params, self.topo.num_experts)
    }

    fn load_params(&mut self, store: &ExpertStore) -> Result<(), String> {
        check_store_like(store, self.topo.num_experts, self.d_model,
                         self.d_hidden, self.gated)?;
        self.rank_params = store.shard(&self.topo.assignment());
        self.session = None;
        Ok(())
    }

    fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = Some(tracer);
    }

    fn set_load_tracker(&mut self, tracker: ExpertLoadTracker) {
        self.load = Some(tracker);
    }
}

/// Shape gate for [`ExecutionEngine::load_params`]: the incoming store
/// must agree with the engine on expert count, dimensions, gating, and
/// every per-expert tensor length — a half-shaped store is corruption,
/// and restoring any of it would be the silent half-restore the
/// resilience tests outlaw.
pub(crate) fn check_store_like(store: &ExpertStore, num_experts: usize, d: usize,
                               h: usize, gated: bool) -> Result<(), String> {
    if store.experts.len() != num_experts || store.d_model != d
        || store.d_hidden != h
    {
        return Err(format!(
            "snapshot store (E={}, d={}, h={}) does not match engine \
             (E={num_experts}, d={d}, h={h})",
            store.experts.len(),
            store.d_model,
            store.d_hidden
        ));
    }
    if store.gated() != gated {
        return Err("snapshot store gating disagrees with the engine".into());
    }
    for (e, p) in store.experts.iter().enumerate() {
        let w3_ok = if gated { p.w3.len() == h * d } else { p.w3.is_empty() };
        if p.w1.len() != h * d || p.b1.len() != h || p.w2.len() != d * h
            || p.b2.len() != d || !w3_ok
        {
            return Err(format!("snapshot expert {e} tensor shapes are torn"));
        }
    }
    Ok(())
}

// -- packed-path reference baseline -----------------------------------------

/// The **pre-PR-5 materialized hot path**, preserved verbatim as the
/// measurable baseline: pack per-(src, dst) send buffers, unpack per
/// rank into a routed-input buffer, run the per-row dot-product kernels,
/// pack per-(dst, home) return buffers, combine through them; the
/// backward packs the gated gradient exchange and walks rows one at a
/// time. Bit-identical to the engines — the `ep_engine.rs` matrix pins
/// new == old for outputs and gradients — but carrying the three
/// whole-batch buffer copies and the per-row weight streaming the
/// index-driven blocked path eliminates.
///
/// The routing plan is built once at construction and reused across
/// steps, exactly as the retired engines' LRU plan caches amortized it —
/// so `ep-bench --json-out` / `benches/ep_alltoall.rs` measure the
/// buffer+kernel cost difference at the same worker count, not a
/// plan-rebuild penalty the old path never paid per step.
pub struct PackedReference {
    plan: BatchPlan,
    /// origin slot → (dst rank, index within rets[dst][home]) — the old
    /// return-buffer cursor layout
    ret_pos: Vec<(u32, u32)>,
    /// expert→rank map for the backward's per-rank gradient bucketing
    assignment: crate::dispatch::shard::ExpertAssignment,
    ranks: usize,
}

impl PackedReference {
    pub fn new(topo: &EpTopology, batch: &StepBatch) -> Result<PackedReference, String> {
        let l = batch.num_tokens();
        let plan = BatchPlan::build(batch.disp(), topo, 0, l)?;
        let r = topo.ranks;
        let mut ret_pos = vec![(0u32, 0u32); batch.disp().slots()];
        for (dst, rr) in plan.rows.per_rank.iter().enumerate() {
            let mut counter = vec![0u32; r];
            for ls in 0..rr.local_slots() {
                let home = rr.src_rank[ls] as usize;
                ret_pos[rr.gate_slots[ls] as usize] = (dst as u32, counter[home]);
                counter[home] += 1;
            }
        }
        Ok(PackedReference {
            plan,
            ret_pos,
            assignment: topo.assignment(),
            ranks: r,
        })
    }

    /// One fwd+bwd step over the cached plan; returns the combined
    /// output and the parameter gradients for `d_out`.
    pub fn step(&self, store: &ExpertStore, batch: &StepBatch, d_out: &[f32],
                policy: CheckpointPolicy, workers: usize)
                -> Result<(Vec<f32>, ExpertGrads), String> {
        packed_step_impl(self, store, batch, d_out, policy, workers)
    }
}

/// One-shot convenience wrapper over [`PackedReference`] (plan built and
/// discarded — tests use this; benches amortize the plan).
pub fn packed_reference_step(topo: &EpTopology, store: &ExpertStore,
                             batch: &StepBatch, d_out: &[f32],
                             policy: CheckpointPolicy, workers: usize)
                             -> Result<(Vec<f32>, ExpertGrads), String> {
    PackedReference::new(topo, batch)?.step(store, batch, d_out, policy, workers)
}

fn packed_step_impl(pr: &PackedReference, store: &ExpertStore,
                    batch: &StepBatch, d_out: &[f32],
                    policy: CheckpointPolicy, workers: usize)
                    -> Result<(Vec<f32>, ExpertGrads), String> {
    let (d, h) = (store.d_model, store.d_hidden);
    check_batch(batch, d, store.experts.len())?;
    let l = batch.num_tokens();
    if d_out.len() != l * d {
        return Err(format!(
            "d_out has {} elements, expected L·d = {}",
            d_out.len(),
            l * d
        ));
    }
    let plan = &pr.plan;
    let rows = &plan.rows;
    let r = pr.ranks;
    if rows.per_rank.iter().map(|rr| rr.local_slots()).sum::<usize>()
        != batch.disp().slots()
    {
        return Err("packed reference plan does not cover this batch".into());
    }
    if pr.assignment.rank_of.len() != store.experts.len() {
        return Err(format!(
            "packed reference plan covers {} experts, store has {}",
            pr.assignment.rank_of.len(),
            store.experts.len()
        ));
    }
    let ret_pos = &pr.ret_pos;
    let workers = workers.max(1).min(r);
    let x = batch.x();
    let gates = batch.gates();
    let k = batch.disp().top_k;

    // (i) pack send buffers: send[src][dst] rows in dst-local slot order
    // (pre-sized from the row matrix, as the old pack helpers were)
    let send: Vec<Vec<Vec<f32>>> = par_map(r, workers, |src| {
        (0..r)
            .map(|dst| {
                let rr = &rows.per_rank[dst];
                let mut buf =
                    Vec::with_capacity(rows.rows(src, dst) as usize * d);
                for ls in 0..rr.local_slots() {
                    if rr.src_rank[ls] as usize == src {
                        let t = rr.tokens[ls] as usize;
                        buf.extend_from_slice(&x[t * d..(t + 1) * d]);
                    }
                }
                buf
            })
            .collect()
    });

    // (ii) per-rank unpack, per-row expert compute, return-buffer pack
    let gated = store.gated();
    type RankOut = (Vec<f32>, Vec<Vec<f32>>,
                    Option<(Vec<f32>, Vec<f32>, Vec<f32>)>);
    let computed: Vec<RankOut> = par_map(r, workers, |dst| {
        let rr = &rows.per_rank[dst];
        let n_local = rr.local_slots();
        let mut xs = vec![0.0f32; n_local * d];
        let mut cursor = vec![0usize; r];
        for ls in 0..n_local {
            let src = rr.src_rank[ls] as usize;
            let i = cursor[src];
            xs[ls * d..(ls + 1) * d]
                .copy_from_slice(&send[src][dst][i * d..(i + 1) * d]);
            cursor[src] = i + 1;
        }
        let save_hidden = policy == CheckpointPolicy::SaveAll;
        let mut ys = vec![0.0f32; n_local * d];
        let mut pre = vec![0.0f32; if save_hidden { n_local * h } else { 0 }];
        let mut act = vec![0.0f32; if save_hidden { n_local * h } else { 0 }];
        let mut gate =
            vec![0.0f32; if save_hidden && gated { n_local * h } else { 0 }];
        let mut hidden = vec![0.0f32; h];
        let mut pre_row = vec![0.0f32; if gated { h } else { 0 }];
        let mut gate_row = vec![0.0f32; if gated { h } else { 0 }];
        for (i, &e) in rr.experts.iter().enumerate() {
            let p = &store.experts[e as usize];
            let lo = rr.expert_offsets[i] as usize;
            let hi = rr.expert_offsets[i + 1] as usize;
            for ls in lo..hi {
                match (save_hidden, gated) {
                    (true, false) => {
                        expert_forward_saving(p, d, h, &xs[ls * d..(ls + 1) * d],
                                              &mut ys[ls * d..(ls + 1) * d],
                                              &mut pre[ls * h..(ls + 1) * h],
                                              &mut act[ls * h..(ls + 1) * h]);
                    }
                    (true, true) => {
                        expert_forward_saving_swiglu(
                            p, d, h, &xs[ls * d..(ls + 1) * d],
                            &mut ys[ls * d..(ls + 1) * d],
                            &mut pre[ls * h..(ls + 1) * h],
                            &mut gate[ls * h..(ls + 1) * h],
                            &mut act[ls * h..(ls + 1) * h]);
                    }
                    (false, false) => {
                        expert_forward(p, d, h, &xs[ls * d..(ls + 1) * d],
                                       &mut ys[ls * d..(ls + 1) * d],
                                       &mut hidden);
                    }
                    (false, true) => {
                        expert_forward_saving_swiglu(
                            p, d, h, &xs[ls * d..(ls + 1) * d],
                            &mut ys[ls * d..(ls + 1) * d], &mut pre_row,
                            &mut gate_row, &mut hidden);
                    }
                }
            }
        }
        let rets: Vec<Vec<f32>> = (0..r)
            .map(|home| {
                let mut buf =
                    Vec::with_capacity(rows.rows(home, dst) as usize * d);
                for ls in 0..n_local {
                    if rr.src_rank[ls] as usize == home {
                        buf.extend_from_slice(&ys[ls * d..(ls + 1) * d]);
                    }
                }
                buf
            })
            .collect();
        (xs, rets, save_hidden.then(|| (pre, act, gate)))
    });

    // (iii) combine on each token's home rank through the return buffers
    let mut out = vec![0.0f32; l * d];
    for (home, toks) in plan.tokens_of_rank.iter().enumerate() {
        for &t in toks {
            let t = t as usize;
            let o = &mut out[t * d..(t + 1) * d];
            for j in 0..k {
                let slot = t * k + j;
                let g = gates[slot];
                let (dst, idx) = ret_pos[slot];
                let buf = &computed[dst as usize].1[home];
                let row = &buf[idx as usize * d..(idx as usize + 1) * d];
                for c in 0..d {
                    o[c] += g * row[c];
                }
            }
        }
    }

    // backward: pack the gated gradient exchange, unpack per rank, walk
    // rows one at a time through the row kernels
    let dsend: Vec<Vec<Vec<f32>>> = par_map(r, workers, |home| {
        (0..r)
            .map(|dst| {
                let rr = &rows.per_rank[dst];
                let mut buf =
                    Vec::with_capacity(rows.rows(home, dst) as usize * d);
                for ls in 0..rr.local_slots() {
                    if rr.src_rank[ls] as usize == home {
                        let t = rr.tokens[ls] as usize;
                        let g = gates[rr.gate_slots[ls] as usize];
                        for c in 0..d {
                            buf.push(g * d_out[t * d + c]);
                        }
                    }
                }
                buf
            })
            .collect()
    });
    let mut grads = ExpertGrads::zeros_gated(store.experts.len(), d, h, gated);
    let assignment = &pr.assignment;
    let mut work: Vec<RankBwdWork> = (0..r)
        .map(|_| RankBwdWork {
            bucket: Vec::new(),
            dxs: Vec::new(),
            timers: KernelTimers::default(),
        })
        .collect();
    for (e, g) in grads.experts.drain(..).enumerate() {
        work[assignment.rank_of[e] as usize].bucket.push((e, g));
    }
    scope_chunks(&mut work, 1, workers, |dst, chunk| {
        let bucket = &mut chunk[0].bucket;
        let rr = &rows.per_rank[dst];
        let n_local = rr.local_slots();
        let mut dys = vec![0.0f32; n_local * d];
        let mut cursor = vec![0usize; r];
        for ls in 0..n_local {
            let src = rr.src_rank[ls] as usize;
            let i = cursor[src];
            dys[ls * d..(ls + 1) * d]
                .copy_from_slice(&dsend[src][dst][i * d..(i + 1) * d]);
            cursor[src] = i + 1;
        }
        let (xs, _, saved_hidden) = &computed[dst];
        let mut pre_row = vec![0.0f32; h];
        let mut act_row = vec![0.0f32; h];
        let mut gate_row = vec![0.0f32; if gated { h } else { 0 }];
        let mut dz = vec![0.0f32; h];
        let mut da_row = vec![0.0f32; if gated { h } else { 0 }];
        let mut dg_row = vec![0.0f32; if gated { h } else { 0 }];
        for (i, (e, g)) in bucket.iter_mut().enumerate() {
            debug_assert_eq!(*e as u32, rr.experts[i]);
            let p = &store.experts[*e];
            let lo = rr.expert_offsets[i] as usize;
            let hi = rr.expert_offsets[i + 1] as usize;
            for ls in lo..hi {
                let xrow = &xs[ls * d..(ls + 1) * d];
                let dy = &dys[ls * d..(ls + 1) * d];
                let (pre, gate, act): (&[f32], &[f32], &[f32]) = match saved_hidden
                {
                    Some((pre, act, gate)) => (
                        &pre[ls * h..(ls + 1) * h],
                        if gated { &gate[ls * h..(ls + 1) * h] } else { &[] },
                        &act[ls * h..(ls + 1) * h],
                    ),
                    None => {
                        if gated {
                            recompute_hidden_swiglu(p, d, h, xrow, &mut pre_row,
                                                    &mut gate_row, &mut act_row);
                        } else {
                            recompute_hidden(p, d, h, xrow, &mut pre_row,
                                             &mut act_row);
                        }
                        (&pre_row[..], &gate_row[..], &act_row[..])
                    }
                };
                if gated {
                    expert_backward_row_swiglu(p, g, d, h, xrow, dy, pre, gate,
                                               act, &mut dz, &mut da_row,
                                               &mut dg_row, None);
                } else {
                    expert_backward_row(p, g, d, h, xrow, dy, pre, act, &mut dz,
                                        None);
                }
            }
        }
    });
    let mut dense: Vec<Option<ExpertParams>> =
        (0..store.experts.len()).map(|_| None).collect();
    for w in work {
        for (e, g) in w.bucket {
            dense[e] = Some(g);
        }
    }
    grads.experts = dense
        .into_iter()
        .enumerate()
        .map(|(e, g)| g.ok_or_else(|| format!("expert {e} grads lost")))
        .collect::<Result<Vec<_>, String>>()?;
    Ok((out, grads))
}

// -- config-driven construction ---------------------------------------------

/// The synthetic workload an `[ep]` config describes — routing, token
/// activations `x` (L·d), combine gates (L·k), and regression targets
/// (L·d). A pure function of the config, shared by `EpTrainer` and the
/// `ep-bench` subcommand so they exercise the identical exchange.
pub fn workload_from_config(
    cfg: &EpConfig,
) -> (DispatchStructures, Vec<f32>, Vec<f32>, Vec<f32>) {
    let (l, d) = (cfg.tokens, cfg.d_model);
    let mut rng = Rng::new(cfg.seed ^ 0xE9E9);
    let (disp, gates) = config_gating(cfg, &mut rng);
    let x = rng.normal_vec(l * d, 1.0);
    let target = rng.normal_vec(l * d, 1.0);
    (disp, x, gates, target)
}

/// The routing prefix of [`workload_from_config`]: same seed, same
/// gating draw, no activation/target tensors. For callers that only
/// need the dispatch structure (e.g. deriving `Placement::LoadAware`
/// loads), this skips the two `L·d` normal draws entirely.
pub fn routing_from_config(cfg: &EpConfig) -> DispatchStructures {
    let mut rng = Rng::new(cfg.seed ^ 0xE9E9);
    config_gating(cfg, &mut rng).0
}

/// The shared gating draw every config entry point starts from — one
/// definition (also behind the stack's per-layer draws), so the routing
/// they see can never drift apart.
pub(crate) fn config_gating(cfg: &EpConfig, rng: &mut Rng) -> (DispatchStructures, Vec<f32>) {
    let (l, e, k) = (cfg.tokens, cfg.num_experts, cfg.top_k);
    let gating = synthetic_gating(rng, l, e, k, cfg.skew);
    let disp = parallel_build(&gating.topk_ids, l, e, k);
    (disp, gating.gates)
}

/// [`workload_from_config`] packaged as a shareable [`StepBatch`] plus
/// the regression targets.
pub fn step_batch_from_config(cfg: &EpConfig) -> Result<(StepBatch, Vec<f32>), String> {
    let (disp, x, gates, target) = workload_from_config(cfg);
    Ok((StepBatch::new(disp, x, gates)?, target))
}

/// Build the topology an `[ep]` config describes for `ranks` ranks.
/// `Placement::LoadAware` derives per-expert routed-row loads from the
/// config's own synthetic workload — on the fixed workload the trainer
/// and benches run, that *is* "the previous step's routing" — and
/// greedily rebalances the expert→rank assignment from them.
pub fn topology_from_config(cfg: &EpConfig, ranks: usize) -> Result<EpTopology, String> {
    if cfg.placement == Placement::LoadAware {
        let disp = routing_from_config(cfg);
        let loads: Vec<u64> = (0..cfg.num_experts)
            .map(|e| disp.expert_tokens(e).len() as u64)
            .collect();
        EpTopology::load_aware(ranks, &loads)
    } else {
        EpTopology::with_placement(ranks, cfg.num_experts, cfg.placement)
    }
}

/// One MoE layer's engine for `cfg`, over a caller-provided expert
/// store and checkpoint policy — the per-layer builder
/// `coordinator::stack` assembles multi-layer stacks from. With
/// `pipeline_chunks = 0` (the default): R = 1 gives the single-rank
/// path, R > 1 the barrier-phased sharded one (one worker per rank).
/// With `pipeline_chunks > 0` the chunk-pipelined engine is built for
/// any R, overlapping each chunk's dispatch exchange with the previous
/// chunk's expert compute under the config's link/compute cost model
/// and the config's chunk-boundary balance.
pub fn layer_engine_from_config(cfg: &EpConfig, store: ExpertStore,
                                policy: CheckpointPolicy)
                                -> Result<Box<dyn ExecutionEngine>, String> {
    // the trainer cycles grad_accum microbatches every step — LRU's
    // worst-case access pattern — so the plan cache must hold them all
    let cache_cap = PLAN_CACHE_CAP.max(cfg.grad_accum);
    // tile_rows = 0 means auto; callers that came through
    // `engine_from_config_with_info` arrive already resolved, direct
    // callers probe here
    let tile_rows = if cfg.tile_rows == 0 {
        probe_tile_rows(cfg)?
    } else {
        cfg.tile_rows
    };
    if cfg.pipeline_chunks > 0 {
        let topo = topology_from_config(cfg, cfg.ranks)?;
        let cost = CostModel::new(cfg.link_gbps, cfg.compute_gflops)?;
        let mut engine = PipelinedEngine::with_policy(
            topo, &store, cfg.ranks, policy, cfg.pipeline_chunks, cost)?;
        engine.set_plan_cache_cap(cache_cap);
        engine.set_chunk_balance(cfg.chunk_balance);
        engine.set_tile_rows(tile_rows);
        return Ok(Box::new(engine));
    }
    if cfg.ranks == 1 {
        let mut engine = SingleRankEngine::with_policy(store, policy);
        engine.set_plan_cache_cap(cache_cap);
        engine.set_tile_rows(tile_rows);
        Ok(Box::new(engine))
    } else {
        let topo = topology_from_config(cfg, cfg.ranks)?;
        let mut engine = ShardedEngine::with_policy(topo, &store, cfg.ranks, policy)?;
        engine.set_plan_cache_cap(cache_cap);
        engine.set_tile_rows(tile_rows);
        Ok(Box::new(engine))
    }
}

/// The shape bucket an autotuned tile choice is keyed by — d_model,
/// d_hidden, routed rows/expert rounded up to a power of two, and the
/// activation. Shapes in one bucket see the same cache-residency
/// trade-off, so one probed tile serves all of them.
pub fn tile_bucket(cfg: &EpConfig) -> String {
    let rows = (cfg.tokens * cfg.top_k / cfg.num_experts.max(1)).max(1);
    format!("tile:d{}:h{}:r{}:{}", cfg.d_model, cfg.d_hidden,
            rows.next_power_of_two(), cfg.activation.name())
}

/// Probe `AUTOTUNE_TILE_CANDIDATES` on the real first microbatch of the
/// config's workload: for each candidate, run the blocked forward over
/// every expert segment (best of two repetitions) and let [`pick_tile`]
/// take the fastest — ties go to the smallest candidate, so the choice
/// is a deterministic function of the measurements. Numerics are
/// untouched: every candidate is bit-identical, the probe only picks
/// the throughput point.
pub fn probe_tile_rows(cfg: &EpConfig) -> Result<usize, String> {
    let (batch, _) = step_batch_from_config(cfg)?;
    let micro = if cfg.grad_accum > 1 {
        batch.split(cfg.grad_accum)?.swap_remove(0).1
    } else {
        batch
    };
    let store = ExpertStore::init_gated(cfg.num_experts, cfg.d_model,
                                        cfg.d_hidden, cfg.seed,
                                        cfg.activation.gated());
    let (d, h) = (cfg.d_model, cfg.d_hidden);
    let disp = micro.disp();
    let x = micro.x();
    let n = disp.slots();
    let mut ys = vec![0.0f32; n * d];
    Ok(pick_tile(&AUTOTUNE_TILE_CANDIDATES, |tile| {
        let mut best = f64::INFINITY;
        for _rep in 0..2 {
            let mut scratch = KernelScratch::new(d, h, tile);
            let t0 = Instant::now();
            for (e, p) in store.experts.iter().enumerate() {
                let lo = disp.expert_token_offsets[e] as usize;
                let hi = disp.expert_token_offsets[e + 1] as usize;
                if lo == hi {
                    continue;
                }
                forward_segment(p, d, h, lo, hi, x,
                                &disp.expert_token_indices, 0, &mut ys, None,
                                None, &mut scratch, None);
            }
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    }))
}

/// How [`engine_from_config_with_info`] resolved the build: the tile
/// that will run, whether a probe ran for it, whether a calibration
/// artifact warmed the cost model, and the shape bucket the tile choice
/// is keyed by.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BuildInfo {
    pub tile_rows: usize,
    pub tile_probed: bool,
    pub calibration_loaded: bool,
    pub bucket: String,
}

/// Build the engine an `[ep]` config describes: the single-layer engine
/// ([`layer_engine_from_config`] over a `cfg.seed` store) for
/// `num_layers = 1` with a fixed policy, or a
/// `coordinator::stack::MoeStack` when the config stacks layers or asks
/// the planner for a per-layer policy vector (`checkpoint = "auto"`).
/// Expert parameters are initialized from `cfg.seed` either way (gated
/// when `activation` is), so any two engines built from the same config
/// hold bit-identical weights.
pub fn engine_from_config(cfg: &EpConfig) -> Result<Box<dyn ExecutionEngine>, String> {
    engine_from_config_with_info(cfg).map(|(engine, _)| engine)
}

/// [`engine_from_config`] that also reports how the build was resolved:
/// a calibration artifact (`[ep] calibration_path`), when present and
/// readable, warms `link_gbps`/`compute_gflops` with its EWMA-folded
/// effective rates, and a stored tile for this config's
/// [`tile_bucket`] lets `tile_rows = 0` skip the probe entirely — the
/// warm-start path the acceptance criteria pin. A missing or corrupt
/// artifact falls back to the config's cold-start rates (and a live
/// probe for `tile_rows = 0`) without error.
pub fn engine_from_config_with_info(
    cfg: &EpConfig,
) -> Result<(Box<dyn ExecutionEngine>, BuildInfo), String> {
    cfg.validate()?;
    let bucket = tile_bucket(cfg);
    let mut resolved = cfg.clone();
    let calib = if cfg.calibration_path.is_empty() {
        None
    } else {
        Calibration::load(&cfg.calibration_path)
    };
    let mut info = BuildInfo {
        tile_rows: cfg.tile_rows,
        tile_probed: false,
        calibration_loaded: calib.is_some(),
        bucket: bucket.clone(),
    };
    if let Some(c) = &calib {
        resolved.link_gbps = c.link_gbps;
        resolved.compute_gflops = c.compute_gflops;
    }
    if resolved.tile_rows == 0 {
        match calib.as_ref().and_then(|c| c.tiles.get(&bucket)) {
            Some(&tile) => resolved.tile_rows = tile.max(1),
            None => {
                resolved.tile_rows = probe_tile_rows(&resolved)?;
                info.tile_probed = true;
            }
        }
    }
    info.tile_rows = resolved.tile_rows;
    let engine: Box<dyn ExecutionEngine> =
        if resolved.num_layers > 1 || resolved.checkpoint_auto {
            Box::new(super::stack::stack_from_config(&resolved)?)
        } else {
            let store = ExpertStore::init_gated(resolved.num_experts,
                                                resolved.d_model,
                                                resolved.d_hidden, resolved.seed,
                                                resolved.activation.gated());
            layer_engine_from_config(&resolved, store, resolved.checkpoint)?
        };
    Ok((engine, info))
}

// -- equivalence harness ----------------------------------------------------

/// Outcome of one sharded-vs-single verification run.
#[derive(Debug, Clone)]
pub struct EquivalenceReport {
    pub ranks: usize,
    pub bitwise_equal: bool,
    pub max_abs_diff: f64,
    pub measured_dispatch_bytes: u64,
    pub planned_cross_bytes: u64,
}

impl EquivalenceReport {
    pub fn ok(&self) -> bool {
        self.bitwise_equal
            && self.measured_dispatch_bytes == self.planned_cross_bytes
    }
}

/// Run the same workload through [`SingleRankEngine`] and
/// [`ShardedEngine`], compare outputs bit-for-bit, and check the measured
/// dispatch traffic against the analytic plan (f32 rows, dtype = 4).
pub fn check_equivalence(topo: &EpTopology, store: &ExpertStore,
                         disp: &DispatchStructures, x: &[f32],
                         gates: &[f32]) -> Result<EquivalenceReport, String> {
    let batch = StepBatch::new(disp.clone(), x.to_vec(), gates.to_vec())?;
    let mut single = SingleRankEngine::new(store.clone());
    let mut sharded = ShardedEngine::new(topo.clone(), store, topo.ranks)?;
    let a = single.forward(&batch)?.into_output();
    let b = sharded.forward(&batch)?.into_output();
    if a.len() != b.len() {
        return Err("engines returned different output sizes".into());
    }
    let bitwise_equal = a
        .iter()
        .zip(&b)
        .all(|(p, q)| p.to_bits() == q.to_bits());
    let max_abs_diff = a
        .iter()
        .zip(&b)
        .map(|(p, q)| (*p as f64 - *q as f64).abs())
        .fold(0.0f64, f64::max);
    let plan = topo.plan(disp, store.d_model, 4);
    Ok(EquivalenceReport {
        ranks: topo.ranks,
        bitwise_equal,
        max_abs_diff,
        measured_dispatch_bytes: sharded.traffic().dispatch_bytes,
        planned_cross_bytes: plan.cross_rank_bytes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ep::Placement;
    use crate::coordinator::optim::{Optimizer, Sgd};
    use crate::dispatch::gating::synthetic_gating;
    use crate::dispatch::parallel_build::parallel_build;
    use crate::testkit::fixtures::{fig2_expected, FIG2_EXPERTS, FIG2_TOKENS,
                                   FIG2_TOP_K};
    use crate::util::prng::Rng;

    fn workload(l: usize, e: usize, k: usize, d: usize, skew: f64, seed: u64) -> StepBatch {
        let mut rng = Rng::new(seed);
        let g = synthetic_gating(&mut rng, l, e, k, skew);
        let disp = parallel_build(&g.topk_ids, l, e, k);
        let x = rng.normal_vec(l * d, 1.0);
        StepBatch::new(disp, x, g.gates).unwrap()
    }

    #[test]
    fn split_bounds_weighted_edge_cases() {
        // empty input: no split is possible, even into one part
        assert!(split_bounds_weighted(&[], 1).is_err());
        assert!(split_bounds_weighted(&[], 0).is_err());
        // a single token splits into exactly one chunk and no more
        assert_eq!(split_bounds_weighted(&[7], 1).unwrap(), vec![0, 1]);
        assert!(split_bounds_weighted(&[7], 2).is_err());
        // more parts than tokens is a named error, not a panic
        assert!(split_bounds_weighted(&[1, 2], 3).is_err());
        // all weight on one token: the clamp still guarantees strictly
        // increasing bounds with >= 1 token per chunk
        let b = split_bounds_weighted(&[0, 0, 100, 0], 2).unwrap();
        assert_eq!(b.first(), Some(&0));
        assert_eq!(b.last(), Some(&4));
        assert!(b.windows(2).all(|w| w[0] < w[1]), "{b:?}");
        let b = split_bounds_weighted(&[100, 0, 0, 0], 4).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3, 4], "every chunk keeps one token");
        // all-zero weights degrade to the even token split
        assert_eq!(split_bounds_weighted(&[0, 0, 0, 0], 2).unwrap(), vec![0, 2, 4]);
        assert_eq!(split_bounds_weighted(&[0; 5], 2).unwrap(), vec![0, 2, 5]);
        // balanced weights cut at the weight midpoint, not the token one
        assert_eq!(split_bounds_weighted(&[9, 1, 1, 1], 2).unwrap(), vec![0, 1, 4]);
    }

    #[test]
    fn figure2_bit_equality_across_rank_counts() {
        let disp = fig2_expected();
        let mut rng = Rng::new(3);
        let d = 8;
        let x = rng.normal_vec(FIG2_TOKENS * d, 1.0);
        let gates = vec![0.5f32; FIG2_TOKENS * FIG2_TOP_K];
        let store = ExpertStore::init(FIG2_EXPERTS, d, 16, 11);
        for ranks in [1, 2, 4] {
            let topo = EpTopology::new(ranks, FIG2_EXPERTS).unwrap();
            let rep = check_equivalence(&topo, &store, &disp, &x, &gates)
                .unwrap();
            assert!(rep.bitwise_equal, "R={ranks}: diff {}", rep.max_abs_diff);
            assert_eq!(rep.measured_dispatch_bytes, rep.planned_cross_bytes,
                       "R={ranks}");
        }
    }

    #[test]
    fn random_gating_bit_equality_and_measured_bytes() {
        let batch = workload(96, 8, 2, 16, 1.2, 21);
        let store = ExpertStore::init(8, 16, 24, 5);
        for placement in [Placement::Contiguous, Placement::Strided] {
            for ranks in [1, 2, 4, 8] {
                let topo =
                    EpTopology::with_placement(ranks, 8, placement).unwrap();
                let rep = check_equivalence(&topo, &store, batch.disp(), batch.x(), batch.gates())
                    .unwrap();
                assert!(rep.ok(),
                        "R={ranks} {placement}: bitwise={} bytes {} vs {}",
                        rep.bitwise_equal, rep.measured_dispatch_bytes,
                        rep.planned_cross_bytes);
            }
        }
    }

    #[test]
    fn all_to_one_expert_skew_still_equal() {
        let l = 40;
        let d = 8;
        let ids = vec![0u32; l];
        let disp = parallel_build(&ids, l, 4, 1);
        let mut rng = Rng::new(9);
        let x = rng.normal_vec(l * d, 1.0);
        let gates = vec![1.0f32; l];
        let store = ExpertStore::init(4, d, 12, 2);
        let topo = EpTopology::new(4, 4).unwrap();
        let rep = check_equivalence(&topo, &store, &disp, &x, &gates).unwrap();
        assert!(rep.ok());
    }

    #[test]
    fn training_is_bitwise_identical_across_sharding() {
        // 3 optimizer steps on the same workload: losses and final
        // parameters must match bit-for-bit between R=1 and R=4
        let batch = workload(64, 8, 2, 12, 0.8, 33);
        let l = batch.num_tokens();
        let d = 12;
        let store = ExpertStore::init(8, d, 16, 77);
        let mut rng = Rng::new(55);
        let target = rng.normal_vec(l * d, 1.0);

        let run = |engine: &mut dyn ExecutionEngine| -> Vec<f64> {
            let mut opt = Sgd;
            let mut losses = Vec::new();
            for _ in 0..3 {
                let handle = engine.forward(&batch).unwrap();
                let out = handle.output();
                let mut loss = 0.0f64;
                let mut d_out = vec![0.0f32; out.len()];
                let scale = 2.0 / out.len() as f32;
                for i in 0..out.len() {
                    let diff = out[i] - target[i];
                    loss += (diff as f64) * (diff as f64);
                    d_out[i] = scale * diff;
                }
                let n = out.len() as f64;
                let grads = handle.backward(engine, &d_out).unwrap();
                let delta = opt.step(&grads, 0.1).unwrap();
                engine.apply_update(&delta).unwrap();
                losses.push(loss / n);
            }
            losses
        };

        let mut single = SingleRankEngine::new(store.clone());
        let topo = EpTopology::new(4, 8).unwrap();
        let mut sharded = ShardedEngine::new(topo, &store, 4).unwrap();
        let la = run(&mut single);
        let lb = run(&mut sharded);
        assert_eq!(la, lb, "losses diverged");
        assert!(la[2] < la[0], "training did not reduce the loss: {la:?}");
        let pa = single.gather_params().unwrap();
        let pb = sharded.gather_params().unwrap();
        assert_eq!(pa, pb, "trained parameters diverged");
        assert_eq!(batch.copy_count(), 0, "engines deep-copied the batch");
    }

    #[test]
    fn checkpoint_policies_bit_identical_grads_decreasing_memory() {
        let batch = workload(72, 8, 2, 10, 0.9, 13);
        let store = ExpertStore::init(8, 10, 14, 3);
        let topo = EpTopology::new(4, 8).unwrap();
        let d_out: Vec<f32> = {
            let mut rng = Rng::new(2);
            rng.normal_vec(batch.num_tokens() * 10, 1.0)
        };
        let mut outs: Vec<Vec<f32>> = Vec::new();
        let mut all_grads: Vec<ExpertGrads> = Vec::new();
        let mut data_bytes: Vec<u64> = Vec::new();
        for policy in CheckpointPolicy::ALL {
            for sharded in [false, true] {
                let mut engine: Box<dyn ExecutionEngine> = if sharded {
                    Box::new(ShardedEngine::with_policy(topo.clone(), &store, 4, policy)
                        .unwrap())
                } else {
                    Box::new(SingleRankEngine::with_policy(store.clone(), policy))
                };
                let handle = engine.forward(&batch).unwrap();
                outs.push(handle.output().to_vec());
                if sharded {
                    data_bytes.push(
                        engine
                            .memory_per_rank()
                            .iter()
                            .map(|m| m.data_bytes)
                            .sum(),
                    );
                }
                let grads = handle.backward(engine.as_mut(), &d_out).unwrap();
                all_grads.push(grads);
            }
        }
        for o in &outs[1..] {
            assert_eq!(o, &outs[0], "outputs diverged across policies");
        }
        for g in &all_grads[1..] {
            assert_eq!(g, &all_grads[0], "grads diverged across policies");
        }
        // SaveAll > SaveInputs > RecomputeAll in data-class bytes
        assert!(data_bytes[0] > data_bytes[1], "{data_bytes:?}");
        assert!(data_bytes[1] > data_bytes[2], "{data_bytes:?}");
    }

    #[test]
    fn recompute_all_reruns_dispatch_exchange_in_backward() {
        let batch = workload(64, 8, 2, 8, 0.5, 4);
        let store = ExpertStore::init(8, 8, 12, 1);
        let topo = EpTopology::new(4, 8).unwrap();
        let mut eng = ShardedEngine::with_policy(
            topo, &store, 4, CheckpointPolicy::RecomputeAll).unwrap();
        let handle = eng.forward(&batch).unwrap();
        let fwd = eng.traffic();
        assert_eq!(fwd.recompute_bytes, 0);
        let d_out = vec![0.1f32; batch.num_tokens() * 8];
        handle.backward(&mut eng, &d_out).unwrap();
        let bwd = eng.traffic();
        // the re-gather moves exactly the rows the fwd dispatch moved
        assert_eq!(bwd.recompute_bytes, fwd.dispatch_bytes);
        assert_eq!(bwd.grad_bytes, fwd.dispatch_bytes);
    }

    #[test]
    fn traffic_resets_at_forward_and_accumulates_through_backward() {
        let batch = workload(48, 4, 2, 8, 0.3, 6);
        let store = ExpertStore::init(4, 8, 10, 9);
        let topo = EpTopology::new(2, 4).unwrap();
        let mut eng = ShardedEngine::new(topo, &store, 2).unwrap();
        let d_out = vec![0.5f32; batch.num_tokens() * 8];
        let handle = eng.forward(&batch).unwrap();
        assert_eq!(eng.traffic().grad_bytes, 0,
                   "grad_bytes must read 0 after forward");
        handle.backward(&mut eng, &d_out).unwrap();
        assert!(eng.traffic().grad_bytes > 0);
        // a fresh forward resets the whole session's counters
        let handle = eng.forward(&batch).unwrap();
        let t = eng.traffic();
        assert_eq!(t.grad_bytes, 0, "grad_bytes leaked across sessions");
        assert_eq!(t.recompute_bytes, 0);
        assert!(t.dispatch_bytes > 0);
        drop(handle);
    }

    #[test]
    fn traffic_accounting_is_conserved() {
        let batch = workload(128, 8, 2, 8, 0.5, 4);
        let store = ExpertStore::init(8, 8, 12, 1);
        let topo = EpTopology::new(2, 8).unwrap();
        let mut eng = ShardedEngine::new(topo, &store, 2).unwrap();
        let _ = eng.forward(&batch).unwrap();
        let t = eng.traffic();
        assert_eq!(t.cross_rows + t.local_rows, batch.disp().slots() as u64);
        assert_eq!(t.dispatch_bytes, t.cross_rows * 8 * 4);
        // combine returns exactly the rows that were dispatched
        assert_eq!(t.combine_bytes, t.dispatch_bytes);
        // memory accounting covers every rank and the routed rows
        let mem = eng.memory_per_rank();
        assert_eq!(mem.len(), 2);
        let data: u64 = mem.iter().map(|m| m.data_bytes).sum();
        assert!(data >= batch.disp().slots() as u64 * 8 * 4);
    }

    #[test]
    fn stale_and_foreign_handles_are_rejected() {
        let batch = workload(16, 4, 2, 4, 0.0, 8);
        let store = ExpertStore::init(4, 4, 8, 3);
        let mut eng = SingleRankEngine::new(store.clone());
        let d_out = vec![0.0f32; batch.num_tokens() * 4];
        let mut grads = eng.zero_grads();

        // a newer forward invalidates the older handle
        let old = eng.forward(&batch).unwrap();
        let new = eng.forward(&batch).unwrap();
        assert!(eng.backward_into(old, &d_out, &mut grads).is_err());
        eng.backward_into(new, &d_out, &mut grads).unwrap();

        // the session ended: even a replayed id cannot re-enter
        let replay = StepHandle { engine_tag: 0, session: 0, out: Vec::new() };
        assert!(eng.backward_into(replay, &d_out, &mut grads).is_err());

        // handles are engine-bound
        let mut other = SingleRankEngine::new(store);
        let foreign = other.forward(&batch).unwrap();
        assert!(eng.backward_into(foreign, &d_out, &mut grads).is_err());
    }

    #[test]
    fn shape_validation() {
        let batch = workload(16, 4, 2, 4, 0.0, 8);
        let store = ExpertStore::init(4, 4, 8, 3);
        let mut eng = SingleRankEngine::new(store.clone());
        // engine/batch shape mismatches
        let bad_store = ExpertStore::init(8, 4, 8, 3);
        let mut bad = SingleRankEngine::new(bad_store);
        assert!(bad.forward(&batch).is_err());
        let wrong_d = ExpertStore::init(4, 6, 8, 3);
        let mut bad_d = SingleRankEngine::new(wrong_d);
        assert!(bad_d.forward(&batch).is_err());
        // d_out and grads shape mismatches
        let handle = eng.forward(&batch).unwrap();
        let mut wrong_grads = ExpertGrads::zeros(4, 4, 9);
        assert!(eng
            .backward_into(handle, &vec![0.0; 16 * 4], &mut wrong_grads)
            .is_err());
        let handle = eng.forward(&batch).unwrap();
        let mut grads = eng.zero_grads();
        assert!(eng.backward_into(handle, &[0.0; 7], &mut grads).is_err());
        // batch constructor validation
        assert!(StepBatch::new(batch.disp().clone(), vec![0.0; 3], batch.gates().to_vec())
            .is_err());
        assert!(StepBatch::new(batch.disp().clone(), batch.x().to_vec(), vec![0.0; 5])
            .is_err());
    }

    #[test]
    fn routing_plan_cache_is_lru_bounded_with_readmission() {
        let store = ExpertStore::init(4, 6, 8, 21);
        let topo = EpTopology::new(2, 4).unwrap();
        let mut eng = ShardedEngine::new(topo, &store, 2).unwrap();
        let mut single = SingleRankEngine::new(store.clone());
        let batches: Vec<StepBatch> = (0..PLAN_CACHE_CAP + 4)
            .map(|i| workload(20, 4, 2, 6, 0.5, 100 + i as u64))
            .collect();
        let mut outs = Vec::new();
        for b in &batches {
            outs.push(eng.forward(b).unwrap().into_output());
            assert!(eng.cached_plans() <= PLAN_CACHE_CAP,
                    "cache grew past the cap: {}", eng.cached_plans());
        }
        assert_eq!(eng.cached_plans(), PLAN_CACHE_CAP);
        assert!(!eng.has_cached_plan(&batches[0]), "oldest plan not evicted");
        assert!(eng.has_cached_plan(batches.last().unwrap()));

        // re-admission: the evicted batch re-plans bit-identically, fwd + bwd
        let again = eng.forward(&batches[0]).unwrap().into_output();
        assert_eq!(again, outs[0], "re-admitted batch diverged from itself");
        let reference = single.forward(&batches[0]).unwrap().into_output();
        assert_eq!(again, reference, "re-admitted batch diverged from R=1");
        let d_out = vec![0.1f32; batches[0].num_tokens() * 6];
        let g_sharded = eng
            .forward(&batches[0])
            .unwrap()
            .backward(&mut eng, &d_out)
            .unwrap();
        let g_single = single
            .forward(&batches[0])
            .unwrap()
            .backward(&mut single, &d_out)
            .unwrap();
        assert_eq!(g_sharded, g_single, "grads diverged after cache churn");
    }

    #[test]
    fn plan_cache_cap_is_adjustable_and_config_covers_grad_accum() {
        let store = ExpertStore::init(4, 6, 8, 23);
        let topo = EpTopology::new(2, 4).unwrap();
        let mut eng = ShardedEngine::new(topo, &store, 2).unwrap();
        eng.set_plan_cache_cap(2);
        for i in 0..4u64 {
            let b = workload(12, 4, 2, 6, 0.3, 800 + i);
            let _ = eng.forward(&b).unwrap();
            assert!(eng.cached_plans() <= 2);
        }
        // engine_from_config must size the cache to the microbatch
        // working set so cyclic grad-accum access never thrashes:
        // routing stays derivable (routing_from_config == the workload's)
        let cfg = EpConfig {
            grad_accum: PLAN_CACHE_CAP + 4,
            tokens: 64,
            num_experts: 4,
            ranks: 2,
            top_k: 2,
            d_model: 8,
            d_hidden: 8,
            ..EpConfig::default()
        };
        let (disp, _, _, _) = workload_from_config(&cfg);
        assert_eq!(routing_from_config(&cfg), disp,
                   "routing prefix drifted from the full workload");
        engine_from_config(&cfg).unwrap();
    }

    #[test]
    fn routing_plan_cache_refreshes_recency_on_hit() {
        let store = ExpertStore::init(4, 6, 8, 22);
        let topo = EpTopology::new(2, 4).unwrap();
        let mut eng = ShardedEngine::new(topo, &store, 2).unwrap();
        let hot = workload(20, 4, 2, 6, 0.5, 500);
        let _ = eng.forward(&hot).unwrap();
        // fill the cache so `hot` is the LRU candidate, then touch it
        for i in 0..PLAN_CACHE_CAP - 1 {
            let b = workload(20, 4, 2, 6, 0.5, 600 + i as u64);
            let _ = eng.forward(&b).unwrap();
        }
        let _ = eng.forward(&hot).unwrap();
        // one more distinct batch evicts the now-oldest cold plan, not `hot`
        let b = workload(20, 4, 2, 6, 0.5, 700);
        let _ = eng.forward(&b).unwrap();
        assert!(eng.has_cached_plan(&hot),
                "recently-touched plan was evicted");
    }

    #[test]
    fn step_batch_share_is_zero_copy_and_split_covers_tokens() {
        let batch = workload(30, 4, 2, 6, 0.4, 12);
        let s = batch.share();
        assert_eq!(s.id(), batch.id());
        assert_eq!(batch.copy_count(), 0);
        let dc = batch.deep_copy().unwrap();
        assert_ne!(dc.id(), batch.id());
        assert_eq!(batch.copy_count(), 1);

        for parts in [1, 2, 3, 4] {
            let micros = batch.split(parts).unwrap();
            assert_eq!(micros.len(), parts);
            let mut covered = 0;
            for (off, mb) in &micros {
                assert_eq!(*off, covered);
                covered += mb.num_tokens();
                mb.disp().validate().unwrap();
                assert_eq!(mb.d_model(), batch.d_model());
                // microbatch payload slices match the parent ranges
                let d = batch.d_model();
                assert_eq!(mb.x(), &batch.x()[*off * d..(*off + mb.num_tokens()) * d]);
            }
            assert_eq!(covered, batch.num_tokens());
        }
        assert!(batch.split(0).is_err());
        assert!(batch.split(31).is_err());
        // split stamps the offset the stack needs for routing slices,
        // and a deep copy keeps it (fresh id, same span)
        for (off, mb) in batch.split(3).unwrap() {
            assert_eq!(mb.token_offset(), off);
            let copy = mb.deep_copy().unwrap();
            assert_eq!(copy.token_offset(), off, "deep copy dropped the offset");
            assert_ne!(copy.id(), mb.id());
            // re-splitting chains offsets to stay root-absolute
            for (off2, gc) in mb.split(2).unwrap() {
                assert_eq!(gc.token_offset(), off + off2,
                           "grandchild offset not absolute");
            }
        }
        assert_eq!(batch.token_offset(), 0);
    }

    #[test]
    fn weighted_split_bounds_balance_heavy_prefixes() {
        // first half of the tokens carries 9x the weight: a 2-way cut
        // must land well before the midpoint
        let mut w = vec![9u64; 8];
        w.extend(vec![1u64; 8]);
        let bounds = split_bounds_weighted(&w, 2).unwrap();
        assert_eq!(bounds.len(), 3);
        assert_eq!((bounds[0], bounds[2]), (0, 16));
        assert!(bounds[1] < 8, "heavy prefix not balanced: {bounds:?}");
        // every chunk keeps at least one token even under degenerate
        // weights concentrated on one token
        let mut spike = vec![0u64; 10];
        spike[0] = 100;
        let b = split_bounds_weighted(&spike, 4).unwrap();
        assert_eq!(b.len(), 5);
        assert!(b.windows(2).all(|w| w[0] < w[1]), "{b:?}");
        // all-zero weights degrade to the even token split
        assert_eq!(split_bounds_weighted(&[0; 8], 4).unwrap(), vec![0, 2, 4, 6, 8]);
        assert!(split_bounds_weighted(&[1; 4], 5).is_err());
        assert!(split_bounds_weighted(&[1; 4], 0).is_err());
    }

    #[test]
    fn split_routing_at_validates_and_covers() {
        let batch = workload(20, 4, 2, 6, 0.5, 14);
        let parts = batch.split_routing_at(&[0, 3, 11, 20]).unwrap();
        assert_eq!(parts.len(), 3);
        let mut covered = 0;
        for (off, disp) in &parts {
            assert_eq!(*off, covered);
            covered += disp.num_tokens;
            disp.validate().unwrap();
        }
        assert_eq!(covered, 20);
        assert!(batch.split_routing_at(&[0, 20]).is_ok());
        assert!(batch.split_routing_at(&[0, 5, 5, 20]).is_err());
        assert!(batch.split_routing_at(&[1, 20]).is_err());
        assert!(batch.split_routing_at(&[0, 19]).is_err());
    }

    #[test]
    fn layer_routing_bind_shares_id_and_layer_tags_plan_keys() {
        let batch = workload(16, 4, 2, 6, 0.4, 15);
        let other = workload(16, 4, 2, 6, 0.9, 16);
        let routing = LayerRouting::new(
            1, other.disp().clone(), other.gates().to_vec()).unwrap();
        assert_eq!(routing.num_tokens(), 16);
        let bound = routing.bind(&batch, vec![0.5f32; 16 * 6]).unwrap();
        assert_eq!(bound.id(), batch.id(), "bound batch must reuse the id");
        assert_eq!(bound.layer(), 1);
        assert_ne!(bound.plan_key(), batch.plan_key(),
                   "same id, different layer must be distinct plan keys");
        assert_eq!(bound.disp(), other.disp());
        assert_eq!(batch.copy_count(), 0, "bind must not deep-copy");
        // validation
        assert!(LayerRouting::new(0, other.disp().clone(),
                                  other.gates().to_vec()).is_err());
        assert!(LayerRouting::new(1, other.disp().clone(), vec![0.0; 3]).is_err());
        assert!(routing.bind(&batch, vec![0.0; 7]).is_err());
        let short = workload(8, 4, 2, 6, 0.4, 17);
        assert!(routing.bind(&short, vec![0.0; 8 * 6]).is_err());
    }

    #[test]
    fn plan_cache_keys_by_batch_and_layer() {
        // one batch id, L derived routings: the engine must hold L
        // distinct plans and keep answering each layer correctly
        let store = ExpertStore::init(4, 6, 8, 31);
        let topo = EpTopology::new(2, 4).unwrap();
        let mut eng = ShardedEngine::new(topo, &store, 2).unwrap();
        let batch = workload(20, 4, 2, 6, 0.5, 900);
        let layers: Vec<StepBatch> = (1..4u32)
            .map(|l| {
                let alt = workload(20, 4, 2, 6, 0.5, 900 + l as u64);
                let routing = LayerRouting::new(
                    l, alt.disp().clone(), alt.gates().to_vec()).unwrap();
                routing.bind(&batch, batch.x().to_vec()).unwrap()
            })
            .collect();
        let mut single = SingleRankEngine::new(store.clone());
        let _ = eng.forward(&batch).unwrap();
        for lb in &layers {
            let out = eng.forward(lb).unwrap().into_output();
            let reference = single.forward(lb).unwrap().into_output();
            assert_eq!(out, reference, "layer batch diverged from R=1");
        }
        assert_eq!(eng.cached_plans(), 4,
                   "one id + 3 layers must occupy 4 cache slots");
        assert!(eng.has_cached_plan(&batch));
        for lb in &layers {
            assert!(eng.has_cached_plan(lb));
        }
        // eviction still works over the (id, layer) working set
        eng.set_plan_cache_cap(2);
        assert_eq!(eng.cached_plans(), 2);
        assert!(!eng.has_cached_plan(&batch), "LRU entry should evict first");
        let again = eng.forward(&batch).unwrap().into_output();
        let reference = single.forward(&batch).unwrap().into_output();
        assert_eq!(again, reference, "re-admitted layer-0 plan diverged");
    }

    #[test]
    fn backward_dx_matches_across_engines_and_leaves_grads_bit_identical() {
        let batch = workload(48, 8, 2, 10, 0.8, 41);
        let store = ExpertStore::init(8, 10, 14, 6);
        let d_out: Vec<f32> = {
            let mut rng = Rng::new(8);
            rng.normal_vec(48 * 10, 1.0)
        };
        let mut reference_dx: Option<Vec<f32>> = None;
        let mut reference_grads: Option<ExpertGrads> = None;
        for policy in CheckpointPolicy::ALL {
            for ranks in [1usize, 2, 4] {
                let topo = EpTopology::new(ranks, 8).unwrap();
                let mut eng: Box<dyn ExecutionEngine> = if ranks == 1 {
                    Box::new(SingleRankEngine::with_policy(store.clone(), policy))
                } else {
                    Box::new(
                        ShardedEngine::with_policy(topo, &store, ranks, policy)
                            .unwrap(),
                    )
                };
                // grads without dx…
                let h = eng.forward(&batch).unwrap();
                let mut plain = eng.zero_grads();
                eng.backward_into(h, &d_out, &mut plain).unwrap();
                // …must equal grads with dx, bit for bit
                let h = eng.forward(&batch).unwrap();
                let mut with_dx = eng.zero_grads();
                let mut dx = vec![0.0f32; 48 * 10];
                eng.backward_into_dx(h, &d_out, &mut with_dx, &mut dx).unwrap();
                assert_eq!(plain, with_dx,
                           "R={ranks} {policy}: dx request changed grads");
                assert!(dx.iter().any(|&v| v != 0.0), "dx all zero");
                match (&reference_dx, &reference_grads) {
                    (Some(rdx), Some(rg)) => {
                        assert_eq!(&dx, rdx, "R={ranks} {policy}: dx diverged");
                        assert_eq!(&with_dx, rg,
                                   "R={ranks} {policy}: grads diverged");
                    }
                    _ => {
                        reference_dx = Some(dx);
                        reference_grads = Some(with_dx);
                    }
                }
            }
        }
        // shape validation
        let mut eng = SingleRankEngine::new(store);
        let h = eng.forward(&batch).unwrap();
        let mut g = eng.zero_grads();
        let mut short = vec![0.0f32; 5];
        assert!(eng.backward_into_dx(h, &d_out, &mut g, &mut short).is_err());
    }
}

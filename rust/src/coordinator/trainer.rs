//! The training step loop: drives the AOT `lm_train_step` executable with
//! data from the batcher under the LR schedule, with metrics, eval, and
//! checkpointing.

use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::train::TrainConfig;
use crate::data::batcher::Batcher;
use crate::metrics::{Ema, MetricsSink};
use crate::runtime::client::{Executable, Runtime};
use crate::runtime::host::HostTensor;

use super::params::ParamStore;

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub first_loss: f64,
    pub final_loss_ema: f64,
    pub losses: Vec<(usize, f64)>,
    pub eval_losses: Vec<(usize, f64)>,
    pub tokens_per_sec: f64,
    pub step_ms_mean: f64,
}

pub struct Trainer {
    train_exe: Rc<Executable>,
    eval_exe: Option<Rc<Executable>>,
    pub store: ParamStore,
    pub cfg: TrainConfig,
    sink: MetricsSink,
    batch_tokens: usize,
}

impl Trainer {
    pub fn new(runtime: &Runtime, store: ParamStore, cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        let lm = runtime
            .manifest
            .lm
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("manifest has no `lm` section"))?;
        store.check_against(lm).map_err(|e| anyhow::anyhow!("{e}"))?;
        let train_exe = runtime.load("lm_train_step")?;
        let eval_exe = if cfg.eval_every > 0 {
            Some(runtime.load("lm_eval_step")?)
        } else {
            None
        };
        let batch_tokens = lm.batch * lm.seq_len();
        let sink = MetricsSink::new(Some(cfg.metrics_path.as_str()))
            .map_err(anyhow::Error::msg)?;
        Ok(Trainer { train_exe, eval_exe, store, cfg, sink, batch_tokens })
    }

    /// One optimizer step on `batch`; returns the loss.
    pub fn step(&mut self, tokens: HostTensor, targets: HostTensor) -> Result<f64> {
        let p = self.store.params.len();
        let step_no = self.store.step as usize;
        let lr = self.cfg.lr_at(step_no) as f32;

        let mut args: Vec<HostTensor> = Vec::with_capacity(3 * p + 4);
        args.extend(self.store.params.iter().cloned());
        args.extend(self.store.m.iter().cloned());
        args.extend(self.store.v.iter().cloned());
        args.push(HostTensor::F32 { shape: vec![], data: vec![(step_no + 1) as f32] });
        args.push(HostTensor::F32 { shape: vec![], data: vec![lr] });
        args.push(tokens);
        args.push(targets);

        let mut out = self.train_exe.run(&args)?;
        if out.len() != 3 * p + 1 {
            bail!("train step returned {} outputs, expected {}", out.len(), 3 * p + 1);
        }
        let loss = match out.pop().unwrap() {
            HostTensor::F32 { data, .. } => data[0] as f64,
            _ => bail!("loss is not f32"),
        };
        if !loss.is_finite() {
            bail!("non-finite loss at step {step_no}: {loss}");
        }
        let v_new: Vec<HostTensor> = out.split_off(2 * p);
        let m_new: Vec<HostTensor> = out.split_off(p);
        self.store.params = out;
        self.store.m = m_new;
        self.store.v = v_new;
        self.store.step += 1;
        Ok(loss)
    }

    pub fn eval(&self, tokens: HostTensor, targets: HostTensor) -> Result<f64> {
        let exe = self
            .eval_exe
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("eval disabled (eval_every = 0)"))?;
        let mut args: Vec<HostTensor> = self.store.params.to_vec();
        args.push(tokens);
        args.push(targets);
        let out = exe.run(&args)?;
        match &out[0] {
            HostTensor::F32 { data, .. } => Ok(data[0] as f64),
            _ => bail!("eval loss is not f32"),
        }
    }

    /// Full training run.
    pub fn run(&mut self, train: &mut Batcher, eval: &mut Batcher) -> Result<TrainReport> {
        let steps = self.cfg.steps;
        let mut ema = Ema::new(0.05);
        let mut losses = Vec::new();
        let mut eval_losses = Vec::new();
        let mut first_loss = None;
        let mut step_times = Vec::with_capacity(steps);
        let run_start = Instant::now();

        for s in 0..steps {
            let b = train.next_batch();
            let shape = vec![b.batch, b.seq_len];
            let t0 = Instant::now();
            let loss = self.step(
                HostTensor::I32 { shape: shape.clone(), data: b.tokens },
                HostTensor::I32 { shape, data: b.targets },
            )?;
            step_times.push(t0.elapsed().as_secs_f64() * 1e3);
            let sm = ema.update(loss);
            first_loss.get_or_insert(loss);
            losses.push((s, loss));

            if self.cfg.log_every > 0 && (s % self.cfg.log_every == 0 || s + 1 == steps) {
                let lr = self.cfg.lr_at(s);
                self.sink.emit("train", &[
                    ("step", s as f64),
                    ("loss", loss),
                    ("loss_ema", sm),
                    ("lr", lr),
                    ("step_ms", *step_times.last().unwrap()),
                ]);
                println!("{}", self.sink.console(s, &[("loss", loss), ("ema", sm), ("lr", lr)]));
            }
            if self.cfg.eval_every > 0 && s > 0 && s % self.cfg.eval_every == 0 {
                let b = eval.next_batch();
                let shape = vec![b.batch, b.seq_len];
                let el = self.eval(
                    HostTensor::I32 { shape: shape.clone(), data: b.tokens },
                    HostTensor::I32 { shape, data: b.targets },
                )?;
                eval_losses.push((s, el));
                self.sink.emit("eval", &[("step", s as f64), ("loss", el)]);
                println!("{}", self.sink.console(s, &[("eval_loss", el)]));
            }
            if self.cfg.checkpoint_every > 0 && (s + 1) % self.cfg.checkpoint_every == 0 {
                let path = PathBuf::from(&self.cfg.checkpoint_dir)
                    .join(format!("step{:06}.ckpt", s + 1));
                self.store.save(&path)?;
                self.sink.emit("checkpoint", &[("step", s as f64)]);
            }
        }

        let total = run_start.elapsed().as_secs_f64();
        let report = TrainReport {
            steps,
            first_loss: first_loss.unwrap_or(f64::NAN),
            final_loss_ema: ema.get().unwrap_or(f64::NAN),
            losses,
            eval_losses,
            tokens_per_sec: (steps * self.batch_tokens) as f64 / total,
            step_ms_mean: step_times.iter().sum::<f64>() / step_times.len().max(1) as f64,
        };
        self.sink.emit("done", &[
            ("steps", steps as f64),
            ("tokens_per_sec", report.tokens_per_sec),
            ("final_loss_ema", report.final_loss_ema),
        ]);
        Ok(report)
    }
}

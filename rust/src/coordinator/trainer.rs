//! Training loops.
//!
//! [`Trainer`] drives the AOT `lm_train_step` executable with data from
//! the batcher under the LR schedule, with metrics, eval, and
//! checkpointing. [`EpTrainer`] drives an [`ExecutionEngine`] — the
//! expert-parallel host engine — through the step-session API: the
//! workload is built once as zero-copy [`StepBatch`] microbatches, each
//! optimizer step accumulates gradients across them with
//! `StepHandle::backward_into`, and the update comes from a pluggable
//! [`Optimizer`] over the accumulated [`ExpertGrads`]. Loss curves are
//! bit-invariant to rank count, placement, checkpoint policy, and the
//! grad-accum split (pinned by the engine tests).

use std::path::PathBuf;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::config::ep::EpConfig;
use crate::config::fault::FaultConfig;
use crate::config::train::TrainConfig;
use crate::resilience::{config_fingerprint, FaultInjector, FaultPlan,
                        SnapshotStore, TrainState};
use crate::data::batcher::Batcher;
use crate::memory::planner::CheckpointPlan;
use crate::metrics::registry::Registry;
use crate::metrics::{Ema, MetricsSink, Peak, Throughput};
use crate::runtime::client::{Executable, Runtime};
use crate::runtime::host::HostTensor;

use super::calibrate::Calibration;
use super::engine::{step_batch_from_config, tile_bucket, BuildInfo,
                    ExecutionEngine, StepBatch, Traffic};
use super::optim::{clip_global_norm, optimizer_from_name, LrSchedule, Optimizer};
use super::params::{ExpertGrads, ParamStore};
use super::pipeline::timeline::{CostModel, OverlapReport};
use super::stack::plan_from_config;
use crate::trace::drift::DriftDetector;
use crate::trace::load::ExpertLoadTracker;
use crate::trace::{StepSummary, TracePhase, Tracer};

/// EWMA weight of one step's measured-vs-simulated ratio when `[ep]
/// calibrate = true` folds it into the effective cost-model rates: heavy
/// enough to converge within a few steps, light enough that one noisy
/// step cannot swing the model.
const CALIBRATE_ALPHA: f64 = 0.2;

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub first_loss: f64,
    pub final_loss_ema: f64,
    pub losses: Vec<(usize, f64)>,
    pub eval_losses: Vec<(usize, f64)>,
    pub tokens_per_sec: f64,
    pub step_ms_mean: f64,
}

pub struct Trainer {
    train_exe: Rc<Executable>,
    eval_exe: Option<Rc<Executable>>,
    pub store: ParamStore,
    pub cfg: TrainConfig,
    sink: MetricsSink,
    batch_tokens: usize,
}

impl Trainer {
    pub fn new(runtime: &Runtime, store: ParamStore, cfg: TrainConfig) -> Result<Trainer> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        let lm = runtime
            .manifest
            .lm
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("manifest has no `lm` section"))?;
        store.check_against(lm).map_err(|e| anyhow::anyhow!("{e}"))?;
        let train_exe = runtime.load("lm_train_step")?;
        let eval_exe = if cfg.eval_every > 0 {
            Some(runtime.load("lm_eval_step")?)
        } else {
            None
        };
        let batch_tokens = lm.batch * lm.seq_len();
        let sink = MetricsSink::new(Some(cfg.metrics_path.as_str()))
            .map_err(anyhow::Error::msg)?;
        Ok(Trainer { train_exe, eval_exe, store, cfg, sink, batch_tokens })
    }

    /// One optimizer step on `batch`; returns the loss.
    pub fn step(&mut self, tokens: HostTensor, targets: HostTensor) -> Result<f64> {
        let p = self.store.params.len();
        let step_no = self.store.step as usize;
        let lr = self.cfg.lr_at(step_no) as f32;

        let mut args: Vec<HostTensor> = Vec::with_capacity(3 * p + 4);
        args.extend(self.store.params.iter().cloned());
        args.extend(self.store.m.iter().cloned());
        args.extend(self.store.v.iter().cloned());
        args.push(HostTensor::F32 { shape: vec![], data: vec![(step_no + 1) as f32] });
        args.push(HostTensor::F32 { shape: vec![], data: vec![lr] });
        args.push(tokens);
        args.push(targets);

        let mut out = self.train_exe.run(&args)?;
        if out.len() != 3 * p + 1 {
            bail!("train step returned {} outputs, expected {}", out.len(), 3 * p + 1);
        }
        let loss = match out.pop().unwrap() {
            HostTensor::F32 { data, .. } => data[0] as f64,
            _ => bail!("loss is not f32"),
        };
        if !loss.is_finite() {
            bail!("non-finite loss at step {step_no}: {loss}");
        }
        let v_new: Vec<HostTensor> = out.split_off(2 * p);
        let m_new: Vec<HostTensor> = out.split_off(p);
        self.store.params = out;
        self.store.m = m_new;
        self.store.v = v_new;
        self.store.step += 1;
        Ok(loss)
    }

    pub fn eval(&self, tokens: HostTensor, targets: HostTensor) -> Result<f64> {
        let exe = self
            .eval_exe
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("eval disabled (eval_every = 0)"))?;
        let mut args: Vec<HostTensor> = self.store.params.to_vec();
        args.push(tokens);
        args.push(targets);
        let out = exe.run(&args)?;
        if out.len() != 1 {
            bail!("eval step returned {} outputs, expected 1 (loss)", out.len());
        }
        match &out[0] {
            HostTensor::F32 { data, .. } => Ok(data[0] as f64),
            _ => bail!("eval loss is not f32"),
        }
    }

    /// Full training run.
    pub fn run(&mut self, train: &mut Batcher, eval: &mut Batcher) -> Result<TrainReport> {
        let steps = self.cfg.steps;
        let mut ema = Ema::new(0.05);
        let mut losses = Vec::new();
        let mut eval_losses = Vec::new();
        let mut first_loss = None;
        let mut step_times = Vec::with_capacity(steps);
        let run_start = Instant::now();

        for s in 0..steps {
            let b = train.next_batch();
            let shape = vec![b.batch, b.seq_len];
            let t0 = Instant::now();
            let loss = self.step(
                HostTensor::I32 { shape: shape.clone(), data: b.tokens },
                HostTensor::I32 { shape, data: b.targets },
            )?;
            step_times.push(t0.elapsed().as_secs_f64() * 1e3);
            let sm = ema.update(loss);
            first_loss.get_or_insert(loss);
            losses.push((s, loss));

            if self.cfg.log_every > 0 && (s % self.cfg.log_every == 0 || s + 1 == steps) {
                let lr = self.cfg.lr_at(s);
                self.sink.emit("train", &[
                    ("step", s as f64),
                    ("loss", loss),
                    ("loss_ema", sm),
                    ("lr", lr),
                    ("step_ms", *step_times.last().unwrap()),
                ]);
                println!("{}", self.sink.console(s, &[("loss", loss), ("ema", sm), ("lr", lr)]));
            }
            if self.cfg.eval_every > 0 && s > 0 && s % self.cfg.eval_every == 0 {
                let b = eval.next_batch();
                let shape = vec![b.batch, b.seq_len];
                let el = self.eval(
                    HostTensor::I32 { shape: shape.clone(), data: b.tokens },
                    HostTensor::I32 { shape, data: b.targets },
                )?;
                eval_losses.push((s, el));
                self.sink.emit("eval", &[("step", s as f64), ("loss", el)]);
                println!("{}", self.sink.console(s, &[("eval_loss", el)]));
            }
            if self.cfg.checkpoint_every > 0 && (s + 1) % self.cfg.checkpoint_every == 0 {
                let path = PathBuf::from(&self.cfg.checkpoint_dir)
                    .join(format!("step{:06}.ckpt", s + 1));
                self.store.save(&path)?;
                self.sink.emit("checkpoint", &[("step", s as f64)]);
            }
        }

        let total = run_start.elapsed().as_secs_f64();
        let report = TrainReport {
            steps,
            first_loss: first_loss.unwrap_or(f64::NAN),
            final_loss_ema: ema.get().unwrap_or(f64::NAN),
            losses,
            eval_losses,
            tokens_per_sec: (steps * self.batch_tokens) as f64 / total,
            step_ms_mean: step_times.iter().sum::<f64>() / step_times.len().max(1) as f64,
        };
        self.sink.emit("done", &[
            ("steps", steps as f64),
            ("tokens_per_sec", report.tokens_per_sec),
            ("final_loss_ema", report.final_loss_ema),
        ]);
        Ok(report)
    }
}

// -- expert-parallel trainer ------------------------------------------------

/// Outcome of an expert-parallel engine training run.
#[derive(Debug, Clone)]
pub struct EpTrainReport {
    pub steps: usize,
    pub first_loss: f64,
    pub final_loss: f64,
    pub losses: Vec<f64>,
    /// measured comm of the final microbatch session
    pub traffic: Traffic,
    pub step_ms_mean: f64,
    /// peak summed `data`-class bytes across any forward (policy-dependent)
    pub peak_data_bytes: u64,
    /// peak single-rank `data`-class bytes across any forward — the
    /// number `[ep] mem_budget_bytes` budgets (per-rank device memory)
    pub peak_rank_data_bytes: u64,
    /// the smart-checkpoint plan the config resolved to (multi-layer
    /// stacks and `checkpoint = auto` runs; `None` for plain engines)
    pub plan: Option<CheckpointPlan>,
    /// final-step global gradient L2 norm (pre-clip, pre-update)
    pub grad_norm: f64,
    /// learning rate the schedule produced for the final step
    pub final_lr: f64,
    /// optimizer steps whose gradients hit the `clip_norm` ceiling
    pub clipped_steps: usize,
    /// last step's phase timeline (chunk-pipelined engines only)
    pub overlap: Option<OverlapReport>,
    /// tokens/s over the run, from **measured** wall-clock: the engine's
    /// per-phase calibration samples when its timeline carries them,
    /// else the step timer — never the simulated schedule
    pub tokens_per_sec: f64,
    /// final effective cost-model rates after `[ep] calibrate = true`
    /// folded measured/simulated ratios across steps (`None` when
    /// calibration was off or no engine carries a timeline)
    pub calibrated: Option<CostModel>,
    /// steps×phases whose measured/predicted ratio left the EWMA drift
    /// band (timeline engines only; always 0 without an overlap report)
    pub drift_flags: usize,
    /// skew-alarm raising edges across all layers (`[ep] skew_alarm`
    /// runs only; always 0 when load telemetry is off)
    pub skew_alarms: usize,
    /// worst per-layer rank-load imbalance (max/mean) any folded step
    /// reached (0 when load telemetry is off)
    pub max_imbalance: f64,
    /// crash-consistent snapshot generations this run wrote
    /// (`[ep] snapshot_interval` runs only)
    pub snapshots_written: usize,
    /// optimizer step the run resumed from (`[ep] resume` runs only;
    /// `None` for fresh runs)
    pub resumed_from_step: Option<usize>,
    /// injected fault events this run raised (`[fault]` runs only)
    pub fault_events: usize,
    /// injected faults that could NOT be recovered — surfaced, never
    /// silent; any nonzero count here failed loudly during the run or
    /// names a snapshot set with no loadable generation left
    pub fault_unrecovered: usize,
}

/// Step-session training loop over an [`ExecutionEngine`] on a synthetic
/// regression task: a fixed random target Y* per token, MSE loss,
/// routing drawn once from the config's seed. The global batch is built
/// once and split into `cfg.grad_accum` contiguous microbatches
/// *before* the loop; every step then runs forward/backward per
/// microbatch with zero workload copies (asserted via the [`StepBatch`]
/// copy counter), accumulates gradients into one [`ExpertGrads`], and
/// applies the configured optimizer once. For a fixed global batch the
/// loss curve is bit-identical across `grad_accum` splits, rank counts,
/// and checkpoint policies.
pub struct EpTrainer {
    pub engine: Box<dyn ExecutionEngine>,
    pub cfg: EpConfig,
    optimizer: Box<dyn Optimizer>,
    schedule: LrSchedule,
    sink: MetricsSink,
    /// how the engine was built (`engine_from_config_with_info`):
    /// resolved tile, whether the autotune probe ran or the calibration
    /// artifact answered it — surfaced through `MetricsSink` and folded
    /// into the artifact this run saves back
    build_info: Option<BuildInfo>,
    /// deterministic fault injection (`[fault]` config); disabled by
    /// default, so a bare run consults nothing
    fault: FaultInjector,
    /// emulated kill switch: stop the loop after this many optimizer
    /// steps, as an interrupted run would. Deliberately NOT a config
    /// key — a kill is not part of the run's numeric identity, so the
    /// halted run's snapshots resume under the unhalted config's
    /// fingerprint (`--halt-after` on `ep-train`, and the resume
    /// bit-identity tests)
    pub halt_after_steps: Option<usize>,
}

impl EpTrainer {
    pub fn new(engine: Box<dyn ExecutionEngine>, cfg: EpConfig) -> Result<EpTrainer> {
        cfg.validate().map_err(anyhow::Error::msg)?;
        let optimizer = optimizer_from_name(&cfg.optimizer)
            .map_err(anyhow::Error::msg)?;
        let schedule = LrSchedule::parse(&cfg.lr_schedule)
            .map_err(anyhow::Error::msg)?;
        let sink = MetricsSink::new(Some(cfg.metrics_path.as_str()))
            .map_err(anyhow::Error::msg)?;
        Ok(EpTrainer { engine, cfg, optimizer, schedule, sink,
                       build_info: None,
                       fault: FaultInjector::new(FaultPlan::disabled()),
                       halt_after_steps: None })
    }

    /// Arm deterministic fault injection (`[fault]` config). The plan
    /// is seeded: two runs with the same config raise the identical
    /// fault sequence.
    pub fn set_fault_plan(&mut self, cfg: FaultConfig) {
        self.fault = FaultInjector::new(FaultPlan::new(cfg));
    }

    /// Attach the [`BuildInfo`] the engine build produced
    /// (`engine_from_config_with_info`), so `run` can log whether the
    /// tile probe ran or the calibration artifact was reused, and save
    /// the resolved tile back into the artifact.
    pub fn set_build_info(&mut self, info: BuildInfo) {
        self.build_info = Some(info);
    }

    /// Run `cfg.steps` optimizer steps; prints a progress line roughly
    /// every tenth step.
    pub fn run(&mut self) -> Result<EpTrainReport> {
        // workload is a pure function of the config (any engine — and
        // ep-bench — sees the same routing, inputs, and targets); built
        // once, shared zero-copy for the whole run
        let (batch, target) =
            step_batch_from_config(&self.cfg).map_err(anyhow::Error::msg)?;
        let micros: Vec<(usize, StepBatch)> = if self.cfg.grad_accum == 1 {
            vec![(0, batch.share())]
        } else {
            batch.split(self.cfg.grad_accum).map_err(anyhow::Error::msg)?
        };
        let d = batch.d_model();
        let global_elems = batch.num_tokens() * d;
        let scale = 2.0 / global_elems as f32;

        // the smart-checkpoint story of this run, emitted up front so
        // the JSONL stream explains the per-layer policies before the
        // first step lands (one solve per run — the engine the caller
        // built resolved its own copy at construction, but the trainer
        // only sees `dyn ExecutionEngine` and the report owns the plan)
        let plan = plan_from_config(&self.cfg).map_err(anyhow::Error::msg)?;
        if let Some(p) = &plan {
            self.sink.emit_tagged("checkpoint_plan", &[("strategy", p.strategy)], &[
                ("layers", p.choices.len() as f64),
                ("budget_bytes", p.budget_bytes as f64),
                ("projected_peak_bytes", p.projected_peak_bytes as f64),
                ("save_all_peak_bytes", p.save_all_peak_bytes as f64),
                ("floor_peak_bytes", p.floor_peak_bytes as f64),
                ("extra_time_s", p.extra_time_s),
                ("feasible", if p.feasible { 1.0 } else { 0.0 }),
            ]);
            for c in &p.choices {
                self.sink.emit_tagged("checkpoint_plan_layer",
                                      &[("policy", c.policy.name())], &[
                    ("layer", c.layer as f64),
                    ("projected_bytes", c.projected_bytes as f64),
                    ("saved_vs_save_all", c.saved_vs_save_all as f64),
                    ("extra_time_s", c.extra_time_s),
                ]);
            }
        }

        // how the tile size was resolved: probed on the first microbatch,
        // answered by the calibration artifact (probe skipped), or pinned
        // statically by `[ep] tile_rows` — one line in the JSONL stream so
        // a warm-start run is auditable against a cold one
        if let Some(info) = &self.build_info {
            self.sink.emit_tagged("autotune", &[("bucket", &info.bucket)], &[
                ("tile_rows", info.tile_rows as f64),
                ("probed", if info.tile_probed { 1.0 } else { 0.0 }),
                ("calibration_loaded",
                 if info.calibration_loaded { 1.0 } else { 0.0 }),
            ]);
        }

        // crash-consistent snapshots + bit-identical resume: generations
        // live under `[ep] snapshot_path`; writing is armed by
        // `snapshot_interval > 0` (0 = disabled — satellite edge case),
        // and `resume = true` restores the newest loadable generation
        // before step 0. The fingerprint covers exactly the
        // numerics-affecting config fields, so a snapshot taken at R=1
        // restores at R=4 but never into a different loss curve.
        let snap_store = if self.cfg.snapshot_path.is_empty() {
            None
        } else {
            Some(SnapshotStore::new(&self.cfg.snapshot_path))
        };
        let snap_armed = self.cfg.snapshot_interval > 0 && snap_store.is_some();
        let fingerprint = config_fingerprint(&self.cfg);
        let mut start_step = 0usize;
        let mut resumed_from = None;
        if self.cfg.resume {
            let store = snap_store
                .as_ref()
                .expect("validate(): resume requires snapshot_path");
            let state = store.load_latest().ok_or_else(|| anyhow::anyhow!(
                "resume = true but no loadable snapshot generation under {}",
                self.cfg.snapshot_path))?;
            if state.fingerprint != fingerprint {
                bail!(
                    "snapshot fingerprint {:#018x} does not match this \
                     config's {:#018x}: the snapshot came from a numerically \
                     different run",
                    state.fingerprint, fingerprint
                );
            }
            // restore exact bits: params via load_params (apply_update
            // would re-round), optimizer state via import_state
            self.engine
                .load_params(&state.params)
                .map_err(anyhow::Error::msg)?;
            self.optimizer
                .import_state(state.optimizer)
                .map_err(anyhow::Error::msg)?;
            start_step = state.step as usize;
            resumed_from = Some(start_step);
            if let Some(c) = &state.calibration {
                self.sink.emit("resume_calibration", &[
                    ("link_gbps", c.link_gbps),
                    ("compute_gflops", c.compute_gflops),
                ]);
            }
            self.sink.emit("resume", &[
                ("step", start_step as f64),
                ("generations", store.generations().len() as f64),
            ]);
            println!("resumed from snapshot at step {start_step} \
                      ({} generation(s) on disk)",
                     store.generations().len());
        }
        let mut snapshots_written = 0usize;

        let mut grads = self.engine.zero_grads();
        let mut losses = Vec::with_capacity(self.cfg.steps - start_step);
        let mut step_times = Vec::with_capacity(self.cfg.steps);
        let mut peak = Peak::new();
        let mut peak_rank = Peak::new();
        let mut throughput = Throughput::new();
        let mut grad_norm = 0.0f64;
        let mut final_lr = self.cfg.lr;
        let mut clipped_steps = 0usize;
        let mut calibrated: Option<CostModel> = None;
        // structured tracing: when `[ep] trace_out` names a file, hand
        // the engine a tracer so it records phase spans and resident-
        // bytes gauges; the trainer adds the optimizer spans, per-step
        // profile events, and the Chrome export at the end
        let tracer = if self.cfg.trace_out.is_empty() {
            None
        } else {
            let t = Tracer::new();
            self.engine.set_tracer(t.clone());
            Some(t)
        };
        // expert-load telemetry: attach a tracker when either consumer
        // is configured — `[ep] skew_alarm` (imbalance alarms) or
        // `[ep] metrics_expose_path` (Prometheus-style exposition).
        // Both default off, so a bare run hands the engines no tracker
        // and the forward path consults nothing.
        let registry = if self.cfg.metrics_expose_path.is_empty() {
            None
        } else {
            Some(Registry::new())
        };
        let load = if self.cfg.skew_alarm > 0.0 || registry.is_some() {
            let lt = ExpertLoadTracker::new(self.cfg.skew_alarm);
            self.engine.set_load_tracker(lt.clone());
            Some(lt)
        } else {
            None
        };
        let mut skew_alarms = 0usize;
        let mut max_imbalance = 0.0f64;
        let mut summaries: Vec<StepSummary> = Vec::new();
        // predicted-vs-measured drift: fold each step's calibration rows
        // into per-phase EWMA bands (timeline engines only), flagging
        // steps where the measured/predicted ratio leaves the band
        let mut drift = DriftDetector::default();
        let log_every = (self.cfg.steps / 10).max(1);
        for s in start_step..self.cfg.steps {
            if let Some(tr) = &tracer {
                tr.begin_step(s as u64);
            }
            // injected rank stall: numerics-neutral (a sleep plus a
            // recovered FaultEvent) — the serving loop reacts to the
            // same signal by shedding
            self.fault.maybe_stall(s as u64, self.cfg.ranks.max(1));
            let t0 = Instant::now();
            grads.clear();
            // one running f64 accumulator across microbatches: the float
            // op sequence matches the unsplit batch element-for-element
            let mut loss = 0.0f64;
            // measured wall-clock of this step's sessions: each
            // microbatch's timeline carries its own calibration samples,
            // so they must be summed per microbatch — the report after
            // the loop would only describe the last one
            let mut sessions_measured = 0.0f64;
            let mut all_sessions_measured = true;
            for (mi, (off, mb)) in micros.iter().enumerate() {
                // transient exchange faults hit BEFORE the engine call:
                // a failed attempt never reaches the numerics, so the
                // retry loop (bounded, exponential backoff) leaves the
                // loss curve untouched; an exhausted budget errors here
                self.fault
                    .exchange_gate(s as u64, mi as u64)
                    .map_err(anyhow::Error::msg)?;
                let handle = self
                    .engine
                    .forward(mb)
                    .map_err(anyhow::Error::msg)?;
                let out = handle.output();
                let mut d_out = vec![0.0f32; out.len()];
                let base = *off * d;
                for i in 0..out.len() {
                    let diff = out[i] - target[base + i];
                    loss += (diff as f64) * (diff as f64);
                    d_out[i] = scale * diff;
                }
                // sample between forward and backward: the session (and
                // its policy-saved tensors — every layer's, for stacks)
                // is resident right now
                let mem = self.engine.memory_per_rank();
                peak.observe(mem.iter().map(|m| m.data_bytes).sum());
                peak_rank.observe(
                    mem.iter().map(|m| m.data_bytes).max().unwrap_or(0),
                );
                handle
                    .backward_into(self.engine.as_mut(), &d_out, &mut grads)
                    .map_err(anyhow::Error::msg)?;
                match self.engine.measured_step_s() {
                    Some(s) => sessions_measured += s,
                    None => all_sessions_measured = false,
                }
            }
            loss /= global_elems as f64;
            if !loss.is_finite() {
                bail!("non-finite ep-train loss at step {s}: {loss}");
            }
            // clip on the accumulated global-step gradient, then apply
            // the scheduled LR — both pure functions of (grads, step),
            // so every bit-identity invariance survives them
            let (norm, clipped) = clip_global_norm(&mut grads, self.cfg.clip_norm);
            grad_norm = norm;
            if clipped {
                clipped_steps += 1;
            }
            let lr = self.schedule.lr_at(self.cfg.lr, s, self.cfg.steps);
            final_lr = lr;
            // the optimizer span covers step + apply — the host-side
            // work between the last backward and the next forward
            let mut opt_scope = tracer
                .as_ref()
                .map(|tr| tr.scope(TracePhase::OptimizerUpdate));
            if let Some(sc) = opt_scope.as_mut() {
                sc.rec.tokens = batch.num_tokens() as u64;
            }
            let delta = self
                .optimizer
                .step(&grads, lr as f32)
                .map_err(anyhow::Error::msg)?;
            self.engine
                .apply_update(&delta)
                .map_err(anyhow::Error::msg)?;
            drop(opt_scope);
            step_times.push(t0.elapsed().as_secs_f64() * 1e3);
            losses.push(loss);

            // tokens/s from measured wall-clock: prefer the engines'
            // per-phase calibration samples, summed over every
            // microbatch session of this step (what the host actually
            // spent in exchange/compute/combine), falling back to the
            // whole-step timer for engines without a timeline
            let step_s = *step_times.last().unwrap() / 1e3;
            let measured_s = if all_sessions_measured && sessions_measured > 0.0 {
                sessions_measured
            } else {
                step_s
            };
            throughput.record_tokens(batch.num_tokens() as u64, measured_s);

            // the self-tuning cost model: fold this step's
            // measured-vs-simulated phase ratios into the engine's
            // effective rates (numerics untouched — only the simulated
            // clock's pricing moves)
            if self.cfg.calibrate {
                if let Some(cm) =
                    self.engine.recalibrate_cost_model(CALIBRATE_ALPHA)
                {
                    calibrated = Some(cm);
                    self.sink.emit("calibration_update", &[
                        ("step", s as f64),
                        ("link_gbps", cm.link_gbps),
                        ("compute_gflops", cm.compute_gflops),
                    ]);
                }
            }

            let t = self.engine.traffic();
            self.sink.emit("ep_train", &[
                ("step", s as f64),
                ("loss", loss),
                ("lr", lr),
                ("step_ms", *step_times.last().unwrap()),
                ("dispatch_bytes", t.dispatch_bytes as f64),
                ("grad_bytes", t.grad_bytes as f64),
                ("recompute_bytes", t.recompute_bytes as f64),
                ("grad_norm", grad_norm),
                ("clipped", if clipped { 1.0 } else { 0.0 }),
                ("micro_steps", micros.len() as f64),
            ]);
            // per-phase drift verdicts for this step (timeline engines
            // only — barrier engines have no calibration rows to judge)
            if let Some(rep) = self.engine.overlap_report() {
                for v in drift.observe_step(&rep.calibration()) {
                    self.sink.emit_tagged("drift", &[("phase", v.phase.name())], &[
                        ("step", s as f64),
                        ("ratio", v.ratio),
                        ("mean", v.mean),
                        ("band", v.band),
                        ("flagged", if v.flagged { 1.0 } else { 0.0 }),
                    ]);
                }
            }
            // step boundary for the load tracker: fold this step's
            // routed rows, judge skew, and surface raised alarms in the
            // JSONL stream and on the console; on the log cadence the
            // registry (if configured) gets the refreshed load picture
            // and the exposition file is rewritten atomically
            if let Some(lt) = &load {
                for sig in lt.end_step() {
                    if sig.should_replan {
                        skew_alarms += 1;
                        self.sink.emit("skew_alarm", &[
                            ("step", s as f64),
                            ("layer", sig.layer as f64),
                            ("imbalance", sig.imbalance),
                            ("threshold", lt.threshold()),
                            ("ranks", sig.rank_loads.len() as f64),
                        ]);
                        println!(
                            "warning: skew alarm: layer {} imbalance {:.3} \
                             over threshold {} at step {s}",
                            sig.layer, sig.imbalance, lt.threshold());
                    }
                }
                let m = lt.max_imbalance();
                if m > max_imbalance {
                    max_imbalance = m;
                }
                // monotone per-rank `load_rows` counter tracks in the
                // Chrome trace (traced + load-tracked runs only)
                if let Some(tr) = &tracer {
                    let cum = lt.cumulative_rank_rows();
                    for (r, rows) in cum.iter().enumerate() {
                        tr.gauge(r, "load_rows", *rows as f64, "gather");
                    }
                }
                if let Some(reg) = &registry {
                    if s % log_every == 0 || s + 1 == self.cfg.steps {
                        reg.gauge("moeblaze_step",
                                  "last completed optimizer step", &[])
                            .set(s as f64);
                        reg.gauge("moeblaze_loss",
                                  "training loss of the last step", &[])
                            .set(loss);
                        reg.gauge("moeblaze_lr",
                                  "learning rate of the last step", &[])
                            .set(lr);
                        lt.publish_registry(reg);
                        // like the calibration artifact, an unwritable
                        // exposition path must not fail the run
                        if let Err(e) = reg.save(&self.cfg.metrics_expose_path) {
                            eprintln!(
                                "warning: could not write metrics exposition {}: {e}",
                                self.cfg.metrics_expose_path);
                        }
                    }
                }
            }
            if let Some(tr) = &tracer {
                self.sink.emit("step_profile", &tr.step_profile(s as u64).fields());
                // the summary the Chrome export embeds: engine-measured
                // step seconds (summed across microbatch sessions) and
                // the per-rank resident bytes the gauges sampled
                let step_measured = if all_sessions_measured && sessions_measured > 0.0 {
                    sessions_measured
                } else {
                    tr.step_measured_s(s as u64)
                };
                summaries.push(StepSummary {
                    step: s as u64,
                    measured_step_s: step_measured,
                    peak_rank_bytes: self
                        .engine
                        .memory_per_rank()
                        .iter()
                        .map(|m| m.data_bytes)
                        .collect(),
                });
            }
            // snapshot due dates land only here — AFTER the optimizer
            // applied the accumulated update, i.e. at an optimizer-step
            // boundary. A due date can never split an accumulation
            // window: the microbatch loop above completed before this
            // point, which is the mid-grad-accum deferral the edge-case
            // tests pin (micro_cursor is structurally 0). The final
            // step always snapshots when armed, so `interval > steps`
            // still yields exactly one generation.
            if snap_armed
                && ((s + 1) % self.cfg.snapshot_interval == 0
                    || s + 1 == self.cfg.steps)
            {
                let store = snap_store.as_ref().unwrap();
                let state = TrainState {
                    fingerprint,
                    step: (s + 1) as u64,
                    micro_cursor: 0,
                    params: self
                        .engine
                        .gather_params()
                        .map_err(anyhow::Error::msg)?,
                    optimizer: self.optimizer.export_state(),
                    calibration: calibrated.as_ref().map(|cm| Calibration {
                        link_gbps: cm.link_gbps,
                        compute_gflops: cm.compute_gflops,
                        tiles: Default::default(),
                    }),
                };
                store.save(&state).map_err(anyhow::Error::msg)?;
                snapshots_written += 1;
                self.sink.emit("snapshot", &[
                    ("step", (s + 1) as f64),
                    ("generations", store.generations().len() as f64),
                ]);
                // injected snapshot corruption hits the artifact just
                // written; recovery (an older generation still loads)
                // or its absence is recorded on the event
                self.fault
                    .maybe_corrupt_snapshot((s + 1) as u64, store)
                    .map_err(anyhow::Error::msg)?;
            }
            // surface this step's injected faults: every event reaches
            // the metrics stream (and the registry when configured) —
            // recovery without a trace would be silent degradation
            for ev in self.fault.drain() {
                self.sink.emit_tagged("fault", &[("kind", ev.kind.name())], &[
                    ("step", ev.step as f64),
                    ("rank", ev.rank as f64),
                    ("retries", ev.retries as f64),
                    ("recovered", if ev.recovered { 1.0 } else { 0.0 }),
                ]);
                if let Some(reg) = &registry {
                    reg.counter("moeblaze_fault_events_total",
                                "injected fault events by kind",
                                &[("kind", ev.kind.name())])
                        .inc();
                    if !ev.recovered {
                        reg.counter("moeblaze_fault_unrecovered_total",
                                    "injected faults that could not be recovered",
                                    &[("kind", ev.kind.name())])
                            .inc();
                    }
                }
            }
            if s % log_every == 0 || s + 1 == self.cfg.steps {
                println!("{}", self.sink.console(s, &[("loss", loss), ("lr", lr)]));
            }
            // the emulated kill: stop exactly as an interrupted run
            // would, with only the snapshots written so far on disk
            if self.halt_after_steps == Some(s + 1) {
                self.sink.emit("halt", &[("step", (s + 1) as f64)]);
                break;
            }
        }
        // chunk-pipelined engines: emit the final step's overlap roll-up
        // plus the simulated-vs-measured calibration per phase
        let overlap = self.engine.overlap_report();
        if let Some(rep) = &overlap {
            let engine_name = self.engine.name();
            self.sink.emit_tagged("overlap", &[("engine", engine_name.as_str())], &[
                ("chunks", rep.chunks as f64),
                ("critical_path_s", rep.critical_path_s),
                ("serial_path_s", rep.serial_path_s()),
                ("ideal_path_s", rep.ideal_path_s()),
                ("exposed_comm_fraction", rep.exposed_comm_fraction()),
                ("overlap_efficiency", rep.overlap_efficiency()),
                ("exchange_bytes", rep.exchange_bytes as f64),
                ("backward_bytes", rep.backward_bytes as f64),
            ]);
            for c in rep.calibration() {
                self.sink.emit_tagged("overlap_calibration",
                                      &[("phase", c.phase.name())], &[
                    ("simulated_s", c.simulated_s),
                    ("measured_s", c.measured_s),
                    ("ratio", c.ratio()),
                ]);
            }
        }
        // the zero-copy contract: nothing in the loop duplicated the
        // workload payload after construction
        for (_, mb) in &micros {
            if mb.copy_count() != 0 {
                bail!("step loop deep-copied a microbatch {} times",
                      mb.copy_count());
            }
        }
        if batch.copy_count() != 0 {
            bail!("step loop deep-copied the global batch {} times",
                  batch.copy_count());
        }
        // persist what this run learned: the EWMA-folded effective rates
        // (when `calibrate = true` produced them; the static config rates
        // otherwise) plus the resolved tile for this shape bucket, merged
        // into whatever the artifact already holds so buckets accumulate
        // across runs of different shapes
        if !self.cfg.calibration_path.is_empty() {
            let mut artifact = Calibration::load(&self.cfg.calibration_path)
                .unwrap_or_else(|| Calibration {
                    link_gbps: self.cfg.link_gbps,
                    compute_gflops: self.cfg.compute_gflops,
                    tiles: Default::default(),
                });
            if let Some(cm) = &calibrated {
                artifact.link_gbps = cm.link_gbps;
                artifact.compute_gflops = cm.compute_gflops;
            }
            let (bucket, tile) = match &self.build_info {
                Some(info) => (info.bucket.clone(), info.tile_rows),
                None => (tile_bucket(&self.cfg), self.cfg.tile_rows),
            };
            if tile > 0 {
                artifact.tiles.insert(bucket, tile);
            }
            match artifact.save(&self.cfg.calibration_path) {
                Ok(()) => self.sink.emit("calibration_saved", &[
                    ("link_gbps", artifact.link_gbps),
                    ("compute_gflops", artifact.compute_gflops),
                    ("tiles", artifact.tiles.len() as f64),
                ]),
                // a read-only path must not fail the training run
                Err(e) => eprintln!(
                    "warning: could not save calibration artifact {}: {e}",
                    self.cfg.calibration_path),
            }
        }
        // the Chrome trace: every span and gauge the run recorded plus
        // the per-step summaries `tools/trace_report.py` cross-checks
        if let Some(tr) = &tracer {
            let json = tr.chrome_trace(&summaries).to_string();
            match std::fs::write(&self.cfg.trace_out, json) {
                Ok(()) => self.sink.emit("trace_written", &[
                    ("steps", summaries.len() as f64),
                    ("spans", tr.span_count() as f64),
                    ("counters", tr.counter_count() as f64),
                ]),
                // like the calibration artifact, an unwritable trace
                // path must not fail the training run
                Err(e) => eprintln!("warning: could not write trace {}: {e}",
                                    self.cfg.trace_out),
            }
        }
        if drift.total_flags() > 0 {
            self.sink.emit("drift_summary", &[
                ("total_flags", drift.total_flags() as f64),
            ]);
        }
        // the load roll-up: one line summarizing what the tracker saw,
        // whether alarms fired or not (an explicit zero is evidence the
        // run was balanced, not that telemetry was off)
        if let Some(lt) = &load {
            self.sink.emit("load_summary", &[
                ("skew_alarms", skew_alarms as f64),
                ("max_imbalance", max_imbalance),
                ("layers", lt.snapshot().len() as f64),
                ("records", lt.record_count() as f64),
            ]);
        }
        // fault roll-up: one line whether faults fired or not is only
        // written for armed plans (a bare run's stream stays unchanged)
        if self.fault.enabled() {
            self.sink.emit("fault_summary", &[
                ("events", self.fault.total as f64),
                ("unrecovered", self.fault.unrecovered as f64),
            ]);
        }
        // surface metrics-stream write failures instead of losing the
        // run's observability silently
        if let Err(e) = self.sink.check() {
            eprintln!("warning: metrics stream {}: {e}", self.cfg.metrics_path);
        }
        Ok(EpTrainReport {
            steps: self.cfg.steps,
            first_loss: losses.first().copied().unwrap_or(f64::NAN),
            final_loss: losses.last().copied().unwrap_or(f64::NAN),
            traffic: self.engine.traffic(),
            step_ms_mean: step_times.iter().sum::<f64>()
                / step_times.len().max(1) as f64,
            peak_data_bytes: peak.get(),
            peak_rank_data_bytes: peak_rank.get(),
            plan,
            grad_norm,
            final_lr,
            clipped_steps,
            overlap,
            tokens_per_sec: throughput.tokens_per_sec(),
            calibrated,
            drift_flags: drift.total_flags(),
            skew_alarms,
            max_imbalance,
            snapshots_written,
            resumed_from_step: resumed_from,
            fault_events: self.fault.total as usize,
            fault_unrecovered: self.fault.unrecovered as usize,
            losses,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::engine_from_config;
    use crate::memory::model::CheckpointPolicy;

    fn tiny_cfg(ranks: usize) -> EpConfig {
        EpConfig {
            ranks,
            tokens: 32,
            num_experts: 4,
            top_k: 2,
            d_model: 8,
            d_hidden: 12,
            steps: 5,
            lr: 0.1,
            seed: 3,
            ..EpConfig::default()
        }
    }

    fn run_losses(cfg: EpConfig) -> Vec<f64> {
        let engine = engine_from_config(&cfg).unwrap();
        let mut t = EpTrainer::new(engine, cfg).unwrap();
        t.run().unwrap().losses
    }

    #[test]
    fn ep_trainer_reduces_loss() {
        let cfg = tiny_cfg(2);
        let engine = engine_from_config(&cfg).unwrap();
        let mut t = EpTrainer::new(engine, cfg).unwrap();
        let r = t.run().unwrap();
        assert_eq!(r.steps, 5);
        assert!(r.final_loss < r.first_loss,
                "loss did not drop: {:?}", r.losses);
        assert!(r.traffic.dispatch_bytes > 0);
        assert!(r.grad_norm > 0.0);
        assert!(r.peak_data_bytes > 0);
    }

    #[test]
    fn single_rank_reports_peak_memory_too() {
        // memory_per_rank persists across the session's backward on
        // both engines — the R=1 path must not report zero
        let cfg = tiny_cfg(1);
        let engine = engine_from_config(&cfg).unwrap();
        let mut t = EpTrainer::new(engine, cfg).unwrap();
        let r = t.run().unwrap();
        assert!(r.peak_data_bytes > 0, "R=1 peak_data_bytes is zero");
        let mem = t.engine.memory_per_rank();
        assert_eq!(mem.len(), 1);
        assert!(mem[0].data_bytes > 0,
                "single-rank memory zeroed after backward");
    }

    #[test]
    fn ep_training_loss_curves_match_across_rank_counts() {
        let losses: Vec<Vec<f64>> = [1usize, 2, 4]
            .iter()
            .map(|&ranks| run_losses(tiny_cfg(ranks)))
            .collect();
        assert_eq!(losses[0], losses[1], "R=1 vs R=2 diverged");
        assert_eq!(losses[0], losses[2], "R=1 vs R=4 diverged");
    }

    #[test]
    fn loss_curve_is_bit_invariant_to_grad_accum_split() {
        let reference = run_losses(tiny_cfg(2));
        for accum in [2usize, 4] {
            for ranks in [1usize, 2] {
                let cfg = EpConfig { grad_accum: accum, ..tiny_cfg(ranks) };
                assert_eq!(run_losses(cfg), reference,
                           "grad_accum={accum} R={ranks} diverged");
            }
        }
    }

    #[test]
    fn loss_curve_is_bit_invariant_to_checkpoint_policy() {
        let reference = run_losses(tiny_cfg(2));
        for policy in CheckpointPolicy::ALL {
            for ranks in [1usize, 2] {
                let cfg = EpConfig { checkpoint: policy, ..tiny_cfg(ranks) };
                assert_eq!(run_losses(cfg), reference,
                           "{policy} R={ranks} diverged");
            }
        }
    }

    #[test]
    fn cosine_schedule_and_clipping_stay_rank_invariant() {
        let mk = |ranks: usize| EpConfig {
            lr_schedule: "cosine".into(),
            clip_norm: 0.5,
            steps: 10,
            ..tiny_cfg(ranks)
        };
        let a = run_losses(mk(1));
        let b = run_losses(mk(4));
        assert_eq!(a, b, "schedule+clip broke rank invariance");
        // and the schedule is live: the trajectory differs from constant-LR
        let constant = run_losses(EpConfig { steps: 10, ..tiny_cfg(1) });
        assert_ne!(a, constant);
    }

    #[test]
    fn clipping_caps_every_step_and_is_counted() {
        let cfg = EpConfig { clip_norm: 1e-3, ..tiny_cfg(2) };
        let engine = engine_from_config(&cfg).unwrap();
        let mut t = EpTrainer::new(engine, cfg).unwrap();
        let r = t.run().unwrap();
        assert_eq!(r.clipped_steps, r.steps, "every step should clip");
        assert!(r.grad_norm > 1e-3, "reported norm must be pre-clip");
        // defaults clip nothing
        let cfg = tiny_cfg(2);
        let engine = engine_from_config(&cfg).unwrap();
        let r = EpTrainer::new(engine, cfg).unwrap().run().unwrap();
        assert_eq!(r.clipped_steps, 0);
        assert!(r.overlap.is_none(), "barrier engines report no timeline");
    }

    #[test]
    fn pipelined_engine_trains_bit_identically_and_reports_overlap() {
        let reference = run_losses(tiny_cfg(2));
        for chunks in [1usize, 2, 4] {
            let cfg = EpConfig { pipeline_chunks: chunks, ..tiny_cfg(2) };
            let engine = engine_from_config(&cfg).unwrap();
            let mut t = EpTrainer::new(engine, cfg).unwrap();
            let r = t.run().unwrap();
            assert_eq!(r.losses, reference, "K={chunks} loss curve diverged");
            let rep = r.overlap.expect("pipelined engine must report a timeline");
            assert_eq!(rep.chunks, chunks.min(32));
            assert!(rep.critical_path_s > 0.0);
            assert!(rep.exposed_comm_fraction() <= 1.0);
        }
    }

    #[test]
    fn multi_layer_stack_trains_rank_and_chunk_invariant() {
        let mk = |ranks: usize, chunks: usize, accum: usize| EpConfig {
            num_layers: 2,
            pipeline_chunks: chunks,
            grad_accum: accum,
            ..tiny_cfg(ranks)
        };
        let engine = engine_from_config(&mk(2, 0, 1)).unwrap();
        let mut t = EpTrainer::new(engine, mk(2, 0, 1)).unwrap();
        let r = t.run().unwrap();
        assert!(r.final_loss < r.first_loss, "stack did not learn: {:?}",
                r.losses);
        assert!(r.peak_rank_data_bytes > 0);
        assert!(r.plan.is_some(), "multi-layer runs must carry a plan");
        // rank counts, chunkings, and grad-accum splits all reproduce
        // the same stacked loss curve bit-for-bit
        for cfg in [mk(1, 0, 1), mk(4, 0, 1), mk(2, 2, 1), mk(2, 0, 2)] {
            assert_eq!(run_losses(cfg.clone()), r.losses,
                       "R={} K={} accum={} stacked curve diverged",
                       cfg.ranks, cfg.pipeline_chunks, cfg.grad_accum);
        }
        // and a single layer still reports no plan
        let single = engine_from_config(&tiny_cfg(2)).unwrap();
        let rs = EpTrainer::new(single, tiny_cfg(2)).unwrap().run().unwrap();
        assert!(rs.plan.is_none());
    }

    #[test]
    fn checkpoint_auto_respects_the_budget_it_plans() {
        use crate::coordinator::stack::plan_from_config;
        let base = EpConfig {
            num_layers: 3,
            checkpoint_auto: true,
            ..tiny_cfg(2)
        };
        let unlimited = plan_from_config(&base).unwrap().unwrap();
        let budget = (unlimited.save_all_peak_bytes + unlimited.floor_peak_bytes) / 2;
        let cfg = EpConfig { mem_budget_bytes: budget, ..base };
        let engine = engine_from_config(&cfg).unwrap();
        let mut t = EpTrainer::new(engine, cfg).unwrap();
        let r = t.run().unwrap();
        let plan = r.plan.as_ref().expect("auto run carries its plan");
        assert!(plan.feasible);
        assert!(plan.policies().iter().any(|&p| p != CheckpointPolicy::SaveAll),
                "a budget under the ceiling must downgrade something");
        assert!(r.peak_rank_data_bytes <= budget,
                "measured per-rank peak {} over budget {budget}",
                r.peak_rank_data_bytes);
        assert!(r.final_loss < r.first_loss);
        // the planned run's loss curve matches every uniform-policy run
        let uniform = run_losses(EpConfig { num_layers: 3, ..tiny_cfg(2) });
        assert_eq!(r.losses, uniform, "planner policies changed the numerics");
    }

    #[test]
    fn calibrate_folds_measured_ratios_into_the_cost_model() {
        let cfg = EpConfig {
            pipeline_chunks: 2,
            calibrate: true,
            ..tiny_cfg(2)
        };
        let engine = engine_from_config(&cfg).unwrap();
        let mut t = EpTrainer::new(engine, cfg.clone()).unwrap();
        let r = t.run().unwrap();
        let cm = r.calibrated.expect("pipelined + calibrate must report rates");
        assert!(cm.link_gbps > 0.0 && cm.link_gbps.is_finite());
        assert!(cm.compute_gflops > 0.0 && cm.compute_gflops.is_finite());
        assert!(r.tokens_per_sec > 0.0, "measured tokens/s missing");
        // calibration only moves the simulated clock's rates — the
        // numerics stay bit-identical
        let plain = run_losses(EpConfig { calibrate: false, ..cfg });
        assert_eq!(r.losses, plain, "calibration changed the numerics");
        // barrier engines carry no timeline: nothing to calibrate, but
        // tokens/s still comes from the step timer
        let cfg2 = EpConfig { calibrate: true, ..tiny_cfg(2) };
        let engine = engine_from_config(&cfg2).unwrap();
        let r2 = EpTrainer::new(engine, cfg2).unwrap().run().unwrap();
        assert!(r2.calibrated.is_none());
        assert!(r2.tokens_per_sec > 0.0);
    }

    #[test]
    fn load_telemetry_is_option_gated_and_loss_neutral() {
        let bare = run_losses(tiny_cfg(2));
        // bare runs attach nothing and report zeros
        let engine = engine_from_config(&tiny_cfg(2)).unwrap();
        let r0 = EpTrainer::new(engine, tiny_cfg(2)).unwrap().run().unwrap();
        assert_eq!(r0.skew_alarms, 0);
        assert_eq!(r0.max_imbalance, 0.0);
        // metered run: same losses bit-for-bit, exposition written
        let dir = std::env::temp_dir().join("moeblaze_trainer_load_test");
        std::fs::create_dir_all(&dir).unwrap();
        let expose = dir.join("metrics.prom");
        let cfg = EpConfig {
            skew_alarm: 4.0,
            metrics_expose_path: expose.to_str().unwrap().into(),
            ..tiny_cfg(2)
        };
        let engine = engine_from_config(&cfg).unwrap();
        let mut t = EpTrainer::new(engine, cfg).unwrap();
        let r = t.run().unwrap();
        assert_eq!(r.losses, bare, "load telemetry perturbed the loss curve");
        assert!(r.max_imbalance > 0.0, "tracker never folded a step");
        // R=2 caps max/mean at 2.0, far under the 4.0 threshold
        assert_eq!(r.skew_alarms, 0, "balanced run raised a skew alarm");
        let text = std::fs::read_to_string(&expose).unwrap();
        for family in ["moeblaze_expert_load_ewma",
                       "moeblaze_load_imbalance",
                       "moeblaze_rank_load_rows_total",
                       "moeblaze_skew_alarms_total",
                       "moeblaze_loss"] {
            assert!(text.contains(family), "exposition missing {family}");
        }
        std::fs::remove_file(&expose).ok();
    }

    fn snap_base(tag: &str) -> String {
        std::env::temp_dir()
            .join(format!("moeblaze_trainer_snap_{}_{tag}", std::process::id()))
            .to_string_lossy()
            .into_owned()
    }

    fn snap_cleanup(base: &str) {
        for (_, p) in crate::resilience::SnapshotStore::new(base).generations() {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn snapshot_interval_zero_disables_snapshotting() {
        // edge case: interval 0 = off, even with a path set
        let base = snap_base("off");
        snap_cleanup(&base);
        let cfg = EpConfig {
            snapshot_interval: 0,
            snapshot_path: base.clone(),
            ..tiny_cfg(2)
        };
        let engine = engine_from_config(&cfg).unwrap();
        let r = EpTrainer::new(engine, cfg).unwrap().run().unwrap();
        assert_eq!(r.snapshots_written, 0);
        assert!(crate::resilience::SnapshotStore::new(&base)
            .generations()
            .is_empty());
        snap_cleanup(&base);
    }

    #[test]
    fn snapshot_interval_past_the_run_yields_one_final_generation() {
        // edge case: interval > total steps -> exactly the final-step
        // snapshot, nothing else
        let base = snap_base("past");
        snap_cleanup(&base);
        let cfg = EpConfig {
            snapshot_interval: 100,
            snapshot_path: base.clone(),
            ..tiny_cfg(2)
        };
        let engine = engine_from_config(&cfg).unwrap();
        let r = EpTrainer::new(engine, cfg.clone()).unwrap().run().unwrap();
        assert_eq!(r.snapshots_written, 1);
        let store = crate::resilience::SnapshotStore::new(&base);
        let gens = store.generations();
        assert_eq!(gens.iter().map(|(g, _)| *g).collect::<Vec<_>>(),
                   vec![cfg.steps as u64]);
        assert_eq!(store.load_latest().unwrap().step, cfg.steps as u64);
        snap_cleanup(&base);
    }

    #[test]
    fn snapshots_defer_to_optimizer_step_boundaries_under_grad_accum() {
        // edge case: with grad_accum > 1 a wall-clock "due" moment can
        // fall mid-accumulation; snapshots must land only at optimizer-
        // step boundaries, so every generation carries micro_cursor 0
        // and a step that is an interval multiple (or the final step)
        let base = snap_base("accum");
        snap_cleanup(&base);
        let cfg = EpConfig {
            grad_accum: 4,
            steps: 5,
            snapshot_interval: 2,
            snapshot_path: base.clone(),
            ..tiny_cfg(2)
        };
        let engine = engine_from_config(&cfg).unwrap();
        let r = EpTrainer::new(engine, cfg.clone()).unwrap().run().unwrap();
        // steps 2, 4, and the final step 5
        assert_eq!(r.snapshots_written, 3);
        let store = crate::resilience::SnapshotStore::new(&base);
        for (g, path) in store.generations() {
            let state = crate::resilience::TrainState::from_bytes(
                &std::fs::read(&path).unwrap())
                .expect("every generation decodes");
            assert_eq!(state.micro_cursor, 0, "gen {g} split an accumulation");
            assert!(g % 2 == 0 || g == cfg.steps as u64,
                    "gen {g} is not an optimizer-step due date");
        }
        snap_cleanup(&base);
    }

    #[test]
    fn snapshotting_is_loss_neutral() {
        // writing snapshots must not move the loss curve by a bit
        let base = snap_base("neutral");
        snap_cleanup(&base);
        let bare = run_losses(tiny_cfg(2));
        let cfg = EpConfig {
            snapshot_interval: 2,
            snapshot_path: base.clone(),
            ..tiny_cfg(2)
        };
        assert_eq!(run_losses(cfg), bare, "snapshotting perturbed the curve");
        snap_cleanup(&base);
    }

    #[test]
    fn resume_without_a_snapshot_is_a_hard_error() {
        let base = snap_base("missing");
        snap_cleanup(&base);
        let cfg = EpConfig {
            resume: true,
            snapshot_path: base.clone(),
            ..tiny_cfg(2)
        };
        let engine = engine_from_config(&cfg).unwrap();
        let err = EpTrainer::new(engine, cfg).unwrap().run().unwrap_err();
        assert!(err.to_string().contains("no loadable snapshot"), "{err}");
    }

    #[test]
    fn resume_rejects_a_numerically_different_config() {
        let base = snap_base("fpr");
        snap_cleanup(&base);
        let cfg = EpConfig {
            snapshot_interval: 2,
            snapshot_path: base.clone(),
            ..tiny_cfg(2)
        };
        let engine = engine_from_config(&cfg).unwrap();
        EpTrainer::new(engine, cfg.clone()).unwrap().run().unwrap();
        // a different lr is a different curve: fingerprint must refuse
        let bad = EpConfig { lr: 0.2, resume: true, ..cfg.clone() };
        let engine = engine_from_config(&bad).unwrap();
        let err = EpTrainer::new(engine, bad).unwrap().run().unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        // topology changes are NOT numeric: R=4 resumes an R=2 snapshot
        let moved = EpConfig { ranks: 4, resume: true, ..cfg };
        let engine = engine_from_config(&moved).unwrap();
        let r = EpTrainer::new(engine, moved).unwrap().run().unwrap();
        assert_eq!(r.resumed_from_step, Some(5), "newest generation wins");
        snap_cleanup(&base);
    }

    #[test]
    fn injected_faults_are_recovered_and_counted_never_silent() {
        // an armed plan over the full training loop: losses stay
        // bit-identical to the bare run (stalls sleep, exchange retries
        // happen before the engine call, corruption hits artifacts, not
        // state), every event is accounted, none is silently dropped
        let base = snap_base("fault");
        snap_cleanup(&base);
        let bare = run_losses(tiny_cfg(2));
        let cfg = EpConfig {
            snapshot_interval: 1,
            snapshot_path: base.clone(),
            ..tiny_cfg(2)
        };
        let engine = engine_from_config(&cfg).unwrap();
        let mut t = EpTrainer::new(engine, cfg).unwrap();
        // seed 2's pinned plan over 5 steps: an exchange retry at step
        // 0 and a snapshot corruption at step 4 — by which point three
        // generations exist, so the last-good fallback recovers it
        t.set_fault_plan(crate::config::FaultConfig {
            seed: 2,
            stall_prob: 0.15,
            stall_ms: 0,
            exchange_fail_prob: 0.25,
            snapshot_corrupt_prob: 0.2,
            max_retries: 3,
            backoff_ms: 0,
        });
        let r = t.run().unwrap();
        assert_eq!(r.losses, bare, "fault injection perturbed the numerics");
        assert!(r.fault_events > 0, "the armed plan injected nothing");
        assert_eq!(r.fault_unrecovered, 0,
                   "seed-2 plan must recover every fault");
        // corrupted generations were really corrupted — yet the newest
        // loadable one still resumes the run
        let resumed = EpConfig {
            resume: true,
            snapshot_interval: 1,
            snapshot_path: base.clone(),
            ..tiny_cfg(2)
        };
        let engine = engine_from_config(&resumed).unwrap();
        let rr = EpTrainer::new(engine, resumed).unwrap().run().unwrap();
        assert!(rr.resumed_from_step.is_some());
        snap_cleanup(&base);
    }

    #[test]
    fn adam_trains_and_is_rank_invariant() {
        let mk = |ranks: usize| EpConfig {
            optimizer: "adam".into(),
            lr: 0.01,
            ..tiny_cfg(ranks)
        };
        let a = run_losses(mk(1));
        let b = run_losses(mk(4));
        assert_eq!(a, b, "adam diverged across rank counts");
        assert!(a.last().unwrap() < a.first().unwrap(),
                "adam did not reduce the loss: {a:?}");
        // and Adam actually differs from SGD (the optimizer is live)
        assert_ne!(a, run_losses(tiny_cfg(1)));
    }
}

//! Benchmark harness: regenerates the paper's figures from the AOT
//! artifacts (speed) and the analytic model (memory).

use std::rc::Rc;

use anyhow::Result;

use crate::config::model::Activation;
use crate::config::paper::{scaled_configs, PaperConfig, SCALED_BLOCK};
use crate::runtime::client::{Executable, Runtime};
use crate::runtime::host::HostTensor;
use crate::util::prng::Rng;
use crate::util::stats::{Bench, Summary};
use crate::util::table::Table;

/// One measured (config, impl) cell of Figure 4/6.
#[derive(Debug, Clone)]
pub struct SpeedCell {
    pub config: String,
    pub moeblaze: Summary,
    pub baseline: Summary,
    pub compile_ms: f64,
}

impl SpeedCell {
    /// median-based: robust to scheduler noise on a shared single core
    pub fn speedup(&self) -> f64 {
        self.baseline.median_ns / self.moeblaze.median_ns
    }
}

/// Random inputs generated from an artifact's manifest input specs, with
/// name-based scale heuristics (weights small, activations moderate).
pub fn inputs_from_specs(specs: &[crate::runtime::artifact::IoSpec], seed: u64)
                         -> Vec<HostTensor> {
    let mut rng = Rng::new(seed);
    specs
        .iter()
        .map(|s| {
            let n = s.elements();
            match s.dtype {
                crate::runtime::artifact::Dtype::F32 => {
                    let scale = if s.name.starts_with('w') { 0.2 } else { 0.5 };
                    HostTensor::F32 { shape: s.shape.clone(),
                                      data: rng.normal_vec(n, scale) }
                }
                crate::runtime::artifact::Dtype::I32 => HostTensor::I32 {
                    shape: s.shape.clone(),
                    data: (0..n).map(|_| rng.below(2) as i32).collect(),
                },
            }
        })
        .collect()
}

/// Measure one (config, activation) pair across both implementations.
pub fn measure_speed(runtime: &Runtime, c: &PaperConfig, activation: Activation,
                     bench: &Bench) -> Result<SpeedCell> {
    let mut compile_ms = 0.0;
    let mut run = |impl_name: &str| -> Result<Summary> {
        let name = format!("layer_step_{}_{}_{}", c.name, activation.name(), impl_name);
        let exe: Rc<Executable> = runtime.load(&name)?;
        compile_ms += exe.compile_ms;
        // both impls must see identical input values: same seed per config
        let inputs = inputs_from_specs(&exe.inputs, 0xBEEF ^ c.tokens() as u64);
        // correctness guard: one verified run before timing
        let out = exe.run(&inputs)?;
        anyhow::ensure!(out[0].as_f32()?[0].is_finite(), "non-finite loss in {name}");
        Ok(bench.run(|| {
            exe.run(&inputs).expect("bench run failed");
        }))
    };
    Ok(SpeedCell {
        config: c.name.to_string(),
        moeblaze: run("moeblaze")?,
        baseline: run("baseline")?,
        compile_ms,
    })
}

/// Full Figure 4 (silu) or Figure 6 (swiglu) sweep.
pub fn speed_figure(runtime: &Runtime, activation: Activation, bench: &Bench,
                    only: Option<&[String]>) -> Result<Vec<SpeedCell>> {
    let mut cells = Vec::new();
    for c in scaled_configs() {
        if let Some(filter) = only {
            if !filter.iter().any(|f| f == c.name) {
                continue;
            }
        }
        eprintln!("  measuring {} ({})...", c.name, activation.name());
        cells.push(measure_speed(runtime, &c, activation, bench)?);
    }
    Ok(cells)
}

pub fn render_speed_figure(title: &str, cells: &[SpeedCell]) -> String {
    let mut t = Table::new(["config", "megablocks-style (ms)", "moeblaze (ms)", "speedup"]);
    for c in cells {
        t.row([
            c.config.clone(),
            format!("{:.2}", c.baseline.median_ms()),
            format!("{:.2}", c.moeblaze.median_ms()),
            format!("{:.2}x", c.speedup()),
        ]);
    }
    format!("{title}\n{}", t.render())
}

/// Emit a figure's data as a JSON line (for EXPERIMENTS.md tooling).
pub fn speed_figure_json(activation: Activation, cells: &[SpeedCell]) -> String {
    use crate::util::json::Json;
    Json::obj(vec![
        ("figure", Json::str(if activation == Activation::Swiglu { "fig6" } else { "fig4" })),
        ("activation", Json::str(activation.name())),
        ("cells", Json::arr(cells.iter().map(|c| Json::obj(vec![
            ("config", Json::str(&c.config)),
            ("baseline_ms", Json::num(c.baseline.mean_ms())),
            ("moeblaze_ms", Json::num(c.moeblaze.mean_ms())),
            ("speedup", Json::num(c.speedup())),
        ])))),
    ])
    .to_string()
}

/// Scaled-config lookup helper shared by benches.
pub fn scaled_by_name(name: &str) -> Option<PaperConfig> {
    scaled_configs().into_iter().find(|c| c.name == name)
}

/// The block size the artifacts were exported with.
pub fn artifact_block() -> usize {
    SCALED_BLOCK
}
